"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
on CPU, with checkpoint/restart, using the production Trainer.

    PYTHONPATH=src python examples/train_lm.py --steps 200

(The multi-model CAMR-shuffled variant is examples/multimodel_camr.py;
this driver exercises the single-model production loop end to end.)
"""

import argparse
import json
import time

from repro.configs import get_config
from repro.data.pipeline import ShardedTokenPipeline
from repro.runtime import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M-param member of the granite family (12L x 768 x 3072)
    cfg = get_config("granite_3_2b").replace(
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
        d_ff=3072, vocab=8192, dtype="float32", loss_chunk=128,
        tie_embeddings=True)
    print(f"model: {cfg.param_count() / 1e6:.1f}M params")

    pipe = ShardedTokenPipeline(vocab=cfg.vocab, seq_len=128,
                                global_batch=8, structure=0.9)
    tr = Trainer(cfg, lr=1e-3, warmup=20, total_steps=args.steps,
                 ckpt_dir=args.ckpt_dir)
    if tr.resume():
        print(f"resumed from step {tr.step}")
    t0 = time.time()
    metrics = tr.run(pipe, steps=args.steps, log_every=20, ckpt_every=100)
    dt = time.time() - t0
    for m in metrics:
        print(json.dumps({k: round(v, 4) for k, v in m.items()}))
    first, last = metrics[0]["loss"], metrics[-1]["loss"]
    print(f"\nloss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"({args.steps / dt:.2f} steps/s)")
    assert last < first, "training must reduce loss"
    print("OK")


if __name__ == "__main__":
    main()
