"""The paper's deep-learning use case (§I): train J models simultaneously
with the CAMR-coded gradient shuffle, vs the uncoded baseline.

J = q^{k-1} = 4 small LMs on K = 6 simulated workers. Each worker maps
the microbatches it stores (redundancy k-1 = 2), aggregates per-batch
gradients (the compression step), and the 3-stage coded shuffle delivers
every worker the summed shard it reduces. Identical losses, fewer bytes.

    PYTHONPATH=src python examples/multimodel_camr.py --steps 3
"""

import argparse

import numpy as np

from repro.configs import get_config, reduced
from repro.core import loads
from repro.data.pipeline import ShardedTokenPipeline
from repro.runtime.train_loop import MultiModelCAMRTrainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=3)
    args = ap.parse_args()

    cfg = reduced(get_config("granite_3_2b")).replace(
        n_layers=2, vocab=256, d_model=64, d_ff=128, loss_chunk=16)
    pipe = ShardedTokenPipeline(vocab=cfg.vocab, seq_len=16,
                                global_batch=4, structure=0.9)

    reports = {}
    for mode in ("camr", "uncoded"):
        tr = MultiModelCAMRTrainer(cfg, q=2, k=3, lr=1e-3, seed=0)
        reports[mode] = tr.train_steps(pipe, args.steps, mode=mode)
        print(f"{mode:8s}: bytes/run={reports[mode].bytes_total:,} "
              f"L={reports[mode].loads.get('L_total_bus', 0):.4f} "
              f"final losses={np.round(reports[mode].losses[-1], 4)}")

    camr, unc = reports["camr"], reports["uncoded"]
    np.testing.assert_allclose(np.array(camr.losses),
                               np.array(unc.losses), rtol=1e-4)
    print(f"\nloss trajectories IDENTICAL; coded shuffle shipped "
          f"{1 - camr.bytes_total / unc.bytes_total:.1%} fewer bytes "
          f"(analytic: 1 - {loads.camr_load(2, 3):.2f}/"
          f"{loads.uncoded_aggregated_load(2, 3):.2f} = "
          f"{1 - loads.camr_load(2, 3) / loads.uncoded_aggregated_load(2, 3):.1%})")
    print("OK")


if __name__ == "__main__":
    main()
