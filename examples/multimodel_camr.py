"""The paper's deep-learning use case (§I): train J models simultaneously
with the CAMR-coded gradient shuffle — on the SPMD fused-codec
collective, the numpy engine interpreter, and the uncoded baseline.

J = q^{k-1} = 4 small LMs on K = 6 workers. Each worker maps the
microbatches it stores (redundancy k-1 = 2), compresses per-batch
gradients with the α-combiner (the paper's aggregation step), and the
3-stage coded shuffle delivers every worker the summed shard it
reduces. All three wires produce BIT-identical parameters and losses
(asserted below — the engine is the bit-identity oracle of the device
path); the coded shuffle just ships fewer bytes, and the SPMD path
runs it as one jitted shard_map program reused across steps.

    PYTHONPATH=src python examples/multimodel_camr.py --steps 3
    PYTHONPATH=src python examples/multimodel_camr.py --steps 3 \
        --modes camr,camr_spmd          # parity: device vs interpreter
"""

import os

if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=6")
# ^ before any jax import: mode="camr_spmd" needs a K=6-device mesh.

import argparse

import numpy as np

from repro.configs import get_config, reduced
from repro.core import loads
from repro.data.pipeline import ShardedTokenPipeline
from repro.runtime.train_loop import MultiModelCAMRTrainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--modes", default="camr,uncoded,camr_spmd",
                    help="comma-separated grad-sync modes to run and "
                         "compare (first one is the reference)")
    ap.add_argument("--grad-sync-dtype", default="float32",
                    choices=["float32", "bfloat16"],
                    help="shuffle payload dtype — bfloat16 rides the "
                         "packed 16-bit codec lane (DESIGN.md §12); the "
                         "bit-identity assertions hold per lane")
    args = ap.parse_args()
    modes = args.modes.split(",")

    cfg = reduced(get_config("granite_3_2b")).replace(
        n_layers=2, vocab=256, d_model=64, d_ff=128, loss_chunk=16)
    pipe = ShardedTokenPipeline(vocab=cfg.vocab, seq_len=16,
                                global_batch=4, structure=0.9)

    reports, trainers = {}, {}
    for mode in modes:
        tr = MultiModelCAMRTrainer(cfg, q=2, k=3, lr=1e-3, seed=0,
                                   spmd_oracle=(mode == "camr_spmd"),
                                   grad_sync_dtype=args.grad_sync_dtype)
        reports[mode] = tr.train_steps(pipe, args.steps, mode=mode)
        trainers[mode] = tr
        extra = (f" sync={reports[mode].sync}" if reports[mode].sync
                 else "")
        print(f"{mode:9s}: bytes/run={reports[mode].bytes_total:,} "
              f"L={reports[mode].loads.get('L_total_bus', 0):.4f} "
              f"final losses={np.round(reports[mode].losses[-1], 4)}"
              f"{extra}")

    ref = modes[0]
    for mode in modes[1:]:
        np.testing.assert_array_equal(
            np.asarray(trainers[mode].flat),
            np.asarray(trainers[ref].flat),
            err_msg=f"{mode} parameters diverged from {ref}")
        np.testing.assert_array_equal(
            np.asarray(reports[mode].losses),
            np.asarray(reports[ref].losses),
            err_msg=f"{mode} losses diverged from {ref}")
    print(f"\n{' vs '.join(modes)}: parameters and losses BIT-IDENTICAL")

    if "camr" in reports and "uncoded" in reports:
        camr, unc = reports["camr"], reports["uncoded"]
        print(f"coded shuffle shipped "
              f"{1 - camr.bytes_total / unc.bytes_total:.1%} fewer bytes "
              f"(analytic: 1 - {loads.camr_load(2, 3):.2f}/"
              f"{loads.uncoded_aggregated_load(2, 3):.2f} = "
              f"{1 - loads.camr_load(2, 3) / loads.uncoded_aggregated_load(2, 3):.1%})")
    print("OK")


if __name__ == "__main__":
    main()
