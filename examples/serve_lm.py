"""Serve a small model two ways (DESIGN.md §13): the legacy host loop
(`generate`, one host sync per token) and the continuous-batching
`DecodeEngine`/`ServeStream` (jitted wave decode over paged KV slots) —
then check the engine reproduces the host loop token-for-token.

    PYTHONPATH=src python examples/serve_lm.py
"""

import time

import numpy as np

import jax

from repro.configs import get_config, reduced
from repro.models import lm
from repro.runtime.serve import (DecodeEngine, Request, ServeStream,
                                 generate)


def main():
    cfg = reduced(get_config("gemma2_2b"))   # local/global + softcaps
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, P, NEW = 4, 12, 16
    prompts = rng.integers(0, cfg.vocab, (B, P)).astype(np.int32)

    t0 = time.time()
    res = generate(cfg, params, prompts, max_new=NEW)
    dt = time.time() - t0
    print(f"host loop: batch={B} prompt={P} new={res.steps} "
          f"({B * res.steps / dt:.1f} tok/s on CPU)")
    print(res.tokens[:, P:])

    # consistency: greedy decode must match teacher-forced argmax
    lg, _ = jax.jit(lambda p, b: lm.prefill(cfg, p, b))(
        params, {"tokens": res.tokens[:, :P + 1]})
    want = int(np.argmax(np.asarray(lg[0, -1, :cfg.vocab])))
    assert want == int(res.tokens[0, P + 1])
    print("OK (teacher-forcing consistency verified)")

    # the production shape: ragged requests through the wave engine
    reqs = [Request(prompt=prompts[i, :P - 2 * i], max_new=NEW)
            for i in range(B)]
    engine = DecodeEngine(cfg, params, slots=2, page_size=8,
                          max_ctx=P + NEW, max_new_cap=NEW)
    stream = ServeStream(engine, wave_len=8)
    stream.run(reqs)                         # warm the executables
    t0 = time.time()
    results = stream.run(reqs)
    dt = time.time() - t0
    rep = stream.last_report
    toks = sum(r.emitted for r in results)
    print(f"engine: {len(reqs)} ragged reqs, {toks} tokens "
          f"({toks / dt:.1f} tok/s), {rep.waves} waves, "
          f"occupancy {rep.occupancy:.2f}, traces {rep.traces}")
    for r in results:
        oracle = generate(cfg, params, r.tokens[None, :r.prompt_len],
                          max_new=NEW)
        assert np.array_equal(oracle.tokens[0, r.prompt_len:],
                              r.generated)
    print("OK (engine == host-loop oracle, token for token)")


if __name__ == "__main__":
    main()
