"""Serve a small model with batched requests: prefill + decode loop with
greedy sampling and per-sequence stopping.

    PYTHONPATH=src python examples/serve_lm.py
"""

import time

import numpy as np

import jax

from repro.configs import get_config, reduced
from repro.models import lm
from repro.runtime.serve import generate


def main():
    cfg = reduced(get_config("gemma2_2b"))   # local/global + softcaps
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, P, NEW = 4, 12, 16
    prompts = rng.integers(0, cfg.vocab, (B, P)).astype(np.int32)

    t0 = time.time()
    res = generate(cfg, params, prompts, max_new=NEW)
    dt = time.time() - t0
    print(f"batch={B} prompt={P} new={res.steps} "
          f"({B * res.steps / dt:.1f} tok/s on CPU)")
    print("generated token ids:")
    print(res.tokens[:, P:])

    # consistency: greedy decode must match teacher-forced argmax
    lg, _ = jax.jit(lambda p, b: lm.prefill(cfg, p, b))(
        params, {"tokens": res.tokens[:, :P + 1]})
    want = int(np.argmax(np.asarray(lg[0, -1, :cfg.vocab])))
    assert want == int(res.tokens[0, P + 1])
    print("OK (teacher-forcing consistency verified)")


if __name__ == "__main__":
    main()
