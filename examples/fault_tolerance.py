"""Fault tolerance demo: a worker dies after Map; the shuffle recovers
from the placement redundancy (no recomputation), functions migrate, and
the job still reduces correctly. Also shows elastic re-planning.

    PYTHONPATH=src python examples/fault_tolerance.py
"""

import numpy as np

from repro.core.engine import CAMRConfig, CAMREngine
from repro.runtime.fault import DegradedCAMREngine, elastic_replan


def main():
    cfg = CAMRConfig(q=3, k=3, gamma=1)
    Q = cfg.num_functions()
    rng = np.random.default_rng(0)
    ds = [[rng.standard_normal(8) for _ in range(cfg.N)]
          for _ in range(cfg.J)]

    def map_fn(job, sf):
        return np.outer(np.arange(1, Q + 1), sf)

    healthy = CAMREngine(cfg, map_fn)
    healthy.verify(ds, healthy.run(ds))
    lh = healthy.measured_loads()["L_total_bus"]
    print(f"healthy run: load {lh:.4f}")

    failed = {4}
    deg = DegradedCAMREngine(cfg, map_fn, failed=failed)
    results = deg.run(ds)
    oracle = deg.oracle(ds)
    checked = 0
    for s_orig in range(cfg.K):
        s = deg.migrate_target(s_orig)
        for qf in deg.functions_of(s_orig):
            for j in range(cfg.J):
                np.testing.assert_allclose(results[s][(j, qf)],
                                           oracle[(j, qf)], rtol=1e-9)
                checked += 1
    ld = deg.trace.total_bytes() / (cfg.J * Q * deg.value_bytes)
    print(f"worker U5 failed after Map: functions migrated to "
          f"U{deg.migrate_target(4) + 1}, all {checked} (job, fn) results"
          f" still exact; degraded load {ld:.4f} ({ld / lh:.2f}x)")

    rep = elastic_replan(3, 3, 12)
    print(f"elastic scale 9 -> 12 workers: new (q, k)={rep.new_qk}, "
          f"moved {rep.moved_fraction:.1%} of stored subfiles, "
          f"mu={rep.new_storage_fraction:.3f}")
    print("OK")


if __name__ == "__main__":
    main()
