"""The paper's neural-network use case (§I): distributed matrix-vector
products as CAMR jobs.

Each job j is y_j = W_j x_j (a forward-prop layer for model j); subfiles
are row-blocks of W_j. Map computes partial products, aggregation is the
(associative+commutative) elementwise sum of per-function row-slices,
and the coded shuffle delivers each server the slice of y_j it owns.

    PYTHONPATH=src python examples/matvec_jobs.py
"""

import numpy as np

from repro.core import loads
from repro.core.engine import CAMRConfig, CAMREngine


def main():
    q, k, gamma = 3, 3, 1
    cfg = CAMRConfig(q=q, k=k, gamma=gamma)
    Q = cfg.num_functions()          # K output slices per job
    DIM = Q * 8                      # y dimension (8 rows per function)
    rng = np.random.default_rng(0)

    # job j: W_j [DIM, DIM], x_j [DIM]; subfile n = column block n of W_j
    Ws = [rng.standard_normal((DIM, DIM)) for _ in range(cfg.J)]
    xs = [rng.standard_normal(DIM) for _ in range(cfg.J)]
    blk = DIM // cfg.N
    datasets = [
        [(Ws[j][:, n * blk:(n + 1) * blk], xs[j][n * blk:(n + 1) * blk])
         for n in range(cfg.N)]
        for j in range(cfg.J)
    ]

    def map_fn(job, subfile):
        Wblk, xblk = subfile
        y_part = Wblk @ xblk                       # [DIM]
        return y_part.reshape(Q, DIM // Q)         # one slice per function

    eng = CAMREngine(cfg, map_fn)
    results = eng.run(datasets)
    eng.verify(datasets, results)

    # server s holds slice s of every y_j — reassemble and check
    for j in range(cfg.J):
        y = np.concatenate([results[s][(j, s)] for s in range(cfg.K)])
        np.testing.assert_allclose(y, Ws[j] @ xs[j], rtol=1e-9)
    L = eng.measured_loads()
    print(f"J={cfg.J} matvec jobs on K={cfg.K} servers: all products "
          f"correct; shuffle load {L['L_total_bus']:.4f} "
          f"(closed form {loads.camr_load(q, k):.4f})")
    print("OK")


if __name__ == "__main__":
    main()
