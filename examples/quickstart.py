"""Quickstart: the paper's Example 1, end to end.

J=4 word-count jobs (books) on K=6 simulated servers (q=2, k=3), N=6
chapters each. Runs Map -> aggregate -> 3-stage coded Shuffle -> Reduce,
verifies every server's counts against the ground truth, and prints the
measured communication load per stage (paper: 1/4 + 1/4 + 1/2 = 1).

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import loads
from repro.core.engine import CAMRConfig, CAMREngine
from repro.data.pipeline import wordcount_corpus


def main():
    q, k, gamma = 2, 3, 2
    cfg = CAMRConfig(q=q, k=k, gamma=gamma)
    Q = cfg.num_functions()
    print(f"cluster: K={cfg.K} servers (q={q}, k={k}) | J={cfg.J} jobs | "
          f"N={cfg.N} subfiles/job | storage fraction mu="
          f"{(k - 1) / cfg.K:.3f}")

    books = wordcount_corpus(cfg.J, cfg.N, Q, chapter_len=200, seed=7)

    def count_words(job, chapter):
        # function f counts word f in the chapter -> (Q, 1) values
        return np.bincount(chapter, minlength=Q)[:, None].astype(np.int64)

    eng = CAMREngine(cfg, count_words)
    results = eng.run(books)
    eng.verify(books, results)
    print("\nreduce results (server -> word counts per job):")
    for s in (0, 3):
        for (j, f), v in sorted(results[s].items())[:2]:
            print(f"  server U{s + 1} reduced phi_{f + 1}(book {j + 1}) "
                  f"= {int(v[0])}")

    L = eng.measured_loads()
    print("\nmeasured communication load (shared-bus model, Def. 3):")
    for st in (1, 2, 3):
        print(f"  stage {st}: {L[f'L_stage{st}_bus']:.4f}")
    print(f"  total  : {L['L_total_bus']:.4f} "
          f"(paper closed form: {loads.camr_load(q, k):.4f})")
    print(f"\nCCDC at the same mu would need J = "
          f"{loads.ccdc_min_jobs(1 / 3, 6)} jobs; CAMR used {cfg.J}.")
    print("OK")


if __name__ == "__main__":
    main()
