"""Self-healing serving tests (DESIGN.md §15): deadlines and load-shed,
wave-retry from decode snapshots, poisoned-slot quarantine, the serving
chaos layer, and the legacy path's uniform status accounting.

The contract under EVERY fault plan: all requests terminate with an
explicit status from STATUSES, survivors are BITWISE identical to the
fault-free run, non-ok results carry a clean bitwise prefix, the page
pool leaks nothing, and the whole recovery path pays zero retraces
after warmup.
"""

import numpy as np
import pytest

import jax

from chaos import (ServeFaultPlan, SlotPoison, WaveCrash, WaveLatency,
                   run_serve_plan)
from repro.configs import get_config, reduced
from repro.models import lm
from repro.runtime.serve import (STATUSES, DecodeEngine, Request,
                                 ServeStream, WaveCrashError,
                                 generate, serve_legacy, trace_total)


@pytest.fixture(scope="module")
def gemma():
    cfg = reduced(get_config("gemma2_2b"))
    return cfg, lm.init_params(cfg, jax.random.PRNGKey(0))


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, (t,)).astype(np.int32)
            for t in lens]


def _oracle_gen(cfg, params, req):
    res = generate(cfg, params, np.asarray(req.prompt)[None],
                   max_new=req.max_new, eos=req.eos,
                   temperature=req.temperature, seed=req.seed,
                   pad=req.pad)
    return res.tokens[0, len(req.prompt):]


def _engine(cfg, params, slots=2):
    return DecodeEngine(cfg, params, slots=slots, page_size=4,
                        max_ctx=16, max_new_cap=6)


def _check_terminal(eng, results):
    """The invariants every fault plan must leave behind."""
    assert all(r is not None for r in results)
    assert all(r.status in STATUSES for r in results)
    assert eng.live == 0, "live slots leaked past stream completion"
    assert sorted(eng._free_slots) == list(range(eng.slots))
    eng.pool.check_invariants()
    assert eng.pool.free_pages == eng.n_pages - 1, "pages leaked"


def _check_vs_oracle(cfg, params, reqs, results):
    """ok/retried_ok: full bitwise parity. expired/quarantined: the
    emitted prefix is bitwise the oracle's prefix. shed: nothing."""
    for req, res in zip(reqs, results):
        if res.status == "shed":
            assert res.emitted == 0
            continue
        want = _oracle_gen(cfg, params, req)
        if res.ok:
            assert np.array_equal(res.generated[:len(want)], want), \
                f"{res.status}: survivor tokens diverged from oracle"
        else:
            assert np.array_equal(res.generated,
                                  want[:res.emitted]), \
                f"{res.status}: dirty prefix"


# --------------------------------------------------------------------- #
# wave-crash retry from the snapshot
# --------------------------------------------------------------------- #
def test_wave_crash_retry_bitwise_and_status(gemma):
    cfg, params = gemma
    reqs = [Request(prompt=p, max_new=6)
            for p in _prompts(cfg, [4, 7, 5], seed=1)]
    plan = ServeFaultPlan((WaveCrash(wave=1, times=1),), name="crash1")
    eng = _engine(cfg, params)
    results, stream, ctrl = run_serve_plan(eng, reqs, plan, wave_len=3)
    assert ctrl.injected_crashes == 1
    assert eng.rollbacks == 1
    assert stream.last_report.retries == 1
    assert stream.last_report.status_counts.get("retried_ok", 0) >= 1
    _check_terminal(eng, results)
    _check_vs_oracle(cfg, params, reqs, results)
    # the two requests live on a slot during the crashed wave survived
    # it -> retried_ok; a request admitted after the retry stays ok
    assert any(r.status == "retried_ok" and r.retries == 1
               for r in results)


def test_wave_crash_repeated_within_budget(gemma):
    cfg, params = gemma
    reqs = [Request(prompt=p, max_new=5)
            for p in _prompts(cfg, [5, 6], seed=2)]
    plan = ServeFaultPlan((WaveCrash(wave=0, times=2),), name="crash2x")
    eng = _engine(cfg, params)
    results, stream, ctrl = run_serve_plan(eng, reqs, plan,
                                           wave_len=2, max_retries=2)
    assert ctrl.injected_crashes == 2
    assert stream.last_report.retries == 2
    _check_terminal(eng, results)
    _check_vs_oracle(cfg, params, reqs, results)
    assert all(r.status == "retried_ok" and r.retries == 2
               for r in results[:2])


def test_wave_crash_exhausts_retry_budget(gemma):
    cfg, params = gemma
    reqs = [Request(prompt=p, max_new=4)
            for p in _prompts(cfg, [4], seed=3)]
    plan = ServeFaultPlan((WaveCrash(wave=0, times=5),), name="crash5x")
    eng = _engine(cfg, params)
    with pytest.raises(WaveCrashError):
        run_serve_plan(eng, reqs, plan, wave_len=2, max_retries=2)


def test_recovery_path_zero_retraces(gemma):
    """A second identical chaos run (fresh engine, same shapes) must hit
    the EXEC_CACHE for EVERYTHING — wave, prefill, admit, snapshot,
    rollback and poison-injection executables included."""
    cfg, params = gemma
    mk = lambda: [Request(prompt=p, max_new=6)
                  for p in _prompts(cfg, [4, 7, 5], seed=4)]
    plan = ServeFaultPlan((WaveCrash(wave=1, times=1),
                           SlotPoison(wave=1, slot=0)), name="warm")
    eng1 = _engine(cfg, params)
    run_serve_plan(eng1, mk(), plan, wave_len=3)     # warmup traces ok
    before = trace_total()
    eng2 = _engine(cfg, params)
    results, stream, _ = run_serve_plan(eng2, mk(), plan, wave_len=3)
    assert trace_total() == before, \
        "crash-retry / quarantine recovery must not retrace"
    assert stream.last_report.traces == 0
    _check_terminal(eng2, results)


# --------------------------------------------------------------------- #
# poisoned-slot quarantine
# --------------------------------------------------------------------- #
def test_slot_poison_quarantines_exactly_one(gemma):
    cfg, params = gemma
    reqs = [Request(prompt=p, max_new=6)
            for p in _prompts(cfg, [4, 7, 5], seed=5)]
    plan = ServeFaultPlan((SlotPoison(wave=1, slot=0),), name="poison")
    eng = _engine(cfg, params)
    results, stream, ctrl = run_serve_plan(eng, reqs, plan, wave_len=2)
    assert ctrl.injected_poisons == 1
    _check_terminal(eng, results)
    _check_vs_oracle(cfg, params, reqs, results)
    statuses = [r.status for r in results]
    assert statuses.count("quarantined") == 1
    q = results[statuses.index("quarantined")]
    # the sentinel fired BEFORE the garbage sample: the quarantined
    # request keeps exactly its clean pre-poison prefix (2 wave_len=2
    # waves ran before the poison landed -> 2 tokens)
    assert 0 < q.emitted < 6
    # siblings fully unaffected, and the freed slot was reused (3 reqs
    # over 2 slots forces recycling through the quarantined slot)
    assert statuses.count("ok") == 2
    assert stream.last_report.status_counts == {"ok": 2,
                                                "quarantined": 1}


def test_slot_poison_on_dead_slot_is_skipped(gemma):
    """A poison event addressing a slot that is no longer live must not
    fire (the controller guards on liveness) — and poisoning a dead
    slot directly is a hard error."""
    cfg, params = gemma
    reqs = [Request(prompt=p, max_new=3)
            for p in _prompts(cfg, [4], seed=6)]
    plan = ServeFaultPlan((SlotPoison(wave=50, slot=1),), name="noop")
    eng = _engine(cfg, params)
    results, _, ctrl = run_serve_plan(eng, reqs, plan, wave_len=4)
    assert ctrl.injected_poisons == 0
    assert results[0].status == "ok"
    with pytest.raises(ValueError):
        eng.poison_slot(1)


# --------------------------------------------------------------------- #
# deadlines + bounded admission (virtual clock)
# --------------------------------------------------------------------- #
def test_deadline_expires_queued_request(gemma):
    cfg, params = gemma
    ps = _prompts(cfg, [4, 5], seed=7)
    reqs = [Request(prompt=ps[0], max_new=4),
            Request(prompt=ps[1], max_new=4, deadline_s=0.0)]
    eng = _engine(cfg, params)
    results, _, _ = run_serve_plan(eng, reqs, ServeFaultPlan(()))
    assert results[0].status == "ok"
    assert results[1].status == "expired" and results[1].emitted == 0
    _check_terminal(eng, results)


def test_deadline_cancels_mid_flight_keeps_clean_prefix(gemma):
    cfg, params = gemma
    ps = _prompts(cfg, [4, 6], seed=8)
    # tick_s=1.0 per wave; deadline 1.5 -> survives wave 0 (2 tokens),
    # evicted before wave 2
    reqs = [Request(prompt=ps[0], max_new=6),
            Request(prompt=ps[1], max_new=6, deadline_s=1.5)]
    eng = _engine(cfg, params)
    results, _, _ = run_serve_plan(eng, reqs, ServeFaultPlan(()),
                                   wave_len=2)
    assert results[0].status == "ok"
    r = results[1]
    assert r.status == "expired"
    assert 0 < r.emitted < 6
    want = _oracle_gen(cfg, params, reqs[1])
    assert np.array_equal(r.generated, want[:r.emitted])
    _check_terminal(eng, results)


def test_bounded_queue_sheds_with_policy(gemma):
    cfg, params = gemma
    reqs = [Request(prompt=p, max_new=3)
            for p in _prompts(cfg, [4, 5, 6], seed=9)]

    def run(policy):
        eng = _engine(cfg, params)
        res, _, _ = run_serve_plan(eng, reqs, ServeFaultPlan(()),
                                   max_queue=1, shed_policy=policy)
        _check_terminal(eng, res)
        return [r.status for r in res]

    assert run("newest") == ["ok", "shed", "shed"]
    assert run("oldest") == ["shed", "shed", "ok"]
    with pytest.raises(ValueError):
        ServeStream(_engine(cfg, params), shed_policy="random")


# --------------------------------------------------------------------- #
# wave timeout -> discard + replay
# --------------------------------------------------------------------- #
def test_wave_timeout_discards_and_replays_bitwise(gemma):
    cfg, params = gemma
    reqs = [Request(prompt=p, max_new=5)
            for p in _prompts(cfg, [5, 6], seed=10)]
    plan = ServeFaultPlan((WaveLatency(wave=1, delay_s=60.0),),
                          name="slow")
    eng = _engine(cfg, params)
    results, stream, _ = run_serve_plan(eng, reqs, plan, wave_len=2,
                                        wave_timeout_s=5.0)
    assert stream.last_report.retries == 1
    assert eng.rollbacks == 1
    _check_terminal(eng, results)
    _check_vs_oracle(cfg, params, reqs, results)
    assert all(r.status == "retried_ok" for r in results)


# --------------------------------------------------------------------- #
# combined storm
# --------------------------------------------------------------------- #
def test_combined_fault_storm(gemma):
    cfg, params = gemma
    ps = _prompts(cfg, [4, 7, 5, 6], seed=11)
    reqs = [Request(prompt=ps[0], max_new=6),
            Request(prompt=ps[1], max_new=6),
            Request(prompt=ps[2], max_new=6, deadline_s=2.5),
            Request(prompt=ps[3], max_new=6)]
    plan = ServeFaultPlan((WaveCrash(wave=0, times=1),
                           SlotPoison(wave=1, slot=1),
                           WaveLatency(wave=2, delay_s=60.0)),
                          name="storm")
    eng = _engine(cfg, params)
    results, stream, ctrl = run_serve_plan(eng, reqs, plan, wave_len=2,
                                           wave_timeout_s=5.0,
                                           max_retries=3)
    assert ctrl.injected_crashes == 1
    assert ctrl.injected_poisons == 1
    assert stream.last_report.retries >= 2
    _check_terminal(eng, results)
    _check_vs_oracle(cfg, params, reqs, results)
    assert sum(stream.last_report.status_counts.values()) == len(reqs)


# --------------------------------------------------------------------- #
# property: randomized fault plans (satellite: test coverage).
# The body is a plain helper — a deterministic seeded sweep runs it
# everywhere; hypothesis fuzzes it when the optional extra is installed
# (CI does), the test_codec_packed.py idiom.
# --------------------------------------------------------------------- #
def check_random_plan(cfg, params, seed):
    """One randomized plan drawn from ``seed``: crash/poison/deadline/
    latency schedules x slot counts. Under ANY of them every request
    ends terminal, nothing leaks, survivors are bitwise the oracle's,
    non-ok prefixes clean."""
    rng = np.random.default_rng(seed)
    slots = int(rng.integers(2, 4))
    n_req = int(rng.integers(2, 6))
    lens = rng.choice([4, 6], size=n_req).tolist()
    deadlines = [None if rng.random() < 0.6
                 else float(rng.choice([0.0, 1.5, 2.5]))
                 for _ in range(n_req)]
    max_queue = None if rng.random() < 0.7 else 2
    events = []
    for w in rng.permutation(4)[:rng.integers(0, 3)]:
        events.append(WaveCrash(wave=int(w),
                                times=int(rng.integers(1, 3))))
    for _ in range(int(rng.integers(0, 3))):
        events.append(SlotPoison(wave=int(rng.integers(0, 4)),
                                 slot=int(rng.integers(0, slots))))
    if rng.random() < 0.5:
        events.append(WaveLatency(wave=int(rng.integers(0, 4)),
                                  delay_s=60.0))
    reqs = [Request(prompt=p, max_new=5, deadline_s=d)
            for p, d in zip(_prompts(cfg, lens, seed=1000 + seed),
                            deadlines)]
    eng = DecodeEngine(cfg, params, slots=slots, page_size=4,
                       max_ctx=16, max_new_cap=5)
    results, stream, _ = run_serve_plan(
        eng, reqs, ServeFaultPlan(tuple(events), name=f"prop{seed}"),
        wave_len=2, max_queue=max_queue, wave_timeout_s=5.0,
        max_retries=4)
    _check_terminal(eng, results)
    _check_vs_oracle(cfg, params, reqs, results)
    assert sum(stream.last_report.status_counts.values()) == n_req


@pytest.mark.parametrize("seed", range(8))
def test_randomized_plans_always_terminal_cases(gemma, seed):
    cfg, params = gemma
    check_random_plan(cfg, params, seed)


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # optional test extra (pyproject.toml)
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @given(seed=st.integers(0, 10**6))
    @settings(max_examples=12, deadline=None)
    def test_randomized_plans_always_terminal_hypothesis(gemma, seed):
        cfg, params = gemma
        check_random_plan(cfg, params, seed)


# --------------------------------------------------------------------- #
# legacy path: uniform status accounting (satellite: bugfix)
# --------------------------------------------------------------------- #
class _TickClock:
    """Deterministic clock: each call returns the current time, then
    advances by ``step`` — no real sleeps anywhere."""

    def __init__(self, step=0.25):
        self.t, self.step = 0.0, step

    def __call__(self):
        t, self.t = self.t, self.t + self.step
        return t


def test_serve_legacy_ok_tokens_match_generate(gemma):
    cfg, params = gemma
    reqs = [Request(prompt=p, max_new=4)
            for p in _prompts(cfg, [4, 6, 5], seed=14)]
    results = serve_legacy(cfg, params, reqs)
    for i, (req, res) in enumerate(zip(reqs, results)):
        assert res.status == "ok" and res.ok and res.index == i
        want = _oracle_gen(cfg, params, req)
        assert np.array_equal(res.generated[:len(want)], want)


def test_serve_legacy_deadline_and_shed_statuses(gemma):
    cfg, params = gemma
    ps = _prompts(cfg, [4, 5, 6], seed=15)
    reqs = [Request(prompt=ps[0], max_new=6, deadline_s=1.0),
            Request(prompt=ps[1], max_new=4),
            Request(prompt=ps[2], max_new=4)]
    results = serve_legacy(cfg, params, reqs, max_queue=2,
                           clock=_TickClock(step=0.25))
    # newest shed first: request 2 never runs
    assert results[2].status == "shed" and results[2].emitted == 0
    # request 0 expires mid-request with a clean bitwise prefix
    r0 = results[0]
    assert r0.status == "expired" and 0 < r0.emitted < 6
    want = _oracle_gen(cfg, params, reqs[0])
    assert np.array_equal(r0.generated, want[:r0.emitted])
    assert results[1].status == "ok"
    # the status vocabulary is shared with the engine path
    assert all(r.status in STATUSES for r in results)


def test_serve_legacy_deadline_zero_expires_before_start(gemma):
    cfg, params = gemma
    reqs = [Request(prompt=_prompts(cfg, [4], seed=16)[0], max_new=4,
                    deadline_s=0.0)]
    results = serve_legacy(cfg, params, reqs, clock=_TickClock())
    assert results[0].status == "expired" and results[0].emitted == 0
