"""Per-architecture smoke tests: REDUCED same-family configs on CPU.

One forward/train step asserting output shapes + finiteness, plus
prefill->decode consistency per family. Full configs are exercised only
by the dry-run (launch/dryrun.py, ShapeDtypeStructs — no allocation).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import (ARCHS, SHAPES, get_config, input_specs, reduced,
                           shape_supported)
from repro.models import lm


def _batch(cfg, B=2, T=32, seed=0):
    rng = np.random.default_rng(seed)
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, T)),
                               jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, T)),
                               jnp.int32)}
    if cfg.frontend == "vit":
        b["patches"] = jnp.asarray(
            rng.standard_normal((B, cfg.frontend_len, cfg.frontend_dim)),
            cfg.jdtype)
    if cfg.frontend == "audio":
        b["frames"] = jnp.asarray(
            rng.standard_normal((B, T, cfg.frontend_dim)), cfg.jdtype)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = reduced(get_config(arch))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)

    def loss_fn(p):
        return lm.train_loss(cfg, p, batch)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss))
    # gradients exist, are finite, and are not all zero
    leaves = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(l, np.float32)))
               for l in leaves)
    assert any(float(jnp.abs(l.astype(jnp.float32)).sum()) > 0
               for l in leaves)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """decode_step(T) after prefill(:T) == prefill(:T+1) last logits."""
    cfg = reduced(get_config(arch))
    params = lm.init_params(cfg, jax.random.PRNGKey(1))
    B, T = 2, 32
    full = _batch(cfg, B=B, T=T + 1, seed=3)
    pre = dict(full)
    pre["tokens"] = full["tokens"][:, :T]
    lg_full, _ = jax.jit(lambda p, b: lm.prefill(cfg, p, b))(params, full)
    lg_pre, cache = jax.jit(
        lambda p, b: lm.prefill(cfg, p, b, max_len=T + 1))(params, pre)
    lg_dec, new_cache = jax.jit(
        lambda p, c, t: lm.decode_step(cfg, p, c, t, jnp.int32(T)))(
        params, cache, full["tokens"][:, T:T + 1])
    np.testing.assert_allclose(np.asarray(lg_full), np.asarray(lg_dec),
                               rtol=1e-3, atol=1e-3)
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_match_structure(arch):
    cfg = reduced(get_config(arch))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    specs = lm.param_specs(cfg)
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(specs,
                             is_leaf=lambda x: isinstance(x, tuple))
    assert len(flat_p) == len(flat_s)
    # every spec tuple matches the rank of its parameter
    def chk(p, s):
        assert len(s) == p.ndim, f"{s} vs {p.shape}"
    jax.tree.map(chk, params,
                 jax.tree.map(tuple, specs,
                              is_leaf=lambda x: isinstance(x, tuple)),
                 is_leaf=lambda x: hasattr(x, "ndim"))


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_count(arch):
    """The FULL config's analytic parameter count is in the advertised
    ballpark (catches config typos without allocating anything)."""
    cfg = get_config(arch)
    n = cfg.param_count()
    expected = {
        "internvl2-26b": 20e9,       # LM backbone only (ViT is a stub)
        "mixtral-8x7b": 47e9,
        # the assigned spec (48L x 64e x d_ff 1408) yields 28B total /
        # 3.97B active; the hf "16B" name counts a narrower layout — the
        # assignment numbers are the contract here.
        "moonshot-v1-16b-a3b": 28e9,
        "internlm2-20b": 20e9,
        "gemma2-2b": 2.6e9,
        "mistral-large-123b": 123e9,
        "granite-3-2b": 2.5e9,
        "zamba2-2.7b": 2.7e9,
        "mamba2-1.3b": 1.3e9,
        "seamless-m4t-large-v2": 2.3e9,
    }[cfg.name]
    assert 0.5 * expected < n < 1.7 * expected, (cfg.name, n, expected)


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_specs_build(arch, shape):
    """input_specs must produce ShapeDtypeStructs for every supported
    (arch x shape) cell without allocating."""
    cfg = get_config(arch)
    sh = SHAPES[shape]
    ok, why = shape_supported(cfg, sh)
    if not ok:
        pytest.skip(why)
    specs = input_specs(cfg, sh)
    leaves = jax.tree.leaves(specs)
    assert leaves and all(isinstance(l, jax.ShapeDtypeStruct)
                          for l in leaves)


def test_long500k_skips_documented():
    """Exactly the SSM/hybrid archs run long_500k (DESIGN.md §6)."""
    runs = [a for a in ARCHS
            if shape_supported(get_config(a), SHAPES["long_500k"])[0]]
    assert sorted(runs) == ["mamba2_1p3b", "zamba2_2p7b"]


@pytest.mark.parametrize("arch", ["mixtral_8x7b", "moonshot_v1_16b_a3b"])
def test_moe_capacity_drops_tokens_gracefully(arch):
    """Production capacity factor may drop tokens; loss must stay finite."""
    cfg = reduced(get_config(arch)).replace(moe_capacity_factor=0.5)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    loss, _ = jax.jit(lambda p, b: lm.train_loss(cfg, p, b))(
        params, _batch(cfg))
    assert np.isfinite(float(loss))
