"""Property tests: the fused gather-XOR codec is bit-identical to the
multipass jnp oracle across (q, k, d) configurations (DESIGN.md §10).

Three lanes are compared on the SAME schedule tables, full-array
bit-for-bit (including rows where the device is not a group member —
both codecs must produce identical don't-care bytes so executor
swaps can never change wire or output bits):

* ``codec="multipass"``   — gather → take_along_axis → fold oracle,
* ``codec="fused"`` jnp   — flat-index-table gather + masked fold,
* ``codec="fused"`` Pallas — ``xor_encode_gather``/``xor_decode_gather``
  (interpret on CPU/GPU, compiled Mosaic when the backend is TPU —
  ``interpret=None`` resolution).

The program is optionally pulled through the survivor-set (degraded)
re-lowering path of the schedule cache first: the fused tables must be
the ones the fault runtime's base program serves.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional test extra (pyproject.toml)
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro.core.collective import _decode_stage, _encode_stage  # noqa: E402
from repro.core.schedule import ScheduleCache  # noqa: E402

_CACHE = ScheduleCache()  # private: don't pollute the global cache stats


def _codec_lanes():
    # (codec, use_kernels); use_kernels=None resolves to compiled Mosaic
    # on TPU and the interpreter elsewhere — "compiled-if-TPU"
    return [("multipass", False), ("fused", False), ("fused", None)]


def _stage_codec_outputs(program, stage, u32, me, k, pk, seed):
    """Run encode + decode of one stage under every codec lane."""
    T = program.stage_tables(stage)
    rng = np.random.default_rng(seed)
    recv = jnp.asarray(rng.integers(0, 2**32, size=(T.n, k - 1, pk),
                                    dtype=np.uint32))
    outs = []
    for codec, uk in _codec_lanes():
        use_kernels = (uk if uk is not None
                       else __import__("jax").default_backend() == "tpu")
        ctx, delta = _encode_stage(u32, T, me, k=k, pk=pk, codec=codec,
                                   use_kernels=use_kernels)
        chunk = _decode_stage(recv, ctx, T, me, k=k, pk=pk, codec=codec,
                              use_kernels=use_kernels)
        outs.append((codec, uk, np.asarray(delta), np.asarray(chunk)))
    return outs


@given(st.integers(2, 3), st.integers(3, 4), st.sampled_from([1, 2, 5]),
       st.integers(0, 10**6), st.booleans())
@settings(max_examples=12, deadline=None)
def test_fused_codec_bit_identical(q, k, pk, seed, degraded):
    """delta and decoded chunks agree bit-for-bit across all lanes, for
    every device, both stages — programs served directly or via the
    survivor-set re-lowering."""
    d = pk * (k - 1)
    K, J_own = q * k, q ** (k - 2)
    program = _CACHE.program(q, k, Q=K, d=d)
    if degraded:
        # pull the program through the fault path: the degraded
        # re-lowering keys by survivor set and must hand back the SAME
        # base tables the fused codec reads
        deg = _CACHE.degraded(program, {0})
        # width variants of one configuration share ONE degraded
        # re-lowering (d is not in the key), so deg.base may be another
        # width-stamped view — but it must serve the same table objects
        assert deg.base.s1 is program.s1 and deg.base.s2 is program.s2
        assert deg.coded_rows  # some groups stay fully coded
        program = deg.base
    rng = np.random.default_rng(seed)
    u32 = jnp.asarray(rng.integers(0, 2**32, size=(J_own, k - 1, K, d),
                                   dtype=np.uint32))
    for stage in (1, 2):
        for me in {0, K // 2, K - 1}:
            ref = None
            for codec, uk, delta, chunk in _stage_codec_outputs(
                    program, stage, u32, me, k, pk, seed):
                if ref is None:
                    ref = (delta, chunk)
                    continue
                np.testing.assert_array_equal(
                    delta, ref[0],
                    err_msg=f"delta {codec}/uk={uk} s={me} stage={stage}")
                np.testing.assert_array_equal(
                    chunk, ref[1],
                    err_msg=f"chunk {codec}/uk={uk} s={me} stage={stage}")


@given(st.integers(2, 3), st.integers(3, 4), st.integers(0, 10**6))
@settings(max_examples=10, deadline=None)
def test_fused_tables_wellformed(q, k, seed):
    """Structural invariants of the lowered index tables: every source
    index addresses a real packet row, masks match validity, and the
    baked round→slot selector is a per-row permutation of the recv rows
    wherever the device is a group member."""
    K, J_own = q * k, q ** (k - 2)
    program = _CACHE.program(q, k, Q=K, d=k - 1)
    P = J_own * (k - 1) * K * (k - 1)          # flat packet rows
    for stage in (1, 2):
        T = program.stage_tables(stage)
        n = T.n
        assert T.enc_src.shape == (K, n, k)
        assert T.dec_src.shape == (K, n, k - 1, k)
        assert T.dec_recv.shape == (K, n, k - 1)
        assert (T.enc_src >= 0).all() and (T.enc_src < P).all()
        assert (T.dec_src >= 0).all() and (T.dec_src < P).all()
        assert (T.dec_recv >= 0).all() and (T.dec_recv < n * (k - 1)).all()
        # invalid sources are baked to row 0 and masked off
        assert (T.enc_src[~T.src_ok] == 0).all()
        assert (T.dec_src[~T.dec_mask] == 0).all()
        # member rows: dec_recv is a permutation of that row's recv rows
        for s in range(K):
            for li in np.flatnonzero(T.valid[s])[:4]:
                want = set(range(li * (k - 1), (li + 1) * (k - 1)))
                assert set(T.dec_recv[s, li].tolist()) == want
                # exactly k-2 cancellation packets per decoded slot
                assert (T.dec_mask[s, li].sum(axis=1) == k - 2).all()
