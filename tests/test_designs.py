"""Resolvable-design construction — paper §III, Lemma 1, Example 2."""

import numpy as np
import pytest

from repro.core.designs import (
    ResolvableDesign, factorize_cluster, make_design, spc_codeword_table)

SWEEP = [(2, 2), (2, 3), (3, 2), (2, 4), (3, 3), (4, 2), (4, 3), (2, 5),
         (5, 2), (6, 2), (3, 4), (4, 4), (5, 3)]  # q need not be prime


@pytest.mark.parametrize("q,k", SWEEP)
def test_lemma1_properties(q, k):
    d = make_design(q, k)
    d.validate()
    assert d.K == k * q
    assert d.J == q ** (k - 1)
    # |A| = kq blocks, |B| = q^{k-2}
    assert len(d.blocks) == k * q
    assert all(len(b) == d.block_size for b in d.blocks)


@pytest.mark.parametrize("q,k", SWEEP)
def test_codeword_table(q, k):
    T = spc_codeword_table(q, k)
    assert T.shape == (k, q ** (k - 1))
    # parity row: sum of message rows mod q
    np.testing.assert_array_equal(T[-1], T[:-1].sum(axis=0) % q)
    # all codewords distinct
    assert len({tuple(c) for c in T.T}) == q ** (k - 1)


def test_example2_owner_sets():
    """Paper Eq. (2): exact owner sets for q=2, k=3 (0-indexed here)."""
    d = make_design(2, 3)
    assert d.owners[0] == (0, 2, 4)  # X^(1) = {U1, U3, U5}
    assert d.owners[1] == (0, 3, 5)  # X^(2) = {U1, U4, U6}
    assert d.owners[2] == (1, 2, 5)  # X^(3) = {U2, U3, U6}
    assert d.owners[3] == (1, 3, 4)  # X^(4) = {U2, U4, U5}
    # parallel classes partition the servers q at a time
    assert d.parallel_classes == ((0, 1), (2, 3), (4, 5))


@pytest.mark.parametrize("q,k", [(2, 3), (3, 3), (2, 4), (4, 3), (3, 4)])
def test_stage2_groups(q, k):
    d = make_design(q, k)
    groups = d.stage2_groups()
    # paper: q^{k-1}(q-1) such groups
    assert len(groups) == q ** (k - 1) * (q - 1)
    for G in groups:
        # one block per parallel class, empty total intersection
        assert sorted(d.class_of(s) for s in G) == list(range(k))
        common = set(d.blocks[G[0]])
        for s in G[1:]:
            common &= set(d.blocks[s])
        assert not common
        # every (k-1)-subset co-owns exactly one job, not owned by the rest
        for kp in G:
            P = tuple(s for s in G if s != kp)
            j = d.common_job(P)
            assert all(d.is_owner(s, j) for s in P)
            assert not d.is_owner(kp, j)
            # the remaining owner is in kp's parallel class
            (l,) = [u for u in d.owners[j]
                    if d.class_of(u) == d.class_of(kp)]
            assert l != kp


@pytest.mark.parametrize("q,k", [(2, 3), (3, 3), (4, 3), (2, 4)])
def test_common_job_matches_bruteforce(q, k):
    d = make_design(q, k)
    import itertools
    for G in d.stage2_groups():
        for kp in G:
            P = tuple(s for s in G if s != kp)
            want = set(d.blocks[P[0]])
            for s in P[1:]:
                want &= set(d.blocks[s])
            assert want == {d.common_job(P)}


def test_owner_block_duality():
    d = make_design(3, 3)
    for j in range(d.J):
        for s in d.owners[j]:
            assert j in d.blocks[s]
    for s in range(d.K):
        for j in d.blocks[s]:
            assert s in d.owners[j]


def test_factorize_cluster():
    assert factorize_cluster(6) in [(2, 3), (3, 2)]
    q, k = factorize_cluster(100, mu_target=0.04)  # K=100, muK=4 -> k=5
    assert k * q == 100 and k == 5
    with pytest.raises(ValueError):
        factorize_cluster(7)  # prime: no q,k >= 2


def test_invalid_params():
    with pytest.raises(ValueError):
        make_design(1, 3)
    with pytest.raises(ValueError):
        make_design(3, 1)
