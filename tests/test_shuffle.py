"""Coded multicast (Lemma 2 / Algorithm 2) and stage schedules — §III-C."""

import numpy as np
import pytest

from repro.core.designs import make_design
from repro.core.placement import make_placement
from repro.core.shuffle import (
    coded_multicast_schedule, decode_coded_multicast, split_packets,
    stage1_chunks, stage2_chunks, stage3_chunks, xor_bytes)


def test_xor_bytes_involution():
    rng = np.random.default_rng(0)
    a, b = rng.bytes(64), rng.bytes(64)
    assert xor_bytes(xor_bytes(a, b), b) == a
    assert xor_bytes(a, a) == b"\x00" * 64


def test_split_packets_roundtrip():
    data = bytes(range(100))
    for m in (1, 2, 3, 4, 7):
        pk = split_packets(data, m)
        assert len(pk) == m
        assert len({len(p) for p in pk}) == 1
        assert b"".join(pk)[:100] == data


@pytest.mark.parametrize("k", [2, 3, 4, 5, 6])
def test_lemma2_coded_multicast(k):
    """k transmissions of B/(k-1) bits deliver all k chunks (Lemma 2)."""
    rng = np.random.default_rng(k)
    group = tuple(range(10, 10 + k))
    B = 12 * (k - 1)  # divisible -> exact load
    chunks = {s: rng.bytes(B) for s in group}
    txs = coded_multicast_schedule(group, chunks, stage=1)
    assert len(txs) == k
    total = sum(t.nbytes for t in txs)
    assert total == B * k // (k - 1)  # Lemma 2: Bk/(k-1) bits
    for r in group:
        known = {s: chunks[s] for s in group if s != r}
        got = decode_coded_multicast(group, r, txs, known, B)
        assert got == chunks[r]


def test_lemma2_padding_overhead_accounted():
    """When (k-1) does not divide B, on-wire bytes include padding."""
    group = (0, 1, 2)
    chunks = {s: bytes([s] * 7) for s in group}  # 7 bytes, k-1=2 -> pad to 8
    txs = coded_multicast_schedule(group, chunks, stage=1)
    assert sum(t.nbytes for t in txs) == 3 * 4  # ceil(7/2)=4 per packet
    for r in group:
        known = {s: chunks[s] for s in group if s != r}
        assert decode_coded_multicast(group, r, txs, known, 7) == chunks[r]


@pytest.mark.parametrize("q,k", [(2, 3), (3, 3), (2, 4), (4, 3)])
def test_stage1_chunk_structure(q, k):
    d = make_design(q, k)
    pl = make_placement(d, gamma=1)
    groups = stage1_chunks(pl)
    assert len(groups) == d.J  # one group per job
    for G, specs in groups.items():
        assert len(specs) == k
        for c in specs:
            # receiver misses exactly that batch; all other owners hold it
            assert not pl.stores(c.receiver, c.job, c.batch)
            for s in G:
                if s != c.receiver:
                    assert pl.stores(s, c.job, c.batch)


@pytest.mark.parametrize("q,k", [(2, 3), (3, 3), (2, 4), (4, 3)])
def test_stage2_chunk_structure(q, k):
    d = make_design(q, k)
    pl = make_placement(d, gamma=1)
    groups = stage2_chunks(pl)
    assert len(groups) == d.J * (q - 1)
    for G, specs in groups.items():
        for c in specs:
            assert not d.is_owner(c.receiver, c.job)
            assert d.class_of(c.classmate_owner) == d.class_of(c.receiver)
            # the batch is the one the class-mate owner misses
            assert not pl.stores(c.classmate_owner, c.job, c.batch)


@pytest.mark.parametrize("q,k", [(2, 3), (3, 3), (2, 4), (4, 3)])
def test_stage3_coverage(q, k):
    """Every (server, missing job) pair receives exactly one unicast with
    the complement batches (proof of stage-3 correctness, Appendix)."""
    d = make_design(q, k)
    pl = make_placement(d, gamma=1)
    specs = stage3_chunks(pl)
    seen = {}
    for c in specs:
        key = (c.receiver, c.job)
        assert key not in seen
        seen[key] = c
        assert d.class_of(c.sender) == d.class_of(c.receiver)
        assert d.is_owner(c.sender, c.job)
        assert not d.is_owner(c.receiver, c.job)
        # sender stores exactly those batches
        for t in c.batches:
            assert pl.stores(c.sender, c.job, t)
        assert len(c.batches) == k - 1
    for s in range(d.K):
        missing = [j for j in range(d.J) if not d.is_owner(s, j)]
        assert len(missing) == d.J - d.block_size
        for j in missing:
            assert (s, j) in seen


def test_example3_stage1_transmission_count():
    """Example 3: 6 servers, J=4 — stage 1 sends J*k = 12 coded packets of
    B/2 each => 6B total, L1 = 6B/(J*Q*B) = 1/4."""
    d = make_design(2, 3)
    pl = make_placement(d, gamma=2)
    groups = stage1_chunks(pl)
    n_tx = sum(len(G) for G in groups)  # k per group
    assert n_tx == d.J * 3
