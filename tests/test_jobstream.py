"""JobStream runtime + structural schedule cache (DESIGN.md §9).

The pipelined multi-wave runtime must be BIT-identical to the serial
engine loop (its correctness oracle), and the schedule cache must serve
repeated configurations — including degraded survivor sets — from one
lowering.
"""

import numpy as np
import pytest

from repro.core.engine import CAMRConfig, CAMREngine
from repro.core.schedule import SCHEDULE_CACHE, ScheduleCache
from repro.runtime.fault import DegradedCAMREngine
from repro.runtime.jobstream import JobSpec, JobStream


def _identity_map(job, sf):
    return sf


def make_specs(q, k, waves, d=4, seed=0, gamma=1):
    cfg = CAMRConfig(q=q, k=k, gamma=gamma)
    Q = cfg.num_functions()
    rng = np.random.default_rng(seed)
    out = []
    for w in range(waves):
        ds = [[rng.standard_normal((Q, d)).astype(np.float32)
               for _ in range(cfg.N)] for _ in range(cfg.J)]
        out.append(JobSpec(cfg, _identity_map, ds, name=f"wave{w}"))
    return out


def assert_results_equal(want, got):
    """Exact (bitwise) equality of two engine result structures."""
    assert len(want) == len(got)
    for a, b in zip(want, got):
        assert a.keys() == b.keys()
        for key in a:
            assert np.array_equal(a[key], b[key]), key


# --------------------------------------------------------------------- #
# schedule cache
# --------------------------------------------------------------------- #
class TestScheduleCache:
    def test_program_hit_is_identity(self):
        c = ScheduleCache()
        p1 = c.program(2, 3, Q=6)
        assert c.stats()["misses"] == 1
        p2 = c.program(2, 3, Q=6)
        assert p1 is p2
        assert c.stats()["hits"] == 1

    def test_program_miss_on_new_shape(self):
        c = ScheduleCache()
        c.program(2, 3, Q=6)
        c.program(3, 3, Q=9)
        assert c.stats()["misses"] == 2
        assert c.stats()["programs"] == 2

    def test_width_variants_share_tables(self):
        """d changes only the runtime packet split — all widths of one
        configuration share the base lowering's tables."""
        c = ScheduleCache()
        p4 = c.program(2, 3, Q=6, d=4)
        p8 = c.program(2, 3, Q=6, d=8)
        assert p4.d == 4 and p8.d == 8
        assert p4.s1 is p8.s1 and p4.s2 is p8.s2
        assert p4.placement is p8.placement
        assert c.program(2, 3, Q=6, d=4) is p4

    def test_identity_label_perm_collapses(self):
        c = ScheduleCache()
        p1 = c.program(2, 3, Q=6)
        ident = [tuple(range(3))] * 4
        assert c.program(2, 3, Q=6, label_perm=ident) is p1

    def test_invalid_width_rejected(self):
        with pytest.raises(ValueError):
            ScheduleCache().program(2, 3, Q=6, d=7)

    def test_degraded_hit_and_survivor_set_keying(self):
        """Same survivor set -> one lowering; a changed survivor set is
        a different key (the invalidation rule of DESIGN.md §9)."""
        c = ScheduleCache()
        prog = c.program(2, 3, Q=6)
        d0 = c.degraded(prog, {0})
        assert c.degraded(prog, {0}) is d0           # hit
        d1 = c.degraded(prog, {1})                   # new survivor set
        assert d1 is not d0
        assert d1.failed == frozenset({1})
        assert c.stats()["degraded"] == 2
        c.clear()
        assert c.stats() == dict(hits=0, misses=0, programs=0,
                                 degraded=0)
        prog = c.program(2, 3, Q=6)
        assert c.degraded(prog, {0}) is not d0       # cold after clear

    def test_degraded_unrecoverable_not_cached(self):
        c = ScheduleCache()
        prog = c.program(2, 3, Q=6)
        for _ in range(2):
            with pytest.raises(ValueError):
                c.degraded(prog, {0, 1})             # same parallel class
        assert c.stats()["degraded"] == 0

    def test_lru_bound(self):
        c = ScheduleCache(maxsize=2)
        c.program(2, 3, Q=6)
        c.program(3, 3, Q=9)
        c.program(2, 4, Q=8)
        assert c.stats()["programs"] == 2

    def test_engines_share_one_lowering(self):
        """Two engines of the same configuration hold the SAME program
        object (lowering paid once per configuration, not per engine)."""
        cfg = CAMRConfig(q=2, k=3, gamma=1)
        e1 = CAMREngine(cfg, _identity_map)
        e2 = CAMREngine(cfg, _identity_map)
        assert e1.program is e2.program
        assert e1.placement is e2.placement


# --------------------------------------------------------------------- #
# serial oracle
# --------------------------------------------------------------------- #
def test_run_stream_matches_individual_runs():
    specs = make_specs(2, 3, 3)
    eng = CAMREngine(specs[0].cfg, _identity_map)
    stream_res = eng.run_stream([sp.datasets for sp in specs])
    for sp, got in zip(specs, stream_res):
        fresh = CAMREngine(sp.cfg, _identity_map)
        assert_results_equal(fresh.run(sp.datasets), got)


# --------------------------------------------------------------------- #
# pipelined JobStream == serial oracle, bit for bit
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("q,k,waves", [(2, 3, 4), (3, 3, 3), (2, 4, 3)])
def test_jobstream_bit_identical_to_serial(q, k, waves):
    specs = make_specs(q, k, waves)
    got = JobStream().run(specs)
    oracle = CAMREngine(specs[0].cfg, _identity_map).run_stream(
        [sp.datasets for sp in specs])
    for want, res in zip(oracle, got):
        assert_results_equal(want, res)


def test_jobstream_mixed_shapes_submission_order():
    """Heterogeneous waves: batching regroups by shape, results come
    back in submission order and match per-wave serial runs."""
    a = make_specs(2, 3, 2, d=4, seed=1)
    b = make_specs(3, 3, 2, d=6, seed=2)
    specs = [a[0], b[0], a[1], b[1]]
    stream = JobStream()
    got = stream.run(specs)
    assert stream.last_report.waves == 4
    assert stream.last_report.batches == 2
    for sp, res in zip(specs, got):
        want = CAMREngine(sp.cfg, sp.map_fn).run(sp.datasets)
        assert_results_equal(want, res)


@pytest.mark.parametrize("kw", [dict(batching=False),
                                dict(pipeline=False),
                                dict(batching=False, pipeline=False),
                                dict(wave_batch=2)])
def test_jobstream_mode_matrix(kw):
    """Every scheduler mode (no batching / no pipeline / capped batch)
    produces the same bits."""
    specs = make_specs(2, 3, 4, seed=3)
    got = JobStream(**kw).run(specs)
    oracle = CAMREngine(specs[0].cfg, _identity_map).run_stream(
        [sp.datasets for sp in specs])
    for want, res in zip(oracle, got):
        assert_results_equal(want, res)


def test_jobstream_degraded_matches_and_lowers_once():
    """Waves on a degraded cluster: bit-identical to the serial
    DegradedCAMREngine loop, and the survivor-set re-lowering is paid
    once for the whole stream (not once per wave)."""
    specs = make_specs(2, 3, 3, seed=4)
    s0 = SCHEDULE_CACHE.stats()
    # batching=False -> one engine per wave, so cache behavior is visible
    got = JobStream(failed={0}, batching=False).run(specs)
    s1 = SCHEDULE_CACHE.stats()
    # 3 engines queried program + degraded; at most one degraded (and
    # one program) lowering was actually paid
    assert s1["misses"] - s0["misses"] <= 2
    assert s1["hits"] - s0["hits"] >= 4
    for sp, res in zip(specs, got):
        want = DegradedCAMREngine(sp.cfg, sp.map_fn, {0}).run(sp.datasets)
        assert_results_equal(want, res)


def test_degraded_cache_shared_across_widths():
    """lower_degraded reads only width-independent tables — all shard
    widths of one configuration share the survivor-set entry."""
    c = ScheduleCache()
    p4 = c.program(2, 3, Q=6, d=4)
    p8 = c.program(2, 3, Q=6, d=8)
    assert c.degraded(p4, {0}) is c.degraded(p8, {0})
    assert c.stats()["degraded"] == 1


def test_jobstream_default_wave_batch_pipelines_homogeneous():
    """The default cap splits a homogeneous stream into several batches
    so the map/shuffle overlap actually engages (and memory stays at
    the documented 2*wave_batch waves)."""
    specs = make_specs(2, 3, JobStream.DEFAULT_WAVE_BATCH * 2, seed=8)
    stream = JobStream()
    got = stream.run(specs)
    assert stream.last_report.batches == 2
    assert stream.last_report.pipelined
    oracle = CAMREngine(specs[0].cfg, _identity_map).run_stream(
        [sp.datasets for sp in specs])
    for want, res in zip(oracle, got):
        assert_results_equal(want, res)


def test_jobstream_rejects_bad_inputs():
    with pytest.raises(ValueError):
        JobStream(wave_batch=0)
    specs = make_specs(2, 3, 1, seed=9)
    short = JobSpec(specs[0].cfg, _identity_map, specs[0].datasets[:-1])
    with pytest.raises(ValueError, match="job datasets"):
        JobStream().run([short])
    extra_ds = [list(job) for job in specs[0].datasets]
    extra_ds[0] = extra_ds[0] + [extra_ds[0][0]]   # N+1 subfiles
    extra = JobSpec(specs[0].cfg, _identity_map, extra_ds)
    with pytest.raises(ValueError, match="subfiles"):
        JobStream().run([extra])


def test_jobstream_empty_run():
    stream = JobStream()
    assert stream.run([]) == []
    assert stream.last_report.waves == 0


def test_jobstream_mixed_dtype_raises_unless_declared():
    """Stacking mixed value dtypes would silently promote — undeclared
    mismatches raise; declared value_dtype splits the batches and each
    wave matches its serial run bit for bit."""
    from dataclasses import replace

    f32 = make_specs(2, 3, 1, seed=6)[0]
    f64_ds = [[sf.astype(np.float64) for sf in job]
              for job in make_specs(2, 3, 1, seed=7)[0].datasets]
    f64 = JobSpec(f32.cfg, _identity_map, f64_ds)
    with pytest.raises(ValueError, match="dtype"):
        JobStream().run([f32, f64])
    tagged = [replace(f32, value_dtype=np.float32),
              replace(f64, value_dtype=np.float64)]
    stream = JobStream()
    got = stream.run(tagged)
    assert stream.last_report.batches == 2
    for sp, res in zip(tagged, got):
        want = CAMREngine(sp.cfg, sp.map_fn).run(sp.datasets)
        assert_results_equal(want, res)


def test_jobstream_half_dtype_guard(monkeypatch):
    """The entry guard consumes the codec's CODEC_DTYPES list: f16/bf16
    waves are ACCEPTED (the packed 16-bit lane, DESIGN.md §12) and run
    bit-identically to the serial engine oracle; sub-word INTEGER waves
    keep riding the byte-level engine exactly as before this lane
    existed; and if a half ever left CODEC_DTYPES the guard would trip
    again — at JobSpec construction for a declared value_dtype, at the
    first map call for an undeclared one — with an actionable cast
    hint, never deep inside a shuffle."""
    import ml_dtypes  # registers the numpy bfloat16 dtype

    from repro.core import collective

    f32 = make_specs(2, 3, 1, seed=8)[0]

    # bf16 wave: accepted, and bit-identical to the serial oracle
    bf16 = np.dtype(ml_dtypes.bfloat16)
    ds = [[sf.astype(np.float32).astype(bf16) for sf in job]
          for job in f32.datasets]
    spec16 = JobSpec(f32.cfg, _identity_map, ds, name="bf16wave",
                     value_dtype=bf16)
    got = JobStream().run([spec16])[0]
    want = CAMREngine(f32.cfg, _identity_map).run(ds)
    assert_results_equal(want, got)
    assert all(v.dtype == bf16 for res in got for v in res.values())

    # sub-word integers transport losslessly on the byte-XOR engine,
    # same as before the packed lane existed (no silent narrowing)
    i16 = [[(sf * 100).astype(np.int16) for sf in job]
           for job in f32.datasets]
    spec_i16 = JobSpec(f32.cfg, _identity_map, i16, name="i16wave",
                       value_dtype=np.int16)
    got_i = JobStream().run([spec_i16])[0]
    assert_results_equal(CAMREngine(f32.cfg, _identity_map).run(i16),
                         got_i)

    # tripwire: a half REMOVED from CODEC_DTYPES fails fast again
    monkeypatch.setattr(collective, "CODEC_DTYPES",
                        ("float32", "uint32"))
    with pytest.raises(TypeError, match="astype"):
        JobSpec(f32.cfg, _identity_map, ds, value_dtype=np.float16)

    def half_map(job, sf):
        return np.zeros((f32.cfg.num_functions(), 4), np.float16)

    spec = JobSpec(f32.cfg, half_map, f32.datasets, name="halfwave")
    with pytest.raises(TypeError, match="astype"):
        JobStream().run([spec])


def test_jobstream_wave_batch_cap():
    specs = make_specs(2, 3, 5, seed=5)
    stream = JobStream(wave_batch=2)
    got = stream.run(specs)
    assert stream.last_report.batches == 3      # 2 + 2 + 1
    oracle = CAMREngine(specs[0].cfg, _identity_map).run_stream(
        [sp.datasets for sp in specs])
    for want, res in zip(oracle, got):
        assert_results_equal(want, res)
