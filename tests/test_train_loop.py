"""Device-resident coded gradient aggregation (DESIGN.md §11).

The three grad-sync wires of MultiModelCAMRTrainer — the SPMD
fused-codec collective, the numpy engine interpreter (healthy AND
degraded), and the uncoded baseline — must produce BIT-identical
parameters and loss trajectories: f32 gradients XOR-code losslessly and
every executor reduces in the engine's canonical combine order.

Also covers the satellite fixes: the (job, subfile_index) gradient
memo, the empty-loss-list guard, orphaned checkpoint tmp dirs, async
checkpoint worker errors surfacing in Trainer.run, and crash-resume
metadata.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.checkpoint.ckpt import available_steps
from repro.configs import get_config, reduced
from repro.data.pipeline import ShardedTokenPipeline
from repro.runtime.train_loop import (MultiModelCAMRTrainer, Trainer,
                                      _mean_losses)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tiny_cfg():
    return reduced(get_config("granite_3_2b")).replace(
        n_layers=2, vocab=64, d_model=32, d_ff=64, n_heads=2, n_kv_heads=1,
        head_dim=16, loss_chunk=8)


def _run_subprocess(code: str, ndev: int) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


# --------------------------------------------------------------------- #
# the acceptance gate: camr_spmd == camr == uncoded, bit for bit,
# including a degraded survivor-set trajectory (runtime/fault.py)
# --------------------------------------------------------------------- #
_RUN_IDENTITY = textwrap.dedent("""
    import numpy as np
    from repro.configs import get_config, reduced
    from repro.data.pipeline import ShardedTokenPipeline
    from repro.runtime.train_loop import MultiModelCAMRTrainer

    cfg = reduced(get_config("granite_3_2b")).replace(
        n_layers=2, vocab=64, d_model=32, d_ff=64, n_heads=2,
        n_kv_heads=1, head_dim=16, loss_chunk=8)
    pipe = ShardedTokenPipeline(vocab=64, seq_len=8, global_batch=2)

    reports, trainers = {}, {}
    for mode in ("camr", "uncoded", "camr_spmd"):
        tr = MultiModelCAMRTrainer(cfg, q=2, k=3, seed=0,
                                   spmd_oracle=(mode == "camr_spmd"))
        reports[mode] = tr.train_steps(pipe, 3, mode=mode)
        trainers[mode] = tr

    ref_flat = np.asarray(trainers["camr"].flat)
    ref_losses = np.asarray(reports["camr"].losses)
    assert np.isfinite(ref_losses).all()
    for mode in ("uncoded", "camr_spmd"):
        np.testing.assert_array_equal(
            np.asarray(trainers[mode].flat), ref_flat,
            err_msg=f"{mode} parameters diverged from the engine oracle")
        np.testing.assert_array_equal(
            np.asarray(reports[mode].losses), ref_losses,
            err_msg=f"{mode} losses diverged")

    # the spmd stream reused ONE compiled executor for all steps
    assert reports["camr_spmd"].sync["compiles"] == 1
    assert reports["camr_spmd"].sync["dispatches"] == 3
    # coded shuffle ships fewer bytes than uncoded
    assert reports["camr"].bytes_total < reports["uncoded"].bytes_total

    # a degraded survivor-set step (runtime/fault.py) is recovery-exact:
    # worker 0 silent in every shuffle, SAME trajectory bits
    td = MultiModelCAMRTrainer(cfg, q=2, k=3, seed=0, failed={0})
    rd = td.train_steps(pipe, 3, mode="camr")
    np.testing.assert_array_equal(np.asarray(td.flat), ref_flat)
    np.testing.assert_array_equal(np.asarray(rd.losses), ref_losses)
    assert rd.bytes_total > reports["camr"].bytes_total  # load inflation

    # mixed healthy/degraded stream: healthy steps, one degraded, healthy
    tm = MultiModelCAMRTrainer(cfg, q=2, k=3, seed=0)
    tm.train_steps(pipe, 1, mode="camr")
    tm.failed = {3}
    tm.train_steps(pipe, 1, mode="camr")
    tm.failed = None
    tm.train_steps(pipe, 1, mode="camr_spmd")
    np.testing.assert_array_equal(np.asarray(tm.flat), ref_flat)
    print("OK")
""")


@pytest.mark.slow
def test_grad_sync_modes_bit_identical():
    out = _run_subprocess(_RUN_IDENTITY, ndev=6)
    assert "OK" in out


# --------------------------------------------------------------------- #
# mixed-precision grad sync: bf16 shuffle payload, f32 master params
# (DESIGN.md §12) — the bit-identity contract holds per lane
# --------------------------------------------------------------------- #
_RUN_IDENTITY_BF16 = textwrap.dedent("""
    import numpy as np
    import ml_dtypes
    from repro.configs import get_config, reduced
    from repro.data.pipeline import ShardedTokenPipeline
    from repro.runtime.train_loop import MultiModelCAMRTrainer

    cfg = reduced(get_config("granite_3_2b")).replace(
        n_layers=2, vocab=64, d_model=32, d_ff=64, n_heads=2,
        n_kv_heads=1, head_dim=16, loss_chunk=8)
    pipe = ShardedTokenPipeline(vocab=64, seq_len=8, global_batch=2)

    reports, trainers = {}, {}
    for mode in ("camr", "uncoded", "camr_spmd"):
        tr = MultiModelCAMRTrainer(cfg, q=2, k=3, seed=0,
                                   grad_sync_dtype="bfloat16",
                                   spmd_oracle=(mode == "camr_spmd"))
        reports[mode] = tr.train_steps(pipe, 2, mode=mode)
        trainers[mode] = tr

    ref_flat = np.asarray(trainers["camr"].flat)
    ref_losses = np.asarray(reports["camr"].losses)
    assert np.isfinite(ref_losses).all()
    for mode in ("uncoded", "camr_spmd"):
        assert reports[mode].grad_sync_dtype == "bfloat16"
        np.testing.assert_array_equal(
            np.asarray(trainers[mode].flat), ref_flat,
            err_msg=f"{mode} parameters diverged on the bf16 lane")
        np.testing.assert_array_equal(
            np.asarray(reports[mode].losses), ref_losses,
            err_msg=f"{mode} losses diverged on the bf16 lane")
    # master params stay f32; the synced payload was bf16
    assert np.asarray(trainers["camr"].flat).dtype == np.float32

    # a degraded bf16 survivor-set step is recovery-exact too
    td = MultiModelCAMRTrainer(cfg, q=2, k=3, seed=0, failed={0},
                               grad_sync_dtype="bfloat16")
    rd = td.train_steps(pipe, 2, mode="camr")
    np.testing.assert_array_equal(np.asarray(td.flat), ref_flat)

    # the packed lane ships ~half the engine-measured shuffle bytes of
    # the f32 lane (exactly half here: widths need no pad words)
    t32 = MultiModelCAMRTrainer(cfg, q=2, k=3, seed=0)
    r32 = t32.train_steps(pipe, 2, mode="camr")
    assert reports["camr"].bytes_total * 2 == r32.bytes_total, (
        reports["camr"].bytes_total, r32.bytes_total)

    # ...and the trajectories genuinely differ across lanes (bf16
    # rounding is real — the identity contract is PER lane)
    assert not np.array_equal(np.asarray(t32.flat), ref_flat)
    print("OK")
""")


@pytest.mark.slow
def test_grad_sync_bf16_modes_bit_identical():
    out = _run_subprocess(_RUN_IDENTITY_BF16, ndev=6)
    assert "OK" in out


def test_grad_sync_dtype_validation():
    cfg = _tiny_cfg()
    with pytest.raises(ValueError, match="loss scaling"):
        MultiModelCAMRTrainer(cfg, q=2, k=3, grad_sync_dtype="float16")
    with pytest.raises(ValueError, match="float32 or bfloat16"):
        MultiModelCAMRTrainer(cfg, q=2, k=3, grad_sync_dtype="int8")
    # the config field (previously dead) is the default source
    tr = MultiModelCAMRTrainer(cfg.replace(grad_sync_dtype="bfloat16"),
                               q=2, k=3)
    assert tr.grad_sync_dtype == "bfloat16"
    tr32 = MultiModelCAMRTrainer(cfg, q=2, k=3)
    assert tr32.grad_sync_dtype == "float32"


# --------------------------------------------------------------------- #
# satellite: the gradient memo is keyed by (job, subfile_index)
# --------------------------------------------------------------------- #
@pytest.mark.slow
def test_gradient_memo_keyed_by_job_and_index(monkeypatch):
    """Regression for the id(subfile)-keyed memo: id() of a payload is
    only unique while the object is alive, and aliased payload objects
    must still be treated as distinct subfiles. With (job, index) keys,
    every (job, subfile) slot is computed exactly once per step — even
    when one dict object is aliased into several slots."""
    import repro.data.pipeline as dp

    cfg = _tiny_cfg()
    pipe = ShardedTokenPipeline(vocab=64, seq_len=8, global_batch=2)
    orig = dp.make_camr_job_datasets

    def aliased(pipeline, J, N, step):
        ds = orig(pipeline, J, N, step)
        ds[0][1] = ds[0][0]   # same OBJECT at two subfile slots
        return ds

    monkeypatch.setattr(dp, "make_camr_job_datasets", aliased)
    tr = MultiModelCAMRTrainer(cfg, q=2, k=3, seed=0)
    rep = tr.train_steps(pipe, 1, mode="camr")
    J, N = tr.camr.J, tr.camr.N
    # an id()-keyed cache would collapse the aliased slots into one
    # gradient compute and record only N-1 losses for job 0
    assert tr.map_calls == J * N
    assert len(tr._last_loss[0]) == N
    # aliased payloads are identical content -> identical losses
    assert tr._last_loss[0][0] == tr._last_loss[0][1]
    assert np.isfinite(np.asarray(rep.losses)).all()


def test_mean_losses_guard():
    """np.mean over an empty list warns and is undefined — the guard
    pins empty per-job maps to NaN without touching np.mean."""
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("error")
        out = _mean_losses([{0: 1.0, 1: 3.0}, {}])
    assert out[0] == pytest.approx(2.0)
    assert np.isnan(out[1])
    # keyed averaging is order-independent (modes walk subfiles in
    # different orders but must average identically)
    assert _mean_losses([{1: 3.0, 0: 1.0}]) == _mean_losses(
        [{0: 1.0, 1: 3.0}])


def test_trainer_rejects_unknown_mode():
    cfg = _tiny_cfg()
    tr = MultiModelCAMRTrainer(cfg, q=2, k=3, seed=0)
    pipe = ShardedTokenPipeline(vocab=64, seq_len=8, global_batch=2)
    with pytest.raises(ValueError, match="mode"):
        tr.train_steps(pipe, 1, mode="nope")


def test_spmd_needs_mesh_actionable_error():
    """Without K devices, camr_spmd fails at sync time with the
    XLA_FLAGS hint (never deep inside a shard_map trace)."""
    cfg = _tiny_cfg()
    tr = MultiModelCAMRTrainer(cfg, q=2, k=3, seed=0)
    if tr.mesh is not None:    # process actually has >= 6 devices
        pytest.skip("process has enough devices for a real mesh")
    pipe = ShardedTokenPipeline(vocab=64, seq_len=8, global_batch=2)
    with pytest.raises(RuntimeError, match="device_count"):
        tr.train_steps(pipe, 1, mode="camr_spmd")


def test_uncoded_rejects_degraded():
    """The uncoded baseline has no degraded mode and must say so;
    camr_spmd no longer rejects a failed set — it routes through the
    stream's degraded host lane (covered by the churn tests in
    tests/test_elastic.py, which need a K-device subprocess)."""
    cfg = _tiny_cfg()
    tr = MultiModelCAMRTrainer(cfg, q=2, k=3, seed=0, failed={0})
    pipe = ShardedTokenPipeline(vocab=64, seq_len=8, global_batch=2)
    with pytest.raises(ValueError, match="uncoded|camr"):
        tr.train_steps(pipe, 1, mode="uncoded")


# --------------------------------------------------------------------- #
# satellite: orphaned checkpoint tmp dirs
# --------------------------------------------------------------------- #
def test_available_steps_skips_tmp_dirs(tmp_path):
    os.makedirs(tmp_path / "step_00000003")
    (tmp_path / "step_00000003" / "manifest.json").write_text("{}")
    os.makedirs(tmp_path / "step_00000007.tmp.12345")   # crashed save
    os.makedirs(tmp_path / "step_00000002.tmp.1")       # crashed save
    assert available_steps(str(tmp_path)) == [3]


def test_gc_reaps_orphaned_tmp_dirs(tmp_path):
    """A crashed writer's stale step_*.tmp.<pid> dirs are removed by
    the next manager's retention pass instead of accumulating forever."""
    import time

    # a pid that provably belonged to a now-dead process
    dead = subprocess.Popen([sys.executable, "-c", "pass"])
    dead.wait()
    orphan = tmp_path / f"step_00000001.tmp.{dead.pid}"
    os.makedirs(orphan)
    (orphan / "junk.npy").write_bytes(b"x")
    old = time.time() - 2 * CheckpointManager.STALE_TMP_SECS
    os.utime(orphan, (old, old))
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save({"w": jnp.zeros((3,))}, step=1)
    mgr.wait()
    assert not orphan.exists()
    assert available_steps(str(tmp_path)) == [1]
    mgr.close()


def test_gc_keeps_fresh_and_own_tmp_dirs(tmp_path):
    """Never reaped: a tmp dir carrying OUR pid (could be a concurrent
    same-process writer) and any FRESH foreign tmp dir (could be
    another host's writer mid-save — pids don't compare across
    hosts, so only stale dirs are fair game)."""
    mine = tmp_path / f"step_00000009.tmp.{os.getpid()}"
    os.makedirs(mine)
    fresh_foreign = tmp_path / "step_00000008.tmp.999999"
    os.makedirs(fresh_foreign)
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save({"w": jnp.zeros((3,))}, step=1)
    mgr.wait()
    assert mine.exists()
    assert fresh_foreign.exists()
    mgr.close()


# --------------------------------------------------------------------- #
# satellite: async checkpoint worker errors surface in Trainer.run
# --------------------------------------------------------------------- #
def test_async_checkpoint_error_surfaces_in_run(tmp_path, monkeypatch):
    cfg = _tiny_cfg().replace(vocab=32, loss_chunk=16)
    pipe = ShardedTokenPipeline(vocab=32, seq_len=8, global_batch=2)
    tr = Trainer(cfg, ckpt_dir=str(tmp_path), total_steps=10, seed=0)

    import repro.checkpoint.ckpt as ckpt_mod

    def boom(*a, **kw):
        raise IOError("disk full")

    monkeypatch.setattr(ckpt_mod, "save_checkpoint", boom)
    with pytest.raises(IOError, match="disk full"):
        tr.run(pipe, steps=2, ckpt_every=2)   # final wait() re-raises


def test_checkpoint_manager_wait_reraises(tmp_path, monkeypatch):
    import repro.checkpoint.ckpt as ckpt_mod

    def boom(*a, **kw):
        raise RuntimeError("torn write")

    monkeypatch.setattr(ckpt_mod, "save_checkpoint", boom)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save({"w": jnp.zeros((2,))}, step=1)
    with pytest.raises(RuntimeError, match="torn write"):
        mgr.wait()
    mgr.close()


# --------------------------------------------------------------------- #
# satellite: crash-resume round trip incl. resume() metadata
# --------------------------------------------------------------------- #
@pytest.mark.slow
def test_trainer_crash_resume_roundtrip_metadata(tmp_path):
    """Kill-and-restart mid-run, then CONTINUE: the resumed trainer
    finishes with the same parameters as an uninterrupted run, and
    resume() restores the checkpointed metadata (step + data cursor)."""
    cfg = _tiny_cfg().replace(vocab=32, loss_chunk=16)
    pipe = ShardedTokenPipeline(vocab=32, seq_len=8, global_batch=2)

    straight = Trainer(cfg, ckpt_dir=str(tmp_path / "a"), total_steps=20,
                       seed=3)
    straight.run(pipe, steps=6, ckpt_every=0)

    t1 = Trainer(cfg, ckpt_dir=str(tmp_path / "b"), total_steps=20, seed=3)
    t1.run(pipe, steps=4, ckpt_every=2)
    # "crash": fresh object, different seed — resume must overwrite it
    t2 = Trainer(cfg, ckpt_dir=str(tmp_path / "b"), total_steps=20,
                 seed=1234)
    assert t2.resume()
    assert t2.step == 4
    _, meta = t2.ckpt.restore({"params": t2.params, "opt": t2.opt})
    assert meta["step"] == 4
    assert meta["pipeline_step"] == 4     # data cursor travels along
    t2.run(pipe, steps=2, ckpt_every=0)   # continue to step 6
    for a, b in zip(jax.tree.leaves(straight.params),
                    jax.tree.leaves(t2.params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
