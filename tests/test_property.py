"""Hypothesis property tests on the system's invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional test extra (pyproject.toml)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import loads
from repro.core.designs import make_design
from repro.core.engine import CAMRConfig, CAMREngine
from repro.core.placement import make_placement
from repro.core.shuffle import (
    coded_multicast_schedule, decode_coded_multicast, split_packets,
    xor_bytes)

qk = st.tuples(st.integers(2, 5), st.integers(2, 5))  # (q, k)


@given(qk)
@settings(max_examples=25, deadline=None)
def test_design_invariants(qk_):
    q, k = qk_
    d = make_design(q, k)
    d.validate()
    # parallel classes partition servers; blocks partition jobs per class
    assert sorted(s for c in d.parallel_classes for s in c) == \
        list(range(d.K))


@given(qk, st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_placement_replication_invariant(qk_, gamma):
    q, k = qk_
    pl = make_placement(make_design(q, k), gamma)
    M = pl.placement_matrix()
    # every subfile on exactly k-1 servers; per-server storage = mu
    assert (M.sum(axis=0) == k - 1).all()
    mu = (k - 1) / (k * q)
    assert np.allclose(M.sum(axis=(1, 2)) / (pl.design.J * pl.N), mu)


@given(st.binary(min_size=1, max_size=200), st.integers(1, 7))
@settings(max_examples=50, deadline=None)
def test_split_packets_reassembles(data, m):
    assert b"".join(split_packets(data, m))[:len(data)] == data


@given(st.lists(st.binary(min_size=16, max_size=16), min_size=2, max_size=6))
@settings(max_examples=50, deadline=None)
def test_xor_group_properties(parts):
    # commutative + self-inverse
    import random
    acc = xor_bytes(*parts)
    shuffled = list(parts)
    random.Random(0).shuffle(shuffled)
    assert xor_bytes(*shuffled) == acc
    assert xor_bytes(acc, *parts[1:]) == parts[0]


@given(st.integers(2, 6), st.integers(0, 2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_lemma2_random_chunks(k, seed):
    rng = np.random.default_rng(seed)
    group = tuple(sorted(rng.choice(100, size=k, replace=False).tolist()))
    B = 8 * (k - 1)
    chunks = {s: rng.bytes(B) for s in group}
    txs = coded_multicast_schedule(group, chunks, stage=1)
    assert sum(t.nbytes for t in txs) == B * k // (k - 1)
    for r in group:
        known = {s: c for s, c in chunks.items() if s != r}
        assert decode_coded_multicast(group, r, txs, known, B) == chunks[r]


@given(st.tuples(st.integers(2, 4), st.integers(2, 4)), st.integers(0, 999))
@settings(max_examples=10, deadline=None)
def test_engine_load_matches_formula_property(qk_, seed):
    """For any (q, k) and random data: decode correct, measured bus load
    equals the closed form (§IV) when packet sizes divide evenly."""
    q, k = qk_
    cfg = CAMRConfig(q=q, k=k, gamma=1)
    dim = 4 * max(1, k - 1)
    rng = np.random.default_rng(seed)
    ds = [[rng.standard_normal(dim) for _ in range(cfg.N)]
          for _ in range(cfg.J)]

    def map_fn(job, sf):
        return np.outer(np.arange(1, cfg.num_functions() + 1), sf)

    eng = CAMREngine(cfg, map_fn)
    eng.verify(ds, eng.run(ds))
    assert abs(eng.measured_loads()["L_total_bus"]
               - loads.camr_load(q, k)) < 1e-9


@given(st.integers(2, 5), st.integers(2, 5))
@settings(max_examples=25, deadline=None)
def test_aggregation_reduces_values_sent(q, k):
    """CAMR (with aggregation) always beats CDC-style per-subfile shuffles
    whenever N > k: the aggregate count is independent of gamma."""
    l_camr = loads.camr_load(q, k)
    # CDC at the same computation redundancy r = k-1 ships (1-mu)/(k-1)
    # *per subfile value*; with N = k*gamma subfiles and gamma >= 2 its
    # total value traffic exceeds CAMR's (which is gamma-invariant).
    mu = (k - 1) / (k * q)
    gamma = 2
    N = k * gamma
    cdc_total_values = loads.cdc_load(k - 1, k * q) * N
    assert l_camr < cdc_total_values or (q == 2 and k == 2)


@given(st.tuples(st.integers(2, 4), st.integers(2, 5)),
       st.tuples(st.integers(2, 4), st.integers(2, 5)))
@settings(max_examples=25, deadline=None)
def test_elastic_replan_properties(old_qk, new_qk):
    """Elastic re-planning (runtime/fault.py) is a pure re-placement:
    the pinned mu_target selects exactly the requested factorization,
    nothing re-encodes (the report is a placement diff bounded in
    [0, 1]), replan of a replan moves nothing (idempotence — the
    Membership.rejoin receipt relies on this), and the new placement
    leaves every subfile with k_new - 1 >= 1 live owners."""
    from repro.runtime.fault import elastic_replan

    q_old, k_old = old_qk
    q_new, k_new = new_qk
    K_new = q_new * k_new
    r = elastic_replan(q_old, k_old, K_new,
                       mu_target=(k_new - 1) / K_new)
    assert r.new_qk == (q_new, k_new)
    assert 0.0 <= r.moved_fraction <= 1.0
    assert abs(r.new_storage_fraction - (k_new - 1) / K_new) < 1e-12
    r2 = elastic_replan(q_new, k_new, K_new,
                        mu_target=(k_new - 1) / K_new)
    assert r2.new_qk == (q_new, k_new)
    assert r2.moved_fraction == 0.0
    M = make_placement(make_design(q_new, k_new), 1).placement_matrix()
    assert (M.sum(axis=0) == k_new - 1).all()


# --------------------------------------------------------------------- #
# fault domains (DESIGN.md §17): random kills never produce a wrong
# answer — either a typed rejection or a recovery the schedule covers
# --------------------------------------------------------------------- #
_HOST_CONFIGS = [(2, 4, 2), (3, 4, 2), (2, 6, 2), (2, 6, 3)]


@given(st.sampled_from(_HOST_CONFIGS),
       st.lists(st.integers(0, 3), min_size=1, max_size=6))
@settings(max_examples=40, deadline=None)
def test_random_host_kills_recover_or_reject(cfg, kills):
    """Any host-kill/rejoin walk either raises a typed MembershipError
    or lands on a surviving topology whose lowering shares the flat
    schedule VALUES bit-for-bit (so the re-homed stream is bitwise by
    construction) — never a wrong answer, never a bare ValueError."""
    from repro.core.collective import make_plan
    from repro.core.schedule import Topology, surviving_topology
    from repro.runtime.fault import (HostMembership, MembershipError,
                                     smallest_unrecoverable_set)

    q, k, hosts = cfg
    hm = HostMembership(q, k, Topology.two_level(hosts),
                        max_failed_hosts=hosts - 1)
    flat = make_plan(q, k, 2 * (k - 1))
    for h in kills:
        try:
            if h in hm.failed_hosts():
                hm.rejoin_host(h)
            else:
                hm.kill_host(h % hosts if h >= hosts else h)
        except MembershipError:
            continue                    # typed rejection is a valid end
        left = len(hm.live_hosts())
        t = hm.current_topology()
        assert t == surviving_topology(left, k)
        if t is not None:
            assert t.hosts == left and k % left == 0
        plan = make_plan(q, k, 2 * (k - 1), topology=t)
        for stage in (1, 2):
            A = flat.program.stage_tables(stage)
            B = plan.program.stage_tables(stage)
            # topology moves packets between edges, never between rows:
            # identical send/recv values ==> bitwise-identical outputs
            np.testing.assert_array_equal(A.a2a_send, B.a2a_send)
            np.testing.assert_array_equal(A.pp_send, B.pp_send)
        if hm.failed_workers():
            # dead blocks are never degradable around, only re-homed
            assert smallest_unrecoverable_set(
                q, k, hm.failed_workers()) is not None


@given(st.sampled_from([(2, 4, 2), (2, 6, 2), (2, 6, 3)]),
       st.lists(st.integers(0, 11), min_size=1, max_size=5))
@settings(max_examples=40, deadline=None)
def test_random_worker_kills_stay_recoverable(cfg, kills):
    """Membership admits a kill ONLY into a state the degraded shuffle
    can lower: every accepted sequence keeps the dead set recoverable
    and inside the domain cap; every refusal is a typed
    MembershipError (never a downstream ValueError)."""
    from repro.core.schedule import Topology
    from repro.runtime.fault import (Membership, MembershipError,
                                     StragglerPolicy,
                                     smallest_unrecoverable_set)

    q, k, hosts = cfg
    m = Membership(q, k, topology=Topology.two_level(hosts),
                   policy=StragglerPolicy(max_failed=1))
    for w in kills:
        try:
            m.kill(w % m.K)
        except MembershipError:
            continue
        assert smallest_unrecoverable_set(q, k, m.failed()) is None
        assert len(m.domains(m.failed())) <= m.policy.max_failed
        assert m.gateway_avoid() >= m.failed()
