"""Serving tests: the fixed host-loop oracle, the jit executable cache,
paged KV slots, and DecodeEngine/ServeStream parity (DESIGN.md §13)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.core.schedule import EXEC_CACHE, ExecCache
from repro.kernels.ops import attention
from repro.models import lm
from repro.runtime.serve import (DecodeEngine, PagePool, Request,
                                 ServeStream, generate, trace_total)


@pytest.fixture(scope="module")
def gemma():
    cfg = reduced(get_config("gemma2_2b"))
    return cfg, lm.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def mamba():
    cfg = reduced(get_config("mamba2_1p3b"))
    return cfg, lm.init_params(cfg, jax.random.PRNGKey(0))


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, (t,)).astype(np.int32)
            for t in lens]


def _oracle_gen(cfg, params, req):
    """Per-request B=1 host-loop reference (the fixed generate)."""
    res = generate(cfg, params, np.asarray(req.prompt)[None],
                   max_new=req.max_new, eos=req.eos,
                   temperature=req.temperature, seed=req.seed,
                   pad=req.pad)
    return res.tokens[0, len(req.prompt):]


def _assert_parity(cfg, params, reqs, results):
    for req, res in zip(reqs, results):
        want = _oracle_gen(cfg, params, req)
        got = res.generated[:len(want)]
        assert np.array_equal(want, got), (
            f"plen={res.prompt_len}: oracle {want} != engine {got}")


# --------------------------------------------------------------------- #
# legacy generate fixes (the oracle itself)
# --------------------------------------------------------------------- #
def test_generate_post_eos_rows_emit_pad(gemma):
    cfg, params = gemma
    prompts = np.asarray(_prompts(cfg, [6, 6, 6])[0])[None].repeat(3, 0)
    # force a known eos: whatever token row 0 emits first becomes eos
    first = generate(cfg, params, prompts, max_new=1).tokens[0, -1]
    res = generate(cfg, params, prompts, max_new=8, eos=int(first))
    gen = res.tokens[:, prompts.shape[1]:]
    for row in gen:
        hit = np.where(row == int(first))[0]
        assert len(hit) > 0
        assert (row[hit[0]:] == int(first)).all(), \
            "rows past eos must emit the eos id, not sampled garbage"
    # custom pad id fills the tail instead
    res2 = generate(cfg, params, prompts, max_new=8, eos=int(first),
                    pad=0)
    gen2 = res2.tokens[:, prompts.shape[1]:]
    for row in gen2:
        hit = np.where(row == int(first))[0]
        assert (row[hit[0] + 1:] == 0).all()


def test_generate_second_call_zero_retrace(gemma):
    cfg, params = gemma
    prompts = np.stack(_prompts(cfg, [7, 7], seed=3))
    r1 = generate(cfg, params, prompts, max_new=5, eos=1)
    before = trace_total()
    r2 = generate(cfg, params, prompts, max_new=5, eos=1)
    assert trace_total() == before, \
        "same-shape generate must reuse the cached executables"
    assert np.array_equal(r1.tokens, r2.tokens)
    assert len(r1.step_times) == r1.steps


# --------------------------------------------------------------------- #
# executable cache
# --------------------------------------------------------------------- #
def test_exec_cache_hit_miss_and_lru():
    c = ExecCache(maxsize=2)
    built = []

    def mk(tag):
        def build():
            built.append(tag)
            return tag
        return build

    assert c.get("a", mk("a")) == "a"
    assert c.get("a", mk("a2")) == "a"          # hit: no rebuild
    assert built == ["a"]
    c.get("b", mk("b"))
    c.get("a", mk("a3"))                         # refresh a's recency
    c.get("c", mk("c"))                          # evicts b (LRU)
    c.get("b", mk("b2"))
    assert built == ["a", "b", "c", "b2"]
    s = c.stats()
    assert s["hits"] == 2 and s["misses"] == 4 and s["entries"] == 2


# --------------------------------------------------------------------- #
# paged KV plumbing
# --------------------------------------------------------------------- #
def test_page_pool_never_aliases():
    pool = PagePool(8)
    a = pool.alloc(0, 3)
    b = pool.alloc(1, 3)
    assert a is not None and b is not None
    assert 0 not in a + b, "trash page must never be handed out"
    assert not set(a) & set(b)
    pool.check_invariants()
    assert pool.alloc(2, 2) is None              # only 1 page left
    pool.free(0)
    c = pool.alloc(2, 3)
    assert set(c) == set(a), "freed pages are immediately reusable"
    pool.check_invariants()
    with pytest.raises(ValueError):
        pool.alloc(1, 1)                         # slot already owns pages


def test_attention_vector_valid_len_matches_scalar():
    rng = np.random.default_rng(0)
    B, H, Tq, Tk, D = 3, 2, 1, 12, 8
    q = jnp.asarray(rng.standard_normal((B, H, Tq, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, H, Tk, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, H, Tk, D)), jnp.float32)
    lens = np.array([4, 9, 12], np.int32)
    out = attention(q, k, v, causal=True, valid_len=jnp.asarray(lens))
    for b, L in enumerate(lens):
        ref = attention(q[b:b + 1], k[b:b + 1], v[b:b + 1], causal=True,
                        valid_len=int(L))
        np.testing.assert_allclose(np.asarray(out[b]),
                                   np.asarray(ref[0]), atol=1e-5)


def test_paged_eviction_reuse_never_aliases_live_rows(gemma):
    """The aliasing trap: B finishes, its pages are re-used by C while A
    is still decoding — A's tokens must be unaffected."""
    cfg, params = gemma
    pa, pb, pc = _prompts(cfg, [6, 4, 5], seed=7)
    # B stops after 2 tokens (cap), A and C run long
    ra = Request(prompt=pa, max_new=10)
    rb = Request(prompt=pb, max_new=2)
    rc = Request(prompt=pc, max_new=10)
    # pool fits exactly two live requests -> C must recycle B's pages
    eng = DecodeEngine(cfg, params, slots=2, page_size=4, max_ctx=16,
                       n_pages=9, max_new_cap=10)
    stream = ServeStream(eng, wave_len=2)
    results = stream.run([ra, rb, rc])
    eng.pool.check_invariants()
    _assert_parity(cfg, params, [ra, rb, rc], results)


# --------------------------------------------------------------------- #
# engine parity vs the host-loop oracle
# --------------------------------------------------------------------- #
def test_engine_greedy_parity_ragged_prompts(gemma):
    cfg, params = gemma
    reqs = [Request(prompt=p, max_new=8)
            for p in _prompts(cfg, [3, 11, 6, 9, 1, 5], seed=1)]
    eng = DecodeEngine(cfg, params, slots=3, page_size=4, max_ctx=24,
                       max_new_cap=8)
    results = ServeStream(eng, wave_len=4).run(reqs)
    _assert_parity(cfg, params, reqs, results)


def test_engine_early_eos_parity(gemma):
    cfg, params = gemma
    prompts = _prompts(cfg, [5, 5, 8, 8], seed=2)
    # pick each request's first greedy token as its eos: stops at step 1
    # in some slots while others keep decoding
    eos = [int(generate(cfg, params, p[None], max_new=1).tokens[0, -1])
           for p in prompts]
    reqs = [Request(prompt=p, max_new=6, eos=e if i % 2 == 0 else None)
            for i, (p, e) in enumerate(zip(prompts, eos))]
    eng = DecodeEngine(cfg, params, slots=4, page_size=4, max_ctx=16,
                       max_new_cap=6)
    results = ServeStream(eng, wave_len=3).run(reqs)
    _assert_parity(cfg, params, reqs, results)
    for req, res in zip(reqs, results):
        if req.eos is not None:
            assert res.emitted < req.max_new


def test_engine_temperature_parity_pinned_key(gemma):
    cfg, params = gemma
    reqs = [Request(prompt=p, max_new=6, temperature=0.8, seed=40 + i)
            for i, p in enumerate(_prompts(cfg, [4, 7, 6], seed=4))]
    eng = DecodeEngine(cfg, params, slots=2, page_size=4, max_ctx=16,
                       max_new_cap=6)
    results = ServeStream(eng, wave_len=4).run(reqs)
    _assert_parity(cfg, params, reqs, results)


def test_engine_parity_ssm_arch(mamba):
    cfg, params = mamba
    reqs = [Request(prompt=p, max_new=6)
            for p in _prompts(cfg, [5, 9, 3], seed=5)]
    eng = DecodeEngine(cfg, params, slots=2, page_size=4, max_ctx=16,
                       max_new_cap=6)
    results = ServeStream(eng, wave_len=3).run(reqs)
    _assert_parity(cfg, params, reqs, results)


def test_engine_wave_length_invariance(gemma):
    """Tokens must not depend on the wave partitioning."""
    cfg, params = gemma
    reqs = [Request(prompt=p, max_new=8)
            for p in _prompts(cfg, [6, 4, 9], seed=6)]

    def run(wave):
        eng = DecodeEngine(cfg, params, slots=2, page_size=4,
                           max_ctx=24, max_new_cap=8)
        return ServeStream(eng, wave_len=wave).run(reqs)

    a, b = run(1), run(8)
    for ra, rb in zip(a, b):
        assert np.array_equal(ra.tokens, rb.tokens)


def test_engine_mid_stream_admission_zero_recompiles(gemma):
    """More requests than slots: admissions happen mid-stream, and after
    the first run has warmed the executables a second stream run with
    fresh prompt lengths drawn from the same set pays ZERO traces."""
    cfg, params = gemma
    lens = [3, 6, 9]
    mk = lambda seed: [Request(prompt=p, max_new=5)
                       for p in _prompts(cfg, lens * 2, seed=seed)]
    eng = DecodeEngine(cfg, params, slots=2, page_size=4, max_ctx=16,
                       max_new_cap=5)
    stream = ServeStream(eng, wave_len=3)
    r1 = stream.run(mk(8))                       # warmup traces allowed
    assert stream.last_report.admitted == 6
    before = trace_total()
    r2 = stream.run(mk(9))
    assert trace_total() == before, \
        "steady-state admission must not trigger recompilation"
    assert stream.last_report.traces == 0
    _assert_parity(cfg, params, mk(9), r2)


def test_engine_multi_tenant_stream(gemma, mamba):
    gcfg, gparams = gemma
    mcfg, mparams = mamba
    engines = {
        "gemma": DecodeEngine(gcfg, gparams, slots=2, page_size=4,
                              max_ctx=16, max_new_cap=5, name="gemma"),
        "mamba": DecodeEngine(mcfg, mparams, slots=2, page_size=4,
                              max_ctx=16, max_new_cap=5, name="mamba"),
    }
    jobs = []
    for i, p in enumerate(_prompts(gcfg, [4, 7, 5], seed=10)):
        jobs.append(("gemma", Request(prompt=p, max_new=5)))
    for i, p in enumerate(_prompts(mcfg, [6, 3, 8], seed=11)):
        jobs.append(("mamba", Request(prompt=p, max_new=5)))
    stream = ServeStream(engines, wave_len=3)
    results = stream.run(jobs)
    assert all(r is not None for r in results)
    for (name, req), res in zip(jobs, results):
        assert res.model == name
        cfg, params = (gcfg, gparams) if name == "gemma" else \
            (mcfg, mparams)
        want = _oracle_gen(cfg, params, req)
        assert np.array_equal(want, res.generated[:len(want)])


def test_engine_rejects_oversized_and_unsupported(gemma):
    cfg, params = gemma
    eng = DecodeEngine(cfg, params, slots=2, page_size=4, max_ctx=8,
                       max_new_cap=4)
    with pytest.raises(ValueError):
        eng.validate(Request(prompt=np.zeros(7, np.int32), max_new=4))
    with pytest.raises(ValueError):
        eng.validate(Request(prompt=np.zeros(2, np.int32), max_new=9))
    enc = get_config("seamless_m4t_large_v2")
    with pytest.raises(NotImplementedError):
        DecodeEngine(reduced(enc), None)


def test_serial_stream_matches_pipelined(gemma):
    cfg, params = gemma
    reqs = [Request(prompt=p, max_new=5)
            for p in _prompts(cfg, [5, 8, 4, 6], seed=12)]

    def run(pipeline):
        eng = DecodeEngine(cfg, params, slots=2, page_size=4,
                           max_ctx=16, max_new_cap=5)
        return ServeStream(eng, wave_len=3, pipeline=pipeline).run(reqs)

    for ra, rb in zip(run(True), run(False)):
        assert np.array_equal(ra.tokens, rb.tokens)
