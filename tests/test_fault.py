"""Fault tolerance: degraded shuffle, straggler recovery, elastic replan,
and the CAMR multi-model training integration."""

import itertools

import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import loads
from repro.core.designs import make_design
from repro.core.engine import CAMRConfig, CAMREngine
from repro.core.placement import make_placement
from repro.data.pipeline import ShardedTokenPipeline
from repro.core.schedule import Topology, surviving_topology
from repro.runtime.fault import (DegradedCAMREngine, HostMembership,
                                 Membership, MembershipError,
                                 StragglerPolicy, elastic_replan,
                                 smallest_unrecoverable_set)
from repro.runtime.train_loop import MultiModelCAMRTrainer


def _linear_map(Q):
    def map_fn(job, sf):
        return np.outer(np.arange(1, Q + 1, dtype=np.float64), sf)
    return map_fn


def _datasets(cfg, dim=8, seed=0):
    rng = np.random.default_rng(seed)
    return [[rng.standard_normal(dim) for _ in range(cfg.N)]
            for _ in range(cfg.J)]


@pytest.mark.parametrize("q,k,failed", [
    (2, 3, {0}), (2, 3, {5}), (3, 3, {4}), (2, 4, {7}), (4, 3, {1}),
    (2, 4, {0, 7}),   # two failures, different classes, k-1 = 3 replicas
    (2, 5, {0, 3, 9}),  # three failures across classes (4-way replication)
])
def test_degraded_engine_recovers(q, k, failed):
    """With failed servers silent in the shuffle, every live server still
    reduces every (job, function) correctly — the placement redundancy
    covers the loss with NO map recomputation."""
    cfg = CAMRConfig(q=q, k=k, gamma=1)
    ds = _datasets(cfg, dim=2 * (k - 1))
    eng = DegradedCAMREngine(cfg, _linear_map(cfg.num_functions()),
                             failed=failed)
    results = eng.run(ds)
    oracle = eng.oracle(ds)
    checked = 0
    for s_orig in range(cfg.K):
        s = eng.migrate_target(s_orig)
        for qf in eng.functions_of(s_orig):
            for j in range(cfg.J):
                got = results[s][(j, qf)]
                assert got is not None, (s_orig, j, qf)
                np.testing.assert_allclose(got, oracle[(j, qf)],
                                           rtol=1e-6, atol=1e-6)
                checked += 1
    assert checked == cfg.J * cfg.num_functions()


def test_degraded_shuffle_is_idempotent():
    """Re-running shuffle_phase on the same engine must not change the
    reduce results (the split stage-3 sends are combined locally, then
    assigned — like the base engine's overwrite semantics)."""
    cfg = CAMRConfig(q=2, k=3, gamma=1)
    ds = _datasets(cfg, dim=4)
    eng = DegradedCAMREngine(cfg, _linear_map(cfg.num_functions()),
                             failed={0})
    r1 = eng.run(ds)
    eng.shuffle_phase()
    r2 = eng.reduce_phase()
    for s in range(cfg.K):
        assert r1[s].keys() == r2[s].keys()
        for key, v in r1[s].items():
            np.testing.assert_array_equal(v, r2[s][key])


def test_degraded_load_inflation_is_bounded():
    """Degraded-mode load exceeds the healthy load, but stays below the
    fully-uncoded baseline (the redundancy absorbs the failure)."""
    cfg = CAMRConfig(q=3, k=3, gamma=1)
    ds = _datasets(cfg, dim=4)
    healthy = CAMREngine(cfg, _linear_map(cfg.num_functions()))
    healthy.verify(ds, healthy.run(ds))
    l_health = healthy.measured_loads()["L_total_bus"]

    degraded = DegradedCAMREngine(cfg, _linear_map(cfg.num_functions()),
                                  failed={2})
    degraded.run(ds)
    l_deg = degraded.trace.total_bytes() / (
        cfg.J * cfg.num_functions() * degraded.value_bytes)
    assert l_health <= l_deg < 2.5 * l_health


def test_too_many_failures_rejected():
    cfg = CAMRConfig(q=2, k=3, gamma=1)
    with pytest.raises(ValueError):
        DegradedCAMREngine(cfg, _linear_map(6), failed={0, 1})  # same class
    # k=3: any cross-class failure pair co-holds a batch -> data loss
    with pytest.raises(ValueError):
        DegradedCAMREngine(cfg, _linear_map(6), failed={0, 4})


@pytest.mark.parametrize("q,k", [(2, 4), (3, 3), (2, 5)])
def test_k_minus_one_failures_always_unrecoverable(q, k):
    """Survivor-set edge: every batch lives on exactly k-1 servers, so
    ANY k-1 concurrent failures either double up inside a parallel
    class or wipe some batch's full holder set — exhaustively
    rejected. (Recoverable k-2 sets exist: the parametrized recovery
    test above runs them.)"""
    cfg = CAMRConfig(q=q, k=k, gamma=1)
    Q = cfg.num_functions()
    for combo in itertools.combinations(range(cfg.K), k - 1):
        with pytest.raises(ValueError):
            DegradedCAMREngine(cfg, _linear_map(Q), failed=set(combo))


def test_single_group_loss_rejected():
    """Losing one whole parallel class (a 'group' of q servers) is
    never recoverable — those servers were each other's only same-class
    migration targets."""
    cfg = CAMRConfig(q=3, k=3, gamma=1)
    d = make_design(3, 3)
    cls = sorted(d.parallel_classes[0])
    with pytest.raises(ValueError, match="parallel class|recompute"):
        DegradedCAMREngine(cfg, _linear_map(cfg.num_functions()),
                           failed=set(cls))


def test_failed_set_frozen_after_lowering():
    """Stacking a second failure onto a LIVE degraded engine must be a
    hard error, not a silent mis-reduce: the re-lowered schedule still
    routes through the newly-dead server. The error points at the
    supported path (a fresh re-lowering via retarget_engine)."""
    cfg = CAMRConfig(q=2, k=4, gamma=1)
    ds = _datasets(cfg, dim=6)
    eng = DegradedCAMREngine(cfg, _linear_map(cfg.num_functions()),
                             failed={0})
    eng.map_phase(ds)
    eng.failed.add(7)                  # mutation after lowering
    with pytest.raises(MembershipError, match="retarget_engine"):
        eng.shuffle_phase()
    with pytest.raises(MembershipError, match="frozen|re-lowered"):
        eng.reduce_phase()
    eng.failed.discard(7)              # matching set runs fine again
    eng.shuffle_phase()
    eng.reduce_phase()


def test_elastic_replan():
    r = elastic_replan(2, 3, 12)             # 6 -> 12 servers
    assert r.new_qk[0] * r.new_qk[1] == 12
    assert 0.0 <= r.moved_fraction <= 1.0
    # growing the cluster must move data to the fresh servers
    assert r.moved_fraction > 0.0
    r2 = elastic_replan(2, 3, 6)              # same size -> same design
    assert r2.new_qk in [(2, 3), (3, 2)]
    if r2.new_qk == (2, 3):
        assert r2.moved_fraction == 0.0


def test_elastic_replan_mu_target():
    r = elastic_replan(2, 3, 100, mu_target=0.04)
    q, k = r.new_qk
    assert q * k == 100
    assert abs((k - 1) / 100 - 0.04) < 0.02


@pytest.mark.parametrize("q_old,k_old", [(2, 3), (3, 3), (2, 4)])
@pytest.mark.parametrize("q_new,k_new",
                         [(2, 3), (3, 2), (2, 4), (4, 3), (2, 5)])
def test_elastic_replan_invariants(q_old, k_old, q_new, k_new):
    """Deterministic grid over the replan invariants (the hypothesis
    twin in tests/test_property.py walks a randomized domain): pinning
    ``mu_target`` selects the intended factorization, re-planning is a
    pure re-placement (never re-encodes — the report is a placement
    diff, bounded in [0, 1]), replan of a replan moves nothing, and
    every subfile keeps k_new - 1 >= 1 live owners afterwards."""
    K_new = q_new * k_new
    r = elastic_replan(q_old, k_old, K_new,
                       mu_target=(k_new - 1) / K_new)
    assert r.new_qk == (q_new, k_new)
    assert 0.0 <= r.moved_fraction <= 1.0
    assert r.new_storage_fraction == pytest.approx((k_new - 1) / K_new)
    r2 = elastic_replan(q_new, k_new, K_new,
                        mu_target=(k_new - 1) / K_new)
    assert r2.new_qk == (q_new, k_new)
    assert r2.moved_fraction == 0.0            # idempotent
    M = make_placement(make_design(q_new, k_new), 1).placement_matrix()
    assert (M.sum(axis=0) == k_new - 1).all()  # every subfile owned


# --------------------------------------------------------------------- #
# fault domains (DESIGN.md §17): the recoverability oracle
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("q,k,sizes", [
    (2, 3, (1, 2)), (3, 3, (1, 2)), (2, 4, (1, 2, 3)),
])
def test_smallest_unrecoverable_set_matches_engine(q, k, sizes):
    """Exhaustive agreement over every failed set of the listed sizes:
    the closed-form oracle rejects EXACTLY the sets the degraded
    lowering rejects, and every witness it names is itself a minimal
    unrecoverable subset of the probe."""
    cfg = CAMRConfig(q=q, k=k, gamma=1)
    Q = cfg.num_functions()
    for size in sizes:
        for combo in itertools.combinations(range(cfg.K), size):
            failed = set(combo)
            bad = smallest_unrecoverable_set(q, k, failed)
            if bad is None:
                DegradedCAMREngine(cfg, _linear_map(Q), failed=failed)
            else:
                assert set(bad) <= failed
                # the witness is unrecoverable ON ITS OWN
                assert smallest_unrecoverable_set(q, k, set(bad)) \
                    is not None
                with pytest.raises(ValueError):
                    DegradedCAMREngine(cfg, _linear_map(Q),
                                       failed=failed)


def test_smallest_unrecoverable_set_edges():
    assert smallest_unrecoverable_set(2, 4, set()) is None
    # k < 3: no coded shuffle, any single failure is fatal
    assert smallest_unrecoverable_set(2, 2, {3}) == (3,)
    # same parallel class (class i owns devices [i*q, (i+1)*q))
    assert smallest_unrecoverable_set(2, 4, {0, 1}) == (0, 1)
    # cross-class singles are fine at k = 4
    assert smallest_unrecoverable_set(2, 4, {0, 2}) is None


def test_membership_counts_fault_domains_not_workers():
    """One host = ONE correlated event: with a two-level topology the
    ``max_failed`` cap counts class-major host blocks, so a second
    same-host (cross-class) kill is admissible where the flat
    accounting would already refuse it."""
    topo = Topology.two_level(2)
    m = Membership(2, 4, topology=topo,
                   policy=StragglerPolicy(max_failed=1))
    m.kill(0)                       # host 0, class 0
    m.kill(2)                       # host 0, class 1: same domain
    assert m.failed() == {0, 2}
    assert m.domains(m.failed()) == {0}
    assert m.gateway_avoid() == {0, 2}
    # a SECOND domain trips the cap, and the message says so in
    # fault-domain terms
    with pytest.raises(MembershipError, match="max_failed") as ei:
        m.kill(4)
    assert "domains" in str(ei.value)
    # an unrecoverable same-class kill is vetoed with the smallest
    # witness, pointing at host-granularity recovery
    with pytest.raises(MembershipError,
                       match="shuffle-unrecoverable") as ei:
        m.kill(1)
    assert "[0, 1]" in str(ei.value)
    assert "HostMembership" in str(ei.value)
    # flat accounting: the same second kill exceeds max_failed=1
    f = Membership(2, 4, policy=StragglerPolicy(max_failed=1))
    f.kill(0)
    assert f.domains(f.failed()) == {0}
    with pytest.raises(MembershipError, match="max_failed"):
        f.kill(2)


@pytest.mark.parametrize("q,k,hosts", [
    (2, 4, 2), (3, 4, 2), (2, 6, 2), (2, 6, 3), (2, 8, 4),
])
def test_host_membership_exhaustive_block_sets(q, k, hosts):
    """Every proper subset of hosts is killable (in any order) under a
    full-width cap, lands on the surviving-topology the closed form
    names, and the lost block is ALWAYS worker-unrecoverable — whole
    hosts can only be re-homed, never degraded around. Killing the
    last host is rejected by name."""
    K = q * k
    dph = K // hosts
    for r in range(1, hosts):
        for combo in itertools.combinations(range(hosts), r):
            hm = HostMembership(q, k, Topology.two_level(hosts),
                                max_failed_hosts=hosts - 1)
            for h in combo:
                block = hm.kill_host(h)
                assert block == tuple(range(h * dph, (h + 1) * dph))
            assert hm.failed_hosts() == set(combo)
            assert hm.failed_workers() == {
                w for h in combo for w in hm.host_block(h)}
            # a dead host block always wipes whole parallel classes
            assert smallest_unrecoverable_set(
                q, k, hm.failed_workers()) is not None
            left = hosts - r
            want = surviving_topology(left, k)
            assert hm.current_topology() == want
            if left >= 2 and k % left == 0:
                assert want == Topology.two_level(left)
            else:
                assert want is None          # bitwise flat fallback
    hm = HostMembership(q, k, Topology.two_level(hosts),
                        max_failed_hosts=hosts - 1)
    for h in range(hosts - 1):
        hm.kill_host(h)
    with pytest.raises(MembershipError, match="unrecoverable"):
        hm.kill_host(hosts - 1)
    # rejoin re-homes back up the very same ladder
    hm.rejoin_host(0)
    assert 0 in hm.live_hosts()
    assert hm.current_topology() == surviving_topology(2, k)


def test_host_membership_validation():
    with pytest.raises(MembershipError, match="two-level"):
        HostMembership(2, 4, None)
    with pytest.raises(MembershipError, match="max_failed_hosts"):
        HostMembership(2, 4, Topology.two_level(2), max_failed_hosts=2)
    hm = HostMembership(2, 4, Topology.two_level(2))
    assert hm.max_failed_hosts == 1
    hm.kill_host(1)
    with pytest.raises(MembershipError, match="already dead"):
        hm.kill_host(1)
    with pytest.raises(MembershipError, match="outside"):
        hm.kill_host(5)
    with pytest.raises(MembershipError, match="only dead"):
        hm.rejoin_host(0)
    # the cap counts host domains: a second host is one event too many
    hm2 = HostMembership(2, 6, Topology.two_level(3),
                         max_failed_hosts=1)
    hm2.kill_host(0)
    with pytest.raises(MembershipError, match="max_failed_hosts"):
        hm2.kill_host(1)


# --------------------------------------------------------------------- #
# paper integration: multi-model training with coded gradient shuffle
# --------------------------------------------------------------------- #
@pytest.mark.slow
def test_multimodel_camr_training_matches_uncoded():
    """J=4 tiny LMs, K=6 workers: the CAMR-synced run and the uncoded run
    produce the SAME loss trajectories (same math, different wires), and
    the measured shuffle load matches §IV."""
    cfg = reduced(get_config("granite_3_2b")).replace(
        n_layers=2, vocab=64, d_model=32, d_ff=64, n_heads=2, n_kv_heads=1,
        head_dim=16, loss_chunk=8)
    pipe = ShardedTokenPipeline(vocab=64, seq_len=8, global_batch=2)
    t_camr = MultiModelCAMRTrainer(cfg, q=2, k=3, seed=0)
    rep_camr = t_camr.train_steps(pipe, steps=2, mode="camr")
    t_unc = MultiModelCAMRTrainer(cfg, q=2, k=3, seed=0)
    rep_unc = t_unc.train_steps(pipe, steps=2, mode="uncoded")

    np.testing.assert_allclose(np.array(rep_camr.losses),
                               np.array(rep_unc.losses), rtol=1e-4)
    # loads: coded == formula; uncoded strictly worse
    assert rep_camr.loads["L_total_bus"] == pytest.approx(
        loads.camr_load(2, 3), rel=1e-6)
    assert rep_unc.loads["L_total_bus"] == pytest.approx(
        loads.uncoded_aggregated_load(2, 3), rel=1e-6)
    assert rep_camr.bytes_total < rep_unc.bytes_total
    # training actually proceeds
    l0 = np.mean(rep_camr.losses[0])
    l1 = np.mean(rep_camr.losses[-1])
    assert np.isfinite(l0) and np.isfinite(l1)
