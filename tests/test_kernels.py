"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracles."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.aggregate import aggregate
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.xor_code import (xor_decode, xor_decode_gather,
                                    xor_encode, xor_encode_gather, xor_fold)


# --------------------------------------------------------------------- #
# xor_code
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("m,n", [(2, 64), (3, 100), (5, 1024), (2, 1),
                                 (4, 4097)])
def test_xor_encode_matches_ref(m, n):
    rng = np.random.default_rng(m * 1000 + n)
    pk = rng.integers(0, 2**32, size=(m, n), dtype=np.uint32)
    got = xor_encode(jnp.asarray(pk), block=256)
    want = ref.xor_encode_ref(jnp.asarray(pk))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_xor_encode_involution():
    rng = np.random.default_rng(0)
    a = rng.integers(0, 2**32, size=(2, 300), dtype=np.uint32)
    enc = np.asarray(xor_encode(jnp.asarray(a)))
    np.testing.assert_array_equal(enc ^ a[0], a[1])


@pytest.mark.parametrize("R,m,n", [(1, 2, 64), (5, 3, 100), (16, 4, 1025),
                                   (3, 2, 1)])
def test_xor_fold_matches_ref(R, m, n):
    rng = np.random.default_rng(R * 100 + m * 10 + n)
    pk = rng.integers(0, 2**32, size=(R, m, n), dtype=np.uint32)
    got = xor_fold(jnp.asarray(pk), block=256)
    want = ref.xor_fold_ref(jnp.asarray(pk))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("R,m,n", [(1, 2, 64), (6, 4, 300), (4, 3, 1025)])
def test_xor_decode_matches_ref(R, m, n):
    rng = np.random.default_rng(R + m + n)
    pk = rng.integers(0, 2**32, size=(R, m, n), dtype=np.uint32)
    rv = rng.integers(0, 2**32, size=(R, n), dtype=np.uint32)
    mk = rng.integers(0, 2, size=(R, m)).astype(bool)
    got = xor_decode(jnp.asarray(rv), jnp.asarray(pk), jnp.asarray(mk),
                     block=256)
    want = ref.xor_decode_ref(jnp.asarray(rv), jnp.asarray(pk),
                              jnp.asarray(mk))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_xor_codec_roundtrip():
    """decode(encode) recovers the receiver's packet: Δ = XOR of m
    packets; cancelling m-1 of them leaves the remaining one."""
    rng = np.random.default_rng(42)
    R, m, n = 4, 3, 200
    pk = rng.integers(0, 2**32, size=(R, m, n), dtype=np.uint32)
    delta = xor_fold(jnp.asarray(pk), block=256)        # all m packets
    mask = np.ones((R, m), dtype=bool)
    mask[:, 0] = False                                   # cancel all but 0
    got = xor_decode(delta, jnp.asarray(pk), jnp.asarray(mask), block=256)
    np.testing.assert_array_equal(np.asarray(got), pk[:, 0])


# --------------------------------------------------------------------- #
# fused gather-XOR codec (single-pass encode/decode)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("P,pk,n,m", [(8, 64, 4, 3), (37, 200, 11, 4),
                                      (5, 1, 3, 2), (64, 1025, 9, 5)])
def test_xor_encode_gather_matches_ref(P, pk, n, m):
    rng = np.random.default_rng(P * 7 + pk + n + m)
    chunks = rng.integers(0, 2**32, size=(P, pk), dtype=np.uint32)
    idx = rng.integers(0, P, size=(n, m)).astype(np.int32)
    mask = rng.integers(0, 2, size=(n, m)).astype(bool)
    got = xor_encode_gather(jnp.asarray(chunks), jnp.asarray(idx),
                            jnp.asarray(mask), block=256)
    want = ref.xor_encode_gather_ref(jnp.asarray(chunks), jnp.asarray(idx),
                                     jnp.asarray(mask))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("P,pk,R,m", [(8, 64, 6, 3), (21, 130, 10, 4),
                                      (4, 1, 2, 2)])
def test_xor_decode_gather_matches_ref(P, pk, R, m):
    rng = np.random.default_rng(P + pk + R + m)
    chunks = rng.integers(0, 2**32, size=(P, pk), dtype=np.uint32)
    recv = rng.integers(0, 2**32, size=(R, pk), dtype=np.uint32)
    rsel = rng.permutation(R).astype(np.int32)
    idx = rng.integers(0, P, size=(R, m)).astype(np.int32)
    mask = rng.integers(0, 2, size=(R, m)).astype(bool)
    got = xor_decode_gather(jnp.asarray(recv), jnp.asarray(chunks),
                            jnp.asarray(rsel), jnp.asarray(idx),
                            jnp.asarray(mask), block=256)
    want = ref.xor_decode_gather_ref(jnp.asarray(recv), jnp.asarray(chunks),
                                     jnp.asarray(rsel), jnp.asarray(idx),
                                     jnp.asarray(mask))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_gather_codec_roundtrip():
    """Fused encode then fused decode recovers the excluded packet:
    Δ = XOR of all m sources; cancelling m-1 of them leaves one."""
    rng = np.random.default_rng(7)
    P, pk, n, m = 30, 96, 5, 4
    chunks = rng.integers(0, 2**32, size=(P, pk), dtype=np.uint32)
    # distinct sources per row so the rows are invertible
    idx = np.stack([rng.choice(P, size=m, replace=False)
                    for _ in range(n)]).astype(np.int32)
    full = np.ones((n, m), dtype=bool)
    delta = xor_encode_gather(jnp.asarray(chunks), jnp.asarray(idx),
                              jnp.asarray(full), block=256)
    canc = full.copy()
    canc[:, 0] = False                          # cancel all but source 0
    rsel = np.arange(n, dtype=np.int32)
    got = xor_decode_gather(delta, jnp.asarray(chunks), jnp.asarray(rsel),
                            jnp.asarray(idx), jnp.asarray(canc), block=256)
    np.testing.assert_array_equal(np.asarray(got), chunks[idx[:, 0]])


def test_gather_codec_masked_zero_index():
    """Masked-off entries are AND-killed even when their baked index
    aliases a real row (the lowering bakes 0 for invalid sources)."""
    rng = np.random.default_rng(8)
    chunks = rng.integers(0, 2**32, size=(6, 40), dtype=np.uint32)
    idx = np.zeros((3, 4), dtype=np.int32)      # all alias row 0
    mask = np.zeros((3, 4), dtype=bool)
    got = xor_encode_gather(jnp.asarray(chunks), jnp.asarray(idx),
                            jnp.asarray(mask), block=256)
    np.testing.assert_array_equal(np.asarray(got), 0)


def test_gather_codec_rejects_bad_shapes():
    chunks = jnp.zeros((4, 8), jnp.uint32)
    with pytest.raises(TypeError):
        xor_encode_gather(chunks.astype(jnp.int32),
                          jnp.zeros((2, 2), jnp.int32),
                          jnp.ones((2, 2), bool))
    with pytest.raises(ValueError):
        xor_encode_gather(chunks, jnp.zeros((2, 2), jnp.int32),
                          jnp.ones((2, 3), bool))
    with pytest.raises(ValueError):
        xor_decode_gather(jnp.zeros((2, 8), jnp.uint32), chunks,
                          jnp.zeros((3,), jnp.int32),
                          jnp.zeros((2, 2), jnp.int32),
                          jnp.ones((2, 2), bool))


# --------------------------------------------------------------------- #
# aggregate
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("n,d,S", [(16, 8, 4), (100, 33, 7), (512, 256, 16),
                                   (7, 640, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_aggregate_matches_ref(n, d, S, dtype):
    rng = np.random.default_rng(n + d)
    vals = rng.standard_normal((n, d)).astype(dtype)
    ids = rng.integers(0, S, size=n).astype(np.int32)
    got = aggregate(jnp.asarray(vals), jnp.asarray(ids), S,
                    block_n=64, block_d=128)
    want = ref.aggregate_ref(jnp.asarray(vals), jnp.asarray(ids), S)
    # one-hot-matmul and segment_sum reduce in different f32 orders
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_aggregate_commutativity():
    """Associativity/commutativity of the α-combiner (Def. 1): permuting
    rows must not change the aggregates."""
    rng = np.random.default_rng(1)
    vals = rng.standard_normal((50, 16)).astype(np.float32)
    ids = rng.integers(0, 5, size=50).astype(np.int32)
    perm = rng.permutation(50)
    a = aggregate(jnp.asarray(vals), jnp.asarray(ids), 5)
    b = aggregate(jnp.asarray(vals[perm]), jnp.asarray(ids[perm]), 5)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-6,
                               atol=1e-5)


# --------------------------------------------------------------------- #
# flash attention
# --------------------------------------------------------------------- #
ATTN_CASES = [
    # B, Hq, Hkv, Tq, Tk, D, causal, window, softcap
    (1, 2, 2, 64, 64, 16, True, None, None),
    (2, 4, 2, 32, 32, 32, True, None, None),        # GQA
    (1, 2, 1, 128, 128, 16, True, 32, None),        # sliding window
    (1, 2, 2, 64, 64, 16, True, None, 50.0),        # softcap (gemma2)
    (1, 4, 4, 48, 48, 16, False, None, None),       # bidirectional (encoder)
    (1, 2, 1, 1, 96, 16, True, None, None),         # decode: Tq=1, KV cache
    (1, 2, 2, 100, 100, 16, True, None, None),      # non-divisible lengths
    (1, 8, 2, 8, 72, 16, True, 24, None),           # decode-window combo
]


@pytest.mark.parametrize(
    "B,Hq,Hkv,Tq,Tk,D,causal,window,softcap", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, Hq, Hkv, Tq, Tk, D, causal, window,
                                     softcap, dtype):
    rng = np.random.default_rng(hash((B, Hq, Tq, Tk)) % 2**31)
    q = rng.standard_normal((B, Hq, Tq, D)).astype(dtype)
    k = rng.standard_normal((B, Hkv, Tk, D)).astype(dtype)
    v = rng.standard_normal((B, Hkv, Tk, D)).astype(dtype)
    got = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                          causal=causal, window=window, softcap=softcap,
                          block_q=32, block_k=32)
    want = ref.flash_attention_ref(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), causal=causal,
        window=window, softcap=softcap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_rejects_bad_gqa():
    q = jnp.zeros((1, 3, 8, 4))
    k = v = jnp.zeros((1, 2, 8, 4))
    with pytest.raises(ValueError):
        flash_attention(q, k, v)


# --------------------------------------------------------------------- #
# ssd scan
# --------------------------------------------------------------------- #
SSD_CASES = [
    # B, T, H, P, S, chunk
    (1, 32, 2, 8, 4, 8),
    (2, 64, 1, 16, 8, 16),
    (1, 100, 2, 8, 4, 32),   # non-divisible T
    (1, 16, 3, 4, 16, 16),   # chunk == T
]


@pytest.mark.parametrize("B,T,H,P,S,chunk", SSD_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_ssd_scan_matches_ref(B, T, H, P, S, chunk, dtype):
    rng = np.random.default_rng(T + P)
    x = rng.standard_normal((B, T, H, P)).astype(dtype)
    a = (-np.abs(rng.standard_normal((B, T, H))) * 0.1).astype(dtype)
    b = rng.standard_normal((B, T, H, S)).astype(dtype) * 0.5
    c = rng.standard_normal((B, T, H, S)).astype(dtype) * 0.5
    got = ssd_scan(jnp.asarray(x), jnp.asarray(a), jnp.asarray(b),
                   jnp.asarray(c), chunk=chunk)
    want = ref.ssd_scan_ref(jnp.asarray(x), jnp.asarray(a), jnp.asarray(b),
                            jnp.asarray(c))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_ssd_scan_chunk_invariance():
    """The chunked evaluation must not depend on the chunk size."""
    rng = np.random.default_rng(5)
    x = rng.standard_normal((1, 64, 1, 8)).astype(np.float32)
    a = (-np.abs(rng.standard_normal((1, 64, 1))) * 0.2).astype(np.float32)
    b = rng.standard_normal((1, 64, 1, 4)).astype(np.float32)
    c = rng.standard_normal((1, 64, 1, 4)).astype(np.float32)
    outs = [np.asarray(ssd_scan(jnp.asarray(x), jnp.asarray(a),
                                jnp.asarray(b), jnp.asarray(c), chunk=ch))
            for ch in (8, 16, 64)]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=2e-5, atol=2e-5)
