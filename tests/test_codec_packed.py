"""Packed low-precision codec lane (DESIGN.md §12).

Three property families:

* **pack/unpack round-trip** — the word packing is a pure bit
  transport: every 16-bit pattern (NaNs with payloads, denormals,
  -0.0, odd trailing lanes) survives ``pack_payload`` →
  ``unpack_payload`` and a full XOR encode/decode exactly. Numpy and
  jnp packers must agree byte-for-byte (the engine oracle and the SPMD
  lane share one wire format).
* **cross-lane bit-identity** — bf16/f16 payloads produce the SAME
  wire words and decoded chunks on all three codec lanes (multipass
  oracle / fused jnp / fused Pallas-interpret u16 kernels), including
  programs pulled through the survivor-set (degraded) re-lowering.
* **full-shuffle parity** — a packed-lane SPMD shuffle equals the
  numpy engine bitwise per device (subprocess mesh), the same contract
  the f32 lane pins in tests/test_collective.py.

Property bodies are plain helpers: hypothesis fuzzes them when the
optional extra is installed (CI does), and a deterministic parametrized
sweep runs them everywhere.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

import jax.numpy as jnp
import ml_dtypes

from repro.core.collective import (_decode_stage, _encode_stage,
                                   _from_wire, _wire_buffer)
from repro.core.schedule import (ScheduleCache, pack_payload,
                                 payload_words, unpack_payload)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # optional test extra (pyproject.toml)
    HAVE_HYPOTHESIS = False

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_CACHE = ScheduleCache()  # private: don't pollute the global cache stats

#: adversarial 16-bit patterns: quiet/signalling NaNs with payloads,
#: +/-inf, +/-0, denormals (min and max), min/max normals
_SPECIAL_U16 = [0x7FC0, 0xFFC0, 0x7F81, 0x7F80, 0xFF80, 0x0000, 0x8000,
                0x0001, 0x8001, 0x007F, 0x0080, 0x7F7F, 0xFF7F]


def _np16(dtype: str) -> np.dtype:
    return np.dtype(ml_dtypes.bfloat16 if dtype == "bfloat16"
                    else np.float16)


def _bits16(rng, shape) -> np.ndarray:
    """Random u16 patterns with adversarial specials sprinkled in."""
    bits = rng.integers(0, 2**16, size=shape, dtype=np.uint16)
    sel = rng.random(shape) < 0.2
    bits[sel] = rng.choice(np.asarray(_SPECIAL_U16, np.uint16),
                           size=int(sel.sum()))
    return bits


# --------------------------------------------------------------------- #
# pack/unpack round-trip
# --------------------------------------------------------------------- #
def check_pack_roundtrip(d: int, k: int, bits, dtype: str) -> None:
    """Any bit pattern (NaN payloads, denormals, -0.0) survives the
    word packing exactly, for every d incl. odd trailing lanes."""
    dt = _np16(dtype)
    rng = np.random.default_rng(len(bits) + d)
    pat = np.asarray(bits, np.uint16)
    x = rng.choice(pat, size=(3, d)).astype(np.uint16).view(dt)
    w = pack_payload(x, k)
    wp = payload_words(d, 2, k)
    assert w.shape == (3, wp) and w.dtype == np.uint32
    assert wp % (k - 1) == 0                      # packets split evenly
    back = unpack_payload(w, dt, d)
    np.testing.assert_array_equal(back.view(np.uint16), x.view(np.uint16))
    # pad lanes are deterministic zeros (wire bytes are reproducible)
    lanes = np.ascontiguousarray(w).view(np.uint16)
    assert (lanes[:, d:] == 0).all()
    # the jnp packer produces the same wire words byte-for-byte
    jw = np.asarray(_wire_buffer(jnp.asarray(x), wp=wp, codec="multipass",
                                 use_kernels=False))
    np.testing.assert_array_equal(jw, w)
    # ...and the jnp unpacker restores the same bits
    jback = np.asarray(_from_wire(jnp.asarray(w), jnp.dtype(dtype), d))
    np.testing.assert_array_equal(jback.view(np.uint16), x.view(np.uint16))


@pytest.mark.parametrize("dtype", ["bfloat16", "float16"])
@pytest.mark.parametrize("d,k", [(1, 3), (4, 3), (6, 3), (9, 4), (17, 5),
                                 (5, 4)])
def test_pack_roundtrip_cases(d, k, dtype):
    check_pack_roundtrip(d, k, _SPECIAL_U16, dtype)


def test_payload_words_lanes():
    # 32-bit lane: identity on already-divisible widths
    assert payload_words(8, 4, 3) == 8
    assert payload_words(9, 4, 4) == 9
    # 16-bit lane: half the words, padded to a packet multiple
    assert payload_words(8, 4, 3) == 2 * payload_words(8, 2, 3)
    assert payload_words(6, 2, 3) == 4      # ceil(6/2)=3 -> pad to 4
    assert payload_words(9, 2, 4) == 6      # odd d: ceil(9/2)=5 -> 6
    with pytest.raises(ValueError):
        payload_words(8, 1, 3)
    with pytest.raises(TypeError):
        pack_payload(np.zeros((2, 4), np.float32), 3)
    with pytest.raises(TypeError):
        unpack_payload(np.zeros((2, 4), np.uint16), np.float16, 4)


# --------------------------------------------------------------------- #
# cross-lane bit-identity (the packed mirror of test_codec_fused)
# --------------------------------------------------------------------- #
def _lane_outputs(program, stage, x16, me, k, pk, seed):
    """Encode+decode one stage under every packed codec lane."""
    T = program.stage_tables(stage)
    rng = np.random.default_rng(seed)
    recv = jnp.asarray(rng.integers(0, 2**32, size=(T.n, k - 1, pk),
                                    dtype=np.uint32))
    wp = pk * (k - 1)
    outs = []
    # (codec, use_kernels): u16 Pallas kernels run in interpret mode on
    # CPU via _resolve_interpret — the same lanes the f32 tests pin
    for codec, uk in (("multipass", False), ("fused", False),
                      ("fused", True)):
        wire = _wire_buffer(x16, wp=wp, codec=codec, use_kernels=uk)
        ctx, delta = _encode_stage(wire, T, me, k=k, pk=pk, codec=codec,
                                   use_kernels=uk)
        chunk = _decode_stage(recv, ctx, T, me, k=k, pk=pk, codec=codec,
                              use_kernels=uk)
        outs.append((codec, uk, np.asarray(delta), np.asarray(chunk)))
    return outs


def check_packed_codec_bit_identical(q, k, d, seed, degraded,
                                     dtype) -> None:
    """Wire deltas and decoded chunks agree bit-for-bit across the
    multipass / fused-jnp / fused-u16-kernel lanes for 16-bit payloads
    of arbitrary bit patterns (incl. NaN/denormal), for every probed
    device, both stages, healthy AND survivor-set-lowered programs."""
    d += (-d) % (k - 1)                    # plan requires (k-1) | d
    K, J_own = q * k, q ** (k - 2)
    program = _CACHE.program(q, k, Q=K, d=d)
    if degraded:
        deg = _CACHE.degraded(program, {0})
        assert deg.base.s1 is program.s1 and deg.base.s2 is program.s2
        program = deg.base
    rng = np.random.default_rng(seed)
    bits = _bits16(rng, (J_own, k - 1, K, d))
    x16 = jnp.asarray(bits.view(_np16(dtype)))
    pk = payload_words(d, 2, k) // (k - 1)
    for stage in (1, 2):
        for me in {0, K - 1}:
            ref = None
            for codec, uk, delta, chunk in _lane_outputs(
                    program, stage, x16, me, k, pk, seed):
                if ref is None:
                    ref = (delta, chunk)
                    continue
                np.testing.assert_array_equal(
                    delta, ref[0],
                    err_msg=f"delta {codec}/uk={uk} s={me} stage={stage}")
                np.testing.assert_array_equal(
                    chunk, ref[1],
                    err_msg=f"chunk {codec}/uk={uk} s={me} stage={stage}")


@pytest.mark.parametrize("q,k,d,degraded,dtype", [
    (2, 3, 2, False, "bfloat16"),
    (2, 3, 6, True, "bfloat16"),      # word pad (w=3 -> wp=4)
    (2, 4, 9, False, "float16"),      # odd trailing lane
    (3, 3, 4, True, "float16"),
])
def test_packed_codec_bit_identical_cases(q, k, d, degraded, dtype):
    check_packed_codec_bit_identical(q, k, d, seed=q * 100 + d,
                                     degraded=degraded, dtype=dtype)


def check_packed_wire_mirrors_numpy(q, d, seed) -> None:
    """The jnp wire buffer equals the numpy ``pack_payload`` mirror,
    and unpacking restores the exact source bits — the XOR transport
    does no arithmetic on the packed lane."""
    k = 3
    K, J_own = q * k, q ** (k - 2)
    rng = np.random.default_rng(seed)
    bits = _bits16(rng, (J_own, k - 1, K, d))
    x16 = jnp.asarray(bits.view(ml_dtypes.bfloat16))
    wp = payload_words(d, 2, k)
    wire = _wire_buffer(x16, wp=wp, codec="multipass", use_kernels=False)
    np.testing.assert_array_equal(
        np.asarray(wire), pack_payload(np.asarray(x16), k))
    flat = np.asarray(wire).reshape(-1, wp)
    back = unpack_payload(flat, ml_dtypes.bfloat16, d)
    np.testing.assert_array_equal(
        back.reshape(bits.shape).view(np.uint16), bits)


@pytest.mark.parametrize("q,d,seed", [(2, 4, 0), (3, 6, 1), (2, 2, 2)])
def test_packed_wire_mirrors_numpy_cases(q, d, seed):
    check_packed_wire_mirrors_numpy(q, d, seed)


# --------------------------------------------------------------------- #
# hypothesis fuzz lanes over the same properties (CI installs the extra)
# --------------------------------------------------------------------- #
if HAVE_HYPOTHESIS:
    _u16 = st.one_of(st.sampled_from(_SPECIAL_U16),
                     st.integers(0, 0xFFFF))

    @given(st.integers(1, 17), st.integers(3, 5),
           st.lists(_u16, min_size=1, max_size=64),
           st.sampled_from(["bfloat16", "float16"]))
    @settings(max_examples=60, deadline=None)
    def test_pack_roundtrip_hypothesis(d, k, bits, dtype):
        check_pack_roundtrip(d, k, bits, dtype)

    @given(st.integers(2, 3), st.integers(3, 4),
           st.sampled_from([2, 3, 9]), st.integers(0, 10**6),
           st.booleans(), st.sampled_from(["bfloat16", "float16"]))
    @settings(max_examples=10, deadline=None)
    def test_packed_codec_bit_identical_hypothesis(q, k, d, seed,
                                                   degraded, dtype):
        check_packed_codec_bit_identical(q, k, d, seed, degraded, dtype)

    @given(st.integers(2, 3), st.sampled_from([2, 4, 6]),
           st.integers(0, 10**5))
    @settings(max_examples=8, deadline=None)
    def test_packed_wire_mirrors_numpy_hypothesis(q, d, seed):
        check_packed_wire_mirrors_numpy(q, d, seed)


# --------------------------------------------------------------------- #
# full-shuffle parity vs the engine (subprocess mesh)
# --------------------------------------------------------------------- #
_RUN_PACKED = """
import numpy as np, jax, jax.numpy as jnp, ml_dtypes
from jax.sharding import PartitionSpec as P
from repro.compat import make_mesh, shard_map
from repro.core.collective import (camr_shuffle, make_plan,
                                   scatter_contributions)
from repro.core.engine import CAMRConfig, CAMREngine

q, k, d = {q}, {k}, {d}
K = q * k
plan = make_plan(q, k, d)
rng = np.random.default_rng({seed})
bg = rng.standard_normal((plan.J, k, K, d)).astype(
    np.float32).astype(ml_dtypes.bfloat16)
contribs = scatter_contributions(plan, bg)
mesh = make_mesh((K,), ('camr',))

eng = CAMREngine(CAMRConfig(q=q, k=k, gamma=1), lambda job, sf: sf)
res = eng.run([[bg[j, t] for t in range(k)] for j in range(plan.J)])
want = np.empty((K, plan.J, d), ml_dtypes.bfloat16)
for s in range(K):
    for j in range(plan.J):
        want[s, j] = res[s][(j, s)]

for codec, uk in (('fused', True), ('fused', False),
                  ('multipass', False)):
    def body(c, codec=codec, uk=uk):
        return camr_shuffle(plan, c[0], axis_name='camr', codec=codec,
                            use_kernels=uk)[None]
    f = jax.jit(shard_map(body, mesh=mesh, in_specs=P('camr'),
                          out_specs=P('camr')))
    got = np.asarray(f(jnp.asarray(contribs)))
    assert got.dtype == ml_dtypes.bfloat16, got.dtype
    np.testing.assert_array_equal(got.view(np.uint16),
                                  want.view(np.uint16),
                                  err_msg=f'{{codec}}/uk={{uk}}')
print('OK')
"""


def _run_subprocess(code: str, ndev: int) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


@pytest.mark.parametrize("q,k,d", [(2, 3, 8), (2, 3, 6), (2, 4, 9)])
def test_packed_shuffle_matches_engine_bitwise(q, k, d):
    """bf16 SPMD shuffle == numpy engine, BITWISE, per device — even
    widths, widths needing word pad (d=6, k=3) and odd trailing lanes
    (d=9), on all three codec lanes."""
    out = _run_subprocess(_RUN_PACKED.format(q=q, k=k, d=d, seed=q * 10 + d),
                          ndev=q * k)
    assert "OK" in out
