"""Analytic loads & job requirements — paper §IV, §V, Tables I-III."""

import math

import numpy as np
import pytest

from repro.core import loads


@pytest.mark.parametrize("q,k", [(2, 3), (3, 3), (2, 4), (4, 3), (5, 4),
                                 (2, 18), (9, 4)])
def test_stage_loads_sum_to_total(q, k):
    assert sum(loads.camr_stage_loads(q, k)) == pytest.approx(
        loads.camr_load(q, k))


@pytest.mark.parametrize("q,k", [(2, 3), (3, 3), (2, 4), (4, 3), (5, 4),
                                 (2, 18), (9, 4), (50, 2), (2, 50)])
def test_camr_equals_ccdc_at_same_mu(q, k):
    """§V: L_CAMR == L_CCDC for mu = (k-1)/K."""
    K = k * q
    mu = loads.storage_fraction(q, k)
    assert loads.camr_load(q, k) == pytest.approx(loads.ccdc_load(mu, K))


def test_table3_job_requirements():
    """Table III: K = 100 servers."""
    rows = [
        # (q, k, J_CAMR, J_CCDC)  with mu*K = k-1
        (50, 2, 50, 4950),
        (25, 4, 15625, 3921225),
        (20, 5, 160000, 75287520),
    ]
    for q, k, j_camr, j_ccdc in rows:
        assert k * q == 100
        assert loads.camr_min_jobs(q, k) == j_camr
        mu = (k - 1) / 100
        assert loads.ccdc_min_jobs(mu, 100) == j_ccdc


@pytest.mark.parametrize("q,k", [(2, 3), (3, 3), (4, 3), (2, 4), (25, 4),
                                 (20, 5), (4, 8)])
def test_job_requirement_bound(q, k):
    """§V: J_CCDC = C(kq, k) >= q^k > q^{k-1} = J_CAMR."""
    K = k * q
    mu = (k - 1) / K
    assert loads.ccdc_min_jobs(mu, K) >= q ** k > loads.camr_min_jobs(q, k)


def test_example1_ccdc_comparison():
    """§III-C: for K=6, mu=1/3 CCDC needs J = C(6,3) = 20 jobs, CAMR 4."""
    assert loads.ccdc_min_jobs(1 / 3, 6) == 20
    assert loads.camr_min_jobs(2, 3) == 4
    assert loads.ccdc_load(1 / 3, 6) == pytest.approx(1.0)
    assert loads.camr_load(2, 3) == pytest.approx(1.0)


def test_load_decreases_with_storage():
    """More redundancy (larger k at fixed K) -> lower load."""
    # K = 64: factorizations (q, k)
    combos = [(32, 2), (16, 4), (8, 8), (4, 16), (2, 32)]
    ls = [loads.camr_load(q, k) for q, k in combos]
    assert all(a > b for a, b in zip(ls, ls[1:]))


def test_uncoded_baselines_dominate_camr():
    for q, k in [(2, 3), (3, 3), (4, 4), (8, 4)]:
        assert loads.camr_load(q, k) < loads.uncoded_aggregated_load(q, k)


def test_cdc_load_context():
    # CDC without aggregation at r=2, K=6: (1/2)(1-1/3) = 1/3 per its own
    # normalization (per-subfile values, N times more of them)
    assert loads.cdc_load(2, 6) == pytest.approx(1 / 3)
    with pytest.raises(ValueError):
        loads.cdc_load(0, 6)


def test_ccdc_invalid_mu():
    with pytest.raises(ValueError):
        loads.ccdc_load(0.17, 6)  # mu*K not integer

# --------------------------------------------------------------------- #
# two-level (hosts x devices-per-host) cost model — DESIGN.md §16
# --------------------------------------------------------------------- #
HIER = [(2, 4), (3, 4), (2, 6), (3, 6), (2, 8), (4, 4)]


def _divisors(k):
    return [h for h in range(1, k + 1) if k % h == 0]


@pytest.mark.parametrize("q,k", HIER)
def test_hierarchical_flat_reduction_identity(q, k):
    """Pinned identities: hosts=1 (any alpha) and alpha=1 (any hosts)
    reduce camr_load_hierarchical to camr_load_p2p EXACTLY — the flat
    topology is the identity case, not an approximation."""
    p2p = loads.camr_load_p2p(q, k)
    for alpha in (1.0, 2.0, 4.0, 17.5):
        assert loads.camr_load_hierarchical(q, k, 1, alpha) == p2p
    for hosts in _divisors(k):
        assert loads.camr_load_hierarchical(q, k, hosts, 1.0) == \
            pytest.approx(p2p, rel=1e-12)
    unc = loads.uncoded_aggregated_load(q, k)
    for alpha in (1.0, 3.0, 9.0):
        assert loads.uncoded_load_hierarchical(q, k, 1, alpha) == unc
    for hosts in _divisors(k):
        assert loads.uncoded_load_hierarchical(q, k, hosts, 1.0) == \
            pytest.approx(unc, rel=1e-12)


@pytest.mark.parametrize("q,k", HIER)
def test_hierarchical_monotone_in_alpha(q, k):
    """Strictly increasing in alpha whenever hosts >= 2 (slope is the
    positive inter-host load), constant for hosts = 1."""
    alphas = [1.0, 1.5, 2.0, 4.0, 8.0]
    for hosts in _divisors(k):
        vals = [loads.camr_load_hierarchical(q, k, hosts, a)
                for a in alphas]
        uvals = [loads.uncoded_load_hierarchical(q, k, hosts, a)
                 for a in alphas]
        if hosts == 1:
            assert len(set(vals)) == 1 and len(set(uvals)) == 1
        else:
            assert all(a < b for a, b in zip(vals, vals[1:]))
            assert all(a < b for a, b in zip(uvals, uvals[1:]))


@pytest.mark.parametrize("q,k", HIER)
def test_edge_loads_totals_and_cut(q, k):
    """Both schedules move the same p2p total; the two-level schedule
    cuts inter-host load by exactly hosts/k — strict when hosts < k."""
    p2p = loads.camr_load_p2p(q, k)
    for hosts in _divisors(k):
        f_intra, f_inter = loads.camr_edge_loads(q, k, hosts, "flat")
        t_intra, t_inter = loads.camr_edge_loads(q, k, hosts,
                                                 schedule="two_level")
        assert f_intra + f_inter == pytest.approx(p2p, rel=1e-12)
        assert t_intra + t_inter == pytest.approx(p2p, rel=1e-12)
        assert t_inter * k == pytest.approx(f_inter * hosts, rel=1e-12)
        if 1 < hosts < k:
            assert t_inter < f_inter
        if hosts == 1:
            assert f_inter == t_inter == 0.0
        if hosts == k:  # one class per host: no dedup possible
            assert t_inter == pytest.approx(f_inter, rel=1e-12)
    # coded two-level never loses to the uncoded plan on the slow edge
    # (strictly better with >= 2 classes per host; ties at hosts = k
    # where both degenerate to one packet-equivalent per remote host)
    for hosts in [h for h in _divisors(k) if h >= 2]:
        _, t_inter = loads.camr_edge_loads(q, k, hosts)
        uncoded_inter = hosts / k
        if hosts < k:
            assert t_inter < uncoded_inter
        else:
            assert t_inter == pytest.approx(uncoded_inter, rel=1e-12)


def test_hierarchical_validation():
    with pytest.raises(ValueError):
        loads.camr_edge_loads(2, 4, hosts=3)      # 3 does not divide 4
    with pytest.raises(ValueError):
        loads.camr_edge_loads(2, 4, 2, schedule="mesh")
    with pytest.raises(ValueError):
        loads.camr_load_hierarchical(2, 4, hosts=0)
    with pytest.raises(ValueError):
        loads.uncoded_load_hierarchical(2, 6, hosts=4)
