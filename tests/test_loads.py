"""Analytic loads & job requirements — paper §IV, §V, Tables I-III."""

import math

import numpy as np
import pytest

from repro.core import loads


@pytest.mark.parametrize("q,k", [(2, 3), (3, 3), (2, 4), (4, 3), (5, 4),
                                 (2, 18), (9, 4)])
def test_stage_loads_sum_to_total(q, k):
    assert sum(loads.camr_stage_loads(q, k)) == pytest.approx(
        loads.camr_load(q, k))


@pytest.mark.parametrize("q,k", [(2, 3), (3, 3), (2, 4), (4, 3), (5, 4),
                                 (2, 18), (9, 4), (50, 2), (2, 50)])
def test_camr_equals_ccdc_at_same_mu(q, k):
    """§V: L_CAMR == L_CCDC for mu = (k-1)/K."""
    K = k * q
    mu = loads.storage_fraction(q, k)
    assert loads.camr_load(q, k) == pytest.approx(loads.ccdc_load(mu, K))


def test_table3_job_requirements():
    """Table III: K = 100 servers."""
    rows = [
        # (q, k, J_CAMR, J_CCDC)  with mu*K = k-1
        (50, 2, 50, 4950),
        (25, 4, 15625, 3921225),
        (20, 5, 160000, 75287520),
    ]
    for q, k, j_camr, j_ccdc in rows:
        assert k * q == 100
        assert loads.camr_min_jobs(q, k) == j_camr
        mu = (k - 1) / 100
        assert loads.ccdc_min_jobs(mu, 100) == j_ccdc


@pytest.mark.parametrize("q,k", [(2, 3), (3, 3), (4, 3), (2, 4), (25, 4),
                                 (20, 5), (4, 8)])
def test_job_requirement_bound(q, k):
    """§V: J_CCDC = C(kq, k) >= q^k > q^{k-1} = J_CAMR."""
    K = k * q
    mu = (k - 1) / K
    assert loads.ccdc_min_jobs(mu, K) >= q ** k > loads.camr_min_jobs(q, k)


def test_example1_ccdc_comparison():
    """§III-C: for K=6, mu=1/3 CCDC needs J = C(6,3) = 20 jobs, CAMR 4."""
    assert loads.ccdc_min_jobs(1 / 3, 6) == 20
    assert loads.camr_min_jobs(2, 3) == 4
    assert loads.ccdc_load(1 / 3, 6) == pytest.approx(1.0)
    assert loads.camr_load(2, 3) == pytest.approx(1.0)


def test_load_decreases_with_storage():
    """More redundancy (larger k at fixed K) -> lower load."""
    # K = 64: factorizations (q, k)
    combos = [(32, 2), (16, 4), (8, 8), (4, 16), (2, 32)]
    ls = [loads.camr_load(q, k) for q, k in combos]
    assert all(a > b for a, b in zip(ls, ls[1:]))


def test_uncoded_baselines_dominate_camr():
    for q, k in [(2, 3), (3, 3), (4, 4), (8, 4)]:
        assert loads.camr_load(q, k) < loads.uncoded_aggregated_load(q, k)


def test_cdc_load_context():
    # CDC without aggregation at r=2, K=6: (1/2)(1-1/3) = 1/3 per its own
    # normalization (per-subfile values, N times more of them)
    assert loads.cdc_load(2, 6) == pytest.approx(1 / 3)
    with pytest.raises(ValueError):
        loads.cdc_load(0, 6)


def test_ccdc_invalid_mu():
    with pytest.raises(ValueError):
        loads.ccdc_load(0.17, 6)  # mu*K not integer
