"""Executable baselines: uncoded-aggregated and CCDC — paper §V."""

import numpy as np
import pytest

from repro.core import loads
from repro.core.baselines import CCDCEngine, UncodedAggregatedEngine


def _linear_map(Q):
    def map_fn(job, sf):
        return np.outer(np.arange(1, Q + 1, dtype=np.float64), sf)
    return map_fn


@pytest.mark.parametrize("q,k,gamma", [(2, 3, 1), (2, 3, 2), (3, 3, 1),
                                       (2, 4, 1), (4, 3, 1)])
def test_uncoded_aggregated(q, k, gamma):
    eng = UncodedAggregatedEngine(q, k, gamma, _linear_map(q * k))
    rng = np.random.default_rng(0)
    ds = [[rng.standard_normal(4) for _ in range(eng.cfg.N)]
          for _ in range(eng.cfg.J)]
    results = eng.run(ds)
    # correctness vs oracle
    for j in range(eng.design.J):
        vals = [np.asarray(eng.map_fn(j, sf)) for sf in ds[j]]
        total = sum(vals[1:], vals[0])
        for s in range(eng.cfg.K):
            np.testing.assert_allclose(results[s][(j, s)], total[s],
                                       rtol=1e-9)
    assert eng.measured_load() == pytest.approx(
        loads.uncoded_aggregated_load(q, k))


@pytest.mark.parametrize("K,r", [(4, 1), (4, 2), (5, 2), (6, 2), (6, 3)])
def test_ccdc_engine(K, r):
    """CCDC coded exchange: correct decode + load == (1-mu)(r+1)/r."""
    def map_fn(job, part):
        return np.outer(np.arange(1, r + 2, dtype=np.float64), part)

    eng = CCDCEngine(K, r, map_fn)
    rng = np.random.default_rng(1)
    dim = 4 * max(1, r)  # divisible by r (packet count) -> no padding
    ds = [[rng.standard_normal(dim) for _ in range(r + 1)]
          for _ in range(eng.J)]
    results = eng.run(ds)
    eng.verify(ds, results)
    # each group ships (r+1) * B/r bits for (r+1) member functions:
    # member-exchange load = 1/r (full-system formula compared analytically
    # in test_loads.py::test_camr_equals_ccdc_at_same_mu)
    assert eng.measured_load() == pytest.approx(1 / r, rel=1e-9)


def test_ccdc_job_count():
    eng = CCDCEngine(6, 2, lambda j, p: np.zeros((3, 2)))
    assert eng.J == loads.ccdc_min_jobs(2 / 6, 6) == 20
