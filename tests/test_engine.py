"""End-to-end CAMR engine — Examples 1-5, load formulas, correctness."""

import numpy as np
import pytest

from repro.core import loads
from repro.core.engine import CAMRConfig, CAMREngine, run_wordcount_example


def _linear_map(Q):
    def map_fn(job, sf):
        return np.outer(np.arange(1, Q + 1, dtype=np.float64) + job, sf)
    return map_fn


def _make_datasets(cfg, dim=6, seed=0):
    rng = np.random.default_rng(seed)
    return [[rng.standard_normal(dim) for _ in range(cfg.N)]
            for _ in range(cfg.J)]


def test_example1_wordcount_loads():
    """Paper Examples 1-5: K=6, q=2, k=3, N=6 -> L = 1/4 + 1/4 + 1/2 = 1."""
    eng, results, L = run_wordcount_example(q=2, k=3, gamma=2)
    assert L["L_stage1_bus"] == pytest.approx(0.25)
    assert L["L_stage2_bus"] == pytest.approx(0.25)
    assert L["L_stage3_bus"] == pytest.approx(0.5)
    assert L["L_total_bus"] == pytest.approx(1.0)


def test_example1_transmission_counts():
    """Stage 2 of Example 4: 4 groups x 3 transmissions of B/2; stage 3 of
    Example 5: 6 servers x 2 missing jobs, uncoded B each."""
    eng, _, _ = run_wordcount_example(q=2, k=3, gamma=2)
    s2 = [t for t in eng.trace.transmissions if t.stage == 2]
    assert len(s2) == 4 * 3
    s3 = [t for t in eng.trace.transmissions if t.stage == 3]
    assert len(s3) == 6 * 2
    assert all(len(t.receivers) == 1 for t in s3)


@pytest.mark.parametrize("q,k,gamma", [
    (2, 3, 1), (2, 3, 2), (3, 3, 1), (2, 4, 3), (4, 3, 1), (3, 4, 1),
    (4, 2, 1), (2, 2, 2), (6, 2, 1), (2, 5, 1),
])
def test_correct_and_loads_match_formula(q, k, gamma):
    """Decode correctness + measured bytes == §IV formulas, all (q,k,gamma).

    Value dim is a multiple of k-1 so packets need no padding (the paper's
    divisibility assumption)."""
    cfg = CAMRConfig(q=q, k=k, gamma=gamma)
    dim = 2 * max(1, k - 1)
    ds = _make_datasets(cfg, dim=dim)
    eng = CAMREngine(cfg, _linear_map(cfg.num_functions()))
    results = eng.run(ds)
    eng.verify(ds, results)
    L = eng.measured_loads()
    l1, l2, l3 = loads.camr_stage_loads(q, k)
    assert L["L_stage1_bus"] == pytest.approx(l1)
    assert L["L_stage2_bus"] == pytest.approx(l2)
    assert L["L_stage3_bus"] == pytest.approx(l3)
    assert L["L_total_bus"] == pytest.approx(loads.camr_load(q, k))
    # p2p model: stages 1-2 cost (k-1)x their bus load
    assert L["L_total_p2p"] == pytest.approx(loads.camr_load_p2p(q, k))


def test_gamma_invariance():
    """gamma scales subfile granularity but never the load (DESIGN.md §8)."""
    got = []
    for gamma in (1, 2, 5):
        cfg = CAMRConfig(q=3, k=3, gamma=gamma)
        ds = _make_datasets(cfg, dim=4)
        eng = CAMREngine(cfg, _linear_map(cfg.num_functions()))
        eng.verify(ds, eng.run(ds))
        got.append(eng.measured_loads()["L_total_bus"])
    assert len(set(got)) == 1


def test_q_multiple_of_K():
    """Q = 2K: shuffle repeats per function group (paper §II)."""
    cfg = CAMRConfig(q=2, k=3, gamma=1, Q=12)
    ds = _make_datasets(cfg, dim=4)
    eng = CAMREngine(cfg, _linear_map(12))
    results = eng.run(ds)
    eng.verify(ds, results)
    # load is normalized by J*Q*B, so it still matches the formula
    assert eng.measured_loads()["L_total_bus"] == pytest.approx(
        loads.camr_load(2, 3))
    # every server reduced exactly Q/K = 2 functions per job
    for s, res in enumerate(results):
        assert len(res) == 2 * cfg.J
        assert {qf % cfg.K for (_, qf) in res} == {s}


def test_label_perm_invariance_of_load_and_result():
    cfg = CAMRConfig(q=2, k=3, gamma=2)
    ds = _make_datasets(cfg, dim=4)
    perms = [(2, 0, 1)] * cfg.J
    eng = CAMREngine(cfg, _linear_map(cfg.num_functions()), label_perm=perms)
    eng.verify(ds, eng.run(ds))
    assert eng.measured_loads()["L_total_bus"] == pytest.approx(1.0)


def test_nonlinear_aggregation_max():
    """Aggregation only needs associativity+commutativity (Def. 1): max."""
    cfg = CAMRConfig(q=2, k=3, gamma=1)
    ds = _make_datasets(cfg, dim=4, seed=3)
    eng = CAMREngine(cfg, _linear_map(cfg.num_functions()),
                     combine=np.maximum)
    eng.verify(ds, eng.run(ds))


def test_map_work_matches_storage():
    """Each server maps exactly mu*J*N subfiles (computation load)."""
    cfg = CAMRConfig(q=2, k=3, gamma=2)
    ds = _make_datasets(cfg, dim=4)
    eng = CAMREngine(cfg, _linear_map(cfg.num_functions()))
    eng.run(ds)
    mu = (cfg.k - 1) / cfg.K
    for st in eng.servers:
        assert st.map_invocations == mu * cfg.J * cfg.N


def test_int_payloads_bitexact():
    cfg = CAMRConfig(q=2, k=3, gamma=1)
    rng = np.random.default_rng(0)
    ds = [[rng.integers(0, 1000, size=4) for _ in range(cfg.N)]
          for _ in range(cfg.J)]

    def map_fn(job, sf):
        return np.tile(sf, (cfg.num_functions(), 1)).astype(np.int64)

    eng = CAMREngine(cfg, map_fn)
    results = eng.run(ds)
    oracle = eng.oracle(ds)
    for s, res in enumerate(results):
        for key, v in res.items():
            np.testing.assert_array_equal(v, oracle[key])
