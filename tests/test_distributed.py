"""Multi-host execution lane (DESIGN.md §16): a REAL two-process
``jax.distributed`` run over gloo CPU collectives.

The parent spawns two worker processes (4 local devices each) that form
one 8-device global mesh through :func:`repro.launch.mesh.make_camr_mesh`
and run the CAMR shuffle — flat and two-level — as jitted shard_map over
a globally-sharded array. Every addressable shard must be BITWISE equal
to the single-process engine oracle. Skips cleanly (never fails) when
this jax build cannot initialize the distributed runtime; a value
mismatch is a hard failure.
"""

import os
import socket
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_WORKER = textwrap.dedent("""
    import sys
    import numpy as np
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.compat import shard_map
    from repro.core.collective import (make_plan, camr_shuffle,
        scatter_contributions)
    from repro.core.engine import CAMRConfig, CAMREngine
    from repro.core.schedule import SCHEDULE_CACHE
    from repro.launch.mesh import (detect_topology, init_distributed,
                                   make_camr_mesh)

    q, k, d = {q}, {k}, {d}
    pid = int(sys.argv[1])
    if not init_distributed(coordinator='localhost:{port}',
                            num_processes=2, process_id=pid):
        print('SKIP: jax.distributed init unavailable')
        sys.exit(0)

    K = q * k
    assert jax.process_count() == 2
    assert jax.device_count() == K, jax.device_count()
    assert len(jax.local_devices()) == K // 2

    topo = detect_topology(k)
    assert topo.key() == (2, 4.0), topo
    plan_f = make_plan(q, k, d)
    plan_t = make_plan(q, k, d, topology=topo)
    mesh = make_camr_mesh(K)

    # identical on both processes: same seed -> same global input
    rng = np.random.default_rng(7)
    bg = rng.standard_normal((plan_f.J, k, K, d)).astype(np.float32)
    contribs = scatter_contributions(plan_f, bg)
    sharding = NamedSharding(mesh, P('camr'))
    garr = jax.make_array_from_callback(
        contribs.shape, sharding, lambda idx: contribs[idx])

    # BITWISE oracle: the serial numpy engine's canonical combine order
    # (camr_shuffle_reference's np.sum uses a different reduction tree
    # and is only an allclose oracle — DESIGN.md §11)
    eng = CAMREngine(CAMRConfig(q=q, k=k, gamma=1), lambda job, sf: sf)
    datasets = [[bg[j, t] for t in range(k)] for j in range(plan_f.J)]
    results = eng.run(datasets)
    for plan, tag in ((plan_f, 'flat'), (plan_t, 'two_level')):
        fn = jax.jit(shard_map(
            lambda c: camr_shuffle(plan, c[0], axis_name='camr')[None],
            mesh=mesh, in_specs=P('camr'), out_specs=P('camr')))
        out = jax.block_until_ready(fn(garr))
        for shard in out.addressable_shards:
            s = shard.index[0].start
            got = np.asarray(shard.data)[0]
            for j in range(plan_f.J):
                np.testing.assert_array_equal(
                    got[j], results[s][(j, s)],
                    err_msg=f'{{tag}} device {{s}} job {{j}} '
                            f'process {{pid}}')

    # survivor-set re-lowering keyed to the DETECTED two-level topology
    # (what a mid-stream degrade on this cluster would pull)
    prog = SCHEDULE_CACHE.program(q, k, Q=K, d=d, topology=topo)
    deg = SCHEDULE_CACHE.degraded(prog, {{1}})
    assert deg.coded_rows and prog.topology is topo
    print('OK', pid)
""")


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("localhost", 0))
        return s.getsockname()[1]


@pytest.mark.parametrize("q,k", [(2, 4)])
def test_two_process_distributed_shuffle(q, k):
    port = _free_port()
    code = _WORKER.format(q=q, k=k, d=2 * (k - 1), port=port)
    dph = q * k // 2
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={dph}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    procs = [subprocess.Popen([sys.executable, "-c", code, str(pid)],
                              stdout=subprocess.PIPE,
                              stderr=subprocess.PIPE, text=True, env=env)
             for pid in range(2)]
    outs = []
    try:
        for p in procs:
            out, err = p.communicate(timeout=600)
            outs.append((p.returncode, out, err))
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
    if any("SKIP:" in out for _, out, _ in outs):
        pytest.skip("jax.distributed unavailable in this environment")
    for pid, (rc, out, err) in enumerate(outs):
        assert rc == 0, f"process {pid}:\n{err[-3000:]}"
        assert f"OK {pid}" in out, f"process {pid}:\n{out}\n{err[-2000:]}"
