"""Live elasticity under chaos (DESIGN.md §14).

The contract for every churn schedule — kills, rejoins, stragglers,
any wave boundary, even mid-flight: the elastic stream's output is
BITWISE identical to the healthy serial oracle, and with a warmed
schedule cache recovery never pays a lowering. The chaos harness
(tests/chaos.py) scripts deterministic FaultPlans; the sweep replays
them across configurations and both pipelining modes.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import chaos
from chaos import (ChaosController, FaultPlan, Kill, Rejoin, Straggle,
                   assert_bit_identical, run_plan, serial_oracle)
from repro.core.engine import CAMRConfig, CAMREngine
from repro.core.schedule import SCHEDULE_CACHE
from repro.runtime.fault import (DegradedCAMREngine, ElasticController,
                                 Membership, MembershipError,
                                 StragglerPolicy, retarget_engine)
from repro.runtime.jobstream import JobSpec, JobStream

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# detector policy for scripted Straggle events: only the synthetic
# delay (seconds) can trip the absolute timeout; real map times are
# microseconds and the huge rel_threshold keeps noise out
DETECT = StragglerPolicy(abs_timeout_s=1.0, rel_threshold=1e9,
                         patience=2, demote=True)

PLANS = [
    FaultPlan((), "healthy"),
    FaultPlan((Kill(0, 1),), "kill-first-wave"),
    FaultPlan((Kill(2, 4),), "kill-mid"),
    FaultPlan((Kill(2, 4), Rejoin(4, 4)), "kill-rejoin"),
    FaultPlan((Kill(1, 0), Rejoin(3, 0), Kill(4, 5)), "churn-twice"),
    FaultPlan((Straggle(1, 2, waves=3, delay_s=9.0),), "straggle"),
]
PLAN_BY_NAME = {p.name: p for p in PLANS}


def _run_sweep(q, k, plan, pipeline):
    specs = chaos.make_specs(q, k, waves=6, d=6)
    oracle = serial_oracle(specs)
    SCHEDULE_CACHE.warm_survivors(
        CAMREngine(specs[0].cfg, specs[0].map_fn).program)
    policy = (DETECT if any(isinstance(ev, Straggle)
                            for ev in plan.events) else None)
    for attempt in range(2):
        got, stream, ctrl = run_plan(specs, plan, policy=policy,
                                     pipeline=pipeline)
        ctx = f"q{q}k{k}:{plan.name}:pipeline={pipeline}:run{attempt}"
        assert_bit_identical(oracle, got, ctx)
        # warm-cache recovery: NO lowering on any run, first or repeat
        assert stream.last_report.cache_misses == 0, ctx
    return ctrl


@pytest.mark.parametrize("pipeline", [False, True])
@pytest.mark.parametrize("plan", ["kill-rejoin", "straggle"])
def test_chaos_quick(plan, pipeline):
    """CI-smoke subset of the sweep: one config, the two richest
    plans, both pipelining modes."""
    _run_sweep(2, 3, PLAN_BY_NAME[plan], pipeline)


@pytest.mark.slow
@pytest.mark.parametrize("q,k", [(2, 3), (3, 3), (2, 4)])
@pytest.mark.parametrize("pipeline", [False, True])
def test_chaos_sweep(q, k, pipeline):
    """Full sweep: every FaultPlan x config x pipelining mode is
    bit-identical to the healthy oracle with zero lowerings."""
    for plan in PLANS:
        _run_sweep(q, k, plan, pipeline)


# --------------------------------------------------------------------- #
# in-flight migration: membership changes AFTER a batch mapped
# --------------------------------------------------------------------- #
def test_in_flight_kill_retargets_without_remap():
    """A worker dies while a batch is between its map and its shuffle:
    the stream re-targets that engine against the new survivor set
    (one migration, zero map recompute) and the output stays
    bit-identical. The kill fires from inside the victim wave's map
    function — deterministically after its engine was built healthy."""
    q, k, waves, kill_wave, victim = 2, 3, 5, 2, 4
    specs = chaos.make_specs(q, k, waves, d=6)
    oracle = serial_oracle(specs)
    SCHEDULE_CACHE.warm_survivors(
        CAMREngine(specs[0].cfg, specs[0].map_fn).program)

    member = Membership(q, k, policy=StragglerPolicy(demote=False))
    ctrl = ElasticController(member)
    calls = [0]

    def killing_map(job, sf):
        calls[0] += 1
        with ctrl._lock:
            if member.state[victim] != Membership.DEAD:
                member.kill(victim)
        return sf

    sp = specs[kill_wave]
    specs[kill_wave] = JobSpec(sp.cfg, killing_map, sp.datasets,
                               name=sp.name)
    stream = JobStream(elastic=ctrl, wave_batch=1, pipeline=False)
    got = stream.run(specs)
    assert_bit_identical(oracle, got, "in-flight kill")

    rep = stream.last_report
    assert rep.migrations == 1
    assert ctrl.migrations == 1
    # the victim wave's engine flipped healthy -> degraded mid-flight;
    # every later wave was BUILT degraded (no further migrations)
    assert isinstance(stream.last_engines[kill_wave], DegradedCAMREngine)
    assert not getattr(stream.last_engines[kill_wave - 1], "failed", None)
    for w in range(kill_wave, waves):
        assert stream.last_engines[w].failed == {victim}
    # zero map recompute: the killing map ran once per (job, server
    # slot) for its wave, exactly like a healthy run of the same spec
    n_churn = calls[0]
    calls[0] = 0
    member2 = Membership(q, k)
    JobStream(elastic=ElasticController(member2), wave_batch=1,
              pipeline=False).run([specs[kill_wave]])
    assert n_churn == calls[0]


def test_retarget_engine_adopts_map_state():
    cfg = CAMRConfig(q=2, k=3, gamma=1)
    rng = np.random.default_rng(1)
    Q = cfg.num_functions()
    ds = [[rng.standard_normal((Q, 4)) for _ in range(cfg.N)]
          for _ in range(cfg.J)]
    healthy = CAMREngine(cfg, chaos._identity_map).run(ds)

    eng = CAMREngine(cfg, chaos._identity_map)
    eng.map_phase(ds)
    assert retarget_engine(eng, set()) is eng       # no-op fast path
    deg = retarget_engine(eng, {3})
    assert isinstance(deg, DegradedCAMREngine)
    assert deg.servers is eng.servers               # adopted, not remapped
    assert deg.map_times is eng.map_times
    deg.shuffle_phase()
    res = JobStream._logical_slots(deg, deg.reduce_phase())
    for s in range(cfg.K):
        assert res[s].keys() == healthy[s].keys()
        for key in healthy[s]:
            np.testing.assert_array_equal(res[s][key], healthy[s][key])
    # ...and back: restoring the survivor set re-adopts the same state
    back = retarget_engine(deg, set())
    assert type(back) is CAMREngine and back.servers is eng.servers
    assert retarget_engine(deg, {3}) is deg


# --------------------------------------------------------------------- #
# straggler detection state machine
# --------------------------------------------------------------------- #
def test_straggler_flag_demote_rejoin_lifecycle():
    """live -> straggler (patience strikes) -> dead -> live again, at
    deterministic wave boundaries (no pipelining), with the replan
    receipt proving the rejoin moved zero data."""
    q, k, waves = 2, 3, 7
    specs = chaos.make_specs(q, k, waves, d=6)
    oracle = serial_oracle(specs)
    plan = FaultPlan((Straggle(1, 3, waves=3, delay_s=9.0),
                      Rejoin(5, 3)), "lifecycle")
    got, stream, ctrl = run_plan(specs, plan, policy=DETECT,
                                 pipeline=False)
    assert_bit_identical(oracle, got, "lifecycle")
    m = ctrl.membership
    assert [(kind, w) for _, kind, w in m.events] == \
        [("flag", 3), ("demote", 3), ("rejoin", 3)]
    assert m.state[3] == Membership.LIVE
    # demotion landed after wave 2's timings: waves 3-4 ran degraded,
    # wave 5 onward healthy again — all at batch boundaries
    assert stream.last_report.migrations == 0
    for w, want in enumerate([None, None, None, {3}, {3}, None, None]):
        assert (getattr(stream.last_engines[w], "failed", None) or
                None) == want, w
    # the rejoin receipt: same-K re-admission is pure re-placement
    assert m.replans[-1].moved_fraction == 0.0
    assert m.replans[-1].new_qk == (q, k)


def test_membership_transitions_and_caps():
    m = Membership(2, 3)
    with pytest.raises(MembershipError, match="outside"):
        m.kill(6)
    with pytest.raises(MembershipError, match="only dead"):
        m.rejoin(0)
    m.kill(0)
    with pytest.raises(MembershipError, match="already dead"):
        m.kill(0)
    with pytest.raises(MembershipError, match="max_failed"):
        m.kill(1)                       # cap: one concurrent failure
    assert m.demote(1) is False         # cap respected, worker stays live
    assert m.state[1] == Membership.LIVE
    assert m.failed() == {0} and 0 not in m.live()
    rep = m.rejoin(0)
    assert rep.moved_fraction == 0.0    # zero data movement certified
    m.kill(1)                           # slot free again
    assert m.failed() == {1}
    assert [e[1] for e in m.events] == ["kill", "rejoin", "kill"]
    assert m.generation == 3


def test_straggler_policy_knobs():
    base = {w: 1.0 for w in range(6)}
    # patience demands CONSECUTIVE strikes: a clean wave resets
    m = Membership(2, 3, policy=StragglerPolicy(rel_threshold=2.0,
                                                patience=2))
    assert m.observe({**base, 2: 10.0}) == []
    assert m.state[2] == Membership.STRAGGLER
    assert m.observe(base) == []                  # clean wave
    assert m.state[2] == Membership.LIVE          # flag cleared
    assert m.observe({**base, 2: 10.0}) == []
    assert m.observe({**base, 2: 10.0}) == [2]    # 2nd consecutive
    assert m.state[2] == Membership.DEAD
    # absolute timeout trips independently of the median
    m2 = Membership(2, 3, policy=StragglerPolicy(
        rel_threshold=1e9, abs_timeout_s=5.0, patience=1))
    assert m2.observe({**base, 4: 6.0}) == [4]
    # demote=False only flags
    m3 = Membership(2, 3, policy=StragglerPolicy(rel_threshold=2.0,
                                                 patience=1,
                                                 demote=False))
    assert m3.observe({**base, 1: 10.0}) == []
    assert m3.state[1] == Membership.STRAGGLER
    # min_wave_s: µs-scale waves are unmeasurable — no strikes at all
    m4 = Membership(2, 3, policy=StragglerPolicy(rel_threshold=2.0,
                                                 patience=1,
                                                 min_wave_s=1e-3))
    fast = {w: 2e-6 for w in range(6)}
    assert m4.observe({**fast, 3: 5.0}) == []
    assert m4.state[3] == Membership.LIVE
    # dead workers are ignored by the detector
    m5 = Membership(2, 3, policy=StragglerPolicy(rel_threshold=2.0,
                                                 patience=1))
    m5.kill(5)
    assert m5.observe({**base, 5: 99.0}) == []


def test_warm_survivors_makes_recovery_pure_hits():
    SCHEDULE_CACHE.clear()
    prog = CAMREngine(CAMRConfig(q=2, k=3, gamma=1),
                      chaos._identity_map).program
    assert SCHEDULE_CACHE.warm_survivors(prog) == 6   # one per worker
    s0 = SCHEDULE_CACHE.stats()
    for w in range(6):
        SCHEDULE_CACHE.degraded(prog, {w})
    s1 = SCHEDULE_CACHE.stats()
    assert s1["misses"] == s0["misses"]
    assert s1["hits"] - s0["hits"] == 6
    # k=3 double failures are all unrecoverable -> skipped, not cached
    assert SCHEDULE_CACHE.warm_survivors(prog, max_failures=2) == 6


# --------------------------------------------------------------------- #
# degraded host interpreter: dead rows are never read
# --------------------------------------------------------------------- #
def test_degraded_host_never_reads_dead_rows():
    """NaN-poison a failed worker's contribution rows: the degraded
    host lane must produce finite output bitwise equal to its own
    healthy (empty-failure) interpretation — proof that no route ever
    touches dead data."""
    from repro.core.collective import (camr_shuffle_reference, make_plan,
                                       scatter_contributions)

    q, k, d = 2, 3, 4
    from repro.runtime.fault import degraded_shuffle_host

    plan = make_plan(q, k, d)
    prog = SCHEDULE_CACHE.program(q, k, Q=plan.K)
    rng = np.random.default_rng(7)
    bg = rng.standard_normal((plan.J, k, plan.K, d)).astype(np.float32)
    contribs = scatter_contributions(plan, bg)
    healthy = degraded_shuffle_host(prog, set(), contribs)
    np.testing.assert_allclose(healthy, camr_shuffle_reference(plan, bg),
                               rtol=2e-5, atol=2e-6)
    for w in range(plan.K):
        poisoned = contribs.copy()
        poisoned[w] = np.nan
        out = degraded_shuffle_host(prog, {w}, poisoned)
        assert np.isfinite(out).all(), w
        np.testing.assert_array_equal(out, healthy, err_msg=f"worker {w}")


def test_degraded_device_executor_bitwise_vs_host_interpreter():
    """The compiled dense degraded executor (DESIGN.md §15) replays the
    host interpreter's exact fold order: bitwise-equal output for EVERY
    recoverable survivor set, across cluster shapes, with -0.0 values
    sprinkled in to catch masked-add bit rewrites (the where-select
    contract)."""
    from itertools import combinations

    from repro.runtime.fault import (build_degraded_executor,
                                     degraded_shuffle_host)

    for q, k, d in [(2, 3, 8), (2, 4, 9)]:
        prog = SCHEDULE_CACHE.program(q, k, Q=q * k, d=d)
        K, J_own = q * k, q ** (k - 2)
        rng = np.random.default_rng(11)
        contribs = rng.standard_normal(
            (K, J_own, k - 1, K, d)).astype(np.float32)
        contribs[rng.random(contribs.shape) < 0.05] = -0.0
        checked = 0
        for r in (1, 2):
            for combo in combinations(range(K), r):
                try:
                    SCHEDULE_CACHE.degraded(prog, set(combo))
                except ValueError:
                    continue
                failed = frozenset(combo)
                want = degraded_shuffle_host(prog, failed, contribs)
                exe = build_degraded_executor(prog, failed, d,
                                              np.float32)
                got = np.asarray(exe(contribs))
                assert (want.view(np.uint32)
                        == got.view(np.uint32)).all(), (q, k, combo)
                checked += 1
        assert checked >= K, (q, k, checked)


# --------------------------------------------------------------------- #
# SPMD stream elasticity (subprocess: needs a K-device mesh)
# --------------------------------------------------------------------- #
def _run_subprocess(code: str, ndev: int) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


_RUN_STREAM_CHURN = textwrap.dedent("""
    import numpy as np
    from repro.compat import make_mesh
    from repro.core.collective import (ShuffleStream, make_plan,
                                       scatter_contributions)

    q, k, d = 2, 3, 8
    plan = make_plan(q, k, d)
    mesh = make_mesh((plan.K,), ("camr",))
    rng = np.random.default_rng(0)
    contribs = [scatter_contributions(
        plan, rng.standard_normal((plan.J, k, plan.K, d)).astype(
            np.float32)) for _ in range(6)]

    stream = ShuffleStream(q, k, d, mesh=mesh, wave_batch=1, depth=2)
    healthy = [np.asarray(o) for o in stream.run_waves(contribs)]
    st0 = dict(stream.stats())

    # kill worker 4 at wave 2, restore at wave 4 — same stream object
    for i, c in enumerate(contribs):
        if i == 2:
            stream.degrade({4})
        if i == 4:
            stream.restore()
        stream.submit(c)
    churned = [np.asarray(o) for o in stream.drain()]
    st1 = stream.stats()

    for h, o in zip(healthy, churned):
        np.testing.assert_array_equal(h, o)   # degraded lane == compiled
    assert st1["compiles"] == st0["compiles"] == 1, st1   # no retrace
    assert st1["swaps"] == 2 and st1["failed"] == (), st1
    assert len(stream.wave_times) == 12, len(stream.wave_times)

    # unrecoverable survivor sets are rejected up front, pre-dispatch
    try:
        stream.degrade({0, 1})
        raise SystemExit("same-class double failure must be rejected")
    except ValueError:
        pass
    print("OK")
""")


def test_shuffle_stream_degrade_restore_bitwise():
    out = _run_subprocess(_RUN_STREAM_CHURN, ndev=6)
    assert "OK" in out


_RUN_DEGRADED_DEVICE = textwrap.dedent("""
    import numpy as np
    from repro.compat import make_mesh
    from repro.core.collective import (ShuffleStream, make_plan,
                                       scatter_contributions)

    q, k, d = 2, 3, 8
    plan = make_plan(q, k, d)
    mesh = make_mesh((plan.K,), ("camr",))
    rng = np.random.default_rng(3)
    contribs = [scatter_contributions(
        plan, rng.standard_normal((plan.J, k, plan.K, d)).astype(
            np.float32)) for _ in range(4)]

    # oracle lane: the fault runtime's host interpreter
    host = ShuffleStream(q, k, d, mesh=mesh, degraded_lane="host")
    host.degrade({4})
    want = [np.asarray(o) for o in host.run_waves(contribs)]
    assert host.stats()["degraded_compiles"] == 0, host.stats()

    # device lane, warmed BEFORE any failure: the degrade itself and
    # every degraded dispatch must then be completely build-free
    dev = ShuffleStream(q, k, d, mesh=mesh)   # degraded_lane="device"
    n = dev.warm_degraded_execs(max_failures=1)
    assert n == plan.K, n                     # every single-failure set
    warmed = dev.stats()["degraded_compiles"]
    assert warmed == plan.K, dev.stats()
    dev.degrade({4})
    got = [np.asarray(o) for o in dev.run_waves(contribs)]
    st = dev.stats()
    assert st["degraded_compiles"] == warmed, st   # warm hit: 0 builds
    assert st["compiles"] == 0, st   # healthy lane never even compiled
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)   # device == host, bitwise

    # a SECOND stream of the same shape hits the process-wide
    # EXEC_CACHE: its own counter stays at zero through a live degrade
    dev2 = ShuffleStream(q, k, d, mesh=mesh)
    dev2.degrade({1})
    got2 = [np.asarray(o) for o in dev2.run_waves(contribs)]
    assert dev2.stats()["degraded_compiles"] == 0, dev2.stats()
    host2 = ShuffleStream(q, k, d, mesh=mesh, degraded_lane="host")
    host2.degrade({1})
    for w, g in zip(host2.run_waves(contribs), got2):
        np.testing.assert_array_equal(np.asarray(w), g)
    print("OK")
""")


def test_shuffle_stream_degraded_device_lane_warm_zero_builds():
    """Satellite gate (DESIGN.md §15): the degraded SPMD lane runs a
    pre-compiled on-device executor — warm-hit means ZERO builds at
    degrade time — and its output is bitwise the host interpreter's."""
    out = _run_subprocess(_RUN_DEGRADED_DEVICE, ndev=6)
    assert "OK" in out


_RUN_TRAINER_CHURN = textwrap.dedent("""
    import numpy as np
    from repro.configs import get_config, reduced
    from repro.data.pipeline import ShardedTokenPipeline
    from repro.runtime.train_loop import MultiModelCAMRTrainer

    cfg = reduced(get_config("granite_3_2b")).replace(
        n_layers=2, vocab=64, d_model=32, d_ff=64, n_heads=2,
        n_kv_heads=1, head_dim=16, loss_chunk=8)
    pipe = ShardedTokenPipeline(vocab=64, seq_len=8, global_batch=2)

    ref = MultiModelCAMRTrainer(cfg, q=2, k=3, seed=0)
    ref_rep = ref.train_steps(pipe, 4, mode="camr")
    ref_flat = np.asarray(ref.flat)
    ref_losses = np.asarray(ref_rep.losses)
    assert np.isfinite(ref_losses).all()

    # kill worker 2 after step 2, rejoin after step 3 — both wires
    for mode in ("camr", "camr_spmd"):
        tr = MultiModelCAMRTrainer(cfg, q=2, k=3, seed=0,
                                   spmd_oracle=(mode == "camr_spmd"))
        losses = list(tr.train_steps(pipe, 2, mode=mode).losses)
        tr.set_failed({2})
        losses += list(tr.train_steps(pipe, 1, mode=mode).losses)
        tr.set_failed(None)
        losses += list(tr.train_steps(pipe, 1, mode=mode).losses)
        np.testing.assert_array_equal(
            np.asarray(tr.flat), ref_flat,
            err_msg=f"{mode} churn diverged from uninterrupted run")
        np.testing.assert_array_equal(np.asarray(losses), ref_losses)
        if mode == "camr_spmd":
            st = tr._stream.stats()
            assert st["compiles"] == 1, st     # kill/rejoin: no retrace
            assert st["swaps"] == 2, st
            assert st["failed"] == (), st
    print("OK")
""")


@pytest.mark.slow
def test_trainer_kill_rejoin_bit_identical():
    """Mid-training churn on both grad-sync wires: the interrupted
    trajectory is bit-identical to the uninterrupted one, and the SPMD
    stream survives degrade/restore without retracing."""
    out = _run_subprocess(_RUN_TRAINER_CHURN, ndev=6)
    assert "OK" in out


# --------------------------------------------------------------------- #
# elastic runs reject conflicting configuration
# --------------------------------------------------------------------- #
def test_jobstream_rejects_elastic_plus_static_failed():
    m = Membership(2, 3)
    with pytest.raises(ValueError, match="membership"):
        JobStream(failed={0}, elastic=ElasticController(m))


def test_jobstream_wraps_bare_membership():
    specs = chaos.make_specs(2, 3, 2, d=4)
    oracle = serial_oracle(specs)
    m = Membership(2, 3, policy=StragglerPolicy(demote=False))
    m.kill(5)
    stream = JobStream(elastic=m, pipeline=False)   # bare Membership
    got = stream.run(specs)
    assert isinstance(stream.elastic, ElasticController)
    assert_bit_identical(oracle, got, "bare membership")
    assert all(e.failed == {5} for e in stream.last_engines)
