"""Topology-parametric lowering (DESIGN.md §16): two-level host-aware
schedules — structure, cache keying, per-edge byte model, and bitwise
identity of the SPMD executor against the flat schedule and the engine
oracle."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.collective import (ShuffleStream, camr_edge_bytes,
                                   expected_collective_calls, make_plan)
from repro.core.loads import (camr_edge_loads, camr_load_hierarchical,
                              camr_load_p2p)
from repro.core.schedule import (SCHEDULE_CACHE, AutoTopology,
                                 ScheduleCache, Topology,
                                 _normalize_topology, _program_key,
                                 resolve_topology, surviving_topology)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CONFIGS = [(2, 4, 2), (3, 4, 2), (2, 6, 2), (2, 6, 3)]


def _run_subprocess(code: str, ndev: int) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


# --------------------------------------------------------------------- #
# the Topology object
# --------------------------------------------------------------------- #
def test_topology_flat_normalizes_to_none():
    assert _normalize_topology(None) is None
    assert _normalize_topology(Topology.flat()) is None
    assert _normalize_topology(Topology(hosts=1, alpha=9.0)) is None
    t = Topology.two_level(2, alpha=3.0)
    assert _normalize_topology(t) is t
    assert t.key() == (2, 3.0)
    assert Topology.flat().key() is None


def test_topology_validation():
    with pytest.raises(ValueError):
        Topology(hosts=0)
    with pytest.raises(ValueError):
        Topology(hosts=2, alpha=0.0)
    with pytest.raises(ValueError):
        Topology.two_level(1)
    with pytest.raises(ValueError):          # hosts must divide k
        Topology.two_level(2).check(2, 3)
    Topology.two_level(3).check(2, 6)        # 3 | 6: fine
    t = Topology.two_level(2)
    assert t.devices_per_host(8) == 4
    assert [t.host_of(s, 8) for s in range(8)] == [0, 0, 0, 0, 1, 1, 1, 1]


# --------------------------------------------------------------------- #
# two-level lowering structure
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("q,k,hosts", CONFIGS)
def test_two_level_tables_conserve_deliveries(q, k, hosts):
    """Masked phase-A sends + phase-B relays == the flat delivery set:
    the overlay re-routes packets, it never drops or duplicates one."""
    plan = make_plan(q, k, 2 * (k - 1), topology=Topology.two_level(hosts))
    c = k // hosts
    for stage in (1, 2):
        T = plan.program.stage_tables(stage)
        X = plan.program.host_tables(stage)
        n = T.n
        flat_deliveries = n * k * (k - 1)
        kept = int((X.a2a_send >= 0).sum())
        assert kept + X.relay_intra == flat_deliveries
        assert int((X.pp_send >= 0).sum()) == kept
        assert int(X.b_mask.sum()) == X.relay_intra
        assert int((X.b_send >= 0).sum()) == X.relay_intra
        # closed-form per-edge counts (one member per class, c per host)
        assert X.flat_inter == n * k * (k - c)
        assert X.two_level_inter == n * k * (hosts - 1)
        assert X.intra == n * k * (c - 1)
        # round 1 can never relay: a gateway needs an earlier round
        assert X.b_live[0] == ()
        # every relay permutation stays inside a host block
        dph = X.dph
        for perm in X.b_perms:
            for src, dst in perm:
                assert src // dph == dst // dph


def test_two_level_requires_hosts_dividing_k():
    with pytest.raises(ValueError):
        make_plan(2, 3, 8, topology=Topology.two_level(2))


def test_flat_plan_has_no_overlay():
    plan = make_plan(2, 3, 8)
    assert plan.topology is None
    assert plan.program.hx1 is None and plan.program.hx2 is None
    with pytest.raises(ValueError):
        plan.program.host_tables(1)
    # explicit flat topology is the SAME program as no topology
    flat = make_plan(2, 3, 8, topology=Topology.flat())
    assert flat.program is plan.program


# --------------------------------------------------------------------- #
# cache keying (satellite: no flat/two-level aliasing)
# --------------------------------------------------------------------- #
def test_schedule_cache_no_topology_aliasing():
    """Flat and two-level lowerings of the same (q, k, gamma, Q) occupy
    distinct entries and never cross-hit."""
    cache = ScheduleCache()
    flat = cache.program(2, 4, Q=8, d=6)
    assert cache.stats()["misses"] == 2 and cache.stats()["hits"] == 0
    two = cache.program(2, 4, Q=8, d=6, topology=Topology.two_level(2))
    st = cache.stats()
    assert st["misses"] == 4 and st["hits"] == 0   # zero cross-hits
    assert two is not flat
    assert two.topology is not None and flat.topology is None
    # repeat lookups hit their own entries only
    assert cache.program(2, 4, Q=8, d=6) is flat
    assert cache.program(2, 4, Q=8, d=6,
                         topology=Topology.two_level(2)) is two
    st = cache.stats()
    assert st["hits"] == 4 and st["misses"] == 4
    # alpha is a cost parameter of the key too
    other = cache.program(2, 4, Q=8, d=6,
                          topology=Topology.two_level(2, alpha=8.0))
    assert other is not two
    # flat Topology object aliases the None entry (the identity case)
    assert cache.program(2, 4, Q=8, d=6, topology=Topology.flat()) is flat


def test_program_key_distinguishes_topology():
    flat = make_plan(2, 4, 6).program
    two = make_plan(2, 4, 6, topology=Topology.two_level(2)).program
    two8 = make_plan(2, 4, 6,
                     topology=Topology.two_level(2, alpha=8.0)).program
    keys = {_program_key(flat), _program_key(two), _program_key(two8)}
    assert len(keys) == 3
    # flat's key is the pre-topology tuple + None: stable across PRs
    assert _program_key(flat)[-1] is None


def test_degraded_cache_per_topology():
    """Degraded re-lowerings key per topology (warm_survivors pre-warms
    each topology's survivor sets independently)."""
    cache = ScheduleCache()
    flat = cache.program(2, 4, Q=8)
    two = cache.program(2, 4, Q=8, topology=Topology.two_level(2))
    n_flat = cache.warm_survivors(flat, max_failures=1)
    st = cache.stats()
    n_two = cache.warm_survivors(two, max_failures=1)
    assert n_flat == n_two == 8
    assert cache.stats()["degraded"] == st["degraded"] * 2
    # same failure, different topology: distinct entries, both valid
    d_flat = cache.degraded(flat, {0})
    d_two = cache.degraded(two, {0})
    assert d_flat is not d_two
    assert d_flat.coded_rows == d_two.coded_rows


# --------------------------------------------------------------------- #
# per-edge byte model: measured tables == analytic closed form
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("q,k,hosts", CONFIGS)
def test_edge_bytes_match_hierarchical_loads(q, k, hosts):
    """camr_edge_bytes (walked from the lowered send tables) must equal
    the camr_load_hierarchical / camr_edge_loads closed forms exactly —
    the same gate benchmarks/bench_topology.py enforces."""
    d = 2 * (k - 1)
    J, K = q ** (k - 1), q * k
    B = d * 4
    plan = make_plan(q, k, d, topology=Topology.two_level(hosts))
    eb = camr_edge_bytes(plan)
    for sched in ("flat", "two_level"):
        intra, inter = camr_edge_loads(q, k, hosts, schedule=sched)
        assert eb[f"{sched}_inter_bytes"] == pytest.approx(
            inter * J * K * B, abs=1e-6)
        assert eb[f"{sched}_intra_bytes"] == pytest.approx(
            intra * J * K * B, abs=1e-6)
    # the headline: two-level cuts inter-host bytes by exactly hosts/k
    assert eb["two_level_inter_bytes"] * k == eb["flat_inter_bytes"] * hosts
    if hosts < k:
        assert eb["two_level_inter_bytes"] < eb["flat_inter_bytes"]
    # both schedules move the same total (the relay rides the fast edge)
    assert (eb["flat_inter_bytes"] + eb["flat_intra_bytes"] ==
            eb["two_level_inter_bytes"] + eb["two_level_intra_bytes"])
    # alpha=1 prices both schedules at camr_load_p2p-equivalent totals
    assert camr_load_hierarchical(q, k, hosts, 1.0) == pytest.approx(
        (eb["flat_inter_bytes"] + eb["flat_intra_bytes"]) / (J * K * B))


def test_edge_bytes_requires_two_level():
    with pytest.raises(ValueError):
        camr_edge_bytes(make_plan(2, 4, 6))


# --------------------------------------------------------------------- #
# SPMD executor: two-level == flat == engine oracle, bitwise
# --------------------------------------------------------------------- #
_RUN_TWO_LEVEL = textwrap.dedent("""
    import numpy as np, jax
    from jax.sharding import PartitionSpec as P
    from repro.compat import make_mesh, shard_map
    from repro.core.collective import (make_plan, camr_shuffle,
        scatter_contributions, expected_collective_calls)
    from repro.core.engine import CAMRConfig, CAMREngine
    from repro.core.schedule import Topology
    q, k, hosts, d, dtype = {q}, {k}, {hosts}, {d}, '{dtype}'
    plan_f = make_plan(q, k, d)
    plan_t = make_plan(q, k, d, topology=Topology.two_level(hosts))
    K = plan_f.K
    rng = np.random.default_rng(5)
    bg = rng.standard_normal((plan_f.J, k, K, d)).astype(np.float32)
    if dtype != 'float32':
        bg = np.asarray(jax.numpy.asarray(bg).astype(dtype))
    contribs = scatter_contributions(plan_f, bg)
    mesh = make_mesh((K,), ('camr',))

    def run(plan, router):
        fn = jax.jit(shard_map(
            lambda c: camr_shuffle(plan, c[0], axis_name='camr',
                                   router=router)[None],
            mesh=mesh, in_specs=P('camr'), out_specs=P('camr')))
        return np.asarray(jax.block_until_ready(fn(contribs)))

    flat = run(plan_f, 'all_to_all')
    bits = np.uint32 if flat.dtype.itemsize == 4 else np.uint16
    for router in ('all_to_all', 'ppermute'):
        two = run(plan_t, router)
        np.testing.assert_array_equal(two.view(bits), flat.view(bits),
                                      err_msg=router)

    def count_collectives(jaxpr):
        n = 0
        def walk(jx):
            nonlocal n
            for eqn in jx.eqns:
                if eqn.primitive.name in ('ppermute', 'all_to_all'):
                    n += 1
                for v in eqn.params.values():
                    for sub in (v if isinstance(v, (list, tuple)) else [v]):
                        if hasattr(sub, 'eqns'):
                            walk(sub)
                        elif hasattr(sub, 'jaxpr'):
                            walk(sub.jaxpr)
        walk(jaxpr.jaxpr)
        return n

    fn = shard_map(
        lambda c: camr_shuffle(plan_t, c[0], axis_name='camr')[None],
        mesh=mesh, in_specs=P('camr'), out_specs=P('camr'))
    got = count_collectives(jax.make_jaxpr(fn)(contribs))
    want = expected_collective_calls(plan_t)['total']
    assert got == want, (got, want)

    if dtype == 'float32':
        cfg = CAMRConfig(q=q, k=k, gamma=1)
        eng = CAMREngine(cfg, lambda job, sf: sf)
        datasets = [[bg[j, t] for t in range(k)] for j in range(plan_f.J)]
        results = eng.run(datasets)
        for s in range(K):
            for j in range(plan_f.J):
                np.testing.assert_array_equal(flat[s, j], results[s][(j, s)])
    print('OK')
""")


@pytest.mark.parametrize("q,k,hosts,dtype", [
    (2, 4, 2, "float32"),
    (3, 4, 2, "float32"),
    (2, 6, 3, "float32"),
    (2, 4, 2, "bfloat16"),
])
def test_two_level_bitwise_identity(q, k, hosts, dtype):
    """The two-level executor (both routers) produces BITWISE the flat
    schedule's output — which is itself bitwise the engine oracle's —
    and traces exactly the predicted collective count."""
    out = _run_subprocess(
        _RUN_TWO_LEVEL.format(q=q, k=k, hosts=hosts, d=2 * (k - 1),
                              dtype=dtype), ndev=q * k)
    assert "OK" in out


_RUN_STREAM_TOPO = textwrap.dedent("""
    import numpy as np, jax
    from repro.compat import make_mesh
    from repro.core.collective import (ShuffleStream, make_plan,
        scatter_contributions, camr_shuffle_reference)
    from repro.core.schedule import Topology
    q, k, d, hosts = {q}, {k}, {d}, {hosts}
    plan = make_plan(q, k, d); K = plan.K
    mesh = make_mesh((K,), ('camr',))
    rng = np.random.default_rng(11)
    bgs = [rng.standard_normal((plan.J, k, K, d)).astype(np.float32)
           for _ in range(4)]
    contribs = [scatter_contributions(plan, bg) for bg in bgs]
    flat = ShuffleStream(q, k, d, mesh=mesh)
    two = ShuffleStream(q, k, d, mesh=mesh,
                        topology=Topology.two_level(hosts))
    ref = flat.run_waves(contribs)
    got = two.run_waves(contribs)
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(a, b)
    # degraded/survivor-set re-lowering on the two-level topology:
    # mid-stream degrade swaps to the survivor executor and stays
    # bitwise identical to the healthy oracle (values are transport-
    # independent; only the edge each packet rides changes)
    two.warm_degraded_execs(max_failures=1)
    for i, c in enumerate(contribs):
        if i == 1:
            two.degrade({{1}})
        if i == 3:
            two.restore()
        two.submit(c)
    churned = two.drain()
    assert two.stats()['degraded_compiles'] <= K  # all pre-warmed
    for out, bg, r in zip(churned, bgs, ref):
        np.testing.assert_allclose(out, camr_shuffle_reference(plan, bg),
                                   rtol=2e-5, atol=2e-6)
        np.testing.assert_array_equal(out, r)
    print('OK')
""")


def test_two_level_stream_and_degraded_relowering():
    """ShuffleStream on a two-level topology: healthy waves bitwise
    equal the flat stream's, and a mid-stream degrade re-lowers from
    the per-topology warm cache with bit-identical outputs."""
    out = _run_subprocess(
        _RUN_STREAM_TOPO.format(q=2, k=4, d=6, hosts=2), ndev=8)
    assert "OK" in out


def test_two_level_rejects_looped_mode():
    plan = make_plan(2, 4, 6, topology=Topology.two_level(2))
    calls = expected_collective_calls(plan)
    flat_calls = expected_collective_calls(make_plan(2, 4, 6))
    assert calls["total"] > flat_calls["total"]   # relay lanes counted
    with pytest.raises(ValueError):
        ShuffleStream(2, 4, 6, mesh=None, mode="looped",
                      topology=Topology.two_level(2))


# --------------------------------------------------------------------- #
# gateway failover (DESIGN.md §17): avoid-set lowering stays conservative
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("q,k,hosts", CONFIGS)
def test_gateway_avoid_preserves_delivery_conservation(q, k, hosts):
    """Every gateway assignment re-routes the SAME delivery set: the
    per-edge conservation counts are invariant in the avoid set, only
    the relay tables move."""
    K = q * k
    dph = K // hosts
    base = make_plan(q, k, 2 * (k - 1), topology=Topology.two_level(hosts))
    # avoid one device per host, a whole host block, and a mixed set
    avoid_sets = [frozenset({h * dph for h in range(hosts)}),
                  frozenset(range(dph)),
                  frozenset({0, K - 1})]
    for avoid in avoid_sets:
        plan = make_plan(q, k, 2 * (k - 1),
                         topology=Topology.two_level(hosts),
                         gateway_avoid=avoid)
        assert plan.program.gateway_avoid == avoid
        moved = False
        for stage in (1, 2):
            B = base.program.host_tables(stage)
            X = plan.program.host_tables(stage)
            n = plan.program.stage_tables(stage).n
            c = k // hosts
            kept = int((X.a2a_send >= 0).sum())
            assert kept + X.relay_intra == n * k * (k - 1)
            assert int((X.pp_send >= 0).sum()) == kept
            assert int(X.b_mask.sum()) == X.relay_intra
            assert int((X.b_send >= 0).sum()) == X.relay_intra
            assert X.flat_inter == n * k * (k - c)
            assert X.two_level_inter == n * k * (hosts - 1)
            assert X.intra == n * k * (c - 1)
            for perm in X.b_perms:
                for src, dst in perm:
                    assert src // dph == dst // dph
            moved = moved or not np.array_equal(X.a2a_send, B.a2a_send)
        # a whole-host avoid set cannot move that host's gateways (the
        # fallback keeps the first receiver), but cross-host sets must
        if not any(set(range(h * dph, (h + 1) * dph)) <= avoid
                   for h in range(hosts)):
            assert moved, f"avoid={sorted(avoid)} left tables unchanged"


def test_gateway_avoid_joins_cache_and_program_key():
    """Gateway assignments never alias: default vs avoid-set lowerings
    occupy distinct cache entries, and the default keeps the pre-§17
    key shape."""
    cache = ScheduleCache()
    topo = Topology.two_level(2)
    base = cache.program(2, 4, Q=8, d=6, topology=topo)
    avoided = cache.program(2, 4, Q=8, d=6, topology=topo,
                            gateway_avoid={0})
    assert avoided is not base
    assert cache.stats()["misses"] == 4 and cache.stats()["hits"] == 0
    assert cache.program(2, 4, Q=8, d=6, topology=topo,
                         gateway_avoid={0}) is avoided
    assert cache.program(2, 4, Q=8, d=6, topology=topo) is base
    # key shape: default lowerings (flat or two-level) keep their
    # pre-gateway tuple; only non-empty avoid sets extend it
    assert _program_key(base) == _program_key(avoided)[:-1]
    assert _program_key(avoided)[-1] == (0,)
    # flat collapses the avoid set (no gateways to move): same entry
    flat = cache.program(2, 4, Q=8, d=6)
    assert cache.program(2, 4, Q=8, d=6, gateway_avoid={0}) is flat


def test_gateway_avoid_validation():
    with pytest.raises(ValueError, match="outside"):
        make_plan(2, 4, 6, topology=Topology.two_level(2),
                  gateway_avoid={99})
    with pytest.raises(ValueError, match="outside"):
        ShuffleStream(2, 4, 6, mesh=None, gateway_avoid={-1})


# --------------------------------------------------------------------- #
# alpha-driven auto-pick (DESIGN.md §17 satellite)
# --------------------------------------------------------------------- #
def test_auto_topology_resolution():
    auto = Topology.auto(2, alpha=4.0)
    assert isinstance(auto, AutoTopology)
    picked = auto.resolve(2, 4)
    assert picked == Topology.two_level(2, alpha=4.0)
    # alpha = 1: analytically equal costs — tie goes to flat
    assert Topology.auto(2, alpha=1.0).resolve(2, 4) is None
    # hosts = k: two-level degenerates to flat's inter traffic
    assert Topology.auto(4, alpha=4.0).resolve(2, 4) is None
    # non-dividing hosts: no class-aligned blocks, flat
    assert Topology.auto(3, alpha=16.0).resolve(2, 4) is None
    assert Topology.auto(1, alpha=16.0).resolve(2, 4) is None
    # the pick is exactly the cost-model argmin
    for hosts, alpha in [(2, 1.5), (2, 8.0), (3, 2.0), (3, 64.0)]:
        got = Topology.auto(hosts, alpha=alpha).resolve(2, 6)
        intra, inter = camr_edge_loads(2, 6, hosts, schedule="flat")
        flat_cost = intra + alpha * inter
        two_cost = camr_load_hierarchical(2, 6, hosts, alpha)
        if flat_cost - two_cost > 1e-9 * flat_cost:
            assert got == Topology.two_level(hosts, alpha=alpha)
        else:
            assert got is None
    # identity: alpha = 1 prices both schedules at camr_load_p2p
    assert camr_load_hierarchical(2, 6, 2, 1.0) == pytest.approx(
        camr_load_p2p(2, 6))


def test_auto_topology_resolves_through_cache_and_plan():
    """An AutoTopology marker is transparent everywhere a Topology is
    accepted — the cache keys the RESOLVED pick (no auto/concrete
    aliasing)."""
    cache = ScheduleCache()
    two = cache.program(2, 4, Q=8, topology=Topology.two_level(2))
    auto = cache.program(2, 4, Q=8, topology=Topology.auto(2, alpha=4.0))
    assert auto is two                       # resolved to the same entry
    flat = cache.program(2, 4, Q=8)
    assert cache.program(2, 4, Q=8,
                         topology=Topology.auto(2, alpha=1.0)) is flat
    plan = make_plan(2, 4, 6, topology=Topology.auto(2, alpha=4.0))
    assert plan.topology == Topology.two_level(2, alpha=4.0)
    assert resolve_topology(Topology.auto(2, alpha=1.0), 2, 4) is None


def test_surviving_topology():
    assert surviving_topology(2, 4) == Topology.two_level(2)
    assert surviving_topology(3, 4) is None          # 3 does not divide 4
    assert surviving_topology(1, 4) is None          # single host: flat
    assert surviving_topology(3, 6, alpha=8.0) == \
        Topology.two_level(3, alpha=8.0)
    with pytest.raises(ValueError):
        surviving_topology(0, 4)


def test_warm_host_survivors_prepays_every_host_loss():
    """After warm_host_survivors, every surviving-host re-lowering of
    up to max_host_failures losses is a pure cache hit."""
    cache = ScheduleCache()
    prog = cache.program(2, 6, Q=12, d=10, topology=Topology.two_level(3))
    n = cache.warm_host_survivors(prog, max_host_failures=2)
    assert n == 2                        # hosts 2 and 1 survivor layouts
    before = cache.stats()
    for lost in (1, 2):
        t = surviving_topology(3 - lost, 6)
        cache.program(2, 6, Q=12, d=10, topology=t)
    st = cache.stats()
    assert st["misses"] == before["misses"], "host recovery must be a " \
        "pure cache hit after warm_host_survivors"
    assert st["hits"] > before["hits"]
    # flat stream has no hosts to lose
    flat = cache.program(2, 6, Q=12, d=10)
    with pytest.raises(ValueError):
        cache.warm_host_survivors(flat)
    with pytest.raises(ValueError):
        cache.warm_host_survivors(prog, max_host_failures=3)


# --------------------------------------------------------------------- #
# SPMD executor: every gateway assignment bitwise == flat == oracle
# --------------------------------------------------------------------- #
_RUN_GATEWAY_SWEEP = textwrap.dedent("""
    import numpy as np, jax
    from jax.sharding import PartitionSpec as P
    from repro.compat import make_mesh, shard_map
    from repro.core.collective import (make_plan, camr_shuffle,
        scatter_contributions)
    from repro.core.schedule import Topology
    q, k, hosts, d = {q}, {k}, {hosts}, {d}
    plan_f = make_plan(q, k, d)
    K = plan_f.K
    dph = K // hosts
    rng = np.random.default_rng(7)
    bg = rng.standard_normal((plan_f.J, k, K, d)).astype(np.float32)
    contribs = scatter_contributions(plan_f, bg)
    mesh = make_mesh((K,), ('camr',))

    def run(plan, router='all_to_all'):
        fn = jax.jit(shard_map(
            lambda c: camr_shuffle(plan, c[0], axis_name='camr',
                                   router=router)[None],
            mesh=mesh, in_specs=P('camr'), out_specs=P('camr')))
        return np.asarray(jax.block_until_ready(fn(contribs)))

    flat = run(plan_f)
    # single-device avoids, one avoided-device-per-host, and a whole
    # host block (fallback keeps a gateway): all bitwise == flat
    sweeps = ([frozenset({{s}}) for s in range(K)]
              + [frozenset({{h * dph for h in range(hosts)}}),
                 frozenset(range(dph))])
    for avoid in sweeps:
        plan_a = make_plan(q, k, d, topology=Topology.two_level(hosts),
                           gateway_avoid=avoid)
        for router in ('all_to_all', 'ppermute'):
            got = run(plan_a, router)
            np.testing.assert_array_equal(
                got, flat, err_msg=f"avoid={{sorted(avoid)}} {{router}}")
    print('OK')
""")


@pytest.mark.parametrize("q,k,hosts", [(2, 4, 2), (2, 6, 3)])
def test_gateway_failover_bitwise_sweep(q, k, hosts):
    """Outputs are BITWISE equal to flat (hence to the engine oracle,
    test_two_level_bitwise_identity) for EVERY gateway assignment —
    gateway choice is pure routing policy."""
    out = _run_subprocess(
        _RUN_GATEWAY_SWEEP.format(q=q, k=k, hosts=hosts, d=2 * (k - 1)),
        ndev=q * k)
    assert "OK" in out
