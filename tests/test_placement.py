"""Algorithm 1 placement — paper §III-A, Example 2."""

import numpy as np
import pytest

from repro.core.designs import make_design
from repro.core.placement import make_placement

SWEEP = [(2, 3, 1), (2, 3, 2), (3, 3, 1), (2, 4, 3), (4, 3, 2), (3, 2, 1),
         (2, 2, 4)]


@pytest.mark.parametrize("q,k,gamma", SWEEP)
def test_placement_valid(q, k, gamma):
    pl = make_placement(make_design(q, k), gamma)
    pl.validate()
    assert pl.N == k * gamma


@pytest.mark.parametrize("q,k,gamma", SWEEP)
def test_storage_fraction(q, k, gamma):
    """mu = (k-1)/K for every server (paper §III-A)."""
    d = make_design(q, k)
    pl = make_placement(d, gamma)
    for s in range(d.K):
        assert pl.storage_fraction(s) == pytest.approx((k - 1) / d.K)


@pytest.mark.parametrize("q,k,gamma", SWEEP)
def test_each_batch_on_k_minus_1_servers(q, k, gamma):
    d = make_design(q, k)
    pl = make_placement(d, gamma)
    M = pl.placement_matrix()  # [K, J, N]
    # every subfile is stored on exactly k-1 servers
    assert (M.sum(axis=0) == k - 1).all()
    # owners store (k-1)*gamma subfiles per owned job; non-owners none
    for s in range(d.K):
        for j in range(d.J):
            n = M[s, j].sum()
            assert n == ((k - 1) * gamma if d.is_owner(s, j) else 0)


def test_example2_batches():
    """Paper Example 2: job 1's subfiles live exclusively on U1, U3, U5."""
    d = make_design(2, 3)
    pl = make_placement(d, gamma=2)
    M = pl.placement_matrix()
    holders = {s for s in range(6) if M[s, 0].any()}
    assert holders == {0, 2, 4}
    # each batch of job 0 is on exactly two of the three owners
    for t in range(3):
        hs = pl.holders(0, t)
        assert len(hs) == 2 and set(hs) <= {0, 2, 4}


def test_label_perm_invariance():
    """Any batch<->owner bijection yields the same storage fraction and
    per-batch replication (DESIGN.md §8)."""
    d = make_design(2, 3)
    perms = [(1, 2, 0)] * d.J
    pl = make_placement(d, gamma=2, label_perm=perms)
    pl.validate()
    M = pl.placement_matrix()
    assert (M.sum(axis=0) == 2).all()


def test_batch_of_label_roundtrip():
    d = make_design(3, 3)
    pl = make_placement(d, gamma=1)
    for j in range(d.J):
        for t in range(d.k):
            lab = pl.batch_owner_label(j, t)
            assert pl.batch_of_label(j, lab) == t
