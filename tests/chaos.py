"""Deterministic chaos harness for the elastic runtime (DESIGN.md §14).

A :class:`FaultPlan` scripts worker churn against wave indices —
``Kill(wave, worker)``, ``Rejoin(wave, worker)``, and
``Straggle(wave, worker, waves, delay_s)`` — and
:class:`ChaosController` replays it through the
:class:`~repro.runtime.fault.ElasticController` hooks: kills/rejoins
fire when the stream starts the scripted wave, straggles inflate the
observed map timings the straggler detector sees. Everything is
deterministic: no randomness, no real clocks — the synthetic
``delay_s`` rides on top of whatever the engine measured, so a plan
replays identically on any machine.

The contract every plan must satisfy (asserted by
:func:`assert_bit_identical` in tests/test_elastic.py's sweep): the
elastic stream's output is BITWISE equal to the healthy serial oracle
for every churn schedule, and — after
:meth:`~repro.core.schedule.ScheduleCache.warm_survivors` — recovery
never pays a lowering.

No ``test_`` prefix: this module is the harness, not the suite.
"""

from dataclasses import dataclass

import numpy as np

from repro.core.engine import CAMRConfig, CAMREngine
from repro.runtime.fault import (ElasticController, Membership,
                                 StragglerPolicy)
from repro.runtime.jobstream import JobSpec, JobStream

__all__ = ["Kill", "Rejoin", "Straggle", "FaultPlan", "ChaosController",
           "make_specs", "serial_oracle", "run_plan",
           "assert_bit_identical"]


@dataclass(frozen=True)
class Kill:
    """Worker drops dead when wave ``wave`` starts (silent after map)."""

    wave: int
    worker: int


@dataclass(frozen=True)
class Rejoin:
    """Dead worker re-admitted when wave ``wave`` starts (pure
    re-placement: the replan receipt proves zero data movement)."""

    wave: int
    worker: int


@dataclass(frozen=True)
class Straggle:
    """Worker's observed map time inflated by ``delay_s`` for waves
    ``wave .. wave + waves - 1`` — what the straggler detector sees,
    not a real sleep, so plans replay deterministically."""

    wave: int
    worker: int
    waves: int = 1
    delay_s: float = 5.0


@dataclass(frozen=True)
class FaultPlan:
    """A named, scripted churn schedule (a tuple of events)."""

    events: tuple
    name: str = ""

    def workers(self) -> frozenset:
        return frozenset(ev.worker for ev in self.events)


class ChaosController(ElasticController):
    """Replays a :class:`FaultPlan` through the elastic hooks.

    Kills/rejoins apply exactly once, when their wave starts (under the
    controller lock, so an in-flight batch's re-target sees them
    atomically); straggles perturb the timing dict fed to
    :meth:`Membership.observe`.
    """

    def __init__(self, plan: FaultPlan, membership: Membership):
        super().__init__(membership)
        self.plan = plan
        self._applied: set = set()

    def on_wave_start(self, wave: int) -> None:
        for i, ev in enumerate(self.plan.events):
            if i in self._applied or ev.wave != wave:
                continue
            if isinstance(ev, Kill):
                self.membership.kill(ev.worker)
                self._applied.add(i)
            elif isinstance(ev, Rejoin):
                self.membership.rejoin(ev.worker)
                self._applied.add(i)

    def on_wave_timings(self, wave, timings):
        for ev in self.plan.events:
            if (isinstance(ev, Straggle)
                    and ev.wave <= wave < ev.wave + ev.waves
                    and ev.worker in timings):
                timings[ev.worker] = timings[ev.worker] + ev.delay_s
        return timings


# --------------------------------------------------------------------- #
# plan driver
# --------------------------------------------------------------------- #
def _identity_map(job, sf):
    return sf


def make_specs(q: int, k: int, waves: int, d: int = 8,
               seed: int = 0) -> list:
    """Waves of pre-mapped values (map = identity), like the benches."""
    cfg = CAMRConfig(q=q, k=k, gamma=1)
    Q = cfg.num_functions()
    rng = np.random.default_rng(seed)
    return [JobSpec(cfg, _identity_map,
                    [[rng.standard_normal((Q, d)).astype(np.float32)
                      for _ in range(cfg.N)] for _ in range(cfg.J)],
                    name=f"wave{w}")
            for w in range(waves)]


def serial_oracle(specs) -> list:
    """Healthy serial runs — the bit-identity reference for any churn."""
    return [CAMREngine(sp.cfg, sp.map_fn, combine=sp.combine).run(
        sp.datasets) for sp in specs]


def run_plan(specs, plan: FaultPlan, *, policy=None, pipeline=False,
             wave_batch=1):
    """Run ``specs`` through an elastic JobStream under ``plan``.

    Default policy disables timing-based demotion so scripted plans
    stay deterministic (µs-scale map noise must not steal the
    ``max_failed`` slot); straggler-detection tests pass an explicit
    policy with ``abs_timeout_s`` instead. Returns
    ``(results, stream, controller)``.
    """
    q, k = specs[0].cfg.q, specs[0].cfg.k
    policy = policy or StragglerPolicy(demote=False)
    ctrl = ChaosController(plan, Membership(q, k, policy=policy))
    stream = JobStream(elastic=ctrl, wave_batch=wave_batch,
                       pipeline=pipeline)
    return stream.run(specs), stream, ctrl


def assert_bit_identical(oracle, got, context="") -> None:
    for w, (want, res) in enumerate(zip(oracle, got)):
        for s, (a, b) in enumerate(zip(want, res)):
            assert a.keys() == b.keys(), (context, w, s)
            for key in a:
                assert np.array_equal(a[key], b[key]), \
                    (context, w, s, key)
