"""Deterministic chaos harness for the elastic runtime (DESIGN.md §14)
and the self-healing serving stack (DESIGN.md §15).

A :class:`FaultPlan` scripts worker churn against wave indices —
``Kill(wave, worker)``, ``Rejoin(wave, worker)``, and
``Straggle(wave, worker, waves, delay_s)`` — and
:class:`ChaosController` replays it through the
:class:`~repro.runtime.fault.ElasticController` hooks: kills/rejoins
fire when the stream starts the scripted wave, straggles inflate the
observed map timings the straggler detector sees. Everything is
deterministic: no randomness, no real clocks — the synthetic
``delay_s`` rides on top of whatever the engine measured, so a plan
replays identically on any machine.

The contract every plan must satisfy (asserted by
:func:`assert_bit_identical` in tests/test_elastic.py's sweep): the
elastic stream's output is BITWISE equal to the healthy serial oracle
for every churn schedule, and — after
:meth:`~repro.core.schedule.ScheduleCache.warm_survivors` — recovery
never pays a lowering.

The SERVING side scripts faults against decode-wave indices:
``WaveCrash(wave, times)`` raises between the device wave and its
commit (the supervisor must roll back to the wave-boundary snapshot
and retry), ``SlotPoison(wave, slot)`` corrupts one live slot's logits
to NaN on device (the jitted wave's sentinel must quarantine exactly
that slot), and ``WaveLatency(wave, delay_s)`` inflates the OBSERVED
wave wall time (drives the timeout-retry path — again no real sleeps).
:class:`ServeChaosController` also provides the stream's deadline
clock: a virtual time that advances ``tick_s`` per committed wave, so
deadline storms replay identically on any machine. The serving
contract (tests/test_serve_chaos.py): every request terminates with an
explicit status, survivors are BITWISE identical to the fault-free
run, and recovery pays zero retraces.

No ``test_`` prefix: this module is the harness, not the suite.
"""

from dataclasses import dataclass

import numpy as np

from repro.core.engine import CAMRConfig, CAMREngine
from repro.runtime.fault import (ElasticController, HostMembership,
                                 Membership, StragglerPolicy)
from repro.runtime.jobstream import JobSpec, JobStream
from repro.runtime.serve import ServeStream, WaveCrashError

__all__ = ["Kill", "Rejoin", "Straggle", "KillHost", "RejoinHost",
           "CorruptPacket", "FaultPlan", "ChaosController",
           "make_specs", "serial_oracle", "run_plan",
           "make_shuffle_waves", "run_host_plan",
           "assert_bit_identical", "WaveCrash", "SlotPoison",
           "WaveLatency", "ServeFaultPlan", "ServeChaosController",
           "run_serve_plan"]


@dataclass(frozen=True)
class Kill:
    """Worker drops dead when wave ``wave`` starts (silent after map)."""

    wave: int
    worker: int


@dataclass(frozen=True)
class Rejoin:
    """Dead worker re-admitted when wave ``wave`` starts (pure
    re-placement: the replan receipt proves zero data movement)."""

    wave: int
    worker: int


@dataclass(frozen=True)
class Straggle:
    """Worker's observed map time inflated by ``delay_s`` for waves
    ``wave .. wave + waves - 1`` — what the straggler detector sees,
    not a real sleep, so plans replay deterministically."""

    wave: int
    worker: int
    waves: int = 1
    delay_s: float = 5.0


@dataclass(frozen=True)
class KillHost:
    """Whole host ``host`` drops when wave ``wave`` starts — ONE
    correlated fault domain (DESIGN.md §17): its entire class-major
    device block dies at once, and the stream must re-home onto the
    surviving-host topology (two-level while divisibility holds, else
    flat) bitwise-identically."""

    wave: int
    host: int


@dataclass(frozen=True)
class RejoinHost:
    """Dead host re-admitted when wave ``wave`` starts; the stream
    re-homes back onto the larger host set (a warm cache hit)."""

    wave: int
    host: int


@dataclass(frozen=True)
class CorruptPacket:
    """One coded wire word of ``device``'s stage-``stage`` Δ is
    bit-flipped by ``bits`` in transit during wave ``wave`` — the
    integrity lane must detect it via the packet checksum and replay
    the wave bitwise, never silently mis-reduce. ``row=None`` targets
    the device's first participating group row (guaranteed on-wire)."""

    wave: int
    stage: int = 1
    device: int = 0
    row: int | None = None
    word: int = 0
    bits: int = 1


@dataclass(frozen=True)
class FaultPlan:
    """A named, scripted churn schedule (a tuple of events)."""

    events: tuple
    name: str = ""

    def workers(self) -> frozenset:
        return frozenset(w for w in (getattr(ev, "worker", None)
                                     for ev in self.events)
                         if w is not None)

    def hosts(self) -> frozenset:
        return frozenset(h for h in (getattr(ev, "host", None)
                                     for ev in self.events)
                         if h is not None)


class ChaosController(ElasticController):
    """Replays a :class:`FaultPlan` through the elastic hooks.

    Kills/rejoins apply exactly once, when their wave starts (under the
    controller lock, so an in-flight batch's re-target sees them
    atomically); straggles perturb the timing dict fed to
    :meth:`Membership.observe`.
    """

    def __init__(self, plan: FaultPlan, membership: Membership):
        super().__init__(membership)
        self.plan = plan
        self._applied: set = set()

    def on_wave_start(self, wave: int) -> None:
        for i, ev in enumerate(self.plan.events):
            if i in self._applied or ev.wave != wave:
                continue
            if isinstance(ev, Kill):
                self.membership.kill(ev.worker)
                self._applied.add(i)
            elif isinstance(ev, Rejoin):
                self.membership.rejoin(ev.worker)
                self._applied.add(i)

    def on_wave_timings(self, wave, timings):
        for ev in self.plan.events:
            if (isinstance(ev, Straggle)
                    and ev.wave <= wave < ev.wave + ev.waves
                    and ev.worker in timings):
                timings[ev.worker] = timings[ev.worker] + ev.delay_s
        return timings


# --------------------------------------------------------------------- #
# plan driver
# --------------------------------------------------------------------- #
def _identity_map(job, sf):
    return sf


def make_specs(q: int, k: int, waves: int, d: int = 8,
               seed: int = 0) -> list:
    """Waves of pre-mapped values (map = identity), like the benches."""
    cfg = CAMRConfig(q=q, k=k, gamma=1)
    Q = cfg.num_functions()
    rng = np.random.default_rng(seed)
    return [JobSpec(cfg, _identity_map,
                    [[rng.standard_normal((Q, d)).astype(np.float32)
                      for _ in range(cfg.N)] for _ in range(cfg.J)],
                    name=f"wave{w}")
            for w in range(waves)]


def serial_oracle(specs) -> list:
    """Healthy serial runs — the bit-identity reference for any churn."""
    return [CAMREngine(sp.cfg, sp.map_fn, combine=sp.combine).run(
        sp.datasets) for sp in specs]


def run_plan(specs, plan: FaultPlan, *, policy=None, pipeline=False,
             wave_batch=1):
    """Run ``specs`` through an elastic JobStream under ``plan``.

    Default policy disables timing-based demotion so scripted plans
    stay deterministic (µs-scale map noise must not steal the
    ``max_failed`` slot); straggler-detection tests pass an explicit
    policy with ``abs_timeout_s`` instead. Returns
    ``(results, stream, controller)``.
    """
    q, k = specs[0].cfg.q, specs[0].cfg.k
    policy = policy or StragglerPolicy(demote=False)
    ctrl = ChaosController(plan, Membership(q, k, policy=policy))
    stream = JobStream(elastic=ctrl, wave_batch=wave_batch,
                       pipeline=pipeline)
    return stream.run(specs), stream, ctrl


def make_shuffle_waves(q: int, k: int, waves: int, d: int = 12,
                       seed: int = 0, dtype=np.float32, mesh=None):
    """Waves of SPMD shuffle contributions plus their healthy oracle:
    ``(contribs [W][K, J_own, k-1, K, d], oracle [W][K, J, d])``.

    With a ``mesh``, the oracle is the HEALTHY flat stream's outputs —
    the bitwise anchor of §16/§17 (every topology, gateway assignment,
    and recovery path must match it word-for-word), itself gated
    allclose against the numpy reduction reference here so the anchor
    is numerically grounded. Without a mesh, the numpy reference is
    returned directly (allclose-grade only: the coded path reduces in
    a different association order)."""
    from repro.core.collective import (ShuffleStream,
                                       camr_shuffle_reference, make_plan,
                                       scatter_contributions)
    plan = make_plan(q, k, d)
    rng = np.random.default_rng(seed)
    contribs, refs = [], []
    for _ in range(waves):
        bg = rng.standard_normal(
            (plan.J, k, plan.K, d)).astype(np.float32).astype(dtype)
        contribs.append(scatter_contributions(plan, bg))
        refs.append(camr_shuffle_reference(plan, np.asarray(bg)))
    if mesh is None:
        return contribs, refs
    oracle = ShuffleStream(q, k, d, mesh=mesh).run_waves(contribs)
    rtol, atol = ((2e-5, 2e-6) if np.dtype(dtype) == np.float32
                  else (6e-2, 1e-1))       # bf16 wire: ~8-bit mantissa
    for got, ref in zip(oracle, refs):
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=rtol, atol=atol)
    return contribs, oracle


def run_host_plan(q: int, k: int, d: int, contribs, plan: FaultPlan, *,
                  mesh, hosts: int, verify_wire: bool = False,
                  warm: bool = True, axis_name: str = "camr"):
    """Run shuffle waves through a two-level :class:`ShuffleStream`
    under a host-granularity ``plan`` (DESIGN.md §17).

    ``KillHost``/``RejoinHost`` events drive a :class:`HostMembership`
    and re-home the stream onto its ``current_topology()`` — two-level
    over the survivors while divisibility holds, else the flat
    fallback; ``CorruptPacket`` arms the stream's one-shot wire fault
    (needs ``verify_wire=True``). Deterministic: faults fire exactly
    when their wave is submitted, one wave per dispatch. Returns
    ``(outputs, stream, host_membership)``.
    """
    from repro.core.collective import ShuffleStream
    from repro.core.schedule import Topology

    topo = Topology.two_level(hosts)
    hm = HostMembership(q, k, topo)
    stream = ShuffleStream(q, k, d, mesh=mesh, axis_name=axis_name,
                           topology=topo, verify_wire=verify_wire)
    if warm:
        stream.warm_host_survivors(max_host_failures=hosts - 1)
    applied: set = set()
    outs = []
    for w, contrib in enumerate(contribs):
        for i, ev in enumerate(plan.events):
            if i in applied or ev.wave != w:
                continue
            if isinstance(ev, KillHost):
                hm.kill_host(ev.host)
                stream.set_topology(hm.current_topology())
                applied.add(i)
            elif isinstance(ev, RejoinHost):
                hm.rejoin_host(ev.host)
                stream.set_topology(hm.current_topology())
                applied.add(i)
            elif isinstance(ev, CorruptPacket):
                stream.inject_corruption(stage=ev.stage,
                                         device=ev.device, row=ev.row,
                                         word=ev.word, bits=ev.bits)
                applied.add(i)
        outs.extend(stream.run_waves([contrib]))
    return outs, stream, hm


def assert_bit_identical(oracle, got, context="") -> None:
    for w, (want, res) in enumerate(zip(oracle, got)):
        for s, (a, b) in enumerate(zip(want, res)):
            assert a.keys() == b.keys(), (context, w, s)
            for key in a:
                assert np.array_equal(a[key], b[key]), \
                    (context, w, s, key)


# --------------------------------------------------------------------- #
# serving chaos (DESIGN.md §15): wave crashes, slot poison, latency
# --------------------------------------------------------------------- #
@dataclass(frozen=True)
class WaveCrash:
    """The first ``times`` attempts of committed wave ``wave`` die
    between the device wave and its commit — the supervisor must roll
    back to the snapshot and replay bitwise."""

    wave: int
    times: int = 1


@dataclass(frozen=True)
class SlotPoison:
    """Live slot ``slot``'s carried logits are corrupted to NaN on
    device when wave ``wave`` starts; the jitted wave's sentinel — not
    host code — must quarantine exactly that slot."""

    wave: int
    slot: int


@dataclass(frozen=True)
class WaveLatency:
    """The observed wall time of the first ``times`` attempts of wave
    ``wave`` is inflated by ``delay_s`` — what the timeout supervisor
    sees, not a real sleep, so timeout-retry plans replay
    deterministically (bounded ``times`` lets the retry recover)."""

    wave: int
    delay_s: float = 60.0
    times: int = 1


@dataclass(frozen=True)
class ServeFaultPlan:
    """A named, scripted serving fault schedule."""

    events: tuple
    name: str = ""


class ServeChaosController:
    """Replays a :class:`ServeFaultPlan` through the
    :class:`~repro.runtime.serve.ServeStream` chaos hooks, and serves
    as the stream's deterministic deadline clock (virtual time starts
    at 0 and advances ``tick_s`` per OBSERVED wave attempt — crashed
    attempts never reach ``on_wave_done`` and do not advance it, so a
    replayed-after-crash wave sees the same clock)."""

    def __init__(self, plan: ServeFaultPlan, tick_s: float = 1.0):
        self.plan = plan
        self.tick_s = tick_s
        self._t = 0.0
        self._crashes: dict[int, int] = {}
        self._lat: dict[int, int] = {}
        self._poisoned: set = set()
        self.injected_crashes = 0
        self.injected_poisons = 0
        for i, ev in enumerate(plan.events):
            if isinstance(ev, WaveCrash):
                self._crashes[i] = ev.times
            elif isinstance(ev, WaveLatency):
                self._lat[i] = ev.times

    # the stream's deadline clock (virtual, per-wave ticks)
    def now(self) -> float:
        return self._t

    def on_wave_start(self, model, wave, engine) -> None:
        for i, ev in enumerate(self.plan.events):
            if (isinstance(ev, SlotPoison) and ev.wave == wave
                    and i not in self._poisoned
                    and ev.slot in engine._live):
                engine.poison_slot(ev.slot)
                self._poisoned.add(i)
                self.injected_poisons += 1

    def on_wave_crash(self, model, wave, engine) -> None:
        for i, ev in enumerate(self.plan.events):
            if (isinstance(ev, WaveCrash) and ev.wave == wave
                    and self._crashes.get(i, 0) > 0):
                self._crashes[i] -= 1
                self.injected_crashes += 1
                raise WaveCrashError(
                    f"chaos: injected crash of wave {wave} "
                    f"(plan {self.plan.name!r})")

    def on_wave_done(self, model, wave, engine, wall_s: float) -> float:
        for i, ev in enumerate(self.plan.events):
            if (isinstance(ev, WaveLatency) and ev.wave == wave
                    and self._lat.get(i, 0) > 0):
                self._lat[i] -= 1
                wall_s = wall_s + ev.delay_s
        self._t += self.tick_s       # attempt observed: clock ticks
        return wall_s


def run_serve_plan(engine, requests, plan: ServeFaultPlan, *,
                   tick_s: float = 1.0, wave_len: int = 8,
                   pipeline: bool = False, **stream_kw):
    """Run ``requests`` through a ServeStream under ``plan``. Returns
    ``(results, stream, controller)``. ``pipeline=False`` by default:
    scripted plans address slots by wave index, so the wave schedule
    must be single-threaded deterministic."""
    ctrl = ServeChaosController(plan, tick_s=tick_s)
    stream = ServeStream(engine, wave_len=wave_len, pipeline=pipeline,
                         chaos=ctrl, **stream_kw)
    return stream.run(requests), stream, ctrl
