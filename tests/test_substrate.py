"""Substrate tests: optimizer, data pipeline, checkpointing, serving."""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, load_checkpoint, \
    save_checkpoint
from repro.configs import get_config, reduced
from repro.data.pipeline import ShardedTokenPipeline, wordcount_corpus
from repro.models import lm
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, \
    cosine_schedule
from repro.runtime import Trainer
from repro.runtime.serve import generate


# --------------------------------------------------------------------- #
# optimizer
# --------------------------------------------------------------------- #
def test_adamw_reduces_quadratic():
    params = {"w": jnp.ones((8,)) * 5.0}
    opt = adamw_init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(params, g, opt, lr=0.1,
                                      weight_decay=0.0)
    assert float(loss(params)) < 1e-2


def test_clip_by_global_norm():
    g = {"a": jnp.ones((4,)) * 100.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(200.0)
    n2 = float(jnp.linalg.norm(clipped["a"]))
    assert n2 == pytest.approx(1.0, rel=1e-5)


def test_cosine_schedule_shape():
    lrs = [float(cosine_schedule(jnp.int32(s), peak=1.0, warmup_steps=10,
                                 total_steps=100)) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0          # warmup
    assert lrs[99] < lrs[50] < lrs[11]     # decay
    assert max(lrs) <= 1.0 + 1e-6


def test_adamw_bf16_params_f32_moments():
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    opt = adamw_init(params)
    assert opt.mu["w"].dtype == jnp.float32
    g = {"w": jnp.ones((4,), jnp.bfloat16)}
    p2, opt2, _ = adamw_update(params, g, opt, lr=0.1)
    assert p2["w"].dtype == jnp.bfloat16
    assert int(opt2.step) == 1


# --------------------------------------------------------------------- #
# data pipeline
# --------------------------------------------------------------------- #
def test_pipeline_deterministic_and_sharded():
    p = ShardedTokenPipeline(vocab=100, seq_len=16, global_batch=8,
                             n_shards=2, seed=3)
    a = p.batch(5, shard=0)
    b = p.batch(5, shard=0)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])  # restart-safe
    c = p.batch(5, shard=1)
    assert not np.array_equal(a["tokens"], c["tokens"])      # disjoint
    assert a["tokens"].shape == (4, 16)
    # labels are next-token shifted
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])


def test_pipeline_microbatches():
    p = ShardedTokenPipeline(vocab=50, seq_len=8, global_batch=8)
    mbs = p.microbatches(0, 0, 4)
    assert len(mbs) == 4 and mbs[0]["tokens"].shape == (2, 8)
    full = p.batch(0, 0)
    np.testing.assert_array_equal(
        np.concatenate([m["tokens"] for m in mbs]), full["tokens"])


def test_wordcount_corpus_shapes():
    ds = wordcount_corpus(4, 6, 6, chapter_len=10)
    assert len(ds) == 4 and len(ds[0]) == 6 and ds[0][0].shape == (10,)


# --------------------------------------------------------------------- #
# checkpointing
# --------------------------------------------------------------------- #
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    save_checkpoint(str(tmp_path), tree, step=7, metadata={"x": 1})
    got, meta = load_checkpoint(str(tmp_path), tree)
    np.testing.assert_array_equal(np.asarray(got["a"]), np.arange(6
                                                                  ).reshape(2, 3))
    assert got["b"]["c"].dtype == jnp.bfloat16
    assert meta["x"] == 1 and meta["step"] == 7


def test_checkpoint_corruption_detected(tmp_path):
    tree = {"a": jnp.arange(4.0)}
    d = save_checkpoint(str(tmp_path), tree, step=1)
    # corrupt the array on disk
    fn = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    arr = np.load(os.path.join(d, fn))   # raw uint8 buffer
    arr[0] ^= 0xFF
    np.save(os.path.join(d, fn), arr)
    # the only step is corrupt: resume warns about it, then raises
    # because nothing intact remains
    with pytest.raises(IOError), \
            pytest.warns(RuntimeWarning, match="failed verification"):
        load_checkpoint(str(tmp_path), tree)


def test_checkpoint_corrupt_resume_falls_back_to_intact(tmp_path):
    """Resume (step=None) must skip corrupted steps and land on the
    newest INTACT one with a warning — a torn write never strands the
    restart (DESIGN.md §15). An explicit step still raises."""
    tree = {"a": jnp.arange(4.0)}
    d1 = save_checkpoint(str(tmp_path), {"a": jnp.full((4,), 1.0)},
                         step=1)
    d2 = save_checkpoint(str(tmp_path), {"a": jnp.full((4,), 2.0)},
                         step=2)
    d3 = save_checkpoint(str(tmp_path), {"a": jnp.full((4,), 3.0)},
                         step=3)
    # the manifest now carries a per-file crc32 alongside the payload
    # hash
    import json
    with open(os.path.join(d1, "manifest.json")) as f:
        assert all("crc32" in e
                   for e in json.load(f)["leaves"].values())
    # two distinct corruptions of the two newest steps: a flipped byte
    # (crc/hash mismatch) and a missing leaf file (torn write)
    fn = [f for f in os.listdir(d3) if f.endswith(".npy")][0]
    raw = np.load(os.path.join(d3, fn))
    raw[0] ^= 0xFF
    np.save(os.path.join(d3, fn), raw)
    os.remove(os.path.join(
        d2, [f for f in os.listdir(d2) if f.endswith(".npy")][0]))
    with pytest.warns(RuntimeWarning, match="failed verification"):
        got, meta = load_checkpoint(str(tmp_path), tree)
    assert meta["step"] == 1
    assert float(np.asarray(got["a"])[0]) == 1.0
    # asking for the corrupt step BY NAME must not silently fall back
    with pytest.raises(IOError):
        load_checkpoint(str(tmp_path), tree, step=3)


def test_checkpoint_manager_async_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.zeros((3,))}
    for s in (1, 2, 3, 4):
        mgr.save({"w": jnp.full((3,), float(s))}, step=s)
    mgr.wait()
    assert mgr.latest_step() == 4
    got, meta = mgr.restore(tree)
    assert float(got["w"][0]) == 4.0
    from repro.checkpoint.ckpt import available_steps
    assert available_steps(str(tmp_path)) == [3, 4]  # retention
    mgr.close()


def test_trainer_crash_resume(tmp_path):
    """Kill-and-restart: the resumed run continues from the checkpoint
    (same params, same data cursor)."""
    cfg = reduced(get_config("granite_3_2b")).replace(
        n_layers=2, vocab=64, loss_chunk=16)
    pipe = ShardedTokenPipeline(vocab=64, seq_len=16, global_batch=4)
    t1 = Trainer(cfg, ckpt_dir=str(tmp_path), total_steps=50, seed=1)
    t1.run(pipe, steps=6, ckpt_every=3)
    # "crash": new trainer object, resume from disk
    t2 = Trainer(cfg, ckpt_dir=str(tmp_path), total_steps=50, seed=999)
    assert t2.resume()
    assert t2.step == 6
    ref = jax.tree.leaves(t1.params)
    got = jax.tree.leaves(t2.params)
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


# --------------------------------------------------------------------- #
# serving
# --------------------------------------------------------------------- #
def test_generate_greedy_deterministic():
    cfg = reduced(get_config("granite_3_2b")).replace(n_layers=2)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    prompts = np.array([[1, 2, 3, 4], [4, 3, 2, 1]], np.int32)
    r1 = generate(cfg, params, prompts, max_new=6)
    r2 = generate(cfg, params, prompts, max_new=6)
    np.testing.assert_array_equal(r1.tokens, r2.tokens)
    assert r1.tokens.shape == (2, 10)


def test_generate_matches_teacher_forcing():
    """Greedy decode must agree with argmax over a full forward pass."""
    cfg = reduced(get_config("granite_3_2b")).replace(n_layers=2)
    params = lm.init_params(cfg, jax.random.PRNGKey(3))
    prompts = np.array([[5, 6, 7, 8, 9, 10]], np.int32)
    r = generate(cfg, params, prompts, max_new=3)
    # teacher-force the generated prefix, check each next-token argmax
    toks = r.tokens
    for i in range(3):
        lg, _ = jax.jit(lambda p, b: lm.prefill(cfg, p, b))(
            params, {"tokens": jnp.asarray(toks[:, :6 + i])})
        want = int(jnp.argmax(lg[0, -1]))
        assert want == int(toks[0, 6 + i])
