"""shard_map MoE (EP a2a / EP-replicated / TP) vs the no-mesh reference —
run in subprocesses with 8 host devices."""

import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_RUN = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.compat import make_mesh
    from repro.configs import get_config, reduced
    from repro.models import lm
    from repro.launch import partitioning as pt
    mesh = make_mesh((4, 2), ('data', 'model'))
    cfg = reduced(get_config('{arch}'))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {{'tokens': jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)),
                                    jnp.int32),
              'labels': jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)),
                                    jnp.int32)}}
    ref, g0 = jax.jit(lambda p: jax.value_and_grad(
        lambda pp: lm.train_loss(cfg, pp, batch)[0])(p))(params)
    def gstep(p):
        with pt.axis_rules(mesh):
            return jax.value_and_grad(
                lambda pp: lm.train_loss(cfg, pp, batch)[0])(p)
    with mesh:
        got, g = jax.jit(gstep)(params)
    assert abs(float(ref) - float(got)) < 2e-4, (float(ref), float(got))
    d = max(float(jnp.abs(a.astype(jnp.float32)
                          - b.astype(jnp.float32)).max())
            for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(g0)))
    assert d < 2e-2, d
    # decode path (EP-replicated for 'ep' mode)
    T = 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (8, T + 1)), jnp.int32)
    lg_full, _ = jax.jit(lambda p: lm.prefill(cfg, p,
                                              {{'tokens': toks}}))(params)
    def dstep(p):
        with pt.axis_rules(mesh):
            _, cache = lm.prefill(cfg, p, {{'tokens': toks[:, :T]}},
                                  max_len=T + 1)
            lg, _ = lm.decode_step(cfg, p, cache, toks[:, T:T + 1],
                                   jnp.int32(T))
            return lg
    with mesh:
        lg_dec = jax.jit(dstep)(params)
    np.testing.assert_allclose(np.asarray(lg_full), np.asarray(lg_dec),
                               rtol=2e-3, atol=2e-3)
    print('OK')
""")


def _run_subprocess(code: str, ndev: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["moonshot_v1_16b_a3b", "mixtral_8x7b"])
def test_moe_mesh_parity(arch):
    out = _run_subprocess(_RUN.format(arch=arch))
    assert "OK" in out
