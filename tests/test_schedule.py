"""ShuffleProgram IR — lowering invariants shared by all three executors."""

import numpy as np
import pytest

from repro.core.designs import make_design
from repro.core.engine import CAMRConfig, CAMREngine
from repro.core.collective import make_plan
from repro.core.placement import make_placement
from repro.core.schedule import lower_degraded, lower_program

CONFIGS = [(2, 3), (3, 3), (4, 3), (2, 4), (3, 4)]


def _program(q, k, d=None, **kw):
    pl = make_placement(make_design(q, k), gamma=1)
    return lower_program(pl, d=d, **kw)


@pytest.mark.parametrize("q,k", CONFIGS)
def test_group_table_partition(q, k):
    """The q^k value vectors split into J stage-1 groups (= owner sets)
    and J(q-1) stage-2 groups; every group has one member per class."""
    prog = _program(q, k)
    d = prog.design
    assert prog.n_groups == q ** k
    assert len(prog.s1_rows) == d.J
    assert len(prog.s2_rows) == d.J * (q - 1)
    for row in range(prog.n_groups):
        G = prog.group_members(row)
        assert [d.class_of(s) for s in G] == list(range(k))
        assert list(G) == sorted(G)
    # stage-1 rows are in job order: group of row s1_rows[j] = owners[j]
    for j in range(d.J):
        assert prog.group_members(int(prog.s1_rows[j])) == d.owners[j]
    # stage-2 rows enumerate stage2_groups() in the same (rank) order
    for row, G in zip(prog.s2_rows, d.stage2_groups()):
        assert prog.group_members(int(row)) == G


@pytest.mark.parametrize("q,k", CONFIGS)
def test_chunk_storage_conditions(q, k):
    """Each chunk is missed by its receiver and stored by every other
    group member (the Lemma-2 condition both coded stages rely on)."""
    prog = _program(q, k)
    pl = prog.placement
    for row in range(prog.n_groups):
        G = prog.group_members(row)
        for kp, job, batch in prog.coded_chunks(row):
            assert not pl.stores(kp, job, batch)
            for s in G:
                if s != kp:
                    assert pl.stores(s, job, batch)


@pytest.mark.parametrize("q,k", [(2, 3), (4, 3), (3, 4)])
def test_routing_tables_roundtrip(q, k):
    """Sender and receiver agree on every routing slot, for both the
    all_to_all and the ppermute router, in every round of both stages."""
    prog = _program(q, k, d=2 * (k - 1))
    for stage in (1, 2):
        T = prog.stage_tables(stage)
        R = int(T.R)
        for r in range(1, k):
            for li, row in enumerate(T.rows):
                G = prog.group_members(int(row))
                for iu, u in enumerate(G):
                    w = G[(iu + r) % k]
                    # a2a: receiver w finds sender u's block at u*R + idx
                    slot = int(T.a2a_recv[r - 1, w, li])
                    assert slot // R == u
                    assert int(T.a2a_send[r - 1, u, w, slot % R]) == li
                    # ppermute: same block under the (r, delta) sub-round
                    delta = ((w % q) - (u % q)) % q
                    pslot = int(T.pp_recv[r - 1, w, li])
                    assert pslot // R == delta
                    assert int(T.pp_send[r - 1, delta, u, pslot % R]) == li
                    # and the sub-round permutation routes u -> w
                    perm = dict(T.pp_perms[r - 1][delta])
                    assert perm[u] == w
        # sub-round perms are full device permutations
        for r in range(1, k):
            for delta in range(q):
                perm = T.pp_perms[r - 1][delta]
                assert sorted(p[0] for p in perm) == list(range(prog.K))
                assert sorted(p[1] for p in perm) == list(range(prog.K))


@pytest.mark.parametrize("q,k", [(2, 3), (4, 3)])
def test_engine_and_plan_share_tables(q, k):
    """Acceptance: CAMREngine and camr_shuffle consume the SAME compiled
    schedule — identical group/chunk/stage-3 tables."""
    eng = CAMREngine(CAMRConfig(q=q, k=k, gamma=1),
                     lambda job, sf: np.zeros((q * k, 1)))
    plan = make_plan(q, k, d=2 * (k - 1))
    a, b = eng.program, plan.program
    for name in ("groups", "stage_of", "chunk_job", "chunk_batch",
                 "s1_rows", "s2_rows", "owned_jobs", "stored_batches",
                 "s3_job", "s3_recv", "s3_send", "s3_batches",
                 "is_own", "own_slot", "s2_ord", "s3_off"):
        np.testing.assert_array_equal(getattr(a, name), getattr(b, name),
                                      err_msg=name)


def test_lowering_is_cached():
    pl = make_placement(make_design(2, 3), gamma=1)
    assert lower_program(pl, Q=6) is lower_program(pl, Q=6)


@pytest.mark.parametrize("q,k,failed", [(2, 3, {0}), (3, 3, {4}),
                                        (2, 4, {0, 7})])
def test_degraded_lowering_structure(q, k, failed):
    prog = _program(q, k)
    deg = lower_degraded(prog, failed)
    d = prog.design
    # migration stays inside the parallel class, on a live server
    for s in range(prog.K):
        tgt = int(deg.migrate[s])
        assert tgt not in failed
        if s not in failed:
            assert tgt == s
        else:
            assert d.class_of(tgt) == d.class_of(s)
    # coded + uncoded rows partition the group table; a row is degraded
    # iff it contains a failed member, and its senders are live
    uncoded_rows = {row for row, _ in deg.uncoded}
    assert uncoded_rows | set(deg.coded_rows) == set(range(prog.n_groups))
    assert not (uncoded_rows & set(deg.coded_rows))
    for row, sends in deg.uncoded:
        assert set(prog.group_members(row)) & failed
        for holder, rcv, job, batch, owner in sends:
            assert holder not in failed
            assert rcv not in failed
            assert prog.placement.stores(holder, job, batch)
    for row in deg.coded_rows:
        assert not (set(prog.group_members(row)) & failed)
    # stage-3 senders and receivers are live
    for snd, rcv, job, owner, batches in deg.s3:
        assert snd not in failed
        assert rcv not in failed


def test_degraded_lowering_rejects_unrecoverable():
    prog = _program(2, 3)
    with pytest.raises(ValueError):
        lower_degraded(prog, {0, 1})   # same parallel class
    with pytest.raises(ValueError):
        lower_degraded(prog, {0, 4})   # a batch loses both replicas
