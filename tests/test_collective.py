"""shard_map CAMR shuffle vs oracle — run in subprocesses with K host
devices (the main test process must keep seeing 1 device)."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.collective import (CAMRPlan, ShuffleStream,
                                   camr_collective_bytes, camr_shuffle,
                                   expected_collective_calls, make_plan)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_RUN = textwrap.dedent("""
    import numpy as np, jax
    from jax.sharding import PartitionSpec as P
    from repro.compat import make_mesh, shard_map
    from repro.core.collective import (make_plan, camr_shuffle,
        scatter_contributions, camr_shuffle_reference, uncoded_reduce_scatter,
        expected_collective_calls)
    q, k, d = {q}, {k}, {d}
    plan = make_plan(q, k, d); K = plan.K
    rng = np.random.default_rng(0)
    bg = rng.standard_normal((plan.J, k, K, d)).astype(np.float32)
    contribs = scatter_contributions(plan, bg)
    mesh = make_mesh((K,), ('camr',))
    ref = camr_shuffle_reference(plan, bg)

    def count_collectives(jaxpr):
        n = dict(ppermute=0, all_to_all=0)
        def walk(jx):
            for eqn in jx.eqns:
                if eqn.primitive.name in n:
                    n[eqn.primitive.name] += 1
                for v in eqn.params.values():
                    for sub in (v if isinstance(v, (list, tuple)) else [v]):
                        if hasattr(sub, 'eqns'):
                            walk(sub)
                        elif hasattr(sub, 'jaxpr'):
                            walk(sub.jaxpr)
        walk(jaxpr.jaxpr)
        return n

    first = None
    for mode, router, codec in [('batched', 'all_to_all', 'fused'),
                                ('batched', 'ppermute', 'fused'),
                                ('looped', 'all_to_all', 'fused'),
                                ('batched', 'all_to_all', 'multipass')]:
        fn = shard_map(
            lambda c: camr_shuffle(plan, c[0], axis_name='camr', mode=mode,
                                   router=router, codec=codec)[None],
            mesh=mesh, in_specs=P('camr'), out_specs=P('camr'))
        out = np.asarray(jax.jit(fn)(contribs))
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-6)
        # fused and multipass codecs are BIT-identical, not just close
        first = out if first is None else first
        np.testing.assert_array_equal(out, first, err_msg=(mode, codec))
        counts = count_collectives(jax.make_jaxpr(fn)(contribs))
        want = expected_collective_calls(plan, mode, router)
        got12 = counts['all_to_all'] + counts['ppermute'] - (q - 1)
        assert got12 == want['stage12'], (mode, router, counts, want)
        assert counts['ppermute'] + counts['all_to_all'] == want['total']

    g = jax.jit(shard_map(
        lambda c: uncoded_reduce_scatter(c[0], axis_name='camr',
                                         plan=plan)[None],
        mesh=mesh, in_specs=P('camr'), out_specs=P('camr')))
    np.testing.assert_allclose(np.asarray(g(contribs)), ref,
                               rtol=2e-5, atol=2e-6)
    print('OK')
""")

# seeded regression pinned to the ENGINE oracle: the SPMD collective and
# the numpy interpreter execute the same ShuffleProgram in the same
# canonical combine order (delivered batch + ascending fold), so their
# per-device outputs must be BITWISE equal — the contract the training
# integration's cross-mode parameter identity rests on (DESIGN.md §11).
_RUN_ENGINE = textwrap.dedent("""
    import numpy as np, jax
    from jax.sharding import PartitionSpec as P
    from repro.compat import make_mesh, shard_map
    from repro.core.collective import (make_plan, camr_shuffle,
        scatter_contributions)
    from repro.core.engine import CAMRConfig, CAMREngine
    q, k, d = {q}, {k}, {d}
    plan = make_plan(q, k, d); K = plan.K
    rng = np.random.default_rng({seed})
    bg = rng.standard_normal((plan.J, k, K, d)).astype(np.float32)

    # engine run: gamma=1, Q=K; map_fn(job, subfile t) = bg[job, t]
    cfg = CAMRConfig(q=q, k=k, gamma=1)
    eng = CAMREngine(cfg, lambda job, sf: sf)
    datasets = [[bg[j, t] for t in range(k)] for j in range(plan.J)]
    results = eng.run(datasets)
    eng.verify(datasets, results)

    contribs = scatter_contributions(plan, bg)
    mesh = make_mesh((K,), ('camr',))
    f = jax.jit(shard_map(
        lambda c: camr_shuffle(plan, c[0], axis_name='camr')[None],
        mesh=mesh, in_specs=P('camr'), out_specs=P('camr')))
    out = np.asarray(f(contribs))
    for s in range(K):
        for j in range(plan.J):
            np.testing.assert_array_equal(
                out[s, j], results[s][(j, s)],
                err_msg=f'device {{s}} job {{j}}')
    print('OK')
""")


# ShuffleStream (DESIGN.md §9): async double-buffered multi-wave
# dispatch, same-shaped waves stacked along d into ONE program
# execution. Per-wave outputs must be bit-identical to single-wave
# dispatch (the codec is elementwise per value column).
_RUN_STREAM = textwrap.dedent("""
    import numpy as np, jax
    from jax.sharding import PartitionSpec as P
    from repro.compat import make_mesh, shard_map
    from repro.core.collective import (ShuffleStream, make_plan,
        camr_shuffle, camr_shuffle_reference, scatter_contributions)
    q, k, d, waves = {q}, {k}, {d}, 5
    plan = make_plan(q, k, d); K = plan.K
    rng = np.random.default_rng(3)
    bgs = [rng.standard_normal((plan.J, k, K, d)).astype(np.float32)
           for _ in range(waves)]
    contribs = [scatter_contributions(plan, bg) for bg in bgs]
    mesh = make_mesh((K,), ('camr',))
    serial_fn = jax.jit(shard_map(
        lambda c: camr_shuffle(plan, c[0], axis_name='camr')[None],
        mesh=mesh, in_specs=P('camr'), out_specs=P('camr')))
    serial = [np.asarray(serial_fn(c)) for c in contribs]
    for wave_batch in (1, 2, 3):
        stream = ShuffleStream(q, k, d, mesh=mesh, wave_batch=wave_batch,
                               depth=2)
        outs = stream.run_waves(contribs)
        assert len(outs) == waves
        for out, bg, ser in zip(outs, bgs, serial):
            np.testing.assert_allclose(
                out, camr_shuffle_reference(plan, bg),
                rtol=2e-5, atol=2e-6)
            np.testing.assert_array_equal(out, ser)
    # incremental submit/drain keeps submission order
    stream = ShuffleStream(q, k, d, mesh=mesh, wave_batch=2, depth=1)
    for c in contribs[:3]:
        stream.submit(c)
    outs = stream.drain()
    for out, ser in zip(outs, serial):
        np.testing.assert_array_equal(out, ser)
    # sync(): the multi-step training path — one compiled executor
    # reused across calls, device-resident output, bit-identical to
    # the per-wave dispatch
    stream = ShuffleStream(q, k, d, mesh=mesh)
    for c, ser in zip(contribs, serial):
        got = stream.sync(c)
        assert isinstance(got, jax.Array)
        np.testing.assert_array_equal(np.asarray(got), ser)
    st = stream.stats()
    assert st['dispatches'] == len(contribs) and st['compiles'] == 1, st
    print('OK')
""")


def _run_subprocess(code: str, ndev: int) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


@pytest.mark.parametrize("q,k,d", [(2, 3, 8), (4, 3, 16), (3, 4, 9),
                                   (2, 4, 6)])
def test_camr_shuffle_multidevice(q, k, d):
    out = _run_subprocess(_RUN.format(q=q, k=k, d=d), ndev=q * k)
    assert "OK" in out


@pytest.mark.parametrize("q,k,d,seed", [(2, 3, 8, 7), (4, 3, 16, 11)])
def test_camr_shuffle_matches_engine_oracle(q, k, d, seed):
    """The SPMD executor and the numpy engine interpret the SAME
    ShuffleProgram: per-device outputs must match the engine's reduce
    results (seeded regression for the decode path)."""
    out = _run_subprocess(_RUN_ENGINE.format(q=q, k=k, d=d, seed=seed),
                          ndev=q * k)
    assert "OK" in out


@pytest.mark.parametrize("q,k,d", [(2, 3, 8), (3, 4, 9)])
def test_shuffle_stream_multidevice(q, k, d):
    """Async double-buffered ShuffleStream == per-wave serial dispatch,
    bit for bit, at every wave_batch width."""
    out = _run_subprocess(_RUN_STREAM.format(q=q, k=k, d=d), ndev=q * k)
    assert "OK" in out


def test_expected_collective_calls_model():
    plan = make_plan(4, 3, 16)
    want = expected_collective_calls(plan, "batched", "all_to_all")
    # the headline number: 2*(k-1) batched collectives for stages 1+2,
    # independent of J (the looped path needs (J + n_s2)*(k-1) = 128)
    assert want["stage12"] == 2 * (plan.k - 1) == 4
    looped = expected_collective_calls(plan, "looped")
    assert looped["stage12"] == (plan.J + plan.program.n_s2) * (plan.k - 1)
    assert looped["stage12"] == 128
    pp = expected_collective_calls(plan, "batched", "ppermute")
    assert pp["stage12"] == 2 * (plan.k - 1) * plan.q


def test_plan_validation():
    with pytest.raises(ValueError):
        make_plan(2, 2, 8)  # k >= 3 for the TPU path
    with pytest.raises(ValueError):
        make_plan(2, 3, 7)  # d not divisible by k-1


def test_codec_dtype_guard():
    """Uncodable dtypes fail AT ENTRY with an actionable message (not a
    bare TypeError from the wire packing deep inside the trace); the
    16-bit floats are NOT rejected — they ride the packed codec lane
    (DESIGN.md §12)."""
    import jax.numpy as jnp

    from repro.core.collective import CODEC_DTYPES, check_codec_dtype
    plan = make_plan(2, 3, 8)
    # numpy f64 (jnp.zeros would silently truncate to f32 without x64)
    bad = np.zeros((plan.J_own, plan.k - 1, plan.K, plan.d),
                   np.float64)
    with pytest.raises(TypeError, match="astype"):
        camr_shuffle(plan, bad, axis_name="camr")
    # the guard names the entry point, so users see WHERE to cast
    with pytest.raises(TypeError, match="camr_shuffle"):
        camr_shuffle(plan, bad, axis_name="camr")
    with pytest.raises(TypeError, match="int8"):
        check_codec_dtype(jnp.int8, "camr_shuffle")
    # bf16/f16 pass every entry guard (the packed 16-bit lane) — the
    # stale advice to cast them UP to f32 would double bytes-on-wire
    for name in ("bfloat16", "float16"):
        assert name in CODEC_DTYPES
        check_codec_dtype(jnp.dtype(name), "camr_shuffle")
    # ShuffleStream rejects uncodable waves at submit, never mid-flight
    stream = ShuffleStream(2, 3, 8, mesh=None)
    wave = np.zeros((stream.K, 2, 2, stream.K, 8), np.float64)
    with pytest.raises(TypeError, match="ShuffleStream"):
        stream.submit(wave)
    # ...and accepts a packed-lane wave (wave_batch=2: no dispatch, no
    # mesh needed — this asserts the GUARD, not the execution)
    stream16 = ShuffleStream(2, 3, 8, mesh=None, wave_batch=2)
    stream16.submit(np.zeros((stream16.K, 2, 2, stream16.K, 8),
                             jnp.bfloat16))


def test_codec_validation():
    import jax.numpy as jnp
    plan = make_plan(2, 3, 8)
    ok = jnp.zeros((plan.J_own, plan.k - 1, plan.K, plan.d), jnp.float32)
    with pytest.raises(ValueError, match="codec"):
        camr_shuffle(plan, ok, axis_name="camr", codec="nope")
    with pytest.raises(ValueError, match="codec"):
        ShuffleStream(2, 3, 8, mesh=None, codec="nope")


def test_shuffle_stream_validation():
    """Width/k checks fire at construction, never mid-stream (a partial
    trailing batch must not be able to fail after waves completed)."""
    with pytest.raises(ValueError):
        ShuffleStream(2, 2, 8, mesh=None)   # k >= 3
    with pytest.raises(ValueError):
        ShuffleStream(2, 3, 9, mesh=None)   # d % (k-1)
    with pytest.raises(ValueError):
        ShuffleStream(2, 3, 8, mesh=None, depth=0)


def test_plan_tables_consistent():
    plan = make_plan(3, 3, 8)
    K, J_own = plan.K, plan.J_own
    assert plan.owned_jobs.shape == (K, J_own)
    # each job appears in exactly k owner lists
    flat = plan.owned_jobs.ravel().tolist()
    for j in range(plan.J):
        assert flat.count(j) == plan.k
    # stage-3 permutations: q-1 full intra-class cyclic shifts
    assert len(plan.s3_perms) == plan.q - 1
    for perm in plan.s3_perms:
        assert len(perm) == K
        assert sorted(p[0] for p in perm) == list(range(K))
        assert sorted(p[1] for p in perm) == list(range(K))


def test_collective_bytes_model():
    """p2p byte counts: stages 1-2 carry k packets of d/(k-1) per group per
    round; totals beat a dense ring-psum of [J, K, d]."""
    plan = make_plan(2, 3, 8)
    b = camr_collective_bytes(plan, itemsize=4)
    K, J, d, k = plan.K, plan.J, plan.d, plan.k
    assert b["stage1"] == J * (k - 1) * (d // (k - 1)) * 4 * k
    assert b["stage3"] == (plan.q - 1) * plan.J_own * d * 4 * K
    assert b["camr_total"] < b["psum_ring_total"]
