"""shard_map CAMR shuffle vs oracle — run in subprocesses with K host
devices (the main test process must keep seeing 1 device)."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.collective import CAMRPlan, camr_collective_bytes, make_plan

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_RUN = textwrap.dedent("""
    import numpy as np, jax
    from jax.sharding import PartitionSpec as P
    from repro.core.collective import (make_plan, camr_shuffle,
        scatter_contributions, camr_shuffle_reference, uncoded_reduce_scatter)
    q, k, d = {q}, {k}, {d}
    plan = make_plan(q, k, d); K = plan.K
    rng = np.random.default_rng(0)
    bg = rng.standard_normal((plan.J, k, K, d)).astype(np.float32)
    contribs = scatter_contributions(plan, bg)
    mesh = jax.make_mesh((K,), ('camr',),
                         axis_types=(jax.sharding.AxisType.Auto,))
    f = jax.jit(jax.shard_map(
        lambda c: camr_shuffle(plan, c[0], axis_name='camr')[None],
        mesh=mesh, in_specs=P('camr'), out_specs=P('camr')))
    out = np.asarray(f(contribs))
    ref = camr_shuffle_reference(plan, bg)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-6)
    g = jax.jit(jax.shard_map(
        lambda c: uncoded_reduce_scatter(c[0], axis_name='camr',
                                         plan=plan)[None],
        mesh=mesh, in_specs=P('camr'), out_specs=P('camr')))
    np.testing.assert_allclose(np.asarray(g(contribs)), ref,
                               rtol=2e-5, atol=2e-6)
    print('OK')
""")


def _run_subprocess(code: str, ndev: int) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


@pytest.mark.parametrize("q,k,d", [(2, 3, 8), (4, 3, 16), (3, 4, 9),
                                   (2, 4, 6)])
def test_camr_shuffle_multidevice(q, k, d):
    out = _run_subprocess(_RUN.format(q=q, k=k, d=d), ndev=q * k)
    assert "OK" in out


def test_plan_validation():
    with pytest.raises(ValueError):
        make_plan(2, 2, 8)  # k >= 3 for the TPU path
    with pytest.raises(ValueError):
        make_plan(2, 3, 7)  # d not divisible by k-1


def test_plan_tables_consistent():
    plan = make_plan(3, 3, 8)
    K, J_own = plan.K, plan.J_own
    assert plan.owned_jobs.shape == (K, J_own)
    # each job appears in exactly k owner lists
    flat = plan.owned_jobs.ravel().tolist()
    for j in range(plan.J):
        assert flat.count(j) == plan.k
    # stage-3 permutations: q-1 full intra-class cyclic shifts
    assert len(plan.s3_perms) == plan.q - 1
    for perm in plan.s3_perms:
        assert len(perm) == K
        assert sorted(p[0] for p in perm) == list(range(K))
        assert sorted(p[1] for p in perm) == list(range(K))


def test_collective_bytes_model():
    """p2p byte counts: stages 1-2 carry k packets of d/(k-1) per group per
    round; totals beat a dense ring-psum of [J, K, d]."""
    plan = make_plan(2, 3, 8)
    b = camr_collective_bytes(plan, itemsize=4)
    K, J, d, k = plan.K, plan.J, plan.d, plan.k
    assert b["stage1"] == J * (k - 1) * (d // (k - 1)) * 4 * k
    assert b["stage3"] == (plan.q - 1) * plan.J_own * d * 4 * K
    assert b["camr_total"] < b["psum_ring_total"]
