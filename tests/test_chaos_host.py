"""Host-granularity chaos (DESIGN.md §17): scripted whole-host failure
re-homes the two-level stream bitwise onto the surviving topology with
zero cold lowerings after warm-up, and scripted wire corruption is
detected by the integrity lane and replayed bitwise — never silently
mis-reduced — on both wire lanes."""

import os
import subprocess
import sys
import textwrap

import pytest

from chaos import CorruptPacket, FaultPlan, Kill, KillHost, RejoinHost

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_subprocess(code: str, ndev: int) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src"), os.path.join(ROOT, "tests")])
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


_PRELUDE = textwrap.dedent("""
    import numpy as np
    from repro.compat import make_mesh
    from repro.core.schedule import SCHEDULE_CACHE
    from chaos import (CorruptPacket, FaultPlan, KillHost, RejoinHost,
                       make_shuffle_waves, run_host_plan)
""")


_RUN_KILL_HOST = _PRELUDE + textwrap.dedent("""
    q, k, hosts, d = {q}, {k}, {hosts}, {d}
    K = q * k
    mesh = make_mesh((K,), ('camr',))
    contribs, oracle = make_shuffle_waves(q, k, 5, d=d, mesh=mesh)
    plan = FaultPlan(({events}), name='kill-host')
    outs, stream, hm = run_host_plan(q, k, d, contribs, plan,
                                     mesh=mesh, hosts=hosts)
    for w, (got, want) in enumerate(zip(outs, oracle)):
        assert np.array_equal(got, want), f'wave {{w}} not bitwise'
    st = stream.stats()
    assert st['host_swaps'] == {swaps}, st
    assert hm.failed_hosts() == {dead_hosts}
    print('OK')
""")


@pytest.mark.parametrize("q,k,hosts", [(2, 4, 2), (2, 6, 3)])
def test_kill_host_recovers_bitwise(q, k, hosts):
    """A scripted whole-host kill mid-stream re-homes onto the
    surviving topology and every wave stays BITWISE identical to the
    healthy serial oracle; the rejoin re-homes back."""
    events = ("KillHost(wave=1, host=%d), RejoinHost(wave=3, host=%d),"
              % (hosts - 1, hosts - 1))
    out = _run_subprocess(
        _RUN_KILL_HOST.format(q=q, k=k, hosts=hosts, d=2 * (k - 1),
                              events=events, swaps=2,
                              dead_hosts="frozenset()"),
        ndev=q * k)
    assert "OK" in out


def test_kill_host_flat_fallback_bitwise():
    """hosts=4, k=4: losing one host leaves 3, which does not divide
    k — the stream falls back to the FLAT lowering (still bitwise);
    a second kill lands back on two_level(2)."""
    events = "KillHost(wave=1, host=3), KillHost(wave=2, host=2),"
    out = _run_subprocess(
        _RUN_KILL_HOST.format(q=2, k=4, hosts=4, d=6, events=events,
                              swaps=2, dead_hosts="{2, 3}"),
        ndev=8)
    assert "OK" in out


_RUN_WARM_GATE = _PRELUDE + textwrap.dedent("""
    q, k, hosts, d = 2, 4, 2, 6
    K = q * k
    mesh = make_mesh((K,), ('camr',))
    contribs, oracle = make_shuffle_waves(q, k, 4, d=d, mesh=mesh)

    from repro.core.collective import ShuffleStream
    from repro.core.schedule import Topology
    from repro.runtime.fault import HostMembership
    topo = Topology.two_level(hosts)
    hm = HostMembership(q, k, topo)
    stream = ShuffleStream(q, k, d, mesh=mesh, topology=topo)
    stream.warm_host_survivors(max_host_failures=hosts - 1)
    outs = stream.run_waves(contribs[:2])          # healthy steady state
    misses_warm = SCHEDULE_CACHE.stats()['misses']
    hm.kill_host(1)
    stream.set_topology(hm.current_topology())
    outs += stream.run_waves(contribs[2:])
    assert SCHEDULE_CACHE.stats()['misses'] == misses_warm, \\
        'host recovery paid a cold lowering'
    for w, (got, want) in enumerate(zip(outs, oracle)):
        assert np.array_equal(got, want), f'wave {w} not bitwise'
    print('OK')
""")


def test_kill_host_recovery_is_pure_cache_hit():
    """The acceptance gate: after ``warm_host_survivors``, host-loss
    recovery pays ZERO cold schedule lowerings (misses stay flat across
    the kill) while outputs stay bitwise."""
    out = _run_subprocess(_RUN_WARM_GATE, ndev=8)
    assert "OK" in out


_RUN_CORRUPT = _PRELUDE + textwrap.dedent("""
    import jax.numpy as jnp
    q, k, hosts, d = {q}, {k}, {hosts}, {d}
    K = q * k
    dtype = {dtype}
    mesh = make_mesh((K,), ('camr',))
    contribs, oracle = make_shuffle_waves(q, k, 4, d=d, dtype=dtype,
                                          mesh=mesh)
    plan = FaultPlan((CorruptPacket(wave=1, stage=1, device=0, bits=1),
                      CorruptPacket(wave=2, stage=2, device=K - 1,
                                    word=0, bits=0x80000000),),
                     name='corrupt')
    outs, stream, hm = run_host_plan(q, k, d, contribs, plan,
                                     mesh=mesh, hosts=hosts,
                                     verify_wire=True)
    for w, (got, want) in enumerate(zip(outs, oracle)):
        assert got.dtype == want.dtype
        assert np.array_equal(got, want), f'wave {{w}} not bitwise'
    st = stream.stats()
    assert st['wire_faults'] == 2, st
    assert st['wire_replays'] == 2, st
    print('OK')
""")


@pytest.mark.parametrize("dtype", ["np.float32", "jnp.bfloat16"])
def test_corrupt_packet_detected_and_replayed_bitwise(dtype):
    """Scripted single-word wire corruption in each coded stage is
    DETECTED by the checksum lane and replayed bitwise through the
    clean executor on both wire lanes (f32 and packed bf16) — never a
    silent mis-reduce."""
    out = _run_subprocess(
        _RUN_CORRUPT.format(q=2, k=4, hosts=2, d=6, dtype=dtype),
        ndev=8)
    assert "OK" in out


_RUN_KILL_PLUS_CORRUPT = _PRELUDE + textwrap.dedent("""
    q, k, hosts, d = 2, 4, 2, 6
    K = q * k
    mesh = make_mesh((K,), ('camr',))
    contribs, oracle = make_shuffle_waves(q, k, 4, d=d, mesh=mesh)
    plan = FaultPlan((CorruptPacket(wave=1),
                      KillHost(wave=2, host=0),), name='combined')
    outs, stream, hm = run_host_plan(q, k, d, contribs, plan,
                                     mesh=mesh, hosts=hosts,
                                     verify_wire=True)
    for w, (got, want) in enumerate(zip(outs, oracle)):
        assert np.array_equal(got, want), f'wave {w} not bitwise'
    st = stream.stats()
    assert st['wire_faults'] == 1 and st['host_swaps'] == 1, st
    print('OK')
""")


def test_combined_corruption_then_host_kill():
    """The two §17 fault models compose: a wire fault on wave 1 and a
    host kill on wave 2 both recover bitwise in one stream."""
    out = _run_subprocess(_RUN_KILL_PLUS_CORRUPT, ndev=8)
    assert "OK" in out


# --------------------------------------------------------------------- #
# in-process: the chaos vocabulary itself
# --------------------------------------------------------------------- #
def test_host_event_defaults_and_plan_queries():
    ev = CorruptPacket(wave=3)
    assert (ev.stage, ev.device, ev.row, ev.word, ev.bits) == \
        (1, 0, None, 0, 1)
    plan = FaultPlan((Kill(wave=0, worker=2), KillHost(wave=1, host=1),
                      RejoinHost(wave=2, host=1), CorruptPacket(wave=3)),
                     name="mixed")
    assert plan.workers() == {2}          # host events carry no worker
    assert plan.hosts() == {1}            # worker events carry no host


def test_corrupt_packet_requires_verify_wire():
    from repro.core.collective import ShuffleStream
    stream = ShuffleStream(2, 4, 6, mesh=None)
    with pytest.raises(ValueError, match="verify_wire"):
        stream.inject_corruption()
