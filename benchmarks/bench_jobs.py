"""Paper Table III — minimum job requirement, CAMR vs CCDC (K = 100)."""

import time

from repro.core import loads


def rows():
    out = []
    for q, k in [(50, 2), (25, 4), (20, 5), (10, 10), (5, 20), (2, 50)]:
        t0 = time.perf_counter()
        j_camr = loads.camr_min_jobs(q, k)
        mu = (k - 1) / (q * k)
        j_ccdc = loads.ccdc_min_jobs(mu, q * k)
        us = (time.perf_counter() - t0) * 1e6
        out.append({
            "name": f"jobs_K100_muK{k - 1}",
            "us_per_call": us,
            "derived": (f"J_CAMR={j_camr} J_CCDC={j_ccdc} "
                        f"ratio={j_ccdc / j_camr:.1f}x"),
        })
    return out
