"""Elastic recovery gap: warm vs cold survivor-set re-lowering.

Acceptance numbers for live elasticity (DESIGN.md §14): stream waves
through an elastic :class:`~repro.runtime.jobstream.JobStream` while a
scripted controller kills one worker mid-stream (and rejoins it later),
then price the RECOVERY PATH — what the kill boundary pays before the
first degraded batch can shuffle. Two variants of the same churn:

  warm   :meth:`ScheduleCache.warm_survivors` pre-lowered every
         single-failure schedule, so recovery is a pure cache hit. The
         elastic run's lowering count must be ZERO (hard gate — this is
         the §14 cache warm-up contract, not a speed preference).
  cold   the cache is cleared first, so the kill boundary pays a full
         degraded re-lowering on the critical path.

Both end-to-end runs are verified BIT-identical to the healthy serial
oracle before anything is reported (the churn contract). The strict
gate times the recovery lookup itself — ``SCHEDULE_CACHE.degraded`` as
a cold miss vs a warm hit — because at these cluster sizes the numpy
interpreter's per-batch wall time (ms, noisy) cannot resolve the
sub-ms lowering; the per-batch kill gap is still reported from
:attr:`StreamReport.batch_times` for the record. Warm recovery must
beat cold; under ``CAMR_BENCH_STRICT=1`` a miss is fatal, otherwise it
is a stderr warning.

    PYTHONPATH=src python -m benchmarks.bench_elastic [--smoke]
"""

import argparse
import os
import sys
import time

import numpy as np

from benchmarks.bench_jobstream import make_specs
from repro.core.engine import CAMREngine
from repro.core.schedule import SCHEDULE_CACHE
from repro.runtime.fault import (ElasticController, Membership,
                                 StragglerPolicy)
from repro.runtime.jobstream import JobStream

# (q, k, waves, kill_at, rejoin_at, worker) — kill mid-stream, rejoin
# before the tail so every membership edge is on the measured path
CONFIGS = [(3, 3, 12, 5, 9, 4), (2, 4, 12, 5, 9, 3), (4, 3, 10, 4, 8, 7)]
SMOKE_CONFIGS = [(2, 4, 8, 3, 6, 2)]
D = 32            # small value width: the bench times the runtime and
                  # the recovery path, not the shuffle arithmetic
RECOVERED = 1.5   # batch time back within 1.5x pre-kill median


class ScriptedChurn(ElasticController):
    """Deterministic churn: kill/rejoin workers at scripted waves."""

    def __init__(self, membership, kills=None, rejoins=None):
        super().__init__(membership)
        self.kills = dict(kills or {})
        self.rejoins = dict(rejoins or {})

    def on_wave_start(self, wave):
        if wave in self.kills:
            self.membership.kill(self.kills.pop(wave))
        if wave in self.rejoins:
            self.membership.rejoin(self.rejoins.pop(wave))


def _serial_oracle(specs):
    return [CAMREngine(sp.cfg, sp.map_fn, combine=sp.combine).run(
        sp.datasets) for sp in specs]


def _run_churned(specs, kill_at, rejoin_at, worker, warm, oracle):
    q, k = specs[0].cfg.q, specs[0].cfg.k
    if warm:
        SCHEDULE_CACHE.warm_survivors(
            CAMREngine(specs[0].cfg, specs[0].map_fn).program)
    else:
        SCHEDULE_CACHE.clear()
    # demote=False: the churn schedule is scripted; µs-scale map noise
    # must not let the detector steal the one max_failed slot
    member = Membership(q, k, policy=StragglerPolicy(demote=False))
    ctrl = ScriptedChurn(member,
                         kills={kill_at: worker},
                         rejoins={rejoin_at: worker})
    # wave_batch=1 + no pipelining: batch_times[i] is exactly wave i's
    # wall time, so the kill boundary is attributable to one sample
    stream = JobStream(elastic=ctrl, wave_batch=1, pipeline=False)
    got = stream.run(specs)
    for want, res in zip(oracle, got):
        for a, b in zip(want, res):
            assert a.keys() == b.keys()
            for key in a:
                assert np.array_equal(a[key], b[key]), key
    return stream.last_report


def _recovery_path(program, worker) -> tuple:
    """(cold s, warm s): the kill boundary's schedule lookup as a cold
    miss (full degraded re-lowering) vs a warm_survivors hit — the
    exact call :class:`~repro.runtime.fault.DegradedCAMREngine` makes
    on the recovery critical path. Best of 3 each (scheduler noise)."""
    cold, hot = [], []
    for _ in range(3):
        SCHEDULE_CACHE.clear()
        t0 = time.perf_counter()
        SCHEDULE_CACHE.degraded(program, {worker})
        cold.append(time.perf_counter() - t0)
        SCHEDULE_CACHE.warm_survivors(program)
        t0 = time.perf_counter()
        SCHEDULE_CACHE.degraded(program, {worker})
        hot.append(time.perf_counter() - t0)
    return min(cold), min(hot)


def _kill_gap(times, kill_at):
    """(kill-batch gap s vs pre-kill median, batches until back within
    RECOVERED x the pre-kill median)."""
    med = float(np.median(times[1:kill_at]))    # drop batch-0 warmup
    gap = times[kill_at] - med
    steps = len(times) - kill_at
    for i in range(kill_at, len(times)):
        if times[i] <= RECOVERED * med:
            steps = i - kill_at
            break
    return gap, steps


def bench_config(q, k, waves, kill_at, rejoin_at, worker, name):
    specs = make_specs(q, k, waves, d=D)
    oracle = _serial_oracle(specs)
    cold = _run_churned(specs, kill_at, rejoin_at, worker, False, oracle)
    warm = _run_churned(specs, kill_at, rejoin_at, worker, True, oracle)
    if warm.cache_misses != 0:
        raise SystemExit(
            f"{name}: warm elastic run paid {warm.cache_misses} "
            "lowerings — warm_survivors must make recovery a pure "
            "cache hit (DESIGN.md §14)")
    prog = CAMREngine(specs[0].cfg, specs[0].map_fn).program
    cold_rec, warm_rec = _recovery_path(prog, worker)
    cold_gap, cold_steps = _kill_gap(cold.batch_times, kill_at)
    warm_gap, warm_steps = _kill_gap(warm.batch_times, kill_at)
    return dict(
        name=name, waves=waves, kill_at=kill_at, rejoin_at=rejoin_at,
        cold_recovery_s=cold_rec, warm_recovery_s=warm_rec,
        cold_gap_s=cold_gap, warm_gap_s=warm_gap,
        cold_steps=cold_steps, warm_steps=warm_steps,
        cold_lowerings=cold.cache_misses,
        migrations=warm.migrations,
    )


# (q, k, hosts) — whole-host fault domains (DESIGN.md §17); mesh-free
# like the rest of this bench (CI runs on one CPU device)
HOST_CONFIGS = [(2, 4, 2), (2, 6, 3)]
SMOKE_HOST_CONFIGS = [(2, 4, 2)]


def _host_recovery_path(q, k, hosts) -> tuple:
    """(cold s, warm s, warm misses): the ``kill_host`` boundary's
    surviving-topology schedule lookup as a cold miss (full two-level/
    flat re-lowering) vs a ``warm_host_survivors`` hit — the exact
    ``ScheduleCache.program`` call ``ShuffleStream.set_topology`` pays
    on the recovery critical path. Best of 3; the warm pass then walks
    the WHOLE survivor ladder and reports any cold misses it paid
    (must be zero: the §17 warm-recovery contract)."""
    from repro.core.schedule import Topology, surviving_topology
    d = 2 * (k - 1)                      # (k-1) | d, same as the tests
    cold, hot = [], []
    misses = 0
    for _ in range(3):
        SCHEDULE_CACHE.clear()
        SCHEDULE_CACHE.program(q, k, Q=q * k, d=d,
                               topology=Topology.two_level(hosts))
        t0 = time.perf_counter()
        SCHEDULE_CACHE.program(q, k, Q=q * k, d=d,
                               topology=surviving_topology(hosts - 1, k))
        cold.append(time.perf_counter() - t0)
        SCHEDULE_CACHE.clear()
        prog = SCHEDULE_CACHE.program(q, k, Q=q * k, d=d,
                                      topology=Topology.two_level(hosts))
        SCHEDULE_CACHE.warm_host_survivors(prog,
                                           max_host_failures=hosts - 1)
        m0 = SCHEDULE_CACHE.stats()["misses"]
        t0 = time.perf_counter()
        SCHEDULE_CACHE.program(q, k, Q=q * k, d=d,
                               topology=surviving_topology(hosts - 1, k))
        hot.append(time.perf_counter() - t0)
        for lost in range(2, hosts):     # the rest of the ladder
            SCHEDULE_CACHE.program(
                q, k, Q=q * k, d=d,
                topology=surviving_topology(hosts - lost, k))
        misses = SCHEDULE_CACHE.stats()["misses"] - m0
    return min(cold), min(hot), misses


def host_rows(smoke: bool, strict: bool) -> list:
    """Host-kill lane: warm vs cold surviving-topology re-homing."""
    out = []
    for q, k, hosts in (SMOKE_HOST_CONFIGS if smoke else HOST_CONFIGS):
        name = f"elastic_host_q{q}_k{k}_h{hosts}"
        cold_s, warm_s, misses = _host_recovery_path(q, k, hosts)
        if misses != 0:
            raise SystemExit(
                f"{name}: warm survivor-ladder walk paid {misses} "
                "lowerings — warm_host_survivors must make host-loss "
                "recovery a pure cache hit (DESIGN.md §17)")
        if not warm_s < cold_s:
            msg = (f"{name}: warm host recovery {warm_s * 1e6:.0f}us "
                   f"did not beat cold re-lowering {cold_s * 1e6:.0f}us")
            if strict:
                raise SystemExit(msg)
            print(f"WARNING: {msg} (set CAMR_BENCH_STRICT=1 to make "
                  "this fatal)", file=sys.stderr)
        out.append({
            "name": name,
            "us_per_call": warm_s * 1e6,
            "config": {"q": q, "k": k, "hosts": hosts},
            "derived": (f"kill_host recovery cold={cold_s * 1e6:.0f}us "
                        f"warm={warm_s * 1e6:.0f}us "
                        f"warm_lowerings=0 ladder={hosts - 1} "
                        f"survivor topologies"),
        })
    return out


def rows(smoke: bool | None = None):
    """Suite entry point for benchmarks/run.py."""
    if smoke is None:
        smoke = os.environ.get("CAMR_BENCH_SMOKE", "") == "1"
    strict = os.environ.get("CAMR_BENCH_STRICT") == "1"
    out = []
    for q, k, w, ka, ra, wk in (SMOKE_CONFIGS if smoke else CONFIGS):
        r = bench_config(q, k, w, ka, ra, wk,
                         f"elastic_q{q}_k{k}_w{w}_kill{ka}")
        if not r["warm_recovery_s"] < r["cold_recovery_s"]:
            msg = (f"{r['name']}: warm recovery "
                   f"{r['warm_recovery_s'] * 1e6:.0f}us did not beat "
                   f"cold re-lowering "
                   f"{r['cold_recovery_s'] * 1e6:.0f}us")
            if strict:
                raise SystemExit(msg)
            print(f"WARNING: {msg} (set CAMR_BENCH_STRICT=1 to make "
                  "this fatal)", file=sys.stderr)
        out.append({
            "name": r["name"],
            "us_per_call": r["warm_recovery_s"] * 1e6,
            "derived": (f"waves={r['waves']} kill@{r['kill_at']} "
                        f"rejoin@{r['rejoin_at']} "
                        f"recovery cold={r['cold_recovery_s'] * 1e6:.0f}us"
                        f" warm={r['warm_recovery_s'] * 1e6:.0f}us "
                        f"kill_gap cold={r['cold_gap_s'] * 1e3:.2f}ms "
                        f"warm={r['warm_gap_s'] * 1e3:.2f}ms "
                        f"cold_lowerings={r['cold_lowerings']} "
                        f"warm_lowerings=0 "
                        f"recover_steps={r['warm_steps']} "
                        f"migrations={r['migrations']}"),
        })
    out.extend(host_rows(smoke, strict))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one tiny config (CI smoke for the README "
                         "commands)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in rows(smoke=args.smoke):
        print(f"{row['name']},{row['us_per_call']:.1f},"
              f"\"{row['derived']}\"", flush=True)


if __name__ == "__main__":
    main()
