"""Fault-tolerance overhead: degraded-mode shuffle load vs healthy.

Not a paper table — it quantifies the recovery protocol DESIGN.md §7
builds on the paper's placement redundancy (one shuffle-only recovery per
single failure; the paper's load is the healthy row), under the §3
bus/p2p accounting."""

import time

import numpy as np

from repro.core.engine import CAMRConfig, CAMREngine
from repro.runtime.fault import DegradedCAMREngine


def rows():
    out = []
    for q, k, failed in [(2, 3, {0}), (3, 3, {4}), (2, 4, {7}),
                         (4, 3, {1})]:
        cfg = CAMRConfig(q=q, k=k, gamma=1)
        rng = np.random.default_rng(0)
        dim = 4 * (k - 1)
        ds = [[rng.standard_normal(dim) for _ in range(cfg.N)]
              for _ in range(cfg.J)]

        def map_fn(job, sf):
            return np.outer(np.arange(1, cfg.num_functions() + 1), sf)

        healthy = CAMREngine(cfg, map_fn)
        healthy.verify(ds, healthy.run(ds))
        lh = healthy.measured_loads()["L_total_bus"]

        t0 = time.perf_counter()
        deg = DegradedCAMREngine(cfg, map_fn, failed=failed)
        deg.run(ds)
        us = (time.perf_counter() - t0) * 1e6
        ld = deg.trace.total_bytes() / (
            cfg.J * cfg.num_functions() * deg.value_bytes)
        out.append({
            "name": f"degraded_q{q}_k{k}_f{len(failed)}",
            "us_per_call": us,
            "derived": (f"L_healthy={lh:.4f} L_degraded={ld:.4f} "
                        f"inflation={ld / lh:.2f}x"),
        })
    return out
