"""Two-level topology byte model vs the flat schedule (DESIGN.md §16).

Per config, lowers the two-level overlay and MEASURES per-edge bytes by
walking the actual send tables (:func:`repro.core.collective
.camr_edge_bytes`), then gates them against the closed forms:

* measured inter-host bytes — flat AND two-level — must equal the
  ``camr_edge_loads`` / ``camr_load_hierarchical`` prediction EXACTLY
  (``load * J * K * B``, ``B = d * itemsize``): the analytic model and
  the lowered tables are the same object, not an approximation;
* the two-level schedule must cut inter-host bytes vs flat on every
  benched config (factor ``hosts/k``, strict because every config here
  has ``hosts < k``).

Both gates are deterministic table-walks (no timing noise); a miss is
fatal under ``CAMR_BENCH_STRICT=1`` and a loud warning otherwise,
matching the repo's gate idiom. CI runs this suite strict in the
topology-smoke step (.github/workflows/ci.yml).
"""

import os
import sys
import time

import numpy as np

from repro.core.collective import camr_edge_bytes, make_plan
from repro.core.loads import (camr_edge_loads, camr_load_hierarchical,
                              uncoded_load_hierarchical)
from repro.core.schedule import Topology, payload_words

# every config has hosts < k: the dedup factor hosts/k is a strict cut
CONFIGS = [(2, 4, 2), (3, 4, 2), (2, 6, 2), (2, 6, 3)]

# (q, k) x wire lane for the integrity overhead lane (DESIGN.md §17)
INTEGRITY_CONFIGS = [(2, 4), (2, 6), (3, 4)]
INTEGRITY_LANES = [("f32", 4), ("bf16", 2)]


def _gate(ok: bool, msg: str) -> None:
    if ok:
        return
    if os.environ.get("CAMR_BENCH_STRICT") == "1":
        raise AssertionError(msg)
    print(f"WARNING: {msg} (set CAMR_BENCH_STRICT=1 to make this "
          "fatal)", file=sys.stderr)


def rows(d: int | None = None, alpha: float = 4.0):
    out = []
    for q, k, hosts in CONFIGS:
        dd = 2 * (k - 1) if d is None else d
        t0 = time.perf_counter()
        plan = make_plan(q, k, dd, topology=Topology.two_level(hosts,
                                                               alpha=alpha))
        eb = camr_edge_bytes(plan)
        us = (time.perf_counter() - t0) * 1e6
        J, K, B = plan.J, plan.K, dd * 4
        for sched in ("flat", "two_level"):
            intra, inter = camr_edge_loads(q, k, hosts, schedule=sched)
            for edge, load in (("inter", inter), ("intra", intra)):
                got = eb[f"{sched}_{edge}_bytes"]
                want = load * J * K * B
                _gate(abs(got - want) < 1e-6,
                      f"q{q}k{k}h{hosts} {sched} {edge}: measured "
                      f"{got}B != predicted {want}B")
        _gate(eb["two_level_inter_bytes"] < eb["flat_inter_bytes"],
              f"q{q}k{k}h{hosts}: two-level inter bytes "
              f"{eb['two_level_inter_bytes']} not < flat "
              f"{eb['flat_inter_bytes']}")
        cut = eb["flat_inter_bytes"] / eb["two_level_inter_bytes"]
        out.append({
            "name": f"topology_q{q}_k{k}_h{hosts}",
            "us_per_call": us,
            "config": {"q": q, "k": k, "hosts": hosts, "d": dd,
                       "alpha": alpha},
            "inter_bytes_flat": eb["flat_inter_bytes"],
            "inter_bytes_two_level": eb["two_level_inter_bytes"],
            "intra_bytes_flat": eb["flat_intra_bytes"],
            "intra_bytes_two_level": eb["two_level_intra_bytes"],
            "hier_load": camr_load_hierarchical(q, k, hosts, alpha),
            "uncoded_hier_load": uncoded_load_hierarchical(q, k, hosts,
                                                           alpha),
            "derived": (f"K={plan.K} inter {eb['flat_inter_bytes']}B->"
                        f"{eb['two_level_inter_bytes']}B (x{cut:.2f} cut"
                        f"=k/hosts) intra {eb['flat_intra_bytes']}B->"
                        f"{eb['two_level_intra_bytes']}B "
                        f"L_hier(a={alpha:g})="
                        f"{camr_load_hierarchical(q, k, hosts, alpha):.3f}"
                        ),
        })
    out.extend(integrity_rows(d))
    return out


def integrity_rows(d: int | None = None) -> list:
    """Self-verifying wire overhead (DESIGN.md §17).

    The integrity lane folds ONE checksum word (the XOR of the
    packet's ``pk`` payload words) into each coded packet, widening
    rows from ``pk`` to ``pk + 1`` wire words. Gates, all
    deterministic:

    * the wire-word overhead is EXACTLY ``1/pk`` on both lanes (the
      closed form the augmented reshape implements — one word per
      packet, nothing else);
    * zero false positives: a numpy mirror of the decode-side fold
      accepts every clean packet;
    * zero false negatives at one word: EVERY single-word flip —
      payload or checksum word, any bit pattern — is detected
      (exhaustive sweep over all ``(round, word)`` positions);
    * XOR-linearity: checksums of XOR-combined packets XOR-combine —
      the property that lets the fold commute with the codec so the
      decode side can verify without re-deriving any schedule state.
    """
    out = []
    rng = np.random.default_rng(0)
    for q, k in INTEGRITY_CONFIGS:
        dd = 2 * (k - 1) if d is None else d
        for lane, itemsize in INTEGRITY_LANES:
            wp = payload_words(dd, itemsize, k)
            _gate(wp % (k - 1) == 0,
                  f"integrity q{q}k{k} {lane}: payload {wp} words does "
                  f"not split into k-1={k - 1} packets")
            pk = wp // (k - 1)
            t0 = time.perf_counter()
            # numpy mirror of the wire fold: [G, k-1, pk] -> + csum word
            G = 8
            w = rng.integers(0, 2 ** 32, size=(G, k - 1, pk),
                             dtype=np.uint32)
            csum = np.bitwise_xor.reduce(w, axis=2)
            aug = np.concatenate([w, csum[:, :, None]], axis=2)
            ratio = aug.size / w.size
            _gate(abs(ratio - (pk + 1) / pk) < 1e-12,
                  f"integrity q{q}k{k} {lane}: wire overhead {ratio} "
                  f"!= (pk+1)/pk = {(pk + 1) / pk}")
            # zero false positives on the clean wire
            calc = np.bitwise_xor.reduce(aug[:, :, :pk], axis=2)
            _gate(bool((calc == aug[:, :, pk]).all()),
                  f"integrity q{q}k{k} {lane}: clean packet failed "
                  "its own checksum")
            # zero false negatives at one word: exhaustive flip sweep
            missed = 0
            for r in range(k - 1):
                for word in range(pk + 1):
                    for bits in (1, 0x80000000, 0xDEADBEEF):
                        t = aug.copy()
                        t[0, r, word] ^= np.uint32(bits)
                        c = np.bitwise_xor.reduce(t[0, :, :pk], axis=1)
                        if (c == t[0, :, pk]).all():
                            missed += 1
            _gate(missed == 0,
                  f"integrity q{q}k{k} {lane}: {missed} single-word "
                  "flips evaded the checksum")
            # XOR-linearity: the fold commutes with the codec
            a, b = aug[0], aug[1]
            _gate(bool((np.bitwise_xor.reduce((a ^ b)[:, :pk], axis=1)
                        == (a ^ b)[:, pk]).all()),
                  f"integrity q{q}k{k} {lane}: checksum not XOR-linear")
            us = (time.perf_counter() - t0) * 1e6
            flips = (k - 1) * (pk + 1) * 3
            out.append({
                "name": f"integrity_q{q}_k{k}_{lane}",
                "us_per_call": us,
                "config": {"q": q, "k": k, "d": dd, "lane": lane,
                           "itemsize": itemsize},
                "overhead_ratio": (pk + 1) / pk,
                "derived": (f"pk={pk} wire {pk}->{pk + 1} words/packet "
                            f"(+{100 / pk:.1f}%) detected {flips}/"
                            f"{flips} single-word flips, 0 false "
                            "positives"),
            })
    return out


if __name__ == "__main__":
    for r in rows():
        print(f"{r['name']},{r['us_per_call']:.1f},\"{r['derived']}\"")
