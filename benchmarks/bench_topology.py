"""Two-level topology byte model vs the flat schedule (DESIGN.md §16).

Per config, lowers the two-level overlay and MEASURES per-edge bytes by
walking the actual send tables (:func:`repro.core.collective
.camr_edge_bytes`), then gates them against the closed forms:

* measured inter-host bytes — flat AND two-level — must equal the
  ``camr_edge_loads`` / ``camr_load_hierarchical`` prediction EXACTLY
  (``load * J * K * B``, ``B = d * itemsize``): the analytic model and
  the lowered tables are the same object, not an approximation;
* the two-level schedule must cut inter-host bytes vs flat on every
  benched config (factor ``hosts/k``, strict because every config here
  has ``hosts < k``).

Both gates are deterministic table-walks (no timing noise); a miss is
fatal under ``CAMR_BENCH_STRICT=1`` and a loud warning otherwise,
matching the repo's gate idiom. CI runs this suite strict in the
topology-smoke step (.github/workflows/ci.yml).
"""

import os
import sys
import time

from repro.core.collective import camr_edge_bytes, make_plan
from repro.core.loads import (camr_edge_loads, camr_load_hierarchical,
                              uncoded_load_hierarchical)
from repro.core.schedule import Topology

# every config has hosts < k: the dedup factor hosts/k is a strict cut
CONFIGS = [(2, 4, 2), (3, 4, 2), (2, 6, 2), (2, 6, 3)]


def _gate(ok: bool, msg: str) -> None:
    if ok:
        return
    if os.environ.get("CAMR_BENCH_STRICT") == "1":
        raise AssertionError(msg)
    print(f"WARNING: {msg} (set CAMR_BENCH_STRICT=1 to make this "
          "fatal)", file=sys.stderr)


def rows(d: int | None = None, alpha: float = 4.0):
    out = []
    for q, k, hosts in CONFIGS:
        dd = 2 * (k - 1) if d is None else d
        t0 = time.perf_counter()
        plan = make_plan(q, k, dd, topology=Topology.two_level(hosts,
                                                               alpha=alpha))
        eb = camr_edge_bytes(plan)
        us = (time.perf_counter() - t0) * 1e6
        J, K, B = plan.J, plan.K, dd * 4
        for sched in ("flat", "two_level"):
            intra, inter = camr_edge_loads(q, k, hosts, schedule=sched)
            for edge, load in (("inter", inter), ("intra", intra)):
                got = eb[f"{sched}_{edge}_bytes"]
                want = load * J * K * B
                _gate(abs(got - want) < 1e-6,
                      f"q{q}k{k}h{hosts} {sched} {edge}: measured "
                      f"{got}B != predicted {want}B")
        _gate(eb["two_level_inter_bytes"] < eb["flat_inter_bytes"],
              f"q{q}k{k}h{hosts}: two-level inter bytes "
              f"{eb['two_level_inter_bytes']} not < flat "
              f"{eb['flat_inter_bytes']}")
        cut = eb["flat_inter_bytes"] / eb["two_level_inter_bytes"]
        out.append({
            "name": f"topology_q{q}_k{k}_h{hosts}",
            "us_per_call": us,
            "config": {"q": q, "k": k, "hosts": hosts, "d": dd,
                       "alpha": alpha},
            "inter_bytes_flat": eb["flat_inter_bytes"],
            "inter_bytes_two_level": eb["two_level_inter_bytes"],
            "intra_bytes_flat": eb["flat_intra_bytes"],
            "intra_bytes_two_level": eb["two_level_intra_bytes"],
            "hier_load": camr_load_hierarchical(q, k, hosts, alpha),
            "uncoded_hier_load": uncoded_load_hierarchical(q, k, hosts,
                                                           alpha),
            "derived": (f"K={plan.K} inter {eb['flat_inter_bytes']}B->"
                        f"{eb['two_level_inter_bytes']}B (x{cut:.2f} cut"
                        f"=k/hosts) intra {eb['flat_intra_bytes']}B->"
                        f"{eb['two_level_intra_bytes']}B "
                        f"L_hier(a={alpha:g})="
                        f"{camr_load_hierarchical(q, k, hosts, alpha):.3f}"
                        ),
        })
    return out


if __name__ == "__main__":
    for r in rows():
        print(f"{r['name']},{r['us_per_call']:.1f},\"{r['derived']}\"")
