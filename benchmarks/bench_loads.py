"""Paper §IV / §V — measured vs analytic loads, CAMR vs CCDC vs uncoded.

Reproduces:
  * Example 1-5 stage loads (K=6, q=2, k=3): 1/4 + 1/4 + 1/2 = 1
  * L_CAMR == L_CCDC at equal storage fraction (§V)
  * the uncoded-aggregated baseline for context
Every CAMR row is MEASURED (bytes on the simulated wire), not just the
closed form; analytic values are printed alongside for the diff.
"""

import time

import numpy as np

from repro.core import loads
from repro.core.engine import CAMRConfig, CAMREngine


def _run(q, k, gamma=1, dim=None):
    cfg = CAMRConfig(q=q, k=k, gamma=gamma)
    dim = dim or 4 * max(1, k - 1)
    rng = np.random.default_rng(0)
    ds = [[rng.standard_normal(dim) for _ in range(cfg.N)]
          for _ in range(cfg.J)]

    def map_fn(job, sf):
        return np.outer(np.arange(1, cfg.num_functions() + 1), sf)

    eng = CAMREngine(cfg, map_fn)
    t0 = time.perf_counter()
    results = eng.run(ds)
    dt = (time.perf_counter() - t0) * 1e6
    eng.verify(ds, results)
    return eng, dt


def rows():
    out = []
    for q, k in [(2, 3), (3, 3), (2, 4), (4, 3), (3, 4), (2, 5), (6, 2)]:
        eng, us = _run(q, k)
        L = eng.measured_loads()
        mu = loads.storage_fraction(q, k)
        analytic = loads.camr_load(q, k)
        ccdc = loads.ccdc_load(mu, q * k)
        out.append({
            "name": f"loads_q{q}_k{k}",
            "us_per_call": us,
            "derived": (f"K={q*k} mu={mu:.3f} "
                        f"L_meas={L['L_total_bus']:.4f} "
                        f"L_camr={analytic:.4f} L_ccdc={ccdc:.4f} "
                        f"L_uncoded={loads.uncoded_aggregated_load(q, k):.4f}"
                        f" match={abs(L['L_total_bus'] - analytic) < 1e-9}"),
        })
    # Example 1 stage decomposition
    eng, us = _run(2, 3, gamma=2, dim=2)
    L = eng.measured_loads()
    out.append({
        "name": "example1_stages",
        "us_per_call": us,
        "derived": (f"L1={L['L_stage1_bus']:.4f} L2={L['L_stage2_bus']:.4f}"
                    f" L3={L['L_stage3_bus']:.4f} total="
                    f"{L['L_total_bus']:.4f} (paper: 0.25 0.25 0.5 -> 1)"),
    })
    return out
