"""ShuffleProgram IR: lowering time + batched-vs-looped shuffle wall time.

Acceptance numbers for the IR refactor (DESIGN.md §5): the batched
router issues ``2*(k-1)`` grouped collectives for stages 1+2 regardless
of J, while the legacy looped schedule issues ``(J + n_s2) * (k-1)``
per-group ppermutes — this table measures what that buys end to end on
a K-host-device mesh, and what one cold ``lower_program`` costs.

    PYTHONPATH=src python -m benchmarks.bench_schedule
"""

import os

if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=16")
# ^ before any jax import.

import time

import numpy as np

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core.collective import (camr_shuffle, camr_shuffle_reference,
                                   expected_collective_calls, make_plan,
                                   scatter_contributions)
from repro.core.designs import make_design
from repro.core.placement import make_placement
from repro.core.schedule import lower_program

CONFIGS = [(2, 3), (4, 3), (3, 4), (2, 4), (5, 3)]


def _steady(fn, n: int = 5) -> float:
    fn()  # warm-up / compile
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def bench_config(q: int, k: int, d: int | None = None) -> dict:
    K = q * k
    d = d or 64 * (k - 1)
    # cold lowering (bypass the lru_cache)
    pl = make_placement(make_design(q, k), gamma=1)
    t0 = time.perf_counter()
    lower_program.__wrapped__(pl, Q=K, d=d)
    lower_us = (time.perf_counter() - t0) * 1e6

    plan = make_plan(q, k, d)
    rng = np.random.default_rng(0)
    bg = rng.standard_normal((plan.J, k, K, d)).astype(np.float32)
    contribs = scatter_contributions(plan, bg)
    ref = camr_shuffle_reference(plan, bg)
    mesh = Mesh(np.array(jax.devices()[:K]), ("camr",))

    times = {}
    for mode, router in [("batched", "all_to_all"), ("batched", "ppermute"),
                         ("looped", "all_to_all")]:
        def body(c, mode=mode, router=router):
            return camr_shuffle(plan, c[0], axis_name="camr", mode=mode,
                                router=router)[None]
        f = jax.jit(shard_map(body, mesh=mesh, in_specs=P("camr"),
                              out_specs=P("camr")))
        out = jax.block_until_ready(f(contribs))
        np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-5,
                                   atol=2e-6)
        times[(mode, router)] = _steady(
            lambda f=f: jax.block_until_ready(f(contribs)))

    calls = expected_collective_calls(plan)
    return dict(
        q=q, k=k, K=K, J=plan.J, d=d, lower_us=lower_us,
        batched_us=times[("batched", "all_to_all")] * 1e6,
        ppermute_us=times[("batched", "ppermute")] * 1e6,
        looped_us=times[("looped", "all_to_all")] * 1e6,
        speedup=times[("looped", "all_to_all")]
        / times[("batched", "all_to_all")],
        collectives_12=calls["stage12"],
        looped_12=expected_collective_calls(plan, "looped")["stage12"],
    )


def _rows_local():
    out = []
    for q, k in CONFIGS:
        r = bench_config(q, k)
        out.append({
            "name": f"schedule_q{q}_k{k}",
            "us_per_call": r["batched_us"],
            "derived": (f"K={r['K']} J={r['J']} lower={r['lower_us']:.0f}us "
                        f"batched={r['batched_us']:.0f}us "
                        f"pp={r['ppermute_us']:.0f}us "
                        f"looped={r['looped_us']:.0f}us "
                        f"speedup={r['speedup']:.2f}x "
                        f"coll12={r['collectives_12']}"
                        f"(was {r['looped_12']})"),
        })
    return out


def rows():
    """Suite entry point for benchmarks/run.py.

    If another suite already initialized the jax backend (the XLA_FLAGS
    device-count hack at the top of this module only works before the
    first jax import), re-run this module in a fresh subprocess and
    relay its CSV rows.
    """
    need = max(q * k for q, k in CONFIGS)
    if len(jax.devices()) >= need:
        return _rows_local()
    import csv
    import io
    import subprocess
    import sys
    res = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_schedule"],
        capture_output=True, text=True, timeout=900,
        env={**os.environ, "JAX_PLATFORMS": "cpu"})
    if res.returncode != 0:
        raise RuntimeError(f"subprocess bench failed: {res.stderr[-500:]}")
    reader = csv.DictReader(io.StringIO(res.stdout))
    return [{"name": r["name"], "us_per_call": float(r["us_per_call"]),
             "derived": r["derived"]} for r in reader]


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in _rows_local():
        print(f"{row['name']},{row['us_per_call']:.1f},"
              f"\"{row['derived']}\"", flush=True)
