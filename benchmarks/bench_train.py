"""SPMD vs interpreter gradient sync — the training hot loop's wire.

Acceptance numbers for the device-resident training path (DESIGN.md
§11): one grad-sync step moves the stacked per-worker contribution
tensor ``[K, J_own, k-1, K, d]`` to the fully-aggregated per-worker
shards ``[K, J, d]``. Two executors of the SAME compiled schedule:

* interpreter — :class:`repro.core.engine.CAMREngine` (map over
  pre-computed gradients + 3-stage coded shuffle + reduce, byte-exact
  accounting), what ``MultiModelCAMRTrainer(mode="camr")`` runs;
* spmd — :meth:`repro.core.collective.ShuffleStream.sync` (ONE jitted
  shard_map execution, fused gather-XOR codec, executor reused across
  steps), what ``mode="camr_spmd"`` runs.

Outputs are verified BIT-identical before any time is reported (the
canonical combine order makes the two executors exactly equal, not
allclose). The SPMD path must win on every config — a hard gate under
``CAMR_BENCH_STRICT=1`` (CPU host-device meshes are noisy; compiled
TPU lanes should see far more than the 5x target).

Two packed-lane rows ride along (DESIGN.md §12): a bf16 sync config
(same identity gate, half the contribution bytes) and an END-TO-END
``MultiModelCAMRTrainer`` step with ``grad_sync_dtype="bfloat16"``
whose parameters must come out bitwise-identical across the
camr_spmd / camr / uncoded executors — the mixed-precision acceptance
gate of the training path.

    PYTHONPATH=src python -m benchmarks.bench_train [--smoke]
"""

import os

if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=16")
# ^ before any jax import.

import argparse
import sys
import time

import numpy as np

import jax

from repro.compat import make_mesh
from repro.core.collective import (ShuffleStream, make_plan,
                                   scatter_contributions)
from repro.core.engine import CAMRConfig, CAMREngine

# (q, k, d) — d = the per-worker function-shard width being synced
CONFIGS = [(2, 3, 256), (3, 3, 128), (2, 4, 96), (3, 4, 96), (5, 3, 64)]
SMOKE_CONFIGS = [(2, 3, 16)]
#: packed-lane sync configs (payload dtype rides the last slot)
PACKED_CONFIGS = [(2, 3, 256), (3, 3, 128)]
PACKED_SMOKE_CONFIGS = [(2, 3, 16)]
TARGET_SPEEDUP = 5.0


def _median(fn, reps: int) -> float:
    fn()  # warm-up (compile / caches)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def bench_config(q: int, k: int, d: int, reps: int,
                 dtype=np.float32) -> dict:
    import ml_dtypes

    np_dtype = np.dtype(dtype)
    dname = ("bfloat16" if np_dtype == np.dtype(ml_dtypes.bfloat16)
             else np_dtype.name)
    plan = make_plan(q, k, d)
    K, J = plan.K, plan.J
    rng = np.random.default_rng(0)
    bg = rng.standard_normal((J, k, K, d)).astype(np.float32)
    if dname != "float32":
        bg = bg.astype(np_dtype)
    datasets = [[bg[j, t] for t in range(k)] for j in range(J)]
    contribs = scatter_contributions(plan, bg)

    cfg = CAMRConfig(q=q, k=k, gamma=1)
    eng = CAMREngine(cfg, lambda job, sf: sf)

    def interp_sync():
        eng.reset()
        return eng.run(datasets)

    mesh = make_mesh((K,), ("camr",))
    stream = ShuffleStream(q, k, d, mesh=mesh)

    def spmd_sync():
        return jax.block_until_ready(stream.sync(contribs))

    # -- bit-identity gate BEFORE any timing ---------------------------- #
    results = interp_sync()
    want = np.empty((K, J, d), np_dtype)
    for s in range(K):
        for j in range(J):
            want[s, j] = results[s][(j, s)]
    got = np.asarray(spmd_sync())
    assert got.dtype == np_dtype, (got.dtype, np_dtype)
    np.testing.assert_array_equal(
        got.view(np.uint8), want.view(np.uint8),
        err_msg=f"spmd grad-sync != engine interpreter (q={q} k={k} "
                f"{dname})")

    t_interp = _median(interp_sync, reps)
    t_spmd = _median(spmd_sync, reps)
    suffix = "" if dname == "float32" else f"_{dname}"
    return dict(
        name=f"train_sync_q{q}_k{k}_d{d}{suffix}",
        config={"q": q, "k": k, "K": K, "J": J, "d": d,
                "payload_dtype": dname},
        payload_dtype=dname,
        interp_us=t_interp * 1e6, spmd_us=t_spmd * 1e6,
        speedup=t_interp / t_spmd,
        sync_bytes=int(contribs.nbytes),
    )


def trainer_bf16_identity_row(steps: int = 2) -> dict:
    """END-TO-END mixed-precision gate: a tiny MultiModelCAMRTrainer
    runs ``grad_sync_dtype="bfloat16"`` through all three grad-sync
    executors and the parameters must come out BITWISE-identical
    (camr_spmd == camr == uncoded); reports wall clock of the SPMD
    path. Raises on any divergence — this is an acceptance gate, not a
    timing row."""
    from repro.configs import get_config, reduced
    from repro.data.pipeline import ShardedTokenPipeline
    from repro.runtime.train_loop import MultiModelCAMRTrainer

    cfg = reduced(get_config("granite_3_2b")).replace(
        n_layers=2, vocab=64, d_model=32, d_ff=64, n_heads=2,
        n_kv_heads=1, head_dim=16, loss_chunk=8)
    pipe = ShardedTokenPipeline(vocab=64, seq_len=8, global_batch=2)
    flats, reports, t_spmd = {}, {}, 0.0
    for mode in ("camr", "uncoded", "camr_spmd"):
        tr = MultiModelCAMRTrainer(cfg, q=2, k=3, seed=0,
                                   grad_sync_dtype="bfloat16")
        t0 = time.perf_counter()
        reports[mode] = tr.train_steps(pipe, steps, mode=mode)
        dt = time.perf_counter() - t0
        if mode == "camr_spmd":
            t_spmd = dt
        flats[mode] = np.asarray(tr.flat)
    for mode in ("uncoded", "camr_spmd"):
        np.testing.assert_array_equal(
            flats[mode], flats["camr"],
            err_msg=f"bf16 grad-sync: {mode} params diverged from the "
                    "engine oracle")
    bytes16 = reports["camr"].bytes_total
    us = t_spmd / steps * 1e6
    return {
        "name": "train_bf16_grad_sync_identity",
        "us_per_call": us,
        "derived": (f"camr_spmd==camr==uncoded BITWISE over {steps} "
                    f"bf16 steps; shuffle_bytes={bytes16} "
                    f"spmd={us:.0f}us/step"),
        "config": {"q": 2, "k": 3, "steps": steps,
                   "payload_dtype": "bfloat16"},
        "payload_dtype": "bfloat16",
        "bytes_on_wire": bytes16,
        "median_us": us,
    }


def _bench_rows(smoke: bool, reps: int) -> list:
    import ml_dtypes

    rows, losers = [], []
    sync_cfgs = [(q, k, d, np.float32)
                 for q, k, d in (SMOKE_CONFIGS if smoke else CONFIGS)]
    sync_cfgs += [(q, k, d, ml_dtypes.bfloat16) for q, k, d in
                  (PACKED_SMOKE_CONFIGS if smoke else PACKED_CONFIGS)]
    for q, k, d, dtype in sync_cfgs:
        r = bench_config(q, k, d, reps, dtype=dtype)
        if r["speedup"] <= 1.0:
            losers.append(r["name"])
        rows.append({
            "name": r["name"],
            "us_per_call": r["spmd_us"],
            "derived": (f"interp={r['interp_us']:.0f}us "
                        f"spmd={r['spmd_us']:.0f}us "
                        f"speedup={r['speedup']:.1f}x "
                        f"(target {TARGET_SPEEDUP:.0f}x) "
                        f"dtype={r['payload_dtype']} "
                        f"sync_bytes={r['sync_bytes']} bit-identical"),
            "config": r["config"],
            "payload_dtype": r["payload_dtype"],
            "sync_bytes": r["sync_bytes"],
            "median_us": r["spmd_us"],
            "interp_median_us": r["interp_us"],
            "speedup": r["speedup"],
        })
    rows.append(trainer_bf16_identity_row())
    # --smoke configs are too tiny for a meaningful wall-clock gate
    # (same policy as bench_encoding): bit-identity still gates above
    if losers and not smoke:
        msg = ("SPMD grad-sync must beat the interpreter on every "
               f"config; lost on {losers}")
        if os.environ.get("CAMR_BENCH_STRICT") == "1":
            raise AssertionError(msg)
        print(f"# WARNING (noisy host?): {msg}", file=sys.stderr)
    return rows


def rows(smoke: bool | None = None):
    """Suite entry point for benchmarks/run.py.

    If another suite already initialized the jax backend (the XLA_FLAGS
    device-count hack above only works before the first jax use),
    re-run in a fresh subprocess and relay the CSV rows.
    """
    if smoke is None:
        smoke = os.environ.get("CAMR_BENCH_SMOKE", "") == "1"
    need = max(q * k for q, k, _ in (SMOKE_CONFIGS if smoke else CONFIGS))
    if len(jax.devices()) >= need:
        return _bench_rows(smoke, reps=5 if smoke else 15)
    import json
    import subprocess
    import tempfile
    with tempfile.NamedTemporaryFile("r", suffix=".json") as tf:
        cmd = [sys.executable, "-m", "benchmarks.bench_train",
               "--json-rows", tf.name]
        if smoke:
            cmd.append("--smoke")
        res = subprocess.run(cmd, capture_output=True, text=True,
                             timeout=900,
                             env={**os.environ, "JAX_PLATFORMS": "cpu"})
        if res.returncode != 0:
            raise RuntimeError(
                f"subprocess bench failed: {res.stderr[-500:]}")
        # full rows (payload_dtype, sync_bytes, speedup, ...) for the
        # --json artifact; a missing/corrupt file is a real bug in the
        # writer above — fail loudly rather than degrade the artifact
        with open(tf.name) as f:
            return json.load(f)


def main():
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one tiny config, few reps (CI train-smoke)")
    ap.add_argument("--json-rows", default=None, metavar="PATH",
                    help="also dump the full row dicts as JSON (the "
                         "rows() subprocess relay uses this to keep "
                         "payload_dtype/bytes fields in the artifact)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    rows_ = _bench_rows(args.smoke, reps=5 if args.smoke else 15)
    for row in rows_:
        print(f"{row['name']},{row['us_per_call']:.1f},"
              f"\"{row['derived']}\"", flush=True)
    if args.json_rows:
        with open(args.json_rows, "w") as f:
            json.dump(rows_, f, default=str)
    print("# spmd grad-sync verified bit-identical to the engine "
          "interpreter before timing", file=sys.stderr)


if __name__ == "__main__":
    main()
