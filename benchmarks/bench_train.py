"""SPMD vs interpreter gradient sync — the training hot loop's wire.

Acceptance numbers for the device-resident training path (DESIGN.md
§11): one grad-sync step moves the stacked per-worker contribution
tensor ``[K, J_own, k-1, K, d]`` to the fully-aggregated per-worker
shards ``[K, J, d]``. Two executors of the SAME compiled schedule:

* interpreter — :class:`repro.core.engine.CAMREngine` (map over
  pre-computed gradients + 3-stage coded shuffle + reduce, byte-exact
  accounting), what ``MultiModelCAMRTrainer(mode="camr")`` runs;
* spmd — :meth:`repro.core.collective.ShuffleStream.sync` (ONE jitted
  shard_map execution, fused gather-XOR codec, executor reused across
  steps), what ``mode="camr_spmd"`` runs.

Outputs are verified BIT-identical before any time is reported (the
canonical combine order makes the two executors exactly equal, not
allclose). The SPMD path must win on every config — a hard gate under
``CAMR_BENCH_STRICT=1`` (CPU host-device meshes are noisy; compiled
TPU lanes should see far more than the 5x target).

    PYTHONPATH=src python -m benchmarks.bench_train [--smoke]
"""

import os

if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=16")
# ^ before any jax import.

import argparse
import sys
import time

import numpy as np

import jax

from repro.compat import make_mesh
from repro.core.collective import (ShuffleStream, make_plan,
                                   scatter_contributions)
from repro.core.engine import CAMRConfig, CAMREngine

# (q, k, d) — d = the per-worker function-shard width being synced
CONFIGS = [(2, 3, 256), (3, 3, 128), (2, 4, 96), (3, 4, 96), (5, 3, 64)]
SMOKE_CONFIGS = [(2, 3, 16)]
TARGET_SPEEDUP = 5.0


def _median(fn, reps: int) -> float:
    fn()  # warm-up (compile / caches)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def bench_config(q: int, k: int, d: int, reps: int) -> dict:
    plan = make_plan(q, k, d)
    K, J = plan.K, plan.J
    rng = np.random.default_rng(0)
    bg = rng.standard_normal((J, k, K, d)).astype(np.float32)
    datasets = [[bg[j, t] for t in range(k)] for j in range(J)]
    contribs = scatter_contributions(plan, bg)

    cfg = CAMRConfig(q=q, k=k, gamma=1)
    eng = CAMREngine(cfg, lambda job, sf: sf)

    def interp_sync():
        eng.reset()
        return eng.run(datasets)

    mesh = make_mesh((K,), ("camr",))
    stream = ShuffleStream(q, k, d, mesh=mesh)

    def spmd_sync():
        return jax.block_until_ready(stream.sync(contribs))

    # -- bit-identity gate BEFORE any timing ---------------------------- #
    results = interp_sync()
    want = np.empty((K, J, d), np.float32)
    for s in range(K):
        for j in range(J):
            want[s, j] = results[s][(j, s)]
    np.testing.assert_array_equal(
        np.asarray(spmd_sync()), want,
        err_msg=f"spmd grad-sync != engine interpreter (q={q} k={k})")

    t_interp = _median(interp_sync, reps)
    t_spmd = _median(spmd_sync, reps)
    return dict(
        name=f"train_sync_q{q}_k{k}_d{d}",
        config={"q": q, "k": k, "K": K, "J": J, "d": d},
        interp_us=t_interp * 1e6, spmd_us=t_spmd * 1e6,
        speedup=t_interp / t_spmd,
        sync_bytes=int(contribs.nbytes),
    )


def _bench_rows(smoke: bool, reps: int) -> list:
    rows, losers = [], []
    for q, k, d in (SMOKE_CONFIGS if smoke else CONFIGS):
        r = bench_config(q, k, d, reps)
        if r["speedup"] <= 1.0:
            losers.append(r["name"])
        rows.append({
            "name": r["name"],
            "us_per_call": r["spmd_us"],
            "derived": (f"interp={r['interp_us']:.0f}us "
                        f"spmd={r['spmd_us']:.0f}us "
                        f"speedup={r['speedup']:.1f}x "
                        f"(target {TARGET_SPEEDUP:.0f}x) bit-identical"),
            "config": r["config"],
            "median_us": r["spmd_us"],
            "interp_median_us": r["interp_us"],
            "speedup": r["speedup"],
        })
    if losers:
        msg = ("SPMD grad-sync must beat the interpreter on every "
               f"config; lost on {losers}")
        if os.environ.get("CAMR_BENCH_STRICT") == "1":
            raise AssertionError(msg)
        print(f"# WARNING (noisy host?): {msg}", file=sys.stderr)
    return rows


def rows(smoke: bool | None = None):
    """Suite entry point for benchmarks/run.py.

    If another suite already initialized the jax backend (the XLA_FLAGS
    device-count hack above only works before the first jax use),
    re-run in a fresh subprocess and relay the CSV rows.
    """
    if smoke is None:
        smoke = os.environ.get("CAMR_BENCH_SMOKE", "") == "1"
    need = max(q * k for q, k, _ in (SMOKE_CONFIGS if smoke else CONFIGS))
    if len(jax.devices()) >= need:
        return _bench_rows(smoke, reps=5 if smoke else 15)
    import csv
    import io
    import subprocess
    cmd = [sys.executable, "-m", "benchmarks.bench_train"]
    if smoke:
        cmd.append("--smoke")
    res = subprocess.run(cmd, capture_output=True, text=True, timeout=900,
                         env={**os.environ, "JAX_PLATFORMS": "cpu"})
    if res.returncode != 0:
        raise RuntimeError(f"subprocess bench failed: {res.stderr[-500:]}")
    reader = csv.DictReader(io.StringIO(res.stdout))
    return [{"name": r["name"], "us_per_call": float(r["us_per_call"]),
             "derived": r["derived"]} for r in reader]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one tiny config, few reps (CI train-smoke)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in _bench_rows(args.smoke, reps=5 if args.smoke else 15):
        print(f"{row['name']},{row['us_per_call']:.1f},"
              f"\"{row['derived']}\"", flush=True)
    print("# spmd grad-sync verified bit-identical to the engine "
          "interpreter before timing", file=sys.stderr)


if __name__ == "__main__":
    main()
