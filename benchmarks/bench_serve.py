"""Continuous-batching serving vs the legacy host loop (DESIGN.md §13).

Acceptance numbers for the serving path: a stream of ragged requests is
decoded by

* legacy — :func:`repro.runtime.serve.generate`, one host round-trip
  per token, one request at a time (the static baseline a naive server
  runs for ragged prompts);
* engine — :class:`DecodeEngine` + :class:`ServeStream`: the jitted
  ``lax.while_loop`` wave decode over paged KV slots, admission and
  eviction between waves, prefill overlapped with decode.

Before any time is reported the two lanes are gated on TOKEN parity
(the engine's greedy tokens must equal the per-request host-loop
oracle's, request by request) and on the zero-recompilation admission
contract (a second stream run traces nothing). The engine must then win
on tokens/sec on every config — a hard gate under
``CAMR_BENCH_STRICT=1`` (CPU wall clocks are noisy; it is a stderr
warning otherwise, and ``--smoke`` configs are too tiny for a
meaningful wall-clock gate at all, matching bench_train's policy).

A second lane prices the SELF-HEALING path (DESIGN.md §15): the same
request stream is replayed while a deterministic injector crashes
whole waves, forcing the supervisor to roll back to the wave-boundary
snapshot and replay. Gated hard (always) on zero retraces during
recovery and on token parity with the fault-free run; the throughput
ratio under churn must stay above ``CHAOS_MIN_RATIO`` — hard under
``CAMR_BENCH_STRICT=1``, a stderr warning otherwise. Reported per
config: healthy vs churn tokens/sec, retry count, and recovery
latency (wall time lost to discarded attempts + rollback).

    PYTHONPATH=src python -m benchmarks.bench_serve [--smoke]
"""

import argparse
import os
import sys
import time

import numpy as np

import jax

from repro.configs import get_config, reduced
from repro.models import lm
from repro.runtime.serve import (DecodeEngine, Request, ServeStream,
                                 WaveCrashError, generate, trace_total)

# (arch, n_requests, max_prompt, max_new, slots, page_size, wave_len)
CONFIGS = [
    ("gemma2_2b", 12, 12, 16, 4, 8, 8),
    ("granite_3_2b", 12, 12, 16, 4, 8, 8),
]
SMOKE_CONFIGS = [
    ("gemma2_2b", 4, 6, 4, 2, 4, 4),
]

#: committed-wave indices the chaos lane crashes (first attempt each);
#: every crash costs one discarded device wave + a snapshot rollback
CHAOS_WAVES = (1, 3)
CHAOS_WAVES_SMOKE = (1,)

#: floor on (churn tok/s) / (healthy tok/s) — recovery overhead gate
CHAOS_MIN_RATIO = 0.4


def _requests(cfg, n, max_prompt, max_new, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        t = int(rng.integers(1, max_prompt + 1))
        out.append(Request(
            prompt=rng.integers(0, cfg.vocab, (t,)).astype(np.int32),
            max_new=max_new, seed=i))
    return out


def _legacy_lane(cfg, params, reqs):
    """Sequential host-loop decode; returns (gen_tokens, step_times)."""
    outs, lat = [], []
    for r in reqs:
        res = generate(cfg, params, np.asarray(r.prompt)[None],
                       max_new=r.max_new, eos=r.eos, seed=r.seed)
        outs.append(res.tokens[0, len(r.prompt):])
        lat.extend(res.step_times)
    return outs, lat


def _pct(xs, q):
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else 0.0


def bench_config(arch, n, max_prompt, max_new, slots, page_size, wave):
    cfg = reduced(get_config(arch))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    reqs = _requests(cfg, n, max_prompt, max_new)
    total_new = n * max_new

    def mk_stream():
        eng = DecodeEngine(cfg, params, slots=slots,
                           page_size=page_size,
                           max_ctx=max_prompt + max_new,
                           max_new_cap=max_new, name=arch)
        return eng, ServeStream(eng, wave_len=wave)

    # -- gate 1: token parity vs the host-loop oracle (also warms both
    #    lanes' executables) ------------------------------------------ #
    oracle, legacy_lat = _legacy_lane(cfg, params, reqs)
    eng, stream = mk_stream()
    results = stream.run(reqs)
    for want, res in zip(oracle, results):
        got = res.generated[:len(want)]
        assert np.array_equal(want, got), (
            f"{arch}: engine tokens diverge from the host-loop oracle "
            f"(plen={res.prompt_len}): {want} != {got}")
    eng.pool.check_invariants()

    # -- gate 2: steady-state admission pays zero recompilations ------ #
    before = trace_total()
    stream.run(reqs)
    assert trace_total() == before, (
        f"{arch}: second stream run recompiled "
        f"({trace_total() - before} traces)")

    # -- timed lanes -------------------------------------------------- #
    t0 = time.perf_counter()
    _, legacy_lat = _legacy_lane(cfg, params, reqs)
    legacy_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    results = stream.run(reqs)
    engine_s = time.perf_counter() - t0
    rep = stream.last_report
    emitted = sum(r.emitted for r in results)
    step_lat = [s[1] / max(1, s[2]) for s in rep.wave_stats]

    return {
        "arch": arch,
        "legacy_toks": total_new / legacy_s,
        "engine_toks": emitted / engine_s,
        "speedup": (emitted / engine_s) / (total_new / legacy_s),
        "legacy_p50_ms": 1e3 * _pct(legacy_lat, 50),
        "legacy_p99_ms": 1e3 * _pct(legacy_lat, 99),
        "engine_p50_ms": 1e3 * _pct(step_lat, 50),
        "engine_p99_ms": 1e3 * _pct(step_lat, 99),
        "occupancy": rep.occupancy,
        "waves": rep.waves,
        "engine_us_per_tok": 1e6 * engine_s / max(1, emitted),
        "config": {"arch": arch, "requests": n, "max_prompt": max_prompt,
                   "max_new": max_new, "slots": slots,
                   "page_size": page_size, "wave_len": wave},
    }


class _CrashInjector:
    """Minimal deterministic ServeStream chaos hook: crash the first
    attempt of each wave in ``waves``. (The full scripted fault
    vocabulary — poison, latency, virtual clocks — lives in
    tests/chaos.py; the bench only needs crash-replay.)"""

    def __init__(self, waves):
        self._remaining = {w: 1 for w in waves}
        self.injected = 0

    def on_wave_start(self, model, wave, engine):
        pass

    def on_wave_crash(self, model, wave, engine):
        if self._remaining.get(wave, 0) > 0:
            self._remaining[wave] -= 1
            self.injected += 1
            raise WaveCrashError(f"bench: injected crash at wave {wave}")

    def on_wave_done(self, model, wave, engine, wall_s):
        return wall_s


def bench_chaos(arch, n, max_prompt, max_new, slots, page_size, wave,
                crash_waves):
    """Price wave-crash recovery: healthy vs under-churn throughput on
    the SAME engine and request stream. Hard-gated (always) on zero
    retraces during recovery and on survivor token parity."""
    cfg = reduced(get_config(arch))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    reqs = _requests(cfg, n, max_prompt, max_new)
    eng = DecodeEngine(cfg, params, slots=slots, page_size=page_size,
                       max_ctx=max_prompt + max_new, max_new_cap=max_new,
                       name=arch)
    # pipeline=False on BOTH lanes: deterministic wave indexing for the
    # scripted crashes, and an apples-to-apples throughput ratio
    healthy_stream = ServeStream(eng, wave_len=wave, pipeline=False)

    def churn_run():
        inj = _CrashInjector(crash_waves)
        stream = ServeStream(eng, wave_len=wave, pipeline=False,
                             chaos=inj, max_retries=len(crash_waves))
        t0 = time.perf_counter()
        res = stream.run(reqs)
        return res, time.perf_counter() - t0, stream.last_report, inj

    healthy_stream.run(reqs)    # warm the decode/snapshot executables
    churn_run()                 # warm the rollback/retry executables

    t0 = time.perf_counter()
    healthy = healthy_stream.run(reqs)
    healthy_s = time.perf_counter() - t0

    before = trace_total()
    churn, churn_s, rep, inj = churn_run()
    assert trace_total() == before, (
        f"{arch}: wave-crash recovery retraced "
        f"({trace_total() - before} traces) — the retry path must "
        f"re-run cached executables only")
    assert rep.retries == inj.injected == len(crash_waves), (
        f"{arch}: expected {len(crash_waves)} supervised retries, "
        f"saw {rep.retries} (injected {inj.injected})")
    for h, c in zip(healthy, churn):
        assert c.status in ("ok", "retried_ok"), (
            f"{arch}: non-terminal-clean status {c.status!r} under "
            f"crash-only churn")
        assert np.array_equal(h.generated, c.generated), (
            f"{arch}: replayed tokens diverge from the fault-free run "
            f"(plen={c.prompt_len}): {h.generated} != {c.generated}")
    eng.pool.check_invariants()

    emitted = sum(r.emitted for r in churn)
    healthy_tok = sum(r.emitted for r in healthy) / healthy_s
    churn_tok = emitted / churn_s
    return {
        "arch": arch,
        "healthy_toks": healthy_tok,
        "churn_toks": churn_tok,
        "ratio": churn_tok / healthy_tok,
        "retries": rep.retries,
        "recovery_ms": 1e3 * rep.recovery_s,
        "churn_us_per_tok": 1e6 * churn_s / max(1, emitted),
        "config": {"arch": arch, "requests": n, "max_prompt": max_prompt,
                   "max_new": max_new, "slots": slots,
                   "page_size": page_size, "wave_len": wave,
                   "crash_waves": list(crash_waves)},
    }


def _bench_rows(smoke: bool) -> list:
    rows, losers = [], []
    for spec in (SMOKE_CONFIGS if smoke else CONFIGS):
        r = bench_config(*spec)
        if r["speedup"] <= 1.0:
            losers.append(r["arch"])
        rows.append({
            "name": f"serve_{r['arch']}",
            "us_per_call": r["engine_us_per_tok"],
            "derived": (f"legacy={r['legacy_toks']:.0f}tok/s "
                        f"engine={r['engine_toks']:.0f}tok/s "
                        f"speedup={r['speedup']:.1f}x "
                        f"p50={r['engine_p50_ms']:.2f}ms "
                        f"p99={r['engine_p99_ms']:.2f}ms "
                        f"(legacy p50={r['legacy_p50_ms']:.2f} "
                        f"p99={r['legacy_p99_ms']:.2f}) "
                        f"occ={r['occupancy']:.2f} token-parity ok"),
            "config": r["config"],
            "median_us": r["engine_us_per_tok"],
            "legacy_tok_s": r["legacy_toks"],
            "engine_tok_s": r["engine_toks"],
            "speedup": r["speedup"],
            "engine_p50_ms": r["engine_p50_ms"],
            "engine_p99_ms": r["engine_p99_ms"],
            "occupancy": r["occupancy"],
        })
    # --smoke configs are too tiny for a meaningful wall-clock gate
    # (same policy as bench_train); parity + recompile gates run above
    if losers and not smoke:
        msg = ("continuous-batching engine must beat the legacy host "
               f"loop on tokens/sec on every config; lost on {losers}")
        if os.environ.get("CAMR_BENCH_STRICT") == "1":
            raise AssertionError(msg)
        print(f"# WARNING (noisy host?): {msg}", file=sys.stderr)

    # -- self-healing lane: wave-crash recovery overhead -------------- #
    slow = []
    crash_waves = CHAOS_WAVES_SMOKE if smoke else CHAOS_WAVES
    for spec in (SMOKE_CONFIGS if smoke else CONFIGS):
        c = bench_chaos(*spec, crash_waves)
        if c["ratio"] < CHAOS_MIN_RATIO:
            slow.append(f"{c['arch']} ({c['ratio']:.2f}x)")
        rows.append({
            "name": f"serve_chaos_{c['arch']}",
            "us_per_call": c["churn_us_per_tok"],
            "derived": (f"healthy={c['healthy_toks']:.0f}tok/s "
                        f"churn={c['churn_toks']:.0f}tok/s "
                        f"ratio={c['ratio']:.2f}x "
                        f"retries={c['retries']} "
                        f"recovery={c['recovery_ms']:.1f}ms "
                        f"zero-retrace ok survivor-parity ok"),
            "config": c["config"],
            "median_us": c["churn_us_per_tok"],
            "healthy_tok_s": c["healthy_toks"],
            "churn_tok_s": c["churn_toks"],
            "churn_ratio": c["ratio"],
            "retries": c["retries"],
            "recovery_ms": c["recovery_ms"],
        })
    if slow:
        msg = (f"throughput under wave-crash churn fell below "
               f"{CHAOS_MIN_RATIO}x of healthy on {slow} — recovery "
               f"is overpriced")
        if os.environ.get("CAMR_BENCH_STRICT") == "1":
            raise AssertionError(msg)
        print(f"# WARNING (noisy host?): {msg}", file=sys.stderr)
    return rows


def rows(smoke: bool | None = None):
    """Suite entry point for benchmarks/run.py."""
    if smoke is None:
        smoke = os.environ.get("CAMR_BENCH_SMOKE", "") == "1"
    return _bench_rows(smoke)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one tiny config, few requests (CI smoke)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in _bench_rows(args.smoke):
        print(f"{row['name']},{row['us_per_call']:.1f},"
              f"\"{row['derived']}\"", flush=True)
    print("# engine tokens verified equal to the host-loop oracle and "
          "admission verified recompile-free before timing",
          file=sys.stderr)


if __name__ == "__main__":
    main()
