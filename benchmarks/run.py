# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness — deliverable (d).

    PYTHONPATH=src python -m benchmarks.run [--only loads,jobs,...]
                                            [--json PATH]

Tables:
  loads      — §IV stage loads + §V CAMR==CCDC comparison (measured)
  jobs       — Table III job minima (K=100)
  encoding   — §I-A encoding claim + fused-vs-multipass codec (§10)
  fault      — degraded-mode load inflation (DESIGN.md §7)
  e2e        — multi-model training integration (paper's DL use case)
  collective — TPU p2p byte model, CAMR vs ring psum
  schedule   — ShuffleProgram lowering + batched-vs-looped shuffle time
  jobstream  — pipelined multi-wave stream vs serial engine loop (§9)
  topology   — two-level vs flat per-edge bytes, analytic gate (§16)
  elastic    — mid-stream churn recovery: warm vs cold re-lowering (§14)
  train      — SPMD vs interpreter gradient sync (training path, §11)
  serve      — continuous-batching engine vs legacy host loop (§13)
  roofline   — §Roofline summary from the dry-run artifacts (if present)

``--json PATH`` additionally writes machine-readable results: every row
verbatim (suites may attach ``config``, ``median_us``/``p10_us``/
``p90_us`` spreads, ``speedup``, and — for shuffle-payload suites
(encoding, train) — ``payload_dtype`` and ``bytes_on_wire`` of the
codec lane measured, DESIGN.md §12) plus backend/timing metadata — CI
uploads the file as the bench-trajectory artifact
(.github/workflows/ci.yml).
"""

import argparse
import json
import platform
import sys
import time


def _roofline_rows():
    try:
        from repro.launch.roofline import table
        rows = []
        for r in table():
            rows.append({
                "name": f"roofline_{r.arch}_{r.shape}",
                "us_per_call": r.step_time_s * 1e6,
                "derived": (f"dom={r.dominant} mfu={r.mfu:.3f} "
                            f"comp={r.compute_s:.4f}s mem={r.memory_s:.4f}s"
                            f" coll={r.collective_s:.4f}s "
                            f"hbm={r.hbm_gib:.1f}GiB"),
            })
        return rows or [{"name": "roofline", "us_per_call": 0.0,
                         "derived": "no dryrun artifacts yet"}]
    except (FileNotFoundError, OSError):
        return [{"name": "roofline", "us_per_call": 0.0,
                 "derived": "no dryrun artifacts (run repro.launch.dryrun)"}]


SUITES = {
    "loads": lambda: __import__("benchmarks.bench_loads",
                                fromlist=["rows"]).rows(),
    "jobs": lambda: __import__("benchmarks.bench_jobs",
                               fromlist=["rows"]).rows(),
    "encoding": lambda: __import__("benchmarks.bench_encoding",
                                   fromlist=["rows"]).rows(),
    "fault": lambda: __import__("benchmarks.bench_fault",
                                fromlist=["rows"]).rows(),
    "e2e": lambda: __import__("benchmarks.bench_e2e",
                              fromlist=["rows"]).rows(),
    "collective": lambda: __import__("benchmarks.bench_collective",
                                     fromlist=["rows"]).rows(),
    "schedule": lambda: __import__("benchmarks.bench_schedule",
                                   fromlist=["rows"]).rows(),
    "jobstream": lambda: __import__("benchmarks.bench_jobstream",
                                    fromlist=["rows"]).rows(),
    "elastic": lambda: __import__("benchmarks.bench_elastic",
                                  fromlist=["rows"]).rows(),
    "topology": lambda: __import__("benchmarks.bench_topology",
                                   fromlist=["rows"]).rows(),
    "train": lambda: __import__("benchmarks.bench_train",
                                fromlist=["rows"]).rows(),
    "serve": lambda: __import__("benchmarks.bench_serve",
                                fromlist=["rows"]).rows(),
    "roofline": _roofline_rows,
}


def _backend() -> str:
    try:
        import jax
        return jax.default_backend()
    except Exception:  # noqa: BLE001 — jax is optional for pure suites
        return "none"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write machine-readable results to PATH")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(SUITES)
    print("name,us_per_call,derived")
    report = {
        "schema": 1,
        "generated_by": "benchmarks.run",
        "unix_time": time.time(),
        "platform": platform.platform(),
        "python": platform.python_version(),
        "backend": _backend(),
        "suites": {},
        "errors": {},
    }
    failed = 0
    for n in names:
        t0 = time.perf_counter()
        try:
            rows = list(SUITES[n]())
            for row in rows:
                print(f"{row['name']},{row['us_per_call']:.1f},"
                      f"\"{row['derived']}\"", flush=True)
            report["suites"][n] = {
                "elapsed_s": time.perf_counter() - t0,
                "rows": rows,
            }
        except Exception as e:  # noqa: BLE001
            failed += 1
            msg = f"{type(e).__name__}: {e}"
            print(f"{n},nan,\"ERROR: {msg}\"", flush=True)
            report["errors"][n] = msg
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, default=str)
        print(f"# json report -> {args.json}", file=sys.stderr)
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
