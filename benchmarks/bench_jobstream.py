"""JobStream pipelined multi-wave throughput vs the serial engine loop.

Acceptance numbers for the JobStream runtime (DESIGN.md §9): stream W
same-shaped (and one mixed-shape) waves of CAMR jobs through the
cluster and compare against the serial baseline
(:meth:`CAMREngine.run_stream` — one engine pass per wave). The
pipelined runtime batches same-shaped waves into a single
ShuffleProgram execution, pulls every lowering from the structural
schedule cache, and overlaps the map lane of batch t+1 with the
shuffle+reduce lane of batch t. Outputs are verified BIT-identical to
the serial oracle before any time is reported.

    PYTHONPATH=src python -m benchmarks.bench_jobstream [--smoke]
"""

import argparse
import time

import numpy as np

from repro.core.engine import CAMRConfig, CAMREngine
from repro.runtime.jobstream import JobSpec, JobStream

# (q, k, waves) — J = q**(k-1) jobs per wave
CONFIGS = [(2, 3, 8), (3, 3, 8), (2, 4, 6), (4, 3, 4)]
SMOKE_CONFIGS = [(2, 3, 3)]
D = 8          # value width per wave


def _identity_map(job, sf):
    return sf


def make_specs(q: int, k: int, waves: int, seed: int = 0,
               d: int = D) -> list:
    """Waves of pre-mapped intermediate values (map = identity), so the
    benchmark times the runtime, not a synthetic map function."""
    cfg = CAMRConfig(q=q, k=k, gamma=1)
    Q = cfg.num_functions()
    rng = np.random.default_rng(seed)
    specs = []
    for w in range(waves):
        ds = [[rng.standard_normal((Q, d)).astype(np.float32)
               for _ in range(cfg.N)] for _ in range(cfg.J)]
        specs.append(JobSpec(cfg, _identity_map, ds, name=f"wave{w}"))
    return specs


def bench_config(specs: list, name: str) -> dict:
    # warm the schedule cache AND the numpy/testing import paths first,
    # so the serial loop is NOT penalized for lowering or first-run
    # costs — the reported speedup is batching + pipelining only
    for sp in specs:
        CAMREngine(sp.cfg, sp.map_fn)
    CAMREngine(specs[0].cfg, specs[0].map_fn,
               combine=specs[0].combine).run(specs[0].datasets)

    t0 = time.perf_counter()
    serial = [CAMREngine(sp.cfg, sp.map_fn, combine=sp.combine).run(
        sp.datasets) for sp in specs]
    t_serial = time.perf_counter() - t0

    stream = JobStream()
    t0 = time.perf_counter()
    got = stream.run(specs)
    t_stream = time.perf_counter() - t0

    # bit-identity: stream outputs == the serial oracle results
    for want, res in zip(serial, got):
        for a, b in zip(want, res):
            assert a.keys() == b.keys()
            for key in a:
                assert np.array_equal(a[key], b[key]), key

    rep = stream.last_report
    return dict(
        name=name, waves=len(specs), batches=rep.batches,
        serial_s=t_serial, stream_s=t_stream,
        speedup=t_serial / t_stream,
        serial_wps=len(specs) / t_serial,
        stream_wps=len(specs) / t_stream,
        cache_misses=rep.cache_misses,
    )


def _all_configs(smoke: bool) -> list:
    out = []
    for q, k, w in (SMOKE_CONFIGS if smoke else CONFIGS):
        out.append((f"jobstream_q{q}_k{k}_w{w}", make_specs(q, k, w)))
    if not smoke:
        # heterogeneous stream: two shapes interleaved — exercises the
        # map/shuffle overlap across batches, not just wave batching
        mixed = make_specs(2, 3, 4, seed=1) + make_specs(2, 4, 4, seed=2)
        mixed = [mixed[i] for i in (0, 4, 1, 5, 2, 6, 3, 7)]
        out.append(("jobstream_mixed_q2k3+q2k4_w8", mixed))
    return out


def rows(smoke: bool = False):
    """Suite entry point for benchmarks/run.py."""
    out = []
    for name, specs in _all_configs(smoke):
        r = bench_config(specs, name)
        out.append({
            "name": r["name"],
            "us_per_call": r["stream_s"] / r["waves"] * 1e6,
            "derived": (f"waves={r['waves']} batches={r['batches']} "
                        f"serial={r['serial_s'] * 1e3:.1f}ms "
                        f"stream={r['stream_s'] * 1e3:.1f}ms "
                        f"speedup={r['speedup']:.2f}x "
                        f"stream={r['stream_wps']:.1f}waves/s "
                        f"lowerings={r['cache_misses']}"),
        })
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one tiny config (CI smoke for the README "
                         "commands)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    beat = 0
    for row in rows(smoke=args.smoke):
        print(f"{row['name']},{row['us_per_call']:.1f},"
              f"\"{row['derived']}\"", flush=True)
        if "speedup=" in row["derived"]:
            beat += float(
                row["derived"].split("speedup=")[1].split("x")[0]) > 1.0
    if not args.smoke and beat < 3:
        raise SystemExit(
            f"pipelined stream beat the serial loop on only {beat} "
            "configs (acceptance needs >= 3)")


if __name__ == "__main__":
    main()
