"""TPU-mapping byte model: CAMR shard_map schedule vs dense ring psum
(DESIGN.md §3 p2p accounting) across (q, k) and shard widths."""

import time

from repro.core.collective import camr_collective_bytes, make_plan


def rows():
    out = []
    for q, k, d in [(2, 3, 4096), (4, 3, 4096), (2, 4, 4098), (4, 4, 8193),
                    (8, 3, 8192)]:
        t0 = time.perf_counter()
        plan = make_plan(q, k, d)
        b = camr_collective_bytes(plan)
        us = (time.perf_counter() - t0) * 1e6
        out.append({
            "name": f"collective_q{q}_k{k}",
            "us_per_call": us,
            "derived": (f"K={plan.K} J={plan.J} camr={b['camr_total']}B "
                        f"ring_psum={b['psum_ring_total']}B "
                        f"ratio={b['camr_total'] / b['psum_ring_total']:.3f}"
                        ),
        })
    return out
