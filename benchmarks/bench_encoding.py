"""Paper §I-A — encoding complexity vs number of jobs.

The implicit claim: fewer jobs/subfiles => less encoding overhead. We
measure the wall time of the CAMR shuffle encode (XOR of packets across
the schedule) as J grows with the cluster held at the CAMR minimum vs the
CCDC minimum job count (both schemes pay one Lemma-2 exchange per group;
group count scales with J)."""

import time

import numpy as np

from repro.core import loads
from repro.core.shuffle import coded_multicast_schedule


def _encode_time(n_groups, k, chunk_bytes=4096):
    rng = np.random.default_rng(0)
    group = tuple(range(k))
    chunks = {s: rng.bytes(chunk_bytes) for s in group}
    t0 = time.perf_counter()
    for _ in range(n_groups):
        coded_multicast_schedule(group, chunks, stage=1)
    return (time.perf_counter() - t0) * 1e6


def rows():
    out = []
    for q, k in [(2, 3), (3, 3), (4, 3), (5, 3)]:
        K = q * k
        mu = (k - 1) / K
        j_camr = loads.camr_min_jobs(q, k)
        j_ccdc = loads.ccdc_min_jobs(mu, K)
        # stage-1+2 group count scales with J for both schemes
        us_camr = _encode_time(j_camr * q, k)          # q^{k-1}*q groups
        us_ccdc = _encode_time(j_ccdc, round(mu * K) + 1)
        out.append({
            "name": f"encode_K{K}_k{k}",
            "us_per_call": us_camr,
            "derived": (f"J_camr={j_camr} enc_camr={us_camr:.0f}us "
                        f"J_ccdc={j_ccdc} enc_ccdc={us_ccdc:.0f}us "
                        f"speedup={us_ccdc / max(us_camr, 1e-9):.1f}x"),
        })
    return out
