"""Paper §I-A — encoding complexity vs number of jobs — plus the codec
microbench: fused gather-XOR vs the multipass oracle (DESIGN.md §10).

Part 1 (paper claim): fewer jobs/subfiles => less encoding overhead.
We measure the wall time of the CAMR shuffle encode (XOR of packets
across the schedule) as J grows with the cluster held at the CAMR
minimum vs the CCDC minimum job count.

Part 2 (fused codec): one device's full per-stage encode+decode through
``codec="fused"`` vs ``codec="multipass"`` over ≥4 (q, k, pk) configs.
Outputs are verified BIT-identical before any time is reported, and the
row carries median/p10/p90 spreads plus the analytic peak-transient-
memory estimate of both paths (the multipass pipeline materializes a
``[n, k, d]`` chunk gather and a ``[n, k-1, k, pk]`` cancellation
gather; fused touches only Δ and the decode output). The run FAILS if
the fused path is not faster on every measured config — this perf
acceptance gate is HARD on the engineered path (compiled Pallas
kernels, i.e. TPU backends) and under ``CAMR_BENCH_STRICT=1``; on the
CPU/GPU XLA fallback lanes a loss prints a stderr warning instead
(shared hosts are too noisy for a hard microbench gate). Timing is
interleaved A/B so drift cannot bias one codec.

    PYTHONPATH=src python -m benchmarks.bench_encoding           # full
    PYTHONPATH=src python -m benchmarks.bench_encoding --smoke   # CI

Part 3 (packed 16-bit lane, DESIGN.md §12): bf16-vs-f32 rows for the
same element payload. The bytes-on-wire gate is analytic and ALWAYS
hard — the packed lane must ship ≤ 0.55x the f32 bytes — and the
wall-clock must-not-lose gate is hard under ``CAMR_BENCH_STRICT=1``
(half the XOR words; the pack is a bitcast). Rows carry
``payload_dtype`` and ``bytes_on_wire`` for the --json artifact.

``--smoke`` shrinks the configs and skips the speed gate but ALSO
pushes the fused path through the Pallas kernels in interpret mode
(u32 AND u16 packed variants), so CI exercises the kernel code paths
bit-exactly on every commit.
"""

import argparse
import os
import sys
import time

import numpy as np

from repro.core import loads
from repro.core.shuffle import coded_multicast_schedule

# (q, k, pk): cluster shape and packet width (d = pk*(k-1))
CODEC_CONFIGS = [(2, 3, 512), (3, 3, 512), (2, 4, 256), (3, 4, 256),
                 (4, 3, 1024)]
SMOKE_CONFIGS = [(2, 3, 32), (2, 4, 16), (3, 3, 8), (2, 3, 8)]


def _encode_time(n_groups, k, chunk_bytes=4096):
    rng = np.random.default_rng(0)
    group = tuple(range(k))
    chunks = {s: rng.bytes(chunk_bytes) for s in group}
    t0 = time.perf_counter()
    for _ in range(n_groups):
        coded_multicast_schedule(group, chunks, stage=1)
    return (time.perf_counter() - t0) * 1e6


def _paper_rows():
    out = []
    for q, k in [(2, 3), (3, 3), (4, 3), (5, 3)]:
        K = q * k
        mu = (k - 1) / K
        j_camr = loads.camr_min_jobs(q, k)
        j_ccdc = loads.ccdc_min_jobs(mu, K)
        # stage-1+2 group count scales with J for both schemes
        us_camr = _encode_time(j_camr * q, k)          # q^{k-1}*q groups
        us_ccdc = _encode_time(j_ccdc, round(mu * K) + 1)
        out.append({
            "name": f"encode_K{K}_k{k}",
            "us_per_call": us_camr,
            "derived": (f"J_camr={j_camr} enc_camr={us_camr:.0f}us "
                        f"J_ccdc={j_ccdc} enc_ccdc={us_ccdc:.0f}us "
                        f"speedup={us_ccdc / max(us_camr, 1e-9):.1f}x"),
        })
    return out


# --------------------------------------------------------------------- #
# fused vs multipass codec
# --------------------------------------------------------------------- #
def _codec_mem_bytes(program, stage, k, pk) -> dict:
    """Analytic peak TRANSIENT u32 bytes of one stage's encode+decode
    (per device, beyond inputs/outputs the exchange needs anyway)."""
    n = program.stage_tables(stage).n
    d = pk * (k - 1)
    multipass = 4 * (n * k * d                 # [n, k, d] chunk gather
                     + n * (k - 1) * k * pk    # [n, k-1, k, pk] cancels
                     + n * (k - 1) * pk)       # decode scratch
    seed_repeat = 4 * n * (k - 1) * k * (k - 1) * pk  # the old .repeat
    fused = 4 * (n * pk                        # delta
                 + n * (k - 1) * pk)           # decoded chunks
    return dict(fused=fused, multipass=multipass, seed_repeat=seed_repeat)


def _time_codecs(fns: dict, args, repeats: int) -> dict:
    """Interleaved A/B timing: one call of EVERY codec per round, so
    machine drift (thermal, co-tenant load) hits all lanes equally
    instead of biasing whichever was measured last."""
    import jax
    ts = {name: [] for name in fns}
    for fn in fns.values():
        jax.block_until_ready(fn(*args))       # compile + warm
    for _ in range(repeats):
        for name, fn in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            ts[name].append((time.perf_counter() - t0) * 1e6)
    out = {}
    for name, samples in ts.items():
        p10, med, p90 = np.percentile(samples, [10, 50, 90])
        out[name] = dict(median_us=float(med), p10_us=float(p10),
                         p90_us=float(p90))
    return out


def codec_rows(configs=None, repeats: int = 30, smoke: bool = False):
    """Fused-vs-multipass rows; raises on any bit mismatch, and — on
    the compiled-kernel path or under CAMR_BENCH_STRICT=1 — on any
    config where fused fails to beat multipass."""
    import jax
    import jax.numpy as jnp

    from repro.core.collective import (_decode_stage, _encode_stage,
                                       _resolve_kernels,
                                       camr_collective_bytes, make_plan)

    configs = configs if configs is not None else (
        SMOKE_CONFIGS if smoke else CODEC_CONFIGS)
    use_kernels = _resolve_kernels(None)       # Pallas iff TPU backend
    rows, losers = [], []
    for q, k, pk in configs:
        d = pk * (k - 1)
        plan = make_plan(q, k, d)
        prog = plan.program
        rng = np.random.default_rng(q * 100 + k * 10 + pk)
        J_own, K = plan.J_own, plan.K
        u32 = jnp.asarray(rng.integers(0, 2**32, (J_own, k - 1, K, d),
                                       dtype=np.uint32))
        stage_T = {s: prog.stage_tables(s) for s in (1, 2)}
        recvs = {s: jnp.asarray(rng.integers(
            0, 2**32, (stage_T[s].n, k - 1, pk), dtype=np.uint32))
            for s in (1, 2)}

        def run(x, r1, r2, codec, kernels):
            outs = []
            for s in (1, 2):
                ctx, delta = _encode_stage(x, stage_T[s], 0, k=k, pk=pk,
                                           codec=codec,
                                           use_kernels=kernels)
                outs.append(delta)
                outs.append(_decode_stage(r1 if s == 1 else r2, ctx,
                                          stage_T[s], 0, k=k, pk=pk,
                                          codec=codec,
                                          use_kernels=kernels))
            return tuple(outs)

        import functools
        fns = {c: jax.jit(functools.partial(run, codec=c,
                                            kernels=use_kernels))
               for c in ("fused", "multipass")}
        args = (u32, recvs[1], recvs[2])
        want = jax.tree_util.tree_map(np.asarray, fns["multipass"](*args))
        got = jax.tree_util.tree_map(np.asarray, fns["fused"](*args))
        for a, b in zip(want, got):
            np.testing.assert_array_equal(a, b)
        if smoke and not use_kernels:
            # CI lane: ALSO run the fused Pallas kernels in interpret
            # mode and hold them to the same bit-identity bar
            interp = jax.jit(functools.partial(run, codec="fused",
                                               kernels=True))
            for a, b in zip(want, interp(*args)):
                np.testing.assert_array_equal(a, np.asarray(b))

        times = _time_codecs(fns, args, repeats)
        t_f, t_m = times["fused"], times["multipass"]
        # stages execute sequentially inside one jitted call, so the
        # PEAK transient is the max over stages, not their sum
        mem = {s: _codec_mem_bytes(prog, s, k, pk) for s in (1, 2)}
        peak = {key: max(mem[s][key] for s in (1, 2))
                for key in ("fused", "multipass", "seed_repeat")}
        mb = {key: v / 2**20 for key, v in peak.items()}
        speedup = t_m["median_us"] / max(t_f["median_us"], 1e-9)
        if speedup <= 1.0:
            losers.append((q, k, pk, speedup))
        rows.append({
            "name": f"codec_q{q}_k{k}_pk{pk}",
            "us_per_call": t_f["median_us"],
            "derived": (f"fused={t_f['median_us']:.0f}us "
                        f"multipass={t_m['median_us']:.0f}us "
                        f"speedup={speedup:.2f}x "
                        f"mem_fused={mb['fused']:.2f}MiB "
                        f"mem_multipass={mb['multipass']:.2f}MiB "
                        f"mem_seed_repeat={mb['seed_repeat']:.2f}MiB "
                        f"kernels={'pallas' if use_kernels else 'xla'}"),
            "config": {"q": q, "k": k, "pk": pk, "d": d,
                       "backend": jax.default_backend(),
                       "pallas_kernels": bool(use_kernels)},
            "payload_dtype": "uint32",
            "bytes_on_wire": camr_collective_bytes(plan)["camr_total"],
            "median_us": t_f["median_us"],
            "p10_us": t_f["p10_us"],
            "p90_us": t_f["p90_us"],
            "multipass_median_us": t_m["median_us"],
            "multipass_p10_us": t_m["p10_us"],
            "multipass_p90_us": t_m["p90_us"],
            "speedup": speedup,
            "peak_mem_bytes": {key: int(v) for key, v in peak.items()},
        })
    if losers and not smoke:
        msg = ("fused codec must beat multipass on every measured "
               f"config; lost on {losers}")
        if use_kernels or os.environ.get("CAMR_BENCH_STRICT") == "1":
            # the perf acceptance gate: hard on the engineered path
            # (compiled Pallas kernels) and under CAMR_BENCH_STRICT=1
            raise AssertionError(msg)
        # CPU/GPU XLA fallback lanes on a noisy host: report, don't fail
        print(f"# WARNING (xla fallback lane): {msg}", file=sys.stderr)
    return rows


# --------------------------------------------------------------------- #
# packed 16-bit lane vs f32 (DESIGN.md §12)
# --------------------------------------------------------------------- #
#: the packed lane must move at most this fraction of the f32 lane's
#: bytes-on-wire for the same element payload (0.5 + pad headroom) —
#: a HARD, deterministic gate on every measured config.
PACKED_BYTES_GATE = 0.55


def packed_rows(configs=None, repeats: int = 30, smoke: bool = False):
    """bf16-vs-f32 codec lane rows: per config, (1) a hard analytic
    bytes-on-wire gate — the packed lane ships <= 0.55x the f32 bytes
    for the SAME element payload ``d``; (2) bit-identity of all three
    packed codec lanes (multipass / fused jnp / fused u16 Pallas
    kernels — interpret lane included in ``--smoke``) before any time
    is reported; (3) interleaved f32-vs-bf16 wall-clock where the
    packed lane must NOT lose under ``CAMR_BENCH_STRICT=1`` (half the
    XOR words; the pack is a bitcast)."""
    import jax
    import jax.numpy as jnp

    from repro.core.collective import (_decode_stage, _encode_stage,
                                       _resolve_kernels, _wire_buffer,
                                       camr_collective_bytes, make_plan)
    from repro.core.schedule import payload_words

    configs = configs if configs is not None else (
        SMOKE_CONFIGS if smoke else CODEC_CONFIGS)
    use_kernels = _resolve_kernels(None)       # Pallas iff TPU backend
    rows, losers = [], []
    for q, k, pk in configs:
        d = pk * (k - 1)
        plan = make_plan(q, k, d)
        prog = plan.program
        stage_T = {s: prog.stage_tables(s) for s in (1, 2)}
        rng = np.random.default_rng(q * 1000 + k * 100 + pk)
        J_own, K = plan.J_own, plan.K
        vals = rng.standard_normal((J_own, k - 1, K, d)).astype(np.float32)

        # (1) the bytes-on-wire gate: deterministic, always enforced
        wire_bytes = {
            name: camr_collective_bytes(plan, dtype=dt)["camr_total"]
            for name, dt in (("float32", jnp.float32),
                             ("bfloat16", jnp.bfloat16))}
        ratio = wire_bytes["bfloat16"] / wire_bytes["float32"]
        if ratio > PACKED_BYTES_GATE:
            raise AssertionError(
                f"packed lane must move <= {PACKED_BYTES_GATE}x the f32 "
                f"bytes-on-wire; q={q} k={k} d={d} ships {ratio:.3f}x "
                f"({wire_bytes['bfloat16']} vs {wire_bytes['float32']})")

        recv_cache: dict = {}

        def recv_for(pkw):
            # one recv buffer per wire width — every lane of one dtype
            # must decode the SAME received words or the bit-identity
            # comparison below compares different inputs
            if pkw not in recv_cache:
                r_rng = np.random.default_rng(pkw * 7 + q)
                recv_cache[pkw] = {s: jnp.asarray(r_rng.integers(
                    0, 2**32, (stage_T[s].n, k - 1, pkw),
                    dtype=np.uint32)) for s in (1, 2)}
            return recv_cache[pkw]

        def make_fn(dtype, codec, kernels):
            x = jnp.asarray(vals).astype(dtype)
            wp = payload_words(d, jnp.dtype(dtype).itemsize, k)
            pkw = wp // (k - 1)
            r = recv_for(pkw)

            def run():
                wire = _wire_buffer(x, wp=wp, codec=codec,
                                    use_kernels=kernels)
                outs = []
                for s in (1, 2):
                    ctx, delta = _encode_stage(
                        wire, stage_T[s], 0, k=k, pk=pkw, codec=codec,
                        use_kernels=kernels)
                    outs.append(delta)
                    outs.append(_decode_stage(
                        r[s], ctx, stage_T[s], 0, k=k, pk=pkw,
                        codec=codec, use_kernels=kernels))
                return tuple(outs)

            return jax.jit(run)

        # (2) packed-lane bit-identity before timing (same bar as
        # codec_rows: multipass oracle == fused jnp == fused kernels)
        lanes = {"multipass": make_fn(jnp.bfloat16, "multipass", False),
                 "fused_jnp": make_fn(jnp.bfloat16, "fused", False)}
        if smoke or use_kernels:
            # u16 Pallas kernels: compiled on TPU, interpret lane in CI
            lanes["fused_kernels"] = make_fn(jnp.bfloat16, "fused", True)
        want = jax.tree_util.tree_map(np.asarray, lanes["multipass"]())
        for name, fn in lanes.items():
            got = jax.tree_util.tree_map(np.asarray, fn())
            for a, b in zip(want, got):
                np.testing.assert_array_equal(a, b, err_msg=name)

        # (3) interleaved wall clock: f32 fused vs bf16 fused
        fns = {"float32": make_fn(jnp.float32, "fused", use_kernels),
               "bfloat16": make_fn(jnp.bfloat16, "fused", use_kernels)}
        times = _time_codecs(fns, (), repeats)
        t32, t16 = times["float32"], times["bfloat16"]
        speedup = t32["median_us"] / max(t16["median_us"], 1e-9)
        if speedup < 1.0:
            losers.append((q, k, d, speedup))
        rows.append({
            "name": f"packed_q{q}_k{k}_d{d}",
            "us_per_call": t16["median_us"],
            "derived": (f"bf16={t16['median_us']:.0f}us "
                        f"f32={t32['median_us']:.0f}us "
                        f"speedup={speedup:.2f}x "
                        f"bytes={wire_bytes['bfloat16']} "
                        f"({ratio:.3f}x of f32, gate "
                        f"{PACKED_BYTES_GATE}) bit-identical "
                        f"kernels={'pallas' if use_kernels else 'xla'}"),
            "config": {"q": q, "k": k, "d": d,
                       "backend": jax.default_backend(),
                       "pallas_kernels": bool(use_kernels)},
            "payload_dtype": "bfloat16",
            "bytes_on_wire": wire_bytes["bfloat16"],
            "f32_bytes_on_wire": wire_bytes["float32"],
            "bytes_ratio": ratio,
            "median_us": t16["median_us"],
            "p10_us": t16["p10_us"],
            "p90_us": t16["p90_us"],
            "f32_median_us": t32["median_us"],
            "speedup": speedup,
        })
    if losers and not smoke:
        msg = ("packed bf16 lane must not lose to f32 on wall clock "
               f"(half the XOR words); lost on {losers}")
        if os.environ.get("CAMR_BENCH_STRICT") == "1":
            raise AssertionError(msg)
        # shared hosts are too noisy for an unconditional microbench gate
        print(f"# WARNING (noisy host?): {msg}", file=sys.stderr)
    return rows


def rows(smoke: bool | None = None):
    if smoke is None:
        # CI sets CAMR_BENCH_SMOKE=1 so the uploaded bench artifact
        # records codec rows without the (CPU-noise-prone) speed gate;
        # local/TPU `python -m benchmarks.run` stays full-fat
        smoke = os.environ.get("CAMR_BENCH_SMOKE", "") == "1"
    return (_paper_rows() + codec_rows(smoke=smoke)
            + packed_rows(smoke=smoke))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small configs, bit-identity only (incl. Pallas "
                         "interpret lane); no speed gate — CI mode")
    args = ap.parse_args()
    reps = 5 if args.smoke else 30
    print("name,us_per_call,derived")
    for row in (codec_rows(repeats=reps, smoke=args.smoke)
                + packed_rows(repeats=reps, smoke=args.smoke)):
        print(f"{row['name']},{row['us_per_call']:.1f},"
              f"\"{row['derived']}\"", flush=True)
    print("# codec outputs verified bit-identical (fused == multipass, "
          "f32 and packed bf16 lanes"
          + (", incl. Pallas interpret lanes)" if args.smoke else ")"))


if __name__ == "__main__":
    main()
