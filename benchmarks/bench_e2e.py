"""End-to-end integration: multi-model training with the CAMR-coded
gradient shuffle vs the uncoded baseline (paper's deep-learning use case,
§I). Reports measured shuffle bytes per step and steps/s on CPU."""

import time

import numpy as np

from repro.configs import get_config, reduced
from repro.data.pipeline import ShardedTokenPipeline
from repro.runtime.train_loop import MultiModelCAMRTrainer


def rows():
    cfg = reduced(get_config("granite_3_2b")).replace(
        n_layers=2, vocab=64, d_model=32, d_ff=64, n_heads=2, n_kv_heads=1,
        head_dim=16, loss_chunk=8)
    pipe = ShardedTokenPipeline(vocab=64, seq_len=8, global_batch=2)
    out = []
    reports = {}
    for mode in ("camr", "uncoded"):
        tr = MultiModelCAMRTrainer(cfg, q=2, k=3, seed=0)
        t0 = time.perf_counter()
        rep = tr.train_steps(pipe, steps=1, mode=mode)
        us = (time.perf_counter() - t0) * 1e6
        reports[mode] = rep
        out.append({
            "name": f"e2e_multimodel_{mode}",
            "us_per_call": us,
            "derived": (f"J=4 models K=6 workers "
                        f"bytes/step={rep.bytes_total} "
                        f"L={rep.loads.get('L_total_bus', 0):.4f} "
                        f"mean_loss={np.mean(rep.losses[-1]):.4f}"),
        })
    saved = 1 - (reports["camr"].bytes_total
                 / reports["uncoded"].bytes_total)
    out.append({
        "name": "e2e_shuffle_savings",
        "us_per_call": 0.0,
        "derived": (f"coded shuffle ships {saved:.1%} fewer bytes; "
                    "loss trajectories identical (tests/test_fault.py)"),
    })
    return out
