"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
results/dryrun artifacts.

    PYTHONPATH=src python scripts/make_experiments_tables.py
"""

import json
import glob
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import ARCHS, SHAPES  # noqa: E402
from repro.launch.roofline import (roofline_from_cell, RESULTS_DIR  # noqa
                                   )


def load(arch, shape, mesh, suffix=""):
    fn = os.path.join(RESULTS_DIR, f"{arch}_{shape}_{mesh}{suffix}.json")
    if not os.path.exists(fn):
        return None
    with open(fn) as f:
        return json.load(f)


def dryrun_table():
    rows = ["| arch | shape | mesh | status | HBM/dev | HLO flops/dev "
            "(scanned) | collectives | compile |",
            "|---|---|---|---|---|---|---|---|"]
    for a in ARCHS:
        for s in SHAPES:
            for m in ("single", "multipod"):
                r = load(a, s, m)
                if r is None:
                    continue
                if r["status"] == "skipped":
                    rows.append(f"| {a} | {s} | {m} | SKIP (see DESIGN.md"
                                " §6) | — | — | — | — |")
                    continue
                if r["status"] != "ok":
                    rows.append(f"| {a} | {s} | {m} | ERROR | — | — | — |"
                                " — |")
                    continue
                mem = r["memory"]
                hbm = (mem["argument_bytes"] + mem["temp_bytes"]
                       + mem["output_bytes"] - mem["alias_bytes"]) / 2**30
                flag = " ⚠" if hbm > 16 else ""
                coll = r["collectives"]["total_bytes"] / 2**30
                rows.append(
                    f"| {a} | {s} | {m} | ok | {hbm:.1f} GiB{flag} | "
                    f"{r['cost']['flops']:.2e} | {coll:.2f} GiB | "
                    f"{r['compile_s']:.0f}s |")
    return "\n".join(rows)


def roofline_table():
    rows = ["| arch | shape | comp s | mem s | coll s | dominant | "
            "step s | MFU | useful | roofline frac |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    worst = []
    for a in ARCHS:
        for s in SHAPES:
            cell = load(a, s, "single")
            if not cell or cell.get("status") != "ok":
                continue
            cost = load(a, s, "single", "_cost")
            if cost and cost.get("status") != "ok":
                cost = None
            r = roofline_from_cell(cell, cost)
            note = "" if cost else " (scanned, under-counted)"
            rows.append(
                f"| {a} | {s} | {r.compute_s:.4f} | {r.memory_s:.4f} | "
                f"{r.collective_s:.4f} | {r.dominant}{note} | "
                f"{r.step_time_s:.4f} | {r.mfu:.1%} | "
                f"{r.useful_flops_ratio:.2f} | "
                f"{r.roofline_fraction:.2f} |")
            worst.append((r.roofline_fraction, a, s, r.dominant))
    worst.sort()
    summary = ["", "Worst roofline fractions (hillclimb candidates):"]
    for frac, a, s, dom in worst[:5]:
        summary.append(f"  - {a} {s}: {frac:.2f} ({dom}-bound)")
    return "\n".join(rows + summary)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "both"
    if which in ("dryrun", "both"):
        print("### Dry-run cells\n")
        print(dryrun_table())
    if which in ("roofline", "both"):
        print("\n### Roofline (single-pod, per §Roofline)\n")
        print(roofline_table())
