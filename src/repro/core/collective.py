"""TPU-native CAMR coded shuffle on a JAX mesh axis (shard_map + ppermute).

This is the production counterpart of :mod:`repro.core.engine`: the same
3-stage schedule, expressed as SPMD collectives on a device axis of size
``K = k*q``. See DESIGN.md §3 for the multicast -> collective_permute
mapping and the bus-vs-p2p accounting.

Semantics
---------
``J = q**(k-1)`` *jobs* (simultaneously-trained model replicas, or
gradient-accumulation groups). Each job's gradient is split into ``K``
function shards of width ``d``; device ``s`` reduces shard ``s`` of every
job (Q = K). The placement assigns device ``s`` the map work of ``k-1``
batches for each of its ``q**(k-2)`` owned jobs; its input here is the
*per-batch gradient aggregates* it computed locally:

    contribs : f32[J_own, k-1, K, d]
        contribs[a, b] = gradient of batch ``stored_batches[s, a, b]`` of
        job ``owned_jobs[s, a]``, split into K shards of width d.

Output per device: ``out : [J, d]`` — the fully-aggregated shard ``s`` of
every job (reduce-scatter semantics, the paper's Reduce phase).

All schedule indices are precomputed on host (numpy) into dense tables
indexed by device id; inside shard_map they are selected with
``lax.axis_index``. XOR coding operates on ``uint32`` bitcasts, so
delivery is bit-exact for any payload.

Notation: for a coded group ``G`` and chunk-owner ``kp`` (the member that
*misses* the chunk), ``pos(x, kp) = sorted(G \\ {kp}).index(x)`` is the
packet index Algorithm 2 assigns to member ``x``.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

import jax.numpy as jnp
from jax import lax

from .designs import ResolvableDesign, make_design
from .placement import Placement, make_placement

__all__ = ["CAMRPlan", "make_plan", "camr_shuffle", "scatter_contributions",
           "camr_shuffle_reference", "uncoded_reduce_scatter",
           "camr_collective_bytes"]


# --------------------------------------------------------------------- #
# plan
# --------------------------------------------------------------------- #
@dataclass(frozen=True, eq=False)
class CAMRPlan:
    q: int
    k: int
    d: int                       # function-shard width (elements)
    design: ResolvableDesign = field(repr=False)
    placement: Placement = field(repr=False)
    owned_jobs: np.ndarray = field(repr=False)       # [K, J_own]
    stored_batches: np.ndarray = field(repr=False)   # [K, J_own, k-1]
    s1_perms: tuple = field(repr=False)              # [J][k-1] perm lists
    s2_groups: tuple = field(repr=False)
    s3_perms: tuple = field(repr=False)              # [q-1] perm lists

    @property
    def K(self) -> int:
        return self.q * self.k

    @property
    def J(self) -> int:
        return self.q ** (self.k - 1)

    @property
    def J_own(self) -> int:
        return self.q ** (self.k - 2)

    @property
    def packet_len(self) -> int:
        return self.d // (self.k - 1)


def make_plan(q: int, k: int, d: int) -> CAMRPlan:
    """Precompute the full SPMD schedule for a (q, k) CAMR cluster."""
    if k < 3:
        # k = 2 degenerates (single-packet chunks, blocks of size 1);
        # supported by the engine but not worth a coded TPU path.
        raise ValueError("TPU collective path requires k >= 3")
    if d % (k - 1):
        raise ValueError(f"shard width d={d} must be divisible by k-1={k-1}")
    design = make_design(q, k)
    pl = make_placement(design, gamma=1)
    K, J_own = design.K, design.block_size

    owned = np.zeros((K, J_own), dtype=np.int32)
    stored = np.zeros((K, J_own, k - 1), dtype=np.int32)
    for s in range(K):
        jobs = design.owned_jobs(s)
        for a, j in enumerate(jobs):
            owned[s, a] = j
            tmiss = pl.batch_of_label(j, s)
            stored[s, a] = [t for t in range(k) if t != tmiss]

    s1_perms = []
    for j in range(design.J):
        G = design.owners[j]
        s1_perms.append(tuple(
            tuple((G[p], G[(p + r) % k]) for p in range(k))
            for r in range(1, k)))

    s2_groups = []
    for G in design.stage2_groups():
        members = []
        for kp in G:
            Pset = tuple(s for s in G if s != kp)
            j = design.common_job(Pset)
            cls = design.class_of(kp)
            (l,) = [u for u in design.owners[j] if design.class_of(u) == cls]
            members.append(dict(server=kp, job=j,
                                batch=pl.batch_of_label(j, l), classmate=l))
        rounds = tuple(
            tuple((G[p], G[(p + r) % k]) for p in range(k))
            for r in range(1, k))
        s2_groups.append(dict(group=G, members=tuple(members),
                              rounds=rounds))

    s3_perms = []
    for o in range(1, q):
        pairs = []
        for i in range(k):
            for l in range(q):
                pairs.append((i * q + l, i * q + (l + o) % q))
        s3_perms.append(tuple(pairs))

    return CAMRPlan(q=q, k=k, d=d, design=design, placement=pl,
                    owned_jobs=owned, stored_batches=stored,
                    s1_perms=tuple(s1_perms), s2_groups=tuple(s2_groups),
                    s3_perms=tuple(s3_perms))


# --------------------------------------------------------------------- #
# bit helpers
# --------------------------------------------------------------------- #
def _to_u32(x):
    if x.dtype == jnp.float32:
        return lax.bitcast_convert_type(x, jnp.uint32)
    if x.dtype == jnp.uint32:
        return x
    raise TypeError(f"XOR path expects f32/u32, got {x.dtype}")


def _from_u32(x, dtype):
    return lax.bitcast_convert_type(x, dtype) if dtype != jnp.uint32 else x


def _xor_reduce(x, axis):
    return lax.reduce(x, np.uint32(0), lax.bitwise_xor, (axis,))


def _coded_exchange(axis_name, u32_chunks, valid, rounds_list,
                    delta_pos, cancel_pos, cancel_mask,
                    dec_gather, k, pk):
    """Shared SPMD machinery of stages 1 and 2 (Algorithm 2 on a mesh axis).

    Parameters (per device; n = number of groups this stage runs):
      u32_chunks  [n, k, d_u32]   chunk of each group member (0 where the
                                  member is me or not computable)
      valid       [n]             True where this device is in group
      member_pos  [n]             my position in the group (-1 if absent)
      delta_pos   [n, k]          pos(me, G[p]) for each chunk owner p
      cancel_pos  [n, k-1, k]     pos(m_r, G[p]) for round r, chunk owner p
      cancel_mask [n, k-1, k]     True where chunk owner p not in {m_r, me}
      dec_gather  [n, k-1]        pos(m_r, me): slot of round-r packet in
                                  my chunk
    Returns decoded chunks [n, d_u32].
    """
    n = u32_chunks.shape[0]
    packets = u32_chunks.reshape(n, k, k - 1, pk)

    # sender side: Δ = XOR_p pkt(G[p], pos(me, G[p])) (self-row is zero)
    my_pkts = jnp.take_along_axis(
        packets, delta_pos[:, :, None, None], axis=2)[:, :, 0]  # [n, k, pk]
    delta = _xor_reduce(my_pkts, axis=1)                        # [n, pk]

    recv = jnp.zeros((n, k - 1, pk), dtype=jnp.uint32)
    for gi in range(n):
        payload = jnp.where(valid[gi], delta[gi], 0)
        for r in range(1, k):
            got = lax.ppermute(payload, axis_name,
                               perm=list(rounds_list[gi][r - 1]))
            recv = recv.at[gi, r - 1].set(jnp.where(valid[gi], got,
                                                    recv[gi, r - 1]))

    # receiver side: pkt(me, pos(m_r, me)) =
    #   recv[r] XOR  XOR_{p: G[p] not in {m_r, me}} pkt(G[p], pos(m_r, G[p]))
    canc = jnp.take_along_axis(
        packets[:, None].repeat(k - 1, axis=1),       # [n, k-1, k, k-1, pk]
        cancel_pos[:, :, :, None, None], axis=3)[:, :, :, 0]
    canc = jnp.where(cancel_mask[:, :, :, None], canc, 0)
    canc = _xor_reduce(canc, axis=2)                  # [n, k-1, pk]
    dec = recv ^ canc                                 # [n, k-1, pk]
    order = jnp.argsort(dec_gather, axis=1)
    chunk = jnp.take_along_axis(dec, order[:, :, None], axis=1)
    return chunk.reshape(n, (k - 1) * pk)


# --------------------------------------------------------------------- #
# the SPMD shuffle body (runs inside shard_map over `axis_name`)
# --------------------------------------------------------------------- #
def camr_shuffle(plan: CAMRPlan, contribs: jnp.ndarray, *,
                 axis_name: str, debug: bool = False) -> jnp.ndarray:
    """3-stage CAMR coded shuffle: contribs [J_own, k-1, K, d] -> [J, d]."""
    q, k, K, J, J_own, d = (plan.q, plan.k, plan.K, plan.J, plan.J_own,
                            plan.d)
    dtype = contribs.dtype
    if contribs.shape != (J_own, k - 1, K, d):
        raise ValueError(f"contribs shape {contribs.shape} != "
                         f"{(J_own, k - 1, K, d)}")
    me = lax.axis_index(axis_name)
    pk = plan.packet_len
    design, pl = plan.design, plan.placement
    owners = design.owners

    owned_list = [list(plan.owned_jobs[s]) for s in range(K)]
    stored_list = [[list(plan.stored_batches[s, a])
                    for a in range(J_own)] for s in range(K)]

    def owned_index(s, j):
        return owned_list[s].index(j)

    def stored_index(s, j, t):
        return stored_list[s][owned_index(s, j)].index(t)

    def pos(x, G, kp):
        return sorted(y for y in G if y != kp).index(x)

    def dev(table):
        return jnp.take(jnp.asarray(table), me, axis=0)

    u32 = _to_u32(contribs)  # [J_own, k-1, K, d]

    # ================= stage 1: groups = owner sets ==================== #
    # chunk owner p of group(j) = owners[j][p]; chunk = (batch t_p, shard p)
    sb = np.zeros((K, J, k), dtype=np.int32)      # local batch idx
    ss = np.zeros((K, J, k), dtype=np.int32)      # shard id
    sj = np.zeros((K, J), dtype=np.int32)         # local job idx
    sv = np.zeros((K, J, k), dtype=bool)
    s_valid = np.zeros((K, J), dtype=bool)
    s_mpos = np.zeros((K, J), dtype=np.int32)
    s_dpos = np.zeros((K, J, k), dtype=np.int32)
    s_cpos = np.zeros((K, J, k - 1, k), dtype=np.int32)
    s_cmask = np.zeros((K, J, k - 1, k), dtype=bool)
    s_dgath = np.zeros((K, J, k - 1), dtype=np.int32)
    for jidx in range(J):
        G = owners[jidx]
        for s in G:
            s_valid[s, jidx] = True
            sj[s, jidx] = owned_index(s, jidx)
            myp = G.index(s)
            s_mpos[s, jidx] = myp
            for p, kp in enumerate(G):
                ss[s, jidx, p] = kp
                if kp != s:
                    t = pl.batch_of_label(jidx, kp)
                    sb[s, jidx, p] = stored_index(s, jidx, t)
                    sv[s, jidx, p] = True
                    s_dpos[s, jidx, p] = pos(s, G, kp)
            for r in range(1, k):
                m = G[(myp - r) % k]
                s_dgath[s, jidx, r - 1] = pos(m, G, s)
                for p, kp in enumerate(G):
                    if kp not in (m, s):
                        s_cpos[s, jidx, r - 1, p] = pos(m, G, kp)
                        s_cmask[s, jidx, r - 1, p] = True

    jb, jsh, jv = dev(sb), dev(ss), dev(sv)
    jjl = dev(sj)
    chunks = u32[jjl[:, None], jb, jsh]           # [J, k, d]
    chunks = jnp.where(jv[:, :, None], chunks, 0)
    dec1 = _coded_exchange(
        axis_name, chunks, dev(s_valid),
        [plan.s1_perms[jidx] for jidx in range(J)],
        dev(s_dpos), dev(s_cpos), dev(s_cmask), dev(s_dgath), k, pk)
    stage1_val = _from_u32(dec1, dtype)           # [J, d]; rows valid where
    #                                               I own job j (my missing
    #                                               batch aggregate, shard me)

    # ================= stage 2: mixed groups =========================== #
    n_g = len(plan.s2_groups)
    gb = np.zeros((K, n_g, k), dtype=np.int32)
    gjl = np.zeros((K, n_g, k), dtype=np.int32)
    gsh = np.zeros((K, n_g, k), dtype=np.int32)
    gv = np.zeros((K, n_g, k), dtype=bool)
    g_valid = np.zeros((K, n_g), dtype=bool)
    g_mpos = np.zeros((K, n_g), dtype=np.int32)
    g_dpos = np.zeros((K, n_g, k), dtype=np.int32)
    g_cpos = np.zeros((K, n_g, k - 1, k), dtype=np.int32)
    g_cmask = np.zeros((K, n_g, k - 1, k), dtype=bool)
    g_dgath = np.zeros((K, n_g, k - 1), dtype=np.int32)
    for gi, g in enumerate(plan.s2_groups):
        G = g["group"]
        for s in G:
            g_valid[s, gi] = True
            myp = G.index(s)
            g_mpos[s, gi] = myp
            for p, mem in enumerate(g["members"]):
                kp, j2, t2 = mem["server"], mem["job"], mem["batch"]
                gsh[s, gi, p] = kp
                if kp != s:
                    gjl[s, gi, p] = owned_index(s, j2)
                    gb[s, gi, p] = stored_index(s, j2, t2)
                    gv[s, gi, p] = True
                    g_dpos[s, gi, p] = pos(s, G, kp)
            for r in range(1, k):
                m = G[(myp - r) % k]
                g_dgath[s, gi, r - 1] = pos(m, G, s)
                for p, kp in enumerate(G):
                    if kp not in (m, s):
                        g_cpos[s, gi, r - 1, p] = pos(m, G, kp)
                        g_cmask[s, gi, r - 1, p] = True

    c2 = u32[dev(gjl), dev(gb), dev(gsh)]         # [n_g, k, d]
    c2 = jnp.where(dev(gv)[:, :, None], c2, 0)
    dec2 = _coded_exchange(
        axis_name, c2, dev(g_valid),
        [g["rounds"] for g in plan.s2_groups],
        dev(g_dpos), dev(g_cpos), dev(g_cmask), dev(g_dgath), k, pk)
    stage2_val = _from_u32(dec2, dtype)           # [n_g, d]

    # ================= stage 3: intra-class unicasts ==================== #
    cls_base = (me // q) * q
    s3_out = jnp.zeros((q - 1, J_own, d), dtype=dtype)
    for o in range(1, q):
        dst = cls_base + (me % q + o) % q
        pay = jnp.take(contribs, dst, axis=2).sum(axis=1)   # [J_own, d]
        got = lax.ppermute(pay, axis_name, perm=list(plan.s3_perms[o - 1]))
        s3_out = s3_out.at[o - 1].set(got)

    # ================= assemble ======================================== #
    own_sum = jnp.take(contribs, me, axis=2).sum(axis=1)    # [J_own, d]

    s2_of_job = np.zeros((K, J), dtype=np.int32)
    s3_off = np.zeros((K, J), dtype=np.int32)
    is_own = np.zeros((K, J), dtype=bool)
    own_slot = np.zeros((K, J), dtype=np.int32)
    s2_lookup = {}
    for gi, g in enumerate(plan.s2_groups):
        for mem in g["members"]:
            s2_lookup[(mem["server"], mem["job"])] = gi
    for s in range(K):
        for j in range(J):
            if design.is_owner(s, j):
                is_own[s, j] = True
                own_slot[s, j] = owned_index(s, j)
            else:
                cls = design.class_of(s)
                (l,) = [u for u in owners[j] if design.class_of(u) == cls]
                # round o delivers from the class-mate at me-o (mod q)
                s3_off[s, j] = (s - l) % q - 1
                s2_of_job[s, j] = s2_lookup[(s, j)]
                own_slot[s, j] = owned_index(l, j)

    d_isown = dev(is_own)
    d_slot = dev(own_slot)
    d_s2 = dev(s2_of_job)
    d_s3 = dev(s3_off)

    owner_val = own_sum[d_slot] + stage1_val      # [J, d] (stage1 is [J, d])
    s2_sel = stage2_val[d_s2]
    s3_sel = s3_out[d_s3, d_slot]
    nonowner_val = s2_sel + s3_sel
    out = jnp.where(d_isown[:, None], owner_val, nonowner_val)
    if debug:
        return dict(out=out, stage1=stage1_val, stage2=s2_sel, stage3=s3_sel,
                    own_sum=own_sum[d_slot], is_own=d_isown)
    return out


# --------------------------------------------------------------------- #
# helpers for drivers & tests
# --------------------------------------------------------------------- #
def scatter_contributions(plan: CAMRPlan,
                          batch_grads: np.ndarray) -> np.ndarray:
    """batch_grads [J, k, K, d] -> per-device contribs [K, J_own, k-1, K, d]
    per the placement (device s gets the batches it stores)."""
    K, J_own, k = plan.K, plan.J_own, plan.k
    out = np.zeros((K, J_own, k - 1, K, plan.d), dtype=batch_grads.dtype)
    for s in range(K):
        for a, j in enumerate(plan.owned_jobs[s]):
            for b, t in enumerate(plan.stored_batches[s, a]):
                out[s, a, b] = batch_grads[j, t]
    return out


def camr_shuffle_reference(plan: CAMRPlan,
                           batch_grads: np.ndarray) -> np.ndarray:
    """Oracle: out[s, j] = sum over batches of shard s of job j."""
    total = batch_grads.sum(axis=1)               # [J, K, d]
    return np.transpose(total, (1, 0, 2))         # [K, J, d]


def uncoded_reduce_scatter(contribs: jnp.ndarray, *, axis_name: str,
                           plan: CAMRPlan) -> jnp.ndarray:
    """Baseline: mask duplicate batch copies, psum, slice my shard."""
    me = lax.axis_index(axis_name)
    K, J, J_own = plan.K, plan.J, plan.J_own
    first = np.zeros((K, J_own, plan.k - 1), dtype=bool)
    seen = set()
    for s in range(K):
        for a, j in enumerate(plan.owned_jobs[s]):
            for b, t in enumerate(plan.stored_batches[s, a]):
                if (j, t) not in seen:
                    seen.add((j, t))
                    first[s, a, b] = True
    mask = jnp.take(jnp.asarray(first), me, axis=0)
    jl = jnp.take(jnp.asarray(plan.owned_jobs), me, axis=0)
    masked = jnp.where(mask[:, :, None, None], contribs, 0)
    dense = jnp.zeros((J, K, plan.d), contribs.dtype)
    dense = dense.at[jl].add(masked.sum(axis=1))
    total = lax.psum(dense, axis_name)            # [J, K, d]
    return jnp.take(total, me, axis=1)


def camr_collective_bytes(plan: CAMRPlan, itemsize: int = 4
                          ) -> dict[str, int]:
    """On-wire bytes per device-step of the SPMD schedule (p2p model),
    for the §Perf comparison against psum-based reduce-scatter."""
    pk_b = plan.packet_len * itemsize
    k, q, J, J_own, K, d = (plan.k, plan.q, plan.J, plan.J_own, plan.K,
                            plan.d)
    s1 = J * (k - 1) * pk_b * k            # J groups, k-1 rounds, k senders
    s2 = len(plan.s2_groups) * (k - 1) * pk_b * k
    s3 = (q - 1) * J_own * d * itemsize * K
    # uncoded alternative: psum of [J, K, d] dense gradient (ring):
    ring = 2 * (K - 1) * J * K * d * itemsize
    return dict(stage1=s1, stage2=s2, stage3=s3,
                camr_total=s1 + s2 + s3, psum_ring_total=ring)
