"""TPU-native CAMR coded shuffle on a JAX mesh axis (shard_map executor
of the compiled :class:`~repro.core.schedule.ShuffleProgram`).

This is the production counterpart of :mod:`repro.core.engine`: the same
3-stage schedule — the same IR tables — expressed as SPMD collectives on
a device axis of size ``K = k*q``. See DESIGN.md §3/§4 for the
multicast -> collective mapping and the bus-vs-p2p accounting.

Semantics
---------
``J = q**(k-1)`` *jobs* (simultaneously-trained model replicas, or
gradient-accumulation groups). Each job's gradient is split into ``K``
function shards of width ``d``; device ``s`` reduces shard ``s`` of every
job (Q = K). The placement assigns device ``s`` the map work of ``k-1``
batches for each of its ``q**(k-2)`` owned jobs; its input here is the
*per-batch gradient aggregates* it computed locally:

    contribs : f32[J_own, k-1, K, d]
        contribs[a, b] = gradient of batch ``stored_batches[s, a, b]`` of
        job ``owned_jobs[s, a]``, split into K shards of width d.

Output per device: ``out : [J, d]`` — the fully-aggregated shard ``s`` of
every job (reduce-scatter semantics, the paper's Reduce phase).

Execution modes
---------------
``mode="batched"`` (default) runs each of the ``k-1`` broadcast rounds
of stages 1 and 2 as ONE grouped collective over every group at once —
``2*(k-1)`` batched collectives total, independent of ``J``:

* ``router="all_to_all"`` — one ``lax.all_to_all`` per round (a single
  ppermute cannot carry a round: each device must reach ``q`` peers,
  see DESIGN.md §4).
* ``router="ppermute"`` — ``q`` value-shift sub-permutations per round
  (``2*(k-1)*q`` ppermutes, every byte on the wire useful).

``mode="looped"`` is the legacy per-group schedule — ``(J + n_s2) *
(k-1)`` tiny ppermutes — kept as the benchmark baseline
(benchmarks/bench_schedule.py).

Multi-wave streaming (DESIGN.md §9) is :class:`ShuffleStream`: async,
double-buffered dispatch of this executor with same-shaped waves
stacked along ``d`` into a single program execution.

XOR encode/decode default to the FUSED single-pass gather-XOR codec
(``codec="fused"``, DESIGN.md §10): packet words are read straight out
of the flat chunk buffer through the schedule's precomputed index
tables — via the scalar-prefetch Pallas kernels of
:mod:`repro.kernels.xor_code` when ``use_kernels`` is true (default: on
TPU backends), via one jnp gather otherwise. ``codec="multipass"``
keeps the original gather → take_along_axis → fold pipeline as the
CPU/GPU oracle (bit-identical, tests/test_codec_fused.py).

Payload dtypes take one of two wire lanes (DESIGN.md §12): 4-byte
dtypes (f32/u32) bitcast one value per u32 word; 16-bit floats
(bf16/f16) PACK two values per word, halving bytes-on-wire for the
coded stages and shipping stage-3 unicasts at native width — the
shuffle itself stays a lossless bit transport on either lane.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from .designs import ResolvableDesign
from .placement import Placement
from .schedule import (EXEC_CACHE, SCHEDULE_CACHE, HostTables,
                       ShuffleProgram, StageTables, Topology,
                       _normalize_topology, payload_words)

__all__ = ["CAMRPlan", "make_plan", "camr_shuffle", "scatter_contributions",
           "camr_shuffle_reference", "uncoded_reduce_scatter",
           "camr_collective_bytes", "camr_edge_bytes",
           "expected_collective_calls",
           "ShuffleStream", "CODEC_DTYPES", "PACKED_DTYPES",
           "check_codec_dtype"]


# --------------------------------------------------------------------- #
# plan — a thin handle on the compiled program
# --------------------------------------------------------------------- #
@dataclass(frozen=True, eq=False)
class CAMRPlan:
    q: int
    k: int
    d: int                       # function-shard width (elements)
    program: ShuffleProgram = field(repr=False)

    @property
    def design(self) -> ResolvableDesign:
        return self.program.design

    @property
    def placement(self) -> Placement:
        return self.program.placement

    @property
    def owned_jobs(self) -> np.ndarray:
        return self.program.owned_jobs

    @property
    def stored_batches(self) -> np.ndarray:
        return self.program.stored_batches

    @property
    def s3_perms(self) -> tuple:
        return self.program.s3_perms

    @property
    def s2_groups(self) -> tuple:
        """Stage-2 groups as member tuples (rank order)."""
        return tuple(self.program.group_members(int(r))
                     for r in self.program.s2_rows)

    @property
    def K(self) -> int:
        return self.q * self.k

    @property
    def J(self) -> int:
        return self.q ** (self.k - 1)

    @property
    def J_own(self) -> int:
        return self.q ** (self.k - 2)

    @property
    def packet_len(self) -> int:
        return self.d // (self.k - 1)

    @property
    def topology(self) -> Topology | None:
        """The topology the program was lowered for (None == flat)."""
        return self.program.topology


def make_plan(q: int, k: int, d: int,
              topology: Topology | None = None, *,
              gateway_avoid=frozenset()) -> CAMRPlan:
    """Lower the full SPMD schedule for a (q, k) CAMR cluster.

    Served from the structural :data:`~repro.core.schedule.SCHEDULE_CACHE`
    — all shard widths of one (q, k) share the same base lowering.

    ``topology=None`` (or flat) lowers the exact schedules every prior
    PR lowered; a two-level :class:`Topology` additionally lowers the
    host-aware relay overlay (DESIGN.md §16) that the executor uses to
    deduplicate inter-host packet copies (an :class:`AutoTopology`
    marker resolves via the cost model first). ``gateway_avoid``
    re-homes phase-A gateways away from the named devices (straggler
    failover, DESIGN.md §17). Outputs are bitwise identical for every
    topology and gateway assignment.
    """
    if k < 3:
        # k = 2 degenerates (single-packet chunks, blocks of size 1);
        # supported by the engine but not worth a coded TPU path.
        raise ValueError("TPU collective path requires k >= 3")
    if d % (k - 1):
        raise ValueError(f"shard width d={d} must be divisible by k-1={k - 1}")
    program = SCHEDULE_CACHE.program(q, k, Q=q * k, d=d,
                                     topology=topology,
                                     gateway_avoid=gateway_avoid)
    return CAMRPlan(q=q, k=k, d=d, program=program)


# --------------------------------------------------------------------- #
# bit helpers
# --------------------------------------------------------------------- #
#: payload dtypes the XOR codec can move, and (implicitly) the wire
#: lane each takes: 4-byte dtypes bitcast one value per u32 word;
#: :data:`PACKED_DTYPES` pack two 16-bit values per word at half the
#: bytes-on-wire (DESIGN.md §12). This tuple is the single source of
#: truth for codec dtype support — the JobStream entry guard
#: (:mod:`repro.runtime.jobstream`) consumes it rather than keeping a
#: second hand-rolled list.
CODEC_DTYPES = ("float32", "uint32", "bfloat16", "float16")

#: the 16-bit members of :data:`CODEC_DTYPES` — the packed wire lane.
PACKED_DTYPES = ("bfloat16", "float16")


def check_codec_dtype(dtype, where: str) -> None:
    """Entry guard: fail fast, with a fix, instead of a bare TypeError
    from ``_wire_buffer`` deep inside the shard_map trace."""
    if jnp.dtype(dtype).name not in CODEC_DTYPES:
        raise TypeError(
            f"{where}: the CAMR XOR codec moves 32-bit wire words; "
            f"supported payload dtypes are {', '.join(CODEC_DTYPES)} "
            "(bf16/f16 ride the packed 16-bit lane, two values per "
            f"word — DESIGN.md §12), got {jnp.dtype(dtype).name}. Cast "
            "the contributions to a supported dtype first (e.g. "
            "contribs.astype(jnp.float32)).")


def _to_u32(x):
    if x.dtype == jnp.float32:
        return lax.bitcast_convert_type(x, jnp.uint32)
    if x.dtype == jnp.uint32:
        return x
    raise TypeError(f"XOR word lane expects f32/u32, got {x.dtype}")


def _from_u32(x, dtype):
    return lax.bitcast_convert_type(x, dtype) if dtype != jnp.uint32 else x


def _u16_pairs_to_u32(x):
    """u16 ``[..., 2*m]`` lane pairs -> u32 ``[..., m]`` wire words
    (little-endian: lane ``2i`` is the low half of word ``i`` — the
    byte order of :func:`repro.core.schedule.pack_payload`)."""
    return lax.bitcast_convert_type(
        x.reshape(*x.shape[:-1], x.shape[-1] // 2, 2), jnp.uint32)


def _wire_buffer(x, *, wp: int, codec: str, use_kernels: bool):
    """Contributions -> the codec's chunk buffer (DESIGN.md §12).

    32-bit dtypes bitcast straight to u32. 16-bit dtypes are viewed as
    u16 lanes, zero-padded per shard from ``d`` to ``2*wp`` lanes (the
    deterministic trailing-lane pad rule), and either packed to u32
    words (jnp / multipass lanes) or handed to the Pallas gather
    kernels as the u16 view itself — the kernels fold lane pairs
    natively, so the pack is a same-width bitcast of their half-width
    output and no value ever widens to 4 bytes in HBM.
    """
    if jnp.dtype(x.dtype).itemsize != 2:
        return _to_u32(x)
    u16 = lax.bitcast_convert_type(x, jnp.uint16)
    pad = 2 * wp - x.shape[-1]
    if pad:
        u16 = jnp.pad(u16, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    if codec == "fused" and use_kernels:
        return u16
    return _u16_pairs_to_u32(u16)


def _from_wire(words, dtype, d: int):
    """Decoded u32 wire words ``[n, wp]`` -> payload values ``[n, d]``
    (inverse of :func:`_wire_buffer`'s per-shard packing)."""
    if jnp.dtype(dtype).itemsize == 2:
        u16 = lax.bitcast_convert_type(words, jnp.uint16)
        u16 = u16.reshape(words.shape[0], -1)[:, :d]
        return lax.bitcast_convert_type(u16, dtype)
    return _from_u32(words, dtype)


def _xor_reduce(x, axis):
    return lax.reduce(x, np.uint32(0), lax.bitwise_xor, (axis,))


def _resolve_kernels(use_kernels) -> bool:
    if use_kernels is None:  # Pallas on TPU; plain-jnp fold on CPU/GPU
        return jax.default_backend() == "tpu"
    return bool(use_kernels)


def _fold(pkts, use_kernels: bool):
    """XOR-fold ``u32[n, m, pk]`` over axis 1 -> ``u32[n, pk]``."""
    if use_kernels:
        from repro.kernels.xor_code import xor_fold
        return xor_fold(pkts)
    return _xor_reduce(pkts, axis=1)


def _decode(recv, pkts, mask, use_kernels: bool):
    """``recv ^ fold(pkts where mask)`` — Lemma-2 receiver decode."""
    if use_kernels:
        from repro.kernels.xor_code import xor_decode
        return xor_decode(recv, pkts, mask)
    return recv ^ _xor_reduce(jnp.where(mask[..., None], pkts, 0), axis=1)


def _gather_fold(flat, idx, mask, use_kernels: bool):
    """Fused encode primitive: ``XOR_j flat[idx[:, j]] where mask``.

    The jnp lane is ONE XLA gather of exactly the needed packet words
    plus a masked fold — memory scales like the Pallas kernel (no
    ``[n, k, d]`` chunk table, no replication)."""
    if use_kernels:
        from repro.kernels.xor_code import xor_encode_gather
        return xor_encode_gather(flat, idx, mask)
    return _xor_reduce(jnp.where(mask[..., None], flat[idx], 0), axis=1)


def _gather_decode(recv_flat, flat, rsel, idx, mask, use_kernels: bool):
    """Fused decode primitive: ``recv[rsel] ^ XOR_j flat[idx] where
    mask`` — rows come out in final chunk-slot order (``rsel`` bakes the
    round→slot scatter)."""
    if use_kernels:
        from repro.kernels.xor_code import xor_decode_gather
        return xor_decode_gather(recv_flat, flat, rsel, idx, mask)
    return recv_flat[rsel] ^ _xor_reduce(
        jnp.where(mask[..., None], flat[idx], 0), axis=1)


# --------------------------------------------------------------------- #
# the coded exchange (stages 1 and 2 share everything; the batched and
# looped modes differ ONLY in how a round's packets move).
#
# Two codecs execute the same tables (DESIGN.md §10):
#
# * ``fused`` (default) — Δ and the decode read packet words straight
#   out of the flat chunk buffer via the schedule's precomputed flat
#   index tables (enc_src / dec_src / dec_recv): encode+decode touch
#   HBM twice total, and the largest transient is the [n, k-1, pk]
#   recv buffer the exchange produces anyway.
# * ``multipass`` — the original gather → reshape → take_along_axis →
#   fold pipeline, kept as the CPU/GPU oracle the fused path must match
#   bit-for-bit (tests/test_codec_fused.py).
# --------------------------------------------------------------------- #
def _encode_stage(wire, T: StageTables, me, *, k, pk, codec, use_kernels):
    """Prologue shared by both modes: the sender-side
    Δ = XOR_p pkt(G[p], pos(me, G[p])) (self-row zero).

    ``wire`` is the chunk buffer :func:`_wire_buffer` built — u32 wire
    words, or the u16 lane view on the packed-kernel lane. Returns
    ``(ctx, delta [n, pk])`` (delta always in u32 wire words) where
    ``ctx`` is whatever the matching :func:`_decode_stage` needs to
    cancel packets — the flat ``[·, pk]`` chunk-buffer view (fused) or
    the materialized packet table ``u32[n, k, k-1, pk]`` (multipass)."""
    def dev(tab):
        return jnp.take(jnp.asarray(tab), me, axis=0)

    n = T.n
    if codec == "fused":
        if wire.dtype == jnp.uint16:   # packed lane, Pallas kernels
            from repro.kernels.xor_code import xor_encode_gather16
            flat = wire.reshape(-1, 2 * pk)
            delta16 = xor_encode_gather16(flat, dev(T.enc_src),
                                          dev(T.src_ok))
            return flat, _u16_pairs_to_u32(delta16)
        flat = wire.reshape(-1, pk)    # free view: packets are contiguous
        delta = _gather_fold(flat, dev(T.enc_src), dev(T.src_ok),
                             use_kernels)
        return flat, delta
    chunks = wire[dev(T.src_jslot), dev(T.src_bslot), jnp.asarray(T.shard)]
    chunks = jnp.where(dev(T.src_ok)[:, :, None], chunks, 0)  # [n, k, d]
    packets = chunks.reshape(n, k, k - 1, pk)
    my_pkts = jnp.take_along_axis(
        packets, dev(T.delta_pos)[:, :, None, None], axis=2)[:, :, 0]
    return packets, _fold(my_pkts, use_kernels)


def _decode_stage(recv, ctx, T: StageTables, me, *, k, pk, codec,
                  use_kernels):
    """Epilogue shared by both modes: pkt(me, pos(m_r, me)) =
    recv[r] XOR XOR_{p: G[p] not in {m_r, me}} pkt(G[p], pos(m_r, G[p])),
    decoded words landing in their chunk-slot positions."""
    def dev(tab):
        return jnp.take(jnp.asarray(tab), me, axis=0)

    n = T.n
    if codec == "fused":
        if ctx.dtype == jnp.uint16:    # packed lane, Pallas kernels
            from repro.kernels.xor_code import xor_decode_gather16
            recv16 = lax.bitcast_convert_type(
                recv.reshape(n * (k - 1), pk),
                jnp.uint16).reshape(n * (k - 1), 2 * pk)
            dec16 = xor_decode_gather16(
                recv16, ctx,
                dev(T.dec_recv).reshape(n * (k - 1)),
                dev(T.dec_src).reshape(n * (k - 1), k),
                dev(T.dec_mask).reshape(n * (k - 1), k))
            return _u16_pairs_to_u32(dec16).reshape(n, (k - 1) * pk)
        dec = _gather_decode(
            recv.reshape(n * (k - 1), pk), ctx,
            dev(T.dec_recv).reshape(n * (k - 1)),
            dev(T.dec_src).reshape(n * (k - 1), k),
            dev(T.dec_mask).reshape(n * (k - 1), k),
            use_kernels)
        return dec.reshape(n, (k - 1) * pk)
    # broadcast (not .repeat) the round axis: XLA folds the replication
    # into the gather, so oracle memory stays ~[n, k-1, k, pk]
    canc = jnp.take_along_axis(
        jnp.broadcast_to(ctx[:, None], (n, k - 1, k, k - 1, pk)),
        dev(T.cancel_pos)[:, :, :, None, None], axis=3)[:, :, :, 0]
    cmask = dev(T.cancel_mask)
    dec = _decode(recv.reshape(n * (k - 1), pk),
                  canc.reshape(n * (k - 1), k, pk),
                  cmask.reshape(n * (k - 1), k),
                  use_kernels).reshape(n, k - 1, pk)
    order = jnp.argsort(dev(T.dec_gather), axis=1)
    chunk = jnp.take_along_axis(dec, order[:, :, None], axis=1)
    return chunk.reshape(n, (k - 1) * pk)


def _corrupt_delta(delta, me, corrupt):
    """Flip ``bits`` in one wire word of one device's outgoing Δ —
    the deterministic single-word fault model of the integrity lane
    (DESIGN.md §17). Injected AFTER the checksum fold, so it lands on
    the wire exactly as a transit bit-flip would: the sender's local
    decode context stays clean and every receiver of the tampered
    packet sees a checksum mismatch."""
    if corrupt is None:
        return delta
    cdev, crow, cword, cbits = corrupt
    bump = jnp.where(me == cdev, jnp.uint32(cbits), jnp.uint32(0))
    return delta.at[crow, cword].set(delta[crow, cword] ^ bump)


def _stage_coded_batched(axis_name, wire, T: StageTables, me, *,
                         q, k, K, pk, router, codec, use_kernels,
                         corrupt=None):
    """One coded stage as ``k-1`` grouped collectives (DESIGN.md §4).

    Returns decoded chunks ``u32[n, wp]`` — row order = the stage's
    group rank order (stage 1: job order; stage 2: ``s2_ord``
    ordinals).
    """
    def dev(tab):
        return jnp.take(jnp.asarray(tab), me, axis=0)

    R = int(T.R)
    ctx, delta = _encode_stage(wire, T, me, k=k, pk=pk, codec=codec,
                               use_kernels=use_kernels)
    delta = _corrupt_delta(delta, me, corrupt)
    recv = []
    for r in range(1, k):
        if router == "all_to_all":
            idx = dev(T.a2a_send[r - 1])                       # [K, R]
            buf = jnp.where((idx >= 0)[:, :, None],
                            delta[jnp.clip(idx, 0)], 0)        # [K, R, pk]
            got = lax.all_to_all(buf, axis_name, split_axis=0,
                                 concat_axis=0, tiled=True)
            flat = got.reshape(K * R, pk)
            slot = dev(T.a2a_recv[r - 1])                      # [n]
        elif router == "ppermute":
            parts = []
            for dd in range(q):
                idx = dev(T.pp_send[r - 1, dd])                # [R]
                buf = jnp.where((idx >= 0)[:, None],
                                delta[jnp.clip(idx, 0)], 0)
                parts.append(lax.ppermute(
                    buf, axis_name, perm=list(T.pp_perms[r - 1][dd])))
            flat = jnp.concatenate(parts, axis=0)              # [q*R, pk]
            slot = dev(T.pp_recv[r - 1])
        else:
            raise ValueError(f"unknown router {router!r}")
        recv.append(flat[slot])                                # [n, pk]
    recv = jnp.stack(recv, axis=1)                             # [n, k-1, pk]
    return _decode_stage(recv, ctx, T, me, k=k, pk=pk, codec=codec,
                         use_kernels=use_kernels)


def _stage_coded_two_level(axis_name, wire, T: StageTables,
                           X: HostTables, me, *, q, k, K, pk, router,
                           codec, use_kernels, corrupt=None):
    """One coded stage on a two-level topology (DESIGN.md §16).

    Phase A is :func:`_stage_coded_batched`'s round exchange driven by
    the PRIMARY-masked send tables: the only packet copies that cross a
    host boundary are the per-host gateway copies; masked slots arrive
    as zero blocks. Phase B then relays each gateway's copy to the
    other same-host receivers with intra-host cyclic-shift ppermutes
    (every hop stays on the fast edge), filling exactly the recv slots
    phase A zeroed. The reconstructed receive buffer is word-identical
    to the flat exchange's, so decode — and the shuffle output — stays
    bitwise equal to the flat schedule and the serial engine oracle.
    """
    def dev(tab):
        return jnp.take(jnp.asarray(tab), me, axis=0)

    R = int(T.R)
    n = T.n
    ctx, delta = _encode_stage(wire, T, me, k=k, pk=pk, codec=codec,
                               use_kernels=use_kernels)
    delta = _corrupt_delta(delta, me, corrupt)
    # ---- phase A: flat round exchange, primary deliveries only ------- #
    recv = []
    for r in range(1, k):
        if router == "all_to_all":
            idx = dev(X.a2a_send[r - 1])                      # [K, R]
            buf = jnp.where((idx >= 0)[:, :, None],
                            delta[jnp.clip(idx, 0)], 0)       # [K, R, pk]
            got = lax.all_to_all(buf, axis_name, split_axis=0,
                                 concat_axis=0, tiled=True)
            flat = got.reshape(K * R, pk)
            slot = dev(T.a2a_recv[r - 1])                     # [n]
        elif router == "ppermute":
            parts = []
            for dd in range(q):
                idx = dev(X.pp_send[r - 1, dd])               # [R]
                buf = jnp.where((idx >= 0)[:, None],
                                delta[jnp.clip(idx, 0)], 0)
                parts.append(lax.ppermute(
                    buf, axis_name, perm=list(T.pp_perms[r - 1][dd])))
            flat = jnp.concatenate(parts, axis=0)             # [q*R, pk]
            slot = dev(T.pp_recv[r - 1])
        else:
            raise ValueError(f"unknown router {router!r}")
        recv.append(flat[slot])                               # [n, pk]
    recv_a = jnp.stack(recv, axis=1)                          # [n, k-1, pk]

    # ---- phase B: intra-host gateway relay --------------------------- #
    if int(X.Rb):
        Rb = int(X.Rb)
        src_a = recv_a.reshape(n * (k - 1), pk)   # gateway slots only:
        # a relay source is always a PRIMARY (phase-A-filled) slot, so
        # gathering from the phase-A buffer can never read a slot that
        # phase B itself fills
        rounds = []
        for r in range(1, k):
            live = X.b_live[r - 1]
            if not live:
                rounds.append(recv_a[:, r - 1])
                continue
            parts = []
            for di in live:
                idx = dev(X.b_send[r - 1, di])                # [Rb]
                buf = jnp.where((idx >= 0)[:, None],
                                src_a[jnp.clip(idx, 0)], 0)
                parts.append(lax.ppermute(
                    buf, axis_name, perm=list(X.b_perms[di])))
            relay = jnp.concatenate(parts, axis=0)   # [len(live)*Rb, pk]
            slot = dev(X.b_recv[r - 1])                       # [n]
            mask = dev(X.b_mask[r - 1])                       # [n]
            rounds.append(jnp.where(mask[:, None], relay[slot],
                                    recv_a[:, r - 1]))
        recv_a = jnp.stack(rounds, axis=1)

    return _decode_stage(recv_a, ctx, T, me, k=k, pk=pk, codec=codec,
                         use_kernels=use_kernels)


def _stage_coded_looped(axis_name, wire, T: StageTables, rounds_list, me, *,
                        k, pk, codec, use_kernels):
    """Legacy exchange — one ppermute per group per round (benchmark
    baseline; same tables, same encode/decode)."""
    ctx, delta = _encode_stage(wire, T, me, k=k, pk=pk, codec=codec,
                               use_kernels=use_kernels)
    n = T.n
    valid = jnp.take(jnp.asarray(T.valid), me, axis=0)
    recv = jnp.zeros((n, k - 1, pk), dtype=jnp.uint32)
    for gi in range(n):
        payload = jnp.where(valid[gi], delta[gi], 0)
        for r in range(1, k):
            got = lax.ppermute(payload, axis_name,
                               perm=list(rounds_list[gi][r - 1]))
            recv = recv.at[gi, r - 1].set(jnp.where(valid[gi], got,
                                                    recv[gi, r - 1]))
    return _decode_stage(recv, ctx, T, me, k=k, pk=pk, codec=codec,
                         use_kernels=use_kernels)


# --------------------------------------------------------------------- #
# the SPMD shuffle body (runs inside shard_map over `axis_name`)
# --------------------------------------------------------------------- #
def camr_shuffle(plan: CAMRPlan, contribs: jnp.ndarray, *,
                 axis_name: str, mode: str = "batched",
                 router: str = "all_to_all", codec: str = "fused",
                 use_kernels=None, debug: bool = False,
                 verify_wire: bool = False, corrupt=None):
    """3-stage CAMR coded shuffle: contribs [J_own, k-1, K, d] -> [J, d].

    ``codec="fused"`` (default) runs the single-pass gather-XOR codec
    over the schedule's flat index tables; ``codec="multipass"`` is the
    original multi-pass pipeline, kept as the oracle (DESIGN.md §10).

    bf16/f16 contributions take the packed wire lane (DESIGN.md §12):
    two values per u32 word through stages 1+2 and native-width stage-3
    unicasts — half the bytes-on-wire of an f32 shuffle of the same
    ``d``, with the decoded bit patterns exactly equal to the inputs'
    (the XOR transport never does arithmetic on either lane).

    Per-device outputs are BITWISE equal to the numpy engine's reduce
    results for the same contributions: XOR delivery is lossless and
    the assembly folds batch aggregates in the engine's canonical
    combine order (DESIGN.md §11) — the contract the training path's
    cross-mode parameter identity rests on.

    ``verify_wire=True`` runs the self-verifying wire (DESIGN.md §17):
    every coded packet carries one extra u32 checksum word — the XOR
    of its payload words — folded through the SAME codec (checksums of
    XOR-combined packets XOR-combine, so coded data verifies without
    decoding first). Returns ``(out, bad)`` where ``bad`` is this
    device's count of decoded rows whose recomputed checksum
    mismatches: 0 on every healthy wave (valid rows decode to exact
    packets), and ANY single corrupted wire word in stages 1+2 —
    payload or checksum, flat or relay edge — is counted (a one-word
    delta cannot cancel between a payload fold and its checksum).
    ``corrupt=(stage, device, row, word, bits)`` XORs ``bits`` into
    one outgoing Δ word post-encode — the deterministic fault the
    chaos layer replays. Requires the fused batched codec; the jnp
    gather lane is forced (index tables are row-oriented, so the
    widened rows reuse the same tables, but the u16 Pallas kernels
    assume unaugmented packet geometry).
    """
    prog = plan.program
    q, k, K, J, J_own, d = (plan.q, plan.k, plan.K, plan.J, plan.J_own,
                            plan.d)
    dtype = contribs.dtype
    check_codec_dtype(dtype, "camr_shuffle")
    if contribs.shape != (J_own, k - 1, K, d):
        raise ValueError(f"contribs shape {contribs.shape} != "
                         f"{(J_own, k - 1, K, d)}")
    if mode not in ("batched", "looped"):
        raise ValueError(f"unknown mode {mode!r}")
    if codec not in ("fused", "multipass"):
        raise ValueError(f"unknown codec {codec!r}")
    two_level = prog.topology is not None
    if two_level and mode != "batched":
        raise ValueError("two-level topology requires mode='batched' "
                         "(the looped legacy router has no host-aware "
                         "relay lane)")
    if verify_wire:
        if codec != "fused" or mode != "batched":
            raise ValueError("verify_wire requires codec='fused' and "
                             "mode='batched' (the checksum word rides "
                             "the row-oriented fused index tables)")
        if debug:
            raise ValueError("verify_wire and debug are mutually "
                             "exclusive (different return shapes)")
        use_kernels = False
    else:
        if corrupt is not None:
            raise ValueError("corrupt injection without verify_wire "
                             "would silently mis-reduce — exactly the "
                             "failure mode the integrity lane exists "
                             "to rule out")
        use_kernels = _resolve_kernels(use_kernels)
    me = lax.axis_index(axis_name)
    # wire lane (DESIGN.md §12): wp u32 words per shard — d for 4-byte
    # dtypes, ceil(d/2) (+ pad to a packet multiple) for packed 16-bit
    wp = payload_words(d, jnp.dtype(dtype).itemsize, k)
    pk = wp // (k - 1)

    def dev(tab):
        return jnp.take(jnp.asarray(tab), me, axis=0)

    wire = _wire_buffer(contribs, wp=wp, codec=codec,
                        use_kernels=use_kernels)  # [J_own, k-1, K, wp]
    pkv = pk
    if verify_wire:
        # widen every packet row from pk to pk+1 u32 words: payload +
        # its XOR checksum. The fused tables index packet ROWS, so the
        # same enc_src/dec_src/dec_recv drive the widened buffer; row
        # ids are unchanged by the reshape below.
        w4 = wire.reshape(*wire.shape[:-1], k - 1, pk)
        csum = _xor_reduce(w4, axis=w4.ndim - 1)
        wire = jnp.concatenate([w4, csum[..., None]], axis=-1)
        wire = wire.reshape(*wire.shape[:-2], (k - 1) * (pk + 1))
        pkv = pk + 1
    if corrupt is not None:
        cst, cdev, crow, cword, cbits = (int(x) for x in corrupt)
        if not 0 <= cword < pkv:
            raise ValueError(f"corrupt word {cword} outside packet "
                             f"[0, {pkv})")
        if not cbits:
            raise ValueError("corrupt bits must be nonzero")

    # ========== stages 1 + 2: one shared coded-exchange machine ======== #
    stage_vals = {}
    bad = jnp.zeros((), dtype=jnp.int32)
    for stage in (1, 2):
        T = prog.stage_tables(stage)
        spec = ((cdev, crow, cword, cbits)
                if corrupt is not None and cst == stage else None)
        if mode == "batched" and two_level:
            decoded = _stage_coded_two_level(
                axis_name, wire, T, prog.host_tables(stage), me, q=q,
                k=k, K=K, pk=pkv, router=router, codec=codec,
                use_kernels=use_kernels, corrupt=spec)
        elif mode == "batched":
            decoded = _stage_coded_batched(
                axis_name, wire, T, me, q=q, k=k, K=K, pk=pkv,
                router=router, codec=codec, use_kernels=use_kernels,
                corrupt=spec)
        else:
            decoded = _stage_coded_looped(
                axis_name, wire, T, prog.round_perms(stage), me,
                k=k, pk=pk, codec=codec, use_kernels=use_kernels)
        if verify_wire:
            # recompute each decoded row's checksum; non-member rows
            # decode garbage by design and are masked out (T.valid)
            dec3 = decoded.reshape(-1, k - 1, pkv)
            calc = _xor_reduce(dec3[:, :, :pk], axis=2)
            bad_rows = (calc != dec3[:, :, pk]) & dev(T.valid)[:, None]
            bad = bad + jnp.sum(bad_rows.astype(jnp.int32))
            # strip checksum words: the payload words are bit-for-bit
            # the unverified decode's output
            decoded = dec3[:, :, :pk].reshape(-1, (k - 1) * pk)
        stage_vals[stage] = _from_wire(decoded, dtype, d)
    stage1_val = stage_vals[1]   # [J, d]; row j valid where I own job j
    stage2_val = stage_vals[2]   # [n_s2, d]; rows at my s2_ord ordinals

    # sequential ascending left fold over the stored-batch axis — the
    # canonical combine order of CAMREngine.reduce_phase (stored_batches
    # rows are ascending), so the SPMD output is BITWISE equal to the
    # engine's, not merely allclose (a plain .sum() would let XLA pick
    # its own reduction tree).
    def _fold_stored(x):                                    # [J_own, k-1, d]
        acc = x[:, 0]
        for b in range(1, k - 1):
            acc = acc + x[:, b]
        return acc                                          # [J_own, d]

    # ========== stage 3: intra-class unicasts (q-1 full ppermutes) ===== #
    cls_base = (me // q) * q
    s3_out = jnp.zeros((q - 1, J_own, d), dtype=dtype)
    for o in range(1, q):
        dst = cls_base + (me % q + o) % q
        pay = _fold_stored(jnp.take(contribs, dst, axis=2))  # [J_own, d]
        got = lax.ppermute(pay, axis_name, perm=list(prog.s3_perms[o - 1]))
        s3_out = s3_out.at[o - 1].set(got)

    # ========== assemble (reduce-side tables of the program) ========== #
    # value = delivered batch + fold of the other k-1 (owners fold their
    # own aggregates; non-owners get the sender-side fold via stage 3)
    own_sum = _fold_stored(jnp.take(contribs, me, axis=2))  # [J_own, d]
    d_isown = dev(prog.is_own)
    d_slot = dev(prog.own_slot)
    d_s2 = dev(prog.s2_ord)
    d_s3 = dev(prog.s3_off)

    owner_val = stage1_val + own_sum[d_slot]      # [J, d]
    s2_sel = stage2_val[d_s2]
    s3_sel = s3_out[d_s3, d_slot]
    nonowner_val = s2_sel + s3_sel
    out = jnp.where(d_isown[:, None], owner_val, nonowner_val)
    if debug:
        return dict(out=out, stage1=stage1_val, stage2=s2_sel, stage3=s3_sel,
                    own_sum=own_sum[d_slot], is_own=d_isown)
    if verify_wire:
        return out, bad
    return out


def expected_collective_calls(plan: CAMRPlan, mode: str = "batched",
                              router: str = "all_to_all") -> dict[str, int]:
    """Collectives per shuffle — what each mode traces (tested against
    the jaxpr in tests/test_collective.py). On a two-level topology the
    phase-B relay adds one intra-host ppermute per live (round, shift)
    lane of each coded stage."""
    q, k = plan.q, plan.k
    if mode == "batched":
        s12 = 2 * (k - 1) if router == "all_to_all" else 2 * (k - 1) * q
        if plan.topology is not None:
            s12 += sum(len(live) for X in (plan.program.hx1,
                                           plan.program.hx2)
                       for live in X.b_live)
    else:
        s12 = (plan.J + plan.program.n_s2) * (k - 1)
    return dict(stage12=s12, stage3=q - 1, total=s12 + q - 1)


# --------------------------------------------------------------------- #
# helpers for drivers & tests
# --------------------------------------------------------------------- #
def scatter_contributions(plan: CAMRPlan,
                          batch_grads: np.ndarray) -> np.ndarray:
    """batch_grads [J, k, K, d] -> per-device contribs [K, J_own, k-1, K, d]
    per the placement (device s gets the batches it stores)."""
    K, J_own, k = plan.K, plan.J_own, plan.k
    out = np.zeros((K, J_own, k - 1, K, plan.d), dtype=batch_grads.dtype)
    for s in range(K):
        for a, j in enumerate(plan.owned_jobs[s]):
            for b, t in enumerate(plan.stored_batches[s, a]):
                out[s, a, b] = batch_grads[j, t]
    return out


def camr_shuffle_reference(plan: CAMRPlan,
                           batch_grads: np.ndarray) -> np.ndarray:
    """Oracle: out[s, j] = sum over batches of shard s of job j."""
    total = batch_grads.sum(axis=1)               # [J, K, d]
    return np.transpose(total, (1, 0, 2))         # [K, J, d]


def uncoded_reduce_scatter(contribs: jnp.ndarray, *, axis_name: str,
                           plan: CAMRPlan) -> jnp.ndarray:
    """Baseline: mask duplicate batch copies, psum, slice my shard."""
    me = lax.axis_index(axis_name)
    K, J, J_own = plan.K, plan.J, plan.J_own
    first = np.zeros((K, J_own, plan.k - 1), dtype=bool)
    seen = set()
    for s in range(K):
        for a, j in enumerate(plan.owned_jobs[s]):
            for b, t in enumerate(plan.stored_batches[s, a]):
                if (j, t) not in seen:
                    seen.add((j, t))
                    first[s, a, b] = True
    mask = jnp.take(jnp.asarray(first), me, axis=0)
    jl = jnp.take(jnp.asarray(plan.owned_jobs), me, axis=0)
    masked = jnp.where(mask[:, :, None, None], contribs, 0)
    dense = jnp.zeros((J, K, plan.d), contribs.dtype)
    dense = dense.at[jl].add(masked.sum(axis=1))
    total = lax.psum(dense, axis_name)            # [J, K, d]
    return jnp.take(total, me, axis=1)


# --------------------------------------------------------------------- #
# async / double-buffered multi-wave execution (DESIGN.md §9)
# --------------------------------------------------------------------- #
class ShuffleStream:
    """Async, double-buffered multi-wave driver of :func:`camr_shuffle`.

    The SPMD half of the JobStream runtime
    (:class:`repro.runtime.jobstream.JobStream` is the host-side,
    bit-exact reference). Two mechanisms, both byte-preserving:

    * **wave batching** — ``wave_batch`` same-shaped waves are stacked
      along the value axis ``d`` and run as ONE shuffle of width
      ``W*d``. Every step of the codec (packet split, XOR fold,
      cancellation, reassembly) is elementwise per value column, so
      stacking commutes with the whole pipeline and the split outputs
      are exactly the per-wave outputs.
    * **async dispatch with double buffering** — :meth:`submit` issues
      the jitted shard_map computation WITHOUT blocking (jax async
      dispatch); at most ``depth`` dispatched waves keep device buffers
      alive (default 2 = classic double buffering, memory cost model in
      DESIGN.md §9). The oldest in-flight wave is materialized only
      when the window is full, so host-side map/aggregate work for
      wave ``t+1`` overlaps the on-device shuffle of wave ``t``.

    Usage::

        stream = ShuffleStream(q, k, d, mesh=mesh, wave_batch=2)
        outs = stream.run_waves(contribs_list)   # [W][K, J, d]
    """

    def __init__(self, q: int, k: int, d: int, *, mesh,
                 axis_name: str = "camr", depth: int = 2,
                 wave_batch: int = 1, mode: str = "batched",
                 router: str = "all_to_all", codec: str = "fused",
                 use_kernels=None, degraded_lane: str = "device",
                 topology: Topology | None = None,
                 gateway_avoid=frozenset(), verify_wire: bool = False,
                 max_replays: int = 2):
        if k < 3:
            raise ValueError("TPU collective path requires k >= 3")
        if d % (k - 1):
            # validated here, not at dispatch: every stacked width W*d
            # inherits divisibility from d, so a stream can never fail
            # mid-flight on a partial trailing batch
            raise ValueError(f"shard width d={d} must be divisible by "
                             f"k-1={k - 1}")
        if depth < 1:
            raise ValueError("depth must be >= 1")
        if wave_batch < 1:
            raise ValueError("wave_batch must be >= 1")
        self.q, self.k, self.d = q, k, d
        self.K = q * k
        self.mesh = mesh
        self.axis_name = axis_name
        self.depth = depth
        self.wave_batch = wave_batch
        self.mode = mode
        self.router = router
        if codec not in ("fused", "multipass"):
            raise ValueError(f"unknown codec {codec!r}")
        self.codec = codec
        self.use_kernels = use_kernels
        if degraded_lane not in ("device", "host"):
            raise ValueError(f"unknown degraded_lane {degraded_lane!r}")
        self.degraded_lane = degraded_lane
        from .schedule import resolve_topology
        self.topology = resolve_topology(topology, q, k)
        if self.topology is not None:
            self.topology.check(q, k)
            if mode != "batched":
                raise ValueError("two-level topology requires "
                                 "mode='batched'")
        self._gateway_avoid = frozenset(int(x)
                                        for x in (gateway_avoid or ()))
        if any(not 0 <= x < self.K for x in self._gateway_avoid):
            raise ValueError(f"gateway_avoid "
                             f"{sorted(self._gateway_avoid)} has "
                             f"devices outside [0, {self.K})")
        self.verify_wire = bool(verify_wire)
        if self.verify_wire and (codec != "fused" or mode != "batched"):
            raise ValueError("verify_wire requires codec='fused' and "
                             "mode='batched'")
        if max_replays < 0:
            raise ValueError("max_replays must be >= 0")
        self.max_replays = max_replays
        self._jitted: dict = {}                # executor key -> compiled
        self._pending: list = []               # waves awaiting dispatch
        self._in_flight: deque = deque()       # (out, W, t0, buf)
        self._done: list = []                  # host [K, J, d] outputs
        self._corrupt = None                   # one-shot fault spec
        self.dispatches = 0                    # program executions issued
        self.compiles = 0                      # executors traced (per key)
        self.degraded_compiles = 0             # degraded execs built (§15)
        self._failed: frozenset = frozenset()  # current survivor-set gap
        self.swaps = 0                         # degrade/restore events
        self.host_swaps = 0                    # topology re-homings (§17)
        self.wire_faults = 0                   # checksum-flagged waves
        self.wire_replays = 0                  # bitwise replays issued
        self.wave_times: list[float] = []      # dispatch->collect wall s

    # -- compiled executor per (width, topology, gateways, fault) ------- #
    def _gw(self) -> frozenset:
        """Gateway preference in effect — flat has no gateways."""
        return (self._gateway_avoid if self.topology is not None
                else frozenset())

    def _fn(self, W: int, corrupt=None):
        key = (W,
               None if self.topology is None else self.topology.key(),
               tuple(sorted(self._gw())), self.verify_wire, corrupt)
        if key not in self._jitted:
            from jax.sharding import PartitionSpec as P

            from repro.compat import shard_map
            prog = SCHEDULE_CACHE.program(self.q, self.k, Q=self.K,
                                          d=W * self.d,
                                          topology=self.topology,
                                          gateway_avoid=self._gw())
            plan = CAMRPlan(q=self.q, k=self.k, d=W * self.d,
                            program=prog)
            verify = self.verify_wire

            def body(c):
                r = camr_shuffle(plan, c[0], axis_name=self.axis_name,
                                 mode=self.mode, router=self.router,
                                 codec=self.codec,
                                 use_kernels=self.use_kernels,
                                 verify_wire=verify, corrupt=corrupt)
                if verify:
                    out, bad = r
                    return out[None], bad[None]
                return r[None]

            self.compiles += 1
            self._jitted[key] = jax.jit(shard_map(
                body, mesh=self.mesh, in_specs=P(self.axis_name),
                out_specs=P(self.axis_name)))
        return self._jitted[key]

    # -- fault domains & gateway failover (DESIGN.md §17) --------------- #
    @property
    def gateway_avoid(self) -> frozenset:
        return self._gw()

    def set_topology(self, topology) -> None:
        """Re-home subsequent dispatches onto ``topology`` — the
        whole-host recovery path: after ``HostMembership.kill_host``,
        pass its ``current_topology()`` here. Purely a re-keying:
        executors compiled for other topologies stay resident (a later
        rejoin swaps back retrace-free) and the schedule comes from the
        warm cache — zero cold lowerings after
        :meth:`warm_host_survivors`. Waves already in flight were
        dispatched under the old topology and complete unchanged;
        outputs are bitwise identical across topologies (§16)."""
        from .schedule import resolve_topology
        t = resolve_topology(topology, self.q, self.k)
        if t is not None:
            t.check(self.q, self.k)
            if self.mode != "batched":
                raise ValueError("two-level topology requires "
                                 "mode='batched'")
        if t != self.topology:
            self.topology = t
            self.host_swaps += 1

    def set_gateway_avoid(self, avoid) -> None:
        """Prefer phase-A gateways OUTSIDE ``avoid`` for subsequent
        dispatches (straggler failover — feed it
        ``Membership.gateway_avoid()``). Joins the executor and
        schedule-cache keys; outputs are bitwise identical for every
        assignment, so this is pure routing policy."""
        fs = frozenset(int(x) for x in (avoid or ()))
        if any(not 0 <= x < self.K for x in fs):
            raise ValueError(f"gateway_avoid {sorted(fs)} has devices "
                             f"outside [0, {self.K})")
        self._gateway_avoid = fs

    def warm_host_survivors(self, *, max_host_failures: int = 1) -> int:
        """Pre-pay the surviving-topology lowering of every loss of up
        to ``max_host_failures`` hosts (ScheduleCache
        .warm_host_survivors), so a later :meth:`set_topology` on the
        kill path is a pure cache hit. Returns survivor topologies
        warmed."""
        if self.topology is None:
            raise ValueError("warm_host_survivors needs a two-level "
                             "stream (flat has no hosts to lose)")
        prog = SCHEDULE_CACHE.program(self.q, self.k, Q=self.K,
                                      d=self.d, topology=self.topology,
                                      gateway_avoid=self._gw())
        return SCHEDULE_CACHE.warm_host_survivors(
            prog, max_host_failures=max_host_failures)

    def inject_corruption(self, *, stage: int = 1, device: int = 0,
                          row=None, word: int = 0, bits: int = 1) -> None:
        """Arm a ONE-SHOT deterministic wire fault: the next dispatched
        wave XORs ``bits`` into outgoing Δ word ``(row, word)`` of
        ``device`` in coded stage ``stage`` (the chaos layer's
        ``CorruptPacket``). The supervisor detects it via the checksum
        word and replays the wave bitwise through the clean executor —
        the transient-fault model. ``row=None`` picks the device's
        first participating group row so the tampered packet is always
        actually sent."""
        if not self.verify_wire:
            raise ValueError("inject_corruption needs verify_wire=True "
                             "— corrupting an unverified wire would "
                             "silently mis-reduce")
        if stage not in (1, 2):
            raise ValueError(f"stage must be 1 or 2, got {stage}")
        if not 0 <= device < self.K:
            raise ValueError(f"device {device} outside [0, {self.K})")
        if not 0 < int(bits) < 2 ** 32:
            raise ValueError("bits must be a nonzero u32 pattern")
        prog = SCHEDULE_CACHE.program(self.q, self.k, Q=self.K,
                                      d=self.d, topology=self.topology,
                                      gateway_avoid=self._gw())
        T = prog.stage_tables(stage)
        if row is None:
            rows = np.flatnonzero(np.asarray(T.valid)[device])
            if not len(rows):
                raise ValueError(f"device {device} participates in no "
                                 f"stage-{stage} group")
            row = int(rows[0])
        if not 0 <= int(row) < T.n:
            raise ValueError(f"row {row} outside [0, {T.n})")
        self._corrupt = (int(stage), int(device), int(row), int(word),
                         int(bits))

    def _take_corrupt(self):
        spec, self._corrupt = self._corrupt, None
        return spec

    def _verified(self, res, bad, buf, W: int):
        """Supervisor half of the integrity lane: block on the per-
        device mismatch counts; on any fault, replay the SAME wave
        through the clean executor (transient-fault model) up to
        ``max_replays`` times, then raise ``WireCorruptionError``.
        Replays are bitwise — the payload words a clean pass decodes
        are exactly the unverified lane's (DESIGN.md §17)."""
        total = int(np.asarray(jax.block_until_ready(bad)).sum())
        if total:
            self.wire_faults += 1
        replays = 0
        while total:
            if replays >= self.max_replays:
                from repro.runtime.fault import WireCorruptionError
                raise WireCorruptionError(
                    f"wave failed wire verification after {replays} "
                    f"bitwise replays ({total} corrupted packet rows "
                    "persist) — persistent corruption, not a "
                    "transient fault; quarantine the link")
            replays += 1
            self.wire_replays += 1
            self.dispatches += 1
            res, bad = self._fn(W)(buf)
            total = int(np.asarray(jax.block_until_ready(bad)).sum())
        return res

    # -- live elasticity (DESIGN.md §14) -------------------------------- #
    @property
    def failed(self) -> frozenset:
        return self._failed

    def degrade(self, failed) -> None:
        """Swap subsequent dispatches to the survivor set ``failed``.

        Validates recoverability up front (unrecoverable sets raise
        ``ValueError`` exactly as :func:`~repro.core.schedule
        .lower_degraded` does) and pulls the re-lowering from the warm
        :data:`SCHEDULE_CACHE`. Waves already in flight were dispatched
        healthy and complete unchanged — a real survivor set only
        affects exchanges issued after the membership change. Degraded
        waves run a COMPILED dense survivor-set executor on device
        (:func:`repro.runtime.fault.build_degraded_executor`, served
        from the process-wide EXEC_CACHE — zero retraces after
        :meth:`warm_degraded_execs`), bitwise-identical to the fault
        runtime's host interpreter, which remains available as the
        ``degraded_lane="host"`` fallback/oracle. The compiled healthy
        executors stay resident either way, so :meth:`restore` is
        retrace-free (``compiles`` flat).
        """
        failed = frozenset(int(s) for s in failed)
        if not failed:
            self.restore()
            return
        prog = SCHEDULE_CACHE.program(self.q, self.k, Q=self.K,
                                      d=self.d, topology=self.topology,
                                      gateway_avoid=self._gw())
        SCHEDULE_CACHE.degraded(prog, failed)   # validate + warm
        if failed != self._failed:
            self._failed = failed
            self.swaps += 1

    def restore(self) -> None:
        """Re-admit everyone: subsequent dispatches run the compiled
        healthy executor again (no retrace — the jitted cache never
        dropped)."""
        if self._failed:
            self._failed = frozenset()
            self.swaps += 1

    def _degraded_fn(self, W: int, dtype, failed=None):
        """The compiled dense degraded executor for stack width ``W``
        and value ``dtype``, AOT-built into the process-wide
        EXEC_CACHE (so a later stream of the same shape — or a
        :meth:`warm_degraded_execs` call before any failure — makes a
        mid-stream degrade completely build-free)."""
        failed = self._failed if failed is None else failed
        topo = None if self.topology is None else self.topology.key()
        key = ("spmd_degraded", self.q, self.k, self.K, W * self.d,
               str(jnp.dtype(dtype)), tuple(sorted(failed)), topo)

        def build():
            from repro.runtime.fault import build_degraded_executor
            prog = SCHEDULE_CACHE.program(self.q, self.k, Q=self.K,
                                          d=W * self.d,
                                          topology=self.topology,
                                          gateway_avoid=self._gw())
            self.degraded_compiles += 1
            return build_degraded_executor(prog, failed, W * self.d,
                                           dtype)

        return EXEC_CACHE.get(key, build)

    def warm_degraded_execs(self, *, max_failures: int = 1,
                            widths=(1,), dtype=np.float32) -> int:
        """Pre-compile the dense degraded executor of every recoverable
        survivor set with up to ``max_failures`` concurrent failures
        (x stack ``widths`` x ``dtype``), alongside the schedule
        warm-up of :meth:`~repro.core.schedule.ScheduleCache
        .warm_survivors` — after this, a mid-stream :meth:`degrade`
        pays neither a lowering nor a compile on the recovery critical
        path (DESIGN.md §15). Returns the number of executables now
        resident."""
        from itertools import combinations
        prog = SCHEDULE_CACHE.program(self.q, self.k, Q=self.K,
                                      d=self.d, topology=self.topology,
                                      gateway_avoid=self._gw())
        SCHEDULE_CACHE.warm_survivors(prog, max_failures=max_failures)
        warmed = 0
        for r in range(1, max_failures + 1):
            for combo in combinations(range(self.K), r):
                fs = frozenset(combo)
                try:
                    SCHEDULE_CACHE.degraded(prog, set(fs))
                except ValueError:
                    continue                   # unrecoverable: skip
                for W in widths:
                    self._degraded_fn(W, dtype, failed=fs)
                    warmed += 1
        return warmed

    def _degraded_exec(self, buf, W: int):
        """Degraded wave over the stacked [K, J_own, k-1, K, W*d]
        tensor, bitwise-identical to the healthy executor's output
        (DESIGN.md §11), in logical slots. ``degraded_lane="device"``
        dispatches the compiled dense executor (async, output stays on
        device); ``"host"`` interprets the re-lowering in numpy — the
        fallback and the oracle the device lane is gated against."""
        if self.degraded_lane == "device":
            dtype = getattr(buf, "dtype", None)
            if dtype is None:
                buf = np.asarray(buf)
                dtype = buf.dtype
            return self._degraded_fn(W, dtype)(jnp.asarray(buf))
        from repro.runtime.fault import degraded_shuffle_host
        prog = SCHEDULE_CACHE.program(self.q, self.k, Q=self.K,
                                      d=W * self.d,
                                      topology=self.topology,
                                      gateway_avoid=self._gw())
        return degraded_shuffle_host(prog, self._failed,
                                     np.asarray(buf))

    def _check_wave(self, contribs) -> None:
        shape = (self.K, self.q ** (self.k - 2), self.k - 1, self.K,
                 self.d)
        if tuple(np.shape(contribs)) != shape:
            raise ValueError(f"wave shape {np.shape(contribs)} != {shape}")
        # dtype guard here, not at dispatch: like the width check above,
        # a stream must never discover an uncodable wave mid-flight.
        # getattr, not np.asarray: a device-array wave must not be
        # synced/copied to host just to read its dtype (dtype-less
        # inputs still hit camr_shuffle's own entry guard at dispatch)
        dtype = getattr(contribs, "dtype", None)
        if dtype is not None:
            check_codec_dtype(dtype, "ShuffleStream")

    # -- streaming ------------------------------------------------------ #
    def submit(self, contribs) -> None:
        """Queue one wave ``[K, J_own, k-1, K, d]``; dispatches as soon
        as ``wave_batch`` waves are pending. Never blocks on compute
        unless the double buffer is full."""
        self._check_wave(contribs)
        self._pending.append(contribs)
        if len(self._pending) >= self.wave_batch:
            self._dispatch()

    # -- multi-step reuse (training grad-sync path) --------------------- #
    def sync(self, contribs):
        """Run ONE wave through the stream's compiled executor and
        return the ``[K, J, d]`` **device** output (async dispatch, no
        host copy) — the training grad-sync path: one lowered plan and
        one compiled executor reused across every step, with the output
        left on the mesh for the device-resident optimizer update
        (DESIGN.md §11). Independent of the submit/drain double buffer.
        """
        self._check_wave(contribs)
        self.dispatches += 1
        if self._failed:
            return self._degraded_exec(contribs, 1)
        if self.verify_wire:
            res, bad = self._fn(1, corrupt=self._take_corrupt())(contribs)
            return self._verified(res, bad, contribs, 1)
        return self._fn(1)(contribs)

    def stats(self) -> dict:
        """Executor-reuse counters (``compiles`` stays flat while
        ``dispatches`` grows on a steady-state stream — including
        across degrade/restore ``swaps`` and topology
        ``host_swaps``)."""
        return dict(dispatches=self.dispatches, compiles=self.compiles,
                    widths=sorted({key[0] for key in self._jitted}),
                    swaps=self.swaps,
                    failed=tuple(sorted(self._failed)),
                    degraded_compiles=self.degraded_compiles,
                    degraded_lane=self.degraded_lane,
                    topology=(None if self.topology is None
                              else self.topology.key()),
                    gateway_avoid=tuple(sorted(self._gw())),
                    host_swaps=self.host_swaps,
                    verify_wire=self.verify_wire,
                    wire_faults=self.wire_faults,
                    wire_replays=self.wire_replays)

    def _dispatch(self) -> None:
        waves, self._pending = self._pending, []
        if not waves:
            return
        buf = (waves[0] if len(waves) == 1
               else np.concatenate([np.asarray(w) for w in waves],
                                   axis=-1))
        t0 = time.perf_counter()
        keep = None
        if self._failed:
            # degraded waves run the dense survivor-set executor — no
            # coded wire, nothing to checksum (host-oracle-gated lane)
            out = self._degraded_exec(buf, len(waves))
        elif self.verify_wire:
            out = self._fn(len(waves), corrupt=self._take_corrupt())(buf)
            keep = buf                  # retained for a bitwise replay
        else:
            out = self._fn(len(waves))(buf)    # async: returns immediately
        self.dispatches += 1
        self._in_flight.append((out, len(waves), t0, keep))
        while len(self._in_flight) > self.depth:
            self._collect_oldest()

    def _collect_oldest(self) -> None:
        out, W, t0, buf = self._in_flight.popleft()
        if isinstance(out, tuple):                     # integrity lane
            res = self._verified(out[0], out[1], buf, W)
            arr = np.asarray(jax.block_until_ready(res))
        else:
            arr = np.asarray(jax.block_until_ready(out))   # [K, J, W*d]
        self.wave_times.append(time.perf_counter() - t0)
        if W == 1:
            self._done.append(arr)
        else:
            self._done.extend(
                arr[..., w * self.d:(w + 1) * self.d] for w in range(W))

    def drain(self) -> list[np.ndarray]:
        """Flush pending waves, block on everything in flight, and
        return all completed ``[K, J, d]`` outputs in submission order."""
        self._dispatch()
        while self._in_flight:
            self._collect_oldest()
        done, self._done = self._done, []
        return done

    def run_waves(self, waves) -> list[np.ndarray]:
        """Convenience: submit every wave, then drain."""
        for w in waves:
            self.submit(w)
        return self.drain()


def camr_collective_bytes(plan: CAMRPlan, itemsize: int = 4,
                          dtype=None) -> dict[str, int]:
    """On-wire bytes per device-step of the SPMD schedule (p2p model),
    for the §Perf comparison against psum-based reduce-scatter.

    ``dtype`` selects the wire lane: 16-bit dtypes pack two values per
    u32 word through the coded stages 1+2 (plus at most ``k-2`` pad
    words per shard) and ship stage-3 unicasts at native width, so the
    total is ~half the f32 bytes for the same element payload ``d``
    (DESIGN.md §12).
    """
    if dtype is not None:
        check_codec_dtype(dtype, "camr_collective_bytes")
        itemsize = jnp.dtype(dtype).itemsize
    k, q, J, J_own, K, d = (plan.k, plan.q, plan.J, plan.J_own, plan.K,
                            plan.d)
    # coded packets move as u32 wire words regardless of payload dtype
    pk_b = (payload_words(d, itemsize, k) // (k - 1)) * 4
    s1 = J * (k - 1) * pk_b * k            # J groups, k-1 rounds, k senders
    s2 = plan.program.n_s2 * (k - 1) * pk_b * k
    s3 = (q - 1) * J_own * d * itemsize * K
    # uncoded alternative: psum of [J, K, d] dense gradient (ring):
    ring = 2 * (K - 1) * J * K * d * itemsize
    return dict(stage1=s1, stage2=s2, stage3=s3,
                camr_total=s1 + s2 + s3, psum_ring_total=ring)


def camr_edge_bytes(plan: CAMRPlan, itemsize: int = 4,
                    dtype=None) -> dict[str, int]:
    """Per-edge bytes of the flat vs two-level schedules, MEASURED from
    the lowered send tables (DESIGN.md §16) — not the closed form.

    Walks the actual routing tables the executor drives the wire with:
    every kept ``a2a_send`` entry is one packet delivery, classified by
    the host blocks of its sender and receiver under the plan's
    two-level topology; phase-B relay hops (``b_send``) are intra-host
    by construction. Stage-3 unicasts are intra-class and parallel
    classes sit inside host blocks (``hosts | k``), so stage 3 never
    crosses under either schedule. ``benchmarks/bench_topology.py``
    gates these measured counts against the analytic
    :func:`repro.core.loads.camr_load_hierarchical` prediction.

    Requires a plan lowered with a two-level topology (the flat plan
    has no host structure to classify against).
    """
    prog = plan.program
    topo = prog.topology
    if topo is None:
        raise ValueError("camr_edge_bytes needs a plan lowered with a "
                         "two-level topology (make_plan(..., topology="
                         "Topology.two_level(hosts)))")
    if dtype is not None:
        check_codec_dtype(dtype, "camr_edge_bytes")
        itemsize = jnp.dtype(dtype).itemsize
    k, q, K, d, J_own = plan.k, plan.q, plan.K, plan.d, plan.J_own
    pk_b = (payload_words(d, itemsize, k) // (k - 1)) * 4
    host = np.arange(K) // topo.devices_per_host(K)
    cross = host[:, None] != host[None, :]                  # [K, K]
    flat = dict(inter=0, intra=0)
    two = dict(inter=0, intra=0)
    for stage in (1, 2):
        T = prog.stage_tables(stage)
        X = prog.host_tables(stage)
        for tab, acc in ((T.a2a_send, flat), (X.a2a_send, two)):
            kept = (tab >= 0).sum(axis=3).sum(axis=0)       # [K, K]
            acc["inter"] += int(kept[cross].sum())
            acc["intra"] += int(kept[~cross].sum())
        two["intra"] += int((X.b_send >= 0).sum())          # relay hops
    s3_b = (q - 1) * J_own * d * itemsize * K               # intra-host
    return dict(
        hosts=topo.hosts, packet_bytes=pk_b,
        flat_inter_bytes=flat["inter"] * pk_b,
        flat_intra_bytes=flat["intra"] * pk_b + s3_b,
        two_level_inter_bytes=two["inter"] * pk_b,
        two_level_intra_bytes=two["intra"] * pk_b + s3_b,
        s3_inter_bytes=0)
