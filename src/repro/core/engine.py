"""Executable aggregated-MapReduce engine (single-host simulator of K servers).

Runs the full CAMR pipeline — Map, per-batch Combine (the paper's
"aggregation"), 3-stage coded Shuffle, Reduce — with *honest* receiver-side
decoding: every XOR cancellation uses only aggregates recomputed from the
receiver's own map outputs (the Lemma-2 storage condition), and every byte
on the wire is accounted in a :class:`~repro.core.shuffle.ShuffleTrace`.

The engine is the reference oracle for the TPU/shard_map implementation in
:mod:`repro.core.collective` and the test bed for the paper's Examples 1-5.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Sequence

import numpy as np

from .designs import ResolvableDesign
from .placement import Placement
from .schedule import SCHEDULE_CACHE, ShuffleProgram
from .shuffle import (
    ShuffleTrace,
    Transmission,
    coded_multicast_schedule,
    decode_coded_multicast,
)

__all__ = ["CAMRConfig", "CAMREngine", "run_wordcount_example"]

Combine = Callable[[np.ndarray, np.ndarray], np.ndarray]


@dataclass(frozen=True)
class CAMRConfig:
    """Scheme parameters. ``Q`` must be a multiple of ``K`` (paper §II)."""

    q: int
    k: int
    gamma: int = 1
    Q: int | None = None  # defaults to K

    @property
    def K(self) -> int:
        return self.q * self.k

    @property
    def J(self) -> int:
        return self.q ** (self.k - 1)

    @property
    def N(self) -> int:
        return self.k * self.gamma

    def num_functions(self) -> int:
        Q = self.K if self.Q is None else self.Q
        if Q % self.K:
            raise ValueError("Q must be a multiple of K")
        return Q


@dataclass
class _ServerState:
    """Local state of one simulated server."""

    # (job, batch) -> (Q, d) array of per-batch aggregates, one row per fn
    agg: dict[tuple[int, int], np.ndarray] = field(default_factory=dict)
    # decoded stage-1/2 values: (job, batch, qfunc) -> (d,) array
    recv_batch: dict[tuple[int, int, int], np.ndarray] = field(
        default_factory=dict)
    # decoded stage-3 values: (job, qfunc) -> (d,) aggregate of k-1 batches
    recv_rest: dict[tuple[int, int], np.ndarray] = field(default_factory=dict)
    map_invocations: int = 0


class CAMREngine:
    """Execute J aggregated-MapReduce jobs on K simulated servers.

    Parameters
    ----------
    cfg
        Scheme parameters (q, k, gamma, Q).
    map_fn
        ``map_fn(job, subfile_payload) -> (Q, d) float/int array``; row ``f``
        is the intermediate value of output function ``f`` on that subfile.
    combine
        Associative+commutative pairwise combiner (default ``np.add`` —
        linear aggregation). Applied elementwise to value arrays.
    """

    def __init__(self, cfg: CAMRConfig, map_fn, combine: Combine = np.add,
                 label_perm=None):
        self.cfg = cfg
        # the engine is a numpy interpreter of the compiled schedule —
        # the SAME tables the SPMD collective executes (schedule.py);
        # the structural SCHEDULE_CACHE shares one lowering (and one
        # design/placement) across every engine of a configuration.
        self.program: ShuffleProgram = SCHEDULE_CACHE.program(
            cfg.q, cfg.k, gamma=cfg.gamma, Q=cfg.num_functions(),
            label_perm=label_perm, device_tables=False)
        self.design: ResolvableDesign = self.program.design
        self.placement: Placement = self.program.placement
        self.map_fn = map_fn
        self.combine = combine
        self.trace = ShuffleTrace()
        self.servers = [_ServerState() for _ in range(cfg.K)]
        self._value_dim: int | None = None
        self._dtype = None
        #: per-server wall seconds spent in the last map phase — the
        #: wave-timing signal the elastic runtime's straggler detector
        #: consumes (repro.runtime.fault.Membership.observe).
        self.map_times = np.zeros(cfg.K)

    # ------------------------------------------------------------------ #
    # function assignment: server s reduces functions {s, s+K, ...}
    # ------------------------------------------------------------------ #
    def functions_of(self, server: int) -> list[int]:
        Q = self.cfg.num_functions()
        return list(range(server, Q, self.cfg.K))

    # ------------------------------------------------------------------ #
    # phases
    # ------------------------------------------------------------------ #
    def run(self, datasets: Sequence[Sequence]) -> list[dict[int, np.ndarray]]:
        """Run all phases. ``datasets[j][n]`` is subfile n of job j.

        Returns ``results`` with ``results[s][ (j, f) ] = reduced value`` for
        every function ``f`` assigned to server ``s`` and every job ``j``.
        """
        d = self.design
        if len(datasets) != d.J:
            raise ValueError(f"need {d.J} job datasets, got {len(datasets)}")
        for ds in datasets:
            if len(ds) != self.placement.N:
                raise ValueError(
                    f"each job needs N={self.placement.N} subfiles")
        self.map_phase(datasets)
        self.shuffle_phase()
        return self.reduce_phase()

    def reset(self) -> None:
        """Clear all per-run state (aggregates, decoded values, trace)."""
        self.trace = ShuffleTrace()
        self.servers = [_ServerState() for _ in range(self.cfg.K)]
        self._value_dim = None
        self._dtype = None
        self.map_times = np.zeros(self.cfg.K)

    def run_stream(self, waves) -> list:
        """Serial multi-wave loop: :meth:`run` on each element of
        ``waves`` (a sequence of per-wave ``datasets``) with fresh state
        in between. This is the correctness oracle the pipelined
        :class:`repro.runtime.jobstream.JobStream` must match
        bit-for-bit (DESIGN.md §9)."""
        out = []
        for datasets in waves:
            self.reset()
            out.append(self.run(datasets))
        return out

    def map_phase(self, datasets) -> None:
        pl, d = self.placement, self.design
        for s in range(d.K):
            t_start = time.perf_counter()
            st = self.servers[s]
            for job, t in pl.stored_batches(s):
                vals = []
                for n in pl.batch_subfiles(t):
                    v = np.asarray(self.map_fn(job, datasets[job][n]))
                    if v.ndim != 2 or v.shape[0] != self.cfg.num_functions():
                        raise ValueError(
                            f"map_fn must return (Q, d), got {v.shape}")
                    vals.append(v)
                    st.map_invocations += 1
                agg = vals[0]
                for v in vals[1:]:
                    agg = self.combine(agg, v)  # per-batch aggregation
                st.agg[(job, t)] = agg
                self._value_dim = agg.shape[1]
                self._dtype = agg.dtype
            self.map_times[s] = time.perf_counter() - t_start

    # -- payload helpers ------------------------------------------------ #
    def _ser(self, arr: np.ndarray) -> bytes:
        return np.ascontiguousarray(arr).tobytes()

    def _de(self, raw: bytes) -> np.ndarray:
        return np.frombuffer(raw, dtype=self._dtype).copy()

    @property
    def value_bytes(self) -> int:
        """B in the paper — size of one intermediate/aggregate value."""
        return self._value_dim * np.dtype(self._dtype).itemsize

    def shuffle_phase(self) -> None:
        ngroups = self.cfg.num_functions() // self.cfg.K
        for g in range(ngroups):  # Q/K repetitions (paper §II)
            self._stage1(g)
            self._stage2(g)
            self._stage3(g)

    def _run_coded_group(self, row: int, stage: int, fn_group: int) -> None:
        """Algorithm 2 on one group row of the compiled program: encode
        from holder aggregates, honest receiver-side decode."""
        K = self.cfg.K
        prog = self.program
        G = prog.group_members(row)
        specs = prog.coded_chunks(row)           # [(receiver, job, batch)]
        # true chunk values, computed from any holder's map outputs and
        # cross-checked across all holders (deterministic map).
        chunks: dict[int, bytes] = {}
        for kp, job, batch in specs:
            qf = fn_group * K + kp
            holders = [s for s in G if s != kp]
            vals = [self.servers[h].agg[(job, batch)][qf]
                    for h in holders]
            for v in vals[1:]:
                np.testing.assert_array_equal(vals[0], v)
            chunks[kp] = self._ser(vals[0])
        txs = coded_multicast_schedule(
            G, chunks, stage=stage, tag=("group", G, "fn", fn_group))
        for t in txs:
            self.trace.add(t)
        # honest decode at every receiver, from ITS OWN aggregates
        clen = len(next(iter(chunks.values())))
        for kp, job, batch in specs:
            known = {}
            for kp2, job2, batch2 in specs:
                if kp2 == kp:
                    continue
                qf2 = fn_group * K + kp2
                own = self.servers[kp].agg.get((job2, batch2))
                if own is None:
                    raise AssertionError(
                        "Lemma-2 condition violated: receiver cannot "
                        "recompute a cancellation chunk")
                known[kp2] = self._ser(own[qf2])
            dec = decode_coded_multicast(G, kp, txs, known, clen)
            qf = fn_group * K + kp
            self.servers[kp].recv_batch[(job, batch, qf)] = self._de(dec)

    def _coded_stage(self, stage: int, fn_group: int) -> None:
        """Interpret stages 1/2 of the program (shared machinery)."""
        for row in self.program.stage_rows(stage):
            self._run_coded_group(int(row), stage, fn_group)

    def _stage1(self, fn_group: int) -> None:
        self._coded_stage(1, fn_group)

    def _stage2(self, fn_group: int) -> None:
        self._coded_stage(2, fn_group)

    def _stage3(self, fn_group: int) -> None:
        K = self.cfg.K
        prog = self.program
        for i in range(len(prog.s3_job)):
            job = int(prog.s3_job[i])
            rcv = int(prog.s3_recv[i])
            snd = int(prog.s3_send[i])
            qf = fn_group * K + rcv
            sender_st = self.servers[snd]
            acc = None
            for t in prog.s3_batches[i]:
                v = sender_st.agg[(job, int(t))][qf]
                acc = v if acc is None else self.combine(acc, v)
            payload = self._ser(acc)
            self.trace.add(Transmission(
                stage=3, sender=snd, receivers=(rcv,),
                payload=payload, tag=("job", job, "fn", fn_group)))
            self.servers[rcv].recv_rest[(job, qf)] = self._de(payload)

    def reduce_phase(self) -> list[dict[tuple[int, int], np.ndarray]]:
        # Canonical combine order (the bit-identity contract every
        # executor of the schedule honors — collective.py, baselines.py,
        # fault.py): value = delivered_batch + fold_asc(other k-1
        # batches), where fold_asc is a sequential left fold in
        # ascending batch order. With a deterministic combiner this
        # makes all executors BITWISE equal, not merely allclose.
        pl, d = self.placement, self.design
        results: list[dict[tuple[int, int], np.ndarray]] = []
        for s in range(d.K):
            st = self.servers[s]
            out: dict[tuple[int, int], np.ndarray] = {}
            for qf in self.functions_of(s):
                for j in range(d.J):
                    if d.is_owner(s, j):
                        tmiss = pl.batch_of_label(j, s)
                        rest = None
                        for t in range(d.k):
                            if t != tmiss:
                                v = st.agg[(j, t)][qf]
                                rest = v if rest is None \
                                    else self.combine(rest, v)
                        acc = self.combine(st.recv_batch[(j, tmiss, qf)],
                                           rest)
                    else:
                        # stage-2 value covers the class-mate owner's missing
                        # batch; stage-3 value covers the other k-1 batches
                        # (already an ascending fold at the sender).
                        cls = d.class_of(s)
                        (l,) = [u for u in d.owners[j]
                                if d.class_of(u) == cls]
                        tl = pl.batch_of_label(j, l)
                        acc = self.combine(st.recv_batch[(j, tl, qf)],
                                           st.recv_rest[(j, qf)])
                    out[(j, qf)] = acc
            results.append(out)
        return results

    # ------------------------------------------------------------------ #
    # verification helpers
    # ------------------------------------------------------------------ #
    def oracle(self, datasets) -> dict[tuple[int, int], np.ndarray]:
        """Uncoded single-machine ground truth for every (job, function)."""
        out = {}
        for j in range(self.design.J):
            vals = [np.asarray(self.map_fn(j, sf)) for sf in datasets[j]]
            acc = vals[0]
            for v in vals[1:]:
                acc = self.combine(acc, v)
            for qf in range(self.cfg.num_functions()):
                out[(j, qf)] = acc[qf]
        return out

    def verify(self, datasets, results) -> None:
        oracle = self.oracle(datasets)
        for s, res in enumerate(results):
            for (j, qf), v in res.items():
                np.testing.assert_allclose(
                    v, oracle[(j, qf)], rtol=1e-6, atol=1e-6,
                    err_msg=f"server {s} job {j} fn {qf}")

    def measured_loads(self) -> dict[str, float]:
        """Per-stage + total load, both cost models (DESIGN.md §3)."""
        J, Q, B = self.design.J, self.cfg.num_functions(), self.value_bytes
        out = {}
        for model in ("bus", "p2p"):
            for st in (1, 2, 3):
                out[f"L_stage{st}_{model}"] = self.trace.load(
                    J, Q, B, stage=st, model=model)
            out[f"L_total_{model}"] = self.trace.load(J, Q, B, model=model)
        return out


# --------------------------------------------------------------------- #
# the paper's running example, runnable end to end
# --------------------------------------------------------------------- #
def run_wordcount_example(q: int = 2, k: int = 3, gamma: int = 2,
                          vocab: int | None = None, seed: int = 0):
    """Paper Example 1: J jobs counting Q words in N-chapter books.

    Returns (engine, results, loads). Each subfile is a chapter = array of
    word ids; function f counts word f. Uses d=1 values (a count).
    """
    cfg = CAMRConfig(q=q, k=k, gamma=gamma)
    Q = cfg.num_functions()
    vocab = vocab or Q
    rng = np.random.default_rng(seed)
    datasets = [
        [rng.integers(0, vocab, size=50) for _ in range(cfg.N)]
        for _ in range(cfg.J)
    ]

    def map_fn(job, chapter):
        counts = np.bincount(chapter % Q, minlength=Q).astype(np.int64)
        return counts[:, None]  # (Q, 1)

    eng = CAMREngine(cfg, map_fn)
    results = eng.run(datasets)
    eng.verify(datasets, results)
    return eng, results, eng.measured_loads()
