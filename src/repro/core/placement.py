"""File placement — paper Algorithm 1.

Each job's dataset is split into ``N = k * gamma`` subfiles, grouped into
``k`` batches of ``gamma`` consecutive subfiles. Batch ``t`` of job ``j`` is
*labeled* with one owner of ``j`` (a bijection batches <-> owners); every
owner stores all batches of the job EXCEPT the one carrying its own label.

The batch an owner misses is exactly the one whose aggregate it must receive
in shuffle stage 1; the batch labeled by owner ``l`` is the one shared by all
other owners and needed by stage-2/3 receivers.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import lru_cache

import numpy as np

from .designs import ResolvableDesign

__all__ = ["Placement", "make_placement"]


@dataclass(frozen=True, eq=False)  # identity hash: methods are lru_cached
class Placement:
    """Placement of ``J`` jobs x ``N`` subfiles onto ``K`` servers.

    ``label_perm[j]`` maps batch index ``t`` (0..k-1) to the *owner position*
    (index into ``design.owners[j]``) whose label the batch carries. The
    default is the identity (sorted-owner order); the paper's Example 2 uses
    a different bijection — correctness and loads are invariant (tested).
    """

    design: ResolvableDesign
    gamma: int
    label_perm: tuple[tuple[int, ...], ...] = field(default=None)  # type: ignore[assignment]

    def __post_init__(self):
        if self.gamma < 1:
            raise ValueError("gamma must be >= 1")
        if self.label_perm is None:
            ident = tuple(range(self.design.k))
            object.__setattr__(
                self, "label_perm", tuple(ident for _ in range(self.design.J))
            )

    # ------------------------------------------------------------------ #
    @property
    def N(self) -> int:
        """Subfiles per job."""
        return self.design.k * self.gamma

    def batch_subfiles(self, t: int) -> tuple[int, ...]:
        """Subfile indices (within a job) of batch ``t``."""
        return tuple(range(t * self.gamma, (t + 1) * self.gamma))

    # ------------------------------------------------------------------ #
    # batch labeling
    # ------------------------------------------------------------------ #
    def batch_owner_label(self, job: int, t: int) -> int:
        """Server id whose label batch ``t`` of ``job`` carries."""
        pos = self.label_perm[job][t]
        return self.design.owners[job][pos]

    def batch_of_label(self, job: int, server: int) -> int:
        """Batch index of ``job`` labeled by owner ``server``."""
        owners = self.design.owners[job]
        pos = owners.index(server)
        t = self.label_perm[job].index(pos)
        return t

    # ------------------------------------------------------------------ #
    # storage maps
    # ------------------------------------------------------------------ #
    @lru_cache(maxsize=None)
    def stored_batches(self, server: int) -> tuple[tuple[int, int], ...]:
        """All (job, batch) pairs stored on ``server``.

        An owner stores the k-1 batches of each owned job that do NOT carry
        its own label (Algorithm 1).
        """
        out = []
        for job in self.design.owned_jobs(server):
            skip = self.batch_of_label(job, server)
            out.extend((job, t) for t in range(self.design.k) if t != skip)
        return tuple(out)

    def stores(self, server: int, job: int, t: int) -> bool:
        if not self.design.is_owner(server, job):
            return False
        return t != self.batch_of_label(job, server)

    @lru_cache(maxsize=None)
    def stored_subfiles(self, server: int) -> tuple[tuple[int, int], ...]:
        """All (job, subfile) pairs stored on ``server``."""
        return tuple(
            (job, n)
            for job, t in self.stored_batches(server)
            for n in self.batch_subfiles(t)
        )

    def storage_fraction(self, server: int) -> float:
        """Measured mu for one server; equals (k-1)/K for every server."""
        total = self.design.J * self.N
        return len(self.stored_subfiles(server)) / total

    # ------------------------------------------------------------------ #
    def holders(self, job: int, t: int) -> tuple[int, ...]:
        """Servers storing batch ``t`` of ``job`` (= owners minus label)."""
        lab = self.batch_owner_label(job, t)
        return tuple(s for s in self.design.owners[job] if s != lab)

    def validate(self) -> None:
        d = self.design
        for j in range(d.J):
            # label map is a bijection onto owners
            labs = {self.batch_owner_label(j, t) for t in range(d.k)}
            assert labs == set(d.owners[j])
            for t in range(d.k):
                assert len(self.holders(j, t)) == d.k - 1
        mus = {self.storage_fraction(s) for s in range(d.K)}
        assert all(abs(m - d.storage_fraction) < 1e-12 for m in mus)

    def placement_matrix(self) -> np.ndarray:
        """Boolean (K, J, N) matrix: stored[s, j, n]. For tests/benchmarks."""
        d = self.design
        M = np.zeros((d.K, d.J, self.N), dtype=bool)
        for s in range(d.K):
            for j, n in self.stored_subfiles(s):
                M[s, j, n] = True
        return M


def make_placement(design: ResolvableDesign, gamma: int = 1,
                   label_perm=None) -> Placement:
    if label_perm is not None:
        label_perm = tuple(tuple(p) for p in label_perm)
    return Placement(design=design, gamma=gamma, label_perm=label_perm)
