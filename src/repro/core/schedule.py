"""ShuffleProgram — the compiled IR of the CAMR 3-stage coded shuffle.

One lowering of ``(Placement, Q, d)`` produces dense numpy tables that
every executor consumes (DESIGN.md §5):

* :class:`repro.core.engine.CAMREngine` — numpy interpreter (the oracle),
* :func:`repro.core.collective.camr_shuffle` — SPMD shard_map executor,
* :class:`repro.runtime.fault.DegradedCAMREngine` — re-lowered degraded
  schedule for a surviving server set.

The key structural fact the IR exploits: stage-1 groups (owner sets of a
job) and stage-2 groups both contain exactly one server per parallel
class, so a group IS a value vector ``v in Z_q^k`` (member of class ``i``
is server ``i*q + v_i``). The ``q**k`` value vectors split by parity:

* ``sum(v[:-1]) % q == v[-1]``  -> the vector is an SPC codeword, the
  group is the owner set of job ``rank(v[:-1])``  (stage 1),
* otherwise                     -> a stage-2 group of paper §III-C.2.

This unification is what lets stages 1 and 2 share one table builder and
one batched per-round exchange (the seed implementation duplicated ~200
lines between the engine and the collective, and issued one ppermute per
group per round).

Batched round routing
---------------------
In broadcast round ``r`` (of ``k-1``), the class-``i`` member of EVERY
group sends its coded packet Δ to the class-``(i+r) % k`` member.  A
device must therefore deliver to ``q`` distinct peers per round, so a
single ``lax.ppermute`` per round cannot carry the traffic (a ppermute
moves each device's payload to exactly ONE destination).  The program
precomputes two equivalent routings (DESIGN.md §4):

* ``all_to_all`` — one ``lax.all_to_all`` per round: device ``u`` sends,
  for each destination ``w``, the block of packets for the groups where
  ``u`` and ``w`` are round-``r`` partners.  Exactly ``k-1`` collectives
  per stage, independent of ``J``.
* ``ppermute`` — ``q`` sub-rounds per round: sub-round ``δ`` uses the
  global device permutation ``(i, l) -> ((i+r) % k, (l+δ) % q)`` and
  carries the groups whose round-``r`` value shift equals ``δ``.  Every
  byte on the wire is useful (no zero blocks), at ``q`` ppermutes per
  round.

Both routings share the block lists: for an ordered device pair
``(u, w)`` with classes ``i_u != i_w``, the groups where ``u`` sends to
``w`` in round ``r = (i_w - i_u) % k`` are the value vectors with
``v[i_u] = val(u)`` and ``v[i_w] = val(w)`` — exactly ``q**(k-3)`` of
them in stage 1 and ``q**(k-3) * (q-1)`` in stage 2, sorted by group
rank so sender and receiver agree on row order.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, field, replace
from functools import lru_cache

import numpy as np

from .designs import ResolvableDesign, make_design
from .placement import Placement, make_placement

__all__ = [
    "Topology",
    "AutoTopology",
    "resolve_topology",
    "surviving_topology",
    "HostTables",
    "StageTables",
    "ShuffleProgram",
    "lower_program",
    "DegradedProgram",
    "lower_degraded",
    "ScheduleCache",
    "SCHEDULE_CACHE",
    "ExecCache",
    "EXEC_CACHE",
    "payload_words",
    "pack_payload",
    "unpack_payload",
]


# --------------------------------------------------------------------- #
# interconnect topology (DESIGN.md §16)
# --------------------------------------------------------------------- #
@dataclass(frozen=True)
class Topology:
    """Physical interconnect model the lowering targets.

    ``hosts``  number of hosts; devices are class-major blocks of
               ``dph = K / hosts`` consecutive device ids per host, so
               ``hosts | k`` aligns whole parallel classes to hosts
               (Konstantinidis & Ramamoorthy: resolvable parallel
               classes mapped onto physical groupings).
    ``alpha``  inter-host cost per byte relative to intra-host (>= 1
               in practice; ``alpha = 1`` collapses the cost model to
               the flat per-link one).

    ``hosts <= 1`` IS the flat topology — the identity case: lowering,
    cache keys and executors treat it exactly as ``topology=None``, so
    every existing flat schedule stays bitwise identical.
    """

    hosts: int = 1
    alpha: float = 1.0

    def __post_init__(self):
        if self.hosts < 1:
            raise ValueError(f"hosts must be >= 1, got {self.hosts}")
        if not self.alpha > 0:
            raise ValueError(f"alpha must be > 0, got {self.alpha}")

    @classmethod
    def flat(cls) -> "Topology":
        return cls(hosts=1, alpha=1.0)

    @classmethod
    def two_level(cls, hosts: int, alpha: float = 4.0) -> "Topology":
        if hosts < 2:
            raise ValueError("two-level topology needs hosts >= 2 "
                             f"(got {hosts}); use Topology.flat()")
        return cls(hosts=hosts, alpha=float(alpha))

    @classmethod
    def auto(cls, hosts: int, alpha: float = 4.0) -> "AutoTopology":
        """Defer the flat-vs-two-level choice to plan time.

        Returns an :class:`AutoTopology` marker that every lowering
        entry point resolves against the configuration's ``(q, k)``
        via the closed-form cost model (DESIGN.md §16 follow-on):
        two-level wins exactly when its hierarchical cost
        ``camr_load_hierarchical`` strictly beats the FLAT schedule
        priced on the same hierarchy (which reduces to
        ``camr_load_p2p`` at ``alpha = 1`` — where the pick is flat).
        """
        return AutoTopology(hosts=hosts, alpha=float(alpha))

    @property
    def is_flat(self) -> bool:
        return self.hosts <= 1

    def check(self, q: int, k: int) -> None:
        """Validate against a CAMR configuration (K = q*k devices)."""
        if self.is_flat:
            return
        if k % self.hosts:
            raise ValueError(
                f"two-level lowering needs hosts | k so parallel "
                f"classes align to host blocks (hosts={self.hosts}, "
                f"k={k})")

    def devices_per_host(self, K: int) -> int:
        if K % self.hosts:
            raise ValueError(f"hosts={self.hosts} must divide K={K}")
        return K // self.hosts

    def host_of(self, s: int, K: int) -> int:
        """Host of device ``s`` under the class-major block layout."""
        return int(s) // self.devices_per_host(K)

    def key(self):
        """Hashable cache-key contribution; flat collapses to None so
        existing flat entries/keys are untouched."""
        if self.is_flat:
            return None
        return (self.hosts, float(self.alpha))


@dataclass(frozen=True)
class AutoTopology:
    """Plan-time marker: pick flat vs two-level from the cost model.

    Not a :class:`Topology` — it has no lowering of its own; every
    entry point that accepts a topology calls :func:`resolve_topology`
    first, which replaces this marker with either ``None`` (flat) or a
    concrete ``Topology.two_level(hosts, alpha)`` for the
    configuration's ``(q, k)``. The decision compares the two
    schedules priced on the SAME hierarchy (``intra + alpha * inter``
    per :func:`repro.core.loads.camr_edge_loads`): ties — including
    ``alpha = 1``, where both collapse to
    :func:`~repro.core.loads.camr_load_p2p`, and ``hosts = k``, where
    no packet has two same-host receivers to deduplicate — go to flat
    (the identity lowering, no overlay to build or relay to run).
    """

    hosts: int
    alpha: float = 4.0

    def resolve(self, q: int, k: int) -> "Topology | None":
        from .loads import camr_edge_loads, camr_load_hierarchical
        if self.hosts < 2 or k % self.hosts:
            return None                      # two-level can't lower
        intra_f, inter_f = camr_edge_loads(q, k, self.hosts,
                                           schedule="flat")
        flat_cost = intra_f + self.alpha * inter_f
        two_cost = camr_load_hierarchical(q, k, self.hosts, self.alpha)
        # strict win with a relative tolerance: at alpha = 1 (or
        # hosts = k) the two costs are EQUAL analytically and differ
        # only by fp association — a tie must resolve to flat
        if flat_cost - two_cost > 1e-9 * flat_cost:
            return Topology.two_level(self.hosts, alpha=self.alpha)
        return None


def resolve_topology(topology, q: int, k: int) -> "Topology | None":
    """Entry-point canonicalization: :class:`AutoTopology` markers
    resolve to their cost-model pick; concrete topologies normalize
    (flat collapses to None)."""
    if isinstance(topology, AutoTopology):
        return topology.resolve(q, k)
    return _normalize_topology(topology)


def surviving_topology(hosts_left: int, k: int,
                       alpha: float = 4.0) -> "Topology | None":
    """Topology to re-lower onto after whole-host loss (DESIGN.md
    §17): two-level over the remaining hosts when that still aligns
    parallel classes to host blocks (``hosts_left >= 2`` and
    ``hosts_left | k``), else flat (``None``) — the bitwise fallback.
    Schedule VALUES are topology-independent, so recovery output is
    bitwise-identical to the healthy lowering either way."""
    if hosts_left < 1:
        raise ValueError("need at least one surviving host, got "
                         f"{hosts_left}")
    if hosts_left >= 2 and k % hosts_left == 0:
        return Topology.two_level(hosts_left, alpha=alpha)
    return None


def _normalize_topology(topology) -> "Topology | None":
    """Canonical form for keys and lowering: flat collapses to None."""
    if topology is None or topology.is_flat:
        return None
    return topology


# --------------------------------------------------------------------- #
# packed payload widths (DESIGN.md §12)
# --------------------------------------------------------------------- #
def payload_words(d: int, itemsize: int, k: int) -> int:
    """u32 words per function shard for a ``d``-element payload of the
    given ``itemsize``, padded so the shard splits into ``k-1`` equal
    codec packets.

    The XOR codec moves 32-bit words; sub-word dtypes (bf16/f16) pack
    ``4 // itemsize`` values per word, so a 16-bit shard costs
    ``ceil(d/2)`` words — HALF the f32 bytes — plus at most ``k-2``
    deterministic zero pad words. For 4-byte dtypes this is exactly
    ``d`` (callers already guarantee ``(k-1) | d``), so every lane
    shares one width formula. The schedule tables are payload-width
    independent (packet units); a word-width program view is the same
    cheap width stamp the :class:`ScheduleCache` already shares.
    """
    if itemsize not in (2, 4):
        raise ValueError(f"payload itemsize must be 2 or 4 bytes, got "
                         f"{itemsize}")
    w = -(-d * itemsize // 4)
    return w + (-w) % (k - 1)


def pack_payload(x: np.ndarray, k: int) -> np.ndarray:
    """Pack a 16-bit payload ``[..., d]`` into u32 words ``[..., wp]``
    (``wp = payload_words(d, 2, k)``) — the numpy mirror of the SPMD
    packing, byte-identical to the device lane (little-endian: value
    ``2i`` is the low half of word ``i``; odd/trailing lanes pad with
    zero u16).
    """
    x = np.asarray(x)
    if x.dtype.itemsize != 2:
        raise TypeError(f"pack_payload packs 16-bit payloads, got "
                        f"{x.dtype}")
    d = x.shape[-1]
    wp = payload_words(d, 2, k)
    u16 = np.zeros(x.shape[:-1] + (2 * wp,), dtype=np.uint16)
    u16[..., :d] = x.view(np.uint16)
    return np.ascontiguousarray(u16).view(np.uint32)


def unpack_payload(w: np.ndarray, dtype, d: int) -> np.ndarray:
    """Inverse of :func:`pack_payload`: u32 words ``[..., wp]`` back to
    the 16-bit payload ``[..., d]`` (pad lanes dropped)."""
    w = np.asarray(w)
    if w.dtype != np.uint32:
        raise TypeError(f"unpack_payload expects uint32 words, got "
                        f"{w.dtype}")
    u16 = np.ascontiguousarray(w).view(np.uint16)
    return np.ascontiguousarray(u16[..., :d]).view(np.dtype(dtype))


# --------------------------------------------------------------------- #
# group <-> value-vector ranking
# --------------------------------------------------------------------- #
def _group_rank(v: tuple[int, ...], q: int) -> int:
    g = 0
    for x in v:
        g = g * q + int(x)
    return g


def _rank_to_vec(g: int, q: int, k: int) -> tuple[int, ...]:
    out = []
    for _ in range(k):
        out.append(g % q)
        g //= q
    return tuple(reversed(out))


# --------------------------------------------------------------------- #
# per-stage device tables
# --------------------------------------------------------------------- #
@dataclass(frozen=True, eq=False)
class StageTables:
    """Dense tables for one coded stage (1 or 2) of the shuffle.

    ``n`` = number of groups in the stage; all index tables are host
    numpy, gathered per-device with ``lax.axis_index`` inside shard_map.
    """

    stage: int
    rows: np.ndarray          # [n]            global group-row ids (rank order)
    R: np.ndarray | int = 0   # rows per (sender, receiver) routing block

    # membership / chunk sources (contribs coords: local job & batch slot)
    valid: np.ndarray = field(default=None, repr=False)      # [K, n] bool
    src_jslot: np.ndarray = field(default=None, repr=False)  # [K, n, k]
    src_bslot: np.ndarray = field(default=None, repr=False)  # [K, n, k]
    src_ok: np.ndarray = field(default=None, repr=False)     # [K, n, k] bool
    shard: np.ndarray = field(default=None, repr=False)      # [n, k] server id

    # Algorithm-2 positions (pos(x, G, kp) over sorted(G \ {kp}))
    delta_pos: np.ndarray = field(default=None, repr=False)  # [K, n, k]
    cancel_pos: np.ndarray = field(default=None, repr=False)  # [K, n, k-1, k]
    cancel_mask: np.ndarray = field(default=None, repr=False)  # [K, n, k-1, k]
    dec_gather: np.ndarray = field(default=None, repr=False)  # [K, n, k-1]

    # fused-codec flat index tables (DESIGN.md §10). Sources are flat
    # packet rows of the local chunk buffer viewed as
    # ``u32.reshape(J_own*(k-1)*K*(k-1), pk)`` — d-independent (packet
    # units), so all shard widths share them like every other table.
    enc_src: np.ndarray = field(default=None, repr=False)    # [K, n, k]
    dec_src: np.ndarray = field(default=None, repr=False)    # [K, n, k-1, k]
    dec_mask: np.ndarray = field(default=None, repr=False)   # [K, n, k-1, k]
    dec_recv: np.ndarray = field(default=None, repr=False)   # [K, n, k-1]
    #   dec_recv[s, row, c] = flat row of recv.reshape(n*(k-1), pk) whose
    #   round packet decodes into chunk slot c — argsort(dec_gather)
    #   baked at lowering time (no per-trace argsort in the executor).

    # batched round routing (see module docstring)
    a2a_send: np.ndarray = field(default=None, repr=False)   # [k-1, K, K, R]
    a2a_recv: np.ndarray = field(default=None, repr=False)   # [k-1, K, n]
    pp_send: np.ndarray = field(default=None, repr=False)    # [k-1, q, K, R]
    pp_recv: np.ndarray = field(default=None, repr=False)    # [k-1, K, n]
    pp_perms: tuple = field(default=(), repr=False)          # [k-1][q] pairs

    @property
    def n(self) -> int:
        return len(self.rows)


# --------------------------------------------------------------------- #
# two-level host-aware relay tables (DESIGN.md §16)
# --------------------------------------------------------------------- #
@dataclass(frozen=True, eq=False)
class HostTables:
    """Two-level relay overlay for one coded stage.

    The flat schedule delivers each coded packet Δ[g, u] (group row
    ``g``, sender ``u``) to its ``k-1`` receivers directly, one per
    broadcast round — so with class-major host blocks, the SAME packet
    crosses the slow inter-host edge once per off-host receiver
    (``k - k/hosts`` times). The two-level schedule deduplicates those
    crossings:

    * **Phase A** is the flat per-round exchange with every delivery
      that is not its packet's GATEWAY copy to a host masked out of
      the send tables (``-1`` -> zero block / dead lane). The gateway
      on each remote host defaults to the first receiver there in
      round order; a ``gateway_avoid`` preference (straggler-aware
      failover, DESIGN.md §17) re-homes it to the first NON-avoided
      receiver instead — same-host deliveries are never masked.
    * **Phase B** relays the masked copies over the fast edge: for
      round ``r`` and intra-host shift ``delta``, a single ppermute
      moves, from each gateway, the packet it received in its own
      primary round ``r0`` to the non-gateway receiver — filling
      exactly the recv slot the flat exchange would have filled.
      Phase B gathers from the COMPLETED phase-A buffer, so ``r0``
      may lie before or after the relay round ``r`` (an avoided
      early receiver relays from a later gateway legally). After A+B
      the receive buffer is WORD-IDENTICAL to the flat one, so decode
      and outputs stay bitwise equal for EVERY gateway assignment.

    Packet counts: per (group row, sender) the flat schedule crosses
    hosts ``k - c`` times (``c = k/hosts`` classes per host) and the
    two-level one ``hosts - 1`` times — a strict cut whenever
    ``hosts < k``. Stage-3 unicasts are intra-class and classes sit
    inside host blocks, so stage 3 never crosses under either schedule.
    """

    hosts: int
    dph: int                      # devices per host (= (k/hosts) * q)
    a2a_send: np.ndarray          # [k-1, K, K, R]   primary-masked
    pp_send: np.ndarray           # [k-1, q, K, R]   primary-masked
    b_deltas: tuple               # intra-host shifts with relay traffic
    b_send: np.ndarray            # [k-1, nd, K, Rb] flat recv rows
    #                               (entry = li*(k-1) + (r0-1); -1 pad)
    b_recv: np.ndarray            # [k-1, K, n] slot into the relay buf
    b_mask: np.ndarray            # [k-1, K, n] round-r slot phase-B fed
    b_perms: tuple                # [nd][K] (src, dst) intra-host cyclic
    b_live: tuple                 # [k-1] delta indices with traffic that
    #                               round (under the DEFAULT gateway
    #                               choice round 1 is always empty: the
    #                               first-in-round-order gateway leaves
    #                               nothing earlier to relay; an avoid
    #                               preference may relay in any round)
    Rb: int                       # relay rows per (round, shift, sender)
    # modeled per-edge delivery counts (packets; DESIGN.md §16)
    flat_inter: int               # cross-host deliveries, flat schedule
    two_level_inter: int          # cross-host gateway copies (phase A)
    relay_intra: int              # phase-B intra-host relay hops
    intra: int                    # same-host phase-A deliveries


def _lower_host_tables(T: StageTables, rows, groups, q, k, K,
                       hosts, avoid=frozenset()) -> HostTables:
    """Build the two-level overlay of one coded stage (see
    :class:`HostTables`). Pure numpy at lowering time, like
    :func:`_lower_stage`.

    ``avoid`` is the gateway preference (DESIGN.md §17): devices a
    straggler-aware caller wants routed AROUND as phase-A gateways.
    Per (sender, remote host) the gateway is the first receiver there
    in round order that is not avoided; when every receiver on the
    host is avoided, the plain round-order first is kept (the packet
    must land somewhere). ``avoid=frozenset()`` reproduces the default
    tables byte-for-byte.
    """
    dph = K // hosts
    c = k // hosts                      # classes per host
    n = len(rows)
    a2a_send = T.a2a_send.copy()
    pp_send = T.pp_send.copy()
    b_mask = np.zeros((k - 1, K, n), dtype=bool)
    moves = {}                          # (r, delta, gateway) -> entries
    flat_inter = two_inter = relay = intra = 0

    for li in range(n):
        g = rows[li]
        G = [int(x) for x in groups[g]]
        for pm, m in enumerate(G):
            hm = m // dph
            remote = {}                 # remote host -> [(r, w)] rnd order
            for r in range(1, k):
                w = G[(pm + r) % k]
                hw = w // dph
                if hw == hm:
                    intra += 1
                    continue            # same-host: always primary
                flat_inter += 1
                remote.setdefault(hw, []).append((r, w))
            for rws in remote.values():
                r0, gw = next(((r, w) for r, w in rws
                               if w not in avoid), rws[0])
                two_inter += 1          # the gateway copy stays primary
                for r, w in rws:
                    if w == gw:
                        continue
                    relay += 1
                    # demote (li, r, m -> w) from phase A ...
                    sl = a2a_send[r - 1, m, w]
                    sl[int(np.flatnonzero(sl == li)[0])] = -1
                    dpp = ((w % q) - (m % q)) % q
                    sl = pp_send[r - 1, dpp, m]
                    sl[int(np.flatnonzero(sl == li)[0])] = -1
                    # ... and relay it intra-host from the gateway
                    b_mask[r - 1, w, li] = True
                    delta = (w - gw) % dph
                    moves.setdefault((r, delta, gw), []).append(
                        (li, r0, w))

    # uniform-count sanity: one member per class, c classes per host
    assert flat_inter == n * k * (k - c)
    assert two_inter == n * k * (hosts - 1)
    assert relay == flat_inter - two_inter
    assert intra == n * k * (c - 1)

    deltas = sorted({delta for (_, delta, _) in moves})
    dmap = {delta: i for i, delta in enumerate(deltas)}
    nd = len(deltas)
    Rb = max((len(v) for v in moves.values()), default=0)
    b_send = np.full((k - 1, max(nd, 1), K, max(Rb, 1)), -1,
                     dtype=np.int32)
    b_recv = np.zeros((k - 1, K, n), dtype=np.int32)
    # per-round live shifts: the executor issues one relay ppermute per
    # (round, shift) WITH traffic and concatenates them in b_live order,
    # so receive slots index the concatenated live lanes only
    b_live = [sorted({dmap[delta] for (rr, delta, _) in moves
                      if rr == r}) for r in range(1, k)]
    for (r, delta, gw), entries in sorted(moves.items()):
        lane = b_live[r - 1].index(dmap[delta])
        for idx, (li, r0, w) in enumerate(sorted(entries)):
            b_send[r - 1, dmap[delta], gw, idx] = li * (k - 1) + (r0 - 1)
            b_recv[r - 1, w, li] = lane * Rb + idx
    b_perms = []
    for delta in deltas:
        pairs = []
        for h in range(hosts):
            for a in range(dph):
                pairs.append((h * dph + a, h * dph + (a + delta) % dph))
        b_perms.append(tuple(pairs))

    return HostTables(
        hosts=hosts, dph=dph,
        a2a_send=a2a_send, pp_send=pp_send,
        b_deltas=tuple(deltas), b_send=b_send, b_recv=b_recv,
        b_mask=b_mask, b_perms=tuple(b_perms),
        b_live=tuple(tuple(x) for x in b_live), Rb=Rb,
        flat_inter=flat_inter, two_level_inter=two_inter,
        relay_intra=relay, intra=intra)


# --------------------------------------------------------------------- #
# the program
# --------------------------------------------------------------------- #
@dataclass(frozen=True, eq=False)
class ShuffleProgram:
    """Compiled CAMR shuffle schedule (see module docstring)."""

    q: int
    k: int
    Q: int                                   # number of reduce functions
    design: ResolvableDesign = field(repr=False)
    placement: Placement = field(repr=False)

    # unified group table over stages 1+2: n_groups = q**k rows
    group_vals: np.ndarray = field(repr=False)   # [n_groups, k] value vecs
    groups: np.ndarray = field(repr=False)       # [n_groups, k] server ids
    stage_of: np.ndarray = field(repr=False)     # [n_groups] in {1, 2}
    chunk_job: np.ndarray = field(repr=False)    # [n_groups, k]
    chunk_batch: np.ndarray = field(repr=False)  # [n_groups, k]
    chunk_aux: np.ndarray = field(repr=False)    # [n_groups, k] classmate
    #                                              owner (stage 2), else -1
    s1_rows: np.ndarray = field(repr=False)      # [J] row of job j's group
    s2_rows: np.ndarray = field(repr=False)      # [n_s2] rows, rank order

    # local storage layout (device s's contribs rows)
    owned_jobs: np.ndarray = field(repr=False)       # [K, J_own]
    stored_batches: np.ndarray = field(repr=False)   # [K, J_own, k-1]

    # stage 3 unicasts
    s3_job: np.ndarray = field(repr=False)       # [n3]
    s3_recv: np.ndarray = field(repr=False)      # [n3]
    s3_send: np.ndarray = field(repr=False)      # [n3]
    s3_batches: np.ndarray = field(repr=False)   # [n3, k-1]
    s3_perms: tuple = field(repr=False)          # [q-1] intra-class shifts

    # reduce-side assembly
    is_own: np.ndarray = field(repr=False)       # [K, J] bool
    own_slot: np.ndarray = field(repr=False)     # [K, J] local job slot
    s2_ord: np.ndarray = field(repr=False)       # [K, J] stage-2 ordinal
    s3_off: np.ndarray = field(repr=False)       # [K, J] stage-3 round idx

    # SPMD tables (None when lowered with device_tables=False)
    s1: StageTables | None = field(repr=False, default=None)
    s2: StageTables | None = field(repr=False, default=None)
    d: int | None = None                         # SPMD shard width

    # two-level topology overlay (None == flat, the identity case)
    topology: Topology | None = None
    hx1: HostTables | None = field(repr=False, default=None)
    hx2: HostTables | None = field(repr=False, default=None)
    # gateway failover preference the host tables were lowered with
    # (empty == default first-in-round-order gateways; flat-only
    # programs always carry the empty set)
    gateway_avoid: frozenset = frozenset()

    # ------------------------------------------------------------------ #
    @property
    def K(self) -> int:
        return self.q * self.k

    @property
    def J(self) -> int:
        return self.q ** (self.k - 1)

    @property
    def J_own(self) -> int:
        return self.q ** (self.k - 2)

    @property
    def n_groups(self) -> int:
        return self.q ** self.k

    @property
    def n_s2(self) -> int:
        return self.n_groups - self.J

    @property
    def packet_len(self) -> int:
        if self.d is None:
            raise ValueError("program lowered without device tables")
        return self.d // (self.k - 1)

    @property
    def n_batched_collectives(self) -> int:
        """Batched collectives issued for stages 1+2 (all_to_all router)."""
        return 2 * (self.k - 1)

    def stage_tables(self, stage: int) -> StageTables:
        t = self.s1 if stage == 1 else self.s2
        if t is None:
            raise ValueError("program lowered without device tables")
        return t

    def host_tables(self, stage: int) -> HostTables:
        t = self.hx1 if stage == 1 else self.hx2
        if t is None:
            raise ValueError("program lowered without a two-level "
                             "topology")
        return t

    def stage_rows(self, stage: int) -> np.ndarray:
        return self.s1_rows if stage == 1 else self.s2_rows

    def group_members(self, row: int) -> tuple[int, ...]:
        return tuple(int(x) for x in self.groups[row])

    def round_perms(self, stage: int) -> tuple:
        """Per-group per-round (src, dst) pairs for the LOOPED legacy
        router: round ``r`` sends ``G[p] -> G[(p+r) % k]``."""
        k = self.k
        out = []
        for row in self.stage_rows(stage):
            G = self.group_members(int(row))
            out.append(tuple(
                tuple((G[p], G[(p + r) % k]) for p in range(k))
                for r in range(1, k)))
        return tuple(out)

    def coded_chunks(self, row: int) -> list[tuple[int, int, int]]:
        """[(receiver, job, batch)] for one group row — engine view."""
        return [
            (int(self.groups[row, p]), int(self.chunk_job[row, p]),
             int(self.chunk_batch[row, p]))
            for p in range(self.k)
        ]


# --------------------------------------------------------------------- #
# lowering
# --------------------------------------------------------------------- #
@lru_cache(maxsize=64)  # Placement hashes by identity (frozen, eq=False);
#                         bounded: long-lived replanning loops build fresh
#                         placements and must not pin every program forever
def lower_program(placement: Placement, Q: int | None = None,
                  d: int | None = None, *,
                  device_tables: bool = True,
                  topology: Topology | None = None,
                  gateway_avoid: frozenset = frozenset()
                  ) -> ShuffleProgram:
    """Lower ``(Placement, Q, d)`` into a :class:`ShuffleProgram`.

    ``d`` (SPMD function-shard width, elements) is only required for the
    collective executor; the engine interprets the schedule tables alone
    (``device_tables=False`` skips the [K, n, ...] SPMD tables).

    ``topology`` selects the transport lowering: ``None`` / flat emits
    exactly the schedules every prior PR emitted (the identity case); a
    two-level topology additionally lowers the host-aware relay overlay
    (:class:`HostTables`) that deduplicates inter-host packet copies.
    An :class:`AutoTopology` marker resolves via the cost model first.
    The VALUES computed are identical either way — topology only
    changes which edge each packet rides.

    ``gateway_avoid`` (two-level only) re-homes phase-A gateways away
    from the named devices (straggler failover, DESIGN.md §17); the
    empty set is the default first-in-round-order assignment, byte-
    identical to every pre-§17 lowering. Outputs stay bitwise equal to
    flat for every assignment.
    """
    design = placement.design
    q, k, K, J = design.q, design.k, design.K, design.J
    Q = K if Q is None else Q
    if Q % K:
        raise ValueError("Q must be a multiple of K")
    if d is not None and d % (k - 1):
        raise ValueError(f"shard width d={d} must be divisible by "
                         f"k-1={k - 1}")
    topology = resolve_topology(topology, q, k)
    if topology is not None:
        topology.check(q, k)
    gateway_avoid = frozenset(int(x) for x in (gateway_avoid or ()))
    if topology is None:
        gateway_avoid = frozenset()      # flat has no gateways to move
    elif not all(0 <= x < K for x in gateway_avoid):
        raise ValueError(f"gateway_avoid {sorted(gateway_avoid)} has "
                         f"devices outside [0, {K})")

    n_groups = q ** k
    group_vals = np.zeros((n_groups, k), dtype=np.int32)
    groups = np.zeros((n_groups, k), dtype=np.int32)
    stage_of = np.zeros(n_groups, dtype=np.int32)
    chunk_job = np.zeros((n_groups, k), dtype=np.int32)
    chunk_batch = np.zeros((n_groups, k), dtype=np.int32)
    chunk_aux = np.full((n_groups, k), -1, dtype=np.int32)
    s1_rows, s2_rows = [], []

    for g in range(n_groups):
        v = _rank_to_vec(g, q, k)
        group_vals[g] = v
        G = tuple(design.server_of(i, v[i]) for i in range(k))
        groups[g] = G
        if sum(v[:-1]) % q == v[-1]:
            stage_of[g] = 1
            j = _group_rank(v[:-1], q)           # job = message rank
            assert design.owners[j] == G
            s1_rows.append(g)
            for p, kp in enumerate(G):
                chunk_job[g, p] = j
                chunk_batch[g, p] = placement.batch_of_label(j, kp)
        else:
            stage_of[g] = 2
            s2_rows.append(g)
            for p, kp in enumerate(G):
                Pset = tuple(s for s in G if s != kp)
                j = design.common_job(Pset)
                (l,) = [u for u in design.owners[j]
                        if design.class_of(u) == p]
                t = placement.batch_of_label(j, l)
                # Lemma-2 condition: every other member stores that batch
                assert all(placement.stores(s, j, t) for s in Pset), \
                    "stage-2 storage condition"
                chunk_job[g, p] = j
                chunk_batch[g, p] = t
                chunk_aux[g, p] = l

    s1_rows = np.asarray(s1_rows, dtype=np.int32)
    s2_rows = np.asarray(s2_rows, dtype=np.int32)
    assert len(s1_rows) == J

    # -- local storage layout ------------------------------------------- #
    J_own = design.block_size
    owned = np.zeros((K, J_own), dtype=np.int32)
    stored = np.zeros((K, J_own, k - 1), dtype=np.int32)
    owned_index = {}
    stored_index = {}
    for s in range(K):
        for a, j in enumerate(design.owned_jobs(s)):
            owned[s, a] = j
            owned_index[(s, j)] = a
            tmiss = placement.batch_of_label(j, s)
            row = [t for t in range(k) if t != tmiss]
            stored[s, a] = row
            for b, t in enumerate(row):
                stored_index[(s, j, t)] = b

    # -- stage 3 -------------------------------------------------------- #
    s3_job, s3_recv, s3_send, s3_batches = [], [], [], []
    for i in range(k):
        cls = design.parallel_class(i)
        for m in cls:
            for u in cls:
                if u == m:
                    continue
                for j in design.owned_jobs(u):
                    tu = placement.batch_of_label(j, u)
                    s3_job.append(j)
                    s3_recv.append(m)
                    s3_send.append(u)
                    s3_batches.append([t for t in range(k) if t != tu])
    s3_job = np.asarray(s3_job, dtype=np.int32)
    s3_recv = np.asarray(s3_recv, dtype=np.int32)
    s3_send = np.asarray(s3_send, dtype=np.int32)
    s3_batches = np.asarray(s3_batches, dtype=np.int32).reshape(-1, k - 1)
    assert len(s3_job) == K * (J - J_own)

    s3_perms = []
    for o in range(1, q):
        pairs = []
        for i in range(k):
            for l in range(q):
                pairs.append((i * q + l, i * q + (l + o) % q))
        s3_perms.append(tuple(pairs))

    # -- reduce-side assembly ------------------------------------------- #
    is_own = np.zeros((K, J), dtype=bool)
    own_slot = np.zeros((K, J), dtype=np.int32)
    s2_ord = np.zeros((K, J), dtype=np.int32)
    s3_off = np.zeros((K, J), dtype=np.int32)
    s2_lookup = {}
    for gi, g in enumerate(s2_rows):
        for p in range(k):
            s2_lookup[(int(groups[g, p]), int(chunk_job[g, p]))] = gi
    for s in range(K):
        for j in range(J):
            if design.is_owner(s, j):
                is_own[s, j] = True
                own_slot[s, j] = owned_index[(s, j)]
            else:
                cls = design.class_of(s)
                (l,) = [u for u in design.owners[j]
                        if design.class_of(u) == cls]
                s3_off[s, j] = (s - l) % q - 1
                s2_ord[s, j] = s2_lookup[(s, j)]
                own_slot[s, j] = owned_index[(l, j)]

    prog = dict(
        q=q, k=k, Q=Q, design=design, placement=placement,
        group_vals=group_vals, groups=groups, stage_of=stage_of,
        chunk_job=chunk_job, chunk_batch=chunk_batch, chunk_aux=chunk_aux,
        s1_rows=s1_rows, s2_rows=s2_rows,
        owned_jobs=owned, stored_batches=stored,
        s3_job=s3_job, s3_recv=s3_recv, s3_send=s3_send,
        s3_batches=s3_batches, s3_perms=tuple(s3_perms),
        is_own=is_own, own_slot=own_slot, s2_ord=s2_ord, s3_off=s3_off,
        d=d, topology=topology, gateway_avoid=gateway_avoid,
    )
    if not device_tables:
        return ShuffleProgram(**prog)

    s1 = _lower_stage(1, s1_rows, groups, chunk_job, chunk_batch,
                      group_vals, q, k, K, owned_index, stored_index)
    s2 = _lower_stage(2, s2_rows, groups, chunk_job, chunk_batch,
                      group_vals, q, k, K, owned_index, stored_index)
    hx1 = hx2 = None
    if topology is not None:
        hx1 = _lower_host_tables(s1, s1_rows, groups, q, k, K,
                                 topology.hosts, avoid=gateway_avoid)
        hx2 = _lower_host_tables(s2, s2_rows, groups, q, k, K,
                                 topology.hosts, avoid=gateway_avoid)
    return ShuffleProgram(s1=s1, s2=s2, hx1=hx1, hx2=hx2, **prog)


def _lower_stage(stage, rows, groups, chunk_job, chunk_batch, group_vals,
                 q, k, K, owned_index, stored_index) -> StageTables:
    """Build the SPMD tables of one coded stage.

    Groups are class-ordered tuples of strictly increasing server ids, so
    ``sorted(G \\ {kp})`` is just ``G`` with ``kp`` removed — the
    Algorithm-2 packet position of member ``x`` w.r.t. chunk owner at
    position ``p_kp`` is ``p_x - (p_x > p_kp)``.
    """
    n = len(rows)
    valid = np.zeros((K, n), dtype=bool)
    src_jslot = np.zeros((K, n, k), dtype=np.int32)
    src_bslot = np.zeros((K, n, k), dtype=np.int32)
    src_ok = np.zeros((K, n, k), dtype=bool)
    shard = np.zeros((n, k), dtype=np.int32)
    delta_pos = np.zeros((K, n, k), dtype=np.int32)
    cancel_pos = np.zeros((K, n, k - 1, k), dtype=np.int32)
    cancel_mask = np.zeros((K, n, k - 1, k), dtype=bool)
    dec_gather = np.zeros((K, n, k - 1), dtype=np.int32)

    def pos(p_x, p_kp):
        return p_x - (1 if p_x > p_kp else 0)

    for li, g in enumerate(rows):
        G = [int(x) for x in groups[g]]
        shard[li] = G
        for myp, s in enumerate(G):
            valid[s, li] = True
            for p, kp in enumerate(G):
                if kp == s:
                    continue
                j, t = int(chunk_job[g, p]), int(chunk_batch[g, p])
                src_jslot[s, li, p] = owned_index[(s, j)]
                src_bslot[s, li, p] = stored_index[(s, j, t)]
                src_ok[s, li, p] = True
                delta_pos[s, li, p] = pos(myp, p)
            for r in range(1, k):
                mp = (myp - r) % k
                dec_gather[s, li, r - 1] = pos(mp, myp)
                for p in range(k):
                    if p not in (mp, myp):
                        cancel_pos[s, li, r - 1, p] = pos(mp, p)
                        cancel_mask[s, li, r - 1, p] = True

    # -- fused-codec flat index tables (DESIGN.md §10) ------------------ #
    # flat packet row of chunk (jslot, bslot, shard, packet-pos) in the
    # device's u32 buffer viewed as [J_own*(k-1)*K*(k-1), pk]
    base = (src_jslot * (k - 1) + src_bslot) * K + shard[None]   # [K, n, k]
    enc_src = np.where(src_ok, base * (k - 1) + delta_pos, 0).astype(
        np.int32)
    # bake argsort(dec_gather): order[s, row, c] = round whose packet
    # lands in chunk slot c (dec_gather is a permutation wherever the
    # device is a group member; elsewhere the rows are dead — stable
    # argsort keeps them deterministic)
    order = np.argsort(dec_gather, axis=2, kind="stable")        # [K,n,k-1]
    dec_recv = (order + np.arange(n, dtype=np.int32)[None, :, None]
                * (k - 1)).astype(np.int32)
    dec_mask = np.take_along_axis(cancel_mask, order[..., None], axis=2)
    dec_src = np.take_along_axis(cancel_pos, order[..., None], axis=2)
    dec_src = np.where(dec_mask, base[:, :, None, :] * (k - 1) + dec_src,
                       0).astype(np.int32)

    # -- routing blocks: shared by both routers ------------------------- #
    # rows per ordered (sender, receiver) pair: fixing two coordinates of
    # the value vector leaves q^(k-3) stage-1 / q^(k-3)*(q-1) stage-2
    # groups — uniform over pairs, so R is exact (asserted below).
    R = q ** (k - 3) if k >= 3 else 1
    if stage == 2:
        R *= q - 1
    a2a_send = np.full((k - 1, K, K, R), -1, dtype=np.int32)
    a2a_recv = np.zeros((k - 1, K, n), dtype=np.int32)
    pp_send = np.full((k - 1, q, K, R), -1, dtype=np.int32)
    pp_recv = np.zeros((k - 1, K, n), dtype=np.int32)
    pp_perms = []
    counts = {}
    for r in range(1, k):
        counts.clear()
        for li, g in enumerate(rows):
            G = [int(x) for x in groups[g]]
            for iu, u in enumerate(G):
                w = G[(iu + r) % k]
                idx = counts.get((u, w), 0)
                counts[(u, w)] = idx + 1
                assert idx < R
                a2a_send[r - 1, u, w, idx] = li
                a2a_recv[r - 1, w, li] = u * R + idx
                delta = ((w % q) - (u % q)) % q
                pp_send[r - 1, delta, u, idx] = li
                pp_recv[r - 1, w, li] = delta * R + idx
        perms_r = []
        for delta in range(q):
            pairs = []
            for i in range(k):
                for l in range(q):
                    src = i * q + l
                    dst = ((i + r) % k) * q + (l + delta) % q
                    pairs.append((src, dst))
            perms_r.append(tuple(pairs))
        pp_perms.append(tuple(perms_r))

    return StageTables(
        stage=stage, rows=np.asarray(rows, dtype=np.int32), R=R,
        valid=valid,
        src_jslot=src_jslot, src_bslot=src_bslot, src_ok=src_ok,
        shard=shard, delta_pos=delta_pos,
        cancel_pos=cancel_pos, cancel_mask=cancel_mask,
        dec_gather=dec_gather,
        enc_src=enc_src, dec_src=dec_src, dec_mask=dec_mask,
        dec_recv=dec_recv,
        a2a_send=a2a_send, a2a_recv=a2a_recv,
        pp_send=pp_send, pp_recv=pp_recv, pp_perms=tuple(pp_perms),
    )


# --------------------------------------------------------------------- #
# degraded lowering (fault runtime)
# --------------------------------------------------------------------- #
@dataclass(frozen=True)
class DegradedProgram:
    """Schedule re-lowered for a surviving server set.

    ``coded_rows``    group rows whose members are all live: run
                      Algorithm 2 unchanged.
    ``uncoded``       per degraded group row, the uncoded unicast plan:
                      tuples ``(sender, receiver, job, batch, owner)``
                      where ``owner`` is the ORIGINAL chunk receiver
                      (its id is the reduce-function index).
    ``s3``            stage-3 sends ``(sender, receiver, job, owner,
                      batches)``; several entries may share a
                      ``(receiver, job, owner)`` key — the executor
                      combines them.
    """

    base: ShuffleProgram
    failed: frozenset
    migrate: np.ndarray                  # [K] takeover server ids
    coded_rows: tuple
    uncoded: tuple                       # [(row, sends)]
    s3: tuple


def lower_degraded(program: ShuffleProgram,
                   failed: set[int]) -> DegradedProgram:
    """Re-lower ``program`` for the live servers ``K \\ failed``.

    Raises ``ValueError`` when the loss exceeds what the placement
    redundancy can absorb (same conditions the paper's recovery needs).
    """
    design, pl = program.design, program.placement
    q, k, K = program.q, program.k, program.K
    failed = frozenset(failed)
    if k < 3:
        raise ValueError("degraded recovery requires k >= 3 (k = 2 "
                         "leaves single-holder batches)")
    for i in range(k):
        cls = set(design.parallel_class(i))
        if len(cls & failed) > 1:
            raise ValueError(
                "multiple failures in one parallel class need map "
                "recompute (not just shuffle recovery)")
    for j in range(design.J):
        for t in range(k):
            if set(pl.holders(j, t)) <= failed:
                raise ValueError(
                    f"batch (job {j}, batch {t}) lost all {k - 1} "
                    "replicas — data loss, not recoverable by the "
                    "shuffle (re-map from the master copy required)")

    migrate = np.arange(K, dtype=np.int32)
    for s in sorted(failed):
        cls = design.parallel_class(design.class_of(s))
        migrate[s] = next(c for c in cls if c not in failed)

    coded_rows, uncoded = [], []
    for row in range(program.n_groups):
        G = program.group_members(row)
        if not (set(G) & failed):
            coded_rows.append(row)
            continue
        sends = []
        for p, (kp, j, t) in zip(range(k), program.coded_chunks(row)):
            rcv = int(migrate[kp])
            holder = next(s for s in G if s != kp and s not in failed)
            sends.append((holder, rcv, j, t, kp))
        uncoded.append((row, tuple(sends)))

    s3 = []
    for i in range(len(program.s3_job)):
        j = int(program.s3_job[i])
        m = int(program.s3_recv[i])
        u = int(program.s3_send[i])
        batches = tuple(int(t) for t in program.s3_batches[i])
        rcv = int(migrate[m])
        if u not in failed:
            s3.append((u, rcv, j, m, batches))
        else:
            for t in batches:
                holder = next(h for h in pl.holders(j, t)
                              if h not in failed)
                s3.append((holder, rcv, j, m, (t,)))
    # migration fill: the takeover of failed f additionally needs, per
    # job f OWNED, the aggregate of the k-1 batches f held locally.
    # Sends are ordered so the receiver's sequential combine reproduces
    # the healthy ascending batch fold bit-for-bit (engine.reduce_phase
    # canonical order): l1 stores everything except its own label batch
    # t1, so the prefix below t1 goes combined, t1 comes from another
    # live holder, and the suffix above t1 goes one batch per send.
    for f in sorted(failed):
        s = int(migrate[f])
        for j in design.owned_jobs(f):
            tf = pl.batch_of_label(j, f)
            rest = [t for t in range(k) if t != tf]
            l1 = next(u for u in design.owners[j] if u not in failed)
            t1 = pl.batch_of_label(j, l1)   # != tf: labels are a bijection
            prefix = tuple(t for t in rest if t < t1)
            if prefix:
                s3.append((l1, s, j, f, prefix))
            h2 = next(h for h in pl.holders(j, t1)
                      if h not in failed)
            s3.append((h2, s, j, f, (t1,)))
            for t in rest:
                if t > t1:
                    s3.append((l1, s, j, f, (t,)))

    return DegradedProgram(
        base=program, failed=failed, migrate=migrate,
        coded_rows=tuple(coded_rows), uncoded=tuple(uncoded),
        s3=tuple(s3))


# --------------------------------------------------------------------- #
# structural schedule cache (DESIGN.md §9)
# --------------------------------------------------------------------- #
def _normalize_label_perm(label_perm, k):
    """Hashable canonical form; the identity labeling collapses to None."""
    if label_perm is None:
        return None
    label_perm = tuple(tuple(int(x) for x in p) for p in label_perm)
    ident = tuple(range(k))
    if all(p == ident for p in label_perm):
        return None
    return label_perm


def _program_key(program: ShuffleProgram) -> tuple:
    """Structural identity of a lowered program — same tuple, same
    tables. ``d`` is deliberately absent: no table depends on it, so
    width variants of one configuration share degraded re-lowerings.
    The topology (with its cost parameters) IS present: flat and
    two-level lowerings of the same ``(q, k, gamma, Q)`` must never
    alias (flat collapses to ``None``, keeping every pre-topology key
    byte-identical). A non-default gateway assignment extends the key
    (the default/flat key shape stays byte-identical to pre-§17)."""
    topo = None if program.topology is None else program.topology.key()
    base = (program.q, program.k, program.placement.gamma,
            _normalize_label_perm(program.placement.label_perm, program.k),
            program.Q, program.s1 is not None, topo)
    gw = tuple(sorted(program.gateway_avoid))
    return base + (gw,) if gw else base


class ScheduleCache:
    """Process-wide cache of lowered schedules, keyed by VALUE.

    :func:`lower_program` is memoized on Placement *identity* (frozen,
    ``eq=False``), which is the right policy for a long-lived placement
    object but useless to a runtime that builds one engine per wave of
    jobs: every wave re-derives the same design/placement and pays the
    full lowering again. This cache keys structurally instead
    (DESIGN.md §9):

    * programs by ``(q, k, gamma, label_perm, Q, device_tables,
      topology)`` — the survivor set of a healthy cluster is implicit,
      and the flat topology normalizes to ``None`` so flat and
      two-level lowerings of one configuration never alias;
    * degraded programs additionally by ``frozenset(failed)``, i.e. one
      entry per *survivor set*, so fault re-lowering is paid once per
      (configuration, failure pattern) instead of once per wave.

    ``d`` (the SPMD shard width) does NOT change any table — only the
    runtime packet split — so all widths of one configuration share the
    same base lowering; a width-stamped view is a cheap
    ``dataclasses.replace``. A changed survivor set is a different key
    (never a mutation), and :meth:`clear` drops everything — those are
    the only two invalidation events; entries otherwise stay valid
    forever because every input of the lowering is in the key.

    Both maps are LRU-bounded (``maxsize`` each) so replanning loops
    cannot pin unbounded table memory. Lookups are serialized by a
    lock: the JobStream runtime constructs engines (and therefore
    queries this cache) from its map prefetch thread.
    """

    def __init__(self, maxsize: int = 128):
        self.maxsize = maxsize
        self._programs: OrderedDict = OrderedDict()
        self._degraded: OrderedDict = OrderedDict()
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0

    # -- bookkeeping ---------------------------------------------------- #
    def _get(self, table: OrderedDict, key):
        got = table.get(key)
        if got is None:
            self.misses += 1
        else:
            self.hits += 1
            table.move_to_end(key)
        return got

    def _put(self, table: OrderedDict, key, value):
        table[key] = value
        while len(table) > self.maxsize:
            table.popitem(last=False)

    def stats(self) -> dict:
        with self._lock:
            return dict(hits=self.hits, misses=self.misses,
                        programs=len(self._programs),
                        degraded=len(self._degraded))

    def clear(self) -> None:
        with self._lock:
            self._programs.clear()
            self._degraded.clear()
            self.hits = 0
            self.misses = 0

    # -- lookups -------------------------------------------------------- #
    def program(self, q: int, k: int, *, gamma: int = 1,
                Q: int | None = None, d: int | None = None,
                label_perm=None, device_tables: bool = True,
                topology: Topology | None = None,
                gateway_avoid: frozenset = frozenset()
                ) -> ShuffleProgram:
        """The lowered program of one configuration (lowering on miss).

        ``topology`` is part of the structural key (flat normalizes to
        ``None``, so flat lookups hit exactly the pre-topology
        entries; an :class:`AutoTopology` marker resolves via the cost
        model first); flat and two-level lowerings of the same
        ``(q, k, gamma, Q)`` occupy distinct entries and never
        cross-hit. ``gateway_avoid`` joins the key the same way: the
        default empty assignment keys as ``None``, so every
        non-default gateway failover lowering is its own entry."""
        label_perm = _normalize_label_perm(label_perm, k)
        Q = q * k if Q is None else Q   # lower_program's own default
        if d is not None and d % (k - 1):
            raise ValueError(f"shard width d={d} must be divisible by "
                             f"k-1={k - 1}")
        topology = resolve_topology(topology, q, k)
        gateway_avoid = frozenset(int(x) for x in (gateway_avoid or ()))
        if topology is None:
            gateway_avoid = frozenset()
        topo_key = None if topology is None else topology.key()
        gw_key = tuple(sorted(gateway_avoid)) or None
        base_key = (q, k, gamma, label_perm, Q, device_tables, topo_key,
                    gw_key, None)
        with self._lock:
            base = self._get(self._programs, base_key)
            if base is None:
                pl = make_placement(make_design(q, k), gamma,
                                    label_perm=label_perm)
                # bypass lower_program's identity-keyed lru_cache: the
                # placement is fresh (guaranteed miss there), and going
                # through it would pin every lowering a second time,
                # surviving this cache's eviction/clear()
                base = lower_program.__wrapped__(
                    pl, Q=Q, d=None, device_tables=device_tables,
                    topology=topology, gateway_avoid=gateway_avoid)
                self._put(self._programs, base_key, base)
            if d is None:
                return base
            key = base_key[:-1] + (d,)
            prog = self._get(self._programs, key)
            if prog is None:
                prog = replace(base, d=d)  # tables shared with the base
                self._put(self._programs, key, prog)
            return prog

    def degraded(self, program: ShuffleProgram,
                 failed) -> DegradedProgram:
        """The re-lowered schedule for ``program`` minus ``failed``.

        Unrecoverable patterns raise (and are not cached) exactly as
        :func:`lower_degraded` does.
        """
        key = (_program_key(program),
               frozenset(int(s) for s in failed))
        with self._lock:
            got = self._get(self._degraded, key)
            if got is None:
                got = lower_degraded(program, set(failed))
                self._put(self._degraded, key, got)
            return got

    def warm_survivors(self, program, max_failures: int = 1) -> int:
        """Pre-lower the degraded schedule of every recoverable
        survivor set with up to ``max_failures`` concurrent failures,
        so a mid-stream membership change never pays a lowering on the
        recovery critical path (DESIGN.md §14). Unrecoverable sets
        (same-class double failures, total batch loss) are skipped.
        Returns the number of degraded programs now resident. Bounded:
        single failures are K entries; keep ``max_failures`` small or
        raise ``maxsize`` accordingly (LRU eviction applies as usual).
        """
        from itertools import combinations
        warmed = 0
        for r in range(1, max_failures + 1):
            for combo in combinations(range(program.K), r):
                try:
                    self.degraded(program, set(combo))
                except ValueError:
                    continue
                warmed += 1
        return warmed

    def warm_host_survivors(self, program: ShuffleProgram,
                            max_host_failures: int = 1) -> int:
        """Pre-lower ``program`` under every surviving-host topology
        reachable by losing up to ``max_host_failures`` whole hosts
        (DESIGN.md §17) — the host-granularity sibling of
        :meth:`warm_survivors`. Host-loss recovery is a TOPOLOGY
        re-homing (the schedule values never change, only which edge
        each packet rides), and the lowering depends only on the
        surviving host COUNT, so one entry per loss count covers every
        subset of that size. After this, ``kill_host`` recovery is a
        pure cache hit: zero cold lowerings on the critical path.
        Returns the number of surviving-topology programs warmed.
        """
        topo = program.topology
        if topo is None:
            raise ValueError(
                "warm_host_survivors needs a program lowered for a "
                "two-level topology (a flat lowering has no host "
                "blocks to lose)")
        if not 0 < max_host_failures < topo.hosts:
            raise ValueError(
                f"max_host_failures={max_host_failures} must leave at "
                f"least one of {topo.hosts} hosts alive")
        warmed = 0
        for lost in range(1, max_host_failures + 1):
            t = surviving_topology(topo.hosts - lost, program.k,
                                   alpha=topo.alpha)
            self.program(
                program.q, program.k, gamma=program.placement.gamma,
                Q=program.Q, d=program.d,
                label_perm=program.placement.label_perm,
                device_tables=program.s1 is not None, topology=t,
                gateway_avoid=program.gateway_avoid)
            warmed += 1
        return warmed


#: Module-level default — all engines/plans share one schedule cache.
SCHEDULE_CACHE = ScheduleCache()


class ExecCache:
    """Process-wide cache of built (usually jitted) executables, keyed
    by VALUE — the serving sibling of :class:`ScheduleCache`
    (DESIGN.md §13).

    A ``ScheduleCache`` entry is a lowered *data plan*; an ``ExecCache``
    entry is a compiled *callable* (or a tuple of them): the jitted
    decode-wave ``lax.while_loop``, prefill/admit executables, the
    legacy serving step pair. Keys are caller-chosen tuples of
    hashables — the convention is
    ``(kind, cfg, *shape_signature)``, e.g.
    ``("serve_wave", cfg, slots, pages, page_size, ...)`` — so every
    input that changes the traced computation is in the key and entries
    never go stale. Same LRU bound + lock discipline as the schedule
    cache (the serving front door builds executables from its prefill
    prefetch thread).
    """

    def __init__(self, maxsize: int = 64):
        self.maxsize = maxsize
        self._entries: OrderedDict = OrderedDict()
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0

    def get(self, key, build):
        """Return the cached executable for ``key``; on a miss, call
        ``build()`` (under the lock — one build per key) and cache the
        result."""
        with self._lock:
            got = self._entries.get(key)
            if got is not None:
                self.hits += 1
                self._entries.move_to_end(key)
                return got
            self.misses += 1
            got = build()
            self._entries[key] = got
            while len(self._entries) > self.maxsize:
                self._entries.popitem(last=False)
            return got

    def stats(self) -> dict:
        with self._lock:
            return dict(hits=self.hits, misses=self.misses,
                        entries=len(self._entries))

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self.hits = 0
            self.misses = 0


#: Module-level default — serving entry points share one executable
#: cache (a second ``generate``/engine over the same config re-uses the
#: compiled closures instead of retracing).
EXEC_CACHE = ExecCache()
