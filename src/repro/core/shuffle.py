"""CAMR 3-stage coded shuffle — paper §III-C, Lemma 2, Algorithm 2.

This module implements the *schedule* and the *coding* exactly as in the
paper, with byte-exact accounting. Payloads are raw ``bytes`` (the engine
bitcasts numpy arrays); XOR coding operates on byte strings, so it is
exactly invertible for any dtype.

Two cost models are tracked per transmission (DESIGN.md §3):

* ``bus``  — the paper's shared-medium model: a multicast costs its payload
  size once, regardless of receiver count. Stage loads under this model
  reproduce §IV exactly.
* ``p2p``  — point-to-point links (TPU ICI / commodity switches): a
  multicast to ``r`` receivers costs ``r * payload``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

from .designs import ResolvableDesign
from .placement import Placement

__all__ = [
    "Transmission",
    "ShuffleTrace",
    "xor_bytes",
    "split_packets",
    "coded_multicast_schedule",
    "decode_coded_multicast",
    "Stage1Chunk",
    "Stage2Chunk",
    "Stage3Chunk",
    "stage1_chunks",
    "stage2_chunks",
    "stage3_chunks",
]


# --------------------------------------------------------------------- #
# byte-level coding primitives
# --------------------------------------------------------------------- #
def xor_bytes(*parts: bytes) -> bytes:
    """XOR of equal-length byte strings."""
    if not parts:
        raise ValueError("need at least one part")
    n = len(parts[0])
    acc = bytearray(parts[0])
    for p in parts[1:]:
        if len(p) != n:
            raise ValueError("length mismatch in xor_bytes")
        for i, b in enumerate(p):
            acc[i] ^= b
    return bytes(acc)


def split_packets(chunk: bytes, m: int) -> list[bytes]:
    """Split ``chunk`` into ``m`` equal packets, zero-padding to a multiple.

    The paper assumes divisibility; padding overhead is accounted by the
    caller (it is the actual on-wire size).
    """
    if m < 1:
        raise ValueError("m must be >= 1")
    plen = -(-len(chunk) // m)  # ceil
    padded = chunk + b"\x00" * (plen * m - len(chunk))
    return [padded[i * plen:(i + 1) * plen] for i in range(m)]


@dataclass(frozen=True)
class Transmission:
    """One on-wire message."""

    stage: int
    sender: int
    receivers: tuple[int, ...]
    payload: bytes = field(repr=False)
    # bookkeeping label for debugging/tests, e.g. ("group", G) or ("job", j)
    tag: tuple = ()

    @property
    def nbytes(self) -> int:
        return len(self.payload)

    @property
    def p2p_bytes(self) -> int:
        return len(self.payload) * len(self.receivers)


@dataclass
class ShuffleTrace:
    """Accumulates transmissions and exposes load accounting."""

    transmissions: list[Transmission] = field(default_factory=list)

    def add(self, t: Transmission) -> None:
        self.transmissions.append(t)

    def bytes_for_stage(self, stage: int, model: str = "bus") -> int:
        sel = (t for t in self.transmissions if t.stage == stage)
        if model == "bus":
            return sum(t.nbytes for t in sel)
        if model == "p2p":
            return sum(t.p2p_bytes for t in sel)
        raise ValueError(f"unknown cost model {model!r}")

    def total_bytes(self, model: str = "bus") -> int:
        return sum(self.bytes_for_stage(s, model) for s in (1, 2, 3))

    def load(self, J: int, Q: int, B_bytes: int, stage: int | None = None,
             model: str = "bus") -> float:
        """Normalized communication load L = bytes / (J*Q*B) (Def. 3)."""
        num = (self.total_bytes(model) if stage is None
               else self.bytes_for_stage(stage, model))
        return num / (J * Q * B_bytes)


# --------------------------------------------------------------------- #
# Algorithm 2 — coded multicast within a group of k machines
# --------------------------------------------------------------------- #
def coded_multicast_schedule(
    group: tuple[int, ...],
    chunks: dict[int, bytes],
    *,
    stage: int,
    tag: tuple = (),
) -> list[Transmission]:
    """Build the k broadcasts of Algorithm 2 for one group.

    ``chunks[k']`` is the data chunk server ``k'`` is missing (and every
    other group member can compute). Packet ``i`` of chunk ``k'`` is
    associated with the i-th machine of ``sorted(group \\ {k'})``.
    Each machine ``m`` broadcasts the XOR of all packets associated with it.
    """
    k = len(group)
    if set(chunks) != set(group):
        raise ValueError("need exactly one chunk per group member")
    lens = {len(c) for c in chunks.values()}
    if len(lens) != 1:
        raise ValueError("all chunks in a group must have equal size")

    packets: dict[int, list[bytes]] = {
        kp: split_packets(chunks[kp], k - 1) for kp in group
    }
    out = []
    for m in group:
        mine = []
        for kp in group:
            if kp == m:
                continue
            others = sorted(s for s in group if s != kp)
            mine.append(packets[kp][others.index(m)])
        out.append(
            Transmission(
                stage=stage,
                sender=m,
                receivers=tuple(s for s in group if s != m),
                payload=xor_bytes(*mine),
                tag=tag,
            )
        )
    return out


def decode_coded_multicast(
    group: tuple[int, ...],
    receiver: int,
    broadcasts: list[Transmission],
    known_chunks: dict[int, bytes],
    chunk_len: int,
) -> bytes:
    """Receiver-side decode (Lemma 2 proof, Appendix).

    ``known_chunks`` must contain chunk ``k'`` for every ``k' != receiver``
    in the group — these are recomputable from the receiver's local map
    outputs (the Lemma-2 storage condition). Returns the recovered chunk.
    """
    k = len(group)
    plen = -(-chunk_len // (k - 1))
    my_others = sorted(s for s in group if s != receiver)
    recovered: dict[int, bytes] = {}
    for t in broadcasts:
        m = t.sender
        if m == receiver:
            continue
        acc = bytearray(t.payload)
        for kp in group:
            if kp in (m, receiver):
                continue
            others = sorted(s for s in group if s != kp)
            pkt = split_packets(known_chunks[kp], k - 1)[others.index(m)]
            for i, b in enumerate(pkt):
                acc[i] ^= b
        # what remains is packet of *receiver's* chunk at receiver-index of m
        recovered[my_others.index(m)] = bytes(acc[:plen])
    chunk = b"".join(recovered[i] for i in range(k - 1))
    return chunk[:chunk_len]


# --------------------------------------------------------------------- #
# stage chunk descriptors — WHICH aggregate flows where
# --------------------------------------------------------------------- #
@dataclass(frozen=True)
class Stage1Chunk:
    """Stage 1: owners of job ``j`` exchange their missing batch aggregate.

    ``alpha^{(j)}_{[k']}`` = aggregate over batch labeled k' of values for
    reduce-function k' — needed by owner k', computable by all other owners.
    """

    job: int
    receiver: int        # k' (an owner of job)
    batch: int           # batch index carrying k' label

    @property
    def qfunc(self) -> int:
        return self.receiver


@dataclass(frozen=True)
class Stage2Chunk:
    """Stage 2: group member ``k'`` receives, for the job co-owned by the
    rest of the group, the aggregate over the batch its class-mate owner
    misses (Eq. 4)."""

    job: int
    receiver: int        # k' (NOT an owner of job)
    batch: int           # batch labeled by the class-mate owner U_l
    classmate_owner: int  # U_l

    @property
    def qfunc(self) -> int:
        return self.receiver


@dataclass(frozen=True)
class Stage3Chunk:
    """Stage 3: unicast of the complement aggregate (Eq. 5)."""

    job: int
    receiver: int        # U_m, non-owner
    sender: int          # U_k, the job's owner in m's parallel class
    batches: tuple[int, ...]  # the k-1 batches the sender stores


def stage1_chunks(pl: Placement) -> dict[tuple[int, ...], list[Stage1Chunk]]:
    """Group (= owner set) -> chunks, one per owner.

    A read-only view over the compiled :class:`ShuffleProgram` tables —
    the IR in :mod:`repro.core.schedule` is the single source of truth
    for WHICH aggregate flows where.
    """
    from .schedule import lower_program
    prog = lower_program(pl, device_tables=False)
    out: dict[tuple[int, ...], list[Stage1Chunk]] = {}
    for row in prog.s1_rows:
        G = prog.group_members(int(row))
        out[G] = [
            Stage1Chunk(job=j, receiver=kp, batch=t)
            for kp, j, t in prog.coded_chunks(int(row))
        ]
    return out


def stage2_chunks(pl: Placement) -> dict[tuple[int, ...], list[Stage2Chunk]]:
    """Stage-2 group -> chunks, one per member (paper §III-C.2).

    View over the :class:`ShuffleProgram` tables, like
    :func:`stage1_chunks`.
    """
    from .schedule import lower_program
    prog = lower_program(pl, device_tables=False)
    out: dict[tuple[int, ...], list[Stage2Chunk]] = {}
    for row in prog.s2_rows:
        row = int(row)
        G = prog.group_members(row)
        out[G] = [
            Stage2Chunk(job=j, receiver=kp, batch=t,
                        classmate_owner=int(prog.chunk_aux[row, p]))
            for p, (kp, j, t) in enumerate(prog.coded_chunks(row))
        ]
    return out


def stage3_chunks(pl: Placement) -> list[Stage3Chunk]:
    """All stage-3 unicasts: for each non-owner U_m of job j, the unique
    class-mate owner U_k sends the aggregate of its stored batches."""
    from .schedule import lower_program
    prog = lower_program(pl, device_tables=False)
    out = [
        Stage3Chunk(job=int(prog.s3_job[i]), receiver=int(prog.s3_recv[i]),
                    sender=int(prog.s3_send[i]),
                    batches=tuple(int(t) for t in prog.s3_batches[i]))
        for i in range(len(prog.s3_job))
    ]
    # each server misses J - q^{k-2} jobs, one unicast per missing job
    assert len(out) == pl.design.K * (pl.design.J - pl.design.block_size)
    return out
