"""Analytic communication loads and job requirements — paper §IV, §V.

All loads are normalized by ``J * Q * B`` (Definition 3). The ``bus`` cost
model is the paper's shared-multicast-medium model; see
:mod:`repro.core.shuffle` for the ``p2p`` variant used on TPU ICI.
"""

from __future__ import annotations

from math import comb

__all__ = [
    "camr_stage_loads",
    "camr_load",
    "camr_load_p2p",
    "camr_edge_loads",
    "camr_load_hierarchical",
    "uncoded_load_hierarchical",
    "ccdc_load",
    "ccdc_min_jobs",
    "camr_min_jobs",
    "cdc_load",
    "uncoded_aggregated_load",
    "uncoded_unit_storage_load",
    "storage_fraction",
]


def storage_fraction(q: int, k: int) -> float:
    """mu = (k-1)/K for the CAMR placement."""
    return (k - 1) / (k * q)


def camr_stage_loads(q: int, k: int) -> tuple[float, float, float]:
    """(L_stage1, L_stage2, L_stage3) — paper §IV."""
    K = k * q
    l1 = k / (K * (k - 1))
    l2 = (q - 1) * k / (K * (k - 1))
    l3 = (q - 1) / q
    return l1, l2, l3


def camr_load(q: int, k: int) -> float:
    """L_CAMR = (k(q-1)+1) / (q(k-1)) — paper §IV."""
    return (k * (q - 1) + 1) / (q * (k - 1))


def camr_load_p2p(q: int, k: int) -> float:
    """CAMR load when a multicast to r receivers costs r transmissions
    (point-to-point links, e.g. TPU ICI) — DESIGN.md §3.

    Stages 1-2 multicast to k-1 receivers; stage 3 is unicast already.
    """
    l1, l2, l3 = camr_stage_loads(q, k)
    return (k - 1) * (l1 + l2) + l3


def camr_min_jobs(q: int, k: int) -> int:
    """J_CAMR = q^(k-1)."""
    return q ** (k - 1)


# --------------------------------------------------------------------- #
# two-level (hosts x devices-per-host) cost model — DESIGN.md §16
# --------------------------------------------------------------------- #
def camr_edge_loads(q: int, k: int, hosts: int = 1,
                    schedule: str = "two_level") -> tuple[float, float]:
    """``(L_intra, L_inter)`` per-edge split of the p2p CAMR load on a
    class-major two-level layout (``hosts | k``, ``c = k/hosts``
    parallel classes — hence ``c*q`` devices — per host).

    Per (group, sender) the coded packet has ``k-1`` receivers, one per
    class: ``c-1`` on the sender's host, ``c`` on each of the other
    ``hosts-1`` hosts. Per-hop loads follow from the per-multicast
    stage loads ``l1 + l2 = 1/(k-1)`` (every hop carries one packet of
    ``B/(k-1)``) and stage 3 being intra-class — classes sit inside
    host blocks, so stage 3 NEVER crosses hosts:

    * ``schedule="flat"`` — every receiver is served by a direct hop:
      ``L_inter = (k - c) * (l1 + l2)``,
      ``L_intra = (c - 1) * (l1 + l2) + l3``.
    * ``schedule="two_level"`` — one gateway copy per remote host, then
      intra-host relay to the other ``c-1`` receivers there:
      ``L_inter = (hosts - 1) * (l1 + l2)``,
      ``L_intra = (c - 1) * hosts * (l1 + l2) + l3``.

    Both schedules total ``camr_load_p2p`` hops (the relay moves every
    deduplicated copy once, on the fast edge); the inter-host cut is
    the factor ``hosts/k < 1`` whenever ``hosts < k``. ``hosts = 1``
    reduces both schedules to ``(camr_load_p2p, 0)`` exactly.
    """
    if schedule not in ("flat", "two_level"):
        raise ValueError(f"unknown schedule {schedule!r}")
    if hosts < 1:
        raise ValueError(f"hosts must be >= 1, got {hosts}")
    if k % hosts:
        raise ValueError(f"hosts={hosts} must divide k={k} (class-major "
                         "host blocks)")
    l1, l2, l3 = camr_stage_loads(q, k)
    c = k // hosts
    if schedule == "flat":
        inter = (k - c) * (l1 + l2)
        intra = (c - 1) * (l1 + l2) + l3
    else:
        inter = (hosts - 1) * (l1 + l2)
        intra = (c - 1) * hosts * (l1 + l2) + l3
    return intra, inter


def camr_load_hierarchical(q: int, k: int, hosts: int = 1,
                           alpha: float = 1.0) -> float:
    """Two-level CAMR cost: ``L_intra + alpha * L_inter`` with
    ``alpha`` = inter-host cost per byte relative to intra-host
    (two-level gateway schedule of :func:`camr_edge_loads`).

    Flat-reduction identities (pinned in tests/test_loads.py):

    * ``hosts = 1`` -> ``camr_load_p2p(q, k)`` exactly, for any alpha
      (no slow edge exists);
    * ``alpha = 1`` -> ``camr_load_p2p(q, k)`` exactly, for any hosts
      (uniform cost collapses the edge split: the two schedules move
      the same total hop count).

    Strictly increasing in ``alpha`` whenever ``hosts >= 2`` (slope
    ``L_inter > 0``), constant for ``hosts = 1``.
    """
    intra, inter = camr_edge_loads(q, k, hosts, schedule="two_level")
    return intra + alpha * inter


def ccdc_load(mu: float, K: int) -> float:
    """L_CCDC = (1-mu)(mu K + 1) / (mu K) — paper Eq. (6), for mu*K integer."""
    r = mu * K
    if abs(r - round(r)) > 1e-9 or not (1 <= round(r) <= K - 1):
        raise ValueError(f"mu*K must be an integer in [1, K-1], got {r}")
    r = round(r)
    return (1 - r / K) * (r + 1) / r


def ccdc_min_jobs(mu: float, K: int) -> int:
    """J_CCDC,min = C(K, mu*K + 1) — paper §V."""
    r = round(mu * K)
    return comb(K, r + 1)


def cdc_load(r: int, K: int) -> float:
    """CDC (no aggregation) tradeoff L(r) = (1/r)(1 - r/K) [Li et al. 2018].

    NOTE: normalized by Q*N*B *per job* in the CDC paper (no combining, so
    every subfile's value crosses the wire); included for context plots.
    """
    if not 1 <= r <= K:
        raise ValueError("r must be in [1, K]")
    return (1 - r / K) / r


def uncoded_aggregated_load(q: int, k: int) -> float:
    """Uncoded shuffle WITH combiners on the CAMR placement.

    Owners: 1 aggregate (B) per (job, owner) -> J*k*B. Non-owners: no single
    server stores all N subfiles, so 2 transmissions (one owner sends its
    k-1 stored batches combined, a second owner sends the remaining batch):
    J*(K-k)*2B.  L = (2K - k)/K.
    """
    K = k * q
    return (2 * K - k) / K


def uncoded_load_hierarchical(q: int, k: int, hosts: int = 1,
                              alpha: float = 1.0) -> float:
    """Uncoded aggregated shuffle (:func:`uncoded_aggregated_load`'s
    delivery plan) priced on the two-level topology:
    ``L_intra + alpha * L_inter``.

    Deliveries on the class-major layout (``hosts | k``): the combined
    ``k-1``-batch aggregate a non-owner receives comes from its
    CLASS-MATE owner — same class, same host block, always intra. The
    single-batch delivery every reducer needs (``J*K`` of them: ``J*k``
    to owners + ``J*(K-k)`` to non-owners) comes from the holder in the
    cyclically-next parallel class, which sits on another host exactly
    when the receiver's class is the last of its host block — ``hosts``
    of the ``k`` classes when ``hosts >= 2`` (including the wrap), none
    when ``hosts = 1``. Hence::

        L_inter = (J*K * hosts/k) / (J*K) = hosts / k     (hosts >= 2)
        L_intra = (2K - k)/K - L_inter

    Identities mirror :func:`camr_load_hierarchical`: ``hosts = 1`` or
    ``alpha = 1`` reduce to ``uncoded_aggregated_load`` exactly.

    (The placement stores every batch on ``c-1 >= 1`` other same-host
    owners whenever ``c = k/hosts >= 2``, so a topology-AWARE uncoded
    sender choice could drive inter-host bytes to zero — at the full
    uncoded total. This function prices the topology-blind plan the
    repo's ``uncoded_reduce_scatter`` baseline actually executes;
    DESIGN.md §16 discusses the tradeoff.)
    """
    if hosts < 1:
        raise ValueError(f"hosts must be >= 1, got {hosts}")
    if k % hosts:
        raise ValueError(f"hosts={hosts} must divide k={k} (class-major "
                         "host blocks)")
    total = uncoded_aggregated_load(q, k)
    inter = hosts / k if hosts >= 2 else 0.0
    return (total - inter) + alpha * inter


def uncoded_unit_storage_load(K: int) -> float:
    """No redundancy (mu = 1/K), combiners on: each server sends one
    aggregate per (job, other reducer): L = (K-1)/K."""
    return (K - 1) / K
