"""Analytic communication loads and job requirements — paper §IV, §V.

All loads are normalized by ``J * Q * B`` (Definition 3). The ``bus`` cost
model is the paper's shared-multicast-medium model; see
:mod:`repro.core.shuffle` for the ``p2p`` variant used on TPU ICI.
"""

from __future__ import annotations

from math import comb

__all__ = [
    "camr_stage_loads",
    "camr_load",
    "camr_load_p2p",
    "ccdc_load",
    "ccdc_min_jobs",
    "camr_min_jobs",
    "cdc_load",
    "uncoded_aggregated_load",
    "uncoded_unit_storage_load",
    "storage_fraction",
]


def storage_fraction(q: int, k: int) -> float:
    """mu = (k-1)/K for the CAMR placement."""
    return (k - 1) / (k * q)


def camr_stage_loads(q: int, k: int) -> tuple[float, float, float]:
    """(L_stage1, L_stage2, L_stage3) — paper §IV."""
    K = k * q
    l1 = k / (K * (k - 1))
    l2 = (q - 1) * k / (K * (k - 1))
    l3 = (q - 1) / q
    return l1, l2, l3


def camr_load(q: int, k: int) -> float:
    """L_CAMR = (k(q-1)+1) / (q(k-1)) — paper §IV."""
    return (k * (q - 1) + 1) / (q * (k - 1))


def camr_load_p2p(q: int, k: int) -> float:
    """CAMR load when a multicast to r receivers costs r transmissions
    (point-to-point links, e.g. TPU ICI) — DESIGN.md §3.

    Stages 1-2 multicast to k-1 receivers; stage 3 is unicast already.
    """
    l1, l2, l3 = camr_stage_loads(q, k)
    return (k - 1) * (l1 + l2) + l3


def camr_min_jobs(q: int, k: int) -> int:
    """J_CAMR = q^(k-1)."""
    return q ** (k - 1)


def ccdc_load(mu: float, K: int) -> float:
    """L_CCDC = (1-mu)(mu K + 1) / (mu K) — paper Eq. (6), for mu*K integer."""
    r = mu * K
    if abs(r - round(r)) > 1e-9 or not (1 <= round(r) <= K - 1):
        raise ValueError(f"mu*K must be an integer in [1, K-1], got {r}")
    r = round(r)
    return (1 - r / K) * (r + 1) / r


def ccdc_min_jobs(mu: float, K: int) -> int:
    """J_CCDC,min = C(K, mu*K + 1) — paper §V."""
    r = round(mu * K)
    return comb(K, r + 1)


def cdc_load(r: int, K: int) -> float:
    """CDC (no aggregation) tradeoff L(r) = (1/r)(1 - r/K) [Li et al. 2018].

    NOTE: normalized by Q*N*B *per job* in the CDC paper (no combining, so
    every subfile's value crosses the wire); included for context plots.
    """
    if not 1 <= r <= K:
        raise ValueError("r must be in [1, K]")
    return (1 - r / K) / r


def uncoded_aggregated_load(q: int, k: int) -> float:
    """Uncoded shuffle WITH combiners on the CAMR placement.

    Owners: 1 aggregate (B) per (job, owner) -> J*k*B. Non-owners: no single
    server stores all N subfiles, so 2 transmissions (one owner sends its
    k-1 stored batches combined, a second owner sends the remaining batch):
    J*(K-k)*2B.  L = (2K - k)/K.
    """
    K = k * q
    return (2 * K - k) / K


def uncoded_unit_storage_load(K: int) -> float:
    """No redundancy (mu = 1/K), combiners on: each server sends one
    aggregate per (job, other reducer): L = (K-1)/K."""
    return (K - 1) / K
