"""Core CAMR library: resolvable designs, placement, coded shuffle, engines."""

from .designs import ResolvableDesign, make_design, factorize_cluster
from .placement import Placement, make_placement
from .schedule import ShuffleProgram, lower_program, lower_degraded
from .engine import CAMRConfig, CAMREngine, run_wordcount_example
from . import loads, shuffle, baselines

__all__ = [
    "ResolvableDesign",
    "make_design",
    "factorize_cluster",
    "Placement",
    "make_placement",
    "ShuffleProgram",
    "lower_program",
    "lower_degraded",
    "CAMRConfig",
    "CAMREngine",
    "run_wordcount_example",
    "loads",
    "shuffle",
    "baselines",
]
