"""Resolvable designs from single-parity-check (SPC) codes — paper §III.

The cluster of ``K = k * q`` servers is identified with the block set of a
resolvable design built from the (k, k-1) SPC code over Z_q; the ``J =
q**(k-1)`` jobs are identified with the point set.

Indexing conventions (0-based everywhere in code; the paper is 1-based):

* job   ``j``  in ``range(J)``   <-> codeword column ``j`` of ``T``
* server ``s`` in ``range(K)``   <-> block ``B[i, l]`` with ``i = s // q``
  (parallel-class index) and ``l = s % q`` (value index), matching the
  paper's convention ``U_i <-> B_{ceil(i/q), (i-1) mod q}``.

All structure needed by placement / shuffle is precomputed once and cached
on the :class:`ResolvableDesign` instance; everything is pure numpy so it
can run on the master node of a real deployment.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from functools import lru_cache

import numpy as np

__all__ = [
    "ResolvableDesign",
    "spc_codeword_table",
    "make_design",
    "factorize_cluster",
]


def spc_codeword_table(q: int, k: int) -> np.ndarray:
    """Codeword table ``T`` of the (k, k-1) SPC code over Z_q.

    Returns an array of shape ``(k, q**(k-1))``: column ``j`` is the j-th
    codeword ``c = [u, sum(u) mod q]`` where ``u`` enumerates Z_q^{k-1} in
    lexicographic order. Works for any integer ``q >= 2`` (Z_q need not be a
    field — paper footnote 1).
    """
    if q < 2 or k < 2:
        raise ValueError(f"need q >= 2 and k >= 2, got q={q}, k={k}")
    # Enumerate all messages u in Z_q^{k-1} lexicographically.
    J = q ** (k - 1)
    msgs = np.indices((q,) * (k - 1)).reshape(k - 1, J)
    parity = msgs.sum(axis=0) % q
    return np.concatenate([msgs, parity[None, :]], axis=0).astype(np.int64)


@dataclass(frozen=True, eq=False)  # identity hash: methods are lru_cached
class ResolvableDesign:
    """The (X_SPC, A_SPC) resolvable design of Lemma 1, plus the incidence
    structure used by the CAMR placement and shuffle.

    Attributes
    ----------
    q, k        cluster factorization ``K = k * q``
    T           codeword table, shape (k, J)
    blocks      ``blocks[s]`` = sorted job ids in the block of server ``s``
    owners      ``owners[j]`` = sorted server ids owning job ``j``
                (exactly one per parallel class, ascending class order)
    """

    q: int
    k: int
    T: np.ndarray = field(repr=False)

    # ------------------------------------------------------------------ #
    # basic parameters
    # ------------------------------------------------------------------ #
    @property
    def K(self) -> int:
        return self.k * self.q

    @property
    def J(self) -> int:
        return self.q ** (self.k - 1)

    @property
    def block_size(self) -> int:
        """|B_{i,l}| = q^{k-2} (Lemma 1)."""
        return self.q ** (self.k - 2)

    @property
    def storage_fraction(self) -> float:
        """mu = (k-1)/K (paper §III-A)."""
        return (self.k - 1) / self.K

    # ------------------------------------------------------------------ #
    # incidence structure
    # ------------------------------------------------------------------ #
    def server_of(self, cls: int, val: int) -> int:
        """Server id of block ``B_{cls, val}``."""
        return cls * self.q + val

    def class_of(self, server: int) -> int:
        """Parallel-class index of ``server``."""
        return server // self.q

    def value_of(self, server: int) -> int:
        """Symbol value ``l`` of the server's block ``B_{i,l}``."""
        return server % self.q

    @property
    def blocks(self) -> tuple[tuple[int, ...], ...]:
        """blocks[s] = tuple of job ids whose codeword has T[i, j] == l."""
        return self._blocks()

    @lru_cache(maxsize=None)
    def _blocks(self) -> tuple[tuple[int, ...], ...]:
        out = []
        for s in range(self.K):
            i, l = self.class_of(s), self.value_of(s)
            out.append(tuple(np.nonzero(self.T[i] == l)[0].tolist()))
        return tuple(out)

    @property
    def owners(self) -> tuple[tuple[int, ...], ...]:
        """owners[j] = the k servers owning job j, one per parallel class."""
        return self._owners()

    @lru_cache(maxsize=None)
    def _owners(self) -> tuple[tuple[int, ...], ...]:
        out = []
        for j in range(self.J):
            out.append(tuple(self.server_of(i, int(self.T[i, j]))
                             for i in range(self.k)))
        return tuple(out)

    def parallel_class(self, i: int) -> tuple[int, ...]:
        """P_i = the q servers (blocks) of class i."""
        return tuple(self.server_of(i, l) for l in range(self.q))

    @property
    def parallel_classes(self) -> tuple[tuple[int, ...], ...]:
        return tuple(self.parallel_class(i) for i in range(self.k))

    def is_owner(self, server: int, job: int) -> bool:
        i = self.class_of(server)
        return int(self.T[i, job]) == self.value_of(server)

    def owned_jobs(self, server: int) -> tuple[int, ...]:
        return self.blocks[server]

    # ------------------------------------------------------------------ #
    # stage-2 group enumeration
    # ------------------------------------------------------------------ #
    def stage2_groups(self) -> list[tuple[int, ...]]:
        """All groups (one block per parallel class, empty intersection).

        A group picks value ``v_i`` in each class i; its intersection is the
        set of codewords with T[i, j] == v_i for all i, which is non-empty
        iff ``v_k == sum(v_1..v_{k-1}) mod q`` (exactly one codeword then).
        Hence the q^{k-1}(q-1) groups are exactly the value tuples whose
        parity coordinate MISmatches the message parity.
        """
        groups = []
        for vals in itertools.product(range(self.q), repeat=self.k):
            if sum(vals[:-1]) % self.q != vals[-1]:
                groups.append(tuple(self.server_of(i, v)
                                    for i, v in enumerate(vals)))
        assert len(groups) == self.J * (self.q - 1)
        return groups

    def common_job(self, servers: tuple[int, ...]) -> int:
        """The unique job owned jointly by k-1 servers from distinct classes.

        For a stage-2 group G and excluded server s, ``common_job(G \\ {s})``
        is the job the remaining k-1 servers co-own (paper §III-C.2).
        """
        if len(servers) != self.k - 1:
            raise ValueError("need exactly k-1 servers")
        classes = [self.class_of(s) for s in servers]
        if len(set(classes)) != self.k - 1:
            raise ValueError("servers must lie in distinct parallel classes")
        vals = {c: self.value_of(s) for c, s in zip(classes, servers)}
        missing = next(i for i in range(self.k) if i not in vals)
        if missing == self.k - 1:
            # parity coordinate missing -> message fully known
            u = [vals[i] for i in range(self.k - 1)]
        else:
            # one message coordinate missing -> solve from parity
            par = vals[self.k - 1]
            known = sum(v for c, v in vals.items() if c != self.k - 1)
            u = [vals.get(i, (par - known) % self.q)
                 for i in range(self.k - 1)]
        # job id = lexicographic rank of the message vector
        j = 0
        for v in u:
            j = j * self.q + int(v)
        return j

    # ------------------------------------------------------------------ #
    # sanity
    # ------------------------------------------------------------------ #
    def validate(self) -> None:
        """Check Lemma 1 properties exhaustively (used by tests)."""
        K, J = self.K, self.J
        for i in range(self.k):
            cls = self.parallel_class(i)
            pts: list[int] = []
            for s in cls:
                assert len(self.blocks[s]) == self.block_size
                pts.extend(self.blocks[s])
            assert sorted(pts) == list(range(J)), "class must partition X"
        for j in range(J):
            own = self.owners[j]
            assert len(own) == self.k
            assert len({self.class_of(s) for s in own}) == self.k
        assert sum(len(self.blocks[s]) for s in range(K)) == K * self.block_size


def make_design(q: int, k: int) -> ResolvableDesign:
    """Build the resolvable design for a ``K = k*q`` cluster."""
    return ResolvableDesign(q=q, k=k, T=spc_codeword_table(q, k))


def factorize_cluster(K: int, mu_target: float | None = None,
                      ) -> tuple[int, int]:
    """Pick (q, k) with K = k*q.

    If ``mu_target`` is given, choose the factorization whose storage
    fraction (k-1)/K is closest to it (used by elastic re-planning);
    otherwise choose the most balanced factorization with q >= 2, k >= 2.
    """
    cands = [(K // q, q) for q in range(2, K) if K % q == 0 and K // q >= 2]
    if not cands:
        raise ValueError(f"K={K} has no factorization with q,k >= 2")
    if mu_target is not None:
        k, q = min(cands, key=lambda kq: abs((kq[0] - 1) / K - mu_target))
    else:
        k, q = min(cands, key=lambda kq: abs(kq[0] - kq[1]))
    return q, k
