"""Executable baseline shuffles, with the same byte accounting as CAMR.

* :class:`UncodedAggregatedEngine` — same resolvable-design placement and
  combiners, but NO coding: every missing aggregate is unicast by a holder.
  Achieves L = (2K - k)/K (loads.uncoded_aggregated_load).
* :class:`CCDCEngine` — the *group-level exchange primitive* of Compressed
  Coded Distributed Computing [Li-Maddah-Ali-Avestimehr, ISIT'18] at
  computation load r = mu*K: jobs are indexed by the (r+1)-subsets of
  servers (J = C(K, r+1) — the paper's §V job-count requirement, which this
  engine makes concrete: every subset must host a job for the scheme to be
  complete), every server in subset S maps all parts of job_S except the
  one exclusive to it, and each S runs one Lemma-2-style coded exchange.
  The engine validates decode correctness and the member-exchange load
  (1/r per (job, member-function)); the full-system CCDC load formula
  (1-mu)(mu K+1)/(mu K) is compared analytically in
  :mod:`repro.core.loads` (test_camr_equals_ccdc_at_same_mu), since the
  paper's own comparison is analytic.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass

import numpy as np

from .designs import make_design
from .placement import make_placement
from .shuffle import (
    ShuffleTrace,
    Transmission,
    coded_multicast_schedule,
    decode_coded_multicast,
)

__all__ = ["UncodedAggregatedEngine", "CCDCEngine"]


class UncodedAggregatedEngine:
    """CAMR placement + combiners, shuffle without coding (all unicast)."""

    def __init__(self, q: int, k: int, gamma: int, map_fn,
                 combine=np.add):
        from .engine import CAMRConfig  # local import to avoid cycle
        self.cfg = CAMRConfig(q=q, k=k, gamma=gamma)
        self.design = make_design(q, k)
        self.placement = make_placement(self.design, gamma)
        self.map_fn = map_fn
        self.combine = combine
        self.trace = ShuffleTrace()

    def run(self, datasets):
        d, pl = self.design, self.placement
        K, Q = self.cfg.K, self.cfg.num_functions()
        agg = [dict() for _ in range(K)]
        for s in range(K):
            for job, t in pl.stored_batches(s):
                vals = [np.asarray(self.map_fn(job, datasets[job][n]))
                        for n in pl.batch_subfiles(t)]
                a = vals[0]
                for v in vals[1:]:
                    a = self.combine(a, v)
                agg[s][(job, t)] = a
        self._value_bytes = a[0].nbytes

        # Same canonical combine order as CAMREngine.reduce_phase
        # (delivered batch + ascending fold of the other k-1): coded and
        # uncoded runs over the same map outputs are BITWISE equal —
        # same math, different wires.
        results = [dict() for _ in range(K)]
        for j in range(d.J):
            for s in range(K):
                if d.is_owner(s, j):
                    # one unicast: any holder of the missing batch sends it
                    tmiss = pl.batch_of_label(j, s)
                    h = pl.holders(j, tmiss)[0]
                    payload = agg[h][(j, tmiss)][s]
                    self.trace.add(Transmission(
                        stage=1, sender=h, receivers=(s,),
                        payload=payload.tobytes(), tag=("job", j)))
                    rest = None
                    for t in range(d.k):
                        if t != tmiss:
                            v = agg[s][(j, t)][s]
                            rest = v if rest is None else self.combine(rest, v)
                    acc = self.combine(payload.copy(), rest)
                else:
                    # two unicasts: the owner u1 in s's parallel class sends
                    # its k-1 stored batches combined; u2 sends u1's missing
                    # batch (mirrors the CAMR stage-2/3 pair).
                    (u1,) = [u for u in d.owners[j]
                             if d.class_of(u) == d.class_of(s)]
                    t1 = pl.batch_of_label(j, u1)
                    acc1 = None
                    for t in range(d.k):
                        if t != t1:
                            v = agg[u1][(j, t)][s]
                            acc1 = v if acc1 is None else self.combine(acc1, v)
                    u2 = pl.holders(j, t1)[0]
                    part2 = agg[u2][(j, t1)][s]
                    for payload, u in ((acc1, u1), (part2, u2)):
                        self.trace.add(Transmission(
                            stage=3, sender=u, receivers=(s,),
                            payload=payload.tobytes(), tag=("job", j)))
                    acc = self.combine(part2, acc1)
                results[s][(j, s)] = acc
        return results

    def measured_load(self, model: str = "bus") -> float:
        J, Q, B = self.design.J, self.cfg.num_functions(), self._value_bytes
        return self.trace.total_bytes(model) / (J * Q * B)


@dataclass(frozen=True)
class _CCDCJob:
    """Job indexed by an (r+1)-subset S of servers."""

    subset: tuple[int, ...]


class CCDCEngine:
    """Executable CCDC group exchange at computation load r, J = C(K, r+1).

    Placement for job S (|S| = r+1): the dataset is split into r+1 parts,
    part ``p`` is stored on ``S \\ {S[p]}`` (each server in S misses exactly
    one part and stores r parts — storage fraction r/K per job).

    Shuffle: within group S, server S[p] needs the aggregate of part p for
    its reduce function; every other server of S can compute it — exactly
    the Lemma-2 setting with k := r+1. Measured member-exchange load is
    1/r per (job, member function); see module docstring for why the
    full-system formula comparison is analytic.
    """

    def __init__(self, K: int, r: int, map_fn, combine=np.add):
        if not 1 <= r <= K - 1:
            raise ValueError("need 1 <= r <= K-1")
        self.K, self.r = K, r
        self.jobs = [
            _CCDCJob(subset=S)
            for S in itertools.combinations(range(K), r + 1)
        ]
        self.map_fn = map_fn
        self.combine = combine
        self.trace = ShuffleTrace()

    @property
    def J(self) -> int:
        return len(self.jobs)

    def run(self, datasets):
        """datasets[j] = list of r+1 parts (each a subfile payload).

        Returns per-server dict {(job, member_index): reduced value}. Each
        member S[p] reduces function p of its job (Q_eff = r+1 per job).
        """
        r, K = self.r, self.K
        results = [dict() for _ in range(K)]
        for j, job in enumerate(self.jobs):
            S = job.subset
            # map: server S[p] maps all parts except part p
            vals = [np.asarray(self.map_fn(j, part)) for part in datasets[j]]
            dim = vals[0].shape
            self._value_bytes = vals[0][0].nbytes
            # coded exchange within S: chunk for S[p] = aggregate of part p
            # for function p (its reduce function)
            chunks = {S[p]: np.ascontiguousarray(vals[p][p]).tobytes()
                      for p in range(r + 1)}
            txs = coded_multicast_schedule(S, chunks, stage=1,
                                           tag=("job", j))
            for t in txs:
                self.trace.add(t)
            clen = len(next(iter(chunks.values())))
            for p, s in enumerate(S):
                known = {S[p2]: chunks[S[p2]] for p2 in range(r + 1)
                         if p2 != p}  # recomputable: s stores those parts
                dec = decode_coded_multicast(S, s, txs, known, clen)
                got = np.frombuffer(dec, dtype=vals[0].dtype).copy()
                acc = got
                for p2 in range(r + 1):
                    if p2 != p:
                        acc = self.combine(acc, vals[p2][p])
                results[s][(j, p)] = acc
        return results

    def verify(self, datasets, results):
        for j, job in enumerate(self.jobs):
            vals = [np.asarray(self.map_fn(j, part)) for part in datasets[j]]
            total = vals[0]
            for v in vals[1:]:
                total = self.combine(total, v)
            for p, s in enumerate(job.subset):
                np.testing.assert_allclose(results[s][(j, p)], total[p],
                                           rtol=1e-6, atol=1e-6)

    def measured_load(self, model: str = "bus") -> float:
        """Normalized by J * Q_eff * B with Q_eff = r+1 reducers per job."""
        B = self._value_bytes
        return self.trace.total_bytes(model) / (self.J * (self.r + 1) * B)
