"""Checkpoint/restart for fault tolerance.

Format: one directory per step containing flat ``.npy`` files (one per
pytree leaf, keyed by its tree path) + ``manifest.json`` with the tree
structure, dtypes, a content hash per leaf, and user metadata (step,
config fingerprint, data-pipeline cursor). Writes go to a temp dir and
are atomically renamed, so a crash mid-write never corrupts the latest
checkpoint. ``CheckpointManager`` adds async writes (a worker thread),
retention, and resume discovery — the pieces a real cluster job needs.

On a real multi-host pod each host writes only the shards it owns
(``process_index`` infix); on single-host it degenerates to full arrays.
"""

from __future__ import annotations

import hashlib
import json
import os
import queue
import shutil
import threading
import time
import warnings
import zlib

import numpy as np

import jax

__all__ = ["save_checkpoint", "load_checkpoint", "CheckpointManager"]

_MANIFEST = "manifest.json"


def _leaf_key(path) -> str:
    return jax.tree_util.keystr(path).replace("/", "_").strip("[]'\"()") \
        .replace("'][", ".").replace("][", ".").replace("'", "")


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {(_leaf_key(p) or f"leaf{i}"): v
            for i, (p, v) in enumerate(leaves)}


def save_checkpoint(path: str, tree, *, step: int, metadata: dict | None
                    = None) -> str:
    """Atomic synchronous save. Returns the final directory."""
    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + f".tmp.{os.getpid()}"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    man = {"step": step, "metadata": metadata or {}, "leaves": {},
           "process": jax.process_index()}
    for key, val in flat.items():
        arr = np.asarray(val)
        fn = f"{key}.npy"
        # store raw bytes: robust for non-native dtypes (bf16, fp8, ...)
        fp = os.path.join(tmp, fn)
        np.save(fp, np.frombuffer(arr.tobytes(), np.uint8))
        # crc32 covers the FILE as written (npy header included), so
        # on-disk corruption anywhere in it is caught at resume even
        # before the payload is parsed; sha256 stays the payload hash
        with open(fp, "rb") as fh:
            crc = zlib.crc32(fh.read())
        man["leaves"][key] = {
            "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "sha256": hashlib.sha256(arr.tobytes()).hexdigest()[:16],
            "crc32": crc,
        }
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(man, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def _load_step(path: str, step: int, flat_keys, verify: bool):
    """Load + verify one step dir. Raises IOError on any integrity
    failure (crc/hash mismatch, unreadable or missing leaf file)."""
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, _MANIFEST)) as f:
        man = json.load(f)
    vals = []
    for key in flat_keys:
        ent = man["leaves"][key]
        fp = os.path.join(d, ent["file"])
        if verify and "crc32" in ent:      # absent in pre-crc manifests
            with open(fp, "rb") as fh:
                if zlib.crc32(fh.read()) != ent["crc32"]:
                    raise IOError(
                        f"checkpoint leaf {key} crc32 mismatch ({fp})")
        try:
            raw = np.load(fp)
        except (OSError, ValueError) as e:
            raise IOError(f"checkpoint leaf {key} unreadable: {e}")
        if verify:
            h = hashlib.sha256(raw.tobytes()).hexdigest()[:16]
            if h != ent["sha256"]:
                raise IOError(f"checkpoint leaf {key} hash mismatch")
        arr = np.frombuffer(raw.tobytes(), dtype=np.dtype(ent["dtype"])
                            ).reshape(ent["shape"])
        vals.append(arr)
    return vals, man


def load_checkpoint(path: str, tree_like, *, step: int | None = None,
                    verify: bool = True):
    """Restore into the structure of ``tree_like``. step=None -> latest
    INTACT step: a checkpoint that fails verification (on-disk
    corruption caught by the per-file crc32 or the payload sha256) is
    skipped with an actionable warning and the next-newest one is
    tried, so a torn write never strands a resume (DESIGN.md §15). An
    EXPLICIT ``step`` still raises on corruption — asking for a
    specific state and silently getting another would be worse than
    failing.

    Returns (tree, manifest_metadata). Raises on hash mismatch when
    ``verify`` (detects torn/corrupt writes on real storage)."""
    flat_keys = list(_flatten(tree_like))
    if step is not None:
        vals, man = _load_step(path, step, flat_keys, verify)
    else:
        steps = available_steps(path)
        if not steps:
            raise FileNotFoundError(f"no checkpoints under {path}")
        vals = man = None
        for s in reversed(steps):
            try:
                vals, man = _load_step(path, s, flat_keys, verify)
                break
            except (OSError, KeyError, ValueError) as e:
                warnings.warn(
                    f"checkpoint step_{s:08d} under {path} failed "
                    f"verification ({e}); falling back to the newest "
                    f"intact step. Delete that directory to stop "
                    f"resuming past it.", RuntimeWarning, stacklevel=2)
        if vals is None:
            raise IOError(
                f"no intact checkpoint under {path}: every step in "
                f"{steps} failed verification")
    leaves, treedef = jax.tree_util.tree_flatten(tree_like)
    restored = jax.tree_util.tree_unflatten(
        treedef, [v.reshape(l.shape) for v, l in zip(vals, leaves)])
    return restored, man["metadata"] | {"step": man["step"]}


def _is_tmp_dir(name: str) -> bool:
    """In-progress/orphaned write dirs: ``step_XXXXXXXX.tmp.<pid>``."""
    return name.startswith("step_") and ".tmp." in name


def available_steps(path: str) -> list[int]:
    if not os.path.isdir(path):
        return []
    out = []
    for n in os.listdir(path):
        # skip tmp dirs EXPLICITLY — previously they were only excluded
        # because int("...tmp.<pid>") happens to raise ValueError, which
        # also silently hid genuinely malformed step dirs
        if n.startswith("step_") and not _is_tmp_dir(n):
            try:
                out.append(int(n.split("_")[1]))
            except (IndexError, ValueError):
                pass
    return sorted(out)


class CheckpointManager:
    """Async checkpointing with retention — overlap I/O with compute.

    save() enqueues a host-synced copy of the tree and returns
    immediately; a worker thread writes it. ``keep`` bounds retained
    checkpoints (latest always kept). wait() drains the queue (call
    before exit or before measuring).
    """

    def __init__(self, path: str, *, keep: int = 3, async_: bool = True):
        self.path = path
        self.keep = keep
        self.async_ = async_
        self._q: queue.Queue = queue.Queue()
        self._err: Exception | None = None
        self._worker = None
        if async_:
            self._worker = threading.Thread(target=self._run, daemon=True)
            self._worker.start()

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            try:
                tree, step, meta = item
                save_checkpoint(self.path, tree, step=step, metadata=meta)
                self._gc()
            except Exception as e:  # surfaced on next save()/wait()
                self._err = e
            finally:
                self._q.task_done()

    #: a foreign step_*.tmp.<pid> dir younger than this is presumed to
    #: be another writer mid-save and is never reaped
    STALE_TMP_SECS = 3600.0

    def _gc(self):
        steps = available_steps(self.path)
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.path, f"step_{s:08d}"),
                          ignore_errors=True)
        # crashed saves leave step_*.tmp.<pid> dirs behind forever —
        # reap the stale ones. Conservative by construction: never our
        # own pid (this manager's writes are serialized on one worker
        # thread, so ours cannot be mid-write here), never a live local
        # writer's, and never anything younger than STALE_TMP_SECS —
        # pids do not compare across hosts, so for another host's
        # writer age is the only safe signal.
        if not os.path.isdir(self.path):
            return
        now = time.time()
        for n in os.listdir(self.path):
            if not _is_tmp_dir(n):
                continue
            pid = n.rsplit(".", 1)[-1]
            if not pid.isdigit() or int(pid) == os.getpid():
                continue
            path = os.path.join(self.path, n)
            try:
                if now - os.path.getmtime(path) < self.STALE_TMP_SECS:
                    continue              # possibly mid-write elsewhere
                os.kill(int(pid), 0)      # raises if no such local pid
                continue                  # live local writer — keep
            except ProcessLookupError:
                pass                      # dead locally AND stale: reap
            except (PermissionError, OSError):
                continue                  # exists but not ours — keep
            shutil.rmtree(path, ignore_errors=True)

    def save(self, tree, *, step: int, metadata: dict | None = None):
        if self._err:
            raise self._err
        host_tree = jax.tree.map(np.asarray, tree)  # device->host copy now
        if self.async_:
            self._q.put((host_tree, step, metadata))
        else:
            save_checkpoint(self.path, host_tree, step=step,
                            metadata=metadata)
            self._gc()

    def wait(self):
        if self.async_:
            self._q.join()
        if self._err:
            raise self._err

    def latest_step(self) -> int | None:
        steps = available_steps(self.path)
        return steps[-1] if steps else None

    def restore(self, tree_like, *, step: int | None = None):
        return load_checkpoint(self.path, tree_like, step=step)

    def close(self):
        if self.async_ and self._worker:
            self._q.put(None)
            self._worker.join(timeout=30)
