"""Version portability for the two jax APIs this repo leans on.

The repo targets the modern spelling (``jax.shard_map``, explicit
``axis_types`` on ``jax.make_mesh``) but must also run on jax 0.4.x,
where ``shard_map`` lives in ``jax.experimental.shard_map`` and meshes
carry no axis types. Import ``make_mesh`` / ``shard_map`` from here
instead of from ``jax`` directly.

``shard_map`` here always disables the replication checker
(``check_vma=False`` on new jax, ``check_rep=False`` on old): the CAMR
collective bodies call Pallas kernels, which have no replication rule.
"""

from __future__ import annotations

import jax

__all__ = ["make_mesh", "shard_map"]


def make_mesh(axis_shapes, axis_names, **kw):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    axis_shapes, axis_names = tuple(axis_shapes), tuple(axis_names)
    mk = getattr(jax, "make_mesh", None)
    if mk is None:  # jax < 0.4.35: build the Mesh directly
        import numpy as np
        devs = list(kw.pop("devices", None) or jax.devices())
        n = 1
        for s in axis_shapes:
            n *= s
        return jax.sharding.Mesh(
            np.asarray(devs[:n]).reshape(axis_shapes), axis_names)
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return mk(axis_shapes, axis_names,
                      axis_types=(axis_type.Auto,) * len(axis_names), **kw)
        except TypeError:  # make_mesh predates axis_types
            pass
    return mk(axis_shapes, axis_names, **kw)


def shard_map(f, *, mesh, in_specs, out_specs):
    """Portable ``shard_map`` with the replication checker off."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        try:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=False)
        except TypeError:  # older spelling of the flag
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)
