"""Partitioned step builders: jit-ready train/prefill/decode steps with
NamedShardings derived from the logical-axis spec trees.

Used by launch/train.py, launch/serve.py and launch/dryrun.py (which
lowers these with ShapeDtypeStruct inputs — deliverable (e)).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ModelConfig, ShapeSpec, input_specs
from repro.launch import partitioning as pt
from repro.launch.mesh import data_axes
from repro.models import lm
from repro.optim import adamw_init, adamw_update

__all__ = ["StepBundle", "build_train_step", "build_decode_step",
           "build_prefill_step", "build_step"]


@dataclass
class StepBundle:
    """Everything the launcher/dry-run needs for one (cfg, shape) cell."""
    fn: Callable                 # jitted
    args: tuple                  # ShapeDtypeStructs (dry-run) or arrays
    mesh: Any
    donate: tuple = ()


def _shard(mesh, spec_tree):
    return pt.tree_shardings(spec_tree)


def _sanitize(sh_tree, avals_tree, mesh):
    """Drop sharding axes that do not divide the dimension (e.g. batch=1
    in long_500k, kv heads < model axis)."""
    def one(sh, av):
        spec = tuple(sh.spec) + (None,) * (len(av.shape) - len(sh.spec))
        parts = []
        for dim, p in zip(av.shape, spec):
            if p is None:
                parts.append(None)
                continue
            names = p if isinstance(p, tuple) else (p,)
            n = 1
            for a in names:
                n *= mesh.shape[a]
            parts.append(p if dim % n == 0 else None)
        return NamedSharding(mesh, P(*parts))
    return jax.tree.map(one, sh_tree, avals_tree)


def _batch_axes(mesh, global_batch: int):
    """Batch partition axes, or None when the batch cannot shard evenly
    (e.g. long_500k's global_batch=1 -> model-parallel only)."""
    ba = data_axes(mesh)
    n = 1
    for a in ba:
        n *= mesh.shape[a]
    if global_batch % n:
        return None
    return ba if len(ba) > 1 else ba[0]


def _batch_sharding(mesh, batch_specs, global_batch: int):
    ba = _batch_axes(mesh, global_batch)

    def one(leaf):
        nd = len(leaf.shape)
        return NamedSharding(mesh, P(ba, *([None] * (nd - 1))))
    return jax.tree.map(one, batch_specs)


def build_train_step(cfg: ModelConfig, mesh, shape: ShapeSpec,
                     lr: float = 3e-4):
    """train_step(params, opt, batch) -> (params, opt, metrics)."""
    nmb = cfg.microbatches
    pspecs_for_grads = lm.param_specs(cfg)

    def _constrain_grads(grads):
        # pin gradients to the parameter sharding so GSPMD reduce-
        # scatters them over the FSDP axis instead of all-reducing
        # (EXPERIMENTS.md §Perf: 4x wire reduction on the grad path).
        # grad_sync_dtype=bfloat16 casts BEFORE the reduction -> the
        # wire carries half the bytes (compressed gradient sync).
        gdt = jnp.dtype(cfg.grad_sync_dtype)
        flat_g, tdef = jax.tree.flatten(grads)
        flat_s = jax.tree.leaves(
            pspecs_for_grads,
            is_leaf=lambda x: isinstance(x, tuple) or x is None)
        out = [pt.constrain(g.astype(gdt) if g.dtype == jnp.float32
                            else g, tuple(s))
               for g, s in zip(flat_g, flat_s)]
        return jax.tree.unflatten(tdef, out)

    def train_step(params, opt, batch):
        def loss_fn(p, mb):
            return lm.train_loss(cfg, p, mb)[0]

        if nmb == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = _constrain_grads(grads)
        else:
            def split(x):
                return x.reshape(nmb, x.shape[0] // nmb, *x.shape[1:])
            mbs = jax.tree.map(split, batch)

            def acc(carry, mb):
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                g = _constrain_grads(g)
                return (carry[0] + l,
                        jax.tree.map(jnp.add, carry[1], g)), None

            zero = (jnp.zeros(()),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params))
            (loss, grads), _ = jax.lax.scan(
                acc, zero, mbs, unroll=nmb if cfg.scan_unroll else 1)
            loss = loss / nmb
            grads = jax.tree.map(lambda g: g / nmb, grads)
        params, opt, gnorm = adamw_update(params, grads, opt, lr=lr)
        return params, opt, {"loss": loss, "gnorm": gnorm}

    pspecs = lm.param_specs(cfg)
    from repro.optim.adamw import AdamWState
    with pt.axis_rules(mesh, data_axes=data_axes(mesh)):
        p_sh = _shard(mesh, pspecs)
        opt_sh = AdamWState(step=NamedSharding(mesh, P()),
                            mu=_shard(mesh, pspecs),
                            nu=_shard(mesh, pspecs))
        bspecs = input_specs(cfg, shape)["batch"]
        b_sh = _batch_sharding(mesh, bspecs, shape.global_batch)
        out_sh = (p_sh, opt_sh, {"loss": NamedSharding(mesh, P()),
                                 "gnorm": NamedSharding(mesh, P())})
        fn = jax.jit(
            _with_rules(train_step, mesh, data_axes(mesh)),
            in_shardings=(p_sh, opt_sh, b_sh),
            out_shardings=out_sh,
            donate_argnums=(0, 1))
    # argument avals
    params_avals = jax.eval_shape(
        functools.partial(lm.init_params, cfg), jax.random.PRNGKey(0))
    opt_avals = jax.eval_shape(adamw_init, params_avals)
    return StepBundle(fn=fn, args=(params_avals, opt_avals, bspecs),
                      mesh=mesh)


def _with_rules(f, mesh, daxes):
    """Re-enter the axis-rules context inside the traced function so
    constrain() calls in the model resolve (tracing happens at lower())."""
    @functools.wraps(f)
    def g(*a, **k):
        with pt.axis_rules(mesh, data_axes=daxes):
            return f(*a, **k)
    return g


def build_prefill_step(cfg: ModelConfig, mesh, shape: ShapeSpec):
    def prefill_step(params, batch):
        return lm.prefill(cfg, params, batch)

    pspecs = lm.param_specs(cfg)
    with pt.axis_rules(mesh, data_axes=data_axes(mesh)):
        p_sh = _shard(mesh, pspecs)
        spec = input_specs(cfg, shape)
        b_sh = _batch_sharding(mesh, spec["batch"], shape.global_batch)
        cache_avals = jax.eval_shape(
            lambda: lm.init_cache(cfg, shape.global_batch, shape.seq_len))
        cache_sh = _sanitize(_shard(mesh, lm.cache_specs(cfg)),
                             cache_avals, mesh)
        ba = _batch_axes(mesh, shape.global_batch)
        logits_sh = NamedSharding(mesh, P(ba, None, "model"))
        fn = jax.jit(_with_rules(prefill_step, mesh, data_axes(mesh)),
                     in_shardings=(p_sh, b_sh),
                     out_shardings=(logits_sh, cache_sh))
    params_avals = jax.eval_shape(
        functools.partial(lm.init_params, cfg), jax.random.PRNGKey(0))
    return StepBundle(fn=fn, args=(params_avals, spec["batch"]), mesh=mesh)


def build_decode_step(cfg: ModelConfig, mesh, shape: ShapeSpec):
    def decode(params, cache, tokens, cache_index):
        return lm.decode_step(cfg, params, cache, tokens, cache_index)

    pspecs = lm.param_specs(cfg)
    with pt.axis_rules(mesh, data_axes=data_axes(mesh)):
        p_sh = _shard(mesh, pspecs)
        spec = input_specs(cfg, shape)
        cache_sh = _sanitize(_shard(mesh, lm.cache_specs(cfg)),
                             spec["cache"], mesh)
        ba = _batch_axes(mesh, shape.global_batch)
        tok_sh = NamedSharding(mesh, P(ba, None))
        idx_sh = NamedSharding(mesh, P())
        logits_sh = NamedSharding(mesh, P(ba, None, "model"))
        fn = jax.jit(_with_rules(decode, mesh, data_axes(mesh)),
                     in_shardings=(p_sh, cache_sh, tok_sh, idx_sh),
                     out_shardings=(logits_sh, cache_sh),
                     donate_argnums=(1,))
    params_avals = jax.eval_shape(
        functools.partial(lm.init_params, cfg), jax.random.PRNGKey(0))
    return StepBundle(
        fn=fn, args=(params_avals, spec["cache"], spec["tokens"],
                     spec["cache_index"]), mesh=mesh, donate=(1,))


def build_step(cfg: ModelConfig, mesh, shape: ShapeSpec) -> StepBundle:
    if shape.kind == "train":
        return build_train_step(cfg, mesh, shape)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, mesh, shape)
    if shape.kind == "decode":
        return build_decode_step(cfg, mesh, shape)
    raise ValueError(shape.kind)
