"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before calling.

Multi-host (DESIGN.md §16): :func:`init_distributed` brings up
``jax.distributed`` (gloo CPU collectives when running multi-process on
CPU), :func:`make_camr_mesh` builds the 1-D CAMR device axis over the
GLOBAL device list in the class-major host-block order the two-level
lowering assumes (host of device ``s`` = ``s // (K/hosts)``), and
:func:`detect_topology` derives a :class:`~repro.core.schedule.Topology`
from the process layout.
"""

from __future__ import annotations

import jax

from repro.compat import make_mesh
from repro.core.schedule import Topology

__all__ = ["make_production_mesh", "data_axes", "mesh_devices",
           "init_distributed", "make_camr_mesh", "detect_topology",
           "host_membership"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) ('data', 'model') = 256 chips.
    Multi-pod: (2, 16, 16) ('pod', 'data', 'model') = 512 chips; the
    'pod' axis carries only data parallelism + cross-pod gradient
    reduction (DCN-friendly), never layer-internal collectives."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def data_axes(mesh) -> tuple[str, ...]:
    """Mesh axes carrying the batch dimension."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def mesh_devices(mesh) -> int:
    return mesh.devices.size


# --------------------------------------------------------------------- #
# multi-host execution (DESIGN.md §16)
# --------------------------------------------------------------------- #
def init_distributed(*, coordinator: str | None = None,
                     num_processes: int | None = None,
                     process_id: int | None = None) -> bool:
    """Bring up ``jax.distributed`` for multi-process execution.

    On a CPU backend, multi-process collectives need a cross-host
    implementation — request gloo before initialize (a no-op on jax
    builds without the option). Returns True when the distributed
    runtime is (now) initialized, False when this build/environment
    cannot (single-process fallback) — callers degrade to the flat
    single-process lane rather than crash, and the subprocess smoke
    test (tests/test_distributed.py) skips on False.

    MUST run before anything touches a backend: ``initialize`` rejects
    an already-materialized XLA client, so this function deliberately
    avoids ``jax.default_backend()`` / ``jax.process_count()`` on the
    init path (both instantiate the backend) and gates purely on
    exceptions.
    """
    try:
        # only meaningful for CPU backends; setting it is side-effect
        # free elsewhere and must NOT query the backend to find out
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except Exception:
        pass                             # older jax: option absent
    try:
        jax.distributed.initialize(coordinator_address=coordinator,
                                   num_processes=num_processes,
                                   process_id=process_id)
        return True
    except RuntimeError:
        # already initialized (e.g. by the launcher): report what is
        return jax.process_count() > 1
    except Exception:
        return False


def make_camr_mesh(K: int, *, axis_name: str = "camr"):
    """The 1-D CAMR mesh over the GLOBAL device list (all processes).

    ``jax.devices()`` orders devices process-major, which IS the
    class-major host-block order the two-level lowering assumes: with
    ``dph`` local devices per process, device ``s`` lives on host
    ``s // dph`` — exactly ``Topology.host_of``. Built through the
    ``compat`` shim like every other mesh in the repo.
    """
    devs = jax.devices()
    if len(devs) < K:
        raise ValueError(f"need {K} devices for the CAMR axis, have "
                         f"{len(devs)} (set XLA_FLAGS="
                         f"--xla_force_host_platform_device_count)")
    return make_mesh((K,), (axis_name,), devices=devs[:K])


def detect_topology(k: int, *, alpha: float = 4.0) -> Topology:
    """Topology implied by the process layout: ``jax.process_count()``
    hosts when that divides ``k`` (two-level, class-major blocks),
    otherwise flat. ``alpha`` is the modeled inter/intra cost ratio for
    the per-edge accounting — it never changes the executed values.
    """
    hosts = jax.process_count()
    if hosts > 1 and k % hosts == 0:
        return Topology.two_level(hosts, alpha=alpha)
    return Topology.flat()


def host_membership(q: int, k: int, *, alpha: float = 4.0,
                    max_failed_hosts: int | None = None):
    """The launch-time fault-domain tracker for this process layout
    (DESIGN.md §17), or ``None`` when the layout is flat (no host
    blocks to lose). Feed ``kill_host``/``current_topology`` into
    ``ShuffleStream.set_topology`` on the recovery path; pre-pay the
    survivor lowerings with ``ShuffleStream.warm_host_survivors``.
    """
    from repro.runtime.fault import HostMembership
    topo = detect_topology(k, alpha=alpha)
    if topo.is_flat:
        return None
    return HostMembership(q, k, topo,
                          max_failed_hosts=max_failed_hosts)
