"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before calling.
"""

from __future__ import annotations

import jax

from repro.compat import make_mesh

__all__ = ["make_production_mesh", "data_axes", "mesh_devices"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) ('data', 'model') = 256 chips.
    Multi-pod: (2, 16, 16) ('pod', 'data', 'model') = 512 chips; the
    'pod' axis carries only data parallelism + cross-pod gradient
    reduction (DCN-friendly), never layer-internal collectives."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def data_axes(mesh) -> tuple[str, ...]:
    """Mesh axes carrying the batch dimension."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def mesh_devices(mesh) -> int:
    return mesh.devices.size
