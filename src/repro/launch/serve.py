"""Serving launcher — the multi-tenant front door.

Default path is the continuous-batching :class:`DecodeEngine` +
:class:`ServeStream` (one engine per arch, requests interleaved across
waves); ``--legacy`` falls back to the host-loop ``serve_legacy`` path,
which also serves frontend (vit/audio) and enc-dec configs the engine
does not support. BOTH paths run the self-healing policy knobs of
DESIGN.md §15 — per-request deadlines, bounded admission with
load-shedding and (engine path) supervised wave retry — and report the
same terminal-status taxonomy.

    # one model, engine path
    PYTHONPATH=src python -m repro.launch.serve --archs gemma2_2b \
        --reduced --requests 8 --max-new 16

    # multi-tenant: two models share the stream
    PYTHONPATH=src python -m repro.launch.serve \
        --archs gemma2_2b,granite_3_2b --reduced --requests 8

    # self-healing policy: deadlines + bounded queue + wave retry
    PYTHONPATH=src python -m repro.launch.serve --archs gemma2_2b \
        --reduced --requests 16 --deadline-s 5 --max-queue 8 \
        --wave-timeout-s 30 --max-retries 2

    # legacy static-batch host loop (same status accounting)
    PYTHONPATH=src python -m repro.launch.serve --archs gemma2_2b \
        --reduced --legacy --requests 4
"""

from __future__ import annotations

import argparse
import time
from collections import Counter

import numpy as np

import jax

from repro.configs import ARCHS, get_config, reduced
from repro.models import lm
from repro.runtime.serve import (DecodeEngine, Request, ServeStream,
                                 serve_legacy)


def _percentile(xs, q):
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else 0.0


def _status_line(results) -> str:
    counts = Counter(r.status for r in results)
    return " ".join(f"{k}={v}" for k, v in sorted(counts.items()))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", required=True,
                    help="comma-separated arch names (multi-tenant when "
                         "more than one)")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8,
                    help="requests per arch")
    ap.add_argument("--prompt-len", type=int, default=16,
                    help="max prompt length (ragged: 1..prompt-len)")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--eos", type=int, default=None)
    ap.add_argument("--legacy", action="store_true",
                    help="host-loop serve_legacy() instead of the engine")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--wave", type=int, default=8)
    # self-healing policy knobs (DESIGN.md §15)
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request wall-clock budget; past it the "
                         "request terminates 'expired' with its clean "
                         "prefix")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bounded admission queue per model; overflow "
                         "is load-shed at submission")
    ap.add_argument("--shed-policy", choices=("newest", "oldest"),
                    default="newest")
    ap.add_argument("--wave-timeout-s", type=float, default=None,
                    help="a wave slower than this is discarded and "
                         "replayed from the snapshot (engine path)")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="wave retry budget before giving up")
    ap.add_argument("--retry-backoff-s", type=float, default=0.0,
                    help="base backoff between wave retries (doubles "
                         "per attempt)")
    args = ap.parse_args()

    names = [a.strip() for a in args.archs.split(",") if a.strip()]
    for a in names:
        if a not in ARCHS:
            ap.error(f"unknown arch {a!r} (choose from {ARCHS})")
    rng = np.random.default_rng(0)

    cfgs, params = {}, {}
    for a in names:
        cfg = get_config(a)
        cfgs[a] = reduced(cfg) if args.reduced else cfg
        params[a] = lm.init_params(cfgs[a], jax.random.PRNGKey(0))

    def prompts_for(a):
        cfg = cfgs[a]
        out = []
        for _ in range(args.requests):
            T = int(rng.integers(1, args.prompt_len + 1))
            out.append(rng.integers(0, cfg.vocab, (T,)).astype(np.int32))
        return out

    def requests_for(a):
        return [Request(prompt=p, max_new=args.max_new, eos=args.eos,
                        temperature=args.temperature, seed=i,
                        deadline_s=args.deadline_s)
                for i, p in enumerate(prompts_for(a))]

    if args.legacy:
        total = tot_time = 0
        all_results = []
        for a in names:
            cfg = cfgs[a]
            extras = {}
            if cfg.frontend == "vit":
                extras["patches"] = rng.standard_normal(
                    (1, cfg.frontend_len, cfg.frontend_dim)).astype(
                    np.float32)
            if cfg.frontend == "audio":
                extras["frames"] = rng.standard_normal(
                    (1, args.prompt_len, cfg.frontend_dim)).astype(
                    np.float32)
            t0 = time.perf_counter()
            results = serve_legacy(cfg, params[a], requests_for(a),
                                   max_queue=args.max_queue,
                                   shed_policy=args.shed_policy,
                                   extras=extras or None, model=a)
            dt = time.perf_counter() - t0
            tot_time += dt
            toks = sum(r.emitted for r in results)
            total += toks
            all_results.extend(results)
            print(f"{a}: {args.requests} reqs (legacy host loop) "
                  f"{toks} tokens in {dt:.2f}s, "
                  f"status: {_status_line(results)}")
        print(f"legacy: {total} tokens in {tot_time:.2f}s "
              f"({total / max(tot_time, 1e-9):.1f} tok/s), "
              f"status: {_status_line(all_results)}")
        return

    engines = {}
    for a in names:
        if cfgs[a].family == "encdec" or cfgs[a].frontend:
            ap.error(f"{a}: enc-dec/frontend archs need --legacy")
        max_ctx = args.prompt_len + args.max_new
        engines[a] = DecodeEngine(
            cfgs[a], params[a], slots=args.slots,
            page_size=args.page_size, max_ctx=max_ctx,
            max_new_cap=args.max_new, name=a)
    stream = ServeStream(engines, wave_len=args.wave,
                         max_queue=args.max_queue,
                         shed_policy=args.shed_policy,
                         wave_timeout_s=args.wave_timeout_s,
                         max_retries=args.max_retries,
                         retry_backoff_s=args.retry_backoff_s)
    jobs = [(a, req) for a in names for req in requests_for(a)]
    t0 = time.perf_counter()
    results = stream.run(jobs)
    dt = time.perf_counter() - t0
    rep = stream.last_report
    toks = sum(r.emitted for r in results)
    per_tok = [s[1] / max(1, s[2]) for s in rep.wave_stats]
    print(f"engine: {len(results)} reqs / {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s), {rep.waves} waves, "
          f"occupancy {rep.occupancy:.2f}, "
          f"step p50={1e3 * _percentile(per_tok, 50):.2f}ms "
          f"p99={1e3 * _percentile(per_tok, 99):.2f}ms, "
          f"traces during run: {rep.traces}")
    print(f"status: {_status_line(results)}, wave retries: "
          f"{rep.retries}, recovery {rep.recovery_s * 1e3:.1f}ms")
    for r in results[:4]:
        print(f"  [{r.model}#{r.index}] +{r.emitted} ({r.status}): "
              f"{r.generated}")


if __name__ == "__main__":
    main()
