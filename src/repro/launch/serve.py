"""Serving launcher: batched generation with a reduced config on CPU or
the full config on a real pod.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2_2b \
        --reduced --batch 4 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from repro.configs import ARCHS, get_config, reduced
from repro.models import lm
from repro.runtime.serve import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab,
                           (args.batch, args.prompt_len)).astype(np.int32)
    extras = {}
    if cfg.frontend == "vit":
        extras["patches"] = rng.standard_normal(
            (args.batch, cfg.frontend_len, cfg.frontend_dim)).astype(
            np.float32)
    if cfg.frontend == "audio":
        extras["frames"] = rng.standard_normal(
            (args.batch, args.prompt_len, cfg.frontend_dim)).astype(
            np.float32)
    t0 = time.time()
    res = generate(cfg, params, prompts, max_new=args.max_new,
                   temperature=args.temperature, extras=extras or None)
    dt = time.time() - t0
    print(f"generated {res.steps} tokens x {args.batch} seqs "
          f"in {dt:.2f}s ({res.steps * args.batch / dt:.1f} tok/s)")
    print(res.tokens[:, args.prompt_len:])


if __name__ == "__main__":
    main()
