"""Training launcher.

On a real TPU pod every host runs this same script (jax.distributed
initializes from the TPU environment); on CPU it runs a reduced config.

    PYTHONPATH=src python -m repro.launch.train --arch granite_3_2b \
        --steps 100 --reduced --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import json
import time

import jax

from repro.configs import ARCHS, SHAPES, get_config, reduced
from repro.data.pipeline import ShardedTokenPipeline
from repro.runtime import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized same-family config")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-sync", choices=["allreduce", "camr"],
                    default="allreduce")
    args = ap.parse_args()

    if jax.process_count() > 1:  # multi-host pod
        jax.distributed.initialize()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    cfg = cfg.replace(grad_sync=args.grad_sync)
    pipe = ShardedTokenPipeline(vocab=cfg.vocab, seq_len=args.seq_len,
                                global_batch=args.batch)
    tr = Trainer(cfg, lr=args.lr, total_steps=args.steps,
                 ckpt_dir=args.ckpt_dir)
    if args.resume:
        if tr.resume():
            print(f"resumed from step {tr.step}")
    t0 = time.time()
    metrics = tr.run(pipe, steps=args.steps, ckpt_every=args.ckpt_every
                     if args.ckpt_dir else 0)
    dt = time.time() - t0
    for m in metrics:
        print(json.dumps(m))
    print(f"# {args.steps} steps in {dt:.1f}s "
          f"({args.steps / dt:.2f} steps/s)")


if __name__ == "__main__":
    main()
