"""Training launcher.

On a real TPU pod every host runs this same script (jax.distributed
initializes from the TPU environment); on CPU it runs a reduced config.

    PYTHONPATH=src python -m repro.launch.train --arch granite_3_2b \
        --steps 100 --reduced --ckpt-dir /tmp/ckpt

``--multi-model`` switches to the paper's J = q^{k-1}-models setting
(:class:`repro.runtime.MultiModelCAMRTrainer`): ``--grad-sync camr``
runs the numpy-engine interpreter, ``camr_spmd`` the device-resident
SPMD fused-codec shuffle (needs a K = q*k device mesh — on CPU set
``XLA_FLAGS=--xla_force_host_platform_device_count=K``), ``uncoded``
the unicast baseline. All three produce bit-identical parameters.

    PYTHONPATH=src python -m repro.launch.train --arch granite_3_2b \
        --reduced --multi-model --q 2 --k 3 --grad-sync camr_spmd \
        --steps 3

``--grad-sync-dtype bfloat16`` (multi-model only) switches the shuffle
payload to the packed 16-bit codec lane — half the bytes-on-wire, f32
master params/moments (DESIGN.md §12).
"""

from __future__ import annotations

import argparse
import json
import time

import jax

from repro.configs import ARCHS, SHAPES, get_config, reduced
from repro.data.pipeline import ShardedTokenPipeline
from repro.runtime import MultiModelCAMRTrainer, Trainer


def _run_multi_model(cfg, args) -> None:
    if args.grad_sync == "allreduce":
        raise SystemExit("--multi-model needs --grad-sync "
                         "camr|camr_spmd|uncoded (allreduce is the "
                         "single-model data-parallel wire)")
    pipe = ShardedTokenPipeline(vocab=cfg.vocab, seq_len=args.seq_len,
                                global_batch=args.batch)
    failed = ({int(s) for s in args.failed.split(",")}
              if args.failed else None)
    tr = MultiModelCAMRTrainer(cfg, q=args.q, k=args.k, lr=args.lr,
                               failed=failed,
                               grad_sync_dtype=args.grad_sync_dtype)
    t0 = time.time()
    rep = tr.train_steps(pipe, args.steps, mode=args.grad_sync)
    dt = time.time() - t0
    for step, losses in enumerate(rep.losses):
        print(json.dumps({"step": step + 1, "losses": losses}))
    print(json.dumps({"mode": rep.mode, "bytes_total": rep.bytes_total,
                      "grad_sync_dtype": rep.grad_sync_dtype,
                      "loads": rep.loads, "sync": rep.sync}))
    print(f"# {args.steps} steps x {tr.camr.J} models in {dt:.1f}s "
          f"({args.steps / dt:.2f} steps/s)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized same-family config")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-sync",
                    choices=["allreduce", "camr", "camr_spmd", "uncoded"],
                    default="allreduce")
    ap.add_argument("--grad-sync-dtype",
                    choices=["float32", "bfloat16"], default="float32",
                    help="gradient shuffle payload dtype: bfloat16 syncs "
                         "on the packed 16-bit codec lane at half the "
                         "bytes-on-wire, with f32 master params/moments "
                         "(DESIGN.md §12; float16 is rejected by the "
                         "trainer — no loss scaling)")
    ap.add_argument("--multi-model", action="store_true",
                    help="train J = q^(k-1) models with CAMR-coded "
                         "gradient aggregation")
    ap.add_argument("--q", type=int, default=2)
    ap.add_argument("--k", type=int, default=3)
    ap.add_argument("--failed", default=None,
                    help="comma-separated failed worker ids (degraded "
                         "survivor-set schedule; --grad-sync camr only)")
    args = ap.parse_args()

    if jax.process_count() > 1:  # multi-host pod
        jax.distributed.initialize()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if args.multi_model:
        _run_multi_model(cfg, args)
        return
    if args.grad_sync in ("camr_spmd", "uncoded"):
        raise SystemExit(f"--grad-sync {args.grad_sync} is a "
                         "--multi-model wire; the single-model loop "
                         "takes allreduce|camr")
    if args.grad_sync_dtype != "float32":
        raise SystemExit("--grad-sync-dtype is a --multi-model option "
                         "(the compressed CAMR gradient shuffle); the "
                         "single-model loop reduces at float32")
    cfg = cfg.replace(grad_sync=args.grad_sync)
    pipe = ShardedTokenPipeline(vocab=cfg.vocab, seq_len=args.seq_len,
                                global_batch=args.batch)
    tr = Trainer(cfg, lr=args.lr, total_steps=args.steps,
                 ckpt_dir=args.ckpt_dir)
    if args.resume:
        if tr.resume():
            print(f"resumed from step {tr.step}")
    t0 = time.time()
    metrics = tr.run(pipe, steps=args.steps, ckpt_every=args.ckpt_every
                     if args.ckpt_dir else 0)
    dt = time.time() - t0
    for m in metrics:
        print(json.dumps(m))
    print(f"# {args.steps} steps in {dt:.1f}s "
          f"({args.steps / dt:.2f} steps/s)")


if __name__ == "__main__":
    main()
