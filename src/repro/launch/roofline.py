"""Roofline analysis from the dry-run artifacts — deliverable (g).

Three terms per (arch x shape x mesh), in seconds per step (TPU v5e):

    compute    = HLO_FLOPs / (chips x 197e12 bf16 FLOP/s)
    memory     = HLO_bytes / (chips x 819e9 B/s HBM)
    collective = collective_wire_bytes / (chips x 50e9 B/s ICI per link
                 x links_used)

HLO numbers come from the trip-true (unrolled) cost pass of
launch/dryrun.py; collective bytes from the optimized-HLO parse. All
dry-run numbers are per-device already (SPMD module), so the per-chip
roofline divides by the peak of ONE chip; `chips` appears only in the
MODEL_FLOPS utilization line.
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass

from repro.configs import SHAPES, get_config

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s / chip
ICI_BW = 50e9              # B/s / link; v5e: 4 links usable per chip
ICI_LINKS = 4

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    devices: int
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float          # 6*N*D (active params for MoE)
    hlo_flops_dev: float
    hbm_gib: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Max-term model (perfect overlap of the other two)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / total HLO FLOPs — remat/redundancy waste."""
        total = self.hlo_flops_dev * self.devices
        return self.model_flops / total if total else float("nan")

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline step time."""
        if self.step_time_s == 0:
            return float("nan")
        return (self.model_flops
                / (self.devices * PEAK_FLOPS * self.step_time_s))

    @property
    def roofline_fraction(self) -> float:
        """compute_term / step_time — 1.0 when compute-bound (the score
        §Perf pushes up)."""
        return self.compute_s / self.step_time_s if self.step_time_s else 0


def model_flops(arch: str, shape_name: str) -> float:
    """6*N*D for train (fwd+bwd); 2*N*D for inference steps."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def load_cell(arch: str, shape: str, mesh: str, suffix: str = "") -> dict:
    fn = os.path.join(RESULTS_DIR, f"{arch}_{shape}_{mesh}{suffix}.json")
    with open(fn) as f:
        return json.load(f)


def roofline_from_cell(cell: dict, cost_cell: dict | None = None
                       ) -> Roofline:
    """cell: the scanned dry-run (memory truth); cost_cell: the unrolled
    cost pass (flops/collective truth; falls back to `cell`)."""
    cc = cost_cell or cell
    dev = cell["devices"]
    flops_dev = cc["cost"]["flops"]
    bytes_dev = cc["cost"]["bytes_accessed"]
    wire_dev = cc["collectives"]["wire_bytes"]
    mem = cell["memory"]
    hbm = (mem["argument_bytes"] + mem["temp_bytes"] + mem["output_bytes"]
           - mem["alias_bytes"]) / 2 ** 30
    return Roofline(
        arch=cell["arch"], shape=cell["shape"], mesh=cell["mesh"],
        devices=dev,
        compute_s=flops_dev / PEAK_FLOPS,
        memory_s=bytes_dev / HBM_BW,
        collective_s=wire_dev / (ICI_BW * ICI_LINKS),
        model_flops=model_flops(cell["arch"], cell["shape"]),
        hlo_flops_dev=flops_dev,
        hbm_gib=hbm,
    )


def table(mesh: str = "single") -> list[Roofline]:
    out = []
    for fn in sorted(os.listdir(RESULTS_DIR)):
        if not fn.endswith(f"_{mesh}.json"):
            continue
        with open(os.path.join(RESULTS_DIR, fn)) as f:
            cell = json.load(f)
        if cell.get("status") != "ok":
            continue
        cost = None
        cfn = os.path.join(RESULTS_DIR, fn.replace(".json", "_cost.json"))
        if os.path.exists(cfn):
            with open(cfn) as f:
                cost = json.load(f)
            if cost.get("status") != "ok":
                cost = None
        out.append(roofline_from_cell(cell, cost))
    return out


def main():
    rows = table()
    hdr = (f"{'arch':24s} {'shape':12s} {'comp_s':>8s} {'mem_s':>8s} "
           f"{'coll_s':>8s} {'dom':>10s} {'MFU':>6s} {'useful':>7s} "
           f"{'HBM':>7s}")
    print(hdr)
    for r in rows:
        print(f"{r.arch:24s} {r.shape:12s} {r.compute_s:8.4f} "
              f"{r.memory_s:8.4f} {r.collective_s:8.4f} {r.dominant:>10s} "
              f"{r.mfu:6.1%} {r.useful_flops_ratio:7.2f} "
              f"{r.hbm_gib:6.1f}G")


if __name__ == "__main__":
    main()
