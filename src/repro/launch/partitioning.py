"""Logical-axis partitioning: maps model-level axis names to mesh axes.

Params and activations are annotated with *logical* axes ('embed', 'ffn',
'heads', 'batch', 'seq', ...); a rule set maps them to mesh axes. The
launcher activates (mesh, rules) via :func:`axis_rules`; outside that
context every annotation is a no-op, so models run unchanged on CPU.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

# default rule set for the production (16, 16) mesh ('data', 'model'),
# extended with a leading 'pod' axis for the multi-pod mesh.
DEFAULT_RULES: dict[str, Any] = {
    "batch": ("data",),       # data parallel (pod axis prepended if present)
    "seq": ("model",),        # sequence-parallel residual stream between
    #                           blocks (Megatron-SP; 16x smaller saved
    #                           activations — see EXPERIMENTS §Perf)
    "embed": None,            # residual feature dim replicated over model
    "fsdp": ("data",),        # parameter FSDP shard
    "ffn": ("model",),        # tensor parallel
    "heads": ("model",),
    "kv": ("model",),
    "vocab": ("model",),
    "experts": ("model",),    # expert parallel
    "ssm_in": ("model",),
    "ssm_heads": ("model",),
    "seq_kv": ("model",),     # KV-cache sequence dim (flash-decode)
    "state": None,
}


def no_seq_parallel_rules() -> dict[str, Any]:
    """Ablation: residual stream replicated over 'model' between blocks
    (the §Perf baseline-vs-SP comparison)."""
    rules = dict(DEFAULT_RULES)
    rules["seq"] = None
    return rules


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: dict[str, Any] | None = None,
               data_axes: tuple[str, ...] = ("data",)):
    """Activate logical->mesh mapping. ``data_axes`` lets multi-pod meshes
    map 'batch'/'fsdp' to ('pod', 'data')."""
    rules = dict(rules or DEFAULT_RULES)
    if data_axes != ("data",):
        rules["batch"] = data_axes
        rules["fsdp"] = ("data",)  # FSDP stays within-pod (DESIGN.md §3)
    prev = getattr(_state, "ctx", None)
    _state.ctx = (mesh, rules)
    try:
        yield
    finally:
        _state.ctx = prev


def current_mesh() -> Mesh | None:
    ctx = getattr(_state, "ctx", None)
    return ctx[0] if ctx else None


def logical_to_spec(axes: tuple) -> P:
    """Translate a tuple of logical axis names to a PartitionSpec."""
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return P()
    _, rules = ctx
    parts = []
    for ax in axes:
        r = rules.get(ax) if ax is not None else None
        if r is None:
            parts.append(None)
        else:
            parts.append(r if len(r) > 1 else r[0])
    return P(*parts)


def constrain(x, axes: tuple):
    """with_sharding_constraint by logical axes; no-op without a context.

    Axes whose dimension does not divide the mesh axes are dropped
    (e.g. seq=1 in decode cannot be sequence-parallel)."""
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return x
    mesh, _ = ctx
    spec = logical_to_spec(axes)
    parts = []
    for dim, p in zip(x.shape, spec):
        if p is None:
            parts.append(None)
            continue
        names = p if isinstance(p, tuple) else (p,)
        n = 1
        for a in names:
            n *= mesh.shape[a]
        parts.append(p if dim % n == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*parts)))


def named_sharding(axes: tuple) -> NamedSharding | None:
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return None
    mesh, _ = ctx
    return NamedSharding(mesh, logical_to_spec(axes))


def tree_shardings(spec_tree, extra_leading: int = 0):
    """Map a tree of logical-axis tuples to NamedShardings.

    ``extra_leading`` prepends unsharded dims (e.g. the scan/stack axis of
    layer params)."""
    def one(axes):
        if axes is None:
            return named_sharding(())
        return named_sharding((None,) * extra_leading + tuple(axes))
    return jax.tree.map(one, spec_tree,
                        is_leaf=lambda x: isinstance(x, tuple) or x is None)
