"""Parse collective traffic out of optimized HLO text.

``compiled.cost_analysis()`` has FLOPs and HBM bytes but NOT collective
bytes, so we sum the result-shape sizes of every collective op in the
optimized module (per-device numbers, since SPMD modules are
per-device). all-gather results count at full (post-gather) size; the
per-device on-wire traffic of a ring all-gather of output size S is
S * (n-1)/n ≈ S, so result size is the right first-order wire proxy;
all-reduce moves ~2x its buffer in a ring — tracked via per-kind counts
so the roofline can weight kinds differently.
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

__all__ = ["CollectiveStats", "collective_stats"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# result type of an HLO line: `%name = TYPE opname(...)`; TYPE may be a
# tuple `(f32[...], u32[...])`.
_LINE = re.compile(
    r"=\s*(?P<ty>\([^)]*\)|[a-z0-9]+\[[^\]]*\][^ ]*)\s*"
    r"(?P<op>[a-z0-9\-]+)\(")
_SHAPE = re.compile(r"(?P<dt>[a-z0-9]+)\[(?P<dims>[0-9,]*)\]")


def _shape_bytes(ty: str) -> int:
    total = 0
    for m in _SHAPE.finditer(ty):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def wire_bytes(self) -> int:
        """On-wire estimate: all-reduce rings move ~2x their buffer."""
        t = 0
        for kind, b in self.bytes_by_kind.items():
            t += 2 * b if kind == "all-reduce" else b
        return t

    def as_dict(self) -> dict:
        return {"bytes_by_kind": dict(self.bytes_by_kind),
                "count_by_kind": dict(self.count_by_kind),
                "total_bytes": self.total_bytes,
                "wire_bytes": self.wire_bytes}


def collective_stats(hlo_text: str) -> CollectiveStats:
    by = defaultdict(int)
    cnt = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _LINE.search(line)
        if not m:
            continue
        op = m.group("op")
        # normalize fusions like all-gather-start / all-reduce-done
        for kind in _COLLECTIVES:
            if op == kind or op.startswith(kind + "-start"):
                by[kind] += _shape_bytes(m.group("ty"))
                cnt[kind] += 1
                break
    return CollectiveStats(bytes_by_kind=dict(by), count_by_kind=dict(cnt))
