import os
if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
# ^ before any jax import.

"""§Perf harness: the paper's technique on the wire.

Lowers + compiles THREE gradient-aggregation schedules for the same
semantic task — deliver the summed gradient shard of each of J jobs to
its reducer on a K-device axis — and parses the collective bytes from the
optimized HLO of each:

  camr      the 3-stage coded shuffle (repro.core.collective)
  uncoded   masked psum + shard slice (same placement, no coding)
  allreduce dense psum of the [J, K, d] gradient block (what a naive
            data-parallel trainer ships)

Also reports the analytic byte model (camr_collective_bytes) so the HLO
parse can be cross-checked.

    PYTHONPATH=src python -m repro.launch.camr_compare --q 4 --k 4 --d 4096
"""

import argparse
import functools
import json

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh, shard_map
from repro.core.collective import (CAMRPlan, camr_collective_bytes,
                                   camr_shuffle, make_plan,
                                   uncoded_reduce_scatter)
from repro.launch.hlo_stats import collective_stats


def lower_schedules(q: int, k: int, d: int) -> dict:
    plan = make_plan(q, k, d)
    K, J, J_own = plan.K, plan.J, plan.J_own
    mesh = make_mesh((K,), ("camr",))
    contribs = jax.ShapeDtypeStruct((K, J_own, k - 1, K, d), jnp.float32)

    def _wire(fn):
        with mesh:
            compiled = jax.jit(fn).lower(contribs).compile()
        st = collective_stats(compiled.as_text())
        return st.wire_bytes, st.count_by_kind

    out = {"q": q, "k": k, "K": K, "J": J, "d": d}

    camr_fn = shard_map(
        lambda c: camr_shuffle(plan, c[0], axis_name="camr")[None],
        mesh=mesh, in_specs=P("camr"), out_specs=P("camr"))
    out["camr_wire"], out["camr_ops"] = _wire(camr_fn)

    unc_fn = shard_map(
        lambda c: uncoded_reduce_scatter(c[0], axis_name="camr",
                                         plan=plan)[None],
        mesh=mesh, in_specs=P("camr"), out_specs=P("camr"))
    out["uncoded_wire"], out["uncoded_ops"] = _wire(unc_fn)

    def allreduce_fn(c):
        # dense data-parallel sync: psum the full [J, K, d] grads, then
        # every device keeps its shard (classic allreduce trainer)
        me = jax.lax.axis_index("camr")
        dense = jnp.zeros((J, K, d), jnp.float32)
        jl = jnp.take(jnp.asarray(plan.owned_jobs), me, axis=0)
        dense = dense.at[jl].add(c[0].sum(axis=1))
        total = jax.lax.psum(dense, "camr")
        return jnp.take(total, me, axis=1)[None]

    ar_fn = shard_map(allreduce_fn, mesh=mesh, in_specs=P("camr"),
                      out_specs=P("camr"))
    out["allreduce_wire"], out["allreduce_ops"] = _wire(ar_fn)

    out["analytic"] = camr_collective_bytes(plan)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--q", type=int, default=4)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--d", type=int, default=4096)
    args = ap.parse_args()
    res = lower_schedules(args.q, args.k, args.d)
    print(json.dumps(res, indent=1, default=str))
    w = {m: res[f"{m}_wire"] for m in ("camr", "uncoded", "allreduce")}
    base = w["allreduce"]
    for m, b in w.items():
        print(f"{m:10s} wire={b / 2**20:9.2f} MiB  "
              f"({b / base:6.3f}x of allreduce)")


if __name__ == "__main__":
    main()
