"""§Perf harness: the paper's technique on the wire.

Lowers + compiles THREE gradient-aggregation schedules for the same
semantic task — deliver the summed gradient shard of each of J jobs to
its reducer on a K-device axis — and parses the collective bytes from the
optimized HLO of each:

  camr      the 3-stage coded shuffle (repro.core.collective)
  uncoded   masked psum + shard slice (same placement, no coding)
  allreduce dense psum of the [J, K, d] gradient block (what a naive
            data-parallel trainer ships)

Also reports the analytic byte model (camr_collective_bytes) so the HLO
parse can be cross-checked.

    PYTHONPATH=src python -m repro.launch.camr_compare --q 4 --k 4 --d 4096

``--stream W`` additionally measures multi-wave throughput: W waves
dispatched serially (block per wave) vs. through the async,
double-buffered :class:`~repro.core.collective.ShuffleStream`
(DESIGN.md §9), with outputs verified against the per-wave oracle.

    PYTHONPATH=src python -m repro.launch.camr_compare --q 2 --k 3 \\
        --d 256 --stream 8
"""

import os
if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
# ^ before any jax import.

import argparse
import functools
import json
import time

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh, shard_map
from repro.core.collective import (CAMRPlan, ShuffleStream,
                                   camr_collective_bytes, camr_edge_bytes,
                                   camr_shuffle, camr_shuffle_reference,
                                   make_plan, scatter_contributions,
                                   uncoded_reduce_scatter)
from repro.core.loads import (camr_edge_loads, camr_load_hierarchical,
                              uncoded_load_hierarchical)
from repro.core.schedule import Topology
from repro.launch.hlo_stats import collective_stats


def lower_schedules(q: int, k: int, d: int, codec: str = "fused",
                    topology: Topology | None = None) -> dict:
    plan = make_plan(q, k, d, topology=topology)
    K, J, J_own = plan.K, plan.J, plan.J_own
    mesh = make_mesh((K,), ("camr",))
    contribs = jax.ShapeDtypeStruct((K, J_own, k - 1, K, d), jnp.float32)

    def _wire(fn):
        with mesh:
            compiled = jax.jit(fn).lower(contribs).compile()
        st = collective_stats(compiled.as_text())
        return st.wire_bytes, st.count_by_kind

    out = {"q": q, "k": k, "K": K, "J": J, "d": d}

    camr_fn = shard_map(
        lambda c: camr_shuffle(plan, c[0], axis_name="camr",
                               codec=codec)[None],
        mesh=mesh, in_specs=P("camr"), out_specs=P("camr"))
    out["camr_wire"], out["camr_ops"] = _wire(camr_fn)

    unc_fn = shard_map(
        lambda c: uncoded_reduce_scatter(c[0], axis_name="camr",
                                         plan=plan)[None],
        mesh=mesh, in_specs=P("camr"), out_specs=P("camr"))
    out["uncoded_wire"], out["uncoded_ops"] = _wire(unc_fn)

    def allreduce_fn(c):
        # dense data-parallel sync: psum the full [J, K, d] grads, then
        # every device keeps its shard (classic allreduce trainer)
        me = jax.lax.axis_index("camr")
        dense = jnp.zeros((J, K, d), jnp.float32)
        jl = jnp.take(jnp.asarray(plan.owned_jobs), me, axis=0)
        dense = dense.at[jl].add(c[0].sum(axis=1))
        total = jax.lax.psum(dense, "camr")
        return jnp.take(total, me, axis=1)[None]

    ar_fn = shard_map(allreduce_fn, mesh=mesh, in_specs=P("camr"),
                      out_specs=P("camr"))
    out["allreduce_wire"], out["allreduce_ops"] = _wire(ar_fn)

    out["analytic"] = camr_collective_bytes(plan)
    if plan.topology is not None:
        # per-edge split on the two-level topology (DESIGN.md §16):
        # measured from the lowered send tables + the closed forms
        topo = plan.topology
        out["topology"] = {"hosts": topo.hosts, "alpha": topo.alpha}
        out["edge_bytes"] = camr_edge_bytes(plan)
        out["edge_loads"] = {
            sched: dict(zip(("intra", "inter"),
                            camr_edge_loads(q, k, topo.hosts,
                                            schedule=sched)))
            for sched in ("flat", "two_level")}
        out["hier_load"] = camr_load_hierarchical(q, k, topo.hosts,
                                                  topo.alpha)
        out["uncoded_hier_load"] = uncoded_load_hierarchical(
            q, k, topo.hosts, topo.alpha)
    return out


def measure_stream(q: int, k: int, d: int, waves: int,
                   wave_batch: int = 2, depth: int = 2,
                   codec: str = "fused", kill_at: int | None = None,
                   rejoin_at: int | None = None,
                   kill_worker: int = 0) -> dict:
    """Serial-dispatch vs. ShuffleStream wall time over ``waves`` waves
    of random contributions (outputs checked against the oracle).

    ``kill_at`` additionally replays the same waves through a churn
    pass: worker ``kill_worker`` is degraded at wave ``kill_at`` (and
    restored at ``rejoin_at``, if given) via the stream's elastic lane
    (DESIGN.md §14). Every churned output must stay BIT-identical to
    the healthy serial oracle, and the compiled executors must survive
    the swap (``compiles`` flat — degrade/restore never retraces)."""
    plan = make_plan(q, k, d)
    K = plan.K
    mesh = make_mesh((K,), ("camr",))
    rng = np.random.default_rng(0)
    bgs = [rng.standard_normal((plan.J, k, K, d)).astype(np.float32)
           for _ in range(waves)]
    contribs = [scatter_contributions(plan, bg) for bg in bgs]

    serial_fn = jax.jit(shard_map(
        lambda c: camr_shuffle(plan, c[0], axis_name="camr",
                               codec=codec)[None],
        mesh=mesh, in_specs=P("camr"), out_specs=P("camr")))
    jax.block_until_ready(serial_fn(contribs[0]))      # compile
    t0 = time.perf_counter()
    serial_out = [np.asarray(jax.block_until_ready(serial_fn(c)))
                  for c in contribs]
    t_serial = time.perf_counter() - t0

    stream = ShuffleStream(q, k, d, mesh=mesh, wave_batch=wave_batch,
                           depth=depth, codec=codec)
    # compile every stack width the timed run will dispatch (full
    # batches of W=wave_batch, plus the trailing partial batch)
    stream.run_waves(contribs[:wave_batch])
    if waves % wave_batch:
        stream.run_waves(contribs[:waves % wave_batch])
    t0 = time.perf_counter()
    outs = stream.run_waves(contribs)
    t_stream = time.perf_counter() - t0

    for out, bg, ser in zip(outs, bgs, serial_out):
        np.testing.assert_allclose(out, camr_shuffle_reference(plan, bg),
                                   rtol=2e-5, atol=2e-6)
        np.testing.assert_array_equal(out, ser)        # bit-identical
    res = dict(waves=waves, wave_batch=wave_batch, depth=depth,
               serial_s=t_serial, stream_s=t_stream,
               speedup=t_serial / t_stream,
               stream_wps=waves / t_stream)

    if kill_at is not None:
        compiles_before = stream.stats()["compiles"]
        for i, c in enumerate(contribs):
            if i == kill_at:
                stream.degrade({kill_worker})
            if rejoin_at is not None and i == rejoin_at:
                stream.restore()
            stream.submit(c)
        churned = stream.drain()
        stream.restore()
        for out, ser in zip(churned, serial_out):
            np.testing.assert_array_equal(out, ser)    # churn contract
        st = stream.stats()
        assert st["compiles"] == compiles_before, \
            "degrade/restore must not retrace the compiled executors"
        res["churn"] = dict(kill_at=kill_at, rejoin_at=rejoin_at,
                            worker=kill_worker, swaps=st["swaps"],
                            compiles=st["compiles"])
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--q", type=int, default=4)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--d", type=int, default=4096)
    ap.add_argument("--stream", type=int, default=0, metavar="W",
                    help="also time W waves: serial dispatch vs "
                         "ShuffleStream (async + d-stacked batching)")
    ap.add_argument("--wave-batch", type=int, default=2)
    ap.add_argument("--kill-at", type=int, default=None, metavar="W",
                    help="with --stream: degrade one worker at wave W "
                         "and replay the stream through the elastic "
                         "lane (outputs stay bit-identical, executors "
                         "stay compiled)")
    ap.add_argument("--rejoin-at", type=int, default=None, metavar="W",
                    help="restore the killed worker at wave W")
    ap.add_argument("--kill-worker", type=int, default=0, metavar="N",
                    help="which worker --kill-at degrades (default 0)")
    ap.add_argument("--codec", choices=("fused", "multipass"),
                    default="fused",
                    help="XOR codec lane (DESIGN.md §10): fused "
                         "single-pass gather kernels vs the multipass "
                         "oracle")
    ap.add_argument("--topology", choices=("flat", "two-level", "auto"),
                    default="flat",
                    help="lowering topology (DESIGN.md §16): two-level "
                         "adds the host-aware gateway/relay schedule "
                         "and per-edge load columns; auto picks "
                         "flat vs two-level from the alpha cost model "
                         "(camr_load_hierarchical vs camr_load_p2p, "
                         "DESIGN.md §17)")
    ap.add_argument("--hosts", type=int, default=2, metavar="N",
                    help="with --topology two-level/auto: host count "
                         "(two-level needs hosts | k; default 2)")
    ap.add_argument("--alpha", type=float, default=4.0, metavar="X",
                    help="modeled inter-host cost per byte relative to "
                         "intra-host (default 4.0)")
    args = ap.parse_args()
    if args.kill_at is not None and not args.stream:
        ap.error("--kill-at needs --stream W (churn replays the "
                 "streamed waves)")
    topology = None
    if args.topology == "two-level":
        topology = Topology.two_level(args.hosts, alpha=args.alpha)
        try:
            topology.check(args.q, args.k)
        except ValueError as e:
            ap.error(str(e))
    elif args.topology == "auto":
        topology = Topology.auto(args.hosts, alpha=args.alpha).resolve(
            args.q, args.k)
        pick = "flat" if topology is None else \
            f"two-level(hosts={topology.hosts})"
        if args.hosts < 2 or args.k % args.hosts:
            why = (f"hosts={args.hosts} does not give class-aligned "
                   f"blocks for k={args.k}")
        else:
            intra_f, inter_f = camr_edge_loads(args.q, args.k,
                                               args.hosts,
                                               schedule="flat")
            flat_cost = intra_f + args.alpha * inter_f
            two_cost = camr_load_hierarchical(args.q, args.k,
                                              args.hosts, args.alpha)
            why = (f"alpha={args.alpha:g}: L_flat={flat_cost:.3f} vs "
                   f"L_two_level={two_cost:.3f}")
        print(f"auto-topology: picked {pick}  [{why}]")
    res = lower_schedules(args.q, args.k, args.d, codec=args.codec,
                          topology=topology)
    print(json.dumps(res, indent=1, default=str))
    w = {m: res[f"{m}_wire"] for m in ("camr", "uncoded", "allreduce")}
    base = w["allreduce"]
    for m, b in w.items():
        print(f"{m:10s} wire={b / 2**20:9.2f} MiB  "
              f"({b / base:6.3f}x of allreduce)")
    if topology is not None:
        eb, el = res["edge_bytes"], res["edge_loads"]
        print(f"edges      hosts={topology.hosts} alpha={topology.alpha:g}"
              f"  L_hier={res['hier_load']:.3f}"
              f"  (uncoded {res['uncoded_hier_load']:.3f})")
        for sched in ("flat", "two_level"):
            print(f"  {sched:9s} inter={eb[f'{sched}_inter_bytes']:>12,}B"
                  f" (L={el[sched]['inter']:.3f})"
                  f"  intra={eb[f'{sched}_intra_bytes']:>12,}B"
                  f" (L={el[sched]['intra']:.3f})")
        cut = (eb["flat_inter_bytes"] / eb["two_level_inter_bytes"]
               if eb["two_level_inter_bytes"] else float("inf"))
        print(f"  inter-host cut x{cut:.2f} (= k/hosts)")
    if args.stream:
        s = measure_stream(args.q, args.k, args.d, args.stream,
                           wave_batch=args.wave_batch, codec=args.codec,
                           kill_at=args.kill_at,
                           rejoin_at=args.rejoin_at,
                           kill_worker=args.kill_worker)
        print(f"stream     {s['waves']} waves: serial="
              f"{s['serial_s'] * 1e3:.1f}ms  pipelined="
              f"{s['stream_s'] * 1e3:.1f}ms  "
              f"({s['speedup']:.2f}x, {s['stream_wps']:.1f} waves/s)")
        if "churn" in s:
            c = s["churn"]
            rj = ("" if c["rejoin_at"] is None
                  else f" rejoin@{c['rejoin_at']}")
            print(f"churn      kill worker {c['worker']} @wave "
                  f"{c['kill_at']}{rj}: outputs bit-identical, "
                  f"swaps={c['swaps']}, compiles={c['compiles']} "
                  "(no retrace)")


if __name__ == "__main__":
    main()
