"""Multi-pod dry-run — deliverable (e).

For every (architecture x input shape) cell, ``jax.jit(step).lower(...)
.compile()`` against the production mesh, then record:

* ``memory_analysis()``  — proves the cell fits per-device HBM,
* ``cost_analysis()``    — HLO FLOPs / bytes for §Roofline,
* collective bytes       — parsed from the optimized HLO (hlo_stats).

Results go to ``results/dryrun/<arch>_<shape>_<mesh>.json``; the roofline
tooling (launch/roofline.py) and EXPERIMENTS.md read from there.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch granite_3_2b \
        --shape train_4k --mesh single   # one cell
    PYTHONPATH=src python -m repro.launch.dryrun --all  # every cell
"""

import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS_EXTRA", ""))
# ^ MUST precede the jax import (jax locks the device count on init).

import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCHS, SHAPES, get_config, shape_supported
from repro.launch.mesh import make_production_mesh
from repro.launch.hlo_stats import collective_stats
from repro.launch.steps import build_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    ok, why = shape_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": why}
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    t0 = time.time()
    bundle = build_step(cfg, mesh, shape)
    with mesh:
        lowered = bundle.fn.lower(*bundle.args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        stats = collective_stats(compiled.as_text())
    out = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "status": "ok",
        "devices": int(mesh.devices.size),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "code_bytes": int(mem.generated_code_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
        },
        "cost": {
            "flops": float(cost.get("flops", -1)),
            "bytes_accessed": float(cost.get("bytes accessed", -1)),
        },
        "collectives": stats.as_dict(),
        "params": cfg.param_count(),
        "params_active": cfg.param_count(active_only=True),
    }
    return out


def cost_pass(arch: str, shape_name: str, mesh_kind: str = "single"
              ) -> dict:
    """Trip-true HLO cost numbers via affine extrapolation.

    ``cost_analysis()`` (and the HLO text) count scan bodies ONCE, so the
    scanned compile undercounts by the trip count. Every quantity in the
    step module is affine in the repeat count R (identical layer bodies),
    so we compile UNROLLED modules at R=1 and R=2 and extrapolate
    f(R_full) = f(1) + (R_full - 1) * (f(2) - f(1)) exactly.
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": why}
    unit = len(cfg.pattern)
    r_full = cfg.repeats
    pts = {}
    t0 = time.time()
    for r in (1, 2):
        c = cfg.replace(n_layers=unit * r, scan_unroll=True)
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
        bundle = build_step(c, mesh, shape)
        with mesh:
            compiled = bundle.fn.lower(*bundle.args).compile()
            cost = compiled.cost_analysis()
            stats = collective_stats(compiled.as_text())
        pts[r] = {"flops": float(cost.get("flops", 0)),
                  "bytes": float(cost.get("bytes accessed", 0)),
                  "wire": float(stats.wire_bytes),
                  "coll": float(stats.total_bytes),
                  "by_kind": stats.bytes_by_kind}

    def extrap(key):
        return pts[1][key] + (r_full - 1) * (pts[2][key] - pts[1][key])

    by_kind = {}
    for k in set(pts[1]["by_kind"]) | set(pts[2]["by_kind"]):
        b1 = pts[1]["by_kind"].get(k, 0)
        b2 = pts[2]["by_kind"].get(k, 0)
        by_kind[k] = b1 + (r_full - 1) * (b2 - b1)
    return {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "status": "ok", "repeats": r_full, "seconds": round(
            time.time() - t0, 1),
        "cost": {"flops": extrap("flops"), "bytes_accessed": extrap(
            "bytes")},
        "collectives": {"wire_bytes": extrap("wire"),
                        "total_bytes": extrap("coll"),
                        "bytes_by_kind": by_kind},
        "points": pts,
    }


def save(result: dict, suffix: str = "") -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    fn = os.path.join(
        RESULTS_DIR,
        f"{result['arch']}_{result['shape']}_{result['mesh']}{suffix}"
        ".json")
    with open(fn, "w") as f:
        json.dump(result, f, indent=1)
    return fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multipod"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--cost", action="store_true",
                    help="run the trip-true cost pass instead of the "
                         "scanned compile")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                meshes = ("single",) if args.cost else ("single",
                                                        "multipod")
                for m in meshes:
                    cells.append((a, s, m))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch/--shape or --all required")
        cells = [(args.arch, args.shape, args.mesh)]

    suffix = "_cost" if args.cost else ""
    failures = 0
    for a, s, m in cells:
        fn = os.path.join(RESULTS_DIR, f"{a}_{s}_{m}{suffix}.json")
        if args.skip_existing and os.path.exists(fn):
            with open(fn) as f:
                prev = json.load(f)
            if prev.get("status") in ("ok", "skipped"):
                print(f"[dryrun] {a} {s} {m}{suffix}: cached "
                      f"{prev['status']}", flush=True)
                continue
        try:
            res = cost_pass(a, s, m) if args.cost else run_cell(a, s, m)
        except Exception as e:  # noqa: BLE001 — record and continue
            res = {"arch": a, "shape": s, "mesh": m, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
            failures += 1
        save(res, suffix)
        msg = res["status"]
        if res["status"] == "ok" and not args.cost:
            hbm = (res["memory"]["argument_bytes"]
                   + res["memory"]["temp_bytes"]
                   + res["memory"]["output_bytes"]
                   - res["memory"]["alias_bytes"]) / 2**30
            msg += (f" mem~{hbm:.1f}GiB flops={res['cost']['flops']:.3g}"
                    f" coll={res['collectives']['total_bytes']/2**30:.2f}"
                    f"GiB lower={res['lower_s']}s "
                    f"compile={res['compile_s']}s")
        elif res["status"] == "ok":
            msg += (f" flops={res['cost']['flops']:.3g} "
                    f"wire={res['collectives']['wire_bytes']/2**30:.2f}GiB"
                    f" ({res['seconds']}s)")
        print(f"[dryrun] {a} {s} {m}{suffix}: {msg}", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
