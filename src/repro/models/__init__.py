"""Model zoo: one composable assembly (lm.py) covering all families."""
from . import layers, lm

__all__ = ["layers", "lm"]
