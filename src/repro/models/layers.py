"""Layer primitives shared by all architectures (pure functions on pytrees).

Conventions
-----------
* params are nested dicts of jnp arrays; every init_* has a matching
  spec_* returning the same structure with *logical axis* tuples used by
  the partitioner (repro.launch.partitioning).
* activations: x [B, T, D]; attention uses [B, H, T, Dh] internally.
* all matmuls accumulate in f32 (preferred_element_type) regardless of the
  param/activation dtype.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import ops

Params = dict
Specs = dict

# logical axis names (mapped to mesh axes in launch/partitioning.py).
# NOTE: the d_model axis of *parameters* is the FSDP shard axis ('fsdp');
# the 'embed' name is reserved for activations (replicated over model).
EMBED, FFN, HEADS, KV, VOCAB, EXP, SSM_IN, STATE = (
    "fsdp", "ffn", "heads", "kv", "vocab", "experts", "ssm_in", "state")


# --------------------------------------------------------------------- #
# basics
# --------------------------------------------------------------------- #
def dense(x, w):
    return lax.dot_general(x, w, (((x.ndim - 1,), (0,)), ((), ())),
                           preferred_element_type=jnp.float32
                           ).astype(x.dtype)


def rms_norm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(x.dtype)


def rope(x, positions, theta=1e4):
    """x: [B, H, T, Dh]; positions: [B, T] or [T]."""
    B, H, T, Dh = x.shape
    half = Dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[:, None, :, None].astype(jnp.float32) * freq  # [B,1,T,h]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- #
# attention (GQA + RoPE + window/softcap), with optional KV cache
# --------------------------------------------------------------------- #
def init_attention(key, cfg) -> Params:
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    sc = d ** -0.5
    return {
        "wq": jax.random.normal(k1, (d, hq * dh), cfg.dtype) * sc,
        "wk": jax.random.normal(k2, (d, hkv * dh), cfg.dtype) * sc,
        "wv": jax.random.normal(k3, (d, hkv * dh), cfg.dtype) * sc,
        "wo": jax.random.normal(k4, (hq * dh, d), cfg.dtype) * sc,
    }


def spec_attention(cfg) -> Specs:
    return {"wq": (EMBED, HEADS), "wk": (EMBED, KV), "wv": (EMBED, KV),
            "wo": (HEADS, EMBED)}


def attention_block(p, x, positions, cfg, *, window=None, softcap=None,
                    causal=True, cache=None, cache_index=None,
                    memory=None):
    """Self- (or cross-, when ``memory`` is set) attention.

    cache: optional dict(k=[B, Hkv, Tmax, Dh], v=...) -> returns updated.
    """
    B, T, D = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = dense(x, p["wq"]).reshape(B, T, hq, dh).transpose(0, 2, 1, 3)
    src = x if memory is None else memory
    Ts = src.shape[1]
    k = dense(src, p["wk"]).reshape(B, Ts, hkv, dh).transpose(0, 2, 1, 3)
    v = dense(src, p["wv"]).reshape(B, Ts, hkv, dh).transpose(0, 2, 1, 3)
    if memory is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    valid_len = None
    if cache is not None and "pages" in cache:
        # paged slot-indexed layout (serving, DESIGN.md §13): k/v live in
        # a shared page pool [P, Hkv, page, Dh]; ``pages`` [B, npp] maps
        # each slot's logical pages to physical ones; ``cache_index`` is
        # the per-row logical write position (-1 = finished row, its
        # write is routed to the reserved trash page 0 and its keys are
        # fully masked via valid_len 0).
        assert T == 1, "paged cache entries are decode-only (T == 1)"
        pt = cache["pages"]                       # [B, npp] int32
        ps = cache["k"].shape[2]                  # page size
        npp = pt.shape[1]
        rows = jnp.arange(B)
        idx = cache_index
        safe = jnp.maximum(idx, 0)
        phys = jnp.where(idx < 0, 0, pt[rows, safe // ps])   # [B]
        off = safe % ps                                       # [B]
        kc = cache["k"].at[phys, :, off].set(k[:, :, 0])
        vc = cache["v"].at[phys, :, off].set(v[:, :, 0])
        new_cache = {"k": kc, "v": vc, "pages": pt}
        # gather the slot's pages back into logical order: the dense
        # per-row view the masked attention below consumes
        k = kc[pt].transpose(0, 2, 1, 3, 4).reshape(B, hkv, npp * ps, dh)
        v = vc[pt].transpose(0, 2, 1, 3, 4).reshape(B, hkv, npp * ps, dh)
        valid_len = idx + T                       # [B]; -1 -> all masked
    elif cache is not None:
        # write this step's k/v at cache_index; keep the updated cache in
        # its sharded layout (a resharded DUS would replicate it)
        from repro.launch.partitioning import constrain as _con
        kc = lax.dynamic_update_slice_in_dim(cache["k"], k, cache_index, 2)
        vc = lax.dynamic_update_slice_in_dim(cache["v"], v, cache_index, 2)
        kc = _con(kc, ("batch", None, "seq_kv", None))
        vc = _con(vc, ("batch", None, "seq_kv", None))
        new_cache = {"k": kc, "v": vc}
        if T == 1:
            # decode: attend over the cache up to the current position
            k, v = kc, vc
            valid_len = cache_index + T
        # else prefill: the T tokens just computed ARE the valid keys —
        # attend over (k, v) directly with the static causal mask (keeps
        # the O(T) chunked-flash path; the cache write is independent)

    # keep the head axis tensor-parallel through the attention einsums
    # (constrain drops axes that do not divide, e.g. gemma2's 8 heads)
    from repro.launch.partitioning import constrain
    q = constrain(q, ("batch", "heads", None, None))
    k = constrain(k, ("batch", "heads", None, None))
    v = constrain(v, ("batch", "heads", None, None))
    out = ops.attention(q, k, v, causal=causal and memory is None,
                        window=window, softcap=softcap, valid_len=valid_len,
                        use_pallas=cfg.use_pallas,
                        block_q=cfg.attn_block, block_k=cfg.attn_block,
                        unroll=cfg.scan_unroll)
    out = constrain(out, ("batch", "heads", None, None))
    out = out.transpose(0, 2, 1, 3).reshape(B, T, hq * dh)
    out = dense(out, p["wo"])
    return (out, new_cache) if cache is not None else (out, None)


# --------------------------------------------------------------------- #
# MLP: SwiGLU / GEGLU
# --------------------------------------------------------------------- #
def init_mlp(key, cfg) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": jax.random.normal(k1, (d, f), cfg.dtype) * d ** -0.5,
        "w_up": jax.random.normal(k2, (d, f), cfg.dtype) * d ** -0.5,
        "w_down": jax.random.normal(k3, (f, d), cfg.dtype) * f ** -0.5,
    }


def spec_mlp(cfg) -> Specs:
    return {"w_gate": (EMBED, FFN), "w_up": (EMBED, FFN),
            "w_down": (FFN, EMBED)}


def mlp_block(p, x, cfg):
    act = jax.nn.gelu if cfg.mlp_act == "geglu" else jax.nn.silu
    h = act(dense(x, p["w_gate"])) * dense(x, p["w_up"])
    return dense(h, p["w_down"])


# --------------------------------------------------------------------- #
# MoE (top-k routing, capacity-bounded sort-free dispatch)
# --------------------------------------------------------------------- #
def init_moe(key, cfg) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": jax.random.normal(k1, (d, e), cfg.dtype) * d ** -0.5,
        "w_gate": jax.random.normal(k2, (e, d, f), cfg.dtype) * d ** -0.5,
        "w_up": jax.random.normal(k3, (e, d, f), cfg.dtype) * d ** -0.5,
        "w_down": jax.random.normal(k4, (e, f, d), cfg.dtype) * f ** -0.5,
    }


def spec_moe(cfg) -> Specs:
    if cfg.moe_shard_mode == "ep":
        w = (EXP, EMBED, None)
        wd = (EXP, None, EMBED)
    else:  # tensor-parallel experts (few big experts, e.g. mixtral)
        w = (None, EMBED, FFN)
        wd = (None, FFN, EMBED)
    return {"router": (EMBED, None), "w_gate": w, "w_up": w, "w_down": wd}


def _moe_dispatch_compute(p, xf, cfg, n_model: int = 1,
                          axis_name: str | None = None,
                          ep_replicated: bool = False):
    """Local dispatch + expert FFN on a flat token block [N, D].

    When running manually over a 'model' axis (axis_name set):
      - 'ep' mode: experts are sharded E/n_model per device; tokens are
        routed with a bidirectional all_to_all (the classic MoE a2a).
      - 'ep' + ``ep_replicated`` (tokens identical on every model shard,
        e.g. decode with T=1): each shard serves only its local experts
        and the partial token outputs are psum'd — no a2a, no duplicate
        expert work.
      - 'tp' mode: every expert's FFN dim is sharded; partial outputs
        are psum'd over the axis.
    Tokens beyond an expert's capacity are dropped (GShard behaviour).
    """
    N, D = xf.shape
    E, topk = cfg.n_experts, cfg.experts_per_token
    logits = dense(xf, p["router"]).astype(jnp.float32)       # [N, E]
    gates = jax.nn.softmax(logits, axis=-1)
    w, idx = lax.top_k(gates, topk)                           # [N, topk]
    w = w / jnp.sum(w, axis=-1, keepdims=True)

    cap = int(cfg.moe_capacity_factor * N * topk / E)
    cap = max(cap, 4)
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)          # [N, topk, E]
    flat = onehot.reshape(N * topk, E)
    pos = jnp.cumsum(flat, axis=0) - flat
    pos = jnp.sum(pos * flat, axis=-1)                        # [N*topk]
    eidx = idx.reshape(N * topk)
    keep = pos < cap
    src = jnp.repeat(xf, topk, axis=0)
    act = jax.nn.gelu if cfg.mlp_act == "geglu" else jax.nn.silu
    ep = axis_name is not None and cfg.moe_shard_mode == "ep" \
        and n_model > 1 and not ep_replicated
    ep_rep = axis_name is not None and cfg.moe_shard_mode == "ep" \
        and n_model > 1 and ep_replicated
    tp = axis_name is not None and cfg.moe_shard_mode == "tp" \
        and n_model > 1

    if ep_rep:
        e_loc = E // n_model
        e0 = lax.axis_index(axis_name) * e_loc
        mine = keep & (eidx >= e0) & (eidx < e0 + e_loc)
        e_sel = jnp.where(mine, eidx - e0, e_loc - 1)
        c_sel = jnp.where(mine, pos, cap - 1)
        buf = jnp.zeros((e_loc, cap, D), xf.dtype)
        buf = buf.at[e_sel, c_sel].add(jnp.where(mine[:, None], src, 0))
    else:
        e_sel = jnp.where(keep, eidx, E - 1)
        c_sel = jnp.where(keep, pos, cap - 1)
        buf = jnp.zeros((E, cap, D), xf.dtype)
        buf = buf.at[e_sel, c_sel].add(jnp.where(keep[:, None], src, 0))
        mine = keep
    if ep:
        # route tokens to the peers owning each expert block:
        # [E, cap, D] -> [E/n, n*cap, D] (tiled a2a, self-transposing)
        buf = lax.all_to_all(buf, axis_name, split_axis=0, concat_axis=1,
                             tiled=True)
    h = act(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"],
                       preferred_element_type=jnp.float32).astype(xf.dtype))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["w_up"],
                       preferred_element_type=jnp.float32).astype(xf.dtype)
    out_e = jnp.einsum("ecf,efd->ecd", h, p["w_down"],
                       preferred_element_type=jnp.float32).astype(xf.dtype)
    if ep:
        # route results back: [E/n, n*cap, D] -> [E, cap, D]
        out_e = lax.all_to_all(out_e, axis_name, split_axis=1,
                               concat_axis=0, tiled=True)
    if tp:
        out_e = lax.psum(out_e, axis_name)  # FFN-dim partial sums

    got = out_e[e_sel, c_sel]
    got = jnp.where(mine[:, None], got, 0)
    wflat = w.reshape(N * topk, 1).astype(xf.dtype)
    out = jnp.sum((got * wflat).reshape(N, topk, D), axis=1)
    if ep_rep:
        out = lax.psum(out, axis_name)     # combine expert-shard partials
    me = jnp.mean(gates, axis=0)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32), axis=0)
    return out, (me, ce)


def moe_block(p, x, cfg):
    """Top-k MoE. With an active mesh, dispatch runs under shard_map so
    the scatter/gather stays LOCAL to each token shard (GSPMD cannot
    partition data-dependent scatters well) and the expert parallelism
    is an explicit all_to_all ('ep') or psum ('tp') on the model axis."""
    from repro.launch import partitioning as pt
    B, T, D = x.shape
    mesh = pt.current_mesh()
    E = cfg.n_experts
    if mesh is None:
        out, (me, ce) = _moe_dispatch_compute(p, x.reshape(B * T, D), cfg)
        return out.reshape(B, T, D), E * jnp.sum(me * ce)

    from jax.sharding import PartitionSpec as P
    ctx_rules = pt._state.ctx[1]
    daxes = tuple(ctx_rules["batch"])
    n_data = 1
    for a in daxes:
        n_data *= mesh.shape[a]
    n_model = mesh.shape["model"]
    batch_ax = daxes if B % n_data == 0 else None
    if batch_ax is not None and len(batch_ax) == 1:
        batch_ax = batch_ax[0]
    # EP splits tokens over 'model' (a2a regroups by expert); TP must NOT
    # (its psum reduces FFN partials of the SAME tokens)
    seq_ax = "model" if (cfg.moe_shard_mode == "ep"
                         and T % n_model == 0) else None
    xs = P(batch_ax, seq_ax, None)

    if cfg.moe_shard_mode == "ep":
        wspec = {"router": P(None, None), "w_gate": P("model", None, None),
                 "w_up": P("model", None, None),
                 "w_down": P("model", None, None)}
    else:
        wspec = {"router": P(None, None), "w_gate": P(None, None, "model"),
                 "w_up": P(None, None, "model"),
                 "w_down": P(None, "model", None)}

    ep_rep = cfg.moe_shard_mode == "ep" and seq_ax is None

    def body(p_loc, x_loc):
        b, t, _ = x_loc.shape
        out, (me, ce) = _moe_dispatch_compute(
            p_loc, x_loc.reshape(b * t, D), cfg, n_model=n_model,
            axis_name="model", ep_replicated=ep_rep)
        # aux loss: global token means FIRST (linear), then the product
        for ax in ("model",) + tuple(daxes):
            me, ce = lax.pmean(me, ax), lax.pmean(ce, ax)
        return out.reshape(b, t, D), E * jnp.sum(me * ce)

    from repro.compat import shard_map
    out, aux = shard_map(
        body, mesh=mesh,
        in_specs=(wspec, xs), out_specs=(xs, P()))(
        {k: p[k] for k in ("router", "w_gate", "w_up", "w_down")}, x)
    return out, aux


# --------------------------------------------------------------------- #
# Mamba2 block (SSD core + gating, simplified faithful structure)
# --------------------------------------------------------------------- #
def init_ssm(key, cfg) -> Params:
    d = cfg.d_model
    di = cfg.ssm_d_inner
    H = cfg.ssm_heads
    S = cfg.ssm_state
    ks = jax.random.split(key, 6)
    return {
        "w_in": jax.random.normal(ks[0], (d, di), cfg.dtype) * d ** -0.5,
        "w_gate": jax.random.normal(ks[1], (d, di), cfg.dtype) * d ** -0.5,
        # B/C are group-shared across heads (n_groups=1, as in Mamba2)
        "w_bc": jax.random.normal(ks[2], (d, 2 * S), cfg.dtype)
        * d ** -0.5,
        "w_dt": jax.random.normal(ks[3], (d, H), cfg.dtype) * d ** -0.5,
        "a_log": jnp.zeros((H,), jnp.float32),
        "skip": jnp.ones((H,), jnp.float32) * 0.1,   # D residual term
        "w_out": jax.random.normal(ks[5], (di, d), cfg.dtype) * di ** -0.5,
    }


def spec_ssm(cfg) -> Specs:
    return {"w_in": (EMBED, SSM_IN), "w_gate": (EMBED, SSM_IN),
            "w_bc": (EMBED, None), "w_dt": (EMBED, None),
            "a_log": (None,), "skip": (None,), "w_out": (SSM_IN, EMBED)}


def ssm_block(p, x, cfg, *, state=None, return_state=False):
    """Mamba2 SSD block. state: [B, H, S, P] for decode (returns updated).

    ``return_state`` (prefill): also returns the final state, computed in
    closed form h_T = sum_s exp(cum_T - cum_s) b_s x_s^T (weights <= 1, so
    numerically stable for arbitrary T).
    """
    B, T, D = x.shape
    H, S = cfg.ssm_heads, cfg.ssm_state
    P = cfg.ssm_d_inner // H
    u = dense(x, p["w_in"]).reshape(B, T, H, P)
    z = dense(x, p["w_gate"])                                  # [B, T, di]
    bc = dense(x, p["w_bc"])                                   # [B, T, 2S]
    b, c = bc[..., :S], bc[..., S:]                            # [B, T, S]
    dt = jax.nn.softplus(dense(x, p["w_dt"]).astype(jnp.float32))  # [B,T,H]
    a = -jnp.exp(p["a_log"])[None, None, :] * dt               # log-decay <0
    xin = u * dt[..., None].astype(u.dtype)

    if state is None:
        y = ops.ssd(xin, a, b, c, use_pallas=cfg.use_pallas,
                    chunk=cfg.ssm_chunk, unroll=cfg.scan_unroll)
        new_state = None
        if return_state:
            cum = jnp.cumsum(a, axis=1)                        # [B, T, H]
            w = jnp.exp(cum[:, -1:, :] - cum)                  # [B, T, H]
            new_state = jnp.einsum(
                "bth,bts,bthp->bhsp", w,
                b.astype(jnp.float32), xin.astype(jnp.float32))
    else:
        # single-step recurrence (T == 1)
        at = jnp.exp(a[:, 0]).astype(jnp.float32)              # [B, H]
        st = state * at[..., None, None] + jnp.einsum(
            "bs,bhp->bhsp", b[:, 0].astype(jnp.float32),
            xin[:, 0].astype(jnp.float32))
        y = jnp.einsum("bs,bhsp->bhp", c[:, 0].astype(jnp.float32),
                       st)[:, None].astype(x.dtype)
        new_state = st
    y = y + xin * p["skip"][None, None, :, None].astype(u.dtype)
    y = y.reshape(B, T, H * P) * jax.nn.silu(z)
    return dense(y, p["w_out"]), new_state
