"""Model assembly: decoder-only / MoE / SSM / hybrid / enc-dec LMs.

One code path serves all ten assigned architectures, driven by
``ModelConfig.pattern`` (the repeating sublayer unit) with ``lax.scan``
over the ``repeats`` axis and optional per-unit remat. Entry points:

    init_params(cfg, key)                  -> params pytree
    param_specs(cfg)                       -> matching logical-axis pytree
    train_loss(cfg, params, batch)         -> (loss, metrics)
    prefill(cfg, params, batch)            -> (last_logits, cache)
    init_cache(cfg, B, T)                  -> zeroed cache pytree
    decode_step(cfg, params, cache, tokens, cache_index)
                                           -> (logits, new_cache)
    init_paged_cache(cfg, slots, n_pages, page_size, pages_per_slot)
                                           -> paged cache (DESIGN.md §13)
    admit_prefill(cfg, paged, prefill_cache, pages, slot)
                                           -> paged cache with the slot
                                              loaded from a B=1 prefill

``cache_index`` may be a scalar (dense cache, uniform position) or a
per-row ``[B]`` vector (paged cache, ragged positions; ``-1`` routes a
finished row's writes to the trash page).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs import ModelConfig
from repro.launch.partitioning import constrain
from . import layers as L

Params = Any


# --------------------------------------------------------------------- #
# structure helpers
# --------------------------------------------------------------------- #
def slot_names(cfg: ModelConfig) -> list[str]:
    return [f"{i}_{kind}" for i, kind in enumerate(cfg.pattern)]


def _init_slot(key, cfg, kind: str) -> Params:
    d = cfg.d_model
    z = jnp.zeros((d,), jnp.float32)
    if kind in ("attn", "local", "shared_attn"):
        k1, k2, k3 = jax.random.split(key, 3)
        p = {"norm1": z, "attn": L.init_attention(k1, cfg), "norm2": z}
        if cfg.n_experts:
            p["moe"] = L.init_moe(k2, cfg)
        else:
            p["mlp"] = L.init_mlp(k2, cfg)
        if cfg.family == "encdec" and kind == "attn":
            p["norm_x"] = z
            p["cross"] = L.init_attention(k3, cfg)
        return p
    if kind == "ssm":
        return {"norm": z, "ssm": L.init_ssm(key, cfg)}
    raise ValueError(f"unknown sublayer kind {kind!r}")


def _spec_slot(cfg, kind: str) -> Any:
    if kind in ("attn", "local", "shared_attn"):
        p = {"norm1": (None,), "attn": L.spec_attention(cfg),
             "norm2": (None,)}
        if cfg.n_experts:
            p["moe"] = L.spec_moe(cfg)
        else:
            p["mlp"] = L.spec_mlp(cfg)
        if cfg.family == "encdec" and kind == "attn":
            p["norm_x"] = (None,)
            p["cross"] = L.spec_attention(cfg)
        return p
    if kind == "ssm":
        return {"norm": (None,), "ssm": L.spec_ssm(cfg)}
    raise ValueError(kind)


def init_params(cfg: ModelConfig, key) -> Params:
    keys = jax.random.split(key, 8)
    d, V = cfg.d_model, cfg.vocab_padded
    params: dict = {
        "embed": jax.random.normal(keys[0], (V, d), cfg.jdtype) * d ** -0.5,
        "norm_f": jnp.zeros((d,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["out"] = jax.random.normal(keys[1], (d, V),
                                          cfg.jdtype) * d ** -0.5
    blocks = {}
    for i, (name, kind) in enumerate(zip(slot_names(cfg), cfg.pattern)):
        if kind == "shared_attn":
            continue  # lives in params['shared']
        sub = jax.random.split(jax.random.fold_in(keys[2], i), cfg.repeats)
        blocks[name] = jax.vmap(
            lambda k: _init_slot(k, cfg, kind))(sub)
    params["blocks"] = blocks
    if "shared_attn" in cfg.pattern:
        params["shared"] = _init_slot(keys[3], cfg, "shared_attn")
    if cfg.n_enc_layers:
        enc_cfg = cfg
        sub = jax.random.split(keys[4], cfg.n_enc_layers)
        params["enc"] = {
            "blocks": jax.vmap(
                lambda k: _init_slot(k, enc_cfg, "attn")
                if cfg.family != "encdec"
                else {kk: vv for kk, vv in _init_slot(
                    k, enc_cfg.replace(family="dense"), "attn").items()}
            )(sub),
            "norm": jnp.zeros((d,), jnp.float32),
        }
    if cfg.frontend:
        params["front"] = {
            "w": jax.random.normal(keys[5], (cfg.frontend_dim, d),
                                   cfg.jdtype) * cfg.frontend_dim ** -0.5}
    return params


def param_specs(cfg: ModelConfig) -> Any:
    specs: dict = {"embed": (L.VOCAB, L.EMBED), "norm_f": (None,)}
    if not cfg.tie_embeddings:
        specs["out"] = (L.EMBED, L.VOCAB)
    blocks = {}
    for name, kind in zip(slot_names(cfg), cfg.pattern):
        if kind == "shared_attn":
            continue
        # leading scan axis is unsharded -> prepend None
        blocks[name] = jax.tree.map(
            lambda ax: (None,) + tuple(ax), _spec_slot(cfg, kind),
            is_leaf=lambda x: isinstance(x, tuple))
    specs["blocks"] = blocks
    if "shared_attn" in cfg.pattern:
        specs["shared"] = _spec_slot(cfg, "shared_attn")
    if cfg.n_enc_layers:
        specs["enc"] = {
            "blocks": jax.tree.map(
                lambda ax: (None,) + tuple(ax),
                _spec_slot(cfg.replace(family="dense"), "attn"),
                is_leaf=lambda x: isinstance(x, tuple)),
            "norm": (None,),
        }
    if cfg.frontend:
        specs["front"] = {"w": (None, L.EMBED)}
    return specs


# --------------------------------------------------------------------- #
# sublayer application
# --------------------------------------------------------------------- #
def _apply_slot(cfg, kind, p, x, positions, *, memory=None, cache=None,
                cache_index=None, mode="train"):
    """Returns (x, new_cache_entry, aux)."""
    aux = jnp.zeros((), jnp.float32)
    sp = ("batch", "seq", "embed")  # sequence-parallel residual layout
    if kind in ("attn", "local", "shared_attn"):
        window = cfg.local_window if kind == "local" else cfg.window
        h = L.rms_norm(x, p["norm1"])
        attn_cache = cache.get("self") if cache else None
        h, new_self = L.attention_block(
            p["attn"], h, positions, cfg, window=window,
            softcap=cfg.attn_softcap, causal=(mode != "encoder"),
            cache=attn_cache, cache_index=cache_index)
        # reduce-scatter the row-parallel output into the SP layout
        x = x + constrain(h, sp)
        if cfg.family == "encdec" and kind == "attn" and mode != "encoder":
            h = L.rms_norm(x, p["norm_x"])
            if cache is not None and "cross" in cache:
                # decode: attend to the prefilled cross k/v directly
                ck = cache["cross"]
                B = x.shape[0]
                q = L.dense(h, p["cross"]["wq"]).reshape(
                    B, x.shape[1], cfg.n_heads, cfg.hd).transpose(0, 2, 1, 3)
                from repro.kernels import ops
                o = ops.attention(q, ck["k"], ck["v"], causal=False,
                                  use_pallas=cfg.use_pallas)
                o = o.transpose(0, 2, 1, 3).reshape(B, x.shape[1], -1)
                h = L.dense(o, p["cross"]["wo"])
                new_cross = ck
            else:
                h, _ = L.attention_block(p["cross"], h, positions, cfg,
                                         causal=False, memory=memory)
                new_cross = None
            x = x + constrain(h, sp)
        else:
            new_cross = None
        h = L.rms_norm(x, p["norm2"])
        if cfg.n_experts:
            h, aux = L.moe_block(p["moe"], h, cfg)
        else:
            h = L.mlp_block(p["mlp"], h, cfg)
        x = x + constrain(h, sp)
        new_cache = None
        if cache is not None:
            new_cache = {"self": new_self}
            if new_cross is not None:
                new_cache["cross"] = new_cross
        return x, new_cache, aux
    if kind == "ssm":
        h = L.rms_norm(x, p["norm"])
        if mode == "prefill":
            h, new_state = L.ssm_block(p["ssm"], h, cfg, state=None,
                                       return_state=True)
            new_cache = {"state": new_state}
        else:
            state = cache.get("state") if cache else None
            h, new_state = L.ssm_block(p["ssm"], h, cfg, state=state)
            new_cache = {"state": new_state} if cache is not None else None
        return x + constrain(h, sp), new_cache, aux
    raise ValueError(kind)


def _unit(cfg, params, shared, x, positions, *, cache=None,
          cache_index=None, mode="train"):
    """Apply one repetition of the pattern. cache: dict slot->entry."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = {} if cache is not None else None
    for name, kind in zip(slot_names(cfg), cfg.pattern):
        p = shared if kind == "shared_attn" else params[name]
        c = cache.get(name) if cache is not None else None
        x, nc, a = _apply_slot(cfg, kind, p, x, positions, cache=c,
                               cache_index=cache_index, mode=mode)
        aux = aux + a
        if new_cache is not None:
            new_cache[name] = nc
    x = constrain(x, ("batch", "seq", "embed"))
    return x, new_cache, aux


def _scan_units(cfg, params, x, positions, *, cache=None, cache_index=None,
                mode="train"):
    """lax.scan over the pattern repetitions, optional per-unit remat."""
    shared = params.get("shared")

    def body(carry, xs):
        x, aux = carry
        blk, cache_sl = xs
        x, new_c, a = _unit(cfg, blk, shared, x, positions, cache=cache_sl,
                            cache_index=cache_index, mode=mode)
        return (x, aux + a), new_c

    if cfg.remat == "block":
        body = jax.checkpoint(body)
    (x, aux), new_cache = lax.scan(
        body, (x, jnp.zeros((), jnp.float32)),
        (params["blocks"], cache),
        unroll=cfg.repeats if cfg.scan_unroll else 1)
    return x, new_cache, aux


# --------------------------------------------------------------------- #
# embedding / logits / loss
# --------------------------------------------------------------------- #
def _embed(cfg, params, batch):
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if cfg.frontend == "vit":
        patches = L.dense(batch["patches"], params["front"]["w"])
        pl_ = patches.shape[1]
        x = jnp.concatenate([patches.astype(x.dtype), x[:, pl_:]], axis=1)
    x = constrain(x, ("batch", "seq", "embed"))
    return x


def _encoder(cfg, params, frames):
    """Bidirectional encoder over (stub-projected) frame features."""
    x = L.dense(frames, params["front"]["w"])
    positions = jnp.arange(x.shape[1])
    shared = None

    def body(carry, blk):
        h, _ = carry
        h, _, _ = _unit(cfg.replace(pattern=("attn",), family="dense"),
                        {"0_attn": blk}, shared, h, positions,
                        mode="encoder")
        return (h, jnp.zeros(())), None

    bodyf = jax.checkpoint(body) if cfg.remat == "block" else body
    (x, _), _ = lax.scan(bodyf, (x, jnp.zeros(())),
                         params["enc"]["blocks"],
                         unroll=cfg.n_enc_layers if cfg.scan_unroll else 1)
    return L.rms_norm(x, params["enc"]["norm"])


def _logits(cfg, params, x):
    out_w = params["embed"].T if cfg.tie_embeddings else params["out"]
    lg = L.dense(x, out_w).astype(jnp.float32)
    if cfg.final_softcap:
        lg = cfg.final_softcap * jnp.tanh(lg / cfg.final_softcap)
    return lg


def _chunked_loss(cfg, params, x, labels):
    """Cross-entropy with seq-chunked logits (memory: O(chunk * vocab))."""
    B, T, D = x.shape
    C = min(cfg.loss_chunk, T)
    assert T % C == 0
    xc = x.reshape(B, T // C, C, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, T // C, C).transpose(1, 0, 2)

    def chunk(carry, xs):
        xi, li = xs
        lg = _logits(cfg, params, xi)
        # sharding-friendly: mask vocab padding (no uneven slice), gold
        # logit via one-hot contraction (no cross-shard gather) — both
        # keep the vocab axis sharded; only [B, C] scalars cross shards.
        vocab_ids = jax.lax.broadcasted_iota(jnp.int32, lg.shape,
                                             lg.ndim - 1)
        lg = jnp.where(vocab_ids < cfg.vocab, lg, -1e30)
        valid = li >= 0
        li = jnp.maximum(li, 0)
        m = jnp.max(lg, axis=-1)
        lse = m + jnp.log(jnp.sum(jnp.exp(lg - m[..., None]), axis=-1))
        gold = jnp.sum(jnp.where(vocab_ids == li[..., None], lg, 0.0),
                       axis=-1)
        nll = jnp.where(valid, lse - gold, 0.0)
        return (carry[0] + nll.sum(), carry[1] + valid.sum()), None

    # remat the chunk: recompute the [B, C, vocab] logits in the backward
    # instead of saving them (vocab-sized activations dominate otherwise)
    chunk_fn = jax.checkpoint(chunk) if cfg.remat == "block" else chunk
    (tot, cnt), _ = lax.scan(chunk_fn, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.int32)),
                             (xc, lc),
                             unroll=(T // C) if cfg.scan_unroll else 1)
    return tot / jnp.maximum(cnt, 1)


# --------------------------------------------------------------------- #
# public entry points
# --------------------------------------------------------------------- #
def train_loss(cfg: ModelConfig, params, batch):
    """batch: tokens, labels (+ patches/frames for vlm/audio)."""
    if cfg.family == "encdec":
        memory = _encoder(cfg, params, batch["frames"])
        x = _embed(cfg, params, batch)
        positions = jnp.arange(x.shape[1])

        # decoder units need the encoder memory for cross-attention: close
        # over it (memory is an invariant of the scan).
        def body_mem(carry, blk):
            h, aux = carry
            h2 = h
            for name, kind in zip(slot_names(cfg), cfg.pattern):
                h2, _, a = _apply_slot(cfg, kind, blk[name], h2, positions,
                                       memory=memory, mode="train")
                aux = aux + a
            h2 = constrain(h2, ("batch", "seq", "embed"))
            return (h2, aux), None

        bodyf = jax.checkpoint(body_mem) if cfg.remat == "block" \
            else body_mem
        (x, aux), _ = lax.scan(bodyf, (x, jnp.zeros(())), params["blocks"],
                               unroll=cfg.repeats if cfg.scan_unroll else 1)
    else:
        x = _embed(cfg, params, batch)
        positions = jnp.arange(x.shape[1])
        x, _, aux = _scan_units(cfg, params, x, positions, mode="train")
    x = L.rms_norm(x, params["norm_f"])
    loss = _chunked_loss(cfg, params, x, batch["labels"])
    if cfg.n_experts:
        loss = loss + 0.01 * aux / cfg.n_layers
    return loss, {"loss": loss, "moe_aux": aux}


def init_cache(cfg: ModelConfig, B: int, T: int):
    """Zeroed decode cache (also the dry-run ShapeDtypeStruct template)."""
    R, hkv, hd = cfg.repeats, cfg.n_kv_heads, cfg.hd
    cache = {}
    for name, kind in zip(slot_names(cfg), cfg.pattern):
        if kind in ("attn", "local", "shared_attn"):
            ent = {"self": {
                "k": jnp.zeros((R, B, hkv, T, hd), cfg.jdtype),
                "v": jnp.zeros((R, B, hkv, T, hd), cfg.jdtype)}}
            if cfg.family == "encdec" and kind == "attn":
                ent["cross"] = {
                    "k": jnp.zeros((R, B, hkv, T, hd), cfg.jdtype),
                    "v": jnp.zeros((R, B, hkv, T, hd), cfg.jdtype)}
            cache[name] = ent
        elif kind == "ssm":
            P = cfg.ssm_d_inner // cfg.ssm_heads
            cache[name] = {"state": jnp.zeros(
                (R, B, cfg.ssm_heads, cfg.ssm_state, P), jnp.float32)}
    return cache


def init_paged_cache(cfg: ModelConfig, slots: int, n_pages: int,
                     page_size: int, pages_per_slot: int):
    """Zeroed paged decode cache (DESIGN.md §13).

    Attention k/v live in ONE physical page pool ``[R, P, Hkv, page,
    Dh]`` shared by every batch slot; ``pages`` ``[R, slots, npp]`` is
    the per-slot page table (replicated over the scanned layer axis so
    the whole pytree scans with ``lax.scan``; int32, ~nothing).
    Physical page 0 is reserved as the trash page — finished rows write
    there and the allocator never hands it out. SSM state is recurrent
    (no sequence axis), so it stays a per-slot row ``[R, slots, ...]``
    and is simply overwritten at admission.
    """
    if cfg.family == "encdec":
        raise NotImplementedError(
            "paged decode does not support enc-dec cross caches; use "
            "the legacy generate() path")
    R, hkv, hd = cfg.repeats, cfg.n_kv_heads, cfg.hd
    cache = {}
    for name, kind in zip(slot_names(cfg), cfg.pattern):
        if kind in ("attn", "local", "shared_attn"):
            # NOTE: each layer gets its OWN page-table buffer — sharing
            # one array across layers would put the same buffer in the
            # pytree twice and break jit argument donation
            cache[name] = {"self": {
                "k": jnp.zeros((R, n_pages, hkv, page_size, hd),
                               cfg.jdtype),
                "v": jnp.zeros((R, n_pages, hkv, page_size, hd),
                               cfg.jdtype),
                "pages": jnp.zeros((R, slots, pages_per_slot),
                                   jnp.int32)}}
        elif kind == "ssm":
            P = cfg.ssm_d_inner // cfg.ssm_heads
            cache[name] = {"state": jnp.zeros(
                (R, slots, cfg.ssm_heads, cfg.ssm_state, P), jnp.float32)}
    return cache


def admit_prefill(cfg: ModelConfig, paged, prefill_cache, pages, slot):
    """Scatter a ``B=1`` prefill cache into the paged pool (DESIGN.md
    §13).

    ``prefill_cache`` comes from :func:`prefill` with
    ``max_len = n * page_size`` (so its sequence axis splits into whole
    pages); ``pages`` is the slot's FULL page-table row ``[npp]`` whose
    first ``n`` entries are the allocated physical pages (the rest point
    at the trash page 0 and are never valid under the length mask);
    ``slot`` is the (traced) batch-slot index. Pure data movement —
    every cached byte lands bit-identical in its page.
    """
    new = {}
    for name, kind in zip(slot_names(cfg), cfg.pattern):
        if kind in ("attn", "local", "shared_attn"):
            ent, src = paged[name]["self"], prefill_cache[name]["self"]
            ps = ent["k"].shape[3]
            R, _, hkv, Tp, hd = src["k"].shape
            assert Tp % ps == 0, (Tp, ps)
            npg = Tp // ps
            out = {}
            for key in ("k", "v"):
                blocks = src[key][:, 0].reshape(R, hkv, npg, ps, hd)
                blocks = blocks.transpose(0, 2, 1, 3, 4)
                out[key] = ent[key].at[:, pages[:npg]].set(blocks)
            out["pages"] = ent["pages"].at[:, slot].set(pages)
            new[name] = {"self": out}
        elif kind == "ssm":
            st = paged[name]["state"].at[:, slot].set(
                prefill_cache[name]["state"][:, 0])
            new[name] = {"state": st}
    return new


def cache_specs(cfg: ModelConfig):
    """Logical axes for the cache: batch over data, cache SEQUENCE over
    model (flash-decode style — kv-head counts are often < the model
    axis, the sequence always divides it)."""
    spec = {}
    for name, kind in zip(slot_names(cfg), cfg.pattern):
        if kind in ("attn", "local", "shared_attn"):
            kv = {"k": (None, "batch", None, "seq_kv", None),
                  "v": (None, "batch", None, "seq_kv", None)}
            ent = {"self": kv}
            if cfg.family == "encdec" and kind == "attn":
                ent["cross"] = dict(kv)
            spec[name] = ent
        elif kind == "ssm":
            spec[name] = {"state": (None, "batch", "ssm_heads", None,
                                    None)}
    return spec


def prefill(cfg: ModelConfig, params, batch, max_len: int | None = None):
    """Forward pass that also writes the KV/state caches.

    Implemented as decode-mode scan with T-length writes at index 0.
    ``max_len`` sizes the cache for subsequent decode_step calls."""
    x = _embed(cfg, params, batch)
    B, T = x.shape[:2]
    positions = jnp.arange(T)
    cache = init_cache(cfg, B, max_len or T)
    memory = None
    if cfg.family == "encdec":
        memory = _encoder(cfg, params, batch["frames"])
        # fill cross k/v once per layer below via _apply_slot(memory=...)
    x, new_cache, _ = _prefill_scan(cfg, params, x, positions, cache,
                                    memory)
    x = L.rms_norm(x, params["norm_f"])
    logits = _logits(cfg, params, x[:, -1:])
    return logits, new_cache


def _prefill_scan(cfg, params, x, positions, cache, memory):
    shared = params.get("shared")

    def body(carry, xs):
        h = carry
        blk, cache_sl = xs
        new_c = {}
        for name, kind in zip(slot_names(cfg), cfg.pattern):
            p = shared if kind == "shared_attn" else blk[name]
            c = cache_sl.get(name)
            if kind in ("attn", "local", "shared_attn"):
                h, nc, _ = _apply_slot(
                    cfg, kind, p, h, positions, memory=memory,
                    cache={"self": c["self"]},
                    cache_index=jnp.zeros((), jnp.int32), mode="prefill")
                if cfg.family == "encdec" and kind == "attn":
                    # fill the cross k/v cache from the encoder memory
                    B, Ts = memory.shape[:2]
                    kx = L.dense(memory, p["cross"]["wk"]).reshape(
                        B, Ts, cfg.n_kv_heads, cfg.hd).transpose(0, 2, 1, 3)
                    vx = L.dense(memory, p["cross"]["wv"]).reshape(
                        B, Ts, cfg.n_kv_heads, cfg.hd).transpose(0, 2, 1, 3)
                    nc["cross"] = {"k": kx, "v": vx}
            else:
                h, nc, _ = _apply_slot(cfg, kind, p, h, positions,
                                       cache=None, mode="prefill")
            new_c[name] = nc
        h = constrain(h, ("batch", "seq", "embed"))
        return h, new_c

    bodyf = jax.checkpoint(body) if cfg.remat == "block" else body
    x, new_cache = lax.scan(bodyf, x, (params["blocks"], cache),
                            unroll=cfg.repeats if cfg.scan_unroll else 1)
    return x, new_cache, None


def decode_step(cfg: ModelConfig, params, cache, tokens, cache_index):
    """One serving step: tokens [B, 1] + cache -> logits [B, 1, V].

    ``cache_index`` is the write/attend position: a scalar (whole batch
    at one position — the classic right-aligned decode) or a ``[B]``
    vector of per-row positions for ragged continuous batching over a
    paged cache (DESIGN.md §13; -1 marks a finished/empty row whose
    write is routed to the trash page and whose keys are fully masked).
    """
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    B = x.shape[0]
    ci = jnp.asarray(cache_index, jnp.int32)
    if ci.ndim == 1:
        positions = jnp.maximum(ci, 0)[:, None]          # [B, 1]
    else:
        positions = jnp.full((B, 1), ci, jnp.int32)
    x, new_cache, _ = _scan_units(cfg, params, x, positions, cache=cache,
                                  cache_index=ci, mode="decode")
    x = L.rms_norm(x, params["norm_f"])
    return _logits(cfg, params, x), new_cache


def poisoned_rows(logits, vocab: int):
    """Device-side poisoned-output sentinel (DESIGN.md §15).

    ``logits [..., V]`` -> bool ``[...]``: True where a row's next-token
    logits contain any non-finite value over the real (unpadded) vocab.
    Rows are independent through every decode op (attention, norms and
    sampling are all per-row), so a poisoned row never contaminates its
    batch siblings — the serving wave carries this mask to stop the bad
    slot exactly at its last clean token while the rest of the wave
    continues undisturbed.
    """
    return ~jnp.all(jnp.isfinite(logits[..., :vocab]), axis=-1)
