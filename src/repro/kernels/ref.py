"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests).

These are deliberately simple, O(n^2)-where-natural implementations: the
kernels must match them bit-for-bit (xor/aggregate) or to fp tolerance
(attention/ssd) across shape/dtype sweeps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["xor_encode_ref", "xor_fold_ref", "xor_decode_ref",
           "xor_encode_gather_ref", "xor_decode_gather_ref",
           "aggregate_ref", "flash_attention_ref", "ssd_scan_ref"]


def xor_encode_ref(packets: jnp.ndarray) -> jnp.ndarray:
    """XOR-fold ``packets[m, :]`` over axis 0. uint32 in/out.

    This is the Algorithm-2 Δ computation: a server's coded broadcast is
    the XOR of the m = k-1 packets assigned to it.
    """
    if packets.dtype != jnp.uint32:
        raise TypeError("xor_encode expects uint32 bit patterns")
    return lax.reduce(packets, jnp.uint32(0), lax.bitwise_xor, (0,))


def xor_fold_ref(packets: jnp.ndarray) -> jnp.ndarray:
    """Batched encode oracle: ``u32[R, m, n]`` -> ``u32[R, n]``."""
    if packets.dtype != jnp.uint32:
        raise TypeError("xor_fold expects uint32 bit patterns")
    return lax.reduce(packets, jnp.uint32(0), lax.bitwise_xor, (1,))


def xor_decode_ref(recv: jnp.ndarray, packets: jnp.ndarray,
                   mask: jnp.ndarray) -> jnp.ndarray:
    """Batched decode oracle: ``recv ^ fold(packets where mask)``."""
    masked = jnp.where(mask[..., None], packets, jnp.uint32(0))
    return recv ^ xor_fold_ref(masked)


def xor_encode_gather_ref(chunks: jnp.ndarray, idx: jnp.ndarray,
                          mask: jnp.ndarray) -> jnp.ndarray:
    """Fused-encode oracle: ``out[i] = XOR_j chunks[idx[i, j]] & mask``.

    ``chunks: u32[P, pk]``, ``idx: i32[n, m]``, ``mask: bool[n, m]`` —
    a plain XLA gather + masked fold (the memory-light jnp lane of the
    fused codec; the Pallas kernel must match it bit-for-bit).
    """
    gathered = chunks[idx]                       # [n, m, pk]
    return xor_fold_ref(jnp.where(mask[..., None], gathered,
                                  jnp.uint32(0)))


def xor_decode_gather_ref(recv: jnp.ndarray, chunks: jnp.ndarray,
                          rsel: jnp.ndarray, idx: jnp.ndarray,
                          mask: jnp.ndarray) -> jnp.ndarray:
    """Fused-decode oracle:
    ``out[i] = recv[rsel[i]] ^ XOR_j chunks[idx[i, j]] & mask``."""
    return recv[rsel] ^ xor_encode_gather_ref(chunks, idx, mask)


def aggregate_ref(values: jnp.ndarray, segment_ids: jnp.ndarray,
                  num_segments: int) -> jnp.ndarray:
    """The paper's α-combiner: sum values with the same (function, batch)
    key. values: [n, d] float; segment_ids: [n] int32 -> [num_segments, d].
    """
    return jax.ops.segment_sum(values, segment_ids,
                               num_segments=num_segments)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True, window: int | None = None,
                        softcap: float | None = None,
                        scale: float | None = None,
                        valid_len=None) -> jnp.ndarray:
    """Materialized attention oracle.

    q: [B, Hq, Tq, D]; k, v: [B, Hkv, Tk, D] (GQA: Hq % Hkv == 0).
    ``window``: sliding-window size (attend to keys in (i-window, i]).
    ``softcap``: gemma2-style logit soft-capping: cap*tanh(x/cap).
    ``valid_len``: (traced) number of valid keys — queries are aligned so
    the last query sits at position valid_len-1 (partial KV-cache decode).
    A ``[B]`` vector gives each batch row its own valid length (ragged
    continuous-batching decode, DESIGN.md §13); scalar/None keep the
    original shared-length mask.
    """
    B, Hq, Tq, D = q.shape
    Hkv = k.shape[1]
    rep = Hq // Hkv
    # grouped form: never materialize the rep-fold K/V broadcast
    qg = q.reshape(B, Hkv, rep, Tq, D).astype(jnp.float32)
    scale = scale if scale is not None else D ** -0.5
    logits = jnp.einsum("bgrqd,bgkd->bgrqk", qg,
                        k.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    Tk = k.shape[2]
    if valid_len is not None and jnp.ndim(valid_len) == 1:
        # per-row valid lengths: mask [B, Tq, Tk], broadcast over heads
        endb = jnp.asarray(valid_len)[:, None, None]         # [B, 1, 1]
        qpos = jnp.arange(Tq)[None, :, None] + (endb - Tq)   # [B, Tq, 1]
        kpos = jnp.arange(Tk)[None, None, :]                 # [1, 1, Tk]
        mask = kpos < endb
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        logits = jnp.where(mask[:, None, None], logits, -1e30)
        p = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bgrqk,bgkd->bgrqd", p, v.astype(jnp.float32))
        return out.reshape(B, Hq, Tq, D).astype(q.dtype)
    end = Tk if valid_len is None else valid_len
    qpos = jnp.arange(Tq)[:, None] + (end - Tq)  # right-aligned (decode ok)
    kpos = jnp.arange(Tk)[None, :]
    mask = kpos < end
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrqk,bgkd->bgrqd", p, v.astype(jnp.float32))
    return out.reshape(B, Hq, Tq, D).astype(q.dtype)


def flash_attention_chunked(q, k, v, *, causal=True, window=None,
                            softcap=None, scale=None, valid_len=None,
                            block_q: int = 1024, block_k: int = 1024,
                            unroll: bool = False):
    """Flash attention in pure jnp (the XLA lane for long sequences).

    Online-softmax over K/V blocks; queries are processed in python-
    unrolled blocks so causal/window scheduling SKIPS fully-masked K
    blocks at the HLO level (no 2x causal FLOP waste). Full-head layout
    (K/V broadcast over the GQA group) so the head axis stays tensor-
    parallel without resharding. ``unroll`` unrolls the inner K-block
    scan — used by the dry-run cost pass for trip-true HLO accounting.
    """
    B, Hq, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    rep = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    bq = min(block_q, Tq)
    bk = min(block_k, Tk)
    tq_pad = -(-Tq // bq) * bq
    tk_pad = -(-Tk // bk) * bk
    end = Tk if valid_len is None else valid_len
    # left-pad queries (keep right alignment), right-pad keys (masked)
    qp = jnp.pad(q, ((0, 0), (0, 0), (tq_pad - Tq, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, tk_pad - Tk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, tk_pad - Tk), (0, 0)))
    if rep > 1:  # broadcast KV to full heads (fuses into the einsum)
        kp = jnp.broadcast_to(kp[:, :, None],
                              (B, Hkv, rep, tk_pad, D)).reshape(
            B, Hq, tk_pad, D)
        vp = jnp.broadcast_to(vp[:, :, None],
                              (B, Hkv, rep, tk_pad, D)).reshape(
            B, Hq, tk_pad, D)
    qg = qp * jnp.asarray(scale, qp.dtype)
    kb = jnp.moveaxis(kp.reshape(B, Hq, tk_pad // bk, bk, D), 2, 0)
    vb = jnp.moveaxis(vp.reshape(B, Hq, tk_pad // bk, bk, D), 2, 0)

    outs = []
    for qi in range(tq_pad // bq):
        qblk = qg[:, :, qi * bq:(qi + 1) * bq]           # [B, Hq, bq, D]
        qpos = (qi * bq + jnp.arange(bq) + (end - tq_pad))  # absolute
        # static block schedule (conservative: uses Tk, not valid_len)
        q_last = qi * bq + bq - 1 + (Tk - tq_pad)
        q_first = qi * bq + (Tk - tq_pad)
        lo = 0
        hi = tk_pad // bk
        if causal:
            hi = min(hi, q_last // bk + 1)
        if window is not None:
            lo = max(lo, (q_first - window + 1) // bk)
        lo = max(min(lo, hi), 0)
        if hi <= lo:
            outs.append(jnp.zeros((B, Hq, bq, D), jnp.float32))
            continue

        def body(carry, xs):
            m, l, acc = carry
            kx, vx, start = xs
            s = jnp.einsum("bhqd,bhkd->bhqk", qblk, kx,
                           preferred_element_type=jnp.float32)
            if softcap is not None:
                s = softcap * jnp.tanh(s / softcap)
            kpos = start + jnp.arange(bk)
            mask = kpos[None, :] < end
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
            if window is not None:
                mask = mask & (kpos[None, :] > qpos[:, None] - window)
            s = jnp.where(mask[None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new)
            l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
            acc = acc * alpha + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(vx.dtype), vx,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        m0 = jnp.full((B, Hq, bq, 1), -1e30, jnp.float32)
        l0 = jnp.zeros((B, Hq, bq, 1), jnp.float32)
        a0 = jnp.zeros((B, Hq, bq, D), jnp.float32)
        starts = (jnp.arange(lo, hi) * bk)
        # checkpoint the block body: backward recomputes the [bq, bk]
        # score/probability tensors instead of saving them per iteration
        # (flash-attention-style; O(T) instead of O(T^2) residuals)
        (m, l, acc), _ = lax.scan(
            jax.checkpoint(body), (m0, l0, a0),
            (kb[lo:hi], vb[lo:hi], starts),
            unroll=(hi - lo) if unroll else 1)
        outs.append(acc / jnp.where(l == 0.0, 1.0, l))

    out = jnp.concatenate(outs, axis=2)
    return out[:, :, tq_pad - Tq:].astype(q.dtype)


def ssd_chunked(x, a, b, c, *, chunk: int = 256, unroll: bool = False):
    """Chunked SSD for the XLA lane — the same matmul-form math as
    kernels/ssd_scan.py (MXU-friendly, O(T/C) sequential steps instead of
    O(T)). ``b``/``c`` are GROUP-SHARED projections [B, T, S] (Mamba2
    n_groups=1) — never broadcast over heads, which keeps the activation
    footprint at [B, T, S] instead of [B, T, H, S].
    ``unroll`` unrolls the chunk scan (dry-run cost pass)."""
    B, T, H, Pd = x.shape
    S = b.shape[-1]
    assert b.ndim == 3 and c.ndim == 3, "group-shared b/c: [B, T, S]"
    C = min(chunk, T)
    t_pad = -(-T // C) * C
    if t_pad != T:
        pad4 = ((0, 0), (0, t_pad - T), (0, 0), (0, 0))
        pad3 = ((0, 0), (0, t_pad - T), (0, 0))
        x = jnp.pad(x, pad4)
        b, c = jnp.pad(b, pad3), jnp.pad(c, pad3)
        a = jnp.pad(a, pad3)
    nc = t_pad // C

    def resh(z):  # [B, T, ...] -> [nc, B, C, ...]
        z2 = z.reshape(B, nc, C, *z.shape[2:])
        return jnp.moveaxis(z2, 1, 0)

    xs = (resh(x), resh(a), resh(b), resh(c))
    tri = (jnp.arange(C)[:, None] >= jnp.arange(C)[None, :])

    def body(h, inp):
        xc, ac, bc, cc = inp                   # [B,C,H,P] [B,C,H] [B,C,S]
        cum = jnp.cumsum(ac.astype(jnp.float32), axis=1)  # [B, C, H]
        decay = jnp.exp(cum)
        ccf = cc.astype(jnp.float32)
        bcf = bc.astype(jnp.float32)
        xcf = xc.astype(jnp.float32)
        y_state = decay[..., None] * jnp.einsum("bcs,bhsp->bchp", ccf, h)
        ratio = jnp.exp(cum[:, :, None] - cum[:, None])   # [B, C, C, H]
        cb = jnp.einsum("bcs,bks->bck", ccf, bcf)         # [B, C, C]
        M = jnp.where(tri[None, :, :, None],
                      cb[..., None] * ratio, 0.0)         # [B, C, C, H]
        y_intra = jnp.einsum("bckh,bkhp->bchp", M, xcf)
        w = jnp.exp(cum[:, -1:, :] - cum)                 # [B, C, H]
        h_new = (jnp.exp(cum[:, -1])[..., None, None] * h
                 + jnp.einsum("bcs,bch,bchp->bhsp", bcf, w, xcf))
        return h_new, (y_state + y_intra).astype(x.dtype)

    h0 = jnp.zeros((B, H, S, Pd), jnp.float32)
    # checkpoint: the [B, C, C, H] decay/mixing tensors are recomputed in
    # the backward instead of being saved per chunk (the SSD memory whale)
    _, ys = lax.scan(jax.checkpoint(body), h0, xs,
                     unroll=nc if unroll else 1)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, t_pad, H, Pd)
    return y[:, :T].astype(x.dtype)


def ssd_scan_ref(x: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
                 c: jnp.ndarray) -> jnp.ndarray:
    """Mamba2 SSD (state-space dual) oracle — sequential recurrence.

    x: [B, T, H, P]   per-head inputs
    a: [B, T, H]      log-decay per step (a_t = exp(log_a_t) in (0, 1])
    b: [B, T, H, S]   input projection onto state
    c: [B, T, H, S]   output projection
    Returns y: [B, T, H, P] with state h_t = a_t * h_{t-1} + b_t x_t^T,
    y_t = c_t^T h_t  (h: [S, P] per head).
    """
    Bt, T, H, Pd = x.shape
    S = b.shape[-1]

    def step(h, inp):
        xt, at, bt, ct = inp
        h = at[..., None, None] * h + jnp.einsum("bhs,bhp->bhsp", bt, xt)
        y = jnp.einsum("bhs,bhsp->bhp", ct, h)
        return h, y

    h0 = jnp.zeros((Bt, H, S, Pd), dtype=jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(jnp.exp(a), 1, 0).astype(jnp.float32),
          jnp.moveaxis(b, 1, 0).astype(jnp.float32),
          jnp.moveaxis(c, 1, 0).astype(jnp.float32))
    _, ys = lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)
