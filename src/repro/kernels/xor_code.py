"""Pallas TPU kernel: XOR-fold packet encoder for the coded shuffle.

Algorithm-2 hot loop: a server's coded broadcast Δ is the XOR of the
``m = k-1`` packets assigned to it (u32 bit patterns of the aggregates).
At production scale this runs once per (group, round) over multi-MB
gradient shards, so we fuse the fold into a single VMEM pass instead of
m-1 separate HLO xors over HBM.

Tiling: grid over the word dimension; each program XOR-folds an
``(m, BLOCK)`` tile held in VMEM. BLOCK is lane-aligned (multiple of 128).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["xor_encode"]

_BLOCK = 1024  # u32 words per tile; multiple of the 128-lane VPU width


def _xor_kernel(p_ref, o_ref, *, m: int):
    acc = p_ref[0]
    for i in range(1, m):  # m = k-1 is small and static: unrolled VPU xors
        acc = acc ^ p_ref[i]
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def xor_encode(packets: jnp.ndarray, *, block: int = _BLOCK,
               interpret: bool = True) -> jnp.ndarray:
    """XOR-fold ``packets: u32[m, n]`` over axis 0 -> ``u32[n]``.

    ``n`` is padded to a multiple of ``block`` (XOR identity is 0, so
    padding never leaks into real words).
    """
    if packets.dtype != jnp.uint32:
        raise TypeError("xor_encode expects uint32")
    m, n = packets.shape
    n_pad = -(-n // block) * block
    x = jnp.pad(packets, ((0, 0), (0, n_pad - n)))
    out = pl.pallas_call(
        functools.partial(_xor_kernel, m=m),
        grid=(n_pad // block,),
        in_specs=[pl.BlockSpec((m, block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.uint32),
        interpret=interpret,
    )(x)
    return out[:n]
