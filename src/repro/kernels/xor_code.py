"""Pallas TPU kernels: XOR packet codec for the coded shuffle.

Algorithm-2 hot loop, both directions:

* encode — a server's coded broadcast Δ is the XOR of the ``m = k-1``
  packets assigned to it (u32 bit patterns of the aggregates).
* decode — a receiver strips a round's broadcast down to its own packet
  by XOR-ing back the ``m`` cancellation packets it can recompute
  locally (the Lemma-2 storage condition); a boolean mask selects which
  ones apply.

At production scale these run once per (stage, round) over multi-MB
gradient shards, so the fold is fused into a single VMEM pass instead of
m-1 separate HLO xors over HBM. The batched variants carry one row per
coded group — the ShuffleProgram executors call them with the whole
per-round packet table at once.

Two kernel families (DESIGN.md §10):

* ``xor_fold`` / ``xor_decode`` — dense variants over pre-gathered
  packet tables. These are the CPU/GPU-oracle building blocks: the
  caller pays separate HBM passes to gather/replicate the packets
  before the fold ever runs.
* ``xor_encode_gather`` / ``xor_decode_gather`` — FUSED variants that
  read packets straight out of the flat chunk buffer via
  scalar-prefetched index tables (``PrefetchScalarGridSpec``). The
  gather happens in the BlockSpec index map, so each packet word moves
  HBM→VMEM exactly once and no ``[n, k, d]`` / ``[n·(k-1), k, pk]``
  intermediate is ever materialized. The decode variant additionally
  scatters each decoded round packet into its final chunk-slot row via
  a precomputed receive-selector table — the post-hoc
  ``argsort``/gather of the multipass path is baked into the schedule
  lowering.

* ``xor_encode_gather16`` / ``xor_decode_gather16`` — the PACKED
  low-precision lane (DESIGN.md §12): the same fused gathers running
  natively on the u16 view of a bf16/f16 chunk buffer (two lanes per
  u32 wire word). XOR commutes with the bit partition, so folding u16
  lane pairs is bit-identical to folding the packed u32 words; pack
  (encode output) and unpack (decode output) are same-width bitcasts —
  no 16-bit value ever widens to a 4-byte word in HBM.

Tiling: grid over (row, word-block[, source]); each program XOR-folds
lane-aligned ``(1, BLOCK)`` tiles held in VMEM. For the gather kernels
the source axis is innermost, so the output tile stays resident in VMEM
across the whole fold (one write-back per (row, block)).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["xor_encode", "xor_fold", "xor_decode",
           "xor_encode_gather", "xor_decode_gather",
           "xor_encode_gather16", "xor_decode_gather16"]

_BLOCK = 1024  # u32 words per tile; multiple of the 128-lane VPU width
_LANE = 128


def _tile(pk: int, block: int) -> tuple[int, int]:
    """Lane-aligned (block, padded_pk) for a packet width ``pk``."""
    blk = min(block, -(-pk // _LANE) * _LANE)
    return blk, -(-pk // blk) * blk


def _mask_words(mask: jnp.ndarray) -> jnp.ndarray:
    """bool -> u32 0x00000000/0xFFFFFFFF (AND-applicable mask words)."""
    return jnp.where(mask, jnp.uint32(0xFFFFFFFF), jnp.uint32(0))


def _resolve_interpret(interpret) -> bool:
    """``interpret=None`` -> compiled Mosaic on TPU, interpreter elsewhere
    (CPU/GPU have no Mosaic lowering for these kernels)."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def _xor_kernel(p_ref, o_ref, *, m: int):
    acc = p_ref[0]
    for i in range(1, m):  # m = k-1 is small and static: unrolled VPU xors
        acc = acc ^ p_ref[i]
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def xor_encode(packets: jnp.ndarray, *, block: int = _BLOCK,
               interpret: bool | None = None) -> jnp.ndarray:
    """XOR-fold ``packets: u32[m, n]`` over axis 0 -> ``u32[n]``.

    ``n`` is padded to a multiple of ``block`` (XOR identity is 0, so
    padding never leaks into real words).
    """
    if packets.dtype != jnp.uint32:
        raise TypeError("xor_encode expects uint32")
    interpret = _resolve_interpret(interpret)
    m, n = packets.shape
    n_pad = -(-n // block) * block
    x = jnp.pad(packets, ((0, 0), (0, n_pad - n)))
    out = pl.pallas_call(
        functools.partial(_xor_kernel, m=m),
        grid=(n_pad // block,),
        in_specs=[pl.BlockSpec((m, block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.uint32),
        interpret=interpret,
    )(x)
    return out[:n]


def _fold_kernel(p_ref, o_ref, *, m: int):
    acc = p_ref[0, 0]
    for i in range(1, m):
        acc = acc ^ p_ref[0, i]
    o_ref[0] = acc


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def xor_fold(packets: jnp.ndarray, *, block: int = _BLOCK,
             interpret: bool | None = None) -> jnp.ndarray:
    """Batched encode: ``u32[R, m, n]`` -> ``u32[R, n]`` (fold axis 1).

    Row ``r`` is one coded group's packet set; the grid runs one program
    per (row, word-block) so every fold is a single VMEM pass.
    """
    if packets.dtype != jnp.uint32:
        raise TypeError("xor_fold expects uint32")
    interpret = _resolve_interpret(interpret)
    R, m, n = packets.shape
    n_pad = -(-n // block) * block
    x = jnp.pad(packets, ((0, 0), (0, 0), (0, n_pad - n)))
    out = pl.pallas_call(
        functools.partial(_fold_kernel, m=m),
        grid=(R, n_pad // block),
        in_specs=[pl.BlockSpec((1, m, block), lambda i, j: (i, 0, j))],
        out_specs=pl.BlockSpec((1, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, n_pad), jnp.uint32),
        interpret=interpret,
    )(x)
    return out[:, :n]


def _decode_kernel(r_ref, p_ref, m_ref, o_ref, *, m: int):
    acc = r_ref[0]
    for i in range(m):
        # m_ref holds 0x00000000 / 0xFFFFFFFF: AND applies the mask
        acc = acc ^ (p_ref[0, i] & m_ref[0, i])
    o_ref[0] = acc


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def xor_decode(recv: jnp.ndarray, packets: jnp.ndarray,
               mask: jnp.ndarray, *, block: int = _BLOCK,
               interpret: bool | None = None) -> jnp.ndarray:
    """Batched decode: ``recv ^ XOR_i(packets[:, i] where mask[:, i])``.

    ``recv: u32[R, n]`` round broadcasts, ``packets: u32[R, m, n]``
    locally recomputed cancellation packets, ``mask: bool[R, m]``
    selects the ones that participate. Returns ``u32[R, n]`` — the
    receiver's own packet per row (Lemma 2 decode).
    """
    if recv.dtype != jnp.uint32 or packets.dtype != jnp.uint32:
        raise TypeError("xor_decode expects uint32")
    interpret = _resolve_interpret(interpret)
    R, m, n = packets.shape
    if recv.shape != (R, n):
        raise ValueError(f"recv shape {recv.shape} != {(R, n)}")
    if mask.shape != (R, m):
        raise ValueError(f"mask shape {mask.shape} != {(R, m)}")
    n_pad = -(-n // block) * block
    rv = jnp.pad(recv, ((0, 0), (0, n_pad - n)))
    pk = jnp.pad(packets, ((0, 0), (0, 0), (0, n_pad - n)))
    mk = jnp.where(mask, jnp.uint32(0xFFFFFFFF), jnp.uint32(0))
    out = pl.pallas_call(
        functools.partial(_decode_kernel, m=m),
        grid=(R, n_pad // block),
        in_specs=[
            pl.BlockSpec((1, block), lambda i, j: (i, j)),
            pl.BlockSpec((1, m, block), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, m), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, n_pad), jnp.uint32),
        interpret=interpret,
    )(rv, pk, mk)
    return out[:, :n]


# --------------------------------------------------------------------- #
# fused gather-XOR codec (single-pass encode/decode, DESIGN.md §10)
# --------------------------------------------------------------------- #
def _encode_gather_kernel(idx_ref, msk_ref, chunk_ref, o_ref):
    i = pl.program_id(0)
    j = pl.program_id(2)
    term = chunk_ref[...] & msk_ref[i, j]

    @pl.when(j == 0)
    def _init():
        o_ref[...] = term

    @pl.when(j > 0)
    def _fold():
        o_ref[...] ^= term


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def xor_encode_gather(chunks: jnp.ndarray, idx: jnp.ndarray,
                      mask: jnp.ndarray, *, block: int = _BLOCK,
                      interpret: bool | None = None) -> jnp.ndarray:
    """Fused gather + XOR-fold encode:
    ``out[i] = XOR_j { chunks[idx[i, j]] : mask[i, j] }``.

    ``chunks: u32[P, pk]`` is the flat packet view of the local chunk
    buffer (``u32.reshape(-1, pk)`` — free); ``idx: i32[n, m]`` holds
    flat packet-row sources (``enc_src`` of the schedule lowering) and
    ``mask: bool[n, m]`` their validity. Invalid entries must carry an
    in-range index (the lowering bakes 0) — they are AND-masked to the
    XOR identity inside VMEM, never branched on.

    The gather IS the block index map (scalar-prefetched tables), so
    encode reads each needed chunk word from HBM exactly once and
    writes Δ once: one pass, vs gather → reshape → take_along_axis →
    fold (3 HBM round trips) in the multipass path.
    """
    if chunks.dtype != jnp.uint32:
        raise TypeError("xor_encode_gather expects uint32")
    interpret = _resolve_interpret(interpret)
    n, m = idx.shape
    if mask.shape != (n, m):
        raise ValueError(f"mask shape {mask.shape} != {(n, m)}")
    pk = chunks.shape[1]
    blk, pkp = _tile(pk, block)
    x = jnp.pad(chunks, ((0, 0), (0, pkp - pk)))
    out = pl.pallas_call(
        _encode_gather_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(n, pkp // blk, m),
            in_specs=[
                pl.BlockSpec((1, blk), lambda i, b, j, idx_r, msk_r:
                             (idx_r[i, j], b)),
            ],
            out_specs=pl.BlockSpec((1, blk), lambda i, b, j, *_: (i, b)),
        ),
        out_shape=jax.ShapeDtypeStruct((n, pkp), jnp.uint32),
        interpret=interpret,
    )(idx.astype(jnp.int32), _mask_words(mask), x)
    return out[:, :pk]


def _decode_gather_kernel(rsel_ref, idx_ref, msk_ref, recv_ref, chunk_ref,
                          o_ref):
    i = pl.program_id(0)
    j = pl.program_id(2)
    term = chunk_ref[...] & msk_ref[i, j]

    @pl.when(j == 0)
    def _init():
        o_ref[...] = recv_ref[...] ^ term

    @pl.when(j > 0)
    def _fold():
        o_ref[...] ^= term


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def xor_decode_gather(recv: jnp.ndarray, chunks: jnp.ndarray,
                      rsel: jnp.ndarray, idx: jnp.ndarray,
                      mask: jnp.ndarray, *, block: int = _BLOCK,
                      interpret: bool | None = None) -> jnp.ndarray:
    """Fused gather + XOR decode + chunk-slot scatter:
    ``out[i] = recv[rsel[i]] ^ XOR_j { chunks[idx[i, j]] : mask[i, j] }``.

    Output row ``i`` is a CHUNK SLOT (row-major ``(group, slot)``), not
    a broadcast round: ``rsel: i32[R]`` (``dec_recv`` of the schedule
    lowering) selects which received round packet lands in each slot —
    the lowering bakes ``argsort(dec_gather)`` into it, so the
    multipass path's per-trace argsort + post-hoc ``take_along_axis``
    disappear. ``idx/mask: [R, m]`` name the cancellation packets as
    flat rows of ``chunks: u32[P, pk]`` (the same flat chunk buffer the
    encode reads — the ``[n, k, k-1, pk]`` packet table and the
    ``(k-1)×``-replicated ``[n, k-1, k, k-1, pk]`` cancellation buffer
    of the multipass path are never built).

    Single pass: every cancellation word moves HBM→VMEM once via the
    scalar-prefetched index maps, each output row is written once, in
    final chunk order.
    """
    if recv.dtype != jnp.uint32 or chunks.dtype != jnp.uint32:
        raise TypeError("xor_decode_gather expects uint32")
    interpret = _resolve_interpret(interpret)
    R, m = idx.shape
    pk = chunks.shape[1]
    if recv.shape[1] != pk:
        raise ValueError(f"recv width {recv.shape[1]} != chunks width {pk}")
    if rsel.shape != (R,):
        raise ValueError(f"rsel shape {rsel.shape} != {(R,)}")
    if mask.shape != (R, m):
        raise ValueError(f"mask shape {mask.shape} != {(R, m)}")
    blk, pkp = _tile(pk, block)
    rv = jnp.pad(recv, ((0, 0), (0, pkp - pk)))
    x = jnp.pad(chunks, ((0, 0), (0, pkp - pk)))
    out = pl.pallas_call(
        _decode_gather_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(R, pkp // blk, m),
            in_specs=[
                pl.BlockSpec((1, blk), lambda i, b, j, rsel_r, *_:
                             (rsel_r[i], b)),
                pl.BlockSpec((1, blk), lambda i, b, j, rsel_r, idx_r, msk_r:
                             (idx_r[i, j], b)),
            ],
            out_specs=pl.BlockSpec((1, blk), lambda i, b, j, *_: (i, b)),
        ),
        out_shape=jax.ShapeDtypeStruct((R, pkp), jnp.uint32),
        interpret=interpret,
    )(rsel.astype(jnp.int32), idx.astype(jnp.int32), _mask_words(mask),
      rv, x)
    return out[:, :pk]


# --------------------------------------------------------------------- #
# packed 16-bit lane (pack/unpack-fused gather kernels, DESIGN.md §12)
#
# bf16/f16 payloads ride the codec as PAIRS of 16-bit lanes per u32
# wire word. XOR commutes with any bit partition, so the fold can run
# natively on the u16 view of the half-precision chunk buffer — the
# "pack" into wire words is a same-width bitcast of the kernel output,
# never a widening: no value ever occupies 4 bytes in HBM on this lane
# (the unpacked-u32 transient a cast-to-f32 shuffle would pay).
# Tables are the SAME d-independent packet-row indices as the u32
# kernels; only the lane count per packet doubles.
# --------------------------------------------------------------------- #
def _mask_words16(mask: jnp.ndarray) -> jnp.ndarray:
    """bool -> u16 0x0000/0xFFFF (AND-applicable mask lanes)."""
    return jnp.where(mask, jnp.uint16(0xFFFF), jnp.uint16(0))


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def xor_encode_gather16(chunks: jnp.ndarray, idx: jnp.ndarray,
                        mask: jnp.ndarray, *, block: int = _BLOCK,
                        interpret: bool | None = None) -> jnp.ndarray:
    """Packed-lane fused encode: :func:`xor_encode_gather` over a u16
    chunk buffer ``u16[P, 2*pk]`` (the bitcast view of the padded
    bf16/f16 contributions — see ``collective._wire_buffer``).

    Returns ``u16[n, 2*pk]``; the caller bitcasts lane pairs to the
    ``u32[n, pk]`` wire Δ (a same-width reinterpretation — the pack is
    fused in the sense that no widened per-value word is ever
    materialized).
    """
    if chunks.dtype != jnp.uint16:
        raise TypeError("xor_encode_gather16 expects uint16")
    interpret = _resolve_interpret(interpret)
    n, m = idx.shape
    if mask.shape != (n, m):
        raise ValueError(f"mask shape {mask.shape} != {(n, m)}")
    pk2 = chunks.shape[1]
    if pk2 % 2:
        raise ValueError(f"packed packet lane count must be even, got "
                         f"{pk2}")
    blk, pkp = _tile(pk2, block)
    x = jnp.pad(chunks, ((0, 0), (0, pkp - pk2)))
    out = pl.pallas_call(
        _encode_gather_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(n, pkp // blk, m),
            in_specs=[
                pl.BlockSpec((1, blk), lambda i, b, j, idx_r, msk_r:
                             (idx_r[i, j], b)),
            ],
            out_specs=pl.BlockSpec((1, blk), lambda i, b, j, *_: (i, b)),
        ),
        out_shape=jax.ShapeDtypeStruct((n, pkp), jnp.uint16),
        interpret=interpret,
    )(idx.astype(jnp.int32), _mask_words16(mask), x)
    return out[:, :pk2]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def xor_decode_gather16(recv: jnp.ndarray, chunks: jnp.ndarray,
                        rsel: jnp.ndarray, idx: jnp.ndarray,
                        mask: jnp.ndarray, *, block: int = _BLOCK,
                        interpret: bool | None = None) -> jnp.ndarray:
    """Packed-lane fused decode: :func:`xor_decode_gather` with the
    received wire words viewed as u16 lane pairs (``recv: u16[R,
    2*pk]``) and cancellation packets read straight from the u16 chunk
    buffer. Output rows are chunk slots in 16-bit lanes — the caller's
    unpack is a slice + same-width bitcast, so the decoded payload
    never round-trips through a widened word buffer.
    """
    if recv.dtype != jnp.uint16 or chunks.dtype != jnp.uint16:
        raise TypeError("xor_decode_gather16 expects uint16")
    interpret = _resolve_interpret(interpret)
    R, m = idx.shape
    pk2 = chunks.shape[1]
    if recv.shape[1] != pk2:
        raise ValueError(f"recv width {recv.shape[1]} != chunks width "
                         f"{pk2}")
    if rsel.shape != (R,):
        raise ValueError(f"rsel shape {rsel.shape} != {(R,)}")
    if mask.shape != (R, m):
        raise ValueError(f"mask shape {mask.shape} != {(R, m)}")
    blk, pkp = _tile(pk2, block)
    rv = jnp.pad(recv, ((0, 0), (0, pkp - pk2)))
    x = jnp.pad(chunks, ((0, 0), (0, pkp - pk2)))
    out = pl.pallas_call(
        _decode_gather_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(R, pkp // blk, m),
            in_specs=[
                pl.BlockSpec((1, blk), lambda i, b, j, rsel_r, *_:
                             (rsel_r[i], b)),
                pl.BlockSpec((1, blk), lambda i, b, j, rsel_r, idx_r, msk_r:
                             (idx_r[i, j], b)),
            ],
            out_specs=pl.BlockSpec((1, blk), lambda i, b, j, *_: (i, b)),
        ),
        out_shape=jax.ShapeDtypeStruct((R, pkp), jnp.uint16),
        interpret=interpret,
    )(rsel.astype(jnp.int32), idx.astype(jnp.int32), _mask_words16(mask),
      rv, x)
    return out[:, :pk2]
