"""Pallas TPU kernels: XOR packet codec for the coded shuffle.

Algorithm-2 hot loop, both directions:

* encode — a server's coded broadcast Δ is the XOR of the ``m = k-1``
  packets assigned to it (u32 bit patterns of the aggregates).
* decode — a receiver strips a round's broadcast down to its own packet
  by XOR-ing back the ``m`` cancellation packets it can recompute
  locally (the Lemma-2 storage condition); a boolean mask selects which
  ones apply.

At production scale these run once per (stage, round) over multi-MB
gradient shards, so the fold is fused into a single VMEM pass instead of
m-1 separate HLO xors over HBM. The batched variants carry one row per
coded group — the ShuffleProgram executors call them with the whole
per-round packet table at once.

Tiling: grid over (row, word-block); each program XOR-folds an
``(m, BLOCK)`` tile held in VMEM. BLOCK is lane-aligned (multiple of 128).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["xor_encode", "xor_fold", "xor_decode"]

_BLOCK = 1024  # u32 words per tile; multiple of the 128-lane VPU width


def _resolve_interpret(interpret) -> bool:
    """``interpret=None`` -> compiled Mosaic on TPU, interpreter elsewhere
    (CPU/GPU have no Mosaic lowering for these kernels)."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def _xor_kernel(p_ref, o_ref, *, m: int):
    acc = p_ref[0]
    for i in range(1, m):  # m = k-1 is small and static: unrolled VPU xors
        acc = acc ^ p_ref[i]
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def xor_encode(packets: jnp.ndarray, *, block: int = _BLOCK,
               interpret: bool | None = None) -> jnp.ndarray:
    """XOR-fold ``packets: u32[m, n]`` over axis 0 -> ``u32[n]``.

    ``n`` is padded to a multiple of ``block`` (XOR identity is 0, so
    padding never leaks into real words).
    """
    if packets.dtype != jnp.uint32:
        raise TypeError("xor_encode expects uint32")
    interpret = _resolve_interpret(interpret)
    m, n = packets.shape
    n_pad = -(-n // block) * block
    x = jnp.pad(packets, ((0, 0), (0, n_pad - n)))
    out = pl.pallas_call(
        functools.partial(_xor_kernel, m=m),
        grid=(n_pad // block,),
        in_specs=[pl.BlockSpec((m, block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.uint32),
        interpret=interpret,
    )(x)
    return out[:n]


def _fold_kernel(p_ref, o_ref, *, m: int):
    acc = p_ref[0, 0]
    for i in range(1, m):
        acc = acc ^ p_ref[0, i]
    o_ref[0] = acc


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def xor_fold(packets: jnp.ndarray, *, block: int = _BLOCK,
             interpret: bool | None = None) -> jnp.ndarray:
    """Batched encode: ``u32[R, m, n]`` -> ``u32[R, n]`` (fold axis 1).

    Row ``r`` is one coded group's packet set; the grid runs one program
    per (row, word-block) so every fold is a single VMEM pass.
    """
    if packets.dtype != jnp.uint32:
        raise TypeError("xor_fold expects uint32")
    interpret = _resolve_interpret(interpret)
    R, m, n = packets.shape
    n_pad = -(-n // block) * block
    x = jnp.pad(packets, ((0, 0), (0, 0), (0, n_pad - n)))
    out = pl.pallas_call(
        functools.partial(_fold_kernel, m=m),
        grid=(R, n_pad // block),
        in_specs=[pl.BlockSpec((1, m, block), lambda i, j: (i, 0, j))],
        out_specs=pl.BlockSpec((1, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, n_pad), jnp.uint32),
        interpret=interpret,
    )(x)
    return out[:, :n]


def _decode_kernel(r_ref, p_ref, m_ref, o_ref, *, m: int):
    acc = r_ref[0]
    for i in range(m):
        # m_ref holds 0x00000000 / 0xFFFFFFFF: AND applies the mask
        acc = acc ^ (p_ref[0, i] & m_ref[0, i])
    o_ref[0] = acc


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def xor_decode(recv: jnp.ndarray, packets: jnp.ndarray,
               mask: jnp.ndarray, *, block: int = _BLOCK,
               interpret: bool | None = None) -> jnp.ndarray:
    """Batched decode: ``recv ^ XOR_i(packets[:, i] where mask[:, i])``.

    ``recv: u32[R, n]`` round broadcasts, ``packets: u32[R, m, n]``
    locally recomputed cancellation packets, ``mask: bool[R, m]``
    selects the ones that participate. Returns ``u32[R, n]`` — the
    receiver's own packet per row (Lemma 2 decode).
    """
    if recv.dtype != jnp.uint32 or packets.dtype != jnp.uint32:
        raise TypeError("xor_decode expects uint32")
    interpret = _resolve_interpret(interpret)
    R, m, n = packets.shape
    if recv.shape != (R, n):
        raise ValueError(f"recv shape {recv.shape} != {(R, n)}")
    if mask.shape != (R, m):
        raise ValueError(f"mask shape {mask.shape} != {(R, m)}")
    n_pad = -(-n // block) * block
    rv = jnp.pad(recv, ((0, 0), (0, n_pad - n)))
    pk = jnp.pad(packets, ((0, 0), (0, 0), (0, n_pad - n)))
    mk = jnp.where(mask, jnp.uint32(0xFFFFFFFF), jnp.uint32(0))
    out = pl.pallas_call(
        functools.partial(_decode_kernel, m=m),
        grid=(R, n_pad // block),
        in_specs=[
            pl.BlockSpec((1, block), lambda i, j: (i, j)),
            pl.BlockSpec((1, m, block), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, m), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, n_pad), jnp.uint32),
        interpret=interpret,
    )(rv, pk, mk)
    return out[:, :n]
