"""Pallas TPU kernel: blockwise flash attention (online softmax).

Dominant train/prefill FLOPs of every LM architecture in the zoo. Supports
the features the assigned archs need: causal masking, GQA (grouped KV
heads), sliding windows (mixtral/gemma2 local layers), gemma2 logit
soft-capping, and right-aligned decode (Tq << Tk against a KV cache).

Tiling: grid (B, Hq, nq, nk), nk innermost. Q/O tiles [BLOCK_Q, D] stay in
VMEM with f32 running (m, l, acc) scratch across the nk loop; K/V stream
through VMEM in [BLOCK_K, D] tiles. Fully-masked K blocks are skipped via
pl.when on the block indices (causal upper triangle / outside the window).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

_BLOCK_Q = 256
_BLOCK_K = 256
_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int | None,
                  softcap: float | None, block_q: int, block_k: int,
                  tq: int, tk: int):
    iq, ik = pl.program_id(2), pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # absolute positions (right-aligned queries for decode)
    q_pos = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + (tk - tq)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    # block-level skip: is any (q, k) pair in this tile visible?
    q_blk_last = iq * block_q + block_q - 1 + (tk - tq)
    q_blk_first = iq * block_q + (tk - tq)
    k_blk_first = ik * block_k
    k_blk_last = ik * block_k + block_k - 1
    live = True
    if causal:
        live = k_blk_first <= q_blk_last
    if window is not None:
        live = jnp.logical_and(live, k_blk_last > q_blk_first - window)

    @pl.when(live)
    def _():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # [bq, D]
        k = k_ref[0, 0].astype(jnp.float32)              # [bk, D]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = k_pos < tk  # padded keys (positions >= tk) are never valid
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_scr[...]                              # [bq, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                           # [bq, bk]
        v = v_ref[0, 0].astype(jnp.float32)              # [bk, D]
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _():
        l = l_scr[...]
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "scale",
                              "block_q", "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int | None = None,
                    softcap: float | None = None,
                    scale: float | None = None,
                    block_q: int = _BLOCK_Q, block_k: int = _BLOCK_K,
                    interpret: bool = True) -> jnp.ndarray:
    """q: [B, Hq, Tq, D]; k, v: [B, Hkv, Tk, D] -> [B, Hq, Tq, D]."""
    B, Hq, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    if Hq % Hkv:
        raise ValueError(f"Hq={Hq} must be a multiple of Hkv={Hkv}")
    rep = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    bq = min(block_q, Tq)
    bk = min(block_k, Tk)
    tq_pad = -(-Tq // bq) * bq
    tk_pad = -(-Tk // bk) * bk
    # pad queries on the LEFT (keep right alignment), keys on the right;
    # padded key rows are masked because padded q rows only ADD rows whose
    # outputs are dropped, and key padding is handled by the causal/window
    # mask against real positions when causal; for non-causal we mask via
    # l==0 guard + explicit key validity below.
    if tk_pad != Tk:
        # appended keys get positions >= Tk and are masked in-kernel
        k = jnp.pad(k, ((0, 0), (0, 0), (0, tk_pad - Tk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, tk_pad - Tk), (0, 0)))
    if tq_pad != Tq:
        q = jnp.pad(q, ((0, 0), (0, 0), (tq_pad - Tq, 0), (0, 0)))

    grid = (B, Hq, tq_pad // bq, tk_pad // bk)
    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, causal=causal, window=window,
            softcap=softcap, block_q=bq, block_k=bk, tq=tq_pad, tk=Tk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j: (b, h // rep, j, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j: (b, h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, tq_pad, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, tq_pad - Tq:, :]
