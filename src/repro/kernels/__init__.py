"""Pallas TPU kernels (interpret-validated on CPU) + jnp reference oracles.

Layout per kernel: ``<name>.py`` holds the ``pl.pallas_call`` + BlockSpec
tiling; ``ref.py`` the pure-jnp oracle; ``ops.py`` the jit'd dispatch
wrappers the models call.
"""

from . import ops, ref
from .aggregate import aggregate
from .flash_attention import flash_attention
from .ssd_scan import ssd_scan
from .xor_code import xor_encode, xor_fold, xor_decode

__all__ = ["ops", "ref", "aggregate", "flash_attention", "ssd_scan",
           "xor_encode", "xor_fold", "xor_decode"]
