"""Jit'd dispatch wrappers: Pallas kernel on TPU, pure-jnp path elsewhere.

Models call these entry points; the ``use_pallas`` switch lives in the
arch config (``ModelConfig.use_pallas``). On the CPU host (dry-run, smoke
tests) the jnp path lowers to plain XLA HLO — same math, honest
cost_analysis. On TPU the Pallas kernels take over (interpret=False).
Interpret-mode execution of the kernels is exercised by tests/test_kernels
against the ref oracles.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .aggregate import aggregate as aggregate_pallas
from .flash_attention import flash_attention as flash_attention_pallas
from .ssd_scan import ssd_scan as ssd_scan_pallas
from .xor_code import xor_encode as xor_encode_pallas

__all__ = ["attention", "ssd", "combine_aggregates", "xor_fold",
           "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


_CHUNK_THRESHOLD = 2 ** 21  # Tq*Tk above which the XLA path chunks


def attention(q, k, v, *, causal=True, window=None, softcap=None,
              scale=None, valid_len=None, use_pallas=False,
              block_q=1024, block_k=1024, unroll=False):
    """Unified attention entry (see flash_attention / ref docstrings).

    Routing: Pallas kernel on TPU (or interpret in kernel tests); on the
    XLA lane, long sequences use the chunked flash (block-skipping)
    implementation, short ones the materialized oracle. ``valid_len``
    (partial-cache decode, Tq ~ 1) uses the materialized path — its
    score matrix is only [B, H, Tq, Tk].
    """
    if use_pallas and valid_len is None:
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, interpret=not on_tpu())
    Tq, Tk = q.shape[2], k.shape[2]
    if valid_len is None and Tq * Tk > _CHUNK_THRESHOLD:
        return ref.flash_attention_chunked(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, block_q=block_q, block_k=block_k, unroll=unroll)
    return ref.flash_attention_ref(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        valid_len=valid_len)


def ssd(x, a, b, c, *, use_pallas=False, chunk=256, unroll=False):
    """Unified Mamba2 SSD entry (chunked matmul form on the XLA lane).

    ``b``/``c`` may be group-shared [B, T, S] (preferred — smaller
    activations) or per-head [B, T, H, S] (broadcast for the Pallas
    kernel / oracle)."""
    if use_pallas:
        if b.ndim == 3:
            H = x.shape[2]
            b = jnp.broadcast_to(b[:, :, None], (*b.shape[:2], H,
                                                 b.shape[-1]))
            c = jnp.broadcast_to(c[:, :, None], (*c.shape[:2], H,
                                                 c.shape[-1]))
        return ssd_scan_pallas(x, a, b, c, chunk=chunk,
                               interpret=not on_tpu())
    if b.ndim == 4:  # per-head inputs: fall back to the oracle
        return ref.ssd_scan_ref(x, a, b, c)
    return ref.ssd_chunked(x, a, b, c, chunk=chunk, unroll=unroll)


def combine_aggregates(values, segment_ids, num_segments, *,
                       use_pallas=False):
    """α-combiner used by the CAMR map phase."""
    if use_pallas:
        return aggregate_pallas(values, segment_ids, num_segments,
                                interpret=not on_tpu())
    return ref.aggregate_ref(values, segment_ids, num_segments)


def xor_fold(packets, *, use_pallas=False):
    """Algorithm-2 Δ encoder."""
    if use_pallas:
        return xor_encode_pallas(packets, interpret=not on_tpu())
    return ref.xor_encode_ref(packets)
