"""Pallas TPU kernel: Mamba2 SSD (state-space duality) chunked scan.

Dominant op of the mamba2/zamba2 architectures. The recurrence
    h_t = a_t * h_{t-1} + b_t x_t^T,   y_t = c_t^T h_t
is evaluated chunk-wise (the SSD trick, arXiv:2405.21060): within a chunk
of length C everything is dense matmuls on the MXU —

    y_intra = (tril(C B^T) * decay ratio) X          [C, C] @ [C, P]
    y_state = decay * (C h_prev)                     [C, S] @ [S, P]
    h_next  = decay_end * h_prev + (B * ratio)^T X   [S, C] @ [C, P]

Tiling: grid (B, H, n_chunks) with the chunk axis innermost/sequential;
the [S, P] state is carried across chunks in f32 VMEM scratch. This is
the TPU-native adaptation of the paper-aggregation idea: intermediate
per-timestep values are combined into per-chunk aggregates before they
ever leave the compute unit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan"]

_CHUNK = 256


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, h_scr, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, 0].astype(jnp.float32)          # [C, P]
    a = a_ref[0, 0].astype(jnp.float32)          # [C]   (log decay)
    b = b_ref[0, 0].astype(jnp.float32)          # [C, S]
    c = c_ref[0, 0].astype(jnp.float32)          # [C, S]

    cum = jnp.cumsum(a)                          # log prod_{s<=t} a_s
    decay = jnp.exp(cum)                         # [C]
    h_prev = h_scr[...]                          # [S, P]

    # inter-chunk: y_state[t] = decay[t] * c_t . h_prev
    y_state = decay[:, None] * jax.lax.dot_general(
        c, h_prev, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)      # [C, P]

    # intra-chunk: M[t, s] = (c_t . b_s) * exp(cum[t] - cum[s]), s <= t
    ratio = jnp.exp(cum[:, None] - cum[None, :])  # [C, C]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    M = jnp.where(tri, cb * ratio, 0.0)
    y_intra = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    y_ref[0, 0] = (y_state + y_intra).astype(y_ref.dtype)

    # state update: h = decay[-1] h_prev + sum_s (decay[-1]/decay[s]) b_s x_s^T
    w = jnp.exp(cum[-1] - cum)                   # [C]
    bw = b * w[:, None]                          # [C, S]
    h_scr[...] = decay[-1] * h_prev + jax.lax.dot_general(
        bw, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
             c: jnp.ndarray, *, chunk: int = _CHUNK,
             interpret: bool = True) -> jnp.ndarray:
    """SSD scan. x: [B, T, H, P], a: [B, T, H] (log-decay),
    b, c: [B, T, H, S] -> y: [B, T, H, P]. T must divide by ``chunk``
    (padded otherwise; padding uses a = 0 -> decay 1, x = 0)."""
    B, T, H, Pd = x.shape
    S = b.shape[-1]
    C = min(chunk, T)
    t_pad = -(-T // C) * C
    if t_pad != T:
        pad = ((0, 0), (0, t_pad - T), (0, 0), (0, 0))
        x = jnp.pad(x, pad)
        b = jnp.pad(b, pad[:3] + ((0, 0),)) if False else jnp.pad(b, pad)
        c = jnp.pad(c, pad)
        a = jnp.pad(a, ((0, 0), (0, t_pad - T), (0, 0)))
    # layout: [B, H, T, *] so (batch, head) are leading grid axes
    xt = jnp.moveaxis(x, 2, 1)                   # [B, H, T, P]
    bt = jnp.moveaxis(b, 2, 1)
    ct = jnp.moveaxis(c, 2, 1)
    at = jnp.moveaxis(a, 2, 1)                   # [B, H, T]

    grid = (B, H, t_pad // C)
    y = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=C),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, C, Pd), lambda i, j, k: (i, j, k, 0)),
            pl.BlockSpec((1, 1, C), lambda i, j, k: (i, j, k)),
            pl.BlockSpec((1, 1, C, S), lambda i, j, k: (i, j, k, 0)),
            pl.BlockSpec((1, 1, C, S), lambda i, j, k: (i, j, k, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, C, Pd), lambda i, j, k: (i, j, k, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, t_pad, Pd), x.dtype),
        scratch_shapes=[pltpu.VMEM((S, Pd), jnp.float32)],
        interpret=interpret,
    )(xt, at, bt, ct)
    return jnp.moveaxis(y, 1, 2)[:, :T]
