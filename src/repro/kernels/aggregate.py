"""Pallas TPU kernel: the α-combiner (batched segment-sum of map outputs).

The Map phase ends by aggregating intermediate values that share a
(function, batch) key — the paper's "compression" step that all three
shuffle stages rely on. On TPU we express the segment-sum as a sequence of
one-hot matmuls so the MXU does the reduction:

    out[S, d] += onehot(ids_block)^T @ values_block

Tiling: grid (d-blocks, n-blocks) with the n axis innermost so the output
tile stays resident in VMEM and accumulates across n-blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["aggregate"]

_BLOCK_N = 256
_BLOCK_D = 512


def _agg_kernel(v_ref, ids_ref, o_ref, *, num_segments: int,
                block_n: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    ids = ids_ref[...]                               # [block_n]
    onehot = (ids[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (block_n, num_segments), 1))      # [block_n, S]
    vals = v_ref[...].astype(jnp.float32)            # [block_n, block_d]
    o_ref[...] += jax.lax.dot_general(
        onehot.astype(jnp.float32), vals,
        (((0,), (0,)), ((), ())),                    # contract over n
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("num_segments", "block_n", "block_d",
                                    "interpret"))
def aggregate(values: jnp.ndarray, segment_ids: jnp.ndarray,
              num_segments: int, *, block_n: int = _BLOCK_N,
              block_d: int = _BLOCK_D, interpret: bool | None = None
              ) -> jnp.ndarray:
    """Segment-sum ``values: [n, d]`` by ``segment_ids: [n] -> [S, d]``.

    Out-of-range ids (used for padding) contribute nothing.
    ``interpret=None`` compiles the kernel on TPU backends and falls
    back to Pallas interpret mode elsewhere (same policy as the codec
    kernels). When every segment holds exactly one row (the trainer's
    gamma=1 map lane) the one-hot matmul is an exact gather — adding
    0-products cannot perturb finite f32 values — so the combiner is
    bit-transparent there.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, d = values.shape
    n_pad = -(-n // block_n) * block_n
    d_pad = -(-d // block_d) * block_d
    v = jnp.pad(values, ((0, n_pad - n), (0, d_pad - d)))
    ids = jnp.pad(segment_ids.astype(jnp.int32), (0, n_pad - n),
                  constant_values=-1)  # -1 never matches the iota
    out = pl.pallas_call(
        functools.partial(_agg_kernel, num_segments=num_segments,
                          block_n=block_n),
        grid=(d_pad // block_d, n_pad // block_n),
        in_specs=[
            pl.BlockSpec((block_n, block_d), lambda i, j: (j, i)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((num_segments, block_d),
                               lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((num_segments, d_pad), jnp.float32),
        interpret=interpret,
    )(v, ids)
    return out[:, :d].astype(values.dtype)
