"""Data pipeline: deterministic, shardable, restart-safe.

Two producers:

* :class:`ShardedTokenPipeline` — synthetic LM token streams. Every batch
  is a pure function of (seed, step, shard), so a restarted job resumes
  bit-identically from the checkpointed step (fault tolerance includes
  the data order), and every data-parallel worker slices its own shard
  without coordination.
* :func:`make_camr_job_datasets` — the J-jobs x N-subfiles layout the
  CAMR engine consumes (paper Example 1 word-count corpora, or gradient
  microbatch groups for the training integration).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class ShardedTokenPipeline:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1
    # markov-ish structure so losses actually decrease during examples
    structure: float = 0.7

    def batch(self, step: int, shard: int = 0) -> dict:
        """Returns tokens/labels [B/n_shards, seq_len] for (step, shard)."""
        if self.global_batch % self.n_shards:
            raise ValueError("global_batch must divide by n_shards")
        b = self.global_batch // self.n_shards
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard]))
        # structured stream: next token = f(prev) with prob `structure`
        base = rng.integers(0, self.vocab, size=(b, self.seq_len + 1))
        shifted = (base[:, :-1] * 31 + 7) % self.vocab
        coin = rng.random((b, self.seq_len)) < self.structure
        seq = np.concatenate(
            [base[:, :1], np.where(coin, shifted, base[:, 1:])], axis=1)
        return {"tokens": seq[:, :-1].astype(np.int32),
                "labels": seq[:, 1:].astype(np.int32)}

    def microbatches(self, step: int, shard: int, n: int) -> list[dict]:
        """Split the shard's batch into n gradient-accumulation groups."""
        full = self.batch(step, shard)
        b = full["tokens"].shape[0]
        if b % n:
            raise ValueError("shard batch must divide by microbatches")
        return [{k: v[i * (b // n):(i + 1) * (b // n)]
                 for k, v in full.items()} for i in range(n)]


def wordcount_corpus(J: int, N: int, Q: int, *, chapter_len: int = 50,
                     seed: int = 0) -> list[list[np.ndarray]]:
    """Paper Example 1: J books of N chapters over a Q-word vocabulary."""
    rng = np.random.default_rng(seed)
    return [[rng.integers(0, Q, size=chapter_len) for _ in range(N)]
            for _ in range(J)]


def make_camr_job_datasets(pipeline: ShardedTokenPipeline, J: int, N: int,
                           step: int) -> list[list[dict]]:
    """J jobs x N subfiles of LM batches (multi-model training: job j is
    model j's step data; subfile n is one map task's microbatch)."""
    out = []
    for j in range(J):
        subs = []
        for n in range(N):
            subs.append(pipeline.batch(step * J * N + j * N + n, 0))
        out.append(subs)
    return out
