"""Deterministic sharded data pipeline."""

from .pipeline import ShardedTokenPipeline, make_camr_job_datasets, wordcount_corpus

__all__ = ["ShardedTokenPipeline", "make_camr_job_datasets",
           "wordcount_corpus"]
