"""Fault tolerance built ON the paper's redundancy.

The CAMR placement stores every batch on k-1 servers (computation
redundancy) — the same structure that buys the coded-shuffle savings also
makes single-server loss recoverable WITHOUT recomputation:

* stage 1/2 groups containing a failed server: its coded broadcast Δ is
  gone, but every packet Δ would have covered is known by other live
  group members (the Lemma-2 storage condition) — each receiver fetches
  its missing packet uncoded from any live holder.
* stage-3 unicasts from a failed sender: the k-1 batches it would have
  aggregated are each stored on other owners of the job; the receiver
  collects them (at most k-1 uncoded values instead of 1).
* the failed server's reduce functions are reassigned to live servers
  (function migration), which then also receive the values the failed
  server would have decoded.

:class:`DegradedCAMREngine` executes exactly this protocol and reports
the load inflation; the straggler path is identical (a straggler is a
failure with a deadline). Elastic re-planning rebuilds the design for a
new K and quantifies data movement.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.designs import factorize_cluster, make_design
from repro.core.engine import CAMRConfig, CAMREngine
from repro.core.placement import make_placement
from repro.core.shuffle import Transmission

__all__ = ["DegradedCAMREngine", "elastic_replan", "ReplanReport"]


class DegradedCAMREngine(CAMREngine):
    """CAMR engine that survives a set of failed/straggling servers.

    ``failed`` servers complete the Map phase but are silent in the
    Shuffle (crash or deadline-miss after map). Their reduce functions
    are migrated to the next live server in their parallel class.
    """

    def __init__(self, cfg: CAMRConfig, map_fn, failed: set[int],
                 **kw):
        super().__init__(cfg, map_fn, **kw)
        self.failed = set(failed)
        if cfg.k < 3:
            raise ValueError("degraded recovery requires k >= 3 (k = 2 "
                             "leaves single-holder batches)")
        for i in range(cfg.k):
            cls = set(self.design.parallel_class(i))
            if len(cls & self.failed) > 1:
                raise ValueError(
                    "multiple failures in one parallel class need map "
                    "recompute (not just shuffle recovery)")
        # batches are replicated k-1 ways: recovery is possible iff no
        # batch lost ALL its holders (for k = 3 that means single failure)
        pl = self.placement
        for j in range(self.design.J):
            for t in range(cfg.k):
                if set(pl.holders(j, t)) <= self.failed:
                    raise ValueError(
                        f"batch (job {j}, batch {t}) lost all {cfg.k - 1} "
                        "replicas — data loss, not recoverable by the "
                        "shuffle (re-map from the master copy required)")

    # -- function migration -------------------------------------------- #
    def migrate_target(self, s: int) -> int:
        """Live server taking over s's reduce duties (same class)."""
        if s not in self.failed:
            return s
        cls = self.design.parallel_class(self.design.class_of(s))
        for cand in cls:
            if cand not in self.failed:
                return cand
        raise RuntimeError("whole parallel class failed")

    # -- degraded shuffle ----------------------------------------------- #
    def _coded_stage(self, stage, groups_chunks, fn_group):
        """Run Algorithm 2 per group among LIVE members; deliver the rest
        uncoded from live holders."""
        from repro.core.shuffle import (coded_multicast_schedule,
                                        decode_coded_multicast)
        K = self.cfg.K
        for G, chunk_specs in groups_chunks.items():
            live = [s for s in G if s not in self.failed]
            chunks, arrs = {}, {}
            for c in chunk_specs:
                qf = fn_group * K + c.qfunc
                holders = [s for s in G
                           if s != c.receiver and s not in self.failed]
                # the failed server stores every batch the group uses
                # except its own chunk's -> >= k-2 live holders remain,
                # and >= 1 because k >= 2 and at most one failure per class
                assert holders, "unrecoverable: no live holder"
                val = self.servers[holders[0]].agg[(c.job, c.batch)][qf]
                arrs[c.receiver] = (c, val)
                chunks[c.receiver] = self._ser(val)
            if len(live) == len(G):
                super_spec = {r: chunks[r] for r in chunks}
                txs = coded_multicast_schedule(G, super_spec, stage=stage,
                                               tag=("group", G))
                for t in txs:
                    self.trace.add(t)
                clen = len(next(iter(chunks.values())))
                for c in chunk_specs:
                    r = c.receiver
                    known = {c2.receiver: self._ser(
                        self.servers[r].agg[(c2.job, c2.batch)][
                            fn_group * K + c2.qfunc])
                        for c2 in chunk_specs if c2.receiver != r}
                    dec = decode_coded_multicast(G, r, txs, known, clen)
                    qf = fn_group * K + c.qfunc
                    self.servers[r].recv_batch[(c.job, c.batch, qf)] = \
                        self._de(dec)
                continue
            # degraded group: uncoded unicasts from live holders
            for c in chunk_specs:
                qf = fn_group * K + c.qfunc
                rcv = self.migrate_target(c.receiver)
                if rcv == c.receiver and c.receiver in self.failed:
                    continue
                holder = next(s for s in G if s != c.receiver
                              and s not in self.failed)
                val = self.servers[holder].agg[(c.job, c.batch)][qf]
                payload = self._ser(val)
                self.trace.add(Transmission(
                    stage=stage, sender=holder, receivers=(rcv,),
                    payload=payload, tag=("degraded", G)))
                self.servers[rcv].recv_batch[(c.job, c.batch, qf)] = \
                    self._de(payload)

    def _stage3(self, fn_group):
        from repro.core.shuffle import stage3_chunks
        K = self.cfg.K
        for spec in stage3_chunks(self.placement):
            qf = fn_group * K + spec.receiver
            rcv = self.migrate_target(spec.receiver)
            if spec.sender not in self.failed:
                sender_st = self.servers[spec.sender]
                acc = None
                for t in spec.batches:
                    v = sender_st.agg[(spec.job, t)][qf]
                    acc = v if acc is None else self.combine(acc, v)
                payload = self._ser(acc)
                self.trace.add(Transmission(
                    stage=3, sender=spec.sender, receivers=(rcv,),
                    payload=payload, tag=("job", spec.job)))
                self.servers[rcv].recv_rest[(spec.job, qf)] = \
                    self._de(payload)
            else:
                # recover each batch from a live redundant holder
                acc = None
                for t in spec.batches:
                    holder = next(h for h in self.placement.holders(
                        spec.job, t) if h not in self.failed)
                    v = self.servers[holder].agg[(spec.job, t)][qf]
                    payload = self._ser(v)
                    self.trace.add(Transmission(
                        stage=3, sender=holder, receivers=(rcv,),
                        payload=payload, tag=("degraded-job", spec.job)))
                    acc = v if acc is None else self.combine(acc, v)
                self.servers[rcv].recv_rest[(spec.job, qf)] = acc
        # migration fill: for every failed server f, the takeover also
        # needs, per job f OWNED, the aggregate of the k-1 batches f held
        # locally (complement of the degraded-stage-1 delivery).
        pl, d = self.placement, self.design
        for f in sorted(self.failed):
            s = self.migrate_target(f)
            qf = fn_group * K + f
            for j in d.owned_jobs(f):
                tf = pl.batch_of_label(j, f)
                rest = [t for t in range(d.k) if t != tf]
                # two live senders cover the complement: a live owner l'
                # sends its stored complement batches (all but t_{l'}),
                # another holder sends t_{l'}.
                l1 = next(u for u in d.owners[j] if u not in self.failed)
                t1 = pl.batch_of_label(j, l1)
                acc = None
                part = [t for t in rest if t != t1]
                if part:
                    a1 = None
                    for t in part:
                        v = self.servers[l1].agg[(j, t)][qf]
                        a1 = v if a1 is None else self.combine(a1, v)
                    self.trace.add(Transmission(
                        stage=3, sender=l1, receivers=(s,),
                        payload=self._ser(a1), tag=("migrate", j)))
                    acc = a1
                if t1 in rest:
                    h2 = next(h for h in pl.holders(j, t1)
                              if h not in self.failed)
                    v2 = self.servers[h2].agg[(j, t1)][qf]
                    self.trace.add(Transmission(
                        stage=3, sender=h2, receivers=(s,),
                        payload=self._ser(v2), tag=("migrate", j)))
                    acc = v2 if acc is None else self.combine(acc, v2)
                self.servers[s].recv_rest[(j, qf)] = acc

    def reduce_phase(self):
        """Reduce on live servers; migrated functions use the redirected
        (stage-1/2 batch value) + (stage-3/fill complement) pair."""
        pl, d = self.placement, self.design
        results = [dict() for _ in range(d.K)]
        for s_orig in range(d.K):
            s = self.migrate_target(s_orig)
            st = self.servers[s]
            migrated = s != s_orig
            for qf in self.functions_of(s_orig):
                for j in range(d.J):
                    if migrated:
                        # unified: l = owner of j in the FAILED server's
                        # class (l == s_orig when s_orig owned j)
                        cls = d.class_of(s_orig)
                        (l,) = [u for u in d.owners[j]
                                if d.class_of(u) == cls]
                        tl = pl.batch_of_label(j, l)
                        acc = self.combine(st.recv_batch[(j, tl, qf)],
                                           st.recv_rest[(j, qf)])
                    elif d.is_owner(s, j):
                        tmiss = pl.batch_of_label(j, s)
                        acc = st.recv_batch[(j, tmiss, qf)]
                        for t in range(d.k):
                            if t != tmiss:
                                acc = self.combine(acc, st.agg[(j, t)][qf])
                    else:
                        cls = d.class_of(s)
                        (l,) = [u for u in d.owners[j]
                                if d.class_of(u) == cls]
                        tl = pl.batch_of_label(j, l)
                        acc = self.combine(st.recv_batch[(j, tl, qf)],
                                           st.recv_rest[(j, qf)])
                    results[s][(j, qf)] = acc
            if migrated:
                results[s_orig] = {}
        return results


@dataclass(frozen=True)
class ReplanReport:
    old_qk: tuple
    new_qk: tuple
    moved_fraction: float     # fraction of stored subfiles that must move
    new_storage_fraction: float


def elastic_replan(q_old: int, k_old: int, K_new: int,
                   mu_target: float | None = None,
                   gamma: int = 1) -> ReplanReport:
    """Re-derive the design for a resized cluster and quantify movement.

    Servers keep their index order; subfiles already resident count as
    not-moved. The CAMR structural requirement is only K = q*k, so
    elastic scaling is a pure re-placement (no re-encoding of data)."""
    q_new, k_new = factorize_cluster(K_new, mu_target)
    old = make_placement(make_design(q_old, k_old), gamma)
    new = make_placement(make_design(q_new, k_new), gamma)
    K_old = q_old * k_old
    # compare on the job universe of the smaller plan, normalized per job
    J = min(old.design.J, new.design.J)
    total, moved = 0, 0
    for s in range(min(K_old, K_new)):
        old_set = {(j, n) for j, n in old.stored_subfiles(s) if j < J}
        new_set = {(j, n) for j, n in new.stored_subfiles(s) if j < J}
        total += len(new_set)
        moved += len(new_set - old_set)
    for s in range(min(K_old, K_new), K_new):   # fresh servers fetch all
        new_set = {(j, n) for j, n in new.stored_subfiles(s) if j < J}
        total += len(new_set)
        moved += len(new_set)
    return ReplanReport(
        old_qk=(q_old, k_old), new_qk=(q_new, k_new),
        moved_fraction=moved / max(total, 1),
        new_storage_fraction=(k_new - 1) / K_new)
