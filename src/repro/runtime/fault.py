"""Fault tolerance built ON the paper's redundancy.

The CAMR placement stores every batch on k-1 servers (computation
redundancy) — the same structure that buys the coded-shuffle savings also
makes single-server loss recoverable WITHOUT recomputation:

* stage 1/2 groups containing a failed server: its coded broadcast Δ is
  gone, but every packet Δ would have covered is known by other live
  group members (the Lemma-2 storage condition) — each receiver fetches
  its missing packet uncoded from any live holder.
* stage-3 unicasts from a failed sender: the k-1 batches it would have
  aggregated are each stored on other owners of the job; the receiver
  collects them (at most k-1 uncoded values instead of 1).
* the failed server's reduce functions are reassigned to live servers
  (function migration), which then also receive the values the failed
  server would have decoded.

:class:`DegradedCAMREngine` executes exactly this protocol and reports
the load inflation; the straggler path is identical (a straggler is a
failure with a deadline). The degraded schedule is not patched at run
time: :func:`repro.core.schedule.lower_degraded` RE-LOWERS the compiled
:class:`~repro.core.schedule.ShuffleProgram` against the surviving
server set, and the engine here interprets the result. The re-lowering
goes through :data:`repro.core.schedule.SCHEDULE_CACHE`, keyed by the
survivor set, so a stream of waves on a degraded cluster pays it once
(DESIGN.md §7/§9). Elastic re-planning rebuilds the design for a new K
and quantifies data movement.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass

import numpy as np

from functools import lru_cache

from repro.core.designs import factorize_cluster, make_design
from repro.core.engine import CAMRConfig, CAMREngine
from repro.core.placement import make_placement
from repro.core.schedule import (SCHEDULE_CACHE, DegradedProgram,
                                 resolve_topology, surviving_topology)
from repro.core.shuffle import Transmission

__all__ = ["DegradedCAMREngine", "elastic_replan", "ReplanReport",
           "MembershipError", "WireCorruptionError", "StragglerPolicy",
           "Membership", "HostMembership", "ElasticController",
           "retarget_engine", "smallest_unrecoverable_set",
           "degraded_shuffle_host", "degraded_dense_plan",
           "build_degraded_executor"]


class MembershipError(RuntimeError):
    """Invalid membership transition, or a degraded engine whose failed
    set was mutated after its survivor-set lowering was fixed."""


class WireCorruptionError(RuntimeError):
    """A coded wire packet failed its checksum after decode and the
    bounded bitwise replay could not produce a clean wave (DESIGN.md
    §17). Raised INSTEAD of returning silently mis-reduced values —
    the integrity lane's whole contract."""


@lru_cache(maxsize=32)
def _design_placement(q: int, k: int, gamma: int):
    design = make_design(q, k)
    return design, make_placement(design, gamma)


def smallest_unrecoverable_set(q: int, k: int, failed,
                               gamma: int = 1):
    """Smallest subset of ``failed`` that is by itself unrecoverable
    by the degraded shuffle, or ``None`` when ``failed`` is
    recoverable (the exact conditions
    :func:`repro.core.schedule.lower_degraded` rejects on).

    Checked smallest-first, so the returned tuple is a MINIMAL witness
    the operator can act on: a single worker when ``k < 3`` (no
    redundancy to recover from), a same-parallel-class pair (map
    recompute required), or a batch's full ``k-1`` holder set (data
    loss).
    """
    failed = frozenset(int(s) for s in failed)
    if not failed:
        return None
    design, pl = _design_placement(q, k, gamma)
    if k < 3:
        return (min(failed),)
    for i in range(k):
        cls = sorted(set(design.parallel_class(i)) & failed)
        if len(cls) > 1:
            return tuple(cls[:2])
    for j in range(design.J):
        for t in range(k):
            holders = frozenset(pl.holders(j, t))
            if holders <= failed:
                return tuple(sorted(holders))
    return None


class DegradedCAMREngine(CAMREngine):
    """CAMR engine that survives a set of failed/straggling servers.

    ``failed`` servers complete the Map phase but are silent in the
    Shuffle (crash or deadline-miss after map). Their reduce functions
    are migrated to the next live server in their parallel class.

    All scheduling decisions live in the re-lowered
    :class:`~repro.core.schedule.DegradedProgram`; this class only moves
    the bytes it prescribes.
    """

    def __init__(self, cfg: CAMRConfig, map_fn, failed: set[int],
                 **kw):
        super().__init__(cfg, map_fn, **kw)
        self.failed = set(failed)
        # raises ValueError when the loss exceeds the redundancy; the
        # re-lowering is cached per (configuration, survivor set), so a
        # JobStream of waves on a degraded cluster pays it once
        self.degraded: DegradedProgram = SCHEDULE_CACHE.degraded(
            self.program, self.failed)

    # -- function migration -------------------------------------------- #
    def migrate_target(self, s: int) -> int:
        """Live server taking over s's reduce duties (same class)."""
        return int(self.degraded.migrate[s])

    # -- frozen-membership guard ---------------------------------------- #
    def _check_membership_frozen(self) -> None:
        """The survivor set is FIXED at construction: every uncoded
        route, stage-3 source and migration-fill send is baked into the
        re-lowered :class:`DegradedProgram`. Stacking another failure
        onto a live engine would silently mis-reduce (the schedule
        would keep routing through the newly-dead server), so any drift
        between ``self.failed`` and the lowered set is a hard error."""
        if frozenset(self.failed) != self.degraded.failed:
            raise MembershipError(
                f"failed set changed after lowering: this engine was "
                f"re-lowered for failures {sorted(self.degraded.failed)} "
                f"but now sees {sorted(self.failed)}. A "
                "DegradedCAMREngine is frozen to one survivor set — "
                "route membership changes through a fresh re-lowering "
                "instead (repro.runtime.fault.retarget_engine adopts "
                "the map state and pulls the new survivor-set schedule "
                "from the warm SCHEDULE_CACHE).")

    def shuffle_phase(self):
        self._check_membership_frozen()
        super().shuffle_phase()

    # -- degraded shuffle ----------------------------------------------- #
    def _coded_stage(self, stage, fn_group):
        """Run Algorithm 2 for the fully-live group rows; deliver the
        degraded rows uncoded, exactly as the re-lowered program says."""
        K = self.cfg.K
        prog, deg = self.program, self.degraded
        for row in deg.coded_rows:
            if int(prog.stage_of[row]) == stage:
                self._run_coded_group(int(row), stage, fn_group)
        for row, sends in deg.uncoded:
            if int(prog.stage_of[row]) != stage:
                continue
            G = prog.group_members(row)
            for holder, rcv, job, batch, owner in sends:
                qf = fn_group * K + owner
                val = self.servers[holder].agg[(job, batch)][qf]
                payload = self._ser(val)
                self.trace.add(Transmission(
                    stage=stage, sender=holder, receivers=(rcv,),
                    payload=payload, tag=("degraded", G)))
                self.servers[rcv].recv_batch[(job, batch, qf)] = \
                    self._de(payload)

    def _stage3(self, fn_group):
        """Interpret the re-lowered stage-3 sends (normal unicasts,
        per-batch recovery from redundant holders, and migration fill).
        Entries sharing a (receiver, job, function) key are combined
        locally first, then ASSIGNED — shuffle_phase stays idempotent
        like the base engine's."""
        K = self.cfg.K
        acc_map: dict = {}
        for snd, rcv, job, owner, batches in self.degraded.s3:
            qf = fn_group * K + owner
            sender_st = self.servers[snd]
            acc = None
            for t in batches:
                v = sender_st.agg[(job, t)][qf]
                acc = v if acc is None else self.combine(acc, v)
            payload = self._ser(acc)
            self.trace.add(Transmission(
                stage=3, sender=snd, receivers=(rcv,),
                payload=payload, tag=("job", job, "fn", fn_group)))
            key = (rcv, job, qf)
            val = self._de(payload)
            acc_map[key] = (val if key not in acc_map
                            else self.combine(acc_map[key], val))
        for (rcv, job, qf), val in acc_map.items():
            self.servers[rcv].recv_rest[(job, qf)] = val

    def reduce_phase(self):
        """Reduce on live servers; migrated functions use the redirected
        (stage-1/2 batch value) + (stage-3/fill complement) pair."""
        self._check_membership_frozen()
        pl, d = self.placement, self.design
        results = [dict() for _ in range(d.K)]
        for s_orig in range(d.K):
            s = self.migrate_target(s_orig)
            st = self.servers[s]
            migrated = s != s_orig
            for qf in self.functions_of(s_orig):
                for j in range(d.J):
                    if migrated:
                        # unified: l = owner of j in the FAILED server's
                        # class (l == s_orig when s_orig owned j)
                        cls = d.class_of(s_orig)
                        (l,) = [u for u in d.owners[j]
                                if d.class_of(u) == cls]
                        tl = pl.batch_of_label(j, l)
                        acc = self.combine(st.recv_batch[(j, tl, qf)],
                                           st.recv_rest[(j, qf)])
                    elif d.is_owner(s, j):
                        # canonical order (engine.reduce_phase): delivered
                        # batch + ascending fold of the k-1 stored ones
                        tmiss = pl.batch_of_label(j, s)
                        rest = None
                        for t in range(d.k):
                            if t != tmiss:
                                v = st.agg[(j, t)][qf]
                                rest = v if rest is None \
                                    else self.combine(rest, v)
                        acc = self.combine(st.recv_batch[(j, tmiss, qf)],
                                           rest)
                    else:
                        cls = d.class_of(s)
                        (l,) = [u for u in d.owners[j]
                                if d.class_of(u) == cls]
                        tl = pl.batch_of_label(j, l)
                        acc = self.combine(st.recv_batch[(j, tl, qf)],
                                           st.recv_rest[(j, qf)])
                    results[s][(j, qf)] = acc
            if migrated:
                results[s_orig] = {}
        return results


@dataclass(frozen=True)
class ReplanReport:
    old_qk: tuple
    new_qk: tuple
    moved_fraction: float     # fraction of stored subfiles that must move
    new_storage_fraction: float


def elastic_replan(q_old: int, k_old: int, K_new: int,
                   mu_target: float | None = None,
                   gamma: int = 1) -> ReplanReport:
    """Re-derive the design for a resized cluster and quantify movement.

    Servers keep their index order; subfiles already resident count as
    not-moved. The CAMR structural requirement is only K = q*k, so
    elastic scaling is a pure re-placement (no re-encoding of data)."""
    q_new, k_new = factorize_cluster(K_new, mu_target)
    old = make_placement(make_design(q_old, k_old), gamma)
    new = make_placement(make_design(q_new, k_new), gamma)
    K_old = q_old * k_old
    # compare on the job universe of the smaller plan, normalized per job
    J = min(old.design.J, new.design.J)
    total, moved = 0, 0
    for s in range(min(K_old, K_new)):
        old_set = {(j, n) for j, n in old.stored_subfiles(s) if j < J}
        new_set = {(j, n) for j, n in new.stored_subfiles(s) if j < J}
        total += len(new_set)
        moved += len(new_set - old_set)
    for s in range(min(K_old, K_new), K_new):   # fresh servers fetch all
        new_set = {(j, n) for j, n in new.stored_subfiles(s) if j < J}
        total += len(new_set)
        moved += len(new_set)
    return ReplanReport(
        old_qk=(q_old, k_old), new_qk=(q_new, k_new),
        moved_fraction=moved / max(total, 1),
        new_storage_fraction=(k_new - 1) / K_new)


# --------------------------------------------------------------------- #
# live elasticity (DESIGN.md §14): membership state machine, straggler
# detection, wave-boundary control, and engine re-targeting
# --------------------------------------------------------------------- #
@dataclass(frozen=True)
class StragglerPolicy:
    """Knobs of the wave-timing straggler detector (DESIGN.md §14).

    A worker whose observed map time exceeds ``rel_threshold`` times the
    live-set median (or ``abs_timeout_s``, when set) earns a strike and
    is flagged ``straggler``; ``patience`` consecutive strikes demote it
    to ``dead`` when ``demote`` is on. ``max_failed`` caps concurrent
    dead workers at what one re-lowering can absorb — a would-be demote
    beyond the cap keeps the worker flagged but live (slow data beats
    no data). Waves whose live median lands under ``min_wave_s`` are
    too fast to measure and are skipped entirely (no strikes, no
    clears) — scheduler jitter on a µs-scale map phase says nothing
    about worker health.
    """

    rel_threshold: float = 4.0
    abs_timeout_s: float | None = None
    patience: int = 2
    demote: bool = True
    max_failed: int = 1
    min_wave_s: float = 0.0


class Membership:
    """Worker membership state machine for one (q, k) CAMR cluster.

    States: ``live`` -> ``straggler`` (timing strikes) -> ``dead``
    (demoted, or killed outright) -> ``live`` again via :meth:`rejoin`.
    Every transition bumps ``generation`` and is appended to ``events``
    — the stream's replan hook keys off :meth:`failed`, so a stale
    engine is always detectable by set comparison.

    :meth:`rejoin` re-admits a worker through
    :func:`elastic_replan`'s pure re-placement: with the cluster size
    unchanged the factorization is pinned to the original ``(q, k)``
    (``mu_target = (k-1)/K``), so the replan receipt proves
    ``moved_fraction == 0`` — no subfile moves and nothing re-encodes;
    the rejoined worker's stored batches are simply valid again.

    With a two-level ``topology`` the ``max_failed`` cap counts FAULT
    DOMAINS (class-major host blocks), not individual workers: two
    dead workers on ONE host are one correlated event and consume one
    slot (DESIGN.md §17). Either way a kill/demote that would make the
    failed set shuffle-unrecoverable is rejected up front with the
    smallest unrecoverable witness named — the stream never reaches
    ``lower_degraded`` with a doomed survivor set.
    """

    LIVE, STRAGGLER, DEAD = "live", "straggler", "dead"

    def __init__(self, q: int, k: int, *, gamma: int = 1,
                 policy: StragglerPolicy | None = None, topology=None):
        self.q, self.k, self.gamma = q, k, gamma
        self.K = q * k
        self.policy = policy or StragglerPolicy()
        self.topology = resolve_topology(topology, q, k)
        self._dph = (self.K // self.topology.hosts
                     if self.topology is not None else None)
        self.state = [self.LIVE] * self.K
        self.strikes = [0] * self.K
        self.generation = 0
        self.events: list[tuple] = []     # (generation, kind, worker)
        self.replans: list[ReplanReport] = []

    # -- queries --------------------------------------------------------- #
    def failed(self) -> frozenset:
        return frozenset(s for s in range(self.K)
                         if self.state[s] == self.DEAD)

    def live(self) -> frozenset:
        return frozenset(s for s in range(self.K)
                         if self.state[s] != self.DEAD)

    def domains(self, workers) -> frozenset:
        """Correlated fault domains covering ``workers``: host ids
        under a two-level topology, the workers themselves when flat
        (every worker its own domain — the pre-§17 accounting)."""
        if self.topology is None:
            return frozenset(workers)
        return frozenset(int(w) // self._dph for w in workers)

    def gateway_avoid(self) -> frozenset:
        """Devices a straggler-aware lowering should not elect as
        phase-A gateways: everything not fully ``live`` right now."""
        return frozenset(s for s in range(self.K)
                         if self.state[s] != self.LIVE)

    def _check_worker(self, w: int) -> None:
        if not 0 <= w < self.K:
            raise MembershipError(f"worker {w} outside cluster "
                                  f"[0, {self.K})")

    def _record(self, kind: str, worker: int) -> None:
        self.generation += 1
        self.events.append((self.generation, kind, worker))

    def _vet_kill(self, w: int) -> str | None:
        """Reason the live/straggler worker ``w`` must not die now, or
        ``None`` when the kill is admissible. Shared by :meth:`kill`
        (raises) and :meth:`demote` (declines quietly)."""
        would = self.failed() | {w}
        if len(self.domains(would)) > self.policy.max_failed:
            unit = ("fault domains (class-major host blocks)"
                    if self.topology is not None else "failures")
            bad = smallest_unrecoverable_set(self.q, self.k, would,
                                             self.gamma)
            hint = (f"; smallest unrecoverable set: workers {list(bad)}"
                    if bad is not None else "")
            return (f"killing worker {w} would exceed "
                    f"max_failed={self.policy.max_failed} concurrent "
                    f"{unit} (dead: {sorted(self.failed())}, domains: "
                    f"{sorted(self.domains(would))}){hint}")
        bad = smallest_unrecoverable_set(self.q, self.k, would,
                                         self.gamma)
        if bad is not None:
            return (f"killing worker {w} would make the dead set "
                    f"{sorted(would)} shuffle-unrecoverable — smallest "
                    f"unrecoverable set: workers {list(bad)} "
                    "(same parallel class, a wiped holder set, or "
                    "k < 3); recover at host granularity instead "
                    "(HostMembership re-lowers the topology)")
        return None

    # -- transitions ----------------------------------------------------- #
    def kill(self, w: int) -> None:
        """live/straggler -> dead (crash or operator drain)."""
        self._check_worker(w)
        if self.state[w] == self.DEAD:
            raise MembershipError(f"worker {w} is already dead")
        veto = self._vet_kill(w)
        if veto is not None:
            raise MembershipError(veto)
        self.state[w] = self.DEAD
        self.strikes[w] = 0
        self._record("kill", w)

    def demote(self, w: int) -> bool:
        """straggler -> dead, respecting the ``max_failed`` cap (and
        never into an unrecoverable set — slow data beats no data).
        Returns whether the demote actually happened."""
        self._check_worker(w)
        if self.state[w] == self.DEAD:
            raise MembershipError(f"worker {w} is already dead")
        if self._vet_kill(w) is not None:
            return False
        self.state[w] = self.DEAD
        self.strikes[w] = 0
        self._record("demote", w)
        return True

    def rejoin(self, w: int) -> ReplanReport:
        """dead -> live, with the elastic-replan receipt recorded."""
        self._check_worker(w)
        if self.state[w] != self.DEAD:
            raise MembershipError(
                f"worker {w} is {self.state[w]}; only dead workers "
                "rejoin")
        # same-K re-admission: mu_target pins factorize_cluster to the
        # original (q, k), so the receipt certifies zero data movement
        rep = elastic_replan(self.q, self.k, self.K,
                             mu_target=(self.k - 1) / self.K,
                             gamma=self.gamma)
        self.replans.append(rep)
        self.state[w] = self.LIVE
        self.strikes[w] = 0
        self._record("rejoin", w)
        return rep

    # -- detection ------------------------------------------------------- #
    def observe(self, timings: dict[int, float]) -> list[int]:
        """Feed one wave of per-worker map seconds; returns workers
        demoted by this observation. Dead workers are ignored; a clean
        wave clears a worker's strikes (the detector demands
        ``patience`` CONSECUTIVE slow waves, so one GC pause or page
        fault never evicts a healthy worker)."""
        pol = self.policy
        live_t = {int(w): float(t) for w, t in timings.items()
                  if self.state[int(w)] != self.DEAD}
        demoted: list[int] = []
        if not live_t:
            return demoted
        med = float(np.median(list(live_t.values())))
        if med < pol.min_wave_s:
            return demoted      # unmeasurable wave: no verdict either way
        for w, t in live_t.items():
            timed_out = (pol.abs_timeout_s is not None
                         and t > pol.abs_timeout_s)
            slow = med > 0 and t > pol.rel_threshold * med
            if timed_out or slow:
                self.strikes[w] += 1
                if self.state[w] == self.LIVE:
                    self.state[w] = self.STRAGGLER
                    self._record("flag", w)
                if pol.demote and self.strikes[w] >= pol.patience:
                    if self.demote(w):
                        demoted.append(w)
            else:
                self.strikes[w] = 0
                if self.state[w] == self.STRAGGLER:
                    self.state[w] = self.LIVE
                    self._record("clear", w)
        return demoted


class HostMembership:
    """Host-granularity fault domains over a two-level topology
    (DESIGN.md §17).

    Whole-host loss is NEVER absorbable by the survivor-set degraded
    shuffle: each class-major host block holds ``k/hosts`` COMPLETE
    parallel classes, so any single dead host already trips
    ``lower_degraded``'s one-per-class check. Recovery is therefore a
    TOPOLOGY re-homing, not a degradation — :meth:`kill_host`
    atomically fails the block (one correlated event) and
    :meth:`current_topology` names the surviving-host lowering target:
    ``two_level`` over the remaining hosts while ``hosts_left | k``
    still holds, else ``None`` (the bitwise-identical flat fallback).
    Schedule values are topology-independent, so the re-homed stream
    stays bitwise-equal to the healthy oracle; pre-pay every
    survivor lowering with ``ScheduleCache.warm_host_survivors`` and
    the swap is a pure cache hit.
    """

    LIVE, DEAD = "live", "dead"

    def __init__(self, q: int, k: int, topology, *,
                 max_failed_hosts: int | None = None):
        topology = resolve_topology(topology, q, k)
        if topology is None:
            raise MembershipError(
                "HostMembership needs a two-level topology (flat "
                "clusters have no host fault domains — use Membership)")
        topology.check(q, k)
        self.q, self.k, self.K = q, k, q * k
        self.topology = topology
        self.hosts = topology.hosts
        self.dph = self.K // self.hosts
        cap = self.hosts - 1 if max_failed_hosts is None \
            else int(max_failed_hosts)
        if not 0 < cap < self.hosts:
            raise MembershipError(
                f"max_failed_hosts={max_failed_hosts} outside "
                f"[1, {self.hosts - 1}] for {self.hosts} hosts")
        self.max_failed_hosts = cap
        self.state = [self.LIVE] * self.hosts
        self.generation = 0
        self.events: list[tuple] = []    # (generation, kind, host)

    # -- queries --------------------------------------------------------- #
    def failed_hosts(self) -> frozenset:
        return frozenset(h for h in range(self.hosts)
                         if self.state[h] == self.DEAD)

    def live_hosts(self) -> frozenset:
        return frozenset(h for h in range(self.hosts)
                         if self.state[h] == self.LIVE)

    def host_block(self, h: int) -> tuple:
        """The class-major device block host ``h`` owns."""
        self._check_host(h)
        return tuple(range(h * self.dph, (h + 1) * self.dph))

    def failed_workers(self) -> frozenset:
        """Every device on a dead host — the correlated loss set."""
        return frozenset(w for h in self.failed_hosts()
                         for w in self.host_block(h))

    def current_topology(self):
        """Lowering target for the surviving hosts: ``two_level`` when
        the block structure still divides ``k``, else ``None``
        (flat)."""
        return surviving_topology(len(self.live_hosts()), self.k,
                                  alpha=self.topology.alpha)

    def _check_host(self, h: int) -> None:
        if not 0 <= h < self.hosts:
            raise MembershipError(f"host {h} outside cluster "
                                  f"[0, {self.hosts})")

    def _record(self, kind: str, host: int) -> None:
        self.generation += 1
        self.events.append((self.generation, kind, host))

    # -- transitions ----------------------------------------------------- #
    def kill_host(self, h: int) -> tuple:
        """Atomically fail host ``h``'s whole block (ONE correlated
        event against ``max_failed_hosts``); returns the dead device
        block so the caller can drain in-flight work."""
        self._check_host(h)
        if self.state[h] == self.DEAD:
            raise MembershipError(f"host {h} is already dead")
        would = sorted(self.failed_hosts() | {h})
        if len(would) >= self.hosts:
            lost = sorted(w for hh in would for w in self.host_block(hh))
            raise MembershipError(
                f"killing host {h} would fail every host {would} — "
                f"smallest unrecoverable set: the full host set owning "
                f"workers {lost}; no surviving host remains to re-home "
                "the shuffle onto")
        if len(would) > self.max_failed_hosts:
            raise MembershipError(
                f"killing host {h} would exceed "
                f"max_failed_hosts={self.max_failed_hosts} concurrent "
                f"host fault domains (dead hosts: "
                f"{sorted(self.failed_hosts())})")
        self.state[h] = self.DEAD
        self._record("kill_host", h)
        return self.host_block(h)

    def rejoin_host(self, h: int) -> None:
        """dead -> live; the next :meth:`current_topology` re-homes
        back onto the larger host set (pure cache hit when warmed)."""
        self._check_host(h)
        if self.state[h] != self.DEAD:
            raise MembershipError(
                f"host {h} is {self.state[h]}; only dead hosts rejoin")
        self.state[h] = self.LIVE
        self._record("rejoin_host", h)


class ElasticController:
    """Wave-boundary control loop between a :class:`Membership` and a
    stream (``JobStream(elastic=...)``).

    The stream calls :meth:`wave_start` from its map-prefetch thread
    when it builds each batch's engine, and :meth:`current_failed` +
    :meth:`wave_timings` from the main thread around each batch's
    shuffle+reduce — one lock serializes the two lanes. Under
    pipelining, batch ``t+1``'s engine may be built before batch ``t``'s
    timings arrive; detection therefore lands one batch late at worst,
    and correctness never depends on WHEN a membership change is seen:
    the stream re-targets every engine against the current survivor set
    right before its shuffle, and degraded output is bitwise-identical
    to healthy output (DESIGN.md §11/§14).

    Subclass hooks (both called under the lock):
    ``on_wave_start(wave)`` — apply scripted churn (tests/chaos.py);
    ``on_wave_timings(wave, timings) -> timings`` — perturb observed
    timings before they reach the detector.
    """

    def __init__(self, membership: Membership):
        self.membership = membership
        self._lock = threading.Lock()
        self.waves = 0                 # batches started
        self.migrations = 0            # engine re-targets (stream-fed)

    # -- subclass hooks -------------------------------------------------- #
    def on_wave_start(self, wave: int) -> None:
        pass

    def on_wave_timings(self, wave: int,
                        timings: dict[int, float]) -> dict[int, float]:
        return timings

    # -- stream interface ------------------------------------------------ #
    def wave_start(self, wave: int) -> frozenset:
        with self._lock:
            self.waves = max(self.waves, wave + 1)
            self.on_wave_start(wave)
            return self.membership.failed()

    def current_failed(self) -> frozenset:
        with self._lock:
            return self.membership.failed()

    def wave_timings(self, wave: int, map_times) -> list[int]:
        """Feed a completed batch's per-server map seconds (live
        workers only) through the straggler detector."""
        with self._lock:
            failed = self.membership.failed()
            timings = {s: float(map_times[s])
                       for s in range(self.membership.K)
                       if s not in failed}
            timings = self.on_wave_timings(wave, timings)
            return self.membership.observe(timings)


def retarget_engine(eng: CAMREngine, failed) -> CAMREngine:
    """Swap an engine's shuffle schedule to the survivor set ``failed``
    WITHOUT recomputing its map phase.

    Returns ``eng`` unchanged when the set already matches; otherwise a
    fresh engine (degraded or healthy) whose re-lowering comes from the
    warm :data:`SCHEDULE_CACHE` and which ADOPTS the old engine's
    mapped aggregates — the recovery memory model of DESIGN.md §14: a
    membership change costs one cached table lookup, never a re-map.
    """
    failed = set(int(s) for s in failed) if failed else set()
    have = set(getattr(eng, "failed", set()) or set())
    if failed == have:
        return eng
    label_perm = eng.placement.label_perm
    if failed:
        new = DegradedCAMREngine(eng.cfg, eng.map_fn, failed,
                                 combine=eng.combine,
                                 label_perm=label_perm)
    else:
        new = CAMREngine(eng.cfg, eng.map_fn, combine=eng.combine,
                         label_perm=label_perm)
    # adopt map-phase state: aggregates, value metadata, timings. The
    # shuffle/reduce run entirely off these plus the (new) lowering.
    new.servers = eng.servers
    new._value_dim = eng._value_dim
    new._dtype = eng._dtype
    new.map_times = eng.map_times
    new.trace = eng.trace
    return new


def degraded_shuffle_host(program, failed, contribs) -> np.ndarray:
    """Host-side degraded executor over SPMD contribution tensors.

    Interprets the survivor-set re-lowering of ``program`` (served from
    :data:`SCHEDULE_CACHE`) against stacked per-worker contributions
    ``[K, J_own, k-1, K, d]`` — the exact input of
    :func:`repro.core.collective.camr_shuffle` — and returns logical
    outputs ``[K, J, d]``: row ``s`` is the fully-aggregated shard
    ``s`` of every job, computed on ``s``'s migrate target when ``s``
    failed. Rows of failed workers in ``contribs`` are NEVER read
    (failed means silent after map), and because every route folds in
    the canonical combine order the output is BITWISE equal to the
    healthy shuffle of the same contributions (DESIGN.md §11).

    This is the :class:`~repro.core.collective.ShuffleStream` degraded
    lane — collective.py imports it lazily (runtime layering: the SPMD
    stream borrows the fault runtime's interpreter rather than lowering
    a second degraded executor).
    """
    deg = SCHEDULE_CACHE.degraded(program, set(failed))
    design, pl = program.design, program.placement
    q, k, K = program.q, program.k, program.K
    J = design.J
    J_own = q ** (k - 2)
    contribs = np.asarray(contribs)
    d = contribs.shape[-1]
    if contribs.shape != (K, J_own, k - 1, K, d):
        raise ValueError(f"contribs shape {contribs.shape} != "
                         f"{(K, J_own, k - 1, K, d)}")
    dead = deg.failed

    # (server, job, batch) -> [K, d] per-function-shard aggregate; only
    # survivor rows enter the table, so a read of dead data is a KeyError
    agg: dict = {}
    for s in range(K):
        if s in dead:
            continue
        for a in range(J_own):
            j = int(program.owned_jobs[s, a])
            for b in range(k - 1):
                t = int(program.stored_batches[s, a, b])
                agg[(s, j, t)] = contribs[s, a, b]
    # stages 1+2: coded rows deliver from the first co-holder (all live);
    # degraded rows follow the uncoded unicast plan
    recv_batch: dict = {}           # (rcv, job, batch, owner) -> [d]
    for row in deg.coded_rows:
        G = program.group_members(int(row))
        for kp, j, t in program.coded_chunks(int(row)):
            holder = next(s for s in G if s != kp)
            recv_batch[(kp, j, t, kp)] = agg[(holder, j, t)][kp]
    for _row, sends in deg.uncoded:
        for holder, rcv, j, t, owner in sends:
            recv_batch[(rcv, j, t, owner)] = agg[(holder, j, t)][owner]
    # stage 3: sender-side ascending folds; entries sharing a key are
    # combined in s3 iteration order (the engine's acc_map contract)
    recv_rest: dict = {}            # (rcv, job, owner) -> [d]
    for snd, rcv, j, owner, batches in deg.s3:
        acc = None
        for t in batches:
            v = agg[(snd, j, t)][owner]
            acc = v if acc is None else acc + v
        key = (rcv, j, owner)
        recv_rest[key] = (acc if key not in recv_rest
                          else recv_rest[key] + acc)
    # reduce: canonical order per DegradedCAMREngine.reduce_phase, with
    # migrated rows normalized back to their logical slots
    out = np.zeros((K, J, d), contribs.dtype)
    for s_orig in range(K):
        s = int(deg.migrate[s_orig])
        migrated = s != s_orig
        for j in range(J):
            if migrated:
                cls = design.class_of(s_orig)
                (l,) = [u for u in design.owners[j]
                        if design.class_of(u) == cls]
                tl = pl.batch_of_label(j, l)
                out[s_orig, j] = (recv_batch[(s, j, tl, s_orig)]
                                  + recv_rest[(s, j, s_orig)])
            elif design.is_owner(s, j):
                tmiss = pl.batch_of_label(j, s)
                rest = None
                for t in range(k):
                    if t != tmiss:
                        v = agg[(s, j, t)][s]
                        rest = v if rest is None else rest + v
                out[s_orig, j] = recv_batch[(s, j, tmiss, s)] + rest
            else:
                cls = design.class_of(s)
                (l,) = [u for u in design.owners[j]
                        if design.class_of(u) == cls]
                tl = pl.batch_of_label(j, l)
                out[s_orig, j] = (recv_batch[(s, j, tl, s)]
                                  + recv_rest[(s, j, s)])
    return out


def degraded_dense_plan(program, failed):
    """Dense index-plan of the survivor-set re-lowering (DESIGN.md §15).

    Every logical output row ``(s_orig, j)`` of
    :func:`degraded_shuffle_host` is ``A + B``: A is ONE element of the
    flattened contribution tensor (the recv_batch delivery) and B is a
    TWO-LEVEL ordered fold over further elements — the outer level over
    "groups" (the s3 sends sharing the row's key, in s3 iteration
    order; or the owner's stored batches ascending), the inner level a
    left fold over each group's elements in listed order. This function
    extracts those indices WITHOUT running anything, preserving the
    host interpreter's exact combine order, so a device executor
    gathering through them is BITWISE-identical to the interpreter
    (fp addition is not associative — flattening the nested folds
    would break the §11 bit-identity contract).

    Returns ``(a_idx [R], g_idx [R, G, E], g_mask [R, G, E])`` int32 /
    bool with ``R = K * J`` row-major over ``(s_orig, j)``, indexing
    the flattened ``[K * J_own * (k-1) * K]`` leading axes of contribs.
    ``g_mask`` marks real (non-pad) elements; every row has >= 1 group
    and every real group >= 1 element, with element 0 always real.
    Indices are value-width independent: one plan serves every stacked
    wave width ``W * d``.
    """
    deg = SCHEDULE_CACHE.degraded(program, set(failed))
    design, pl = program.design, program.placement
    q, k, K = program.q, program.k, program.K
    J = design.J
    J_own = q ** (k - 2)
    dead = deg.failed

    def flat(s, a, b, owner):
        return ((s * J_own + a) * (k - 1) + b) * K + owner

    # (server, job, batch) -> (a, b) slot in the contribs tensor; only
    # survivors enter, so indexing dead data is a KeyError (a plan bug)
    pos: dict = {}
    for s in range(K):
        if s in dead:
            continue
        for a in range(J_own):
            j = int(program.owned_jobs[s, a])
            for b in range(k - 1):
                t = int(program.stored_batches[s, a, b])
                pos[(s, j, t)] = (a, b)

    recv_src: dict = {}          # (rcv, job, batch, owner) -> flat idx
    for row in deg.coded_rows:
        G = program.group_members(int(row))
        for kp, j, t in program.coded_chunks(int(row)):
            holder = next(s for s in G if s != kp)
            a, b = pos[(holder, j, t)]
            recv_src[(kp, j, t, kp)] = flat(holder, a, b, kp)
    for _row, sends in deg.uncoded:
        for holder, rcv, j, t, owner in sends:
            a, b = pos[(holder, j, t)]
            recv_src[(rcv, j, t, owner)] = flat(holder, a, b, owner)

    rest_groups: dict = {}       # (rcv, job, owner) -> [group, ...]
    for snd, rcv, j, owner, batches in deg.s3:
        grp = [flat(snd, *pos[(snd, j, t)], owner) for t in batches]
        rest_groups.setdefault((rcv, j, owner), []).append(grp)

    a_idx = np.zeros(K * J, np.int32)
    per_row: list = []
    for s_orig in range(K):
        s = int(deg.migrate[s_orig])
        migrated = s != s_orig
        for j in range(J):
            r = s_orig * J + j
            if migrated:
                cls = design.class_of(s_orig)
                (l,) = [u for u in design.owners[j]
                        if design.class_of(u) == cls]
                tl = pl.batch_of_label(j, l)
                a_idx[r] = recv_src[(s, j, tl, s_orig)]
                grps = rest_groups[(s, j, s_orig)]
            elif design.is_owner(s, j):
                tmiss = pl.batch_of_label(j, s)
                a_idx[r] = recv_src[(s, j, tmiss, s)]
                grps = [[flat(s, *pos[(s, j, t)], s)
                         for t in range(k) if t != tmiss]]
            else:
                cls = design.class_of(s)
                (l,) = [u for u in design.owners[j]
                        if design.class_of(u) == cls]
                tl = pl.batch_of_label(j, l)
                a_idx[r] = recv_src[(s, j, tl, s)]
                grps = rest_groups[(s, j, s)]
            per_row.append(grps)

    Gm = max(len(g) for g in per_row)
    Em = max(len(e) for g in per_row for e in g)
    g_idx = np.zeros((K * J, Gm, Em), np.int32)
    g_mask = np.zeros((K * J, Gm, Em), bool)
    for r, grps in enumerate(per_row):
        for gi, grp in enumerate(grps):
            g_idx[r, gi, :len(grp)] = grp
            g_mask[r, gi, :len(grp)] = True
    return a_idx, g_idx, g_mask


def build_degraded_executor(program, failed, d: int, dtype):
    """AOT-compile the dense degraded plan into ONE device executable
    ``contribs [K, J_own, k-1, K, d] -> out [K, J, d]`` (DESIGN.md
    §15) — the :class:`~repro.core.collective.ShuffleStream` degraded
    lane. Compilation happens HERE (``.lower(...).compile()``), never
    at dispatch: warmed through the EXEC_CACHE, a mid-stream degrade
    swaps executables with zero retraces, and the recovery data path
    stays on device instead of falling back to the host interpreter.

    Bitwise contract: the gathers and the two-level masked fold below
    replay :func:`degraded_shuffle_host`'s adds in its exact order.
    Masking uses ``where(mask, acc + v, acc)`` — a SELECT around the
    add, never ``acc + where(mask, v, 0)``, which would rewrite
    ``-0.0`` rows.
    """
    import jax                   # lazy: this module is host-only
    import jax.numpy as jnp

    a_idx, g_idx, g_mask = degraded_dense_plan(program, failed)
    q, k, K = program.q, program.k, program.K
    J_own = q ** (k - 2)
    J = a_idx.shape[0] // K
    Gm, Em = g_idx.shape[1], g_idx.shape[2]
    ai = jnp.asarray(a_idx)
    gi = jnp.asarray(g_idx)
    gm = jnp.asarray(g_mask)
    gvalid = jnp.asarray(g_mask.any(axis=-1))

    def run(contribs):
        flat = contribs.reshape(-1, contribs.shape[-1])   # [F, d]
        A = flat[ai]                                      # [R, d]
        elems = flat[gi]                                  # [R, G, E, d]
        acc = elems[:, :, 0]
        for e in range(1, Em):
            acc = jnp.where(gm[:, :, e, None],
                            acc + elems[:, :, e], acc)
        B = acc[:, 0]
        for g in range(1, Gm):
            B = jnp.where(gvalid[:, g, None], B + acc[:, g], B)
        return (A + B).reshape(K, J, -1)

    spec = jax.ShapeDtypeStruct((K, J_own, k - 1, K, d),
                                jnp.dtype(dtype))
    return jax.jit(run).lower(spec).compile()
