"""Fault tolerance built ON the paper's redundancy.

The CAMR placement stores every batch on k-1 servers (computation
redundancy) — the same structure that buys the coded-shuffle savings also
makes single-server loss recoverable WITHOUT recomputation:

* stage 1/2 groups containing a failed server: its coded broadcast Δ is
  gone, but every packet Δ would have covered is known by other live
  group members (the Lemma-2 storage condition) — each receiver fetches
  its missing packet uncoded from any live holder.
* stage-3 unicasts from a failed sender: the k-1 batches it would have
  aggregated are each stored on other owners of the job; the receiver
  collects them (at most k-1 uncoded values instead of 1).
* the failed server's reduce functions are reassigned to live servers
  (function migration), which then also receive the values the failed
  server would have decoded.

:class:`DegradedCAMREngine` executes exactly this protocol and reports
the load inflation; the straggler path is identical (a straggler is a
failure with a deadline). The degraded schedule is not patched at run
time: :func:`repro.core.schedule.lower_degraded` RE-LOWERS the compiled
:class:`~repro.core.schedule.ShuffleProgram` against the surviving
server set, and the engine here interprets the result. The re-lowering
goes through :data:`repro.core.schedule.SCHEDULE_CACHE`, keyed by the
survivor set, so a stream of waves on a degraded cluster pays it once
(DESIGN.md §7/§9). Elastic re-planning rebuilds the design for a new K
and quantifies data movement.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.designs import factorize_cluster, make_design
from repro.core.engine import CAMRConfig, CAMREngine
from repro.core.placement import make_placement
from repro.core.schedule import SCHEDULE_CACHE, DegradedProgram
from repro.core.shuffle import Transmission

__all__ = ["DegradedCAMREngine", "elastic_replan", "ReplanReport"]


class DegradedCAMREngine(CAMREngine):
    """CAMR engine that survives a set of failed/straggling servers.

    ``failed`` servers complete the Map phase but are silent in the
    Shuffle (crash or deadline-miss after map). Their reduce functions
    are migrated to the next live server in their parallel class.

    All scheduling decisions live in the re-lowered
    :class:`~repro.core.schedule.DegradedProgram`; this class only moves
    the bytes it prescribes.
    """

    def __init__(self, cfg: CAMRConfig, map_fn, failed: set[int],
                 **kw):
        super().__init__(cfg, map_fn, **kw)
        self.failed = set(failed)
        # raises ValueError when the loss exceeds the redundancy; the
        # re-lowering is cached per (configuration, survivor set), so a
        # JobStream of waves on a degraded cluster pays it once
        self.degraded: DegradedProgram = SCHEDULE_CACHE.degraded(
            self.program, self.failed)

    # -- function migration -------------------------------------------- #
    def migrate_target(self, s: int) -> int:
        """Live server taking over s's reduce duties (same class)."""
        return int(self.degraded.migrate[s])

    # -- degraded shuffle ----------------------------------------------- #
    def _coded_stage(self, stage, fn_group):
        """Run Algorithm 2 for the fully-live group rows; deliver the
        degraded rows uncoded, exactly as the re-lowered program says."""
        K = self.cfg.K
        prog, deg = self.program, self.degraded
        for row in deg.coded_rows:
            if int(prog.stage_of[row]) == stage:
                self._run_coded_group(int(row), stage, fn_group)
        for row, sends in deg.uncoded:
            if int(prog.stage_of[row]) != stage:
                continue
            G = prog.group_members(row)
            for holder, rcv, job, batch, owner in sends:
                qf = fn_group * K + owner
                val = self.servers[holder].agg[(job, batch)][qf]
                payload = self._ser(val)
                self.trace.add(Transmission(
                    stage=stage, sender=holder, receivers=(rcv,),
                    payload=payload, tag=("degraded", G)))
                self.servers[rcv].recv_batch[(job, batch, qf)] = \
                    self._de(payload)

    def _stage3(self, fn_group):
        """Interpret the re-lowered stage-3 sends (normal unicasts,
        per-batch recovery from redundant holders, and migration fill).
        Entries sharing a (receiver, job, function) key are combined
        locally first, then ASSIGNED — shuffle_phase stays idempotent
        like the base engine's."""
        K = self.cfg.K
        acc_map: dict = {}
        for snd, rcv, job, owner, batches in self.degraded.s3:
            qf = fn_group * K + owner
            sender_st = self.servers[snd]
            acc = None
            for t in batches:
                v = sender_st.agg[(job, t)][qf]
                acc = v if acc is None else self.combine(acc, v)
            payload = self._ser(acc)
            self.trace.add(Transmission(
                stage=3, sender=snd, receivers=(rcv,),
                payload=payload, tag=("job", job, "fn", fn_group)))
            key = (rcv, job, qf)
            val = self._de(payload)
            acc_map[key] = (val if key not in acc_map
                            else self.combine(acc_map[key], val))
        for (rcv, job, qf), val in acc_map.items():
            self.servers[rcv].recv_rest[(job, qf)] = val

    def reduce_phase(self):
        """Reduce on live servers; migrated functions use the redirected
        (stage-1/2 batch value) + (stage-3/fill complement) pair."""
        pl, d = self.placement, self.design
        results = [dict() for _ in range(d.K)]
        for s_orig in range(d.K):
            s = self.migrate_target(s_orig)
            st = self.servers[s]
            migrated = s != s_orig
            for qf in self.functions_of(s_orig):
                for j in range(d.J):
                    if migrated:
                        # unified: l = owner of j in the FAILED server's
                        # class (l == s_orig when s_orig owned j)
                        cls = d.class_of(s_orig)
                        (l,) = [u for u in d.owners[j]
                                if d.class_of(u) == cls]
                        tl = pl.batch_of_label(j, l)
                        acc = self.combine(st.recv_batch[(j, tl, qf)],
                                           st.recv_rest[(j, qf)])
                    elif d.is_owner(s, j):
                        # canonical order (engine.reduce_phase): delivered
                        # batch + ascending fold of the k-1 stored ones
                        tmiss = pl.batch_of_label(j, s)
                        rest = None
                        for t in range(d.k):
                            if t != tmiss:
                                v = st.agg[(j, t)][qf]
                                rest = v if rest is None \
                                    else self.combine(rest, v)
                        acc = self.combine(st.recv_batch[(j, tmiss, qf)],
                                           rest)
                    else:
                        cls = d.class_of(s)
                        (l,) = [u for u in d.owners[j]
                                if d.class_of(u) == cls]
                        tl = pl.batch_of_label(j, l)
                        acc = self.combine(st.recv_batch[(j, tl, qf)],
                                           st.recv_rest[(j, qf)])
                    results[s][(j, qf)] = acc
            if migrated:
                results[s_orig] = {}
        return results


@dataclass(frozen=True)
class ReplanReport:
    old_qk: tuple
    new_qk: tuple
    moved_fraction: float     # fraction of stored subfiles that must move
    new_storage_fraction: float


def elastic_replan(q_old: int, k_old: int, K_new: int,
                   mu_target: float | None = None,
                   gamma: int = 1) -> ReplanReport:
    """Re-derive the design for a resized cluster and quantify movement.

    Servers keep their index order; subfiles already resident count as
    not-moved. The CAMR structural requirement is only K = q*k, so
    elastic scaling is a pure re-placement (no re-encoding of data)."""
    q_new, k_new = factorize_cluster(K_new, mu_target)
    old = make_placement(make_design(q_old, k_old), gamma)
    new = make_placement(make_design(q_new, k_new), gamma)
    K_old = q_old * k_old
    # compare on the job universe of the smaller plan, normalized per job
    J = min(old.design.J, new.design.J)
    total, moved = 0, 0
    for s in range(min(K_old, K_new)):
        old_set = {(j, n) for j, n in old.stored_subfiles(s) if j < J}
        new_set = {(j, n) for j, n in new.stored_subfiles(s) if j < J}
        total += len(new_set)
        moved += len(new_set - old_set)
    for s in range(min(K_old, K_new), K_new):   # fresh servers fetch all
        new_set = {(j, n) for j, n in new.stored_subfiles(s) if j < J}
        total += len(new_set)
        moved += len(new_set)
    return ReplanReport(
        old_qk=(q_old, k_old), new_qk=(q_new, k_new),
        moved_fraction=moved / max(total, 1),
        new_storage_fraction=(k_new - 1) / K_new)
