"""JobStream — a pipelined multi-wave CAMR runtime (DESIGN.md §9).

A *wave* is one complete CAMR execution: ``J = q**(k-1)`` aggregated
MapReduce jobs pushed through Map -> per-batch Combine -> 3-stage coded
Shuffle -> Reduce on the ``K = q*k``-server cluster. The serial baseline
(:meth:`repro.core.engine.CAMREngine.run_stream`) runs waves strictly
one at a time — the shuffle machinery idles during map and vice versa,
exactly the waste the coded-MapReduce line of work (Li et al.,
1512.01625 / 1604.07086) identifies as dominating job time.

:class:`JobStream` streams heterogeneous waves through the cluster with
three cooperating mechanisms, all byte-preserving:

* **schedule caching** — every engine pulls its lowered
  :class:`~repro.core.schedule.ShuffleProgram` (and any degraded
  re-lowering) from the structural
  :data:`~repro.core.schedule.SCHEDULE_CACHE`, so lowering cost is paid
  once per ``(q, k, gamma, label_perm, Q, survivor-set)`` configuration
  instead of once per wave.
* **wave batching** — same-shaped waves are stacked along the value
  axis ``d`` and run as a SINGLE ShuffleProgram execution. The XOR
  codec and any elementwise combiner act independently per value
  element, so concatenation commutes with the whole pipeline and the
  split results are bit-identical to serial runs (tested in
  tests/test_jobstream.py).
* **software pipelining** — the map/aggregate phase of batch ``t+1``
  runs on a prefetch thread while the main thread drives the shuffle +
  reduce of batch ``t`` (double buffering: at most TWO batches of
  aggregates are alive at any time; memory cost model in DESIGN.md §9).

The SPMD counterpart — async, double-buffered dispatch of the shard_map
executor — is :class:`repro.core.collective.ShuffleStream`; this module
is the host-side runtime and the bit-exact reference for it.
"""

from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Callable, Sequence

import numpy as np

from repro.core.engine import CAMRConfig, CAMREngine
from repro.core.schedule import SCHEDULE_CACHE

__all__ = ["JobSpec", "JobStream", "StreamReport"]

def _check_wave_dtype(dtype, where: str) -> None:
    """Entry guard for half-precision value dtypes.

    The numpy engine XORs raw bytes, so every full-width dtype (and
    sub-word integers) transports losslessly, as it always has. 16-bit
    floats are accepted exactly when the SPMD codec lists a wire lane
    for them — :data:`repro.core.collective.PACKED_DTYPES`, backed by
    :data:`~repro.core.collective.CODEC_DTYPES` as the single source
    of truth (DESIGN.md §12) — so this guard and the collective's can
    never drift apart. Today both halves are packed-lane members and
    the raise arm is a tripwire against a future lane removal.
    """
    from repro.core.collective import CODEC_DTYPES, PACKED_DTYPES

    dt = np.dtype(dtype)
    half_float = (dt.itemsize == 2
                  and (dt.kind == "f" or dt.name == "bfloat16"))
    if half_float and dt.name not in CODEC_DTYPES:
        raise TypeError(
            f"{where}: {dt.name} values have no codec wire lane; the "
            f"packed 16-bit lane covers {', '.join(PACKED_DTYPES)} "
            "(DESIGN.md §12) — cast the map outputs "
            "(v.astype(np.float32)) or use a supported dtype.")


@dataclass(frozen=True)
class JobSpec:
    """One wave submitted to a :class:`JobStream`.

    ``datasets[j][n]`` is subfile ``n`` of job ``j`` (the engine's
    :meth:`~repro.core.engine.CAMREngine.run` input); ``map_fn`` and
    ``combine`` follow the engine's contract. Waves batch together only
    when they share :meth:`shape_key` — the schedule shape AND the
    combiner (stacking along ``d`` requires the same elementwise
    combine on both sides of the seam). Waves in one batch must also
    produce the same value dtype (``np.concatenate`` would silently
    promote mixed dtypes, changing the bits): declare ``value_dtype``
    to pre-split mixed-dtype streams into separate batches; undeclared
    mismatches are detected at map time and raise.
    """

    cfg: CAMRConfig
    map_fn: Callable
    datasets: Sequence = field(repr=False)
    combine: Callable = np.add
    name: str = ""
    value_dtype: object = None

    def __post_init__(self):
        if self.value_dtype is not None:
            _check_wave_dtype(self.value_dtype,
                              f"JobSpec {self.name!r}")

    def shape_key(self) -> tuple:
        c = self.cfg
        dt = (None if self.value_dtype is None
              else np.dtype(self.value_dtype).str)
        return (c.q, c.k, c.gamma, c.num_functions(), self.combine, dt)


@dataclass
class StreamReport:
    """What the last :meth:`JobStream.run` did (for benchmarks/tests)."""

    waves: int
    batches: int
    cache_hits: int       # SCHEDULE_CACHE hits during the run
    cache_misses: int     # lowerings actually paid during the run
    pipelined: bool
    migrations: int = 0   # in-flight engine re-targets (elastic runs)
    batch_times: list = field(default_factory=list)  # wall s per batch
                          # completion (elastic recovery-gap signal)


class JobStream:
    """Pipelined multi-wave scheduler over the numpy CAMR engine.

    Parameters
    ----------
    failed
        Optional failed-server set: waves run on the degraded cluster
        via :class:`repro.runtime.fault.DegradedCAMREngine`, whose
        survivor-set re-lowering is served from the schedule cache.
    batching
        Stack same-shaped waves along ``d`` into one engine pass
        (default on). ``wave_batch`` caps the stack width — the default
        of 4 keeps batches small enough that homogeneous streams still
        pipeline and bounds live memory at ``2 * wave_batch`` waves'
        aggregates (the double buffer); ``wave_batch=None`` removes the
        cap (one maximal batch per shape, no overlap within a shape).
    pipeline
        Overlap map/aggregate of the next batch with shuffle+reduce of
        the current one on a prefetch thread (default on).
    elastic
        Live-churn controller (:class:`repro.runtime.fault
        .ElasticController`, or a bare :class:`~repro.runtime.fault
        .Membership` which gets wrapped): workers may die, straggle and
        rejoin BETWEEN batches. Each batch's engine is built against
        the survivor set at its map time, re-targeted (zero map
        recompute, warm-cache re-lowering) right before its shuffle if
        membership moved while it was in flight, and its per-server map
        timings feed the controller's straggler detector. Results come
        back in LOGICAL slots — bitwise-identical to the healthy serial
        oracle for every churn schedule (DESIGN.md §14). Mutually
        exclusive with the static ``failed`` set.
    """

    DEFAULT_WAVE_BATCH = 4

    def __init__(self, *, failed: set[int] | None = None,
                 batching: bool = True,
                 wave_batch: int | None = DEFAULT_WAVE_BATCH,
                 pipeline: bool = True, elastic=None):
        if wave_batch is not None and wave_batch < 1:
            raise ValueError("wave_batch must be >= 1 (or None for "
                             "no cap)")
        if elastic is not None and failed:
            raise ValueError(
                "failed= is a static survivor set; elastic= manages "
                "membership live — pass the kill to the controller "
                "(membership.kill) instead of both")
        if elastic is not None:
            from repro.runtime.fault import (ElasticController,
                                             Membership)
            if isinstance(elastic, Membership):
                elastic = ElasticController(elastic)
        self.elastic = elastic
        self.failed = set(failed) if failed else None
        self.batching = batching
        self.wave_batch = wave_batch
        self.pipeline = pipeline
        self.last_report: StreamReport | None = None
        #: engines of the last run, one per batch in completion order —
        #: byte accounting (``.trace``) and degraded-mode migration
        #: (``.migrate_target``) for callers like the training loop.
        self.last_engines: list = []

    # ------------------------------------------------------------------ #
    # batching plan
    # ------------------------------------------------------------------ #
    def _plan_batches(self, specs: list[JobSpec]) -> list[list[int]]:
        """Group submission indices by shape key (first-seen order),
        splitting groups at ``wave_batch``."""
        if not self.batching:
            return [[i] for i in range(len(specs))]
        groups: dict = {}
        order: list = []
        for i, sp in enumerate(specs):
            key = sp.shape_key()
            if key not in groups:
                groups[key] = []
                order.append(key)
            groups[key].append(i)
        cap = (max((len(v) for v in groups.values()), default=1)
               if self.wave_batch is None else self.wave_batch)
        out = []
        for key in order:
            idxs = groups[key]
            out.extend(idxs[a:a + cap] for a in range(0, len(idxs), cap))
        return out

    # ------------------------------------------------------------------ #
    # one batch = one engine pass over d-stacked waves
    # ------------------------------------------------------------------ #
    def _make_engine(self, specs: list[JobSpec], idxs: list[int],
                     failed=None):
        """Build the batched engine + datasets for one batch.

        Returns ``(engine, datasets, widths)`` where ``widths[w]`` is
        filled with wave ``w``'s value width after the map phase runs.
        ``failed`` overrides the stream's static set (elastic runs pass
        the controller's survivor set at map time).
        """
        batch = [specs[i] for i in idxs]
        cfg = batch[0].cfg
        W = len(batch)
        widths: list = [None] * W

        def map_fn(job, subfiles):
            vals = []
            for w, sp in enumerate(batch):
                v = np.asarray(sp.map_fn(job, subfiles[w]))
                _check_wave_dtype(v.dtype, f"JobStream wave {sp.name!r}")
                widths[w] = v.shape[1] if v.ndim == 2 else None
                vals.append(v)
            if W == 1:
                return vals[0]
            if len({v.dtype for v in vals}) > 1:
                raise ValueError(
                    "waves with different value dtypes cannot be "
                    "stacked bit-exactly (np.concatenate would "
                    "promote); declare JobSpec.value_dtype so they "
                    "batch separately, or run with batching=False: "
                    f"{[str(v.dtype) for v in vals]}")
            return np.concatenate(vals, axis=1)

        J, N = cfg.J, cfg.N
        for sp in batch:
            # same checks CAMREngine.run applies — truncating or
            # index-erroring here would diverge from the serial oracle
            if len(sp.datasets) != J:
                raise ValueError(
                    f"spec {sp.name!r}: need {J} job datasets, got "
                    f"{len(sp.datasets)}")
            for ds in sp.datasets:
                if len(ds) != N:
                    raise ValueError(
                        f"spec {sp.name!r}: each job needs N={N} "
                        "subfiles")
        datasets = [
            [tuple(sp.datasets[j][n] for sp in batch) for n in range(N)]
            for j in range(J)
        ]
        failed = self.failed if failed is None else (set(failed) or None)
        if failed:
            from repro.runtime.fault import DegradedCAMREngine
            eng = DegradedCAMREngine(cfg, map_fn, failed,
                                     combine=batch[0].combine)
        else:
            eng = CAMREngine(cfg, map_fn, combine=batch[0].combine)
        return eng, datasets, widths

    @staticmethod
    def _split_results(results, widths: list) -> list:
        """Slice per-server ``(job, fn) -> (sum(widths),)`` values back
        into per-wave result structures (submission order preserved by
        the caller)."""
        offs = np.concatenate([[0], np.cumsum(widths)])
        out = []
        for w in range(len(widths)):
            a, b = int(offs[w]), int(offs[w + 1])
            out.append([{key: v[a:b] for key, v in res.items()}
                        for res in results])
        return out

    # ------------------------------------------------------------------ #
    # the stream
    # ------------------------------------------------------------------ #
    def run(self, specs: Sequence[JobSpec]) -> list:
        """Run every wave; returns per-wave results in submission order
        (each exactly what :meth:`CAMREngine.run` returns for that
        wave — bit-identical to the serial oracle)."""
        specs = list(specs)
        self.last_engines = []
        if not specs:
            self.last_report = StreamReport(
                waves=0, batches=0, cache_hits=0, cache_misses=0,
                pipelined=False)
            return []
        results: list = [None] * len(specs)
        batches = self._plan_batches(specs)
        s0 = SCHEDULE_CACHE.stats()
        ctrl = self.elastic
        migrations = 0
        batch_times: list[float] = []
        t_mark = [time.perf_counter()]

        def prepare(bi, idxs):
            # dataset validation + map phase: the prefetch-lane half of
            # the pipeline. Elastic runs map against the survivor set
            # at map time; a later membership change is absorbed by the
            # re-target in finish (the map state is survivor-agnostic —
            # every server maps its stored batches regardless).
            failed = ctrl.wave_start(bi) if ctrl is not None else None
            eng, datasets, widths = self._make_engine(specs, idxs,
                                                      failed=failed)
            eng.map_phase(datasets)
            return eng, widths, idxs

        def finish(bi, eng, widths, idxs):
            nonlocal migrations
            if ctrl is not None:
                # membership may have moved while this batch was in
                # flight: swap the shuffle schedule to the CURRENT
                # survivor set (warm-cache lookup, adopts the mapped
                # aggregates — no map recompute)
                from repro.runtime.fault import retarget_engine
                eng2 = retarget_engine(eng, ctrl.current_failed())
                if eng2 is not eng:
                    migrations += 1
                    eng = eng2
            eng.shuffle_phase()
            res = eng.reduce_phase()
            if ctrl is not None and getattr(eng, "failed", None):
                res = self._logical_slots(eng, res)
            split = self._split_results(res, widths)
            for w, spec_idx in enumerate(idxs):
                results[spec_idx] = split[w]
            self.last_engines.append(eng)
            if ctrl is not None:
                ctrl.wave_timings(bi, eng.map_times)
            now = time.perf_counter()
            batch_times.append(now - t_mark[0])
            t_mark[0] = now

        pipelined = self.pipeline and len(batches) > 1
        if pipelined:
            # double buffer: while batch t shuffles+reduces here, batch
            # t+1 maps on the worker — at most 2 engines alive
            with ThreadPoolExecutor(max_workers=1) as pool:
                fut = pool.submit(prepare, 0, batches[0])
                for t in range(len(batches)):
                    eng, widths, idxs = fut.result()
                    if t + 1 < len(batches):
                        fut = pool.submit(prepare, t + 1, batches[t + 1])
                    finish(t, eng, widths, idxs)
        else:
            for t, idxs in enumerate(batches):
                finish(t, *prepare(t, idxs))

        if ctrl is not None:
            ctrl.migrations += migrations
        s1 = SCHEDULE_CACHE.stats()
        self.last_report = StreamReport(
            waves=len(specs), batches=len(batches),
            cache_hits=s1["hits"] - s0["hits"],
            cache_misses=s1["misses"] - s0["misses"],
            pipelined=pipelined, migrations=migrations,
            batch_times=batch_times)
        return results

    @staticmethod
    def _logical_slots(eng, results) -> list:
        """Degraded engine results -> logical per-server slots.

        A degraded reduce leaves a failed server's functions on its
        migrate target (``results[failed] == {}``). Elastic callers are
        owed the HEALTHY result shape — server ``s``'s functions in
        slot ``s`` — and since degraded values are bitwise-identical to
        healthy values (the canonical-order contract, DESIGN.md §11),
        relocating them restores the exact serial-oracle output."""
        K = eng.cfg.K
        return [{key: val
                 for key, val in results[eng.migrate_target(s)].items()
                 if key[1] % K == s}
                for s in range(K)]
