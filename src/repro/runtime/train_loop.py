"""Training loops.

* :class:`Trainer` — single-model loop (used by launch/train.py and the
  examples): jitted step = grad-accumulated loss/grad + AdamW, metrics,
  async checkpointing, crash-resume.
* :class:`MultiModelCAMRTrainer` — the paper's setting end-to-end:
  J = q^{k-1} same-architecture models trained simultaneously on K
  workers. Per step: every worker maps its stored (job, batch)
  microbatches to gradients (computation redundancy k-1), per-batch
  gradients are compressed with the α-combiner
  (:func:`repro.kernels.aggregate.aggregate`), the CAMR 3-stage coded
  shuffle delivers each worker the fully-aggregated shard of every job
  it reduces (ZeRO-style: worker s owns optimizer shard s of ALL jobs),
  and the worker-sharded AdamW update is applied to the flat padded
  parameter vectors.

  Three grad-sync wires execute the same compiled schedule
  (DESIGN.md §11):

  * ``mode="camr_spmd"`` — the production path: the stacked per-worker
    contribution tensor ``[K, J_own, k-1, K, d]`` goes through ONE
    jitted shard_map execution of :func:`repro.core.collective
    .camr_shuffle` (fused gather-XOR codec) on a K-device mesh, reused
    across steps via :meth:`repro.core.collective.ShuffleStream.sync`;
    the synced gradient stays on the mesh for the update.
  * ``mode="camr"`` — the numpy :class:`~repro.core.engine.CAMREngine`
    interpreter, driven through a :class:`~repro.runtime.jobstream
    .JobStream` wave (byte-exact accounting; with ``failed=...`` it
    runs the degraded survivor-set schedule of runtime/fault.py).
  * ``mode="uncoded"`` — same placement, unicast everything (the
    paper's baseline).

  All three produce BIT-IDENTICAL parameters: gradients XOR-code
  losslessly, every executor reduces in the engine's canonical combine
  order (delivered batch + ascending fold), and every mode shares the
  same jitted update. Asserted exactly in tests/test_train_loop.py.

  ``grad_sync_dtype="bfloat16"`` turns on mixed-precision grad sync
  (DESIGN.md §12): the memoized map gradients are rounded to bf16 ONCE
  at the source, every wire ships them on the packed 16-bit codec lane
  (half the bytes), and the shared update upcasts the synced gradient
  to f32 against f32 master params/moments. The bitwise cross-mode
  contract holds per lane because all three executors consume the SAME
  bf16 bits and fold them in the same canonical order.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.checkpoint import CheckpointManager
from repro.configs import ModelConfig
from repro.core.engine import CAMRConfig, CAMREngine
from repro.data.pipeline import ShardedTokenPipeline
from repro.models import lm
from repro.optim import AdamWState, adamw_update, adamw_init, cosine_schedule


# --------------------------------------------------------------------- #
# single-model trainer
# --------------------------------------------------------------------- #
class Trainer:
    def __init__(self, cfg: ModelConfig, *, lr: float = 3e-4,
                 warmup: int = 20, total_steps: int = 1000,
                 ckpt_dir: str | None = None, seed: int = 0):
        self.cfg = cfg
        self.lr, self.warmup, self.total = lr, warmup, total_steps
        self.params = lm.init_params(cfg, jax.random.PRNGKey(seed))
        self.opt = adamw_init(self.params)
        self.step = 0
        self.ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
        self._jit_step = jax.jit(self._train_step)

    def _train_step(self, params, opt, batch, step):
        nmb = self.cfg.microbatches

        def loss_fn(p, mb):
            return lm.train_loss(self.cfg, p, mb)[0]

        if nmb == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            # gradient accumulation over microbatches (scan keeps HLO small)
            def split(x):
                return x.reshape(nmb, x.shape[0] // nmb, *x.shape[1:])
            mbs = jax.tree.map(split, batch)

            def acc(carry, mb):
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (carry[0] + l,
                        jax.tree.map(jnp.add, carry[1], g)), None

            zero = (jnp.zeros(()),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params))
            (loss, grads), _ = jax.lax.scan(acc, zero, mbs)
            loss = loss / nmb
            grads = jax.tree.map(lambda g: g / nmb, grads)
        lr = cosine_schedule(step, peak=self.lr, warmup_steps=self.warmup,
                             total_steps=self.total)
        params, opt, gnorm = adamw_update(params, grads, opt, lr=lr)
        return params, opt, {"loss": loss, "gnorm": gnorm, "lr": lr}

    def run(self, pipeline: ShardedTokenPipeline, steps: int,
            log_every: int = 10, ckpt_every: int = 0):
        metrics = []
        for _ in range(steps):
            batch = {k: jnp.asarray(v)
                     for k, v in pipeline.batch(self.step).items()}
            self.params, self.opt, m = self._jit_step(
                self.params, self.opt, batch, jnp.int32(self.step))
            self.step += 1
            if self.step % log_every == 0 or self.step == 1:
                metrics.append({k: float(v) for k, v in m.items()}
                               | {"step": self.step})
            if self.ckpt and ckpt_every and self.step % ckpt_every == 0:
                self.ckpt.save({"params": self.params, "opt": self.opt},
                               step=self.step,
                               metadata={"pipeline_step": self.step})
        if self.ckpt:
            # the final drain is the last chance to learn that an async
            # checkpoint write failed: wait() re-raises the worker error
            # (a run that "completed" with every checkpoint silently
            # lost must not look successful)
            self.ckpt.wait()
        return metrics

    def resume(self):
        """Crash-resume from the latest checkpoint (incl. data cursor)."""
        if not self.ckpt or self.ckpt.latest_step() is None:
            return False
        tree, meta = self.ckpt.restore(
            {"params": self.params, "opt": self.opt})
        self.params, self.opt = tree["params"], tree["opt"]
        self.step = meta["step"]
        return True


# --------------------------------------------------------------------- #
# the paper's multi-job trainer
# --------------------------------------------------------------------- #
@dataclass
class CAMRTrainReport:
    loads: dict = field(default_factory=dict)
    bytes_total: int = 0
    losses: list = field(default_factory=list)
    mode: str = ""
    sync: dict = field(default_factory=dict)   # executor-reuse stats
    grad_sync_dtype: str = "float32"           # shuffle payload dtype


def _mean_losses(per_job: list) -> list[float]:
    """Per-job mean loss for one step.

    ``per_job[j]`` maps subfile index -> loss; keyed (not appended) so
    every grad-sync mode averages in the same order regardless of the
    order its engine walked the subfiles. ``np.mean`` over an empty
    list warns and is undefined — an empty map (a job served entirely
    from a warm memo) is an explicit NaN instead.
    """
    return [float(np.mean([d[n] for n in sorted(d)])) if d
            else float("nan") for d in per_job]


class MultiModelCAMRTrainer:
    """Train J = q^{k-1} models with CAMR-coded gradient aggregation.

    Parameters beyond the original (cfg, q, k, lr, seed):

    mesh
        Device mesh with a single axis of size K = q*k. ``None`` builds
        one automatically when the process has >= K devices (it is used
        by EVERY mode's update placement, so coded/uncoded/SPMD runs in
        one process stay bit-comparable); ``mode="camr_spmd"`` requires
        it.
    failed
        Failed/straggling worker set: ``mode="camr"`` steps run the
        degraded survivor-set schedule (runtime/fault.py), and
        ``mode="camr_spmd"`` steps route through the stream's degraded
        host lane (:meth:`~repro.core.collective.ShuffleStream
        .degrade` — no retrace, DESIGN.md §14). Recovery is exact — a
        degraded step leaves the trajectory bit-identical to the
        healthy one; flip membership live via :meth:`set_failed`.
    spmd_oracle
        When true, every ``camr_spmd`` step ALSO runs the numpy engine
        on the same memoized gradients and asserts the device result
        equals it bit-for-bit (and takes the measured byte accounting
        from the engine trace). Off by default: the engine is the
        *oracle*, not the fast path.
    grad_sync_dtype
        Shuffle payload dtype: ``"float32"`` (default) or
        ``"bfloat16"`` for mixed-precision grad sync — gradients are
        rounded to bf16 once at the map memo, synced on the packed
        16-bit codec lane at half the bytes-on-wire, and upcast to f32
        for the master-copy update (DESIGN.md §12). ``None`` reads
        ``cfg.grad_sync_dtype``. ``float16`` is rejected: raw LM
        gradients overflow/underflow its 5-bit exponent without loss
        scaling — use bfloat16 (f32-range exponent) instead.

    State layout: parameters, moments and synced gradients live as flat
    padded f32 vectors of ``Dpad = K * d_shard`` elements per job
    (``(k-1) | d_shard`` so every shard splits into codec packets);
    worker s owns shard s of every job — the update is worker-sharded
    ZeRO-style and identical across modes by construction (one jitted
    update function).
    """

    def __init__(self, cfg: ModelConfig, *, q: int, k: int,
                 lr: float = 1e-3, seed: int = 0, mesh=None,
                 axis_name: str = "camr", codec: str = "fused",
                 router: str = "all_to_all", use_kernels=None,
                 failed=None, spmd_oracle: bool = False,
                 grad_sync_dtype: str | None = None):
        self.cfg, self.q, self.k = cfg, q, k
        gsd = (cfg.grad_sync_dtype if grad_sync_dtype is None
               else grad_sync_dtype)
        name = jnp.dtype(gsd).name
        if name == "float16":
            raise ValueError(
                "grad_sync_dtype=float16 is unsafe for raw gradients: "
                "the 5-bit exponent overflows above 65504 and flushes "
                "below ~6e-5, and this trainer implements no loss "
                "scaling. Use grad_sync_dtype='bfloat16' (same exponent "
                "range as float32, same 2x wire savings) or 'float32'.")
        if name not in ("float32", "bfloat16"):
            raise ValueError(
                f"grad_sync_dtype must be float32 or bfloat16, got "
                f"{name}")
        self.grad_sync_dtype = name
        #: numpy view of the sync dtype (ml_dtypes.bfloat16 rounds and
        #: adds bit-identically to the XLA bf16 lane for normal values)
        self._sync_np = np.dtype(np.float32 if name == "float32"
                                 else "bfloat16")
        self.camr = CAMRConfig(q=q, k=k, gamma=1)
        J, K = self.camr.J, self.camr.K
        keys = jax.random.split(jax.random.PRNGKey(seed), J)
        params = [lm.init_params(cfg, keys[j]) for j in range(J)]
        flat0, self._unravel = ravel_pytree(params[0])
        self.D = flat0.size
        self.K = K
        # pad so the K function-shards are equal (paper: Q | gradients)
        # AND each shard splits into k-1 codec packets (collective.py)
        d = -(-self.D // K)
        d += (-d) % (k - 1)
        self.d_shard = d
        self.Dpad = K * d
        flat = np.zeros((J, self.Dpad), np.float32)
        for j in range(J):
            flat[j, :self.D] = np.asarray(ravel_pytree(params[j])[0],
                                          np.float32)
        self.flat = jnp.asarray(flat)          # f32 master copy [J, Dpad]
        self.opt = AdamWState(step=jnp.zeros((J,), jnp.int32),
                              mu=jnp.zeros((J, self.Dpad), jnp.float32),
                              nu=jnp.zeros((J, self.Dpad), jnp.float32))
        self.lr = lr
        self.step = 0
        self.axis_name = axis_name
        self.codec, self.router, self.use_kernels = codec, router, use_kernels
        self.failed = set(failed) if failed else None
        self.spmd_oracle = spmd_oracle
        self.mesh = mesh
        if self.mesh is None and len(jax.devices()) >= K:
            from repro.compat import make_mesh
            self.mesh = make_mesh((K,), (axis_name,))
        self._stream = None                    # lazy ShuffleStream
        self.map_calls = 0                     # gradient computations paid

        D, Dpad, N = self.D, self.Dpad, self.camr.N

        def _loss_grad(flat_row, batch):
            def loss_fn(fl):
                return lm.train_loss(cfg, self._unravel(fl[:D]), batch)[0]
            return jax.value_and_grad(loss_fn)(flat_row)

        self._grad = jax.jit(_loss_grad)

        def _apply(flat, opt, gsync):
            # gsync [K, J, d]: worker s holds shard s of every job's
            # summed gradient. Transpose/reshape are pure data movement;
            # the astype upcasts a bf16-lane sync to the f32 master
            # numerics (exact — a no-op on the f32 lane); /N and AdamW
            # are elementwise (+ the per-job clip norm) — ONE function
            # for every sync mode, so cross-mode parameter bits can
            # only diverge if the shuffles themselves do.
            grads = jnp.transpose(gsync, (1, 0, 2)).reshape(
                J, Dpad).astype(jnp.float32) / N
            return jax.vmap(partial(adamw_update, lr=lr))(flat, grads, opt)

        self._apply = jax.jit(_apply)

    # ------------------------------------------------------------------ #
    @property
    def params(self):
        """Per-job parameter pytrees (unravelled views of the master)."""
        return [self._unravel(self.flat[j, :self.D])
                for j in range(self.camr.J)]

    def _grad_vec(self, j: int, n: int, batch) -> np.ndarray:
        loss, g = self._grad(self.flat[j],
                             {k: jnp.asarray(v) for k, v in batch.items()})
        self._last_loss[j][n] = float(loss)
        self.map_calls += 1
        g = np.asarray(g, np.float32).reshape(self.K, self.d_shard)
        # mixed precision: round ONCE at the memo source so every
        # grad-sync wire consumes the SAME bf16 bits (ml_dtypes casts
        # round-to-nearest-even, bit-identical to the XLA convert)
        return g if self._sync_np == np.float32 else g.astype(self._sync_np)

    def _place(self, gsync):
        """Put a synced-gradient array where the update expects it: on
        the worker mesh (sharded along K) when one exists. The SPMD
        output already lives there; host-engine results are transferred
        — the point is that every mode feeds the SAME placement, so the
        jitted update compiles once and reduces identically."""
        g = gsync if isinstance(gsync, jnp.ndarray) else jnp.asarray(gsync)
        if self.mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            g = jax.device_put(g, NamedSharding(self.mesh,
                                                P(self.axis_name)))
        return g

    # -- grad-sync wires ------------------------------------------------ #
    def _assemble(self, results, migrate=None) -> np.ndarray:
        """Engine result dicts -> gsync [K, J, d] (pure data movement)."""
        J, K = self.camr.J, self.K
        gs = np.empty((K, J, self.d_shard), self._sync_np)
        for s in range(K):
            src = migrate(s) if migrate else s
            for j in range(J):
                gs[s, j] = results[src][(j, s)]
        return gs

    def _sync_interpreter(self, map_fn, datasets, report) -> np.ndarray:
        from repro.runtime.jobstream import JobSpec, JobStream

        stream = JobStream(failed=self.failed, pipeline=False)
        spec = JobSpec(self.camr, map_fn, datasets,
                       name=f"train-step{self.step}",
                       value_dtype=self._sync_np)
        results = stream.run([spec])[0]
        eng = stream.last_engines[0]
        report.loads = eng.measured_loads()
        report.bytes_total += eng.trace.total_bytes()
        migrate = eng.migrate_target if self.failed else None
        return self._assemble(results, migrate)

    def _sync_uncoded(self, map_fn, datasets, report) -> np.ndarray:
        from repro.core.baselines import UncodedAggregatedEngine

        if self.failed:
            raise ValueError("the uncoded baseline has no degraded mode; "
                             "failed-worker steps need mode='camr'")
        eng = UncodedAggregatedEngine(self.q, self.k, 1, map_fn)
        results = eng.run(datasets)
        report.loads = {"L_total_bus": eng.measured_load()}
        report.bytes_total += eng.trace.total_bytes()
        return self._assemble(results)

    def set_failed(self, failed) -> None:
        """Live membership change between steps: subsequent ``camr``
        steps re-lower from the warm schedule cache, and an existing
        SPMD stream swaps to its degraded lane (or back) WITHOUT
        retracing — ``stream.compiles`` stays flat across kill/rejoin
        (DESIGN.md §14). Recovery is exact: degraded steps leave the
        parameter trajectory bit-identical to the healthy one."""
        self.failed = set(failed) if failed else None
        if self._stream is not None:
            if self.failed:
                self._stream.degrade(self.failed)
            else:
                self._stream.restore()

    def _spmd_stream(self):
        if self._stream is None:
            from repro.core.collective import ShuffleStream
            if self.mesh is None:
                raise RuntimeError(
                    f"mode='camr_spmd' needs a {self.K}-device mesh; this "
                    f"process sees {len(jax.devices())} device(s). On CPU "
                    "set XLA_FLAGS=--xla_force_host_platform_device_count="
                    f"{self.K} before importing jax, or pass mesh=.")
            self._stream = ShuffleStream(
                self.q, self.k, self.d_shard, mesh=self.mesh,
                axis_name=self.axis_name, mode="batched",
                router=self.router, codec=self.codec,
                use_kernels=self.use_kernels)
        # reconcile with the trainer's failed set (covers both a lazy
        # first build under failure and a direct self.failed mutation)
        want = frozenset(self.failed or ())
        if want != self._stream.failed:
            self._stream.degrade(want) if want else self._stream.restore()
        return self._stream

    def _build_contribs(self, map_fn, datasets) -> np.ndarray:
        """The map lane of the SPMD path: per worker, map the stored
        (job, batch) subfiles and compress same-batch outputs with the
        α-combiner (:func:`repro.kernels.aggregate.aggregate`) into the
        stacked contribution tensor ``[K, J_own, k-1, K, d]``.

        gamma == 1 here, so each segment holds exactly one subfile and
        the combiner is bit-exact (a one-hot matmul gather); wider
        gammas would sum through the MXU."""
        from repro.core.collective import make_plan
        from repro.kernels.aggregate import aggregate

        prog = make_plan(self.q, self.k, self.d_shard).program
        K, k = self.K, self.k
        J_own = self.q ** (self.k - 2)
        pl = prog.placement
        out = np.zeros((K, J_own, k - 1, K, self.d_shard), self._sync_np)
        for s in range(K):
            vals, ids = [], []
            for a in range(J_own):
                j = int(prog.owned_jobs[s, a])
                for b in range(k - 1):
                    t = int(prog.stored_batches[s, a, b])
                    for n in pl.batch_subfiles(t):
                        vals.append(np.asarray(
                            map_fn(j, datasets[j][n])).reshape(-1))
                        ids.append(a * (k - 1) + b)
            agg = aggregate(jnp.asarray(np.stack(vals)),
                            jnp.asarray(np.asarray(ids, np.int32)),
                            J_own * (k - 1))
            out[s] = np.asarray(agg).reshape(J_own, k - 1, K, self.d_shard)
        return out

    def _sync_spmd(self, map_fn, datasets, report):
        stream = self._spmd_stream()
        contribs = self._build_contribs(map_fn, datasets)
        out = stream.sync(jnp.asarray(contribs))   # device [K, J, d]
        if self.spmd_oracle:
            # the numpy engine is the bit-identity + byte-accounting
            # oracle of the device path (map_fn memoized: no extra
            # gradient computes)
            eng = CAMREngine(self.camr, map_fn)
            results = eng.run(datasets)
            np.testing.assert_array_equal(
                np.asarray(out), self._assemble(results),
                err_msg="camr_spmd shuffle diverged from the engine "
                        "oracle")
            report.loads = eng.measured_loads()
            report.bytes_total += eng.trace.total_bytes()
        else:
            from repro.core import loads as L
            from repro.core.collective import (camr_collective_bytes,
                                               make_plan)
            plan = make_plan(self.q, self.k, self.d_shard)
            report.loads = {
                "L_total_bus": L.camr_load(self.q, self.k),
                "L_total_p2p": L.camr_load_p2p(self.q, self.k),
            }
            report.bytes_total += camr_collective_bytes(
                plan, dtype=self._sync_np)["camr_total"]
        report.sync = stream.stats()
        return out

    # ------------------------------------------------------------------ #
    def train_steps(self, pipeline: ShardedTokenPipeline, steps: int,
                    mode: str = "camr") -> CAMRTrainReport:
        """Run ``steps`` training steps; ``self.step`` advances, so
        consecutive calls continue the same data stream (a mid-run
        mode or ``failed`` switch keeps the trajectory comparable)."""
        from repro.data.pipeline import make_camr_job_datasets

        syncs = {"camr": self._sync_interpreter,
                 "uncoded": self._sync_uncoded,
                 "camr_spmd": self._sync_spmd}
        if mode not in syncs:
            raise ValueError(f"unknown mode {mode!r}; choose from "
                             f"{sorted(syncs)}")
        report = CAMRTrainReport(mode=mode,
                                 grad_sync_dtype=self.grad_sync_dtype)
        J, N = self.camr.J, self.camr.N
        for _ in range(steps):
            self._last_loss = [dict() for _ in range(J)]
            base = make_camr_job_datasets(pipeline, J, N, self.step)
            # subfile payloads carry their index: the gradient memo is
            # keyed by (job, subfile_index) — an id(subfile)-keyed memo
            # is only unique while the object lives, i.e. one GC away
            # from silently serving another subfile's gradient
            datasets = [[(n, base[j][n]) for n in range(N)]
                        for j in range(J)]
            cache: dict = {}

            def map_fn(j, subfile):
                n, batch = subfile
                key = (j, n)
                if key not in cache:   # each (job, subfile) mapped once
                    cache[key] = self._grad_vec(j, n, batch)  # per step
                return cache[key]

            gsync = syncs[mode](map_fn, datasets, report)
            self.flat, self.opt, _ = self._apply(
                self.flat, self.opt, self._place(gsync))
            report.losses.append(_mean_losses(self._last_loss))
            self.step += 1
        return report
