"""Training loops.

* :class:`Trainer` — single-model loop (used by launch/train.py and the
  examples): jitted step = grad-accumulated loss/grad + AdamW, metrics,
  async checkpointing, crash-resume.
* :class:`MultiModelCAMRTrainer` — the paper's setting end-to-end:
  J = q^{k-1} same-architecture models trained simultaneously on K
  simulated workers. Per step: every worker maps its stored (job, batch)
  microbatches to gradients (computation redundancy k-1), the CAMR
  3-stage coded shuffle delivers each worker the fully-aggregated shard
  of every job it reduces (ZeRO-style: worker s owns optimizer shard s of
  ALL jobs), workers update their shards, and the updated shards are
  reassembled. Byte-exact shuffle accounting comes along for free.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.checkpoint import CheckpointManager
from repro.configs import ModelConfig
from repro.core.engine import CAMRConfig, CAMREngine
from repro.data.pipeline import ShardedTokenPipeline
from repro.models import lm
from repro.optim import adamw_init, adamw_update, cosine_schedule


# --------------------------------------------------------------------- #
# single-model trainer
# --------------------------------------------------------------------- #
class Trainer:
    def __init__(self, cfg: ModelConfig, *, lr: float = 3e-4,
                 warmup: int = 20, total_steps: int = 1000,
                 ckpt_dir: str | None = None, seed: int = 0):
        self.cfg = cfg
        self.lr, self.warmup, self.total = lr, warmup, total_steps
        self.params = lm.init_params(cfg, jax.random.PRNGKey(seed))
        self.opt = adamw_init(self.params)
        self.step = 0
        self.ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
        self._jit_step = jax.jit(self._train_step)

    def _train_step(self, params, opt, batch, step):
        nmb = self.cfg.microbatches

        def loss_fn(p, mb):
            return lm.train_loss(self.cfg, p, mb)[0]

        if nmb == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            # gradient accumulation over microbatches (scan keeps HLO small)
            def split(x):
                return x.reshape(nmb, x.shape[0] // nmb, *x.shape[1:])
            mbs = jax.tree.map(split, batch)

            def acc(carry, mb):
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (carry[0] + l,
                        jax.tree.map(jnp.add, carry[1], g)), None

            zero = (jnp.zeros(()),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params))
            (loss, grads), _ = jax.lax.scan(acc, zero, mbs)
            loss = loss / nmb
            grads = jax.tree.map(lambda g: g / nmb, grads)
        lr = cosine_schedule(step, peak=self.lr, warmup_steps=self.warmup,
                             total_steps=self.total)
        params, opt, gnorm = adamw_update(params, grads, opt, lr=lr)
        return params, opt, {"loss": loss, "gnorm": gnorm, "lr": lr}

    def run(self, pipeline: ShardedTokenPipeline, steps: int,
            log_every: int = 10, ckpt_every: int = 0):
        metrics = []
        for _ in range(steps):
            batch = {k: jnp.asarray(v)
                     for k, v in pipeline.batch(self.step).items()}
            self.params, self.opt, m = self._jit_step(
                self.params, self.opt, batch, jnp.int32(self.step))
            self.step += 1
            if self.step % log_every == 0 or self.step == 1:
                metrics.append({k: float(v) for k, v in m.items()}
                               | {"step": self.step})
            if self.ckpt and ckpt_every and self.step % ckpt_every == 0:
                self.ckpt.save({"params": self.params, "opt": self.opt},
                               step=self.step,
                               metadata={"pipeline_step": self.step})
        if self.ckpt:
            self.ckpt.wait()
        return metrics

    def resume(self):
        """Crash-resume from the latest checkpoint (incl. data cursor)."""
        if not self.ckpt or self.ckpt.latest_step() is None:
            return False
        tree, meta = self.ckpt.restore(
            {"params": self.params, "opt": self.opt})
        self.params, self.opt = tree["params"], tree["opt"]
        self.step = meta["step"]
        return True


# --------------------------------------------------------------------- #
# the paper's multi-job trainer on simulated workers
# --------------------------------------------------------------------- #
@dataclass
class CAMRTrainReport:
    loads: dict = field(default_factory=dict)
    bytes_total: int = 0
    losses: list = field(default_factory=list)


class MultiModelCAMRTrainer:
    """Train J = q^{k-1} models with CAMR-coded gradient aggregation.

    grad-sync modes: 'camr' (coded 3-stage shuffle), 'uncoded' (same
    placement, unicast everything — the paper's baseline). Loss
    trajectories must match between modes to fp tolerance (same math,
    different wires) — asserted in tests.
    """

    def __init__(self, cfg: ModelConfig, *, q: int, k: int,
                 lr: float = 1e-3, seed: int = 0):
        self.cfg, self.q, self.k = cfg, q, k
        self.camr = CAMRConfig(q=q, k=k, gamma=1)
        J, K = self.camr.J, self.camr.K
        keys = jax.random.split(jax.random.PRNGKey(seed), J)
        self.params = [lm.init_params(cfg, keys[j]) for j in range(J)]
        flat0, self._unravel = ravel_pytree(self.params[0])
        self.D = flat0.size
        self.K = K
        # pad so the K function-shards are equal (paper: Q | gradients)
        self.d_shard = -(-self.D // K)
        self.opts = [adamw_init(p) for p in self.params]
        self.lr = lr
        self._grad = jax.jit(jax.value_and_grad(
            lambda p, b: lm.train_loss(cfg, p, b)[0]))
        self._upd = jax.jit(partial(adamw_update, lr=lr))

    def _grad_vec(self, j: int, batch) -> np.ndarray:
        loss, g = self._grad(self.params[j],
                             {k: jnp.asarray(v) for k, v in batch.items()})
        vec = np.asarray(ravel_pytree(g)[0], np.float32)
        pad = np.zeros(self.d_shard * self.K, np.float32)
        pad[:self.D] = vec
        self._last_loss[j].append(float(loss))
        return pad.reshape(self.K, self.d_shard)

    def train_steps(self, pipeline: ShardedTokenPipeline, steps: int,
                    mode: str = "camr") -> CAMRTrainReport:
        from repro.core.baselines import UncodedAggregatedEngine
        from repro.data.pipeline import make_camr_job_datasets

        report = CAMRTrainReport()
        J, N = self.camr.J, self.camr.N
        for step in range(steps):
            self._last_loss = [[] for _ in range(J)]
            datasets = make_camr_job_datasets(pipeline, J, N, step)
            cache: dict = {}

            def map_fn(j, subfile):
                key = (j, id(subfile))
                if key not in cache:   # each (job, subfile) mapped once per
                    cache[key] = self._grad_vec(j, subfile)  # worker set
                return cache[key]

            if mode == "camr":
                eng = CAMREngine(self.camr, map_fn)
                results = eng.run(datasets)
                eng.verify(datasets, results)
                report.loads = eng.measured_loads()
                report.bytes_total += eng.trace.total_bytes()
            else:
                eng = UncodedAggregatedEngine(self.q, self.k, 1, map_fn)
                results = eng.run(datasets)
                report.loads = {"L_total_bus": eng.measured_load()}
                report.bytes_total += eng.trace.total_bytes()

            # reduce: worker s holds shard s of every job's summed grad;
            # reassemble per job and update (worker-sharded optimizer).
            for j in range(J):
                shards = [results[s][(j, s)] for s in range(self.K)]
                full = np.concatenate(shards)[:self.D] / N
                grads = self._unravel(jnp.asarray(full))
                self.params[j], self.opts[j], _ = self._upd(
                    self.params[j], grads, self.opts[j])
            report.losses.append(
                [float(np.mean(l)) for l in self._last_loss])
        return report
