"""Batched serving loop: prefill + greedy/temperature decode.

Production shape: requests arrive as (prompt, max_new) pairs; the loop
prefills the batch once, then iterates decode_step with per-sequence
stop handling. (The dry-run serve_step in launch/dryrun.py lowers a
single decode step against the full-length cache; this module is the
host-side loop that drives it.)
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models import lm

__all__ = ["GenerationResult", "generate"]


@dataclass
class GenerationResult:
    tokens: np.ndarray          # [B, T_out]
    steps: int
    prefill_len: int


def generate(cfg: ModelConfig, params, prompts: np.ndarray, *,
             max_new: int = 32, eos: int | None = None,
             temperature: float = 0.0, seed: int = 0,
             extras: dict | None = None) -> GenerationResult:
    """prompts: [B, T_prompt] int32 (right-aligned, no padding support
    needed for the examples). Greedy when temperature == 0."""
    B, T = prompts.shape
    max_len = T + max_new
    batch = {"tokens": jnp.asarray(prompts)}
    if extras:
        batch.update({k: jnp.asarray(v) for k, v in extras.items()})

    prefill = jax.jit(lambda p, b: lm.prefill(cfg, p, b, max_len=max_len))
    step_fn = jax.jit(
        lambda p, c, t, i: lm.decode_step(cfg, p, c, t, i))

    logits, cache = prefill(params, batch)
    key = jax.random.PRNGKey(seed)
    out = [np.asarray(prompts)]
    done = np.zeros(B, bool)
    cur = None
    for i in range(max_new):
        lg = logits[:, -1, :cfg.vocab]       # drop vocab padding
        if temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, lg / temperature)
        else:
            nxt = jnp.argmax(lg, axis=-1)
        cur = np.asarray(nxt, np.int32)[:, None]
        out.append(cur)
        if eos is not None:
            done |= (cur[:, 0] == eos)
            if done.all():
                break
        logits, cache = step_fn(params, cache, jnp.asarray(cur),
                                jnp.int32(T + i))
    return GenerationResult(tokens=np.concatenate(out, axis=1),
                            steps=len(out) - 1, prefill_len=T)
