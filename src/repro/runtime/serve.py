"""Serving runtime: legacy host-loop generate + the device-resident
continuous-batching decode engine (DESIGN.md §13).

Two execution paths share the model code in :mod:`repro.models.lm`:

* :func:`generate` — the HOST loop: one Python iteration and one
  device->host sync per token. After this module's fixes it is
  deterministic past ``eos`` (finished rows emit the eos/pad id, not
  sampled garbage) and compiles its prefill/step closures ONCE per
  ``(cfg, max_len)`` via the process-wide
  :data:`~repro.core.schedule.EXEC_CACHE` instead of on every call.
  It is the bit-level ORACLE the engine is tested against.
* :class:`DecodeEngine` + :class:`ServeStream` — the production shape:
  the token loop is ONE jitted ``lax.while_loop`` carrying
  ``(cache, logits, lengths, done, step, ...)`` on device, KV lives in
  fixed-size paged slots shared by all sequences, and the stream
  admits/evicts requests *between* waves (continuous batching) while
  prefilling incoming requests on a prefetch thread — the same
  double-buffer discipline as :class:`repro.runtime.jobstream.JobStream`
  uses for map vs shuffle. One host round-trip per WAVE, not per token.

Both paths are SELF-HEALING (DESIGN.md §15). Every request ends in a
terminal status from :data:`STATUSES` — ``ok``, ``expired`` (deadline),
``shed`` (bounded admission queue), ``quarantined`` (non-finite logits)
or ``retried_ok`` (finished after >= 1 wave retry). The engine
snapshots its device wave state into a double-buffered slot at every
wave boundary, so the stream's supervisor can retry a crashed or
timed-out wave from the snapshot with bounded backoff — replay is
bitwise-identical to the fault-free run because the snapshot carries
the token buffer, lens/done/emitted, page tables and the per-request
PRNG chains. A device-side NaN/Inf sentinel
(:func:`repro.models.lm.poisoned_rows`) rides in the jitted wave carry
and quarantines exactly the poisoned slot while its batch siblings
continue undisturbed. All snapshot/restore/evict executables live in
the process-wide EXEC_CACHE, so the whole recovery path retraces
NOTHING after warmup.
"""

from __future__ import annotations

import time
from collections import Counter, deque
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs import ModelConfig
from repro.core.schedule import EXEC_CACHE
from repro.models import lm

__all__ = ["GenerationResult", "generate", "serve_legacy", "Request",
           "ServeResult", "STATUSES", "PagePool", "DecodeEngine",
           "ServeStream", "ServeReport", "WaveCrashError",
           "WaveTimeoutError", "trace_total", "TRACE_COUNTS"]

#: terminal request statuses — every submitted request ends in exactly
#: one of these, on both serving paths (DESIGN.md §15)
STATUSES = ("ok", "expired", "shed", "quarantined", "retried_ok")


class WaveCrashError(RuntimeError):
    """A decode wave died before its results could be committed (real
    crash, or injected by the serving chaos layer). The supervisor
    rolls the engine back to the wave-boundary snapshot and retries."""


class WaveTimeoutError(RuntimeError):
    """A decode wave exceeded ``ServeStream.wave_timeout_s``. Treated
    exactly like a crash: its (possibly complete) results are discarded
    and the wave is replayed from the snapshot — replay is bitwise
    equal, so discarding a late wave never changes any token."""


# --------------------------------------------------------------------- #
# compilation accounting
# --------------------------------------------------------------------- #
#: traces per executable-cache key. A bump happens when jax TRACES the
#: wrapped python function — i.e. on every (re)compilation. Steady-state
#: serving (and a second ``generate`` call of the same shape) must not
#: move these counters; tests and the bench recompile gate assert on
#: :func:`trace_total`.
TRACE_COUNTS: Counter = Counter()


def trace_total() -> int:
    """Total number of jit traces paid by the serving entry points."""
    return sum(TRACE_COUNTS.values())


def _counted_jit(key, fn, **jit_kw):
    """``jax.jit(fn)`` that bumps ``TRACE_COUNTS[key]`` at trace time."""

    def traced(*args, **kwargs):
        TRACE_COUNTS[key] += 1
        return fn(*args, **kwargs)

    return jax.jit(traced, **jit_kw)


# --------------------------------------------------------------------- #
# legacy host loop (the oracle)
# --------------------------------------------------------------------- #
@dataclass
class GenerationResult:
    tokens: np.ndarray          # [B, T_out]
    steps: int
    prefill_len: int
    #: host-loop wall time per emitted token (the per-token latency the
    #: serving bench samples p50/p99 from)
    step_times: np.ndarray | None = None


def _legacy_fns(cfg: ModelConfig, max_len: int):
    """Jitted (prefill, decode_step) pair for ``(cfg, max_len)``.

    Hoisted out of :func:`generate` into the process-wide
    :data:`~repro.core.schedule.EXEC_CACHE`: the seed implementation
    built ``jax.jit(lambda ...)`` closures inside the function body, so
    EVERY call retraced and recompiled both.
    """
    key = ("serve_legacy", cfg, max_len)

    def build():
        def prefill_fn(p, b):
            TRACE_COUNTS[key] += 1
            return lm.prefill(cfg, p, b, max_len=max_len)

        def step_fn(p, c, t, i):
            TRACE_COUNTS[key] += 1
            return lm.decode_step(cfg, p, c, t, i)

        return jax.jit(prefill_fn), jax.jit(step_fn)

    return EXEC_CACHE.get(key, build)


def generate(cfg: ModelConfig, params, prompts: np.ndarray, *,
             max_new: int = 32, eos: int | None = None,
             temperature: float = 0.0, seed: int = 0,
             extras: dict | None = None,
             pad: int | None = None) -> GenerationResult:
    """prompts: [B, T_prompt] int32 (right-aligned, no padding support
    needed for the examples). Greedy when temperature == 0.

    Stop handling is deterministic: once a row has emitted ``eos``,
    every later column of that row is ``pad`` (default: the eos id
    itself) — never a sampled token. This fixed behavior is the oracle
    :class:`DecodeEngine` is tested against.
    """
    B, T = prompts.shape
    max_len = T + max_new
    batch = {"tokens": jnp.asarray(prompts)}
    if extras:
        batch.update({k: jnp.asarray(v) for k, v in extras.items()})

    prefill_fn, step_fn = _legacy_fns(cfg, max_len)

    logits, cache = prefill_fn(params, batch)
    key = jax.random.PRNGKey(seed)
    out = [np.asarray(prompts)]
    done = np.zeros(B, bool)
    fill = np.int32(pad if pad is not None else (eos if eos is not None
                                                 else 0))
    times: list[float] = []
    for i in range(max_new):
        t0 = time.perf_counter()
        lg = logits[:, -1, :cfg.vocab]       # drop vocab padding
        if temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, lg / temperature)
        else:
            nxt = jnp.argmax(lg, axis=-1)
        cur = np.asarray(nxt, np.int32)[:, None]
        if eos is not None:
            # finished rows emit the pad/eos id forever (deterministic
            # post-stop tail), never the sampled garbage
            cur = np.where(done[:, None], fill, cur)
            out.append(cur)
            done |= (cur[:, 0] == eos)
            if done.all():
                times.append(time.perf_counter() - t0)
                break
        else:
            out.append(cur)
        logits, cache = step_fn(params, cache, jnp.asarray(cur),
                                jnp.int32(T + i))
        jax.block_until_ready(logits)
        times.append(time.perf_counter() - t0)
    return GenerationResult(tokens=np.concatenate(out, axis=1),
                            steps=len(out) - 1, prefill_len=T,
                            step_times=np.asarray(times))


def serve_legacy(cfg: ModelConfig, params, requests, *,
                 max_queue: int | None = None,
                 shed_policy: str = "newest", clock=None,
                 extras: dict | None = None,
                 model: str = "") -> list:
    """Serve :class:`Request` s through the HOST generate loop with the
    SAME per-request deadline/status accounting as :class:`ServeStream`
    — the enc-dec/frontend configs (and ``--legacy``) get uniform
    :class:`ServeResult` s instead of silently lacking failure fields.

    Sequential FIFO over one model: queue overflow beyond ``max_queue``
    is shed at submission (``shed_policy`` as in the stream), deadlines
    are checked before start and between tokens (an expired request
    keeps its clean prefix), and every request terminates with a status
    from :data:`STATUSES` (``quarantined``/``retried_ok`` never occur —
    the host loop has no shared slots to poison and no wave to retry).
    Tokens are bitwise the :func:`generate` oracle's.
    """
    if shed_policy not in ("newest", "oldest"):
        raise ValueError(f"unknown shed_policy {shed_policy!r}")
    now = clock if clock is not None else time.monotonic
    t_start = now()
    results: list = [None] * len(requests)
    order = deque(enumerate(requests))
    if max_queue is not None:
        while len(order) > max_queue:
            i, req = (order.pop() if shed_policy == "newest"
                      else order.popleft())
            prompt = np.asarray(req.prompt, np.int32)
            results[i] = ServeResult(
                tokens=prompt, prompt_len=prompt.shape[0], emitted=0,
                model=model, index=i, status="shed")
    for i, req in order:
        prompt = np.asarray(req.prompt, np.int32)
        T = prompt.shape[0]
        deadline = (None if req.deadline_s is None
                    else t_start + req.deadline_s)
        if deadline is not None and now() >= deadline:
            results[i] = ServeResult(
                tokens=prompt, prompt_len=T, emitted=0, model=model,
                index=i, status="expired")
            continue
        prefill_fn, step_fn = _legacy_fns(cfg, T + req.max_new)
        batch = {"tokens": jnp.asarray(prompt[None])}
        if extras:
            batch.update({k: jnp.asarray(v) for k, v in extras.items()})
        logits, cache = prefill_fn(params, batch)
        key = jax.random.PRNGKey(req.seed)
        toks: list[int] = []
        status = "ok"
        for t in range(req.max_new):
            if deadline is not None and now() >= deadline:
                status = "expired"      # cancel mid-request, keep prefix
                break
            lg = logits[:, -1, :cfg.vocab]
            if req.temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, lg / req.temperature)
            else:
                nxt = jnp.argmax(lg, axis=-1)
            cur = int(np.asarray(nxt)[0])
            toks.append(cur)
            if req.eos is not None and cur == req.eos:
                break
            if t + 1 < req.max_new:
                logits, cache = step_fn(
                    params, cache, jnp.asarray([[cur]], jnp.int32),
                    jnp.int32(T + t))
        results[i] = ServeResult(
            tokens=np.concatenate([prompt,
                                   np.asarray(toks, np.int32)]),
            prompt_len=T, emitted=len(toks), model=model, index=i,
            status=status)
    return results


# --------------------------------------------------------------------- #
# requests / results
# --------------------------------------------------------------------- #
@dataclass(frozen=True)
class Request:
    """One serving request (a single sequence)."""

    prompt: np.ndarray = field(repr=False)     # [T] int32
    max_new: int = 32
    eos: int | None = None
    temperature: float = 0.0
    seed: int = 0                               # per-request PRNG chain
    pad: int | None = None                      # post-eos fill (def: eos)
    #: wall-clock budget in seconds from submission; None = no deadline.
    #: Checked between waves (engine path) / between tokens (legacy
    #: path): an expired request terminates with status "expired" and
    #: whatever clean tokens it had emitted so far.
    deadline_s: float | None = None

    @property
    def fill(self) -> int:
        if self.pad is not None:
            return self.pad
        return self.eos if self.eos is not None else 0


@dataclass
class ServeResult:
    """Terminated request: ``tokens`` = prompt + generated ids; generated
    cells past the stop point carry the request's pad/eos fill.

    ``status`` is one of :data:`STATUSES` and is UNIFORM across the
    engine and legacy serving paths. Non-``ok`` results still carry
    every clean token emitted before termination (``shed`` requests
    carry none) — a quarantined/expired result's generated prefix is
    bitwise equal to the fault-free run's prefix.
    """

    tokens: np.ndarray
    prompt_len: int
    emitted: int
    model: str = ""
    index: int = -1
    status: str = "ok"
    #: wave retries survived while this request was live on a slot
    retries: int = 0

    @property
    def generated(self) -> np.ndarray:
        return self.tokens[self.prompt_len:]

    @property
    def ok(self) -> bool:
        return self.status in ("ok", "retried_ok")


# --------------------------------------------------------------------- #
# paged KV slots
# --------------------------------------------------------------------- #
class PagePool:
    """Host-side physical-page allocator for the paged KV cache.

    Page 0 is the reserved TRASH page (finished rows' writes are routed
    there on device); pages ``1..n_pages-1`` are allocatable. Allocation
    is deterministic (lowest free ids first) so engine runs are
    reproducible. The invariant the paged cache relies on — no two live
    slots ever share a physical page, and nobody owns the trash page —
    is checkable at any time via :meth:`check_invariants`.
    """

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the trash page)")
        self.n_pages = n_pages
        self._free = list(range(1, n_pages))
        self._owned: dict[int, list[int]] = {}

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def alloc(self, slot: int, n: int) -> list[int] | None:
        """``n`` pages for ``slot``; None when the pool is exhausted
        (the request stays queued until evictions free pages)."""
        if slot in self._owned:
            raise ValueError(f"slot {slot} already owns pages")
        if n > len(self._free):
            return None
        pages, self._free = self._free[:n], self._free[n:]
        self._owned[slot] = pages
        return pages

    def free(self, slot: int) -> None:
        pages = self._owned.pop(slot)
        self._free.extend(pages)
        self._free.sort()

    def check_invariants(self) -> None:
        seen: set[int] = set()
        for slot, pages in self._owned.items():
            for p in pages:
                if p == 0:
                    raise AssertionError(f"slot {slot} owns trash page 0")
                if p in seen:
                    raise AssertionError(
                        f"page {p} aliased by two live slots")
                if not 0 < p < self.n_pages:
                    raise AssertionError(f"page {p} out of range")
                seen.add(p)
        if seen & set(self._free):
            raise AssertionError("page both owned and free")


# --------------------------------------------------------------------- #
# the device-resident decode engine
# --------------------------------------------------------------------- #
class DecodeEngine:
    """Continuous-batching decode engine: paged KV slots + ONE jitted
    ``lax.while_loop`` per wave (DESIGN.md §13).

    ``slots`` sequences decode simultaneously; each may hold up to
    ``pages_per_slot = ceil(max_ctx / page_size)`` pages out of a shared
    pool of ``n_pages`` physical pages (default: enough for every slot
    to max out; pass a smaller pool to get real paging pressure —
    admission then waits for evictions). All per-sequence decode state
    (cache pages, next-token logits, lengths, done flags, PRNG chains,
    emitted-token buffers) lives on device; a wave of up to ``wave_len``
    tokens runs without host contact and only the tiny
    ``done``/``emitted`` vectors sync back.

    Greedy tokens are bit-compatible with the fixed :func:`generate`
    oracle; temperature>0 follows the per-request PRNG chain
    ``PRNGKey(request.seed)`` split once per step — exactly the oracle's
    ``B=1`` chain.
    """

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 page_size: int = 8, max_ctx: int = 64,
                 n_pages: int | None = None, max_new_cap: int = 64,
                 name: str = ""):
        if cfg.family == "encdec" or cfg.frontend:
            raise NotImplementedError(
                f"{cfg.name}: enc-dec / frontend models are served by "
                "the legacy generate() path, not DecodeEngine")
        self.cfg, self.params, self.name = cfg, params, name
        self.slots = slots
        self.page_size = page_size
        self.pages_per_slot = -(-max_ctx // page_size)
        self.capacity = self.pages_per_slot * page_size
        self.max_new_cap = max_new_cap
        self.n_pages = (1 + slots * self.pages_per_slot
                        if n_pages is None else n_pages)
        self.pool = PagePool(self.n_pages)
        self._sig = (slots, self.n_pages, page_size, self.pages_per_slot,
                     max_new_cap)
        self._free_slots = list(range(slots))
        self._live: dict[int, dict] = {}
        self._step_prev = 0
        self.st = self._init_state()
        self._wave_fn = self._build_wave()
        # double-buffered wave-boundary snapshots (DESIGN.md §15): the
        # copy lands in the idle slot and only then does the valid
        # index flip, so a crash mid-snapshot still leaves the previous
        # boundary restorable. Cost: 2x the per-engine state memory,
        # nothing on the wave critical path but one async device copy.
        self._snaps: list = [None, None]
        self._snap_i = 0
        self.rollbacks = 0

    # -- device state --------------------------------------------------- #
    def _init_state(self) -> dict:
        S, V = self.slots, self.cfg.vocab_padded
        return {
            "cache": lm.init_paged_cache(self.cfg, S, self.n_pages,
                                         self.page_size,
                                         self.pages_per_slot),
            "logits": jnp.zeros((S, V), jnp.float32),
            "len": jnp.zeros((S,), jnp.int32),
            "done": jnp.ones((S,), bool),
            "emitted": jnp.zeros((S,), jnp.int32),
            "eos": jnp.full((S,), -1, jnp.int32),
            "cap": jnp.zeros((S,), jnp.int32),
            "fill": jnp.zeros((S,), jnp.int32),
            "temp": jnp.zeros((S,), jnp.float32),
            "keys": jnp.zeros((S, 2), jnp.uint32),
            "buf": jnp.zeros((S, self.max_new_cap), jnp.int32),
            "step": jnp.zeros((), jnp.int32),
            # NaN/Inf sentinel carried by the wave body: True marks a
            # slot whose logits went non-finite (-> quarantined)
            "poison": jnp.zeros((S,), bool),
        }

    # -- jitted executables (EXEC_CACHE-keyed, trace-counted) ----------- #
    def _build_wave(self):
        cfg, S, buf_T = self.cfg, self.slots, self.max_new_cap
        vocab = cfg.vocab
        key = ("serve_wave", cfg) + self._sig

        def build():
            def sample_row(k, lg, temp):
                k2, sub = jax.random.split(k)
                greedy = jnp.argmax(lg).astype(jnp.int32)
                z = (lg / jnp.where(temp > 0, temp, 1.0))[None, :]
                samp = jax.random.categorical(sub, z)[0].astype(jnp.int32)
                return k2, jnp.where(temp > 0, samp, greedy)

            def wave(params, st, wave_len):
                TRACE_COUNTS[key] += 1

                def cond(carry):
                    st, i = carry
                    return (i < wave_len) & ~jnp.all(st["done"])

                def body(carry):
                    st, i = carry
                    # 0. poisoned-slot sentinel (DESIGN.md §15): a live
                    #    row whose carried logits went non-finite stops
                    #    HERE — before its garbage sample could be
                    #    emitted — so its buffer holds exactly the clean
                    #    prefix. Rows are independent through sampling
                    #    and decode, so siblings are undisturbed.
                    bad = lm.poisoned_rows(st["logits"], vocab) \
                        & ~st["done"]
                    poison = st["poison"] | bad
                    # 1. sample from the carried logits (the oracle's
                    #    order: prefill logits feed the first token)
                    keys, nxt = jax.vmap(sample_row)(
                        st["keys"], st["logits"][:, :vocab], st["temp"])
                    # a poisoned row's sample is garbage — feed the
                    #    decode step its pad fill (a valid token id)
                    nxt = jnp.where(bad, st["fill"], nxt)
                    done = st["done"] | bad
                    rows = jnp.arange(S)
                    pos = jnp.minimum(st["emitted"], buf_T - 1)
                    # finished rows re-write their current cell's value
                    # (a no-op) so their tail stays at the pad fill
                    old = st["buf"][rows, pos]
                    buf = st["buf"].at[rows, pos].set(
                        jnp.where(done, old, nxt))
                    emitted = st["emitted"] + jnp.where(done, 0, 1)
                    just_eos = ((~done) & (st["eos"] >= 0)
                                & (nxt == st["eos"]))
                    done2 = done | just_eos | (emitted >= st["cap"])
                    # 2. device-side stop handling: finished rows write
                    #    to the trash page (index -1) and freeze length
                    ci = jnp.where(done2, -1, st["len"])
                    logits, cache = lm.decode_step(
                        cfg, params, st["cache"], nxt[:, None], ci)
                    st2 = dict(st, cache=cache, logits=logits[:, 0],
                               keys=keys, buf=buf, emitted=emitted,
                               done=done2, poison=poison,
                               len=st["len"] + jnp.where(done2, 0, 1),
                               step=st["step"] + 1)
                    return st2, i + 1

                st, _ = lax.while_loop(cond, body, (st, jnp.int32(0)))
                return st

            # params (arg 0) are shared across engines — only the state
            # buffers are donated
            return jax.jit(wave, donate_argnums=(1,))

        return EXEC_CACHE.get(key, build)

    def _prefill_fn(self, T: int):
        cfg = self.cfg
        Tp = -(-T // self.page_size) * self.page_size
        key = ("serve_prefill", cfg, T, Tp)

        def build():
            def pf(params, tokens):
                TRACE_COUNTS[key] += 1
                return lm.prefill(cfg, params, {"tokens": tokens},
                                  max_len=Tp)

            return jax.jit(pf)

        return EXEC_CACHE.get(key, build)

    def _admit_fn(self, T: int):
        cfg = self.cfg
        key = ("serve_admit", cfg, T) + self._sig

        def build():
            def admit(st, slot, pages, pcache, logits0, eos, cap, temp,
                      fill, prng):
                TRACE_COUNTS[key] += 1
                cache = lm.admit_prefill(cfg, st["cache"], pcache, pages,
                                         slot)
                return dict(
                    st, cache=cache,
                    logits=st["logits"].at[slot].set(logits0),
                    len=st["len"].at[slot].set(T),
                    done=st["done"].at[slot].set(False),
                    emitted=st["emitted"].at[slot].set(0),
                    eos=st["eos"].at[slot].set(eos),
                    cap=st["cap"].at[slot].set(cap),
                    temp=st["temp"].at[slot].set(temp),
                    fill=st["fill"].at[slot].set(fill),
                    keys=st["keys"].at[slot].set(prng),
                    buf=st["buf"].at[slot].set(fill),
                    poison=st["poison"].at[slot].set(False),
                )

            return jax.jit(admit, donate_argnums=(0,))

        return EXEC_CACHE.get(key, build)

    def _snap_fn(self):
        """Jitted deep copy of the wave state — fresh device buffers,
        so the original survives the wave executable's donation. Used
        both to TAKE a snapshot (copy ``st``) and to RESTORE one (copy
        the snapshot back, keeping it intact for another retry)."""
        key = ("serve_snapshot", self.cfg) + self._sig

        def build():
            def snap(st):
                TRACE_COUNTS[key] += 1
                return jax.tree.map(jnp.copy, st)

            return jax.jit(snap)

        return EXEC_CACHE.get(key, build)

    def _evict_fn(self):
        """Jitted slot freeze: marks one row done so the wave loop
        stops decoding it (its writes route to the trash page)."""
        key = ("serve_evict", self.cfg) + self._sig

        def build():
            def ev(st, slot):
                TRACE_COUNTS[key] += 1
                return dict(st, done=st["done"].at[slot].set(True))

            return jax.jit(ev, donate_argnums=(0,))

        return EXEC_CACHE.get(key, build)

    def _poison_fn(self):
        """Jitted logit corruption of one slot (chaos injection): the
        next wave body's sentinel must flag exactly this row."""
        key = ("serve_poison", self.cfg) + self._sig

        def build():
            def pz(st, slot):
                TRACE_COUNTS[key] += 1
                row = jnp.full_like(st["logits"][slot], jnp.nan)
                return dict(st, logits=st["logits"].at[slot].set(row))

            return jax.jit(pz, donate_argnums=(0,))

        return EXEC_CACHE.get(key, build)

    # -- host-side protocol --------------------------------------------- #
    @property
    def live(self) -> int:
        return len(self._live)

    @property
    def has_free_slot(self) -> bool:
        return bool(self._free_slots)

    def validate(self, req: Request) -> None:
        T = int(np.asarray(req.prompt).shape[0])
        if T + req.max_new > self.capacity:
            raise ValueError(
                f"request needs {T + req.max_new} cache positions > slot "
                f"capacity {self.capacity} (= pages_per_slot * page_size)")
        if req.max_new > self.max_new_cap:
            raise ValueError(f"max_new {req.max_new} > engine "
                             f"max_new_cap {self.max_new_cap}")
        if -(-(T + req.max_new) // self.page_size) > self.n_pages - 1:
            raise ValueError("request needs more pages than the pool has")

    def prefill(self, req: Request) -> dict:
        """Run (jitted) prefill for a request — safe to call from the
        stream's prefetch thread while a wave is in flight."""
        prompt = np.asarray(req.prompt, np.int32)
        T = prompt.shape[0]
        logits, cache = self._prefill_fn(T)(self.params,
                                            jnp.asarray(prompt[None]))
        return {"T": T, "logits": logits[0, 0], "cache": cache}

    def admit(self, req: Request, pre: dict | None = None,
              handle=None) -> int | None:
        """Admit a request into a free slot (between waves). Returns the
        slot id, or None when no slot / not enough free pages."""
        if not self._free_slots:
            return None
        T = pre["T"] if pre else int(np.asarray(req.prompt).shape[0])
        n_total = -(-(T + req.max_new) // self.page_size)
        slot = self._free_slots[0]
        pages = self.pool.alloc(slot, n_total)
        if pages is None:
            return None          # paging pressure: caller keeps it queued
        self._free_slots.pop(0)
        if pre is None:
            pre = self.prefill(req)
        row = np.zeros(self.pages_per_slot, np.int32)
        row[:n_total] = pages
        eos = -1 if req.eos is None else int(req.eos)
        self.st = self._admit_fn(T)(
            self.st, jnp.int32(slot), jnp.asarray(row), pre["cache"],
            pre["logits"], jnp.int32(eos), jnp.int32(req.max_new),
            jnp.float32(req.temperature), jnp.int32(req.fill),
            jax.random.PRNGKey(req.seed))
        self._live[slot] = {"handle": handle, "prompt_len": T,
                            "prompt": np.asarray(req.prompt, np.int32),
                            "emitted_prev": 0, "retries": 0}
        return slot

    # -- self-healing protocol (DESIGN.md §15) -------------------------- #
    def snapshot(self) -> None:
        """Copy the device wave state into the idle snapshot slot, then
        flip the valid index (the commit point). Called at every wave
        boundary by :meth:`wave`."""
        nxt = 1 - self._snap_i
        self._snaps[nxt] = self._snap_fn()(self.st)
        self._snap_i = nxt

    def rollback(self) -> None:
        """Restore the device state from the latest snapshot (keeping
        the snapshot intact for further retries). Host-side bookkeeping
        (live slots, page tables, emitted counters) needs no restore:
        it only mutates at wave COMMIT and at admissions, both of which
        happen before the snapshot is taken — a crashed attempt never
        touched it."""
        snap = self._snaps[self._snap_i]
        if snap is None:
            raise WaveCrashError(
                f"engine {self.name!r}: no snapshot to roll back to "
                "(crash before the first wave boundary)")
        self.st = self._snap_fn()(snap)
        self.rollbacks += 1

    def mark_retried(self) -> None:
        """Count one survived wave retry on every live request (their
        terminal status becomes ``retried_ok`` instead of ``ok``)."""
        for h in self._live.values():
            h["retries"] += 1

    def poison_slot(self, slot: int) -> None:
        """Chaos injection: corrupt one live slot's carried logits to
        NaN on device. The next wave body's sentinel — not any host
        code — must detect and quarantine it."""
        if slot not in self._live:
            raise ValueError(f"slot {slot} is not live")
        self.st = self._poison_fn()(self.st, jnp.int32(slot))

    def evict(self, slot: int, status: str = "expired"):
        """Evict a LIVE slot between waves (deadline cancellation).
        Freezes the row on device, frees its pages, and returns
        ``(handle, ServeResult)`` carrying the clean tokens emitted so
        far."""
        h = self._live.pop(slot)
        self.st = self._evict_fn()(self.st, jnp.int32(slot))
        e = int(np.asarray(self.st["emitted"])[slot])
        buf = np.asarray(self.st["buf"][slot, :e])
        self.pool.free(slot)
        self._free_slots.append(slot)
        self._free_slots.sort()
        res = ServeResult(
            tokens=np.concatenate([h["prompt"], buf]),
            prompt_len=h["prompt_len"], emitted=e, model=self.name,
            status=status, retries=h["retries"])
        return h["handle"], res

    def run_wave(self, wave_len: int = 8, *, crash_hook=None) -> None:
        """The DEVICE half of a wave: snapshot, then up to ``wave_len``
        jitted decode steps. NO host bookkeeping moves — that is
        :meth:`commit_wave`'s job, so a supervisor can still discard
        this attempt (crash, timeout) via :meth:`rollback` without
        un-winding any host state.

        The wave-boundary snapshot is taken BEFORE the device wave runs
        (the wave executable donates the state buffers, so the copy is
        the only way back). ``crash_hook(engine)``, when given, fires
        after the device wave is dispatched but before any commit — the
        chaos layer raises :class:`WaveCrashError` there, leaving the
        engine exactly as a real mid-wave crash would: advanced device
        state, untouched host bookkeeping, and a valid snapshot to
        :meth:`rollback` to.
        """
        self.snapshot()
        self.st = self._wave_fn(self.params, self.st,
                                jnp.int32(wave_len))
        if crash_hook is not None:
            crash_hook(self)
        # honest attempt timing for the supervisor's timeout check: the
        # wave is only "done" when its buffers are
        jax.block_until_ready(self.st["done"])

    def commit_wave(self):
        """The HOST half of a wave: sync the finished set back, evict
        it, settle token accounting. Returns ``(finished,
        tokens_emitted, steps_run)`` where ``finished`` is a list of
        ``(slot, handle, ServeResult)``. Only call after the attempt is
        accepted — a committed wave cannot be rolled back."""
        done = np.asarray(self.st["done"])
        poison = np.asarray(self.st["poison"])
        emitted = np.asarray(self.st["emitted"])
        step = int(self.st["step"])
        steps_run, self._step_prev = step - self._step_prev, step
        tokens = 0
        for s, h in self._live.items():
            tokens += int(emitted[s]) - h["emitted_prev"]
            h["emitted_prev"] = int(emitted[s])
        newly = [s for s in list(self._live) if done[s]]
        finished = []
        if newly:
            buf = np.asarray(self.st["buf"])
            for s in newly:
                h = self._live.pop(s)
                self.pool.free(s)
                self._free_slots.append(s)
                self._free_slots.sort()
                e = int(emitted[s])
                status = ("quarantined" if poison[s]
                          else "retried_ok" if h["retries"] else "ok")
                res = ServeResult(
                    tokens=np.concatenate([h["prompt"], buf[s, :e]]),
                    prompt_len=h["prompt_len"], emitted=e,
                    model=self.name, status=status,
                    retries=h["retries"])
                finished.append((s, h["handle"], res))
        return finished, tokens, steps_run

    def wave(self, wave_len: int = 8, *, crash_hook=None):
        """One unsupervised wave: :meth:`run_wave` + :meth:`commit_wave`
        back to back (the no-faults fast path)."""
        self.run_wave(wave_len, crash_hook=crash_hook)
        return self.commit_wave()


# --------------------------------------------------------------------- #
# the continuous-batching front door
# --------------------------------------------------------------------- #
@dataclass
class ServeReport:
    """What the last :meth:`ServeStream.run` did."""

    requests: int
    waves: int
    admitted: int
    #: mean fraction of batch slots occupied over executed decode steps
    occupancy: float
    #: per-wave samples: (model, wall_s, steps, tokens, live_slots)
    wave_stats: list = field(default_factory=list, repr=False)
    #: jit traces paid during the run (0 after warmup — the
    #: zero-recompilation admission contract; the RECOVERY path is held
    #: to the same bar)
    traces: int = 0
    pipelined: bool = False
    #: wave retries paid by the supervisor (crashes + timeouts)
    retries: int = 0
    #: terminal-status histogram over this run's requests
    status_counts: dict = field(default_factory=dict)
    #: wall seconds spent on crashed/timed-out wave attempts + rollbacks
    recovery_s: float = 0.0


class ServeStream:
    """Multi-tenant continuous-batching scheduler over
    :class:`DecodeEngine` s — the serving sibling of
    :class:`repro.runtime.jobstream.JobStream`'s wave batcher.

    Requests are FIFO per model. Each scheduler iteration (1) tops up
    the prefill prefetch lane, (2) runs one decode WAVE per engine with
    live work — while the wave occupies the device, the prefetch thread
    drives prefill of queued requests (the JobStream double-buffer
    discipline) — and (3) evicts finished sequences and admits prefilled
    ones into the freed slots. Jitted executables come from the
    process-wide EXEC_CACHE, so steady-state admission pays ZERO new
    compilations.

    Self-healing policy knobs (DESIGN.md §15):

    ``max_queue``        bounds the per-model admission queue; overflow
                         is load-shed at submission with status
                         ``shed`` (``shed_policy`` picks the victim:
                         ``"newest"`` rejects the incoming tail,
                         ``"oldest"`` sheds the stalest queued work).
    ``wave_timeout_s``   a wave observed slower than this is treated as
                         crashed: discarded and replayed from the
                         snapshot (replay is bitwise, so a late wave
                         never changes a token).
    ``max_retries``      attempts per wave before the supervisor gives
                         up and re-raises; backoff between attempts is
                         ``retry_backoff_s * 2**(attempt-1)``.
    ``chaos``            optional fault-injection hook (duck-typed; see
                         tests/chaos.py ``ServeChaosController``):
                         ``on_wave_start(model, wave, engine)`` before
                         each attempt, ``on_wave_crash(model, wave,
                         engine)`` between device wave and commit (may
                         raise :class:`WaveCrashError`), and
                         ``on_wave_done(model, wave, engine, wall_s)``
                         returning the (possibly inflated) wall time.
                         When it provides ``now()``, deadlines run on
                         that virtual clock — fully deterministic
                         replay, no real clocks.
    """

    def __init__(self, engines, *, wave_len: int = 8, prefetch: int = 2,
                 pipeline: bool = True, max_queue: int | None = None,
                 shed_policy: str = "newest",
                 wave_timeout_s: float | None = None,
                 max_retries: int = 2, retry_backoff_s: float = 0.0,
                 chaos=None, clock=None):
        if isinstance(engines, DecodeEngine):
            engines = {"": engines}
        if shed_policy not in ("newest", "oldest"):
            raise ValueError(f"unknown shed_policy {shed_policy!r}")
        self.engines: dict[str, DecodeEngine] = dict(engines)
        self.wave_len = wave_len
        self.prefetch = max(1, prefetch)
        self.pipeline = pipeline
        self.max_queue = max_queue
        self.shed_policy = shed_policy
        self.wave_timeout_s = wave_timeout_s
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s
        self.chaos = chaos
        self._now = (clock if clock is not None
                     else getattr(chaos, "now", None) or time.monotonic)
        self.last_report: ServeReport | None = None

    # -- supervised wave (retry from the wave-boundary snapshot) -------- #
    def _supervised_wave(self, name: str, eng: DecodeEngine, wave: int):
        """One committed wave, surviving up to ``max_retries`` crashed
        or timed-out attempts. Every retry restores the snapshot and
        re-runs the SAME cached executables — zero retraces, bitwise
        replay. Returns ``(finished, tokens, steps, wall_s, retries,
        recovery_s)``."""
        attempt, lost_s = 0, 0.0
        while True:
            t0 = time.perf_counter()
            try:
                if self.chaos is not None:
                    self.chaos.on_wave_start(name, wave, eng)
                hook = None
                if self.chaos is not None:
                    hook = (lambda e: self.chaos.on_wave_crash(
                        name, wave, e))
                eng.run_wave(self.wave_len, crash_hook=hook)
                dt = time.perf_counter() - t0
                if self.chaos is not None:
                    dt = self.chaos.on_wave_done(name, wave, eng, dt)
                # accept/reject BEFORE the host commit: a rejected
                # attempt must leave no trace for rollback to unwind
                if (self.wave_timeout_s is not None
                        and dt > self.wave_timeout_s):
                    raise WaveTimeoutError(
                        f"{name!r} wave {wave}: {dt:.3f}s > "
                        f"wave_timeout_s={self.wave_timeout_s}")
                fin, toks, steps = eng.commit_wave()
                return fin, toks, steps, dt, attempt, lost_s
            except (WaveCrashError, WaveTimeoutError):
                lost_s += time.perf_counter() - t0
                attempt += 1
                if attempt > self.max_retries:
                    raise
                t1 = time.perf_counter()
                eng.rollback()
                eng.mark_retried()
                lost_s += time.perf_counter() - t1
                if self.retry_backoff_s:
                    time.sleep(self.retry_backoff_s
                               * 2 ** (attempt - 1))

    def run(self, requests: Sequence) -> list[ServeResult]:
        """``requests``: a sequence of :class:`Request` (single-engine
        streams) or ``(model_name, Request)`` pairs. Returns results in
        submission order; every result carries a terminal ``status``
        from :data:`STATUSES`."""
        jobs: list[tuple[str, Request]] = []
        for r in requests:
            name, req = r if isinstance(r, tuple) else ("", r)
            if name not in self.engines:
                raise KeyError(f"no engine named {name!r}")
            self.engines[name].validate(req)
            jobs.append((name, req))
        results: list[ServeResult | None] = [None] * len(jobs)
        t_start = self._now()
        deadline_at = [None if req.deadline_s is None
                       else t_start + req.deadline_s
                       for _, req in jobs]

        def terminal(idx: int, status: str) -> None:
            prompt = np.asarray(jobs[idx][1].prompt, np.int32)
            results[idx] = ServeResult(
                tokens=prompt, prompt_len=prompt.shape[0], emitted=0,
                model=jobs[idx][0], index=idx, status=status)

        queues = {n: deque() for n in self.engines}
        for i, (n, req) in enumerate(jobs):
            queues[n].append((i, req))
        # bounded admission: shed queue overflow NOW, at submission —
        # an explicit early "no" beats a deadline miss later
        if self.max_queue is not None:
            for n, q in queues.items():
                while len(q) > self.max_queue:
                    i, _ = (q.pop() if self.shed_policy == "newest"
                            else q.popleft())
                    terminal(i, "shed")
        pending = {n: deque() for n in self.engines}
        t_traces = trace_total()
        stats: list = []
        waves = admitted = retries = 0
        recovery_s = 0.0
        pool = ThreadPoolExecutor(max_workers=1) if self.pipeline else None
        try:
            while any(r is None for r in results):
                progress = False
                now = self._now()
                for name, eng in self.engines.items():
                    q, pend = queues[name], pending[name]
                    # 0. deadline sweep (between waves): expire queued,
                    #    prefetched and LIVE requests past their budget
                    for lane in (q, pend):
                        for item in [it for it in lane
                                     if deadline_at[it[0]] is not None
                                     and now >= deadline_at[it[0]]]:
                            lane.remove(item)
                            terminal(item[0], "expired")
                            progress = True
                    for slot in [s for s, h in list(eng._live.items())
                                 if deadline_at[h["handle"]] is not None
                                 and now >= deadline_at[h["handle"]]]:
                        handle, res = eng.evict(slot, "expired")
                        res.model, res.index = name, handle
                        results[handle] = res
                        progress = True
                    # 1. top up the prefill prefetch lane
                    while q and len(pend) < self.prefetch:
                        idx, req = q.popleft()
                        if pool is not None:
                            fut = pool.submit(eng.prefill, req)
                        else:
                            fut = None
                        pend.append((idx, req, fut))
                        progress = True
                    # 2. decode wave (prefetch thread prefills meanwhile)
                    if eng.live:
                        fin, toks, steps, dt, att, lost = \
                            self._supervised_wave(name, eng, waves)
                        retries += att
                        recovery_s += lost
                        stats.append((name, dt, steps, toks, eng.live
                                      + len(fin)))
                        waves += 1
                        progress = True
                        for _slot, handle, res in fin:
                            res.model, res.index = name, handle
                            results[handle] = res
                    # 3. admit prefilled requests into freed slots
                    while pend and eng.has_free_slot:
                        idx, req, fut = pend[0]
                        pre = fut.result() if fut is not None \
                            else eng.prefill(req)
                        slot = eng.admit(req, pre, handle=idx)
                        if slot is None:
                            break                # pool pressure: wait
                        pend.popleft()
                        admitted += 1
                        progress = True
                if not progress:
                    raise RuntimeError(
                        "serve stream stalled (no admission possible and "
                        "no live work) — request larger than pool?")
        finally:
            if pool is not None:
                pool.shutdown(wait=True)
        slot_steps = sum(s[2] * s[4] for s in stats)
        cap_steps = sum(s[2] * self.engines[s[0]].slots for s in stats)
        counts = Counter(r.status for r in results)  # type: ignore
        self.last_report = ServeReport(
            requests=len(jobs), waves=waves, admitted=admitted,
            occupancy=(slot_steps / cap_steps) if cap_steps else 0.0,
            wave_stats=stats, traces=trace_total() - t_traces,
            pipelined=self.pipeline, retries=retries,
            status_counts=dict(counts), recovery_s=recovery_s)
        return results  # type: ignore[return-value]
