"""Serving runtime: legacy host-loop generate + the device-resident
continuous-batching decode engine (DESIGN.md §13).

Two execution paths share the model code in :mod:`repro.models.lm`:

* :func:`generate` — the HOST loop: one Python iteration and one
  device->host sync per token. After this module's fixes it is
  deterministic past ``eos`` (finished rows emit the eos/pad id, not
  sampled garbage) and compiles its prefill/step closures ONCE per
  ``(cfg, max_len)`` via the process-wide
  :data:`~repro.core.schedule.EXEC_CACHE` instead of on every call.
  It is the bit-level ORACLE the engine is tested against.
* :class:`DecodeEngine` + :class:`ServeStream` — the production shape:
  the token loop is ONE jitted ``lax.while_loop`` carrying
  ``(cache, logits, lengths, done, step, ...)`` on device, KV lives in
  fixed-size paged slots shared by all sequences, and the stream
  admits/evicts requests *between* waves (continuous batching) while
  prefilling incoming requests on a prefetch thread — the same
  double-buffer discipline as :class:`repro.runtime.jobstream.JobStream`
  uses for map vs shuffle. One host round-trip per WAVE, not per token.
"""

from __future__ import annotations

import time
from collections import Counter, deque
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs import ModelConfig
from repro.core.schedule import EXEC_CACHE
from repro.models import lm

__all__ = ["GenerationResult", "generate", "Request", "ServeResult",
           "PagePool", "DecodeEngine", "ServeStream", "ServeReport",
           "trace_total", "TRACE_COUNTS"]


# --------------------------------------------------------------------- #
# compilation accounting
# --------------------------------------------------------------------- #
#: traces per executable-cache key. A bump happens when jax TRACES the
#: wrapped python function — i.e. on every (re)compilation. Steady-state
#: serving (and a second ``generate`` call of the same shape) must not
#: move these counters; tests and the bench recompile gate assert on
#: :func:`trace_total`.
TRACE_COUNTS: Counter = Counter()


def trace_total() -> int:
    """Total number of jit traces paid by the serving entry points."""
    return sum(TRACE_COUNTS.values())


def _counted_jit(key, fn, **jit_kw):
    """``jax.jit(fn)`` that bumps ``TRACE_COUNTS[key]`` at trace time."""

    def traced(*args, **kwargs):
        TRACE_COUNTS[key] += 1
        return fn(*args, **kwargs)

    return jax.jit(traced, **jit_kw)


# --------------------------------------------------------------------- #
# legacy host loop (the oracle)
# --------------------------------------------------------------------- #
@dataclass
class GenerationResult:
    tokens: np.ndarray          # [B, T_out]
    steps: int
    prefill_len: int
    #: host-loop wall time per emitted token (the per-token latency the
    #: serving bench samples p50/p99 from)
    step_times: np.ndarray | None = None


def _legacy_fns(cfg: ModelConfig, max_len: int):
    """Jitted (prefill, decode_step) pair for ``(cfg, max_len)``.

    Hoisted out of :func:`generate` into the process-wide
    :data:`~repro.core.schedule.EXEC_CACHE`: the seed implementation
    built ``jax.jit(lambda ...)`` closures inside the function body, so
    EVERY call retraced and recompiled both.
    """
    key = ("serve_legacy", cfg, max_len)

    def build():
        def prefill_fn(p, b):
            TRACE_COUNTS[key] += 1
            return lm.prefill(cfg, p, b, max_len=max_len)

        def step_fn(p, c, t, i):
            TRACE_COUNTS[key] += 1
            return lm.decode_step(cfg, p, c, t, i)

        return jax.jit(prefill_fn), jax.jit(step_fn)

    return EXEC_CACHE.get(key, build)


def generate(cfg: ModelConfig, params, prompts: np.ndarray, *,
             max_new: int = 32, eos: int | None = None,
             temperature: float = 0.0, seed: int = 0,
             extras: dict | None = None,
             pad: int | None = None) -> GenerationResult:
    """prompts: [B, T_prompt] int32 (right-aligned, no padding support
    needed for the examples). Greedy when temperature == 0.

    Stop handling is deterministic: once a row has emitted ``eos``,
    every later column of that row is ``pad`` (default: the eos id
    itself) — never a sampled token. This fixed behavior is the oracle
    :class:`DecodeEngine` is tested against.
    """
    B, T = prompts.shape
    max_len = T + max_new
    batch = {"tokens": jnp.asarray(prompts)}
    if extras:
        batch.update({k: jnp.asarray(v) for k, v in extras.items()})

    prefill_fn, step_fn = _legacy_fns(cfg, max_len)

    logits, cache = prefill_fn(params, batch)
    key = jax.random.PRNGKey(seed)
    out = [np.asarray(prompts)]
    done = np.zeros(B, bool)
    fill = np.int32(pad if pad is not None else (eos if eos is not None
                                                 else 0))
    times: list[float] = []
    for i in range(max_new):
        t0 = time.perf_counter()
        lg = logits[:, -1, :cfg.vocab]       # drop vocab padding
        if temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, lg / temperature)
        else:
            nxt = jnp.argmax(lg, axis=-1)
        cur = np.asarray(nxt, np.int32)[:, None]
        if eos is not None:
            # finished rows emit the pad/eos id forever (deterministic
            # post-stop tail), never the sampled garbage
            cur = np.where(done[:, None], fill, cur)
            out.append(cur)
            done |= (cur[:, 0] == eos)
            if done.all():
                times.append(time.perf_counter() - t0)
                break
        else:
            out.append(cur)
        logits, cache = step_fn(params, cache, jnp.asarray(cur),
                                jnp.int32(T + i))
        jax.block_until_ready(logits)
        times.append(time.perf_counter() - t0)
    return GenerationResult(tokens=np.concatenate(out, axis=1),
                            steps=len(out) - 1, prefill_len=T,
                            step_times=np.asarray(times))


# --------------------------------------------------------------------- #
# requests / results
# --------------------------------------------------------------------- #
@dataclass(frozen=True)
class Request:
    """One serving request (a single sequence)."""

    prompt: np.ndarray = field(repr=False)     # [T] int32
    max_new: int = 32
    eos: int | None = None
    temperature: float = 0.0
    seed: int = 0                               # per-request PRNG chain
    pad: int | None = None                      # post-eos fill (def: eos)

    @property
    def fill(self) -> int:
        if self.pad is not None:
            return self.pad
        return self.eos if self.eos is not None else 0


@dataclass
class ServeResult:
    """Finished request: ``tokens`` = prompt + generated ids; generated
    cells past the stop point carry the request's pad/eos fill."""

    tokens: np.ndarray
    prompt_len: int
    emitted: int
    model: str = ""
    index: int = -1

    @property
    def generated(self) -> np.ndarray:
        return self.tokens[self.prompt_len:]


# --------------------------------------------------------------------- #
# paged KV slots
# --------------------------------------------------------------------- #
class PagePool:
    """Host-side physical-page allocator for the paged KV cache.

    Page 0 is the reserved TRASH page (finished rows' writes are routed
    there on device); pages ``1..n_pages-1`` are allocatable. Allocation
    is deterministic (lowest free ids first) so engine runs are
    reproducible. The invariant the paged cache relies on — no two live
    slots ever share a physical page, and nobody owns the trash page —
    is checkable at any time via :meth:`check_invariants`.
    """

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the trash page)")
        self.n_pages = n_pages
        self._free = list(range(1, n_pages))
        self._owned: dict[int, list[int]] = {}

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def alloc(self, slot: int, n: int) -> list[int] | None:
        """``n`` pages for ``slot``; None when the pool is exhausted
        (the request stays queued until evictions free pages)."""
        if slot in self._owned:
            raise ValueError(f"slot {slot} already owns pages")
        if n > len(self._free):
            return None
        pages, self._free = self._free[:n], self._free[n:]
        self._owned[slot] = pages
        return pages

    def free(self, slot: int) -> None:
        pages = self._owned.pop(slot)
        self._free.extend(pages)
        self._free.sort()

    def check_invariants(self) -> None:
        seen: set[int] = set()
        for slot, pages in self._owned.items():
            for p in pages:
                if p == 0:
                    raise AssertionError(f"slot {slot} owns trash page 0")
                if p in seen:
                    raise AssertionError(
                        f"page {p} aliased by two live slots")
                if not 0 < p < self.n_pages:
                    raise AssertionError(f"page {p} out of range")
                seen.add(p)
        if seen & set(self._free):
            raise AssertionError("page both owned and free")


# --------------------------------------------------------------------- #
# the device-resident decode engine
# --------------------------------------------------------------------- #
class DecodeEngine:
    """Continuous-batching decode engine: paged KV slots + ONE jitted
    ``lax.while_loop`` per wave (DESIGN.md §13).

    ``slots`` sequences decode simultaneously; each may hold up to
    ``pages_per_slot = ceil(max_ctx / page_size)`` pages out of a shared
    pool of ``n_pages`` physical pages (default: enough for every slot
    to max out; pass a smaller pool to get real paging pressure —
    admission then waits for evictions). All per-sequence decode state
    (cache pages, next-token logits, lengths, done flags, PRNG chains,
    emitted-token buffers) lives on device; a wave of up to ``wave_len``
    tokens runs without host contact and only the tiny
    ``done``/``emitted`` vectors sync back.

    Greedy tokens are bit-compatible with the fixed :func:`generate`
    oracle; temperature>0 follows the per-request PRNG chain
    ``PRNGKey(request.seed)`` split once per step — exactly the oracle's
    ``B=1`` chain.
    """

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 page_size: int = 8, max_ctx: int = 64,
                 n_pages: int | None = None, max_new_cap: int = 64,
                 name: str = ""):
        if cfg.family == "encdec" or cfg.frontend:
            raise NotImplementedError(
                f"{cfg.name}: enc-dec / frontend models are served by "
                "the legacy generate() path, not DecodeEngine")
        self.cfg, self.params, self.name = cfg, params, name
        self.slots = slots
        self.page_size = page_size
        self.pages_per_slot = -(-max_ctx // page_size)
        self.capacity = self.pages_per_slot * page_size
        self.max_new_cap = max_new_cap
        self.n_pages = (1 + slots * self.pages_per_slot
                        if n_pages is None else n_pages)
        self.pool = PagePool(self.n_pages)
        self._sig = (slots, self.n_pages, page_size, self.pages_per_slot,
                     max_new_cap)
        self._free_slots = list(range(slots))
        self._live: dict[int, dict] = {}
        self._step_prev = 0
        self.st = self._init_state()
        self._wave_fn = self._build_wave()

    # -- device state --------------------------------------------------- #
    def _init_state(self) -> dict:
        S, V = self.slots, self.cfg.vocab_padded
        return {
            "cache": lm.init_paged_cache(self.cfg, S, self.n_pages,
                                         self.page_size,
                                         self.pages_per_slot),
            "logits": jnp.zeros((S, V), jnp.float32),
            "len": jnp.zeros((S,), jnp.int32),
            "done": jnp.ones((S,), bool),
            "emitted": jnp.zeros((S,), jnp.int32),
            "eos": jnp.full((S,), -1, jnp.int32),
            "cap": jnp.zeros((S,), jnp.int32),
            "fill": jnp.zeros((S,), jnp.int32),
            "temp": jnp.zeros((S,), jnp.float32),
            "keys": jnp.zeros((S, 2), jnp.uint32),
            "buf": jnp.zeros((S, self.max_new_cap), jnp.int32),
            "step": jnp.zeros((), jnp.int32),
        }

    # -- jitted executables (EXEC_CACHE-keyed, trace-counted) ----------- #
    def _build_wave(self):
        cfg, S, buf_T = self.cfg, self.slots, self.max_new_cap
        vocab = cfg.vocab
        key = ("serve_wave", cfg) + self._sig

        def build():
            def sample_row(k, lg, temp):
                k2, sub = jax.random.split(k)
                greedy = jnp.argmax(lg).astype(jnp.int32)
                z = (lg / jnp.where(temp > 0, temp, 1.0))[None, :]
                samp = jax.random.categorical(sub, z)[0].astype(jnp.int32)
                return k2, jnp.where(temp > 0, samp, greedy)

            def wave(params, st, wave_len):
                TRACE_COUNTS[key] += 1

                def cond(carry):
                    st, i = carry
                    return (i < wave_len) & ~jnp.all(st["done"])

                def body(carry):
                    st, i = carry
                    # 1. sample from the carried logits (the oracle's
                    #    order: prefill logits feed the first token)
                    keys, nxt = jax.vmap(sample_row)(
                        st["keys"], st["logits"][:, :vocab], st["temp"])
                    done = st["done"]
                    rows = jnp.arange(S)
                    pos = jnp.minimum(st["emitted"], buf_T - 1)
                    # finished rows re-write their current cell's value
                    # (a no-op) so their tail stays at the pad fill
                    old = st["buf"][rows, pos]
                    buf = st["buf"].at[rows, pos].set(
                        jnp.where(done, old, nxt))
                    emitted = st["emitted"] + jnp.where(done, 0, 1)
                    just_eos = ((~done) & (st["eos"] >= 0)
                                & (nxt == st["eos"]))
                    done2 = done | just_eos | (emitted >= st["cap"])
                    # 2. device-side stop handling: finished rows write
                    #    to the trash page (index -1) and freeze length
                    ci = jnp.where(done2, -1, st["len"])
                    logits, cache = lm.decode_step(
                        cfg, params, st["cache"], nxt[:, None], ci)
                    st2 = dict(st, cache=cache, logits=logits[:, 0],
                               keys=keys, buf=buf, emitted=emitted,
                               done=done2,
                               len=st["len"] + jnp.where(done2, 0, 1),
                               step=st["step"] + 1)
                    return st2, i + 1

                st, _ = lax.while_loop(cond, body, (st, jnp.int32(0)))
                return st

            # params (arg 0) are shared across engines — only the state
            # buffers are donated
            return jax.jit(wave, donate_argnums=(1,))

        return EXEC_CACHE.get(key, build)

    def _prefill_fn(self, T: int):
        cfg = self.cfg
        Tp = -(-T // self.page_size) * self.page_size
        key = ("serve_prefill", cfg, T, Tp)

        def build():
            def pf(params, tokens):
                TRACE_COUNTS[key] += 1
                return lm.prefill(cfg, params, {"tokens": tokens},
                                  max_len=Tp)

            return jax.jit(pf)

        return EXEC_CACHE.get(key, build)

    def _admit_fn(self, T: int):
        cfg = self.cfg
        key = ("serve_admit", cfg, T) + self._sig

        def build():
            def admit(st, slot, pages, pcache, logits0, eos, cap, temp,
                      fill, prng):
                TRACE_COUNTS[key] += 1
                cache = lm.admit_prefill(cfg, st["cache"], pcache, pages,
                                         slot)
                return dict(
                    st, cache=cache,
                    logits=st["logits"].at[slot].set(logits0),
                    len=st["len"].at[slot].set(T),
                    done=st["done"].at[slot].set(False),
                    emitted=st["emitted"].at[slot].set(0),
                    eos=st["eos"].at[slot].set(eos),
                    cap=st["cap"].at[slot].set(cap),
                    temp=st["temp"].at[slot].set(temp),
                    fill=st["fill"].at[slot].set(fill),
                    keys=st["keys"].at[slot].set(prng),
                    buf=st["buf"].at[slot].set(fill),
                )

            return jax.jit(admit, donate_argnums=(0,))

        return EXEC_CACHE.get(key, build)

    # -- host-side protocol --------------------------------------------- #
    @property
    def live(self) -> int:
        return len(self._live)

    @property
    def has_free_slot(self) -> bool:
        return bool(self._free_slots)

    def validate(self, req: Request) -> None:
        T = int(np.asarray(req.prompt).shape[0])
        if T + req.max_new > self.capacity:
            raise ValueError(
                f"request needs {T + req.max_new} cache positions > slot "
                f"capacity {self.capacity} (= pages_per_slot * page_size)")
        if req.max_new > self.max_new_cap:
            raise ValueError(f"max_new {req.max_new} > engine "
                             f"max_new_cap {self.max_new_cap}")
        if -(-(T + req.max_new) // self.page_size) > self.n_pages - 1:
            raise ValueError("request needs more pages than the pool has")

    def prefill(self, req: Request) -> dict:
        """Run (jitted) prefill for a request — safe to call from the
        stream's prefetch thread while a wave is in flight."""
        prompt = np.asarray(req.prompt, np.int32)
        T = prompt.shape[0]
        logits, cache = self._prefill_fn(T)(self.params,
                                            jnp.asarray(prompt[None]))
        return {"T": T, "logits": logits[0, 0], "cache": cache}

    def admit(self, req: Request, pre: dict | None = None,
              handle=None) -> int | None:
        """Admit a request into a free slot (between waves). Returns the
        slot id, or None when no slot / not enough free pages."""
        if not self._free_slots:
            return None
        T = pre["T"] if pre else int(np.asarray(req.prompt).shape[0])
        n_total = -(-(T + req.max_new) // self.page_size)
        slot = self._free_slots[0]
        pages = self.pool.alloc(slot, n_total)
        if pages is None:
            return None          # paging pressure: caller keeps it queued
        self._free_slots.pop(0)
        if pre is None:
            pre = self.prefill(req)
        row = np.zeros(self.pages_per_slot, np.int32)
        row[:n_total] = pages
        eos = -1 if req.eos is None else int(req.eos)
        self.st = self._admit_fn(T)(
            self.st, jnp.int32(slot), jnp.asarray(row), pre["cache"],
            pre["logits"], jnp.int32(eos), jnp.int32(req.max_new),
            jnp.float32(req.temperature), jnp.int32(req.fill),
            jax.random.PRNGKey(req.seed))
        self._live[slot] = {"handle": handle, "prompt_len": T,
                            "prompt": np.asarray(req.prompt, np.int32),
                            "emitted_prev": 0}
        return slot

    def wave(self, wave_len: int = 8):
        """Run up to ``wave_len`` decode steps on device, then sync the
        finished set back and evict it. Returns
        ``(finished, tokens_emitted, steps_run)`` where ``finished`` is
        a list of ``(slot, handle, ServeResult)``."""
        self.st = self._wave_fn(self.params, self.st,
                                jnp.int32(wave_len))
        done = np.asarray(self.st["done"])
        emitted = np.asarray(self.st["emitted"])
        step = int(self.st["step"])
        steps_run, self._step_prev = step - self._step_prev, step
        tokens = 0
        for s, h in self._live.items():
            tokens += int(emitted[s]) - h["emitted_prev"]
            h["emitted_prev"] = int(emitted[s])
        newly = [s for s in list(self._live) if done[s]]
        finished = []
        if newly:
            buf = np.asarray(self.st["buf"])
            for s in newly:
                h = self._live.pop(s)
                self.pool.free(s)
                self._free_slots.append(s)
                self._free_slots.sort()
                e = int(emitted[s])
                res = ServeResult(
                    tokens=np.concatenate([h["prompt"], buf[s, :e]]),
                    prompt_len=h["prompt_len"], emitted=e,
                    model=self.name)
                finished.append((s, h["handle"], res))
        return finished, tokens, steps_run


# --------------------------------------------------------------------- #
# the continuous-batching front door
# --------------------------------------------------------------------- #
@dataclass
class ServeReport:
    """What the last :meth:`ServeStream.run` did."""

    requests: int
    waves: int
    admitted: int
    #: mean fraction of batch slots occupied over executed decode steps
    occupancy: float
    #: per-wave samples: (model, wall_s, steps, tokens, live_slots)
    wave_stats: list = field(default_factory=list, repr=False)
    #: jit traces paid during the run (0 after warmup — the
    #: zero-recompilation admission contract)
    traces: int = 0
    pipelined: bool = False


class ServeStream:
    """Multi-tenant continuous-batching scheduler over
    :class:`DecodeEngine` s — the serving sibling of
    :class:`repro.runtime.jobstream.JobStream`'s wave batcher.

    Requests are FIFO per model. Each scheduler iteration (1) tops up
    the prefill prefetch lane, (2) runs one decode WAVE per engine with
    live work — while the wave occupies the device, the prefetch thread
    drives prefill of queued requests (the JobStream double-buffer
    discipline) — and (3) evicts finished sequences and admits prefilled
    ones into the freed slots. Jitted executables come from the
    process-wide EXEC_CACHE, so steady-state admission pays ZERO new
    compilations.
    """

    def __init__(self, engines, *, wave_len: int = 8, prefetch: int = 2,
                 pipeline: bool = True):
        if isinstance(engines, DecodeEngine):
            engines = {"": engines}
        self.engines: dict[str, DecodeEngine] = dict(engines)
        self.wave_len = wave_len
        self.prefetch = max(1, prefetch)
        self.pipeline = pipeline
        self.last_report: ServeReport | None = None

    def run(self, requests: Sequence) -> list[ServeResult]:
        """``requests``: a sequence of :class:`Request` (single-engine
        streams) or ``(model_name, Request)`` pairs. Returns results in
        submission order."""
        jobs: list[tuple[str, Request]] = []
        for r in requests:
            name, req = r if isinstance(r, tuple) else ("", r)
            if name not in self.engines:
                raise KeyError(f"no engine named {name!r}")
            self.engines[name].validate(req)
            jobs.append((name, req))
        results: list[ServeResult | None] = [None] * len(jobs)
        queues = {n: deque() for n in self.engines}
        for i, (n, req) in enumerate(jobs):
            queues[n].append((i, req))
        pending = {n: deque() for n in self.engines}
        t_traces = trace_total()
        stats: list = []
        waves = admitted = 0
        pool = ThreadPoolExecutor(max_workers=1) if self.pipeline else None
        try:
            while any(r is None for r in results):
                progress = False
                for name, eng in self.engines.items():
                    q, pend = queues[name], pending[name]
                    # 1. top up the prefill prefetch lane
                    while q and len(pend) < self.prefetch:
                        idx, req = q.popleft()
                        if pool is not None:
                            fut = pool.submit(eng.prefill, req)
                        else:
                            fut = None
                        pend.append((idx, req, fut))
                        progress = True
                    # 2. decode wave (prefetch thread prefills meanwhile)
                    if eng.live:
                        t0 = time.perf_counter()
                        fin, toks, steps = eng.wave(self.wave_len)
                        dt = time.perf_counter() - t0
                        stats.append((name, dt, steps, toks, eng.live
                                      + len(fin)))
                        waves += 1
                        progress = True
                        for _slot, handle, res in fin:
                            res.model, res.index = name, handle
                            results[handle] = res
                    # 3. admit prefilled requests into freed slots
                    while pend and eng.has_free_slot:
                        idx, req, fut = pend[0]
                        pre = fut.result() if fut is not None \
                            else eng.prefill(req)
                        slot = eng.admit(req, pre, handle=idx)
                        if slot is None:
                            break                # pool pressure: wait
                        pend.popleft()
                        admitted += 1
                        progress = True
                if not progress:
                    raise RuntimeError(
                        "serve stream stalled (no admission possible and "
                        "no live work) — request larger than pool?")
        finally:
            if pool is not None:
                pool.shutdown(wait=True)
        slot_steps = sum(s[2] * s[4] for s in stats)
        cap_steps = sum(s[2] * self.engines[s[0]].slots for s in stats)
        self.last_report = ServeReport(
            requests=len(jobs), waves=waves, admitted=admitted,
            occupancy=(slot_steps / cap_steps) if cap_steps else 0.0,
            wave_stats=stats, traces=trace_total() - t_traces,
            pipelined=self.pipeline)
        return results  # type: ignore[return-value]
