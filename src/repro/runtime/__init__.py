"""Distributed runtime: training loops, fault tolerance, serving."""

from .train_loop import Trainer, MultiModelCAMRTrainer
from . import fault, serve

__all__ = ["Trainer", "MultiModelCAMRTrainer", "fault", "serve"]
