"""Distributed runtime: training loops, fault tolerance, serving, and
the pipelined multi-wave JobStream scheduler (DESIGN.md §9)."""

from .train_loop import Trainer, MultiModelCAMRTrainer
from .jobstream import JobSpec, JobStream, StreamReport
from .serve import (DecodeEngine, GenerationResult, PagePool, Request,
                    ServeResult, ServeStream, ServeReport, generate)
from . import fault, serve

__all__ = ["Trainer", "MultiModelCAMRTrainer", "JobSpec", "JobStream",
           "StreamReport", "fault", "serve", "generate",
           "GenerationResult", "Request", "ServeResult", "PagePool",
           "DecodeEngine", "ServeStream", "ServeReport"]
