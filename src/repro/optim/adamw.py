"""AdamW with decoupled weight decay and global-norm clipping.

States are pytrees mirroring the params; moments are f32 regardless of the
param dtype (bf16-safe). Under pjit the states inherit the params'
shardings (same logical axes), which is exactly ZeRO-1 when params are
FSDP-sharded.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "adamw_init", "adamw_update",
           "clip_by_global_norm"]


class AdamWState(NamedTuple):
    step: jnp.ndarray     # i32 scalar
    mu: Any               # first moment (f32 tree)
    nu: Any               # second moment (f32 tree)


def adamw_init(params) -> AdamWState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(f32, params),
                      nu=jax.tree.map(f32, params))


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                      for l in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


def adamw_update(params, grads, state: AdamWState, *, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1,
                 max_grad_norm: float | None = 1.0):
    """One AdamW step. ``lr`` may be a scalar or a schedule value."""
    if max_grad_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    else:
        gnorm = jnp.zeros(())
    step = state.step + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * jnp.square(gf)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + \
            weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu), gnorm


