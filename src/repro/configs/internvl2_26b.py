"""internvl2-26b [vlm]: InternViT frontend (stub) + InternLM2 backbone.

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553
[arXiv:2404.16821; hf]. The ViT frontend is a STUB per the assignment:
``input_specs`` provides precomputed patch embeddings (1024-dim InternViT
features after pixel-shuffle), projected into the LM by params['front'].
"""

from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92553,
    rope_theta=1e6,
    pattern=("attn",),
    frontend="vit",
    frontend_dim=1024,
    frontend_len=256,          # patch tokens prepended to the sequence
    microbatches=2,
)
