"""seamless-m4t-large-v2 [audio]: enc-dec, 24L (each side) d_model=1024
16H (kv=16) d_ff=8192 vocab=256206 [arXiv:2308.11596; hf].

The speech frontend is a STUB per the assignment: ``input_specs``
provides precomputed 80-dim filterbank frame features; params['front']
projects them into the encoder. Decoder layers carry cross-attention to
the encoder memory; decode caches both self and cross K/V."""

from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,               # decoder sublayers
    n_enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256206,
    pattern=("attn",),
    frontend="audio",
    frontend_dim=80,
)
