"""mamba2-1.3b [ssm]: 48L d_model=2048 attention-free vocab=50280,
ssm_state=128 — SSD (state-space duality) [arXiv:2405.21060; unverified].

The depthwise conv1d of the reference implementation is omitted
(DESIGN.md §8); the SSD core (the paper's contribution and the compute
hot-spot) is kernels/ssd_scan.py.
"""

from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=1,                 # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab=50280,
    pattern=("ssm",),
    ssm_state=128,
    ssm_heads=64,              # d_inner 4096 / headdim 64
    ssm_d_inner=4096,
    ssm_chunk=64,              # see zamba2_2p7b.py note
    microbatches=2,
)
