"""Architecture configs (assigned pool) + shape specs + registry.

Every architecture is a :class:`ModelConfig`; ``reduced(cfg)`` derives the
small same-family variant used by CPU smoke tests. ``input_specs`` builds
the ShapeDtypeStruct stand-ins the dry-run lowers against (no allocation).
"""

from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

__all__ = ["ModelConfig", "ShapeSpec", "SHAPES", "ARCHS", "get_config",
           "reduced", "list_archs", "shape_supported"]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec
    n_layers: int               # total sublayers (pattern * repeats)
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    # layer stacking: `pattern` is the repeating unit of sublayer kinds
    #   'attn'        causal (optionally windowed) attention + MLP/MoE
    #   'local'       sliding-window attention + MLP (gemma2 alternation)
    #   'ssm'         Mamba2 SSD block
    #   'shared_attn' attention block with weights SHARED across repeats
    pattern: tuple = ("attn",)
    rope_theta: float = 1e4
    window: int | None = None           # SWA width for 'attn' layers
    local_window: int | None = None     # width for 'local' layers
    attn_softcap: float | None = None
    final_softcap: float | None = None
    mlp_act: str = "swiglu"             # swiglu | geglu
    tie_embeddings: bool = False
    scale_embed: bool = False           # gemma2 sqrt(d) embedding scale
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    moe_shard_mode: str = "ep"          # ep | tp  (see layers.spec_moe)
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_d_inner: int = 0
    # enc-dec
    n_enc_layers: int = 0
    # modality frontend stub
    frontend: str | None = None         # vit | audio
    frontend_dim: int = 0               # precomputed feature dim
    frontend_len: int = 0               # prefix length (vlm patches)
    # numerics / execution
    dtype: str = "bfloat16"
    use_pallas: bool = False
    remat: str = "block"                # none | block
    loss_chunk: int = 1024              # vocab-logit seq chunking
    microbatches: int = 1               # grad-accumulation inside train_step
    scan_unroll: bool = False           # unroll scans (trip-true HLO cost
    #                                     analysis in the dry-run; scanned
    #                                     form is the production default)
    attn_block: int = 1024              # XLA-lane flash block size
    ssm_chunk: int = 256                # XLA-lane SSD chunk length
    # paper integration: gradient sync mode for the data-parallel axis
    grad_sync: str = "allreduce"        # allreduce | camr
    grad_sync_dtype: str = "float32"    # float32 | bfloat16 — bf16 syncs
    #                                     gradients on the packed 16-bit
    #                                     codec lane at half the bytes,
    #                                     f32 master params (DESIGN.md
    #                                     §12; MultiModelCAMRTrainer and
    #                                     launch/train.py
    #                                     --grad-sync-dtype)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Embedding/logit table padded to 128 (vocab-parallel sharding +
        MXU alignment — Megatron-style). Logits beyond ``vocab`` are
        sliced off in the loss and by consumers."""
        return -(-self.vocab // 128) * 128

    @property
    def repeats(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, (
            f"{self.name}: n_layers {self.n_layers} not a multiple of "
            f"pattern {self.pattern}")
        return self.n_layers // len(self.pattern)

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # parameter count (for 6ND model-FLOPs roofline accounting)
    def param_count(self, active_only: bool = False) -> int:
        d, f, hd = self.d_model, self.d_ff, self.hd
        attn = d * self.n_heads * hd * 2 + d * self.n_kv_heads * hd * 2
        mlp = 3 * d * f
        if self.n_experts:
            e = self.experts_per_token if active_only else self.n_experts
            mlp = 3 * d * f * e + d * self.n_experts  # experts + router
        di, H, S = self.ssm_d_inner, self.ssm_heads, self.ssm_state
        ssm = 2 * d * di + d * 2 * S + d * H + di * d  # B/C group-shared
        per = {"attn": attn + mlp, "local": attn + mlp,
               "shared_attn": attn + mlp, "ssm": ssm + d}
        reps = self.repeats
        total = 0
        for kind in self.pattern:
            n = reps if kind != "shared_attn" else 1  # shared weights
            total += per[kind] * n
        total += self.n_enc_layers * (attn + 3 * d * f)
        total += self.vocab * d * (1 if self.tie_embeddings else 2)
        return int(total)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

ARCHS = [
    "internvl2_26b", "mixtral_8x7b", "moonshot_v1_16b_a3b", "internlm2_20b",
    "gemma2_2b", "mistral_large_123b", "granite_3_2b", "zamba2_2p7b",
    "mamba2_1p3b", "seamless_m4t_large_v2",
]


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{name.replace('-', '_')}")
    return mod.CONFIG


def list_archs() -> list[str]:
    return list(ARCHS)


def shape_supported(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """long_500k only for sub-quadratic archs (DESIGN.md §6)."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, ("full/global-attention arch: 500k ctx needs a "
                       "per-layer 500k KV cache + quadratic prefill "
                       "(see DESIGN.md §6)")
    return True, ""


# --------------------------------------------------------------------- #
# reduced configs for CPU smoke tests
# --------------------------------------------------------------------- #
def reduced(cfg: ModelConfig) -> ModelConfig:
    """Small same-family variant: few layers, tiny widths/tables."""
    kw = dict(
        n_layers=2 * len(cfg.pattern), d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab=256, dtype="float32", loss_chunk=64,
        microbatches=1,
    )
    if cfg.n_experts:
        # capacity 8x: no token drops -> deterministic consistency tests
        kw.update(n_experts=4, experts_per_token=2,
                  moe_capacity_factor=8.0)
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_heads=4, ssm_d_inner=128)
    if cfg.n_enc_layers:
        kw.update(n_enc_layers=2)
    if cfg.frontend:
        kw.update(frontend_dim=24, frontend_len=8)
    if cfg.local_window:
        kw.update(local_window=32)
    if cfg.window:
        kw.update(window=32)
    return cfg.replace(**kw)


# --------------------------------------------------------------------- #
# dry-run input specs (ShapeDtypeStructs; no allocation)
# --------------------------------------------------------------------- #
def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Stand-ins for every model input of the step function for
    (cfg, shape). See repro.models.lm for the matching step signatures."""
    from repro.models import lm  # late import; jax-touching module

    B, T = shape.global_batch, shape.seq_len
    i32, f = jnp.int32, cfg.jdtype
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        batch = {"tokens": sds((B, T), i32), "labels": sds((B, T), i32)}
        if cfg.frontend == "vit":
            batch["patches"] = sds((B, cfg.frontend_len, cfg.frontend_dim),
                                   f)
        if cfg.frontend == "audio":
            batch["frames"] = sds((B, T, cfg.frontend_dim), f)
        return {"batch": batch}
    if shape.kind == "prefill":
        batch = {"tokens": sds((B, T), i32)}
        if cfg.frontend == "vit":
            batch["patches"] = sds((B, cfg.frontend_len, cfg.frontend_dim),
                                   f)
        if cfg.frontend == "audio":
            batch["frames"] = sds((B, T, cfg.frontend_dim), f)
        return {"batch": batch}
    # decode: one new token against a full-length cache
    cache = jax.eval_shape(lambda: lm.init_cache(cfg, B, T))
    return {"tokens": sds((B, 1), i32), "cache": cache,
            "cache_index": sds((), i32)}
