"""mixtral-8x7b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8 experts top-2, sliding-window attention
[arXiv:2401.04088; hf].

8 experts < the 16-way model axis, so experts are tensor-parallel
('tp' shard mode: every chip holds a d_ff slice of all 8 experts).
"""

from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    rope_theta=1e6,
    window=4096,               # SWA
    pattern=("attn",),
    n_experts=8,
    experts_per_token=2,
    moe_shard_mode="tp",
    microbatches=2,
)
