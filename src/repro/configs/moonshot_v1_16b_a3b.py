"""moonshot-v1-16b-a3b [moe] (kimi/moonlight): 48L d_model=2048 16H
(kv=16) d_ff=1408 (per expert) vocab=163840, MoE 64 experts top-6
[hf:moonshotai/Moonlight-16B-A3B; hf].

64 experts over the 16-way model axis -> 4 experts/chip (EP mode).
"""

from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=163840,
    rope_theta=5e4,
    pattern=("attn",),
    n_experts=64,
    experts_per_token=6,
    moe_shard_mode="ep",
)
