"""zamba2-2.7b [hybrid]: 54 sublayers d_model=2560 32H (kv=32)
d_ff=10240 vocab=32000, ssm_state=64 — Mamba2 backbone with SHARED
attention blocks interleaved [arXiv:2411.15242; hf].

Pattern: 5 Mamba2 sublayers + 1 shared-weight attention block, repeated
9x (the 'shared_attn' kind reuses ONE parameter set across all 9
occurrences, faithful to Zamba2's shared-block design)."""

from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    pattern=("ssm", "ssm", "ssm", "ssm", "ssm", "shared_attn"),
    ssm_state=64,
    ssm_heads=80,              # d_inner 5120 / headdim 64
    ssm_d_inner=5120,
    # chunk 64: the intra-chunk decay tensor [B, C, C, H] is the SSD
    # memory driver — 2.1 GiB at C=64 vs 33 GiB at C=256 (§Perf)
    ssm_chunk=64,
    microbatches=2,
)
