"""The paper's own Example-1 workload as a config: J=4 word-count jobs on
K=6 servers (q=2, k=3, gamma=2). Used by examples/quickstart.py and the
benchmark harness; not an LM architecture."""

CAMR_PARAMS = dict(q=2, k=3, gamma=2)
